//! Adaptive bit-width scheduling ablation on *measured* model statistics:
//! the `quant::schedule` planner vs every static uniform allocation at equal
//! total wire bits, on the WGAN dual stream and the transformer-LM gradient
//! stream, plus the error-feedback (EF14) leg and a live scheduled WGAN
//! training run.
//!
//! The headline comparison is a certificate, not a benchmark: for each
//! static width `b` the planner is granted the static allocation's own true
//! wire cost plus only the L-GreCo DP's ceil-discretization headroom
//! (`matched_budget`, < 0.2%). The uniform-`b` choice is then reachable in
//! the DP's state space and the DP minimizes weighted quantization error
//! over that set, so the adaptive plan can never have higher error — the
//! asserts below encode exactly that, and the heterogeneous per-layer
//! statistics of real models are where it wins outright.
//!
//! Emits `adaptive/*` records into `results/BENCH_comm.json` (merge-write;
//! CI's perf-gate requires the prefix).
//!
//! Run: `cargo run --release --example adaptive_sweep -- [--steps 30]`

use qoda::bench_harness::experiments::{matched_budget, static_allocation};
use qoda::bench_harness::JsonBench;
use qoda::coding::protocol::ProtocolKind;
use qoda::comm::{Adaptation, Compressor, FeedbackCompressor, QuantCompressor};
use qoda::gan::{train, GanCompression, GanTrainConfig};
use qoda::lm::Corpus;
use qoda::quant::adaptive::TypeStats;
use qoda::quant::layer_map::LayerMap;
use qoda::quant::{lgreco, schedule, QuantConfig};
use qoda::runtime::{LmModel, Runtime, WganModel};
use qoda::util::cli::Args;
use qoda::util::table::Table;

const MAX_BITS: u32 = 6;

/// Fold `samples` measured vectors into per-type histograms along the
/// model's own layer map — the exact fold `Adaptation::Scheduled` performs
/// on decoded packets.
fn fold_stats(map: &LayerMap, draws: &[Vec<f32>]) -> Vec<TypeStats> {
    let mut stats: Vec<TypeStats> =
        (0..map.num_types()).map(|_| TypeStats::default()).collect();
    for v in draws {
        assert_eq!(v.len(), map.dim, "draw length != map dim");
        for l in &map.layers {
            stats[l.type_id].add_layer_sample(&v[l.offset..l.offset + l.len], 2.0);
        }
    }
    stats
}

/// The matched-budget sweep for one workload: one table row and one bench
/// record per static width, with the never-loses certificate asserted and
/// at least one strict win demanded.
fn sweep_workload(
    name: &str,
    map: &LayerMap,
    stats: &[TypeStats],
    bench: &mut JsonBench,
) -> Table {
    let ladder = lgreco::alpha_ladder(MAX_BITS);
    let problems = schedule::type_problems(map, stats, &ladder);
    let mut t = Table::new(
        &format!("{name}: adaptive schedule vs static uniform widths (equal wire bits)"),
        &["static width", "bits/coord", "static err", "adaptive err", "err ratio"],
    );
    let mut strict_win = false;
    for b in 1..=MAX_BITS as usize {
        let (cost, err) = static_allocation(&problems, b);
        let budget = matched_budget(cost, problems.len());
        let plan = schedule::plan(map, stats, budget / map.dim as f64, MAX_BITS);
        assert!(
            plan.total_bits <= budget,
            "{name} b={b}: plan spent {} of budget {budget}",
            plan.total_bits
        );
        assert!(
            plan.total_err <= err * (1.0 + 1e-12),
            "{name} b={b}: adaptive err {} above static {err}",
            plan.total_err
        );
        if plan.total_err < err * (1.0 - 1e-9) {
            strict_win = true;
        }
        let ratio = if plan.total_err > 0.0 { err / plan.total_err } else { 1.0 };
        t.row(&[
            format!("{b}-bit"),
            format!("{:.3}", cost / map.dim as f64),
            format!("{err:.6}"),
            format!("{:.6}", plan.total_err),
            format!("{ratio:.3}x"),
        ]);
        bench.push(
            &format!("adaptive/{name}/static_{b}bit"),
            &[
                ("bits_per_coord", format!("{:.4}", cost / map.dim as f64)),
                ("static_err", format!("{err:.6}")),
                ("adaptive_err", format!("{:.6}", plan.total_err)),
                ("err_ratio", format!("{ratio:.4}")),
            ],
        );
    }
    assert!(
        strict_win,
        "{name}: adaptive never improved on any static width — \
         the measured statistics should be heterogeneous"
    );
    t
}

fn main() -> qoda::util::error::Result<()> {
    let args = Args::from_env();
    let steps = args.usize_or("steps", 30)?;
    let rt = Runtime::cpu()?;
    let mut bench = JsonBench::new();

    // --- WGAN: measured dual-vector statistics ------------------------------
    let wgan = WganModel::load(&rt)?;
    let params = wgan.init_params(1)?;
    let draws: Vec<Vec<f32>> = (0..6)
        .map(|s| wgan.dual(&params, 1000 + s).map(|(d, _, _)| d))
        .collect::<qoda::util::error::Result<_>>()?;
    let wgan_stats = fold_stats(&wgan.meta, &draws);
    let t = sweep_workload("wgan", &wgan.meta, &wgan_stats, &mut bench);
    t.print();

    // --- transformer LM: measured gradient statistics -----------------------
    let lm = LmModel::load(&rt)?;
    let lm_params = lm.init_params(1)?;
    let mut corpus = Corpus::new(lm.vocab, 42);
    let draws: Vec<Vec<f32>> = (0..6)
        .map(|_| {
            let tokens = corpus.batch(lm.batch, lm.seq);
            lm.grad(&lm_params, &tokens).map(|(g, _)| g)
        })
        .collect::<qoda::util::error::Result<_>>()?;
    let lm_stats = fold_stats(&lm.meta, &draws);
    let t = sweep_workload("lm", &lm.meta, &lm_stats, &mut bench);
    t.print();
    println!("\nadaptive never loses at equal wire bits and wins strictly on both workloads: ok");

    // --- error feedback on the real WGAN dual stream ------------------------
    // the EF telescoping property: the accumulated decoded stream tracks the
    // accumulated input stream to within one residual, while the plain
    // codec's quantization errors add up independently
    let map = wgan.meta.bucketed(128);
    let quant = |seed: u64| -> Box<dyn Compressor> {
        Box::new(QuantCompressor::new(
            map.clone(),
            QuantConfig::uniform_bits(map.num_types(), 2, 2.0),
            ProtocolKind::Main,
            Adaptation::Fixed,
            seed,
        ))
    };
    let mut ef = FeedbackCompressor::new(quant(7));
    let mut plain = quant(7);
    let dim = map.dim;
    let (mut sum_v, mut sum_ef, mut sum_plain) =
        (vec![0.0f64; dim], vec![0.0f64; dim], vec![0.0f64; dim]);
    for s in 0..20 {
        let (d, _, _) = wgan.dual(&params, 2000 + s)?;
        let v: Vec<f64> = d.iter().map(|&x| x as f64).collect();
        let comm = |e: qoda::comm::CommError| qoda::util::error::Error::msg(e.to_string());
        let pe = ef.encode(&v).map_err(comm)?;
        let de = ef.decode(&pe).map_err(comm)?;
        let pp = plain.encode(&v).map_err(comm)?;
        let dp = plain.decode(&pp).map_err(comm)?;
        for i in 0..dim {
            sum_v[i] += v[i];
            sum_ef[i] += de[i];
            sum_plain[i] += dp[i];
        }
    }
    let err = |s: &[f64]| -> f64 {
        s.iter().zip(&sum_v).map(|(a, b)| (a - b) * (a - b)).sum::<f64>()
    };
    let (e_ef, e_plain) = (err(&sum_ef), err(&sum_plain));
    assert!(
        e_ef < e_plain,
        "error feedback must shrink the accumulated 2-bit error: {e_ef} vs {e_plain}"
    );
    println!(
        "error feedback, 2-bit wire, 20 real WGAN duals: accumulated err {e_ef:.4} \
         vs {e_plain:.4} plain ({:.1}x smaller)",
        e_plain / e_ef
    );
    bench.push(
        "adaptive/wgan/error_feedback",
        &[
            ("accum_err_ef", format!("{e_ef:.6}")),
            ("accum_err_plain", format!("{e_plain:.6}")),
            ("gain", format!("{:.4}", e_plain / e_ef)),
        ],
    );

    // --- a live scheduled WGAN training run ---------------------------------
    // the whole loop end-to-end: decode-count-triggered re-planning + EF,
    // against the static layer-wise baseline at a comparable budget
    let mut rt_table = Table::new(
        &format!("WGAN {steps}-step run, K=4 (scheduled vs static layer-wise)"),
        &["compression", "final FID", "wire MB", "step ms"],
    );
    let scheduled = GanCompression::Scheduled {
        budget: 4.0,
        bucket: 128,
        every: 10,
        error_feedback: true,
    };
    let baseline = GanCompression::LayerwiseLGreco { bits: 3, bucket: 128, every: 10 };
    for (label, compression) in
        [("scheduled 4b budget + EF", scheduled), ("static layer-wise 3b", baseline)]
    {
        let cfg = GanTrainConfig {
            compression,
            k_nodes: 4,
            steps,
            fid_every: (steps / 2).max(5),
            seed: 1,
            ..GanTrainConfig::default()
        };
        let run = train(&wgan, &cfg)?;
        rt_table.row(&[
            label.to_string(),
            format!("{:.4}", run.final_fid),
            format!("{:.3}", run.metrics.total_bytes() / 1e6),
            format!("{:.2}", run.metrics.mean_step_ms()),
        ]);
        assert!(run.final_fid.is_finite(), "{label}: FID diverged");
        bench.push(
            &format!(
                "adaptive/gan_run/{}",
                if matches!(compression, GanCompression::Scheduled { .. }) {
                    "scheduled_ef"
                } else {
                    "static_3bit"
                }
            ),
            &[
                ("final_fid", format!("{:.5}", run.final_fid)),
                ("wire_mb", format!("{:.4}", run.metrics.total_bytes() / 1e6)),
            ],
        );
    }
    rt_table.print();

    let path = bench.save_merged("BENCH_comm.json")?;
    println!("\nbench records merged into {}", path.display());
    Ok(())
}

//! Regenerates Tables 1 and 2 (step time vs bandwidth; weak scaling), plus a
//! finer bandwidth sweep to locate the crossover where quantization stops
//! paying (very high bandwidth).
//!
//! Run: `cargo run --release --example bandwidth_sweep`

use qoda::bench_harness::experiments::{
    measure_qoda5_bytes_per_coord, step_time_ms, table1, table2,
};
use qoda::util::table::Table;

fn main() {
    let t1 = table1();
    t1.print();
    let _ = t1.save_csv("table1.csv");
    println!();
    let t2 = table2();
    t2.print();
    let _ = t2.save_csv("table2.csv");
    println!();

    // finer sweep (not in the paper): where does the baseline catch up?
    let bpc = measure_qoda5_bytes_per_coord(1 << 20, 42);
    let mut t = Table::new(
        "Bandwidth sweep, K = 4 (model extrapolation)",
        &["Gbps", "baseline ms", "QODA5 ms", "speedup"],
    );
    for bw in [0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 100.0] {
        let b = step_time_ms(4, bw, false, bpc);
        let q = step_time_ms(4, bw, true, bpc);
        t.row(&[
            format!("{bw}"),
            format!("{b:.0}"),
            format!("{q:.0}"),
            format!("{:.2}x", b / q),
        ]);
    }
    t.print();
    let _ = t.save_csv("bandwidth_sweep.csv");
}

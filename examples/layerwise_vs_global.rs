//! Figure 4 regenerator: FID evolution during WGAN training for
//! Adam (uncompressed) vs QODA+global (Q-GenX) vs QODA+layer-wise (L-GreCo),
//! averaged over seeds. Writes results/fig4_fid.csv.
//!
//! Run: `cargo run --release --example layerwise_vs_global -- [--steps 240] [--seeds 2]`

use qoda::bench_harness::model_experiments::fig4;
use qoda::util::cli::Args;
use qoda::util::table::save_series_csv;

fn main() -> qoda::util::error::Result<()> {
    let args = Args::from_env();
    let steps = args.usize_or("steps", 240)?;
    let nseeds = args.usize_or("seeds", 2)?;
    let seeds: Vec<u64> = (1..=nseeds as u64).collect();
    println!("Figure 4: {steps} steps x {nseeds} seeds x 3 configurations\n");
    let (summary, rows) = fig4(steps, &seeds)?;
    summary.print();
    summary.save_csv("fig4_summary.csv")?;
    save_series_csv(
        "fig4_fid.csv",
        &["step", "adam", "qoda_global", "qoda_layerwise"],
        &rows,
    )?;
    println!("\nFID curves:");
    println!("step      adam   qoda_global  qoda_layerwise");
    for r in &rows {
        println!("{:>5}  {:>8.4}  {:>10.4}  {:>12.4}", r[0], r[1], r[2], r[3]);
    }
    Ok(())
}

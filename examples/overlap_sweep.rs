//! Overlapped-exchange sweep: the Table 1/2 QODA5 regime with
//! double-buffered duals — round t's communication hides behind round
//! t+1's compute, and only the exposed remainder stays on the critical
//! path.
//!
//! The regime to see: synchronously, topology choice matters (hierarchical
//! beats flat broadcast from K = 12 under heterogeneous links, the
//! parameter server collapses). Overlapped, the compute window swallows the
//! quantized exchange almost everywhere — the flat-vs-hierarchical gap
//! closes as compute per step grows, and at the paper's weak-scaling points
//! the step time drops to the compute + codec floor: the
//! hidden-communication speedup. A driven `RunSpec` pair at the end shows
//! the same split flowing through the solver driver's accounting
//! (`comm_exposed_s` / `comm_hidden_s`), with bit-identical iterates — on
//! the driver's clock the overlap is accounting, not different math.
//!
//! Run: `cargo run --release --example overlap_sweep -- [--bandwidth 5]`

use qoda::bench_harness::experiments::{
    measure_qoda5_bytes_per_coord, overlap_sweep, overlap_table, qoda5_charge,
    table2_compute_window_s, QODA_CODEC_MS,
};
use qoda::coordinator::{ExchangeMode, ExchangePlan, TopologySpec};
use qoda::net::NetworkModel;
use qoda::oda::{CompressionSpec, OperatorSpec, RunSpec, SolverKind};
use qoda::util::cli::Args;
use qoda::util::table::Table;
use qoda::vi::noise::NoiseModel;

fn main() -> qoda::util::error::Result<()> {
    let args = Args::from_env();
    let bw = args.f64_or("bandwidth", 5.0)?;
    let ks = args.list_or("ks", vec![4usize, 8, 12, 16])?;
    let depth = args.usize_or("depth", 1)?;

    // --- the weak-scaling regime, synchronous vs overlapped ------------------
    let t = overlap_table(&ks, bw, depth);
    t.print();
    t.save_csv("overlap_sweep.csv")?;

    // the acceptance regime is pinned at the paper testbed's 5 Gbps: at
    // K >= 12 the overlap hides the (quantized) exchange and the step time
    // collapses to the compute + codec floor — the hidden-communication
    // speedup
    for row in overlap_sweep(&[12, 16], 5.0, depth) {
        assert!(
            row.comm_exposed_ms <= row.comm_ms,
            "overlap can never expose more than the exchange costs"
        );
        if !matches!(row.topology, TopologySpec::ParameterServer) {
            assert!(
                row.overlap_ms < row.sync_ms,
                "K={} {}: overlap {} vs sync {}",
                row.k,
                row.topology.label(),
                row.overlap_ms,
                row.sync_ms
            );
            assert!(
                row.comm_hidden_ms > 0.9 * row.comm_ms,
                "K={} {}: the Table 2 compute window hides the exchange",
                row.k,
                row.topology.label()
            );
        }
    }
    println!("\nK >= 12: overlapped QODA5 hides the exchange behind compute: ok");

    // --- overlap closes the flat-vs-hierarchical gap as compute grows --------
    // sweep the compute-per-step knob at K = 16: synchronously the two
    // topologies differ by the full comm delta; overlapped, the gap shrinks
    // monotonically and vanishes once the window covers both exchanges
    let k = 16usize;
    let bpc = measure_qoda5_bytes_per_coord(1 << 16, 42);
    let comm_ms =
        |spec: &TopologySpec| qoda5_charge(k, 5.0, bpc, spec).comm_s * 1e3;
    let flat_ms = comm_ms(&TopologySpec::BroadcastAllGather);
    let hier_ms = comm_ms(&TopologySpec::hierarchical_for(k));
    let full_window_ms = table2_compute_window_s(k) * 1e3;
    let mut gt = Table::new(
        "Overlap closes the topology gap as compute/step grows (K=16, QODA5 ms)",
        &["compute ms", "flat step", "hier step", "gap"],
    );
    let mut last_gap = f64::INFINITY;
    for frac in [0.0, 0.25, 0.5, 0.75, 1.0] {
        let window_ms = full_window_ms * frac;
        let plan = ExchangePlan::overlapped(depth, window_ms * 1e-3);
        let step = |comm: f64| {
            let (exposed_s, _) = plan.split(comm * 1e-3);
            window_ms + QODA_CODEC_MS + exposed_s * 1e3
        };
        let (f, h) = (step(flat_ms), step(hier_ms));
        let gap = (f - h).abs();
        gt.row(&[
            format!("{window_ms:.0}"),
            format!("{f:.1}"),
            format!("{h:.1}"),
            format!("{gap:.2}"),
        ]);
        assert!(
            gap <= last_gap + 1e-9,
            "the topology gap must shrink as compute grows: {gap} after {last_gap}"
        );
        last_gap = gap;
    }
    gt.print();
    assert!(last_gap < 1e-9, "at the full Table 2 window the gap closes entirely");
    println!("(hierarchical's synchronous edge was {:.1} ms)", flat_ms - hier_ms);

    // --- the same split through a real driven run ----------------------------
    let mut rt = Table::new(
        "RunSpec x exchange (QODA, quadratic d=32, K=12, 150 steps, hier topology)",
        &["exchange", "comm ms", "exposed ms", "hidden ms", "wall comm share"],
    );
    let drive = |mode: ExchangeMode| {
        RunSpec::new(
            SolverKind::Qoda,
            OperatorSpec::Quadratic { dim: 32, mu: 0.5, seed: 7 },
        )
        .nodes(12)
        .noise(NoiseModel::Absolute { sigma: 0.2 })
        .compression(CompressionSpec::Global { bits: 5, bucket: 128 })
        .steps(150)
        .topology(TopologySpec::hierarchical_for(12))
        .network(NetworkModel::genesis_cloud(bw))
        .exchange(mode)
        .compute_per_step(table2_compute_window_s(12))
        .run()
    };
    let sync = drive(ExchangeMode::Synchronous);
    let over = drive(ExchangeMode::Overlapped { depth });
    for (name, r) in [("synchronous", &sync), ("overlapped", &over)] {
        rt.row(&[
            name.to_string(),
            format!("{:.1}", r.comm_s * 1e3),
            format!("{:.1}", r.comm_exposed_s * 1e3),
            format!("{:.1}", r.comm_hidden_s * 1e3),
            format!("{:.0}%", r.comm_exposed_s / r.comm_s * 100.0),
        ]);
    }
    rt.print();
    assert_eq!(sync.x_last, over.x_last, "the driver clock never touches math");
    assert!(over.comm_exposed_s <= sync.comm_exposed_s);
    assert!(over.comm_hidden_s > 0.0);
    println!(
        "\n(identical iterates; the exchange schedule moved {:.0} ms of comm off \
         the critical path)",
        over.comm_hidden_s * 1e3
    );
    Ok(())
}

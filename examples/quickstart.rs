//! Quickstart: the library in ~60 lines.
//!
//! 1. quantize a gradient layer-wise, entropy-code it, decode it back;
//! 2. solve a monotone VI with QODA under quantized communication;
//! 3. check the Theorem 5.1 variance bound on the fly.
//!
//! Run: `cargo run --release --example quickstart`

use qoda::coding::protocol::{decode_vector, encode_vector, Codebooks, ProtocolKind};
use qoda::oda::compress::{Compressor, QuantCompressor};
use qoda::oda::lr::AdaptiveLr;
use qoda::oda::qoda::Qoda;
use qoda::oda::source::OracleSource;
use qoda::quant::layer_map::LayerMap;
use qoda::quant::quantizer::{dequantize, quantize};
use qoda::quant::{variance, QuantConfig};
use qoda::stats::rng::Rng;
use qoda::vi::gap::GapEvaluator;
use qoda::vi::noise::NoiseModel;
use qoda::vi::operator::{Operator, QuadraticOperator};

fn main() {
    // ---- 1. layer-wise quantization + coding round trip -------------------
    let map = LayerMap::from_spec(&[
        ("encoder.w", 4096, "ff"),
        ("encoder.b", 64, "bias"),
        ("head.w", 2048, "embedding"),
    ]);
    let cfg = QuantConfig::uniform_bits(map.num_types(), 5, 2.0);
    let mut rng = Rng::new(7);
    let grad: Vec<f32> = (0..map.dim).map(|_| rng.gaussian() as f32 * 0.1).collect();

    let qv = quantize(&grad, &map, &cfg, &mut rng);
    let books = Codebooks::uniform(ProtocolKind::Main, &cfg, &map.type_proportions());
    let wire = encode_vector(&qv, &books);
    let decoded = dequantize(&decode_vector(&wire, &map, &books).expect("decode"), &cfg);

    println!(
        "quantized {} coords: {} -> {} bytes ({:.1}x), eps_Q bound = {:.3}",
        map.dim,
        map.dim * 4,
        wire.len_bytes(),
        (map.dim * 4) as f64 / wire.len_bytes() as f64,
        variance::eps_q_for(&map, &cfg),
    );
    let err: f64 = grad
        .iter()
        .zip(&decoded)
        .map(|(a, b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        / grad.iter().map(|a| (*a as f64).powi(2)).sum::<f64>();
    println!("relative reconstruction error: {err:.4}");

    // ---- 2. QODA on a monotone VI with 4 quantized nodes ------------------
    let mut op_rng = Rng::new(1);
    let op = QuadraticOperator::random(16, 0.5, &mut op_rng);
    let mut src = OracleSource::new(&op, 4, NoiseModel::Absolute { sigma: 0.2 }, 3);
    let vmap = LayerMap::single(16);
    let comps: Vec<Box<dyn Compressor>> = (0..4)
        .map(|i| Box::new(QuantCompressor::global_bits(&vmap, 5, 128, i as u64)) as _)
        .collect();
    let mut solver = Qoda::new(&mut src, comps, Box::new(AdaptiveLr::default()));
    let run = solver.run(&vec![0.0; 16], 1000, &[]);

    // ---- 3. evaluate the restricted gap ------------------------------------
    let sol = op.solution().unwrap();
    let radius = 1.0
        + qoda::stats::vecops::l2_norm64(&qoda::stats::vecops::sub(&vec![0.0; 16], &sol));
    let gap = GapEvaluator::new(&op, sol, radius).eval(&run.xbar);
    println!(
        "QODA: 1000 iters x 4 nodes, {:.1} bits/coord on the wire, GAP(x-bar) = {gap:.5}",
        run.bits_per_iter_node / 16.0
    );
    assert!(gap < 0.05, "quickstart should converge");
    println!("quickstart OK");
}

//! Quickstart: the library in ~60 lines.
//!
//! 1. quantize a gradient layer-wise, entropy-code it, decode it back;
//! 2. solve a monotone VI with QODA under quantized communication, built
//!    declaratively with `RunSpec` and driven by the shared `RunDriver`;
//! 3. read the restricted gap straight off the run report.
//!
//! Run: `cargo run --release --example quickstart`

use qoda::coding::protocol::{decode_vector, encode_vector, Codebooks, ProtocolKind};
use qoda::oda::{CompressionSpec, GapMode, OperatorSpec, RunSpec, SolverKind};
use qoda::quant::layer_map::LayerMap;
use qoda::quant::quantizer::{dequantize, quantize};
use qoda::quant::{variance, QuantConfig};
use qoda::stats::rng::Rng;
use qoda::vi::noise::NoiseModel;

fn main() {
    // ---- 1. layer-wise quantization + coding round trip -------------------
    let map = LayerMap::from_spec(&[
        ("encoder.w", 4096, "ff"),
        ("encoder.b", 64, "bias"),
        ("head.w", 2048, "embedding"),
    ]);
    let cfg = QuantConfig::uniform_bits(map.num_types(), 5, 2.0);
    let mut rng = Rng::new(7);
    let grad: Vec<f32> = (0..map.dim).map(|_| rng.gaussian() as f32 * 0.1).collect();

    let qv = quantize(&grad, &map, &cfg, &mut rng);
    let books = Codebooks::uniform(ProtocolKind::Main, &cfg, &map.type_proportions());
    let wire = encode_vector(&qv, &books);
    let decoded = dequantize(&decode_vector(&wire, &map, &books).expect("decode"), &cfg);

    println!(
        "quantized {} coords: {} -> {} bytes ({:.1}x), eps_Q bound = {:.3}",
        map.dim,
        map.dim * 4,
        wire.len_bytes(),
        (map.dim * 4) as f64 / wire.len_bytes() as f64,
        variance::eps_q_for(&map, &cfg),
    );
    let err: f64 = grad
        .iter()
        .zip(&decoded)
        .map(|(a, b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        / grad.iter().map(|a| (*a as f64).powi(2)).sum::<f64>();
    println!("relative reconstruction error: {err:.4}");

    // ---- 2. QODA on a monotone VI with 4 quantized nodes ------------------
    // one declarative spec: operator / noise / nodes / compression / steps;
    // the driver owns checkpoints, averaging, accounting and gap evaluation
    let report = RunSpec::new(
        SolverKind::Qoda,
        OperatorSpec::Quadratic { dim: 16, mu: 0.5, seed: 1 },
    )
    .nodes(4)
    .noise(NoiseModel::Absolute { sigma: 0.2 })
    .compression(CompressionSpec::Global { bits: 5, bucket: 128 })
    .steps(1000)
    .checkpoints(&[1000])
    .seed(3)
    .gap(GapMode::AtCheckpoints)
    .run();

    // ---- 3. read the restricted gap off the report -------------------------
    let gap = report.final_gap().expect("gap evaluated at the horizon");
    println!(
        "QODA: 1000 iters x 4 nodes, {:.1} bits/coord on the wire, GAP(x-bar) = {gap:.5}",
        report.bits_per_iter_node / 16.0
    );
    assert!(gap < 0.05, "quickstart should converge");
    println!("quickstart OK");
}

//! Solver race under a shared wire budget — a scenario the old monolithic
//! `run()` loops could not express, now plain library code on the step-wise
//! `Solver` trait: QODA and the Q-GenX extra-gradient baseline advance
//! *interleaved*, one iteration at a time, and whichever has spent fewer
//! wire bits steps next. When the shared budget is exhausted the ergodic
//! averages are compared by restricted gap — optimism's half-cost oracle
//! and single exchange per iteration shows up directly as more iterations
//! (and a lower gap) inside the same budget.
//!
//! Run: `cargo run --release --example solver_race -- [--budget-mbits 4] [--k 4]`

use qoda::comm::{Compressor, QuantCompressor};
use qoda::oda::{
    AdaptiveLr, CompressionSpec, GapMode, OperatorSpec, OracleSource, QGenX, Qoda,
    RunSpec, Solver, SolverKind,
};
use qoda::quant::layer_map::LayerMap;
use qoda::stats::rng::Rng;
use qoda::stats::vecops::{l2_norm64, sub};
use qoda::util::cli::Args;
use qoda::vi::gap::GapEvaluator;
use qoda::vi::noise::NoiseModel;
use qoda::vi::operator::QuadraticOperator;

/// One racer: a step-wise solver plus its share of the accounting.
struct Racer<'s> {
    solver: Box<dyn Solver + 's>,
    bits: u64,
    steps: usize,
    xbar_sum: Vec<f64>,
}

impl<'s> Racer<'s> {
    fn new(mut solver: Box<dyn Solver + 's>, x0: &[f64]) -> Self {
        solver.init(x0);
        let d = x0.len();
        Racer { solver, bits: 0, steps: 0, xbar_sum: vec![0.0; d] }
    }

    fn step(&mut self) {
        self.steps += 1;
        let stats = self.solver.step(self.steps);
        self.bits += stats.bits;
        for (s, v) in self.xbar_sum.iter_mut().zip(self.solver.state().avg_point) {
            *s += v;
        }
    }

    fn xbar(&self) -> Vec<f64> {
        let n = self.steps.max(1) as f64;
        self.xbar_sum.iter().map(|s| s / n).collect()
    }
}

fn main() -> qoda::util::error::Result<()> {
    let args = Args::from_env();
    let budget_bits = (args.f64_or("budget-mbits", 4.0)? * 1e6) as u64;
    let k = args.usize_or("k", 4)?;
    let d = 12;

    let mut op_rng = Rng::new(23);
    let op = QuadraticOperator::random(d, 0.8, &mut op_rng);
    let sol = op.sol.clone();
    let x0 = vec![0.0; d];
    let radius = 1.0 + l2_norm64(&sub(&x0, &sol));
    let noise = NoiseModel::Absolute { sigma: 0.3 };
    let map = LayerMap::single(d);
    let mk = |seed: u64| -> Vec<Box<dyn Compressor>> {
        (0..k)
            .map(|i| {
                Box::new(QuantCompressor::global_bits(&map, 5, 128, seed + i as u64))
                    as Box<dyn Compressor>
            })
            .collect()
    };

    let mut src_a = OracleSource::new(&op, k, noise, 1);
    let mut src_b = OracleSource::new(&op, k, noise, 1);
    let mut racers = [
        Racer::new(
            Box::new(Qoda::new(&mut src_a, mk(10), Box::new(AdaptiveLr::default()))),
            &x0,
        ),
        Racer::new(
            Box::new(QGenX::new(&mut src_b, mk(10), Box::new(AdaptiveLr::default()))),
            &x0,
        ),
    ];

    // fairness by spend: the racer with fewer wire bits moves next, until
    // nobody can step without blowing the shared budget
    println!(
        "racing {} vs {} inside {:.1} Mbits of shared wire budget (K = {k})",
        racers[0].solver.name(),
        racers[1].solver.name(),
        budget_bits as f64 / 1e6
    );
    loop {
        let total: u64 = racers.iter().map(|r| r.bits).sum();
        if total >= budget_bits {
            break;
        }
        let next = if racers[0].bits <= racers[1].bits { 0 } else { 1 };
        racers[next].step();
    }

    let gap_eval = GapEvaluator::new(&op, sol, radius);
    println!();
    println!("{:<10} {:>7} {:>12} {:>12} {:>10}", "solver", "iters", "oracle", "Mbits", "GAP");
    for r in racers.iter() {
        let gap = gap_eval.eval(&r.xbar());
        println!(
            "{:<10} {:>7} {:>12} {:>12.2} {:>10.5}",
            r.solver.name(),
            r.steps,
            r.solver.oracle_calls(),
            r.bits as f64 / 1e6,
            gap,
        );
    }
    assert!(
        racers[0].steps > racers[1].steps,
        "QODA should fit more iterations than extra-gradient in the same budget"
    );
    println!(
        "\nsame budget, {:.1}x the iterations for {} — optimism pays",
        racers[0].steps as f64 / racers[1].steps as f64,
        racers[0].solver.name()
    );

    // reference: the same QODA configuration as one declarative spec driven
    // start-to-finish by the shared RunDriver, to the winner's horizon
    let horizon = racers[0].steps;
    let reference = RunSpec::new(
        SolverKind::Qoda,
        OperatorSpec::Quadratic { dim: d, mu: 0.8, seed: 23 },
    )
    .nodes(k)
    .noise(noise)
    .compression(CompressionSpec::Global { bits: 5, bucket: 128 })
    .steps(horizon)
    .checkpoints(&[horizon])
    .seed(10)
    .gap(GapMode::AtCheckpoints)
    .run();
    println!(
        "reference RunDriver run, {} steps: GAP = {:.5}, {:.2} Mbits",
        reference.steps_run,
        reference.final_gap().unwrap_or(f64::NAN),
        reference.total_bits as f64 / 1e6,
    );
    Ok(())
}

//! Weak-scaling sweep across communication topologies: the Table 2 regime
//! (fp32 baseline vs QODA5, K = 4..16, 5 Gbps cross-rack links) replayed
//! under flat broadcast-allgather, hierarchical two-level aggregation
//! (K/4 racks over 50 Gbps rack-local links) and a parameter-server hub —
//! the scaling scenarios the pluggable transport layer exists for.
//!
//! The regime to see: the flat fp32 baseline degrades with K (incast),
//! the parameter server collapses (serialized hub egress), hierarchical
//! aggregation keeps scaling — and beats broadcast from K = 12 on, for the
//! quantized payloads too. A straggler injection at the end shows the
//! topology-aware charging: a slow rack-local link barely moves the
//! two-level step time, a slow *leader* link drags the whole exchange.
//!
//! Run: `cargo run --release --example topology_sweep -- [--bandwidth 5]`

use qoda::bench_harness::experiments::{
    measure_qoda5_bytes_per_coord, step_time_ms_topo, topology_table,
};
use qoda::coordinator::{TopologySpec, Transport};
use qoda::net::NetworkModel;
use qoda::oda::{CompressionSpec, OperatorSpec, RunSpec, SolverKind};
use qoda::stats::rng::Rng;
use qoda::util::cli::Args;
use qoda::util::table::Table;
use qoda::vi::noise::NoiseModel;

fn main() -> qoda::util::error::Result<()> {
    let args = Args::from_env();
    let bw = args.f64_or("bandwidth", 5.0)?;
    let ks = args.list_or("ks", vec![4usize, 8, 12, 16])?;

    // --- the weak-scaling regime, all three topologies -----------------------
    let t = topology_table(&ks, bw);
    t.print();
    t.save_csv("topology_sweep.csv")?;

    // the acceptance regime is pinned at the paper testbed's 5 Gbps
    // cross-rack links (a user-supplied --bandwidth may legitimately move
    // the crossover, e.g. 50 Gbps cross-rack erases the two-level win)
    let bpc = measure_qoda5_bytes_per_coord(1 << 16, 42);
    for k in [12usize, 16] {
        let flat = step_time_ms_topo(k, 5.0, true, bpc, &TopologySpec::BroadcastAllGather);
        let hier = step_time_ms_topo(k, 5.0, true, bpc, &TopologySpec::hierarchical_for(k));
        assert!(
            hier < flat,
            "hierarchical must beat broadcast at K={k}, 5 Gbps: {hier} vs {flat}"
        );
    }
    println!("\nhierarchical beats broadcast at K >= 12 (quantized payloads, 5 Gbps): ok");

    // --- straggler injection: the phase structure shows ----------------------
    let k = 16;
    let spec = TopologySpec::hierarchical_for(k);
    let d = 1usize << 20;
    let bits = vec![(d as f64 * bpc * 8.0) as u64; k];
    let charge = |net: &NetworkModel| {
        let mut rng = Rng::new(3);
        spec.build().charge(&bits, d, net, false, true, &mut rng).comm_s * 1e3
    };
    let clean = charge(&NetworkModel::genesis_cloud(bw));
    // node 13 is a plain rack member; node 12 leads its rack of 4
    let member = charge(&NetworkModel::genesis_cloud(bw).with_straggler(13, 4.0));
    let leader = charge(&NetworkModel::genesis_cloud(bw).with_straggler(12, 4.0));
    let mut st = Table::new(
        "Straggler injection, hierarchical K=16 (comm ms/step)",
        &["scenario", "comm ms"],
    );
    st.row(&["no straggler".into(), format!("{clean:.2}")]);
    st.row(&["4x slower rack member (node 13)".into(), format!("{member:.2}")]);
    st.row(&["4x slower rack leader (node 12)".into(), format!("{leader:.2}")]);
    st.print();
    assert!(member < leader, "a slow member must hurt less than a slow leader");

    // --- the same topologies threaded through a real driven run --------------
    let mut rt = Table::new(
        "RunSpec x topology (QODA, quadratic d=32, K=8, 200 steps)",
        &["topology", "wire Mbits (routed)", "comm ms (modeled)", "GAP"],
    );
    for topo in [
        TopologySpec::BroadcastAllGather,
        TopologySpec::hierarchical_for(8),
        TopologySpec::ParameterServer,
    ] {
        let report = RunSpec::new(
            SolverKind::Qoda,
            OperatorSpec::Quadratic { dim: 32, mu: 0.5, seed: 7 },
        )
        .nodes(8)
        .noise(NoiseModel::Absolute { sigma: 0.2 })
        .compression(CompressionSpec::Global { bits: 5, bucket: 128 })
        .steps(200)
        .checkpoints(&[200])
        .gap(qoda::oda::GapMode::AtCheckpoints)
        .topology(topo)
        .network(NetworkModel::genesis_cloud(bw))
        .run();
        rt.row(&[
            topo.label().to_string(),
            format!("{:.3}", report.net_wire_bits as f64 / 1e6),
            format!("{:.1}", report.comm_s * 1e3),
            format!("{:.5}", report.final_gap().unwrap_or(f64::NAN)),
        ]);
    }
    rt.print();
    println!("\n(identical GAP per topology — routing changes cost, never math)");
    Ok(())
}

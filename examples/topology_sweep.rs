//! Weak-scaling sweep across communication topologies: the Table 2 regime
//! (fp32 baseline vs QODA5, K = 4..64, 5 Gbps cross-rack links) replayed
//! under flat broadcast-allgather, hierarchical two-level aggregation
//! (K/4 racks over 50 Gbps rack-local links), a parameter-server hub, the
//! sharded reduce-scatter → allgather and the classic ring — the scaling
//! scenarios the pluggable transport layer exists for.
//!
//! The regime to see: the flat fp32 baseline degrades with K (incast),
//! the parameter server collapses (serialized hub egress), hierarchical
//! aggregation keeps scaling — and beats broadcast from K = 12 on, for the
//! quantized payloads too. From K = 32 the star-shaped plans all hit the
//! per-link wall and the sharded collective takes over, with a peak
//! per-link load ≤ 1.5/K of flat's. A straggler injection at the end shows
//! the topology-aware charging: a slow rack-local link barely moves the
//! two-level step time, a slow *leader* link drags the whole exchange.
//!
//! Run: `cargo run --release --example topology_sweep -- [--bandwidth 5]`

use qoda::bench_harness::experiments::{
    measure_qoda5_bytes_per_coord, step_time_ms_topo, topology_table,
};
use qoda::coordinator::{TopologySpec, Transport};
use qoda::net::NetworkModel;
use qoda::oda::{CompressionSpec, OperatorSpec, RunSpec, SolverKind};
use qoda::stats::rng::Rng;
use qoda::util::cli::Args;
use qoda::util::table::Table;
use qoda::vi::noise::NoiseModel;

fn main() -> qoda::util::error::Result<()> {
    let args = Args::from_env();
    let bw = args.f64_or("bandwidth", 5.0)?;
    let ks = args.list_or("ks", vec![4usize, 8, 16, 32, 64])?;

    // --- the weak-scaling regime, all three topologies -----------------------
    let t = topology_table(&ks, bw);
    t.print();
    t.save_csv("topology_sweep.csv")?;

    // the acceptance regime is pinned at the paper testbed's 5 Gbps
    // cross-rack links (a user-supplied --bandwidth may legitimately move
    // the crossover, e.g. 50 Gbps cross-rack erases the two-level win)
    let bpc = measure_qoda5_bytes_per_coord(1 << 16, 42);
    for k in [12usize, 16] {
        let flat = step_time_ms_topo(k, 5.0, true, bpc, &TopologySpec::BroadcastAllGather);
        let hier = step_time_ms_topo(k, 5.0, true, bpc, &TopologySpec::hierarchical_for(k));
        assert!(
            hier < flat,
            "hierarchical must beat broadcast at K={k}, 5 Gbps: {hier} vs {flat}"
        );
    }
    println!("\nhierarchical beats broadcast at K >= 12 (quantized payloads, 5 Gbps): ok");

    // from K = 32 on, the sharded collective must beat every star-shaped
    // plan on modeled step time AND keep its busiest link under 1.5/K of
    // flat's — the PR-9 acceptance regime
    for k in [32usize, 64] {
        let sharded =
            step_time_ms_topo(k, 5.0, true, bpc, &TopologySpec::ShardedReduceScatter);
        for old in [
            TopologySpec::BroadcastAllGather,
            TopologySpec::hierarchical_for(k),
            TopologySpec::ParameterServer,
        ] {
            let t = step_time_ms_topo(k, 5.0, true, bpc, &old);
            assert!(
                sharded < t,
                "sharded must beat {} at K={k}, 5 Gbps: {sharded} vs {t}",
                old.label()
            );
        }
        let d = 1usize << 16;
        let bits = vec![360_000u64; k];
        let net = NetworkModel::genesis_cloud(5.0);
        let mut rng = Rng::new(11);
        let flat_peak = TopologySpec::BroadcastAllGather
            .build()
            .charge(&bits, d, &net, false, true, &mut rng)
            .peak_link_bytes;
        let mut rng = Rng::new(11);
        let sharded_peak = TopologySpec::ShardedReduceScatter
            .build()
            .charge(&bits, d, &net, false, true, &mut rng)
            .peak_link_bytes;
        assert!(
            sharded_peak <= 1.5 / k as f64 * flat_peak,
            "K={k}: sharded peak link {sharded_peak} B above 1.5/K x flat ({flat_peak} B)"
        );
    }
    println!("sharded beats flat/hier/PS at K >= 32 with peak link <= 1.5/K of flat's: ok");

    // --- straggler injection: the phase structure shows ----------------------
    let k = 16;
    let spec = TopologySpec::hierarchical_for(k);
    let d = 1usize << 20;
    let bits = vec![(d as f64 * bpc * 8.0) as u64; k];
    let charge = |net: &NetworkModel| {
        let mut rng = Rng::new(3);
        spec.build().charge(&bits, d, net, false, true, &mut rng).comm_s * 1e3
    };
    let clean = charge(&NetworkModel::genesis_cloud(bw));
    // node 13 is a plain rack member; node 12 leads its rack of 4
    let member = charge(&NetworkModel::genesis_cloud(bw).with_straggler(13, 4.0));
    let leader = charge(&NetworkModel::genesis_cloud(bw).with_straggler(12, 4.0));
    let mut st = Table::new(
        "Straggler injection, hierarchical K=16 (comm ms/step)",
        &["scenario", "comm ms"],
    );
    st.row(&["no straggler".into(), format!("{clean:.2}")]);
    st.row(&["4x slower rack member (node 13)".into(), format!("{member:.2}")]);
    st.row(&["4x slower rack leader (node 12)".into(), format!("{leader:.2}")]);
    st.print();
    assert!(member < leader, "a slow member must hurt less than a slow leader");

    // --- the same topologies threaded through a real driven run --------------
    let mut rt = Table::new(
        "RunSpec x topology (QODA, quadratic d=32, K=8, 200 steps)",
        &["topology", "wire Mbits (routed)", "comm ms (modeled)", "peak link KB", "GAP"],
    );
    for topo in [
        TopologySpec::BroadcastAllGather,
        TopologySpec::hierarchical_for(8),
        TopologySpec::ParameterServer,
        TopologySpec::ShardedReduceScatter,
        TopologySpec::Ring,
    ] {
        let report = RunSpec::new(
            SolverKind::Qoda,
            OperatorSpec::Quadratic { dim: 32, mu: 0.5, seed: 7 },
        )
        .nodes(8)
        .noise(NoiseModel::Absolute { sigma: 0.2 })
        .compression(CompressionSpec::Global { bits: 5, bucket: 128 })
        .steps(200)
        .checkpoints(&[200])
        .gap(qoda::oda::GapMode::AtCheckpoints)
        .topology(topo)
        .network(NetworkModel::genesis_cloud(bw))
        .run();
        rt.row(&[
            topo.label().to_string(),
            format!("{:.3}", report.net_wire_bits as f64 / 1e6),
            format!("{:.1}", report.comm_s * 1e3),
            format!("{:.3}", report.peak_link_bytes / 1e3),
            format!("{:.5}", report.final_gap().unwrap_or(f64::NAN)),
        ]);
    }
    rt.print();
    println!("\n(identical GAP per topology — routing changes cost, never math)");
    Ok(())
}

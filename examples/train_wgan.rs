//! End-to-end driver (DESIGN.md validation run): train the WGAN on the
//! in-graph Gaussian-mixture workload via the PJRT-loaded L2 model for a few
//! hundred steps with QODA + layer-wise quantization across 4 simulated
//! nodes, logging the loss curve, W-distance, FID and the wire traffic.
//! This is the run recorded in EXPERIMENTS.md §End-to-end.
//!
//! Run: `cargo run --release --example train_wgan -- [--steps 300] [--k 4]`

use qoda::gan::trainer::{train, GanCompression, GanOptimizer, GanTrainConfig};
use qoda::runtime::{Runtime, WganModel};
use qoda::util::cli::Args;
use qoda::util::table::save_series_csv;

fn main() -> qoda::util::error::Result<()> {
    let args = Args::from_env();
    let steps = args.usize_or("steps", 300)?;
    let rt = Runtime::cpu()?;
    let model = WganModel::load(&rt)?;
    println!(
        "WGAN loaded: dim={} ({} layers, {} types), K={} nodes, {steps} steps",
        model.dim,
        model.meta.layers.len(),
        model.meta.num_types(),
        args.usize_or("k", 4)?,
    );
    let cfg = GanTrainConfig {
        optimizer: GanOptimizer::OptimisticAdam,
        compression: GanCompression::LayerwiseLGreco { bits: 5, bucket: 128, every: 50 },
        k_nodes: args.usize_or("k", 4)?,
        steps,
        fid_every: (steps / 12).max(5),
        seed: args.u64_or("seed", 1)?,
        ..Default::default()
    };
    let run = train(&model, &cfg)?;

    println!("\nstep    g_loss     w_dist    step_ms  KB/node   FID");
    let mut rows = Vec::new();
    for m in &run.metrics.steps {
        rows.push(vec![
            m.step as f64,
            m.scalar("g_loss").unwrap_or(f64::NAN),
            m.scalar("w_dist").unwrap_or(f64::NAN),
            m.total_s() * 1e3,
            m.bytes_per_node / 1e3,
            m.scalar("fid").unwrap_or(f64::NAN),
        ]);
        if m.step % (steps / 20).max(1) == 0 || m.scalar("fid").is_some() {
            println!(
                "{:>4}  {:+.5}  {:+.5}  {:>7.1}  {:>7.2}   {}",
                m.step,
                m.scalar("g_loss").unwrap_or(f64::NAN),
                m.scalar("w_dist").unwrap_or(f64::NAN),
                m.total_s() * 1e3,
                m.bytes_per_node / 1e3,
                m.scalar("fid").map(|f| format!("{f:.4}")).unwrap_or_default(),
            );
        }
    }
    save_series_csv(
        "train_wgan_e2e.csv",
        &["step", "g_loss", "w_dist", "step_ms", "kb_per_node", "fid"],
        &rows,
    )?;
    println!("\nfinal FID {:.4}  (curve -> results/train_wgan_e2e.csv)", run.final_fid);
    let first_fid = run.fid_curve.first().map(|&(_, f)| f).unwrap_or(f64::NAN);
    println!("FID improved {first_fid:.4} -> {:.4}", run.final_fid);
    Ok(())
}

//! Table 3 + Figure 5 regenerator: transformer LM with PowerSGD and
//! {global, layer-wise} factor quantization (Table 3), and the single-type
//! quantization ablation (Figure 5).
//!
//! Run: `cargo run --release --example transformer_ablation -- [--steps 120] [--ablation]`

use qoda::bench_harness::model_experiments::{fig5, table3};
use qoda::util::cli::Args;

fn main() -> qoda::util::error::Result<()> {
    let args = Args::from_env();
    let steps = args.usize_or("steps", 120)?;
    let nseeds = args.usize_or("seeds", 2)?;
    let seeds: Vec<u64> = (1..=nseeds as u64).collect();
    if !args.has("ablation") {
        let t = table3(steps, &[4, 8, 16], &seeds)?;
        t.print();
        t.save_csv("table3.csv")?;
    }
    if args.has("ablation") || args.has("all") {
        let t = fig5(steps, &seeds)?;
        t.print();
        t.save_csv("fig5.csv")?;
    }
    Ok(())
}

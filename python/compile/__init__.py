"""Build-time compile package: L2 models + L1 kernels + AOT lowering."""

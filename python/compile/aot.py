"""AOT lowering: jit + lower every L2 entry point to HLO *text* artifacts.

HLO text (not `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the rust `xla` 0.1.6 crate) rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (all under artifacts/):
  wgan_op.hlo.txt       (params f32[d], seed i32)      -> (dual, g_loss, w_dist)
  wgan_sample.hlo.txt   (params, seed)                 -> (fake[N,2], real[N,2])
  wgan_init.hlo.txt     (seed)                         -> (params,)
  wgan.meta             layer map + dims (plain text, parsed by rust)
  lm_grad.hlo.txt       (params f32[d], tokens i32[B,T+1]) -> (grads, loss)
  lm_eval.hlo.txt       (params, tokens)               -> (loss,)
  lm_init.hlo.txt       (seed)                         -> (params,)
  lm.meta               layer map + dims
  quantize_k8.hlo.txt   (v f32[n], levels f32[8], uniforms f32[n]) -> (q,)
                        the L1 Pallas kernel lowered standalone so the rust
                        runtime can cross-validate its own quantizer via PJRT
  testvectors/quant_*.txt  shared quantization test vectors (rust cross-check)

`make artifacts` re-runs this only when python sources change.
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as wgan
from . import transformer as lm
from .kernels import quantize as qk
from .kernels import ref


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def write(path, text):
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text)} bytes)")


def write_meta(path, kind, cfg, extra=()):
    lines = [f"kind {kind}", f"dim {cfg.dim}"]
    for k, v in extra:
        lines.append(f"{k} {v}")
    shapes = {name: shape for name, shape, _ in cfg.layers}
    for name, off, ln, ty in cfg.layer_spec():
        shape = shapes[name]
        rows = shape[0]
        cols = ln // rows
        lines.append(f"layer {name} {off} {ln} {ty} {rows} {cols}")
    write(path, "\n".join(lines) + "\n")


def lower_wgan(outdir):
    cfg = wgan.WganConfig()
    print(f"[wgan] dim={cfg.dim} batch={cfg.batch} hidden={cfg.hidden}")
    pspec = jax.ShapeDtypeStruct((cfg.dim,), jnp.float32)
    sspec = jax.ShapeDtypeStruct((), jnp.int32)

    op = jax.jit(lambda p, s: wgan.wgan_operator(cfg, p, s))
    write(f"{outdir}/wgan_op.hlo.txt", to_hlo_text(op.lower(pspec, sspec)))

    samp = jax.jit(lambda p, s: wgan.wgan_sampler(cfg, p, s))
    write(f"{outdir}/wgan_sample.hlo.txt", to_hlo_text(samp.lower(pspec, sspec)))

    init = jax.jit(lambda s: wgan.wgan_init(cfg, s))
    write(f"{outdir}/wgan_init.hlo.txt", to_hlo_text(init.lower(sspec)))

    write_meta(
        f"{outdir}/wgan.meta",
        "wgan",
        cfg,
        extra=[
            ("batch", cfg.batch),
            ("sample_n", cfg.sample_n),
            ("gen_dim", cfg.gen_dim),
            ("modes", cfg.modes),
            ("mode_radius", cfg.mode_radius),
            ("mode_std", cfg.mode_std),
        ],
    )


def lower_lm(outdir):
    cfg = lm.LmConfig()
    print(
        f"[lm] dim={cfg.dim} vocab={cfg.vocab} d={cfg.d_model} "
        f"layers={cfg.n_layers} seq={cfg.seq} batch={cfg.batch}"
    )
    pspec = jax.ShapeDtypeStruct((cfg.dim,), jnp.float32)
    tspec = jax.ShapeDtypeStruct((cfg.batch, cfg.seq + 1), jnp.int32)
    sspec = jax.ShapeDtypeStruct((), jnp.int32)

    grad = jax.jit(lambda p, t: lm.lm_grad(cfg, p, t))
    write(f"{outdir}/lm_grad.hlo.txt", to_hlo_text(grad.lower(pspec, tspec)))

    ev = jax.jit(lambda p, t: lm.lm_eval(cfg, p, t))
    write(f"{outdir}/lm_eval.hlo.txt", to_hlo_text(ev.lower(pspec, tspec)))

    init = jax.jit(lambda s: lm.lm_init(cfg, s))
    write(f"{outdir}/lm_init.hlo.txt", to_hlo_text(init.lower(sspec)))

    write_meta(
        f"{outdir}/lm.meta",
        "lm",
        cfg,
        extra=[
            ("vocab", cfg.vocab),
            ("d_model", cfg.d_model),
            ("n_layers", cfg.n_layers),
            ("seq", cfg.seq),
            ("batch", cfg.batch),
        ],
    )


QUANT_N = 4096
QUANT_LEVELS = 8


def lower_quantize(outdir):
    """Standalone lowering of the L1 Pallas quantization kernel."""
    vspec = jax.ShapeDtypeStruct((QUANT_N,), jnp.float32)
    lspec = jax.ShapeDtypeStruct((QUANT_LEVELS,), jnp.float32)
    fn = jax.jit(lambda v, l, u: (qk.quantize(v, l, u, q=2),))
    write(f"{outdir}/quantize_k8.hlo.txt", to_hlo_text(fn.lower(vspec, lspec, vspec)))


def emit_testvectors(outdir):
    """Deterministic quantization cases shared with the rust test-suite.

    Format (one float per line blocks, '#'-prefixed section headers):
      # case <i> n <n> levels <L> q <q>
      # v / levels / uniforms / expected
    """
    tvdir = os.path.join(outdir, "testvectors")
    os.makedirs(tvdir, exist_ok=True)
    rng = np.random.default_rng(7)
    cases = []
    for i, (n, nl, q) in enumerate(
        [(16, 4, 2), (100, 8, 2), (257, 8, 1), (1024, 16, 2), (33, 6, 2)]
    ):
        v = rng.standard_normal(n).astype(np.float32)
        if i == 1:
            v[::7] = 0.0  # exercise exact zeros
        inner = np.sort(rng.uniform(0.02, 0.98, nl - 2)).astype(np.float32)
        levels = np.concatenate([[0.0], inner, [1.0]]).astype(np.float32)
        u = rng.uniform(0, 1, n).astype(np.float32)
        expected = np.asarray(
            ref.quantize_ref(jnp.asarray(v), jnp.asarray(levels), jnp.asarray(u), q=q)
        )
        kern = np.asarray(
            qk.quantize(jnp.asarray(v), jnp.asarray(levels), jnp.asarray(u), q=q)
        )
        np.testing.assert_allclose(kern, expected, rtol=1e-5, atol=1e-6)
        cases.append((n, nl, q, v, levels, u, expected))

    lines = [f"ncases {len(cases)}"]
    for i, (n, nl, q, v, levels, u, expected) in enumerate(cases):
        lines.append(f"case {i} n {n} levels {nl} q {q}")
        for tag, arr in [("v", v), ("levels", levels), ("u", u), ("expected", expected)]:
            lines.append(tag + " " + " ".join(repr(float(x)) for x in arr))
    write(os.path.join(tvdir, "quant_cases.txt"), "\n".join(lines) + "\n")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default="", help="comma list: wgan,lm,quantize,tv")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    only = set(args.only.split(",")) if args.only else set()

    jax.config.update("jax_platform_name", "cpu")
    if not only or "wgan" in only:
        lower_wgan(args.out)
    if not only or "lm" in only:
        lower_lm(args.out)
    if not only or "quantize" in only:
        lower_quantize(args.out)
    if not only or "tv" in only:
        emit_testvectors(args.out)
    print("AOT done.")


if __name__ == "__main__":
    sys.exit(main())

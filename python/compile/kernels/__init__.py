"""L1: Pallas kernels (quantize, matmul) + pure-jnp oracles (ref)."""
from . import matmul, quantize, ref  # noqa: F401

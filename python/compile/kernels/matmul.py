"""L1 Pallas kernel: MXU-tiled matmul used by the L2 models.

The paper's models (WGAN MLPs, Transformer-XL blocks) spend their compute in
dense matmuls. On GPU the reference implementation leans on cuBLAS/WMMA; the
TPU rethink is a classic systolic-array schedule: (bm, bn) output tiles
accumulated over bk-sized K panels, A and B panels staged HBM->VMEM by
BlockSpec, f32 accumulation on the MXU (bf16 inputs would halve the VMEM
footprint; we keep f32 since the CPU interpret path validates numerics).

VMEM footprint per grid step = bm*bk + bk*bn + bm*bn floats; with the default
128x128x128 tiling that is 3 * 64 KiB = 192 KiB, well under a TPU core's ~16
MiB VMEM, leaving room for double buffering (the TPU compiler pipelines the
HBM->VMEM copies across the innermost k steps).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(dim, target=128):
    """Largest divisor of ``dim`` that is <= target (TPU-friendly when the
    caller pads dims to multiples of 8; exact for our model dims)."""
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


def _mm_kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def _matmul_raw(a, b):
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bk, bn = _pick_block(m), _pick_block(k), _pick_block(n)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _mm_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        interpret=True,
    )(a, b)


# The accumulate-over-k grid pattern has no JVP rule in interpret mode, so
# differentiation is supplied explicitly — and the backward pass reuses the
# same MXU-tiled kernel: dA = g @ B^T, dB = A^T @ g.
@jax.custom_vjp
def matmul(a, b):
    """C = A @ B via the tiled Pallas kernel. A: f32[M,K], B: f32[K,N]."""
    return _matmul_raw(a, b)


def _matmul_fwd(a, b):
    return _matmul_raw(a, b), (a, b)


def _matmul_bwd(res, g):
    a, b = res
    return _matmul_raw(g, b.T), _matmul_raw(a.T, g)


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def linear(x, w, b=None):
    """x @ w (+ b) through the Pallas matmul."""
    y = matmul(x, w)
    if b is not None:
        y = y + b
    return y

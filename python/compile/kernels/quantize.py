"""L1 Pallas kernel: unbiased stochastic layer-wise quantization.

This is the paper's compute hot-spot (Section 3): given a vector ``v``, its
``L^q`` norm, a sequence of quantization levels ``0 = l_0 < l_1 < ... <
l_{s+1} = 1`` and a stream of uniforms, emit the unbiased stochastic
quantization

    Q(v_i) = ||v||_q * sign(v_i) * q_l(|v_i| / ||v||_q)

where ``q_l(u)`` rounds ``u`` to ``l_tau`` or ``l_{tau+1}`` with probability
proportional to the relative distance (paper, Section 3.1).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the CUDA original does a
warp-level bucket max + LUT search; on TPU the level table is tiny and lives
in VMEM alongside each (8x128-aligned) gradient tile, and the level search is
a branchless vectorized comparison on the VPU. ``interpret=True`` is mandatory
on CPU PJRT (Mosaic custom-calls cannot run there); the BlockSpec structure is
the TPU schedule.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block of coordinates processed per grid step. 1024 = 8 * 128: one VPU-tile
# row-aligned chunk; at f32 this is 4 KiB of gradient + 4 KiB of uniforms +
# the (tiny) level table resident in VMEM per step.
BLOCK = 1024


def _quantize_kernel(v_ref, norm_ref, levels_ref, u_ref, o_ref):
    """Quantize one block of coordinates against the level table."""
    v = v_ref[...]
    norm = norm_ref[0]
    levels = levels_ref[...]  # [L], levels[0] = 0, levels[L-1] = 1
    u01 = u_ref[...]

    inv = jnp.where(norm > 0.0, 1.0 / jnp.maximum(norm, 1e-38), 0.0)
    mag = jnp.clip(jnp.abs(v) * inv, 0.0, 1.0)

    # Branchless level search: tau = #{j : levels[j] <= mag} - 1, clipped so
    # that [l_tau, l_{tau+1}] is always a valid bracket (mag == 1.0 lands in
    # the last interval and rounds up with probability 1).
    cmp = (levels[None, :] <= mag[:, None]).astype(jnp.int32)
    tau = jnp.clip(jnp.sum(cmp, axis=1) - 1, 0, levels.shape[0] - 2)
    lo = levels[tau]
    hi = levels[tau + 1]

    xi = (mag - lo) / jnp.maximum(hi - lo, 1e-38)
    qmag = jnp.where(u01 < xi, hi, lo)
    o_ref[...] = norm * jnp.sign(v) * qmag


def quantize_block(v, norm, levels, uniforms):
    """Stochastically quantize ``v`` (flat f32[n], n % BLOCK == 0 not
    required — we pad) against ``levels`` (f32[L] with endpoints 0 and 1).

    ``norm`` is the f32[1] L^q norm of the *unpadded* vector; ``uniforms`` are
    i.i.d. U[0,1) of the same shape as ``v``.
    """
    n = v.shape[0]
    nl = levels.shape[0]
    pad = (-n) % BLOCK
    if pad:
        v = jnp.pad(v, (0, pad))
        uniforms = jnp.pad(uniforms, (0, pad))
    npad = v.shape[0]
    grid = (npad // BLOCK,)
    out = pl.pallas_call(
        _quantize_kernel,
        out_shape=jax.ShapeDtypeStruct((npad,), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((nl,), lambda i: (0,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        interpret=True,
    )(v, norm, levels, uniforms)
    return out[:n]


def lq_norm(v, q):
    """L^q norm used for normalization; q in {1, 2} or jnp.inf."""
    if q == jnp.inf or q == "inf":
        return jnp.max(jnp.abs(v))
    return jnp.sum(jnp.abs(v) ** q) ** (1.0 / q)


@partial(jax.jit, static_argnames=("q",))
def quantize(v, levels, uniforms, q=2):
    """Full unbiased quantization Q_{L}(v) of a flat vector with one level
    sequence (one 'type'); returns the dequantized vector."""
    norm = lq_norm(v, q).reshape((1,))
    return quantize_block(v, norm, levels, uniforms)


def quantize_layerwise(v, offsets, lengths, level_table, type_of_layer, uniforms, q=2):
    """Layer-wise quantization of a flat vector: layer ``k`` spans
    ``v[offsets[k]:offsets[k]+lengths[k]]`` and uses the level sequence
    ``level_table[type_of_layer[k]]`` with its own norm. Python-level loop —
    layers are static at trace time (they come from the model's layer map).
    """
    outs = []
    for off, ln, ty in zip(offsets, lengths, type_of_layer):
        seg = jax.lax.dynamic_slice(v, (off,), (ln,))
        useg = jax.lax.dynamic_slice(uniforms, (off,), (ln,))
        norm = lq_norm(seg, q).reshape((1,))
        outs.append(quantize_block(seg, norm, jnp.asarray(level_table[ty]), useg))
    return jnp.concatenate(outs)

"""Pure-jnp oracles for the L1 Pallas kernels.

These implement the exact same math with no Pallas machinery; pytest asserts
allclose between kernel and oracle across shape/level/dtype sweeps
(python/tests/test_kernel.py), and aot.py dumps shared test vectors that the
rust quantizer checks against bit-for-bit (rust/tests/quant_crosscheck.rs).
"""

import jax.numpy as jnp


def lq_norm_ref(v, q):
    if q == jnp.inf or q == "inf":
        return jnp.max(jnp.abs(v))
    return jnp.sum(jnp.abs(v) ** q) ** (1.0 / q)


def quantize_ref(v, levels, uniforms, q=2):
    """Reference unbiased stochastic quantization (single type)."""
    norm = lq_norm_ref(v, q)
    inv = jnp.where(norm > 0.0, 1.0 / jnp.maximum(norm, 1e-38), 0.0)
    mag = jnp.clip(jnp.abs(v) * inv, 0.0, 1.0)
    cmp = (levels[None, :] <= mag[:, None]).astype(jnp.int32)
    tau = jnp.clip(jnp.sum(cmp, axis=1) - 1, 0, levels.shape[0] - 2)
    lo = levels[tau]
    hi = levels[tau + 1]
    xi = (mag - lo) / jnp.maximum(hi - lo, 1e-38)
    qmag = jnp.where(uniforms < xi, hi, lo)
    return norm * jnp.sign(v) * qmag


def quantize_indices_ref(v, levels, uniforms, q=2):
    """Same as quantize_ref but returns (level_index, sign, norm) — the wire
    representation the coding layer consumes."""
    norm = lq_norm_ref(v, q)
    inv = jnp.where(norm > 0.0, 1.0 / jnp.maximum(norm, 1e-38), 0.0)
    mag = jnp.clip(jnp.abs(v) * inv, 0.0, 1.0)
    cmp = (levels[None, :] <= mag[:, None]).astype(jnp.int32)
    tau = jnp.clip(jnp.sum(cmp, axis=1) - 1, 0, levels.shape[0] - 2)
    lo = levels[tau]
    hi = levels[tau + 1]
    xi = (mag - lo) / jnp.maximum(hi - lo, 1e-38)
    idx = jnp.where(uniforms < xi, tau + 1, tau)
    return idx, jnp.sign(v), norm


def matmul_ref(a, b):
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def variance_bound_eps_q(level_seqs, d, q):
    """Theorem 5.1 epsilon_Q for a set of level sequences (one per type).

    level_seqs: list of 1-D arrays, each [0, l_1, ..., l_alpha, 1].
    Mirrors rust/src/quant/variance.rs (tested for agreement via shared
    vectors).
    """
    import numpy as np

    lbar_m = []
    l1s = []
    for seq in level_seqs:
        seq = np.asarray(seq, dtype=np.float64)
        ratios = seq[2:] / seq[1:-1]  # l_{j+1}/l_j for j >= 1
        lbar_m.append(ratios.max() if ratios.size else 1.0)
        l1s.append(seq[1])
    lbar = max(lbar_m)
    l1 = max(l1s)
    qm = min(q, 2)
    d_th = (2.0 / l1) ** qm
    eps = (lbar - 1.0) ** 2 / (4.0 * lbar)
    if d >= d_th:
        eps += l1 * d ** (1.0 / qm) - 1.0
    else:
        eps += 0.25 * l1**2 * d ** (2.0 / qm)
    return eps

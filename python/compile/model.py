"""L2: WGAN minimax game as a VI operator (build-time JAX, lowered AOT).

The paper trains a Wasserstein GAN (Arjovsky et al., 2017) on CIFAR with the
VI formulation of Gidel et al. (2018): for the saddle problem

    min_G max_D  E_x[D(x)] - E_z[D(G(z))]

the (stochastic) dual vector / operator is

    A(theta) = ( grad_G L_G(theta),  grad_D L_D(theta) )
    L_G = -E_z[D(G(z))],   L_D = E_z[D(G(z))] - E_x[D(x)]

Environment substitution (DESIGN.md): CIFAR is replaced by an 8-mode 2-D
Gaussian mixture synthesized *inside the graph* from the seed input, and the
DCGAN conv stacks by MLPs routed through the L1 Pallas matmul kernel. The VI
structure, gradient-compression path and FID metric formula are unchanged.

All functions operate on a single flat f32[d] parameter vector; the layer
segmentation (offsets / lengths / types) is exported via `layer_spec()` and
written to artifacts/wgan.meta by aot.py for the rust coordinator.
"""

import math
import os
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from .kernels.matmul import linear

# ---------------------------------------------------------------------------
# Configuration (env-overridable so `make artifacts` can scale the model).
# ---------------------------------------------------------------------------


@dataclass
class WganConfig:
    z_dim: int = int(os.environ.get("QODA_WGAN_ZDIM", 16))
    hidden: int = int(os.environ.get("QODA_WGAN_HIDDEN", 64))
    data_dim: int = 2
    batch: int = int(os.environ.get("QODA_WGAN_BATCH", 256))
    sample_n: int = int(os.environ.get("QODA_WGAN_SAMPLES", 512))
    modes: int = 8
    mode_radius: float = 2.0
    mode_std: float = 0.05
    layers: list = field(default_factory=list)

    def __post_init__(self):
        h, z, x = self.hidden, self.z_dim, self.data_dim
        # (name, shape, type) — types drive the layer-wise quantization.
        self.layers = [
            ("g.fc1.w", (z, h), "ff"),
            ("g.fc1.b", (h,), "bias"),
            ("g.fc2.w", (h, h), "ff"),
            ("g.fc2.b", (h,), "bias"),
            ("g.out.w", (h, x), "ff"),
            ("g.out.b", (x,), "bias"),
            ("d.fc1.w", (x, h), "ff"),
            ("d.fc1.b", (h,), "bias"),
            ("d.fc2.w", (h, h), "ff"),
            ("d.fc2.b", (h,), "bias"),
            ("d.out.w", (h, 1), "ff"),
            ("d.out.b", (1,), "bias"),
        ]

    @property
    def dim(self):
        return sum(int(math.prod(s)) for _, s, _ in self.layers)

    def layer_spec(self):
        """[(name, offset, length, type)] over the flat parameter vector."""
        out, off = [], 0
        for name, shape, ty in self.layers:
            ln = int(math.prod(shape))
            out.append((name, off, ln, ty))
            off += ln
        return out

    # generator params come first; the critic segment starts here
    @property
    def gen_dim(self):
        return sum(
            int(math.prod(s)) for n, s, _ in self.layers if n.startswith("g.")
        )


def unflatten(cfg: WganConfig, flat):
    params, off = {}, 0
    for name, shape, _ in cfg.layers:
        ln = int(math.prod(shape))
        params[name] = flat[off : off + ln].reshape(shape)
        off += ln
    return params


def flatten_tree(cfg: WganConfig, tree):
    return jnp.concatenate([tree[name].reshape(-1) for name, _, _ in cfg.layers])


def init_params(cfg: WganConfig, key):
    """He-style init, returned as the flat vector the rust side owns."""
    parts = []
    for name, shape, ty in cfg.layers:
        key, sub = jax.random.split(key)
        if ty == "bias":
            parts.append(jnp.zeros(shape, jnp.float32).reshape(-1))
        else:
            fan_in = shape[0]
            w = jax.random.normal(sub, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)
            parts.append(w.reshape(-1))
    return jnp.concatenate(parts)


# ---------------------------------------------------------------------------
# Networks (all matmuls go through the L1 Pallas kernel).
# ---------------------------------------------------------------------------


def generator(cfg, p, z):
    h = jax.nn.relu(linear(z, p["g.fc1.w"], p["g.fc1.b"]))
    h = jax.nn.relu(linear(h, p["g.fc2.w"], p["g.fc2.b"]))
    return linear(h, p["g.out.w"], p["g.out.b"])


def critic(cfg, p, x):
    h = jax.nn.relu(linear(x, p["d.fc1.w"], p["d.fc1.b"]))
    h = jax.nn.relu(linear(h, p["d.fc2.w"], p["d.fc2.b"]))
    return linear(h, p["d.out.w"], p["d.out.b"])[:, 0]


def sample_real(cfg, key, n):
    """8-mode Gaussian mixture on a circle (the classic WGAN toy testbed)."""
    km, kn = jax.random.split(key)
    mode = jax.random.randint(km, (n,), 0, cfg.modes)
    ang = 2.0 * jnp.pi * mode.astype(jnp.float32) / cfg.modes
    centers = cfg.mode_radius * jnp.stack([jnp.cos(ang), jnp.sin(ang)], axis=-1)
    return centers + cfg.mode_std * jax.random.normal(kn, (n, cfg.data_dim))


# ---------------------------------------------------------------------------
# The VI operator (stochastic dual vector) + auxiliary entry points.
# ---------------------------------------------------------------------------


def wgan_operator(cfg: WganConfig, params_flat, seed):
    """A(theta) + noise-from-minibatch: returns (dual f32[d], g_loss, w_dist).

    The minibatch subsampling *is* the stochastic oracle of Section 2.4: at a
    saddle point the residual scales with the operator norm (relative-noise
    regime); far from it the minibatch variance acts as absolute noise.
    """
    key = jax.random.PRNGKey(seed)
    kz, kx = jax.random.split(key)
    z = jax.random.normal(kz, (cfg.batch, cfg.z_dim))
    real = sample_real(cfg, kx, cfg.batch)

    def g_loss_fn(pf):
        p = unflatten(cfg, pf)
        return -jnp.mean(critic(cfg, p, generator(cfg, p, z)))

    def d_loss_fn(pf):
        p = unflatten(cfg, pf)
        fake = generator(cfg, p, z)
        return jnp.mean(critic(cfg, p, fake)) - jnp.mean(critic(cfg, p, real))

    g_loss, g_grad = jax.value_and_grad(g_loss_fn)(params_flat)
    d_loss, d_grad = jax.value_and_grad(d_loss_fn)(params_flat)

    gd = cfg.gen_dim
    dual = jnp.concatenate([g_grad[:gd], d_grad[gd:]])
    # w_dist = E D(real) - E D(fake) = -d_loss
    return dual, g_loss, -d_loss


def wgan_sampler(cfg: WganConfig, params_flat, seed):
    """(fake[N,2], real[N,2]) for the FID evaluation on the rust side."""
    key = jax.random.PRNGKey(seed)
    kz, kx = jax.random.split(key)
    p = unflatten(cfg, params_flat)
    z = jax.random.normal(kz, (cfg.sample_n, cfg.z_dim))
    fake = generator(cfg, p, z)
    real = sample_real(cfg, kx, cfg.sample_n)
    return fake, real


def wgan_init(cfg: WganConfig, seed):
    """Initial flat parameter vector (lowered so rust never inits params)."""
    return (init_params(cfg, jax.random.PRNGKey(seed)),)

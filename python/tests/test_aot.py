"""AOT pipeline tests: meta emission consistency, HLO text validity,
layer-spec/shape agreement between python and what rust parses."""

import math
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as wgan
from compile import transformer as lm

jax.config.update("jax_platform_name", "cpu")

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_hlo_text_lowering_roundtrip():
    """A tiny jitted fn lowers to parseable HLO text containing ENTRY."""
    fn = jax.jit(lambda x: (x * 2.0 + 1.0,))
    txt = aot.to_hlo_text(fn.lower(jax.ShapeDtypeStruct((4,), jnp.float32)))
    assert "ENTRY" in txt
    assert "f32[4]" in txt


def test_wgan_meta_matches_config(tmp_path):
    cfg = wgan.WganConfig()
    aot.write_meta(str(tmp_path / "w.meta"), "wgan", cfg, extra=[("gen_dim", cfg.gen_dim)])
    lines = (tmp_path / "w.meta").read_text().strip().splitlines()
    assert lines[0] == "kind wgan"
    assert lines[1] == f"dim {cfg.dim}"
    layer_lines = [l for l in lines if l.startswith("layer ")]
    assert len(layer_lines) == len(cfg.layers)
    # offsets contiguous and rows*cols == len
    off = 0
    for l in layer_lines:
        toks = l.split()
        assert int(toks[2]) == off
        ln, rows, cols = int(toks[3]), int(toks[5]), int(toks[6])
        assert rows * cols == ln
        off += ln
    assert off == cfg.dim


def test_lm_meta_types_cover_ablation(tmp_path):
    cfg = lm.LmConfig()
    aot.write_meta(str(tmp_path / "l.meta"), "lm", cfg)
    txt = (tmp_path / "l.meta").read_text()
    for ty in ["embedding", "attention", "ff", "norm", "bias"]:
        assert f" {ty} " in txt, ty


def test_layer_spec_total_dims():
    wcfg = wgan.WganConfig()
    assert sum(ln for _, _, ln, _ in wcfg.layer_spec()) == wcfg.dim
    lcfg = lm.LmConfig()
    assert sum(ln for _, _, ln, _ in lcfg.layer_spec()) == lcfg.dim
    # gen params strictly before critic params
    gen_layers = [s for s in wcfg.layer_spec() if s[0].startswith("g.")]
    crit_layers = [s for s in wcfg.layer_spec() if s[0].startswith("d.")]
    assert max(o + l for _, o, l, _ in gen_layers) == wcfg.gen_dim
    assert min(o for _, o, _, _ in crit_layers) == wcfg.gen_dim


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "wgan_op.hlo.txt")),
    reason="artifacts not built",
)
def test_artifacts_exist_and_nonempty():
    for name in [
        "wgan_op.hlo.txt",
        "wgan_sample.hlo.txt",
        "wgan_init.hlo.txt",
        "wgan.meta",
        "lm_grad.hlo.txt",
        "lm_eval.hlo.txt",
        "lm_init.hlo.txt",
        "lm.meta",
        "quantize_k8.hlo.txt",
    ]:
        path = os.path.join(ART, name)
        assert os.path.getsize(path) > 100, name


def test_quantize_artifact_signature():
    """The standalone kernel lowering takes (v, levels, uniforms)."""
    path = os.path.join(ART, "quantize_k8.hlo.txt")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    txt = open(path).read()
    assert f"f32[{aot.QUANT_N}]" in txt
    assert f"f32[{aot.QUANT_LEVELS}]" in txt

"""L1 kernel correctness: Pallas vs pure-jnp oracle (hypothesis sweeps).

This is the CORE correctness signal for the quantization kernel: shapes,
level-sequence geometry, norms q in {1, 2, inf}, zeros, padding boundaries.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import quantize as qk
from compile.kernels import matmul as mk
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _levels(nl, rng):
    inner = np.sort(rng.uniform(0.01, 0.99, nl - 2)).astype(np.float32)
    # enforce strict ordering
    inner = np.unique(inner)
    return np.concatenate([[0.0], inner, [1.0]]).astype(np.float32)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=3000),
    nl=st.integers(min_value=3, max_value=17),
    q=st.sampled_from([1, 2, "inf"]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_quantize_matches_ref(n, nl, q, seed):
    rng = np.random.default_rng(seed)
    v = (rng.standard_normal(n) * rng.uniform(0.1, 10)).astype(np.float32)
    levels = _levels(nl, rng)
    u = rng.uniform(0, 1, n).astype(np.float32)
    qq = jnp.inf if q == "inf" else q
    got = qk.quantize(jnp.asarray(v), jnp.asarray(levels), jnp.asarray(u), q=qq)
    want = ref.quantize_ref(jnp.asarray(v), jnp.asarray(levels), jnp.asarray(u), q=qq)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_quantize_zero_vector():
    v = jnp.zeros(64, jnp.float32)
    levels = jnp.asarray([0.0, 0.5, 1.0], jnp.float32)
    u = jnp.full((64,), 0.3, jnp.float32)
    out = qk.quantize(v, levels, u, q=2)
    np.testing.assert_array_equal(np.asarray(out), np.zeros(64, np.float32))


def test_quantize_output_in_level_set():
    rng = np.random.default_rng(0)
    v = rng.standard_normal(500).astype(np.float32)
    levels = _levels(8, rng)
    u = rng.uniform(0, 1, 500).astype(np.float32)
    out = np.asarray(qk.quantize(jnp.asarray(v), jnp.asarray(levels), jnp.asarray(u)))
    norm = float(np.linalg.norm(v))
    mags = np.abs(out) / norm
    # every output magnitude is (numerically) one of the levels
    d = np.min(np.abs(mags[:, None] - levels[None, :]), axis=1)
    assert np.all(d < 1e-5)


def test_quantize_unbiased_statistically():
    """E[Q(v)] = v — the paper's defining property of the scheme."""
    rng = np.random.default_rng(3)
    n, reps = 256, 400
    v = rng.standard_normal(n).astype(np.float32)
    levels = _levels(6, rng)
    acc = np.zeros(n, np.float64)
    for r in range(reps):
        u = rng.uniform(0, 1, n).astype(np.float32)
        acc += np.asarray(
            qk.quantize(jnp.asarray(v), jnp.asarray(levels), jnp.asarray(u))
        )
    mean = acc / reps
    # componentwise CLT bound: 5 sigma of the quantization variance
    norm = np.linalg.norm(v)
    err = np.abs(mean - v)
    assert np.max(err) < 5 * norm * 0.5 / np.sqrt(reps), np.max(err)


def test_quantize_variance_bound_thm51():
    """Empirical variance <= eps_Q ||v||^2 (Theorem 5.1), M = 1."""
    rng = np.random.default_rng(11)
    n, reps = 128, 300
    v = rng.standard_normal(n).astype(np.float32)
    levels = np.asarray([0.0, 0.25, 0.5, 0.75, 1.0], np.float32)
    norm2 = float(np.sum(v.astype(np.float64) ** 2))
    acc = 0.0
    for r in range(reps):
        u = rng.uniform(0, 1, n).astype(np.float32)
        qv = np.asarray(
            qk.quantize(jnp.asarray(v), jnp.asarray(levels), jnp.asarray(u))
        )
        acc += float(np.sum((qv - v) ** 2))
    emp = acc / reps
    eps = ref.variance_bound_eps_q([levels], n, 2)
    assert emp <= eps * norm2 * 1.05, (emp, eps * norm2)


@settings(max_examples=15, deadline=None)
@given(
    m=st.sampled_from([8, 16, 24, 64, 128]),
    k=st.sampled_from([8, 16, 64, 128, 192]),
    n=st.sampled_from([8, 16, 64, 128]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, k)).astype(np.float32)
    b = rng.standard_normal((k, n)).astype(np.float32)
    got = np.asarray(mk.matmul(jnp.asarray(a), jnp.asarray(b)))
    want = np.asarray(ref.matmul_ref(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_matmul_block_picker():
    assert mk._pick_block(128) == 128
    assert mk._pick_block(256) == 128
    assert mk._pick_block(100) == 100
    assert mk._pick_block(192) == 96
    assert mk._pick_block(1) == 1


def test_layerwise_quantize_segments_independent():
    """Each layer is normalized by its own norm (the whole point)."""
    rng = np.random.default_rng(5)
    a = rng.standard_normal(100).astype(np.float32)
    b = (rng.standard_normal(50) * 100).astype(np.float32)  # big-norm layer
    v = np.concatenate([a, b])
    u = rng.uniform(0, 1, 150).astype(np.float32)
    lv = {"ff": np.asarray([0.0, 0.5, 1.0], np.float32)}
    out = np.asarray(
        qk.quantize_layerwise(
            jnp.asarray(v), [0, 100], [100, 50], lv, ["ff", "ff"], jnp.asarray(u)
        )
    )
    wa = np.asarray(
        ref.quantize_ref(jnp.asarray(a), jnp.asarray(lv["ff"]), jnp.asarray(u[:100]))
    )
    wb = np.asarray(
        ref.quantize_ref(jnp.asarray(b), jnp.asarray(lv["ff"]), jnp.asarray(u[100:]))
    )
    np.testing.assert_allclose(out, np.concatenate([wa, wb]), rtol=1e-5, atol=1e-6)

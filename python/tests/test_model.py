"""L2 model correctness: shapes, flatten/unflatten round trips, operator
structure (WGAN VI operator), transformer LM gradients."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as wgan
from compile import transformer as lm

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def wcfg():
    return wgan.WganConfig()


@pytest.fixture(scope="module")
def lcfg():
    return lm.LmConfig()


# ------------------------------- WGAN --------------------------------------


def test_wgan_layer_spec_contiguous(wcfg):
    off = 0
    for name, o, ln, ty in wcfg.layer_spec():
        assert o == off, name
        assert ln > 0
        assert ty in ("ff", "bias")
        off += ln
    assert off == wcfg.dim


def test_wgan_gen_dim_prefix(wcfg):
    spec = wcfg.layer_spec()
    gen_layers = [s for s in spec if s[0].startswith("g.")]
    assert gen_layers[-1][1] + gen_layers[-1][2] == wcfg.gen_dim


def test_wgan_flatten_roundtrip(wcfg):
    flat = wgan.init_params(wcfg, jax.random.PRNGKey(0))
    tree = wgan.unflatten(wcfg, flat)
    back = wgan.flatten_tree(wcfg, tree)
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(back))


def test_wgan_operator_shapes(wcfg):
    flat = wgan.init_params(wcfg, jax.random.PRNGKey(0))
    dual, gl, wd = wgan.wgan_operator(wcfg, flat, jnp.int32(1))
    assert dual.shape == (wcfg.dim,)
    assert gl.shape == () and wd.shape == ()
    assert np.all(np.isfinite(np.asarray(dual)))


def test_wgan_operator_is_gradient_field(wcfg):
    """The generator segment of A equals d(g_loss)/d(theta_G)."""
    flat = wgan.init_params(wcfg, jax.random.PRNGKey(2))
    dual, gl, wd = wgan.wgan_operator(wcfg, flat, jnp.int32(7))
    dual2, gl2, _ = wgan.wgan_operator(wcfg, flat, jnp.int32(7))
    np.testing.assert_array_equal(np.asarray(dual), np.asarray(dual2))
    assert float(gl) == float(gl2)


def test_wgan_operator_seed_changes_sample(wcfg):
    flat = wgan.init_params(wcfg, jax.random.PRNGKey(2))
    d1, _, _ = wgan.wgan_operator(wcfg, flat, jnp.int32(1))
    d2, _, _ = wgan.wgan_operator(wcfg, flat, jnp.int32(2))
    assert not np.allclose(np.asarray(d1), np.asarray(d2))


def test_wgan_sampler_real_modes(wcfg):
    flat = wgan.init_params(wcfg, jax.random.PRNGKey(0))
    fake, real = wgan.wgan_sampler(wcfg, flat, jnp.int32(3))
    assert fake.shape == (wcfg.sample_n, 2)
    assert real.shape == (wcfg.sample_n, 2)
    r = np.linalg.norm(np.asarray(real), axis=1)
    # all real points near the mode circle of radius 2
    assert np.all(np.abs(r - wcfg.mode_radius) < 0.5)


def test_wgan_critic_grad_descends(wcfg):
    """One gradient step on the critic decreases d_loss (sanity of signs)."""
    flat = wgan.init_params(wcfg, jax.random.PRNGKey(4))
    seed = jnp.int32(5)
    dual, _, wd0 = wgan.wgan_operator(wcfg, flat, seed)
    step = flat - 0.05 * dual
    _, _, wd1 = wgan.wgan_operator(wcfg, step, seed)
    # moving along -A increases the W-distance estimate for the critic
    assert float(wd1) >= float(wd0) - 1e-3


# ---------------------------- Transformer ----------------------------------


def test_lm_layer_spec_types(lcfg):
    types = {ty for _, _, _, ty in lcfg.layer_spec()}
    assert types == {"embedding", "attention", "ff", "norm", "bias"}
    off = 0
    for name, o, ln, ty in lcfg.layer_spec():
        assert o == off, name
        off += ln
    assert off == lcfg.dim


def test_lm_forward_shapes(lcfg):
    flat = lm.init_params(lcfg, jax.random.PRNGKey(0))
    toks = jnp.zeros((lcfg.batch, lcfg.seq), jnp.int32)
    logits = lm.forward(lcfg, flat, toks)
    assert logits.shape == (lcfg.batch, lcfg.seq, lcfg.vocab)


def test_lm_grad_finite_and_full(lcfg):
    flat = lm.init_params(lcfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(
        jax.random.PRNGKey(1), (lcfg.batch, lcfg.seq + 1), 0, lcfg.vocab
    ).astype(jnp.int32)
    grads, loss = lm.lm_grad(lcfg, flat, toks)
    assert grads.shape == (lcfg.dim,)
    assert np.isfinite(float(loss))
    g = np.asarray(grads)
    assert np.all(np.isfinite(g))
    # every weight layer receives gradient signal
    for name, off, ln, ty in lcfg.layer_spec():
        if ty in ("bias",):
            continue
        assert np.linalg.norm(g[off : off + ln]) > 0, name


def test_lm_loss_at_init_near_uniform(lcfg):
    flat = lm.init_params(lcfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(
        jax.random.PRNGKey(2), (lcfg.batch, lcfg.seq + 1), 0, lcfg.vocab
    ).astype(jnp.int32)
    (loss,) = lm.lm_eval(lcfg, flat, toks)
    assert abs(float(loss) - np.log(lcfg.vocab)) < 0.7


def test_lm_one_sgd_step_reduces_loss(lcfg):
    flat = lm.init_params(lcfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(
        jax.random.PRNGKey(3), (lcfg.batch, lcfg.seq + 1), 0, lcfg.vocab
    ).astype(jnp.int32)
    grads, loss0 = lm.lm_grad(lcfg, flat, toks)
    (loss1,) = lm.lm_eval(lcfg, flat - 0.5 * grads, toks)
    assert float(loss1) < float(loss0)


def test_lm_causal_mask(lcfg):
    """Changing a future token must not change earlier logits."""
    flat = lm.init_params(lcfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(
        jax.random.PRNGKey(4), (1, lcfg.seq), 0, lcfg.vocab
    ).astype(jnp.int32)
    la = lm.forward(lcfg, flat, toks)
    toks2 = toks.at[0, -1].set((toks[0, -1] + 1) % lcfg.vocab)
    lb = lm.forward(lcfg, flat, toks2)
    np.testing.assert_allclose(
        np.asarray(la[0, :-1]), np.asarray(lb[0, :-1]), rtol=1e-5, atol=1e-5
    )

//! Coding-layer benches: Elias codes, Huffman build/encode/decode, and both
//! wire protocols end to end.

use qoda::bench_harness::bench;
use qoda::coding::bitio::BitWriter;
use qoda::coding::elias::{gamma_decode, gamma_encode};
use qoda::coding::huffman::{normalize, Huffman};
use qoda::coding::protocol::{encode_vector, symbol_counts, Codebooks, ProtocolKind};
use qoda::quant::layer_map::LayerMap;
use qoda::quant::quantizer::quantize;
use qoda::quant::QuantConfig;
use qoda::stats::rng::Rng;

fn main() {
    let n = 1usize << 16;
    let mut rng = Rng::new(3);
    let syms: Vec<u64> = (0..n).map(|_| 1 + rng.below(64)).collect();
    bench("elias/gamma/encode 64k", Some(n as u64), || {
        let mut w = BitWriter::new();
        for &s in &syms {
            gamma_encode(&mut w, s);
        }
        w.finish()
    });
    let mut w = BitWriter::new();
    for &s in &syms {
        gamma_encode(&mut w, s);
    }
    let buf = w.finish();
    bench("elias/gamma/decode 64k", Some(n as u64), || {
        let mut r = buf.reader();
        let mut acc = 0u64;
        for _ in 0..n {
            acc = acc.wrapping_add(gamma_decode(&mut r));
        }
        acc
    });

    let weights: Vec<f64> = (0..32).map(|i| 1.0 / (1 + i) as f64).collect();
    bench("huffman/build/32sym", None, || Huffman::from_weights(&weights));
    let h = Huffman::from_weights(&weights);
    let hsyms: Vec<usize> = (0..n).map(|_| rng.below(32) as usize).collect();
    bench("huffman/encode 64k", Some(n as u64), || {
        let mut w = BitWriter::new();
        for &s in &hsyms {
            h.encode(&mut w, s);
        }
        w.finish()
    });
    let mut hw = BitWriter::new();
    for &s in &hsyms {
        h.encode(&mut hw, s);
    }
    let hbuf = hw.finish();
    bench("huffman/decode 64k", Some(n as u64), || {
        let mut r = hbuf.reader();
        let mut acc = 0usize;
        for _ in 0..n {
            acc = acc.wrapping_add(h.decode(&mut r).unwrap());
        }
        acc
    });

    // protocols end-to-end on a quantized gradient
    let v: Vec<f32> = (0..n).map(|_| rng.gaussian() as f32).collect();
    let map = LayerMap::from_spec(&[("a", n / 2, "ff"), ("b", n / 2, "emb")]).bucketed(128);
    let cfg = QuantConfig {
        sequences: vec![
            qoda::quant::LevelSequence::bits(4),
            qoda::quant::LevelSequence::bits(6),
        ],
        q: 2.0,
    };
    let mut qrng = Rng::new(4);
    let qv = quantize(&v, &map, &cfg, &mut qrng);
    let sizes: Vec<usize> = cfg.sequences.iter().map(|s| s.num_symbols()).collect();
    let probs: Vec<Vec<f64>> =
        symbol_counts(&qv, 2, &sizes).iter().map(|c| normalize(c)).collect();
    for (kind, name) in [(ProtocolKind::Main, "main"), (ProtocolKind::Alternating, "alt")] {
        let books = Codebooks::build(kind, &probs, &map.type_proportions());
        bench(&format!("protocol/{name}/encode 64k"), Some(n as u64), || {
            encode_vector(&qv, &books)
        });
    }
}

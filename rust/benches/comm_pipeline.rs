//! Perf baseline for the unified `comm` pipeline: ns/coordinate and
//! bytes/step for the full encode+decode path — identity vs quantized,
//! both wire protocols, sequential vs per-layer-parallel entropy coding,
//! and the fused single-pass kernels against the staged reference (the
//! streams are bit-identical; only the time differs). Emits its records
//! into the shared machine-readable `results/BENCH_comm.json` (merged with
//! the other comm benches) so CI's perf gate can diff ns/step without
//! scraping stdout.

use qoda::bench_harness::{bench, JsonBench};
use qoda::coding::protocol::ProtocolKind;
use qoda::comm::{
    Adaptation, CommEndpoint, Compressor, IdentityCompressor, QuantCompressor,
};
use qoda::quant::layer_map::LayerMap;
use qoda::quant::QuantConfig;
use qoda::stats::rng::Rng;

fn grad(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| rng.gaussian() * if i % 61 == 0 { 20.0 } else { 0.3 })
        .collect()
}

/// Bench one codec's full encode+decode roundtrip; returns mean ns/step.
fn bench_endpoint(
    json: &mut JsonBench,
    name: &str,
    codec: Box<dyn Compressor>,
    v: &[f64],
) -> f64 {
    let mut ep = CommEndpoint::new(codec);
    let mut out = Vec::with_capacity(v.len());
    // one warm roundtrip so the report shows the packet's steady-state size
    ep.roundtrip_into(v, &mut out).expect("roundtrip");
    let bytes = ep.packet().len_bytes();
    let res = bench(
        &format!("{name}/encode+decode"),
        Some(v.len() as u64),
        || ep.roundtrip_into(v, &mut out).expect("roundtrip"),
    );
    println!(
        "{name:<46} bytes/step: {bytes} ({:.3} bytes/coord)",
        bytes as f64 / v.len() as f64
    );
    json.push_perf(name, res.mean_ns, bytes as f64);
    res.mean_ns
}

/// Fused (default) and staged variants of one configuration, plus the
/// speedup record the perf gate tracks.
fn bench_fused_vs_staged(
    json: &mut JsonBench,
    name: &str,
    mk: impl Fn() -> QuantCompressor,
    v: &[f64],
) {
    let fused_ns = bench_endpoint(json, name, Box::new(mk()), v);
    let mut staged = mk();
    staged.staged = true;
    let staged_ns = bench_endpoint(json, &format!("{name}/staged"), Box::new(staged), v);
    let speedup = staged_ns / fused_ns.max(1e-9);
    println!("{name:<46} fused speedup: {speedup:.2}x");
    json.push(
        &format!("fusion_speedup/{name}"),
        &[("speedup", format!("{speedup:.3}"))],
    );
}

fn main() {
    let mut json = JsonBench::new();
    let n = 1usize << 16;
    let v = grad(n, 3);
    let map = LayerMap::single(n);

    bench_endpoint(
        &mut json,
        "comm/identity/64k",
        Box::new(IdentityCompressor::new()),
        &v,
    );

    for (kind, name) in [
        (ProtocolKind::Main, "main"),
        (ProtocolKind::Alternating, "alternating"),
    ] {
        let map = map.clone();
        bench_fused_vs_staged(
            &mut json,
            &format!("comm/quant5/{name}/64k"),
            move || {
                QuantCompressor::new(
                    map.bucketed(128).with_single_type(),
                    QuantConfig::uniform_bits(1, 5, 2.0),
                    kind,
                    Adaptation::Fixed,
                    7,
                )
            },
            &v,
        );
    }

    // per-layer encode parallelism (same wire bits, more threads)
    for threads in [1usize, 2, 4] {
        let map = map.clone();
        bench_fused_vs_staged(
            &mut json,
            &format!("comm/quant5/main/64k/threads={threads}"),
            move || {
                let mut codec = QuantCompressor::global_bits(&map, 5, 128, 9);
                codec.encode_threads = threads;
                codec
            },
            &v,
        );
    }

    // layer-wise adaptive configuration (the paper's QODA5 layerwise mode)
    let het = LayerMap::from_spec(&[
        ("ff", n / 2, "ff"),
        ("emb", n / 4, "embedding"),
        ("attn", n / 4, "attention"),
    ]);
    bench_fused_vs_staged(
        &mut json,
        "comm/quant5-layerwise/main/64k",
        move || QuantCompressor::layerwise(&het, 5, 128, 0, 11),
        &v,
    );

    match json.save_merged("BENCH_comm.json") {
        Ok(path) => println!("merged into {}", path.display()),
        Err(e) => eprintln!("could not write BENCH_comm.json: {e}"),
    }
}

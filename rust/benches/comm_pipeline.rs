//! Perf baseline for the unified `comm` pipeline: ns/coordinate and
//! bytes/step for the full encode+decode path — identity vs quantized,
//! both wire protocols, sequential vs per-layer-parallel entropy coding.
//! Future transport PRs (sharded/async allgather, multi-backend) measure
//! against these numbers.

use qoda::bench_harness::bench;
use qoda::coding::protocol::ProtocolKind;
use qoda::comm::{
    Adaptation, CommEndpoint, Compressor, IdentityCompressor, QuantCompressor,
};
use qoda::quant::layer_map::LayerMap;
use qoda::quant::QuantConfig;
use qoda::stats::rng::Rng;

fn grad(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| rng.gaussian() * if i % 61 == 0 { 20.0 } else { 0.3 })
        .collect()
}

fn bench_endpoint(name: &str, codec: Box<dyn Compressor>, v: &[f64]) {
    let mut ep = CommEndpoint::new(codec);
    let mut out = Vec::with_capacity(v.len());
    // one warm roundtrip so the report shows the packet's steady-state size
    ep.roundtrip_into(v, &mut out).expect("roundtrip");
    let bytes = ep.packet().len_bytes();
    bench(
        &format!("{name}/encode+decode"),
        Some(v.len() as u64),
        || ep.roundtrip_into(v, &mut out).expect("roundtrip"),
    );
    println!("{name:<46} bytes/step: {bytes} ({:.3} bytes/coord)", bytes as f64 / v.len() as f64);
}

fn main() {
    let n = 1usize << 16;
    let v = grad(n, 3);
    let map = LayerMap::single(n);

    bench_endpoint("comm/identity/64k", Box::new(IdentityCompressor), &v);

    for (kind, name) in [
        (ProtocolKind::Main, "main"),
        (ProtocolKind::Alternating, "alternating"),
    ] {
        let codec = QuantCompressor::new(
            map.bucketed(128).with_single_type(),
            QuantConfig::uniform_bits(1, 5, 2.0),
            kind,
            Adaptation::Fixed,
            7,
        );
        bench_endpoint(&format!("comm/quant5/{name}/64k"), Box::new(codec), &v);
    }

    // per-layer encode parallelism (same wire bits, more threads)
    for threads in [1usize, 2, 4] {
        let mut codec = QuantCompressor::global_bits(&map, 5, 128, 9);
        codec.encode_threads = threads;
        bench_endpoint(&format!("comm/quant5/main/64k/threads={threads}"), Box::new(codec), &v);
    }

    // layer-wise adaptive configuration (the paper's QODA5 layerwise mode)
    let het = LayerMap::from_spec(&[
        ("ff", n / 2, "ff"),
        ("emb", n / 4, "embedding"),
        ("attn", n / 4, "attention"),
    ]);
    let codec = QuantCompressor::layerwise(&het, 5, 128, 0, 11);
    bench_endpoint("comm/quant5-layerwise/main/64k", Box::new(codec), &v);
}

//! Solver-step benches: QODA vs Q-GenX per-iteration cost (the optimism
//! saving), identity vs quantized compression — all through the shared
//! `RunDriver` outer loop.

use qoda::bench_harness::bench;
use qoda::comm::{Compressor, IdentityCompressor, QuantCompressor};
use qoda::oda::{AdaptiveLr, OracleSource, QGenX, Qoda, RunDriver};
use qoda::quant::layer_map::LayerMap;
use qoda::stats::rng::Rng;
use qoda::vi::noise::NoiseModel;
use qoda::vi::operator::QuadraticOperator;

fn main() {
    let mut rng = Rng::new(1);
    let op = QuadraticOperator::random(64, 0.5, &mut rng);
    let d = 64;
    let k = 4;
    let map = LayerMap::single(d);
    let steps = 50;

    let mk_q = |seed: u64| -> Vec<Box<dyn Compressor>> {
        (0..k)
            .map(|i| Box::new(QuantCompressor::global_bits(&map, 5, 128, seed + i as u64)) as _)
            .collect()
    };
    let mk_id = || -> Vec<Box<dyn Compressor>> {
        (0..k).map(|_| Box::new(IdentityCompressor::new()) as _).collect()
    };

    bench(&format!("qoda/identity/{steps}steps/K{k}/d{d}"), Some(steps as u64), || {
        let mut src = OracleSource::new(&op, k, NoiseModel::Absolute { sigma: 0.2 }, 2);
        let mut solver = Qoda::new(&mut src, mk_id(), Box::new(AdaptiveLr::default()));
        RunDriver::new().run(&mut solver, &vec![0.0; d], steps)
    });
    bench(&format!("qoda/quant5/{steps}steps/K{k}/d{d}"), Some(steps as u64), || {
        let mut src = OracleSource::new(&op, k, NoiseModel::Absolute { sigma: 0.2 }, 2);
        let mut solver = Qoda::new(&mut src, mk_q(7), Box::new(AdaptiveLr::default()));
        RunDriver::new().run(&mut solver, &vec![0.0; d], steps)
    });
    bench(&format!("qgenx/quant5/{steps}steps/K{k}/d{d}"), Some(steps as u64), || {
        let mut src = OracleSource::new(&op, k, NoiseModel::Absolute { sigma: 0.2 }, 2);
        let mut solver = QGenX::new(&mut src, mk_q(7), Box::new(AdaptiveLr::default()));
        RunDriver::new().run(&mut solver, &vec![0.0; d], steps)
    });
}

//! Hot-path bench: the layer-wise quantizer (quantize / dequantize /
//! quantize+code round trip) at gradient-realistic sizes, plus the fused
//! single-pass ENC/DEC kernels against the staged reference — same wire
//! bits, one pass instead of four. The kernel records merge into the shared
//! `results/BENCH_comm.json` for the CI perf gate.

use qoda::bench_harness::{bench, JsonBench};
use qoda::coding::bitio::BitWriter;
use qoda::coding::fused::{
    decode_vector_fused, encode_layer_body, fold_layer_stats, layer_norm_f32,
};
use qoda::coding::protocol::{
    decode_vector, decode_vector_into, encode_vector, Codebooks, ProtocolKind,
};
use qoda::quant::adaptive::TypeStats;
use qoda::quant::layer_map::LayerMap;
use qoda::quant::quantizer::{
    dequantize, dequantize_into, quantize, quantize_into, QuantizedVector,
};
use qoda::quant::QuantConfig;
use qoda::stats::rng::Rng;

fn main() {
    let mut json = JsonBench::new();
    for &n in &[1usize << 14, 1 << 18, 1 << 20] {
        let mut rng = Rng::new(1);
        let v: Vec<f32> = (0..n).map(|_| rng.gaussian() as f32).collect();
        let v64: Vec<f64> = v.iter().map(|&x| x as f64).collect();
        let map = LayerMap::single(n).bucketed(128);
        let cfg = QuantConfig::uniform_bits(1, 5, 2.0);
        let mut qrng = Rng::new(2);
        bench(&format!("quantize/5bit/bucket128/n={n}"), Some(n as u64), || {
            quantize(&v, &map, &cfg, &mut qrng)
        });
        let qv = quantize(&v, &map, &cfg, &mut qrng);
        bench(&format!("dequantize/5bit/n={n}"), Some(n as u64), || {
            dequantize(&qv, &cfg)
        });
        let books = Codebooks::uniform(ProtocolKind::Main, &cfg, &map.type_proportions());
        bench(&format!("encode/main/n={n}"), Some(n as u64), || {
            encode_vector(&qv, &books)
        });
        let buf = encode_vector(&qv, &books);
        bench(&format!("decode/main/n={n}"), Some(n as u64), || {
            decode_vector(&buf, &map, &books).unwrap()
        });

        // ---- fused vs staged ENC kernel (from the f64 dual, the full
        // per-step work: stats fold + stochastic rounding + entropy bits) ----
        let mut codes = Vec::new();
        books.fill_code_table(0, &mut codes);
        let mut w = BitWriter::new();
        let mut v32: Vec<f32> = Vec::with_capacity(n);
        let mut enc_qv = QuantizedVector::default();
        let mut st = TypeStats::default();
        let mut enc_rng = Rng::new(3);
        let staged_enc = bench(&format!("kernel/enc/staged/n={n}"), Some(n as u64), || {
            v32.clear();
            v32.extend(v64.iter().map(|&x| x as f32));
            for l in &map.layers {
                st.add_layer_sample(&v32[l.offset..l.offset + l.len], cfg.q);
            }
            quantize_into(&v32, &map, &cfg, &mut enc_rng, &mut enc_qv);
            encode_vector(&enc_qv, &books)
        });
        let mut fused_rng = Rng::new(3);
        let fused_enc = bench(&format!("kernel/enc/fused/n={n}"), Some(n as u64), || {
            w.clear();
            for l in &map.layers {
                let s = &v64[l.offset..l.offset + l.len];
                let raw = layer_norm_f32(s, cfg.q);
                fold_layer_stats(s, raw, &mut st);
                encode_layer_body(s, &cfg.sequences[0], raw, &codes, &mut fused_rng, &mut w);
            }
            w.len_bits()
        });

        // ---- fused vs staged DEC kernel (wire bits back to the f64 dual) ----
        let mut dec_qv = QuantizedVector::default();
        let mut out32: Vec<f32> = Vec::new();
        let mut out64: Vec<f64> = Vec::new();
        let staged_dec = bench(&format!("kernel/dec/staged/n={n}"), Some(n as u64), || {
            let mut r = buf.reader();
            decode_vector_into(&mut r, &map, &books, &mut dec_qv).unwrap();
            dequantize_into(&dec_qv, &cfg, &mut out32);
            out64.clear();
            out64.extend(out32.iter().map(|&x| x as f64));
            out64.len()
        });
        let fused_dec = bench(&format!("kernel/dec/fused/n={n}"), Some(n as u64), || {
            let mut r = buf.reader();
            decode_vector_fused(&mut r, &map, &books, &cfg, &mut out64).unwrap();
            out64.len()
        });

        for (dir, staged_ns, fused_ns) in [
            ("enc", staged_enc.mean_ns, fused_enc.mean_ns),
            ("dec", staged_dec.mean_ns, fused_dec.mean_ns),
        ] {
            json.push(
                &format!("kernel/{dir}/staged/n={n}"),
                &[("ns_per_step", format!("{staged_ns:.1}"))],
            );
            json.push(
                &format!("kernel/{dir}/fused/n={n}"),
                &[("ns_per_step", format!("{fused_ns:.1}"))],
            );
            let speedup = staged_ns / fused_ns.max(1e-9);
            println!("kernel_speedup/{dir}/n={n}: {speedup:.2}x");
            json.push(
                &format!("kernel_speedup/{dir}/n={n}"),
                &[("speedup", format!("{speedup:.3}"))],
            );
        }
    }
    match json.save_merged("BENCH_comm.json") {
        Ok(path) => println!("merged into {}", path.display()),
        Err(e) => eprintln!("could not write BENCH_comm.json: {e}"),
    }
}

//! Hot-path bench: the layer-wise quantizer (quantize / dequantize /
//! quantize+code round trip) at gradient-realistic sizes.

use qoda::bench_harness::bench;
use qoda::coding::protocol::{decode_vector, encode_vector, Codebooks, ProtocolKind};
use qoda::quant::layer_map::LayerMap;
use qoda::quant::quantizer::{dequantize, quantize};
use qoda::quant::QuantConfig;
use qoda::stats::rng::Rng;

fn main() {
    for &n in &[1usize << 14, 1 << 18, 1 << 20] {
        let mut rng = Rng::new(1);
        let v: Vec<f32> = (0..n).map(|_| rng.gaussian() as f32).collect();
        let map = LayerMap::single(n).bucketed(128);
        let cfg = QuantConfig::uniform_bits(1, 5, 2.0);
        let mut qrng = Rng::new(2);
        bench(&format!("quantize/5bit/bucket128/n={n}"), Some(n as u64), || {
            quantize(&v, &map, &cfg, &mut qrng)
        });
        let qv = quantize(&v, &map, &cfg, &mut qrng);
        bench(&format!("dequantize/5bit/n={n}"), Some(n as u64), || {
            dequantize(&qv, &cfg)
        });
        let books = Codebooks::uniform(ProtocolKind::Main, &cfg, &map.type_proportions());
        bench(&format!("encode/main/n={n}"), Some(n as u64), || {
            encode_vector(&qv, &books)
        });
        let buf = encode_vector(&qv, &books);
        bench(&format!("decode/main/n={n}"), Some(n as u64), || {
            decode_vector(&buf, &map, &books).unwrap()
        });
    }
}

//! Table 1 regenerator bench: prints the paper table and times one full
//! simulated QODA5 communication round at the paper's payload size.

use qoda::bench_harness::bench;
use qoda::bench_harness::experiments::{measure_qoda5_bytes_per_coord, table1};

fn main() {
    let t = table1();
    t.print();
    let _ = t.save_csv("table1.csv");
    bench("table1/qoda5 quantize+code 1M coords", Some(1 << 20), || {
        measure_qoda5_bytes_per_coord(1 << 20, 9)
    });
}

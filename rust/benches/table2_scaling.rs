//! Table 2 regenerator bench (weak scaling) + the end-to-end cluster
//! exchange cost at each node count, across topologies.

use qoda::bench_harness::bench;
use qoda::bench_harness::experiments::{table2, topology_table};
use qoda::comm::{Compressor, QuantCompressor};
use qoda::coordinator::sim::ClusterSim;
use qoda::coordinator::TopologySpec;
use qoda::net::NetworkModel;
use qoda::quant::layer_map::LayerMap;
use qoda::stats::rng::Rng;

fn main() {
    let t = table2();
    t.print();
    let _ = t.save_csv("table2.csv");

    // weak scaling with the topology axis (flat / hierarchical / PS)
    let tt = topology_table(&[4, 8, 12, 16], 5.0);
    tt.print();
    let _ = tt.save_csv("topology.csv");

    // real codec work per exchange at increasing K (payload per node fixed)
    let d = 1usize << 16;
    for &k in &[4usize, 8] {
        for spec in [TopologySpec::BroadcastAllGather, TopologySpec::hierarchical_for(k)] {
            let map = LayerMap::single(d);
            let comps: Vec<Box<dyn Compressor>> = (0..k)
                .map(|i| {
                    Box::new(QuantCompressor::global_bits(&map, 5, 128, i as u64)) as _
                })
                .collect();
            let mut sim = ClusterSim::new(comps, NetworkModel::genesis_cloud(5.0), false)
                .with_topology(&spec);
            let mut rng = Rng::new(5);
            let duals: Vec<Vec<f64>> =
                (0..k).map(|_| (0..d).map(|_| rng.gaussian()).collect()).collect();
            bench(
                &format!("cluster_exchange/{}/K={k}/d=64k", spec.label()),
                Some((k * d) as u64),
                || sim.exchange(&duals).unwrap(),
            );
        }
    }
}

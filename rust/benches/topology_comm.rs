//! Per-topology comm cost baseline: ns/step (real codec + aggregation work)
//! and bytes/step (topology-charged wire traffic) for one cluster exchange
//! under each [`TopologySpec`], plus the modeled per-step comm milliseconds
//! of the Table 1/2 regime — synchronous AND overlapped (exposed vs hidden
//! against the weak-scaling compute window), plus the deterministic
//! `topology/{sharded,ring}/K=*` per-link records that CI gates against
//! flat's peak. Emits the machine-readable `results/BENCH_comm.json` so CI
//! and regression tooling can diff the numbers without scraping stdout.

use qoda::bench_harness::experiments::{
    overlap_sweep, table2_compute_window_s, topology_sweep,
};
use qoda::bench_harness::{bench, JsonBench};
use qoda::comm::{Compressor, QuantCompressor};
use qoda::coordinator::sim::ClusterSim;
use qoda::coordinator::{ExchangePlan, TopologySpec, Transport};
use qoda::net::NetworkModel;
use qoda::quant::layer_map::LayerMap;
use qoda::stats::rng::Rng;

fn main() {
    let mut json = JsonBench::new();
    let d = 1usize << 16;
    let k = 8usize;
    let map = LayerMap::single(d);
    let mut rng = Rng::new(5);
    let duals: Vec<Vec<f64>> =
        (0..k).map(|_| (0..d).map(|_| rng.gaussian()).collect()).collect();
    // the Table 1/2 compute window at this K, for the exposed/hidden split
    let plan = ExchangePlan::overlapped(1, table2_compute_window_s(k));

    for spec in [
        TopologySpec::BroadcastAllGather,
        TopologySpec::hierarchical_for(k),
        TopologySpec::ParameterServer,
        TopologySpec::ShardedReduceScatter,
        TopologySpec::Ring,
    ] {
        let comps: Vec<Box<dyn Compressor>> = (0..k)
            .map(|i| Box::new(QuantCompressor::global_bits(&map, 5, 128, i as u64)) as _)
            .collect();
        let mut sim = ClusterSim::new(comps, NetworkModel::genesis_cloud(5.0), false)
            .with_topology(&spec);
        let (_, metrics) = sim.exchange(&duals).expect("exchange");
        let (exposed_s, hidden_s) = plan.split(metrics.comm_s);
        let res = bench(
            &format!("topology/{}/K={k}/d=64k", spec.label()),
            Some((k * d) as u64),
            || sim.exchange(&duals).unwrap(),
        );
        json.push(
            &format!("exchange/{}", spec.label()),
            &[
                ("k", format!("{k}")),
                ("ns_per_step", format!("{:.1}", res.mean_ns)),
                ("bytes_per_step", format!("{:.1}", metrics.wire_bits as f64 / 8.0)),
                ("peak_link_bytes", format!("{:.2}", metrics.peak_link_bytes)),
                ("modeled_comm_ms", format!("{:.4}", metrics.comm_s * 1e3)),
                ("comm_exposed_ms", format!("{:.4}", exposed_s * 1e3)),
                ("comm_hidden_ms", format!("{:.4}", hidden_s * 1e3)),
            ],
        );
    }

    // the weak-scaling regime, per topology, from the calibrated harness
    for row in topology_sweep(&[4, 8, 12, 16], 5.0) {
        json.push(
            &format!("step_time/{}/K={}", row.topology.label(), row.k),
            &[
                ("k", format!("{}", row.k)),
                ("baseline_ms", format!("{:.2}", row.baseline_ms)),
                ("qoda5_ms", format!("{:.2}", row.qoda5_ms)),
                ("peak_link_bytes", format!("{:.2}", row.peak_link_bytes)),
            ],
        );
    }

    // per-link accounting for the new collectives, pinned against flat's:
    // pure `Transport::charge` arithmetic (no timers, no rng draws — see
    // `new_transports_never_draw_from_the_shared_rng`), so these records
    // are exact and runner-independent. check_bench.py gates every
    // `topology/sharded/*` record at `peak <= 1.5/K x flat's peak`.
    let net = NetworkModel::genesis_cloud(5.0);
    for &kk in &[8usize, 16, 32, 64] {
        let bits = vec![360_000u64; kk]; // 45 kB coded payload per node
        let d64 = 1usize << 16;
        let mut rng = Rng::new(9);
        let flat = TopologySpec::BroadcastAllGather
            .build()
            .charge(&bits, d64, &net, false, true, &mut rng);
        for spec in [TopologySpec::ShardedReduceScatter, TopologySpec::Ring] {
            let mut rng = Rng::new(9);
            let c = spec.build().charge(&bits, d64, &net, false, true, &mut rng);
            json.push(
                &format!("topology/{}/K={kk}", spec.label()),
                &[
                    ("k", format!("{kk}")),
                    ("peak_link_bytes", format!("{:.2}", c.peak_link_bytes)),
                    ("flat_peak_link_bytes", format!("{:.2}", flat.peak_link_bytes)),
                    ("wire_bits", format!("{}", c.wire_bits)),
                    ("comm_ms", format!("{:.4}", c.comm_s * 1e3)),
                ],
            );
        }
    }

    // the same regime under the overlapped exchange: exposed/hidden comm
    // and the double-buffered step time, per topology
    for row in overlap_sweep(&[4, 8, 12, 16], 5.0, 1) {
        json.push(
            &format!("overlap/{}/K={}", row.topology.label(), row.k),
            &[
                ("k", format!("{}", row.k)),
                ("comm_ms", format!("{:.2}", row.comm_ms)),
                ("comm_exposed_ms", format!("{:.2}", row.comm_exposed_ms)),
                ("comm_hidden_ms", format!("{:.2}", row.comm_hidden_ms)),
                ("sync_step_ms", format!("{:.2}", row.sync_ms)),
                ("overlap_step_ms", format!("{:.2}", row.overlap_ms)),
            ],
        );
    }

    match json.save_merged("BENCH_comm.json") {
        Ok(path) => println!("merged into {}", path.display()),
        Err(e) => eprintln!("could not write BENCH_comm.json: {e}"),
    }
}

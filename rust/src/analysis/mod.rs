//! In-tree static analysis: the `qoda audit` invariant auditor.
//!
//! Every claim this repro makes — the paper's variance and code-length
//! bounds, the fused-vs-staged speedups, the cross-engine golden-parity pins
//! — rests on the wire stream being **bit-identical** across engines,
//! topologies, seeds and thread counts. The parity suites defend that
//! property after the fact; this module defends it *statically*, by scanning
//! `rust/src/` for the hazard patterns that historically break bit-exactness
//! long before a lucky seed trips them:
//!
//! * [`rules::RULE_HASH`] (`hash-container`) — `HashMap`/`HashSet` in a
//!   wire-affecting module. Hash iteration order is nondeterministic across
//!   builds; if it leaks into a Huffman codebook or a layer walk, two nodes
//!   disagree on the stream. Protected suites: `golden_parity`,
//!   `topology_equivalence`.
//! * [`rules::RULE_PANIC`] (`panic-path`) — `unwrap`/`expect`/`panic!`/
//!   `unreachable!` on decode/comm paths. Corrupt wire input must surface as
//!   [`crate::comm::CommError`], never abort a node. Protected suite:
//!   `comm_fuzz` (corruption never panics).
//! * [`rules::RULE_RNG`] (`rng-clone`) — `Rng` clones outside justified
//!   parallel-splice sites. An unaccounted clone desynchronizes the leader
//!   draw stream from the sequential reference. Protected suite:
//!   `fused_parity` (parallel == sequential encode, bit for bit).
//! * [`rules::RULE_CAST`] (`lossy-cast`) — truncating `as f32`/`as u8`/
//!   `as u16` outside the quantizer/bitio owner modules that define the
//!   wire's value widths. Protected invariant: C_q (fp32 norm header) and
//!   u8 symbol forms stay confined to the modules the protocol docs name.
//!
//! Findings are suppressed only by an explicit, *verified* pragma:
//!
//! ```text
//! // audit:allow(<rule>) — <reason>
//! ```
//!
//! trailing on the offending line or standalone directly above it. A pragma
//! that no longer suppresses anything is itself an error, so allows cannot
//! go stale. Test code (`#[cfg(test)]` / `#[test]` items) is exempt from all
//! rules.
//!
//! The scanner ([`scanner`]) is a hand-rolled token-level lexer — zero
//! dependencies, no `syn` — that understands comments, string/char/raw
//! literals and lifetimes, which is exactly enough for these rules to be
//! reliable. The dynamic complement lives in CI: nightly **Miri** over the
//! `coding/` + `stats/` unit tests (UB check on the word-level bit cache)
//! and **ThreadSanitizer** over the `coordinator/parallel` tests.

pub mod report;
pub mod rules;
pub mod scanner;

pub use report::{AuditReport, FileAudit, Finding, PragmaIssue};
pub use rules::audit_file;

use crate::util::error::{Error, Result};
use std::path::{Path, PathBuf};

/// Walk `root` (a crate `src/` directory), audit every `.rs` file, and
/// aggregate the results. Files are visited in sorted path order so the
/// report (and its JSON rendering) is deterministic.
pub fn run_audit(root: &Path) -> Result<AuditReport> {
    let mut files: Vec<PathBuf> = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();

    let mut report = AuditReport::default();
    for path in &files {
        let rel = path
            .strip_prefix(root)
            .map_err(|_| Error::msg(format!("path {} escapes audit root", path.display())))?
            .to_string_lossy()
            .replace('\\', "/");
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::msg(format!("read {}: {e}", path.display())))?;
        report.absorb(audit_file(&rel, &text));
    }
    Ok(report)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let entries = std::fs::read_dir(dir)
        .map_err(|e| Error::msg(format!("read_dir {}: {e}", dir.display())))?;
    for entry in entries {
        let entry = entry.map_err(|e| Error::msg(format!("read_dir {}: {e}", dir.display())))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().map_or(false, |x| x == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// The source root `qoda audit` scans by default: the crate's own `src/`,
/// resolved relative to the working directory (`src` when run from `rust/`,
/// `rust/src` from the repo root), falling back to the build-time manifest
/// path for `cargo run` from arbitrary directories.
pub fn default_root() -> PathBuf {
    for cand in ["src", "rust/src"] {
        let p = Path::new(cand);
        if p.join("lib.rs").is_file() {
            return p.to_path_buf();
        }
    }
    Path::new(env!("CARGO_MANIFEST_DIR")).join("src")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_root_points_at_a_lib() {
        assert!(default_root().join("lib.rs").is_file());
    }

    #[test]
    fn run_audit_counts_files_deterministically() {
        let root = default_root();
        let a = run_audit(&root).expect("audit walks the live tree");
        let b = run_audit(&root).expect("audit walks the live tree");
        assert!(a.files_scanned > 10);
        assert_eq!(a.files_scanned, b.files_scanned);
        assert_eq!(a.to_json(), b.to_json());
    }
}

//! Audit results: findings, pragma issues, and the aggregate report with
//! human-readable and machine-readable (JSON) renderings.
//!
//! The JSON schema is versioned (`qoda-audit/1`) and hand-rolled like the
//! bench harness's writer — the crate stays zero-dependency. CI uploads the
//! report as an artifact next to the bench JSON.

use super::rules;

/// One rule match at a source location.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    /// Rule name (one of [`rules::RULES`]).
    pub rule: &'static str,
    /// Path relative to the audited source root, e.g. `comm/codec.rs`.
    pub file: String,
    /// 1-based line.
    pub line: u32,
    pub message: String,
    /// True when an `audit:allow` pragma suppresses this finding.
    pub allowed: bool,
    /// The pragma's justification, when allowed.
    pub reason: Option<String>,
}

/// A rejected `audit:allow` pragma: stale, unknown rule, or missing reason.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PragmaIssue {
    pub file: String,
    pub line: u32,
    pub rule: String,
    pub problem: String,
}

/// Audit result for a single file.
#[derive(Debug, Default)]
pub struct FileAudit {
    pub findings: Vec<Finding>,
    pub pragma_issues: Vec<PragmaIssue>,
}

/// Aggregate over a whole source tree.
#[derive(Debug, Default)]
pub struct AuditReport {
    pub files_scanned: usize,
    pub findings: Vec<Finding>,
    pub pragma_issues: Vec<PragmaIssue>,
}

impl AuditReport {
    pub fn absorb(&mut self, file: FileAudit) {
        self.files_scanned += 1;
        self.findings.extend(file.findings);
        self.pragma_issues.extend(file.pragma_issues);
    }

    /// Findings not suppressed by a pragma.
    pub fn violations(&self) -> impl Iterator<Item = &Finding> {
        self.findings.iter().filter(|f| !f.allowed)
    }

    /// Findings suppressed by a verified pragma.
    pub fn allowed(&self) -> impl Iterator<Item = &Finding> {
        self.findings.iter().filter(|f| f.allowed)
    }

    /// True when the tree passes: no violations and no rejected pragmas.
    pub fn clean(&self) -> bool {
        self.violations().next().is_none() && self.pragma_issues.is_empty()
    }

    /// Human-readable report (one `file:line` diagnostic per finding).
    pub fn render(&self) -> String {
        let mut s = String::new();
        let nviol = self.violations().count();
        let nallow = self.allowed().count();
        for f in self.violations() {
            s.push_str(&format!(
                "error[{}]: {}:{}: {}\n",
                f.rule, f.file, f.line, f.message
            ));
        }
        for p in &self.pragma_issues {
            s.push_str(&format!(
                "error[pragma]: {}:{}: audit:allow({}) {}\n",
                p.file, p.line, p.rule, p.problem
            ));
        }
        s.push_str(&format!(
            "audit: {} file(s) scanned, {} violation(s), {} allowed finding(s), {} pragma issue(s)\n",
            self.files_scanned,
            nviol,
            nallow,
            self.pragma_issues.len()
        ));
        s.push_str(if self.clean() { "audit: PASS\n" } else { "audit: FAIL\n" });
        s
    }

    /// Machine-readable report (schema `qoda-audit/1`).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str("  \"schema\": \"qoda-audit/1\",\n");
        s.push_str(&format!("  \"files_scanned\": {},\n", self.files_scanned));
        s.push_str(&format!("  \"clean\": {},\n", self.clean()));

        s.push_str("  \"rules\": {\n");
        for (k, (name, desc)) in rules::RULES.iter().enumerate() {
            let comma = if k + 1 < rules::RULES.len() { "," } else { "" };
            s.push_str(&format!(
                "    \"{}\": \"{}\"{}\n",
                esc(name),
                esc(desc),
                comma
            ));
        }
        s.push_str("  },\n");

        push_findings(&mut s, "violations", self.violations());
        s.push(',');
        s.push('\n');
        push_findings(&mut s, "allowed", self.allowed());
        s.push(',');
        s.push('\n');

        s.push_str("  \"pragma_issues\": [\n");
        let n = self.pragma_issues.len();
        for (k, p) in self.pragma_issues.iter().enumerate() {
            let comma = if k + 1 < n { "," } else { "" };
            s.push_str(&format!(
                "    {{\"file\": \"{}\", \"line\": {}, \"rule\": \"{}\", \"problem\": \"{}\"}}{}\n",
                esc(&p.file),
                p.line,
                esc(&p.rule),
                esc(&p.problem),
                comma
            ));
        }
        s.push_str("  ]\n");
        s.push_str("}\n");
        s
    }
}

fn push_findings<'a>(s: &mut String, key: &str, it: impl Iterator<Item = &'a Finding>) {
    let items: Vec<&Finding> = it.collect();
    s.push_str(&format!("  \"{key}\": [\n"));
    let n = items.len();
    for (k, f) in items.iter().enumerate() {
        let comma = if k + 1 < n { "," } else { "" };
        let reason = match &f.reason {
            Some(r) => format!(", \"reason\": \"{}\"", esc(r)),
            None => String::new(),
        };
        s.push_str(&format!(
            "    {{\"rule\": \"{}\", \"file\": \"{}\", \"line\": {}, \"message\": \"{}\"{}}}{}\n",
            esc(f.rule),
            esc(&f.file),
            f.line,
            esc(&f.message),
            reason,
            comma
        ));
    }
    s.push_str("  ]");
}

/// Minimal JSON string escape (backslash, quote, control chars).
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> AuditReport {
        let mut r = AuditReport::default();
        r.absorb(FileAudit {
            findings: vec![
                Finding {
                    rule: rules::RULE_PANIC,
                    file: "comm/codec.rs".into(),
                    line: 10,
                    message: "`.unwrap()` on a decode path".into(),
                    allowed: false,
                    reason: None,
                },
                Finding {
                    rule: rules::RULE_CAST,
                    file: "comm/codec.rs".into(),
                    line: 20,
                    message: "truncating `as f32`".into(),
                    allowed: true,
                    reason: Some("fp32 wire contract".into()),
                },
            ],
            pragma_issues: vec![PragmaIssue {
                file: "comm/codec.rs".into(),
                line: 30,
                rule: "panic-path".into(),
                problem: "stale: suppresses no finding on its target line".into(),
            }],
        });
        r
    }

    #[test]
    fn clean_logic() {
        let r = sample();
        assert!(!r.clean());
        assert_eq!(r.violations().count(), 1);
        assert_eq!(r.allowed().count(), 1);

        let mut ok = AuditReport::default();
        ok.absorb(FileAudit::default());
        assert!(ok.clean());
    }

    #[test]
    fn render_mentions_each_problem() {
        let text = sample().render();
        assert!(text.contains("error[panic-path]: comm/codec.rs:10"));
        assert!(text.contains("error[pragma]: comm/codec.rs:30"));
        assert!(text.contains("audit: FAIL"));
    }

    #[test]
    fn json_shape_and_escaping() {
        let j = sample().to_json();
        assert!(j.contains("\"schema\": \"qoda-audit/1\""));
        assert!(j.contains("\"clean\": false"));
        assert!(j.contains("\"violations\""));
        assert!(j.contains("\"reason\": \"fp32 wire contract\""));
        // backtick messages survive; embedded quotes are escaped
        let mut r = AuditReport::default();
        r.absorb(FileAudit {
            findings: vec![Finding {
                rule: rules::RULE_HASH,
                file: "comm/x.rs".into(),
                line: 1,
                message: "say \"hi\"\\".into(),
                allowed: false,
                reason: None,
            }],
            pragma_issues: vec![],
        });
        assert!(r.to_json().contains("say \\\"hi\\\"\\\\"));
        // brace balance as a cheap well-formedness probe
        let open = j.matches('{').count();
        let close = j.matches('}').count();
        assert_eq!(open, close);
    }
}

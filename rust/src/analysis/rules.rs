//! The audit rules: what they match, where they apply, how pragmas suppress.
//!
//! All four rules are scoped to the wire-affecting module trees
//! ([`WIRE_DIRS`]): code whose behavior reaches the encoded bit stream or the
//! cross-node exchange. Outside those trees (CLI plumbing, bench harness,
//! util) the rules are silent — a `HashMap` in `util/cli.rs` cannot perturb
//! a codebook.
//!
//! Suppression is explicit and verified: a finding is allowed only by an
//! `// audit:allow(<rule>) — <reason>` pragma on the same line (trailing) or
//! on the line directly above (standalone, covering the next code line). A
//! pragma that suppresses nothing is itself an error — allows cannot go
//! stale when the code they justified is refactored away.

use super::report::{FileAudit, Finding, PragmaIssue};
use super::scanner::{self, Tok};

/// Determinism: no hash-ordered containers on wire-affecting paths.
pub const RULE_HASH: &str = "hash-container";
/// Panic-freedom: no `unwrap`/`expect`/`panic!`/`unreachable!` in library
/// decode/comm paths.
pub const RULE_PANIC: &str = "panic-path";
/// RNG discipline: `*rng*.clone()` only at justified parallel-splice sites.
pub const RULE_RNG: &str = "rng-clone";
/// Lossy-cast containment: truncating `as f32`/`as u8`/`as u16` only inside
/// the quantizer/bitio modules that own the wire's value widths.
pub const RULE_CAST: &str = "lossy-cast";

/// Every rule the auditor knows, with a one-line description (surfaced in
/// `qoda audit --json` and the CLI help).
pub const RULES: &[(&str, &str)] = &[
    (
        RULE_HASH,
        "no HashMap/HashSet in wire-affecting modules: iteration order would leak into codebooks and streams; use BTreeMap or a sorted Vec",
    ),
    (
        RULE_PANIC,
        "no unwrap/expect/panic!/unreachable! on decode/comm paths: corrupt wire input or a lost worker must surface as CommError, never a panic",
    ),
    (
        RULE_RNG,
        "Rng clones only at justified parallel-splice sites where layer_draws accounting advances the leader stream",
    ),
    (
        RULE_CAST,
        "truncating `as f32`/`as u8`/`as u16` casts only inside the quantizer/bitio owner modules",
    ),
];

/// Module trees whose code can affect the encoded wire stream. `wire/` is
/// the measured-TCP runtime: its frames carry the coded packets verbatim,
/// so it is held to the same panic-free / no-hash-container bar as the
/// in-process engines.
pub const WIRE_DIRS: &[&str] = &["coding/", "comm/", "quant/", "coordinator/", "wire/"];

/// Files that *own* the wire's lossy value widths: the quantizer maps f64
/// activations onto the level ladder, bitio/fused write the u8/u16 wire
/// forms. Truncation there is the contract, not a hazard.
pub const CAST_OWNERS: &[&str] = &[
    "coding/bitio.rs",
    "coding/fused.rs",
    "quant/quantizer.rs",
    "quant/levels.rs",
];

/// Cast targets the lossy-cast rule flags. `as u32`/`as usize` are excluded:
/// in this codebase they are overwhelmingly widening (u8 lengths into u32
/// shift counts, bit positions into usize) and flagging them would bury the
/// real truncations.
const LOSSY_TARGETS: &[&str] = &["f32", "u8", "u16"];

fn is_wire_path(rel: &str) -> bool {
    WIRE_DIRS.iter().any(|d| rel.starts_with(d))
}

fn is_cast_owner(rel: &str) -> bool {
    CAST_OWNERS.contains(&rel)
}

pub fn known_rule(name: &str) -> bool {
    RULES.iter().any(|(r, _)| *r == name)
}

/// Audit a single file's source text. Pure (no I/O) so fixture tests and the
/// live-tree meta-test share the exact same code path.
pub fn audit_file(rel: &str, text: &str) -> FileAudit {
    let mut out = FileAudit::default();
    if !is_wire_path(rel) {
        return out;
    }

    let scan = scanner::scan(text);
    let regions = scanner::test_regions(&scan.toks);
    let region_lines = scanner::region_lines(&scan.toks, &regions);
    let in_test = |ti: usize| regions.iter().any(|&(a, z)| ti >= a && ti < z);
    let line_in_test = |l: u32| region_lines.iter().any(|&(a, z)| l >= a && l <= z);

    let mut findings = raw_findings(rel, &scan.toks, &in_test);

    // Resolve pragmas: mark suppressed findings, reject stale/malformed ones.
    for p in &scan.pragmas {
        if line_in_test(p.line) {
            continue; // comments inside test mods are not audited
        }
        if !known_rule(&p.rule) {
            out.pragma_issues.push(PragmaIssue {
                file: rel.to_string(),
                line: p.line,
                rule: p.rule.clone(),
                problem: "unknown rule name".to_string(),
            });
            continue;
        }
        if p.reason.is_empty() {
            out.pragma_issues.push(PragmaIssue {
                file: rel.to_string(),
                line: p.line,
                rule: p.rule.clone(),
                problem: "missing justification after the rule name".to_string(),
            });
            continue;
        }
        // A trailing pragma covers its own line; a standalone pragma covers
        // the next line that holds any code token.
        let target = if p.trailing {
            Some(p.line)
        } else {
            scan.toks.iter().map(|t| t.line).find(|&l| l > p.line)
        };
        let mut suppressed = 0usize;
        if let Some(target) = target {
            for f in findings.iter_mut() {
                if f.rule == p.rule && f.line == target && !f.allowed {
                    f.allowed = true;
                    f.reason = Some(p.reason.clone());
                    suppressed += 1;
                }
            }
        }
        if suppressed == 0 {
            out.pragma_issues.push(PragmaIssue {
                file: rel.to_string(),
                line: p.line,
                rule: p.rule.clone(),
                problem: "stale: suppresses no finding on its target line".to_string(),
            });
        }
    }

    out.findings = findings;
    out
}

/// Scan the token stream for rule matches, before pragma resolution.
fn raw_findings(rel: &str, toks: &[Tok], in_test: &dyn Fn(usize) -> bool) -> Vec<Finding> {
    let cast_owner = is_cast_owner(rel);
    let mut found: Vec<Finding> = Vec::new();
    let mut push = |rule: &'static str, line: u32, msg: String| {
        found.push(Finding {
            rule,
            file: rel.to_string(),
            line,
            message: msg,
            allowed: false,
            reason: None,
        });
    };

    for (i, t) in toks.iter().enumerate() {
        if in_test(i) {
            continue;
        }
        let Some(id) = t.ident() else { continue };
        let next_punct = |k: usize, c: char| toks.get(k).map_or(false, |n| n.is_punct(c));
        let next_ident = |k: usize| toks.get(k).and_then(|n| n.ident());

        match id {
            "HashMap" | "HashSet" => {
                push(
                    RULE_HASH,
                    t.line,
                    format!("`{id}` in a wire-affecting module (hash iteration order would leak into the stream); use BTreeMap or a sorted Vec"),
                );
            }
            "unwrap" | "expect" => {
                // Method call: `.unwrap(` / `.expect(`. Plain idents named
                // unwrap (e.g. a local fn) are not panic sites.
                let is_method = i > 0 && toks[i - 1].is_punct('.') && next_punct(i + 1, '(');
                if is_method {
                    push(
                        RULE_PANIC,
                        t.line,
                        format!("`.{id}()` on a decode/comm path; propagate a CommError (or justify with an audit:allow pragma)"),
                    );
                }
            }
            "panic" | "unreachable" => {
                if next_punct(i + 1, '!') {
                    push(
                        RULE_PANIC,
                        t.line,
                        format!("`{id}!` on a decode/comm path; corrupt input must surface as an error, not abort the node"),
                    );
                }
            }
            "as" => {
                if !cast_owner {
                    if let Some(tgt) = next_ident(i + 1) {
                        if LOSSY_TARGETS.contains(&tgt) {
                            push(
                                RULE_CAST,
                                t.line,
                                format!("truncating `as {tgt}` cast outside the quantizer/bitio owner modules"),
                            );
                        }
                    }
                }
            }
            _ => {
                // rng-clone: `<ident containing rng>.clone()`
                if id.to_ascii_lowercase().contains("rng")
                    && next_punct(i + 1, '.')
                    && next_ident(i + 2) == Some("clone")
                    && next_punct(i + 3, '(')
                {
                    push(
                        RULE_RNG,
                        t.line,
                        format!("`{id}.clone()`: an unaccounted Rng clone desynchronizes the leader draw stream; justify splice sites with an audit:allow pragma"),
                    );
                }
            }
        }
    }
    found
}

#[cfg(test)]
mod tests {
    use super::*;

    fn violations(a: &FileAudit) -> Vec<(&'static str, u32)> {
        a.findings
            .iter()
            .filter(|f| !f.allowed)
            .map(|f| (f.rule, f.line))
            .collect()
    }

    #[test]
    fn out_of_scope_file_is_silent() {
        let a = audit_file("util/cli.rs", "use std::collections::HashMap;\nfn f() { x.unwrap(); }\n");
        assert!(a.findings.is_empty() && a.pragma_issues.is_empty());
    }

    #[test]
    fn hash_container_detected_in_scope() {
        let a = audit_file("comm/codec.rs", "use std::collections::HashMap;\n");
        assert_eq!(violations(&a), vec![(RULE_HASH, 1)]);
    }

    #[test]
    fn panic_rule_matches_methods_and_macros_only() {
        let src = concat!(
            "fn f(v: Option<u32>) -> u32 {\n",
            "    let a = v.unwrap();\n",          // line 2: finding
            "    let b = v.unwrap_or(0);\n",      // no finding
            "    if a > b { panic!(\"no\"); }\n", // line 4: finding
            "    unreachable!()\n",               // line 5: finding
            "}\n",
        );
        let a = audit_file("coding/protocol.rs", src);
        assert_eq!(
            violations(&a),
            vec![(RULE_PANIC, 2), (RULE_PANIC, 4), (RULE_PANIC, 5)]
        );
    }

    #[test]
    fn trailing_pragma_suppresses_and_is_counted() {
        let src = "fn f() { v.unwrap(); } // audit:allow(panic-path) — ctor guarantees Some\n";
        let a = audit_file("coding/protocol.rs", src);
        assert!(violations(&a).is_empty());
        assert_eq!(a.findings.len(), 1);
        assert!(a.findings[0].allowed);
        assert_eq!(a.findings[0].reason.as_deref(), Some("ctor guarantees Some"));
        assert!(a.pragma_issues.is_empty());
    }

    #[test]
    fn standalone_pragma_covers_next_code_line() {
        let src = concat!(
            "// audit:allow(lossy-cast) — wire norm header is fp32 by contract\n",
            "fn f(x: f64) -> f32 { x as f32 }\n",
        );
        let a = audit_file("comm/codec.rs", src);
        assert!(violations(&a).is_empty());
        assert!(a.pragma_issues.is_empty());
    }

    #[test]
    fn stale_pragma_rejected() {
        let src = "// audit:allow(panic-path) — nothing here anymore\nfn f() {}\n";
        let a = audit_file("comm/codec.rs", src);
        assert_eq!(a.pragma_issues.len(), 1);
        assert!(a.pragma_issues[0].problem.starts_with("stale"));
    }

    #[test]
    fn unknown_rule_and_missing_reason_rejected() {
        let src = concat!(
            "// audit:allow(made-up-rule) — whatever\n",
            "fn f() { v.unwrap(); }\n",
            "// audit:allow(panic-path)\n",
            "fn g() { w.unwrap(); }\n",
        );
        let a = audit_file("comm/codec.rs", src);
        assert_eq!(a.pragma_issues.len(), 2);
        assert_eq!(a.pragma_issues[0].problem, "unknown rule name");
        assert!(a.pragma_issues[1].problem.contains("missing justification"));
        // neither pragma suppresses, so both unwraps stay as violations
        assert_eq!(violations(&a).len(), 2);
    }

    #[test]
    fn cfg_test_code_is_exempt() {
        let src = concat!(
            "pub fn live() -> u32 { 1 }\n",
            "#[cfg(test)]\n",
            "mod tests {\n",
            "    use std::collections::HashMap;\n",
            "    #[test]\n",
            "    fn t() { Some(1).unwrap(); let _ = 1.0f64 as f32; }\n",
            "}\n",
        );
        let a = audit_file("coding/huffman.rs", src);
        assert!(violations(&a).is_empty(), "{:?}", a.findings);
    }

    #[test]
    fn rng_clone_detected_and_allowed() {
        let src = concat!(
            "fn bad(rng: &Rng) { let r = rng.clone(); }\n",
            "fn good(splice_rng: &Rng) {\n",
            "    // audit:allow(rng-clone) — leader stream advanced by layer_draws below\n",
            "    let w = splice_rng.clone();\n",
            "}\n",
        );
        let a = audit_file("coordinator/parallel.rs", src);
        assert_eq!(violations(&a), vec![(RULE_RNG, 1)]);
        assert!(a.pragma_issues.is_empty());
        assert_eq!(a.findings.iter().filter(|f| f.allowed).count(), 1);
    }

    #[test]
    fn cast_owner_files_are_exempt() {
        let src = "pub fn q(x: f64) -> f32 { x as f32 }\n";
        let owner = audit_file("quant/quantizer.rs", src);
        assert!(owner.findings.is_empty());
        let outsider = audit_file("quant/lgreco.rs", src);
        assert_eq!(violations(&outsider), vec![(RULE_CAST, 1)]);
    }

    #[test]
    fn new_transport_modules_are_in_scope() {
        // the sharded/ring collectives and the mesh wire runtime are
        // wire-affecting: they slice, route and fold the coded stream, so
        // they must stay under the same rules as the codecs
        for rel in ["coordinator/collectives.rs", "wire/cluster.rs"] {
            let a = audit_file(rel, "fn f(v: Option<u32>) -> u32 { v.unwrap() }\n");
            assert_eq!(violations(&a), vec![(RULE_PANIC, 1)], "{rel}");
        }
    }

    #[test]
    fn scheduling_modules_are_in_scope() {
        // the bit-width scheduler re-plans the codebooks every node decodes
        // with, and the error-feedback wrapper sits directly on the encode
        // path — a panic or hash-order wobble in either desynchronizes the
        // wire stream, so both live under the wire-scope rules
        for rel in ["quant/schedule.rs", "comm/feedback.rs"] {
            let a = audit_file(rel, "fn f(v: Option<u32>) -> u32 { v.unwrap() }\n");
            assert_eq!(violations(&a), vec![(RULE_PANIC, 1)], "{rel}");
        }
    }

    #[test]
    fn widening_casts_not_flagged() {
        let a = audit_file("coding/huffman.rs", "fn f(l: u8) -> u32 { l as u32 }\n");
        assert!(a.findings.is_empty());
    }
}

//! Token-level Rust scanner for the in-tree auditor.
//!
//! A deliberately small, zero-dependency lexer: it understands exactly enough
//! Rust surface syntax to make the audit rules reliable — line/nested-block
//! comments, string / raw-string / byte-string / char literals (so `"HashMap"`
//! inside a string never trips a rule), lifetimes vs char literals, and number
//! literals with type suffixes (so `0f32` is not an identifier). Everything
//! else is emitted as a stream of [`Tok`]s: identifiers and single-character
//! punctuation, each tagged with its 1-based source line.
//!
//! On top of the token stream the scanner derives two structural facts the
//! rules need:
//!
//! * **pragmas** — `// audit:allow(<rule>) — <reason>` line comments, with
//!   trailing-vs-standalone position so a pragma can cover either its own
//!   line or the next line of code;
//! * **test regions** — token ranges under `#[cfg(test)]` / `#[test]` items,
//!   which every rule skips (tests are allowed to unwrap and to build hash
//!   maps; only library code on the wire path is held to the invariants).

/// One lexed token: an identifier or a single punctuation character.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Tok {
    /// 1-based source line the token starts on.
    pub line: u32,
    pub kind: TokKind,
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TokKind {
    Ident(String),
    Punct(char),
}

impl Tok {
    pub fn is_ident(&self, s: &str) -> bool {
        matches!(&self.kind, TokKind::Ident(i) if i == s)
    }
    pub fn is_punct(&self, c: char) -> bool {
        matches!(&self.kind, TokKind::Punct(p) if *p == c)
    }
    pub fn ident(&self) -> Option<&str> {
        match &self.kind {
            TokKind::Ident(i) => Some(i.as_str()),
            TokKind::Punct(_) => None,
        }
    }
}

/// An `// audit:allow(<rule>) — <reason>` pragma found in a line comment.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Pragma {
    /// Line the pragma comment sits on.
    pub line: u32,
    /// True when code tokens precede the comment on the same line (the pragma
    /// then covers its own line; otherwise it covers the next code line).
    pub trailing: bool,
    /// Rule name between the parentheses, e.g. `panic-path`.
    pub rule: String,
    /// Justification text after the closing paren (separator stripped).
    pub reason: String,
}

/// Result of lexing one file.
#[derive(Debug, Default)]
pub struct Scan {
    pub toks: Vec<Tok>,
    pub pragmas: Vec<Pragma>,
}

/// Lex `text` into tokens + pragmas. Never panics: unexpected bytes are
/// skipped, unterminated literals simply end the scan at EOF.
pub fn scan(text: &str) -> Scan {
    let b = text.as_bytes();
    let mut i = 0usize;
    let mut line = 1u32;
    let mut toks: Vec<Tok> = Vec::new();
    let mut pragmas: Vec<Pragma> = Vec::new();
    // line of the most recent token — tells a line comment whether code
    // precedes it on the same line (trailing pragma) or not (standalone).
    let mut last_tok_line = 0u32;

    while i < b.len() {
        let c = b[i];
        match c {
            b'\n' => {
                line += 1;
                i += 1;
            }
            _ if c.is_ascii_whitespace() => i += 1,
            b'/' if b.get(i + 1) == Some(&b'/') => {
                // Line comment (includes doc comments). Capture for pragmas.
                let start = i + 2;
                let mut j = start;
                while j < b.len() && b[j] != b'\n' {
                    j += 1;
                }
                if let Some((rule, reason)) = parse_pragma(&text[start..j]) {
                    pragmas.push(Pragma {
                        line,
                        trailing: last_tok_line == line,
                        rule,
                        reason,
                    });
                }
                i = j;
            }
            b'/' if b.get(i + 1) == Some(&b'*') => {
                // Block comment; Rust block comments nest.
                let mut depth = 1usize;
                i += 2;
                while i < b.len() && depth > 0 {
                    if b[i] == b'\n' {
                        line += 1;
                        i += 1;
                    } else if b[i] == b'/' && b.get(i + 1) == Some(&b'*') {
                        depth += 1;
                        i += 2;
                    } else if b[i] == b'*' && b.get(i + 1) == Some(&b'/') {
                        depth -= 1;
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
            }
            b'"' => i = skip_string(b, i, &mut line),
            b'\'' => i = skip_char_or_lifetime(b, i, &mut line),
            _ if c == b'r' || c == b'b' => {
                // Possible raw/byte string or byte-char prefix; falls back to
                // a plain identifier when the prefix shape does not match.
                if let Some(ni) = try_skip_prefixed_literal(b, i, &mut line) {
                    i = ni;
                } else {
                    i = lex_ident(text, b, i, line, &mut toks);
                    last_tok_line = line;
                }
            }
            _ if c.is_ascii_alphabetic() || c == b'_' => {
                i = lex_ident(text, b, i, line, &mut toks);
                last_tok_line = line;
            }
            _ if c.is_ascii_digit() => {
                // Number literal with optional suffix (`1.0f64`, `0x5A`,
                // `1e-3` lexes as number / punct / number — harmless).
                while i < b.len() {
                    let d = b[i];
                    if d.is_ascii_alphanumeric() || d == b'_' {
                        i += 1;
                    } else if d == b'.' && b.get(i + 1).map_or(false, |n| n.is_ascii_digit()) {
                        i += 1;
                    } else {
                        break;
                    }
                }
            }
            _ if c.is_ascii() => {
                toks.push(Tok {
                    line,
                    kind: TokKind::Punct(c as char),
                });
                last_tok_line = line;
                i += 1;
            }
            // Non-ASCII outside strings/comments is not valid Rust code;
            // skip the byte rather than guess (continuation bytes are never
            // b'\n', so line counting stays correct).
            _ => i += 1,
        }
    }

    Scan { toks, pragmas }
}

fn lex_ident(text: &str, b: &[u8], mut i: usize, line: u32, toks: &mut Vec<Tok>) -> usize {
    let start = i;
    while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
        i += 1;
    }
    toks.push(Tok {
        line,
        kind: TokKind::Ident(text[start..i].to_string()),
    });
    i
}

/// Skip a normal `"..."` string starting at the opening quote.
fn skip_string(b: &[u8], mut i: usize, line: &mut u32) -> usize {
    i += 1;
    while i < b.len() {
        match b[i] {
            b'\\' => i += 2,
            b'"' => return i + 1,
            b'\n' => {
                *line += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    i
}

/// Skip a char literal or a lifetime starting at the `'`.
fn skip_char_or_lifetime(b: &[u8], mut i: usize, line: &mut u32) -> usize {
    // Lifetime: 'ident not followed by a closing quote ('a' is a char).
    let next_is_ident = b
        .get(i + 1)
        .map_or(false, |&n| n.is_ascii_alphabetic() || n == b'_');
    let closes = b.get(i + 2) == Some(&b'\'');
    if next_is_ident && !closes {
        i += 1;
        while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
            i += 1;
        }
        return i;
    }
    // Char literal: skip escape (if any), then scan to the closing quote.
    i += 1;
    if b.get(i) == Some(&b'\\') {
        i += 2;
    }
    while i < b.len() && b[i] != b'\'' {
        if b[i] == b'\n' {
            *line += 1;
        }
        i += 1;
    }
    i + 1
}

/// At a `r` or `b`: skip `r"…"`, `r#"…"#`, `b"…"`, `b'…'`, `br"…"` / `br#"…"#`
/// literals. Returns `None` when this is actually an identifier (including
/// raw identifiers like `r#type`, which re-lex as punct + ident — fine).
fn try_skip_prefixed_literal(b: &[u8], i: usize, line: &mut u32) -> Option<usize> {
    match b[i] {
        b'r' => match b.get(i + 1) {
            Some(b'"') | Some(b'#') => skip_raw_string(b, i + 1, line),
            _ => None,
        },
        b'b' => match b.get(i + 1) {
            Some(b'"') => Some(skip_string(b, i + 1, line)),
            Some(b'\'') => Some(skip_char_or_lifetime(b, i + 1, line)),
            Some(b'r') => match b.get(i + 2) {
                Some(b'"') | Some(b'#') => skip_raw_string(b, i + 2, line),
                _ => None,
            },
            _ => None,
        },
        _ => None,
    }
}

/// At the first `#` or `"` of a raw string body. Returns `None` when the
/// hashes are not followed by a quote (then it was a raw identifier, not a
/// raw string).
fn skip_raw_string(b: &[u8], mut i: usize, line: &mut u32) -> Option<usize> {
    let mut hashes = 0usize;
    while i < b.len() && b[i] == b'#' {
        hashes += 1;
        i += 1;
    }
    if b.get(i) != Some(&b'"') {
        return None;
    }
    i += 1;
    while i < b.len() {
        if b[i] == b'\n' {
            *line += 1;
            i += 1;
        } else if b[i] == b'"' && b[i + 1..].len() >= hashes && b[i + 1..i + 1 + hashes].iter().all(|&h| h == b'#') {
            return Some(i + 1 + hashes);
        } else {
            i += 1;
        }
    }
    Some(i)
}

/// Parse `audit:allow(<rule>)<sep><reason>` out of a line-comment body.
fn parse_pragma(comment: &str) -> Option<(String, String)> {
    const KEY: &str = "audit:allow(";
    let at = comment.find(KEY)?;
    let rest = &comment[at + KEY.len()..];
    let close = rest.find(')')?;
    let rule = rest[..close].trim().to_string();
    // Separator between `)` and the reason: whitespace plus an optional
    // em-dash, hyphen-run or colon.
    let reason = rest[close + 1..]
        .trim_start()
        .trim_start_matches(&['—', '-', ':'][..])
        .trim()
        .to_string();
    Some((rule, reason))
}

/// Token-index ranges `[start, end)` covered by `#[cfg(test)]` or `#[test]`
/// items. The attribute tokens themselves are included in the range, and the
/// range extends through the item's brace-matched body (or to its `;` for a
/// bodiless item). `#[cfg(not(test))]` does **not** match — the pattern is
/// the exact token sequence `# [ cfg ( test ) ]`.
pub fn test_regions(toks: &[Tok]) -> Vec<(usize, usize)> {
    let mut regions: Vec<(usize, usize)> = Vec::new();
    let ident_at = |k: usize, s: &str| toks.get(k).map_or(false, |t| t.is_ident(s));
    let punct_at = |k: usize, c: char| toks.get(k).map_or(false, |t| t.is_punct(c));

    let mut i = 0usize;
    while i < toks.len() {
        if !(punct_at(i, '#') && punct_at(i + 1, '[')) {
            i += 1;
            continue;
        }
        // `#[cfg(test)]` => # [ cfg ( test ) ]   (7 tokens)
        // `#[test]`      => # [ test ]           (4 tokens)
        let attr_end = if ident_at(i + 2, "cfg")
            && punct_at(i + 3, '(')
            && ident_at(i + 4, "test")
            && punct_at(i + 5, ')')
            && punct_at(i + 6, ']')
        {
            Some(i + 6)
        } else if ident_at(i + 2, "test") && punct_at(i + 3, ']') {
            Some(i + 3)
        } else {
            None
        };
        let Some(attr_end) = attr_end else {
            i += 2;
            continue;
        };
        // Scan forward to the item body: the first `{` opens it (brace-match
        // to its close), a `;` first means a bodiless item. Intervening
        // attributes like `#[should_panic(expected = "…")]` contain neither,
        // so they are crossed transparently.
        let mut j = attr_end + 1;
        let mut end = toks.len();
        while j < toks.len() {
            if punct_at(j, ';') {
                end = j + 1;
                break;
            }
            if punct_at(j, '{') {
                let mut depth = 1usize;
                let mut k = j + 1;
                while k < toks.len() && depth > 0 {
                    if punct_at(k, '{') {
                        depth += 1;
                    } else if punct_at(k, '}') {
                        depth -= 1;
                    }
                    k += 1;
                }
                end = k;
                break;
            }
            j += 1;
        }
        regions.push((i, end));
        i = end;
    }
    regions
}

/// Map test-region token ranges to inclusive line ranges, so pragmas (which
/// live in comments, not tokens) can also be excluded inside tests.
pub fn region_lines(toks: &[Tok], regions: &[(usize, usize)]) -> Vec<(u32, u32)> {
    regions
        .iter()
        .filter_map(|&(a, z)| {
            let first = toks.get(a)?.line;
            let last = toks.get(z.saturating_sub(1))?.line;
            Some((first, last))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(s: &Scan) -> Vec<&str> {
        s.toks.iter().filter_map(|t| t.ident()).collect()
    }

    #[test]
    fn strings_and_comments_hide_tokens() {
        let s = scan(concat!(
            "let a = \"HashMap // not a comment\";\n",
            "/* HashSet\n   /* nested */ still comment */\n",
            "let b = r#\"unwrap()\"#;\n",
            "let c = 'x'; let d: &'static str = \"\";\n",
        ));
        let ids = idents(&s);
        assert!(ids.contains(&"a") && ids.contains(&"b") && ids.contains(&"c"));
        assert!(!ids.contains(&"HashMap"));
        assert!(!ids.contains(&"HashSet"));
        assert!(!ids.contains(&"unwrap"));
        // 'static lexes as a lifetime, not a char + ident
        assert!(!ids.contains(&"static"));
        assert!(ids.contains(&"str"));
    }

    #[test]
    fn escaped_char_literals() {
        let s = scan("let q = '\\''; let n = '\\n'; let u = '\\u{1F600}'; let e = 'é';");
        let ids = idents(&s);
        assert_eq!(
            ids.iter().filter(|&&i| i == "let").count(),
            4,
            "all four statements lexed: {ids:?}"
        );
    }

    #[test]
    fn number_suffixes_are_not_idents() {
        let s = scan("let x = 1.0f64 + 0f32; let y = 0x5A_u16;");
        let ids = idents(&s);
        assert!(!ids.contains(&"f64"));
        assert!(!ids.contains(&"f32"));
        assert!(!ids.contains(&"u16"));
        // ...but a cast target is a real ident
        let s2 = scan("let z = w as f32;");
        assert!(idents(&s2).contains(&"f32"));
    }

    #[test]
    fn line_numbers_survive_multiline_constructs() {
        let s = scan("let a = \"x\ny\";\n/* c\nc */\nlet b = 1;\n");
        let b = s.toks.iter().find(|t| t.is_ident("b")).map(|t| t.line);
        assert_eq!(b, Some(5));
    }

    #[test]
    fn pragma_trailing_vs_standalone() {
        let s = scan(concat!(
            "let x = v.unwrap(); // audit:allow(panic-path) — bounded by ctor\n",
            "// audit:allow(lossy-cast) — wire norms are fp32 by contract\n",
            "let y = n as f32;\n",
        ));
        assert_eq!(s.pragmas.len(), 2);
        assert!(s.pragmas[0].trailing);
        assert_eq!(s.pragmas[0].rule, "panic-path");
        assert_eq!(s.pragmas[0].reason, "bounded by ctor");
        assert!(!s.pragmas[1].trailing);
        assert_eq!(s.pragmas[1].rule, "lossy-cast");
        assert_eq!(s.pragmas[1].line, 2);
    }

    #[test]
    fn pragma_colon_separator_and_empty_reason() {
        let s = scan("// audit:allow(rng-clone): splice accounting advances the leader\nlet a = 1;\n// audit:allow(panic-path)\n");
        assert_eq!(s.pragmas.len(), 2);
        assert_eq!(s.pragmas[0].reason, "splice accounting advances the leader");
        assert_eq!(s.pragmas[1].reason, "");
    }

    #[test]
    fn cfg_test_region_covers_mod_body() {
        let src = concat!(
            "fn live() { v.unwrap(); }\n",
            "#[cfg(test)]\n",
            "mod tests {\n",
            "    use std::collections::HashMap;\n",
            "    #[test]\n",
            "    fn t() { x.unwrap(); }\n",
            "}\n",
            "fn after() { y.unwrap(); }\n",
        );
        let s = scan(src);
        let regions = test_regions(&s.toks);
        assert_eq!(regions.len(), 1);
        let (a, z) = regions[0];
        let in_region = |name: &str| {
            s.toks
                .iter()
                .enumerate()
                .any(|(k, t)| t.is_ident(name) && k >= a && k < z)
        };
        assert!(in_region("HashMap"));
        let unwraps: Vec<usize> = s
            .toks
            .iter()
            .enumerate()
            .filter(|(_, t)| t.is_ident("unwrap"))
            .map(|(k, _)| k)
            .collect();
        assert_eq!(unwraps.len(), 3);
        assert!(unwraps[0] < a, "live() unwrap outside region");
        assert!(unwraps[1] >= a && unwraps[1] < z, "test unwrap inside");
        assert!(unwraps[2] >= z, "after() unwrap outside region");
    }

    #[test]
    fn cfg_not_test_is_not_a_test_region() {
        let s = scan("#[cfg(not(test))]\nfn live() { v.unwrap(); }\n");
        assert!(test_regions(&s.toks).is_empty());
    }

    #[test]
    fn test_attr_with_should_panic() {
        let s = scan(concat!(
            "#[test]\n",
            "#[should_panic(expected = \"boom {\")]\n",
            "fn t() { x.unwrap(); }\n",
            "fn live() { y.unwrap(); }\n",
        ));
        let regions = test_regions(&s.toks);
        assert_eq!(regions.len(), 1);
        let (a, z) = regions[0];
        let last_unwrap = s
            .toks
            .iter()
            .enumerate()
            .filter(|(_, t)| t.is_ident("unwrap"))
            .map(|(k, _)| k)
            .max();
        assert!(last_unwrap.map_or(false, |k| k >= z || k < a), "live unwrap outside");
    }
}

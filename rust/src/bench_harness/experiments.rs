//! Experiment harnesses: one function per paper table/figure (T1, T2, F4,
//! T3, F5) plus the theory-verification experiments (V1–V6 of DESIGN.md).
//! The CLI (`qoda <exp>`), the examples and the benches all call these.

use crate::coding::huffman::normalize;
use crate::coding::protocol::{
    encoded_bits, symbol_counts, Codebooks, ProtocolKind,
};
use crate::comm::{Compressor, QuantCompressor};
use crate::coordinator::topology::{
    rack_spans, resolve_racks, ExchangePlan, TopologySpec, Transport,
};
use crate::net::{Collective, NetworkModel};
use crate::oda::{
    CompressionSpec, ConstantLr, GapMode, LrSpec, OperatorSpec, Qoda, RunDriver,
    RunSpec, SolverKind, StreamSource,
};
use crate::quant::adaptive::TypeStats;
use crate::quant::layer_map::LayerMap;
use crate::quant::levels::LevelSequence;
use crate::quant::quantizer::{quantize, QuantConfig};
use crate::quant::variance;
use crate::quant::{lgreco, schedule};
use crate::stats::rng::Rng;
use crate::util::table::Table;
use crate::vi::noise::NoiseModel;

// ---------------------------------------------------------------------------
// Step-time model for Tables 1–2 (calibration documented in DESIGN.md §T1/T2
// and EXPERIMENTS.md): the paper's WGAN communicates ~4.2 MB of fp32
// gradients per step; per-step compute shrinks under weak scaling
// (constant global batch) as a + b/K; the fp32 baseline additionally pays a
// per-peer synchronization/incast cost that quantized sub-MB payloads avoid.
// ---------------------------------------------------------------------------

/// fp32 payload bytes per node (≈1.05 M parameters).
pub const PAYLOAD_BYTES: f64 = 4.2e6;
/// weak-scaling compute model (ms): a + b / K
pub const COMPUTE_A_MS: f64 = 88.0;
pub const COMPUTE_B_MS: f64 = 400.0;
/// baseline per-peer full-precision sync overhead (ms per peer)
pub const BASELINE_SYNC_MS_PER_PEER: f64 = 13.0;
/// measured-once codec cost of the paper's CUDA quantizer (ms) — our CPU
/// codec is benchmarked separately in rust/benches; the table uses the
/// device-speed figure so the regime matches the testbed
pub const QODA_CODEC_MS: f64 = 4.0;

/// The Table 2 per-step compute window (seconds) at `k` nodes — the weak-
/// scaling model `COMPUTE_A_MS + COMPUTE_B_MS / K` in one place, shared by
/// the overlap harness, the bench JSON emitter, the simulator calibration
/// pins and `examples/overlap_sweep.rs` so they can never disagree about
/// what an overlapped exchange hides behind.
pub fn table2_compute_window_s(k: usize) -> f64 {
    (COMPUTE_A_MS + COMPUTE_B_MS / k as f64) * 1e-3
}

/// One QODA5-regime exchange charge: `k` nodes each shipping the Table 1/2
/// payload at `bpc` measured bytes/coordinate, routed by `topo` over the
/// `bandwidth_gbps` genesis-cloud model. The single source of the
/// payload-construction recipe shared by [`step_time_ms_topo`],
/// [`overlap_sweep`] and `examples/overlap_sweep.rs`.
pub fn qoda5_charge(
    k: usize,
    bandwidth_gbps: f64,
    bpc: f64,
    topo: &TopologySpec,
) -> crate::coordinator::topology::WireCharge {
    let net = NetworkModel::genesis_cloud(bandwidth_gbps);
    let coords = (PAYLOAD_BYTES / 4.0) as usize;
    let bits = vec![(coords as f64 * bpc * 8.0) as u64; k];
    let mut rng = Rng::new(1);
    topo.build().charge(&bits, coords, &net, false, true, &mut rng)
}

/// Real encoded bytes/coordinate for a gradient-shaped vector under the
/// QODA5 configuration (5-bit, bucket 128, entropy-coded): measured through
/// the unified comm pipeline — one warm-up encode gathers statistics, the
/// codebooks retune, and the reported figure is the second packet's actual
/// payload size.
pub fn measure_qoda5_bytes_per_coord(n: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    // heavy-tailed gradient: a few coordinates dominate each bucket's norm
    let v: Vec<f64> = (0..n)
        .map(|i| {
            let base = rng.gaussian();
            if i % 61 == 0 {
                base * 20.0
            } else {
                base * 0.3
            }
        })
        .collect();
    let map = LayerMap::single(n);
    let mut codec = QuantCompressor::global_bits(&map, 5, 128, seed ^ 0x51);
    // pass 1: cold (uniform books) — gathers the per-type statistics
    let _ = codec.encode(&v).expect("warm-up encode");
    // tune the entropy coder to the observed level distribution (Prop D.1)
    codec.retune_books();
    // pass 2: the measured wire packet
    let packet = codec.encode(&v).expect("measured encode");
    packet.len_bits() as f64 / 8.0 / n as f64
}

/// Step time (ms) for one configuration of the Tables 1–2 testbed.
pub fn step_time_ms(k: usize, bandwidth_gbps: f64, qoda5: bool, bytes_per_coord: f64) -> f64 {
    let net = NetworkModel::genesis_cloud(bandwidth_gbps);
    let compute = COMPUTE_A_MS + COMPUTE_B_MS / k as f64;
    if qoda5 {
        let coords = PAYLOAD_BYTES / 4.0;
        let bytes = coords * bytes_per_coord;
        let wire =
            net.collective_seconds(Collective::RingAllGather, &vec![bytes; k]) * 1e3;
        compute + QODA_CODEC_MS + wire
    } else {
        let wire =
            net.collective_seconds(Collective::RingAllReduce, &vec![PAYLOAD_BYTES; k])
                * 1e3;
        let sync = BASELINE_SYNC_MS_PER_PEER * (k as f64 - 1.0);
        compute + sync + wire
    }
}

/// Peers a node synchronizes with per step under a topology (the fp32
/// baseline's per-peer sync overhead): all K-1 under flat broadcast, rack
/// peers + rack leaders under hierarchical, just the hub under a parameter
/// server, the full mesh under sharded reduce-scatter (shards travel to
/// every owner), and the two ring neighbours under ring routing.
fn sync_peers(topo: &TopologySpec, k: usize) -> usize {
    match *topo {
        TopologySpec::BroadcastAllGather => k.saturating_sub(1),
        TopologySpec::Hierarchical { racks } => {
            // racks = 0 resolves to the conventional K/4 layout, exactly as
            // `Hierarchical::charge` does via `resolve_racks`
            let racks = resolve_racks(k, racks);
            let spans = rack_spans(k, racks);
            let m = spans.iter().map(|&(s, e)| e - s).max().unwrap_or(1);
            (m - 1) + spans.len().saturating_sub(1)
        }
        TopologySpec::ParameterServer => 1,
        TopologySpec::ShardedReduceScatter => k.saturating_sub(1),
        TopologySpec::Ring => 2.min(k.saturating_sub(1)),
    }
}

/// [`step_time_ms`] under an arbitrary topology: the same calibrated
/// compute/codec/sync constants, with the wire phase routed and charged by
/// the topology's [`Transport`](crate::coordinator::topology::Transport)
/// over the heterogeneous-link network model. For
/// [`TopologySpec::BroadcastAllGather`] this reproduces [`step_time_ms`].
pub fn step_time_ms_topo(
    k: usize,
    bandwidth_gbps: f64,
    qoda5: bool,
    bytes_per_coord: f64,
    topo: &TopologySpec,
) -> f64 {
    let compute = COMPUTE_A_MS + COMPUTE_B_MS / k as f64;
    if qoda5 {
        let charge = qoda5_charge(k, bandwidth_gbps, bytes_per_coord, topo);
        compute + QODA_CODEC_MS + charge.comm_s * 1e3
    } else {
        let net = NetworkModel::genesis_cloud(bandwidth_gbps);
        let coords = (PAYLOAD_BYTES / 4.0) as usize;
        let bits = vec![(PAYLOAD_BYTES * 8.0) as u64; k];
        let mut rng = Rng::new(1);
        let charge = topo.build().charge(&bits, coords, &net, true, true, &mut rng);
        let sync = BASELINE_SYNC_MS_PER_PEER * sync_peers(topo, k) as f64;
        compute + sync + charge.comm_s * 1e3
    }
}

/// One (K, topology) cell of the weak-scaling topology sweep.
pub struct TopologySweepRow {
    pub k: usize,
    pub topology: TopologySpec,
    pub baseline_ms: f64,
    pub qoda5_ms: f64,
    /// peak bytes any single link carries per QODA5 step under this plan —
    /// the hot-spot metric the sharded/ring plans exist to shrink
    pub peak_link_bytes: f64,
}

/// The weak-scaling regime across all five topologies: per node count,
/// step time for the fp32 baseline and QODA5 under flat broadcast,
/// hierarchical (K/4 racks), parameter-server, sharded reduce-scatter and
/// ring routing, plus each plan's peak per-link load. Drives the
/// `topology_sweep` example, `qoda topology` and the `BENCH_comm.json`
/// emitter.
pub fn topology_sweep(ks: &[usize], bandwidth_gbps: f64) -> Vec<TopologySweepRow> {
    let bpc = measure_qoda5_bytes_per_coord(1 << 16, 42);
    let mut rows = Vec::new();
    for &k in ks {
        for spec in [
            TopologySpec::BroadcastAllGather,
            TopologySpec::hierarchical_for(k),
            TopologySpec::ParameterServer,
            TopologySpec::ShardedReduceScatter,
            TopologySpec::Ring,
        ] {
            let charge = qoda5_charge(k, bandwidth_gbps, bpc, &spec);
            rows.push(TopologySweepRow {
                k,
                topology: spec,
                baseline_ms: step_time_ms_topo(k, bandwidth_gbps, false, bpc, &spec),
                qoda5_ms: step_time_ms_topo(k, bandwidth_gbps, true, bpc, &spec),
                peak_link_bytes: charge.peak_link_bytes,
            });
        }
    }
    rows
}

/// Render [`topology_sweep`] as a table (the weak-scaling Table 2 with a
/// topology axis) — the body of `qoda topology`.
pub fn topology_table(ks: &[usize], bandwidth_gbps: f64) -> Table {
    let mut t = Table::new(
        &format!(
            "Weak scaling x topology — time per step (ms), {bandwidth_gbps} Gbps cross-rack"
        ),
        &["K", "topology", "baseline", "QODA5", "speedup", "peak link KB/step"],
    );
    for row in topology_sweep(ks, bandwidth_gbps) {
        t.row(&[
            format!("{}", row.k),
            row.topology.label().to_string(),
            format!("{:.0}", row.baseline_ms),
            format!("{:.0}", row.qoda5_ms),
            format!("{:.2}x", row.baseline_ms / row.qoda5_ms),
            format!("{:.2}", row.peak_link_bytes / 1e3),
        ]);
    }
    t
}

/// One (K, topology) cell of the overlapped-exchange sweep: the Table 1/2
/// QODA5 regime with comm split into exposed vs hidden against the weak-
/// scaling compute window.
pub struct OverlapRow {
    pub k: usize,
    pub topology: TopologySpec,
    /// full modeled comm per step (ms) — what a synchronous exchange pays
    pub comm_ms: f64,
    /// comm left on the critical path under the overlapped exchange (ms)
    pub comm_exposed_ms: f64,
    /// comm hidden behind the next step's compute (ms)
    pub comm_hidden_ms: f64,
    /// synchronous step time (ms): compute + codec + full comm
    pub sync_ms: f64,
    /// overlapped step time (ms): compute + codec + exposed comm only
    pub overlap_ms: f64,
}

/// The QODA5 weak-scaling regime under an overlapped exchange of `depth`:
/// per (K, topology), the transport's charge is split against the
/// calibrated compute window `COMPUTE_A_MS + COMPUTE_B_MS / K` and the step
/// time recomputed with only the exposed share on the critical path.
/// Drives `overlap_table`, the `BENCH_comm.json` exposed/hidden columns and
/// `examples/overlap_sweep.rs`.
pub fn overlap_sweep(ks: &[usize], bandwidth_gbps: f64, depth: usize) -> Vec<OverlapRow> {
    let bpc = measure_qoda5_bytes_per_coord(1 << 16, 42);
    let mut rows = Vec::new();
    for &k in ks {
        let compute_ms = table2_compute_window_s(k) * 1e3;
        let plan = ExchangePlan::overlapped(depth, table2_compute_window_s(k));
        for spec in [
            TopologySpec::BroadcastAllGather,
            TopologySpec::hierarchical_for(k),
            TopologySpec::ParameterServer,
        ] {
            let charge = qoda5_charge(k, bandwidth_gbps, bpc, &spec);
            let (exposed_s, hidden_s) = plan.split(charge.comm_s);
            let comm_ms = charge.comm_s * 1e3;
            rows.push(OverlapRow {
                k,
                topology: spec,
                comm_ms,
                comm_exposed_ms: exposed_s * 1e3,
                comm_hidden_ms: hidden_s * 1e3,
                sync_ms: compute_ms + QODA_CODEC_MS + comm_ms,
                overlap_ms: compute_ms + QODA_CODEC_MS + exposed_s * 1e3,
            });
        }
    }
    rows
}

/// Render [`overlap_sweep`] as a table (the Table 2 regime with the
/// synchronous-vs-overlapped axis).
pub fn overlap_table(ks: &[usize], bandwidth_gbps: f64, depth: usize) -> Table {
    let mut t = Table::new(
        &format!(
            "Weak scaling x overlap (QODA5) — per-step ms, depth {depth}, \
             {bandwidth_gbps} Gbps cross-rack"
        ),
        &["K", "topology", "comm", "exposed", "hidden", "sync step", "overlap step", "speedup"],
    );
    for row in overlap_sweep(ks, bandwidth_gbps, depth) {
        t.row(&[
            format!("{}", row.k),
            row.topology.label().to_string(),
            format!("{:.1}", row.comm_ms),
            format!("{:.1}", row.comm_exposed_ms),
            format!("{:.1}", row.comm_hidden_ms),
            format!("{:.0}", row.sync_ms),
            format!("{:.0}", row.overlap_ms),
            format!("{:.2}x", row.sync_ms / row.overlap_ms),
        ]);
    }
    t
}

/// Table 1: time per optimization step vs inter-node bandwidth (K = 4).
pub fn table1() -> Table {
    let bpc = measure_qoda5_bytes_per_coord(1 << 20, 42);
    let bws = [1.0, 2.5, 5.0];
    let mut t = Table::new(
        "Table 1 — time per optimization step (ms), K = 4",
        &["Mode", "1 Gbps", "2.5 Gbps", "5 Gbps"],
    );
    let base: Vec<f64> = bws.iter().map(|&bw| step_time_ms(4, bw, false, bpc)).collect();
    let qoda: Vec<f64> = bws.iter().map(|&bw| step_time_ms(4, bw, true, bpc)).collect();
    t.row(&[
        "Baseline".into(),
        format!("{:.0}", base[0]),
        format!("{:.0}", base[1]),
        format!("{:.0}", base[2]),
    ]);
    t.row(&[
        "QODA5".into(),
        format!("{:.0}", qoda[0]),
        format!("{:.0}", qoda[1]),
        format!("{:.0}", qoda[2]),
    ]);
    t.row(&[
        "Speedup".into(),
        format!("{:.2}x", base[0] / qoda[0]),
        format!("{:.2}x", base[1] / qoda[1]),
        format!("{:.2}x", base[2] / qoda[2]),
    ]);
    t
}

/// Table 2: weak scaling — time per step vs node count (5 Gbps).
pub fn table2() -> Table {
    let bpc = measure_qoda5_bytes_per_coord(1 << 20, 42);
    let ks = [4usize, 8, 12, 16];
    let mut t = Table::new(
        "Table 2 — time per optimization step (ms) under weak scaling, 5 Gbps",
        &["Mode", "4 GPUs", "8 GPUs", "12 GPUs", "16 GPUs"],
    );
    let base: Vec<f64> = ks.iter().map(|&k| step_time_ms(k, 5.0, false, bpc)).collect();
    let qoda: Vec<f64> = ks.iter().map(|&k| step_time_ms(k, 5.0, true, bpc)).collect();
    t.row(&[
        "baseline".into(),
        format!("{:.0}", base[0]),
        format!("{:.0}", base[1]),
        format!("{:.0}", base[2]),
        format!("{:.0}", base[3]),
    ]);
    t.row(&[
        "QODA5".into(),
        format!("{:.0}", qoda[0]),
        format!("{:.0}", qoda[1]),
        format!("{:.0}", qoda[2]),
        format!("{:.0}", qoda[3]),
    ]);
    t.row(&[
        "Speedup".into(),
        format!("{:.2}x", base[0] / qoda[0]),
        format!("{:.2}x", base[1] / qoda[1]),
        format!("{:.2}x", base[2] / qoda[2]),
        format!("{:.2}x", base[3] / qoda[3]),
    ]);
    t
}

// ---------------------------------------------------------------------------
// V1 — Theorem 5.1 variance bound
// ---------------------------------------------------------------------------

pub fn verify_variance() -> Table {
    let mut t = Table::new(
        "V1 — Theorem 5.1: empirical variance ratio vs eps_Q bound",
        &["d", "q", "levels", "empirical", "eps_Q", "holds"],
    );
    let mut rng = Rng::new(7);
    for &d in &[16usize, 256, 4096, 65536] {
        for &(q, qs) in &[(2.0, "L2"), (1.0, "L1"), (f64::INFINITY, "Linf")] {
            for &(alpha, name) in &[(3usize, "uni(3)"), (14, "uni(14)")] {
                let v: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
                let seq = LevelSequence::uniform(alpha);
                let map = LayerMap::single(d);
                let cfg = QuantConfig::same(1, seq.clone(), q);
                let reps = if d > 10_000 { 5 } else { 40 };
                let emp = variance::empirical_variance_ratio(&v, &map, &cfg, reps, 1);
                let bound = variance::eps_q(&[seq], d, q);
                t.row(&[
                    format!("{d}"),
                    qs.to_string(),
                    name.to_string(),
                    format!("{emp:.4}"),
                    format!("{bound:.4}"),
                    format!("{}", emp <= bound * 1.05),
                ]);
            }
        }
    }
    t
}

// ---------------------------------------------------------------------------
// V2 — Theorem 5.3/D.5 code length vs measured bits
// ---------------------------------------------------------------------------

pub fn verify_codelen() -> Table {
    let mut t = Table::new(
        "V2 — Theorem 5.3 / D.5: measured wire bits vs entropy bounds (per vector)",
        &["protocol", "d", "measured", "bound", "fixed-width", "within"],
    );
    let mut rng = Rng::new(11);
    for &d in &[4096usize, 65536] {
        let v: Vec<f32> = (0..d)
            .map(|i| (rng.gaussian() as f32) * if i % 31 == 0 { 10.0 } else { 0.2 })
            .collect();
        let map = LayerMap::from_spec(&[("a", d / 2, "ff"), ("b", d / 2, "emb")]);
        let cfg = QuantConfig {
            sequences: vec![LevelSequence::bits(4), LevelSequence::bits(6)],
            q: 2.0,
        };
        let qv = quantize(&v, &map, &cfg, &mut rng);
        let sizes: Vec<usize> = cfg.sequences.iter().map(|s| s.num_symbols()).collect();
        let probs: Vec<Vec<f64>> = symbol_counts(&qv, 2, &sizes)
            .iter()
            .map(|c| normalize(c))
            .collect();
        let mu = map.type_proportions();
        for (kind, name) in
            [(ProtocolKind::Main, "main"), (ProtocolKind::Alternating, "alternating")]
        {
            let books = Codebooks::build(kind, &probs, &mu);
            let measured = encoded_bits(&qv, &books);
            let bound = match kind {
                ProtocolKind::Main => crate::coding::length::main_protocol_bound(
                    &probs, &mu, d, 32,
                ) + 32.0 * (map.layers.len() as f64 - 1.0),
                ProtocolKind::Alternating => {
                    crate::coding::length::alternating_protocol_bound(&probs, &mu, d, 32)
                        + 32.0 * (map.layers.len() as f64 - 1.0)
                }
            };
            let fixed = crate::quant::quantizer::fixed_width_bits(&qv, &cfg, 32);
            t.row(&[
                name.to_string(),
                format!("{d}"),
                format!("{measured}"),
                format!("{bound:.0}"),
                format!("{fixed}"),
                format!("{}", (measured as f64) <= bound * 1.02),
            ]);
        }
    }
    t
}

// ---------------------------------------------------------------------------
// V3/V4 — convergence rates (Theorems 5.5, 5.7, 6.2)
// ---------------------------------------------------------------------------

pub struct RatePoint {
    pub t: usize,
    pub gap: f64,
}

/// GAP of QODA's ergodic average at a sweep of horizons, one (operator, K,
/// noise) configuration. One declarative spec; the driver evaluates the gap
/// at each checkpoint as the run streams by.
pub fn rate_sweep(
    kind: &str,
    k: usize,
    noise: NoiseModel,
    bits: Option<u32>,
    horizons: &[usize],
    seed: u64,
    use_alt: bool,
) -> Vec<RatePoint> {
    let (operator, x0) = match kind {
        "bilinear" => (OperatorSpec::Bilinear { n: 8, seed }, vec![1.0; 16]),
        _ => (OperatorSpec::Quadratic { dim: 12, mu: 0.8, seed }, vec![0.0; 12]),
    };
    let compression = match bits {
        None => CompressionSpec::None,
        Some(b) => CompressionSpec::Global { bits: b, bucket: 128 },
    };
    let lr = if use_alt { LrSpec::Alt { q_hat: 0.25 } } else { LrSpec::Adaptive };
    let report = RunSpec::new(SolverKind::Qoda, operator)
        .nodes(k)
        .noise(noise)
        .compression(compression)
        .lr(lr)
        .steps(*horizons.last().unwrap())
        .checkpoints(horizons)
        .seed(seed)
        .x0(x0)
        .gap(GapMode::AtCheckpoints)
        .run();
    report
        .gap_trace
        .into_iter()
        .map(|(t, gap)| RatePoint { t, gap })
        .collect()
}

/// V3/V4 table: GAP vs T for both noise models, with fitted decay exponent.
pub fn rates_table(noise_name: &str) -> Table {
    let horizons = [64usize, 256, 1024, 4096];
    let (noise, kind, use_alt) = match noise_name {
        "relative" => (NoiseModel::Relative { sigma_r: 0.5 }, "quadratic", false),
        "relative-alt" => (NoiseModel::Relative { sigma_r: 0.5 }, "bilinear", true),
        _ => (NoiseModel::Absolute { sigma: 0.5 }, "quadratic", false),
    };
    let mut t = Table::new(
        &format!("V3/V4 — QODA GAP vs T ({noise_name} noise, {kind})"),
        &["K", "T=64", "T=256", "T=1024", "T=4096", "slope"],
    );
    for &k in &[1usize, 4] {
        // average over seeds for stability
        let mut gaps = vec![0.0; horizons.len()];
        let seeds = 3;
        for s in 0..seeds {
            let pts = rate_sweep(kind, k, noise, Some(6), &horizons, 100 + s, use_alt);
            for (g, p) in gaps.iter_mut().zip(&pts) {
                *g += p.gap / seeds as f64;
            }
        }
        // log-log slope between first and last horizon
        let slope = (gaps.last().unwrap().max(1e-12) / gaps[0].max(1e-12)).ln()
            / ((*horizons.last().unwrap() as f64) / horizons[0] as f64).ln();
        t.row(&[
            format!("{k}"),
            format!("{:.4}", gaps[0]),
            format!("{:.4}", gaps[1]),
            format!("{:.4}", gaps[2]),
            format!("{:.4}", gaps[3]),
            format!("{slope:.2}"),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// V5 — Remark 3.2: layer-wise (MQV) <= global (MQV)
// ---------------------------------------------------------------------------

/// Samplers for heterogeneous layer-magnitude distributions.
fn layer_sample(rng: &mut Rng, shape: &str, n: usize) -> Vec<f32> {
    (0..n)
        .map(|_| match shape {
            // dense gaussian magnitudes
            "gauss" => rng.gaussian() as f32,
            // sparse/spiky: a few huge coordinates (attention-like)
            "sparse" => {
                if rng.uniform() < 0.08 {
                    (rng.gaussian() * 10.0) as f32
                } else {
                    (rng.gaussian() * 0.05) as f32
                }
            }
            // near-uniform magnitudes (normalization-layer-like)
            _ => (rng.uniform() * 2.0 - 1.0) as f32,
        })
        .collect()
}

pub fn verify_mqv() -> Table {
    // Remark 3.2 isolated: identical per-layer normalization in both arms;
    // layer-wise = per-type sequences each optimized on its own CDF (Eq. 2),
    // global = ONE sequence optimized on the merged CDF used for all types.
    let mut t = Table::new(
        "V5 — Remark 3.2: per-type optimized sequences vs one global sequence (MQV)",
        &["scenario", "layerwise", "global", "improvement"],
    );
    let mut rng = Rng::new(13);
    let per = 1024usize;
    let alpha = 6usize;
    for (name, shapes) in [
        ("homogeneous", vec!["gauss", "gauss", "gauss"]),
        ("two-kinds", vec!["gauss", "sparse", "gauss"]),
        ("three-kinds", vec!["gauss", "sparse", "uniform"]),
    ] {
        let samples: Vec<Vec<f32>> = (0..4)
            .map(|_| {
                let mut v = Vec::new();
                for sh in &shapes {
                    v.extend(layer_sample(&mut rng, sh, per));
                }
                v
            })
            .collect();
        let spec: Vec<(String, usize, String)> = shapes
            .iter()
            .enumerate()
            .map(|(i, sh)| (format!("l{i}"), per, format!("t_{sh}_{i}")))
            .collect();
        let spec_ref: Vec<(&str, usize, &str)> =
            spec.iter().map(|(n, l, ty)| (n.as_str(), *l, ty.as_str())).collect();
        let map = LayerMap::from_spec(&spec_ref);
        // gather per-type CDFs
        let mut stats: Vec<crate::quant::adaptive::TypeStats> =
            (0..map.num_types()).map(|_| Default::default()).collect();
        let mut merged = crate::quant::adaptive::TypeStats::default();
        for s in &samples {
            for l in &map.layers {
                let slice = &s[l.offset..l.offset + l.len];
                stats[l.type_id].add_layer_sample(slice, 2.0);
                merged.add_layer_sample(slice, 2.0);
            }
        }
        let (lw_seqs, _) = crate::quant::adaptive::adapt_all(
            &stats,
            &vec![alpha; map.num_types()],
            8,
        );
        let (gl_seq, _) =
            crate::quant::adaptive::optimize_levels(&merged.hist, alpha, 8);
        let lw_cfg = QuantConfig { sequences: lw_seqs, q: 2.0 };
        let gl_cfg = QuantConfig::same(map.num_types(), gl_seq, 2.0);
        let lw = variance::mqv_objective(&samples, &map, &lw_cfg, 20, 1);
        let gl = variance::mqv_objective(&samples, &map, &gl_cfg, 20, 1);
        t.row(&[
            name.to_string(),
            format!("{lw:.4}"),
            format!("{gl:.4}"),
            format!("{:.3}x", gl / lw.max(1e-12)),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// V6 — Remark D.3: protocol trade-off under jitter
// ---------------------------------------------------------------------------

pub fn protocols_table() -> Table {
    let mut t = Table::new(
        "V6 — Remark D.3: Main vs Alternating protocol under network jitter",
        &["jitter p", "main bits", "alt bits", "main time(ms)", "alt time(ms)", "winner"],
    );
    let mut rng = Rng::new(17);
    let d = 1 << 16;
    let v: Vec<f32> = (0..d)
        .map(|i| (rng.gaussian() as f32) * if i % 37 == 0 { 8.0 } else { 0.2 })
        .collect();
    let map = LayerMap::from_spec(&[("a", d / 2, "ff"), ("b", d / 2, "emb")]);
    let cfg = QuantConfig {
        sequences: vec![LevelSequence::bits(4), LevelSequence::bits(6)],
        q: 2.0,
    };
    let qv = quantize(&v, &map, &cfg, &mut rng);
    let sizes: Vec<usize> = cfg.sequences.iter().map(|s| s.num_symbols()).collect();
    let probs: Vec<Vec<f64>> =
        symbol_counts(&qv, 2, &sizes).iter().map(|c| normalize(c)).collect();
    let mu = map.type_proportions();
    let main_bits =
        encoded_bits(&qv, &Codebooks::build(ProtocolKind::Main, &probs, &mu)) as f64;
    let alt_bits =
        encoded_bits(&qv, &Codebooks::build(ProtocolKind::Alternating, &probs, &mu))
            as f64;
    for &p in &[0.0, 0.05, 0.2, 0.5] {
        let mut net = NetworkModel::genesis_cloud(5.0);
        net.jitter =
            crate::net::JitterModel { p, retrans_fraction: 1.0, resync_fraction: 0.05 };
        let tm =
            main_bits / 8.0 / (net.bandwidth_gbps * 1e9 / 8.0) * net.jitter_multiplier(true);
        let ta = alt_bits / 8.0 / (net.bandwidth_gbps * 1e9 / 8.0)
            * net.jitter_multiplier(false);
        t.row(&[
            format!("{p:.2}"),
            format!("{main_bits:.0}"),
            format!("{alt_bits:.0}"),
            format!("{:.4}", tm * 1e3),
            format!("{:.4}", ta * 1e3),
            (if tm <= ta { "main" } else { "alternating" }).to_string(),
        ]);
    }
    t
}

/// Q-GenX vs QODA oracle/communication cost at matched GAP (the optimism
/// claim quantified — supports the Figure 4 discussion). Same [`RunSpec`]
/// twice; only the solver kind changes. Note: the migration onto `RunSpec`
/// re-derives the oracle seed from the spec seed, so the table's absolute
/// numbers differ from the pre-driver harness; the 2x oracle/wire claim it
/// demonstrates is seed-independent.
pub fn optimism_table() -> Table {
    let mut t = Table::new(
        "Optimism — oracle calls & wire bits to reach GAP <= target (quadratic, abs noise)",
        &["solver", "iters", "oracle calls", "wire Mbits", "GAP"],
    );
    let steps = 2048;
    for (kind, label) in [(SolverKind::Qoda, "QODA"), (SolverKind::QGenX, "Q-GenX")] {
        let report =
            RunSpec::new(kind, OperatorSpec::Quadratic { dim: 12, mu: 0.8, seed: 23 })
                .nodes(4)
                .noise(NoiseModel::Absolute { sigma: 0.3 })
                .compression(CompressionSpec::Global { bits: 5, bucket: 128 })
                .steps(steps)
                .checkpoints(&[steps])
                .seed(10)
                .gap(GapMode::AtCheckpoints)
                .run();
        t.row(&[
            label.into(),
            format!("{steps}"),
            format!("{}", report.oracle_calls),
            format!("{:.2}", report.total_bits as f64 / 1e6),
            format!("{:.4}", report.final_gap().unwrap_or(f64::NAN)),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_regime_matches_paper_shape() {
        let bpc = measure_qoda5_bytes_per_coord(1 << 16, 1);
        // QODA5 payload well under 32 bits/coord
        assert!(bpc < 1.2, "bytes/coord {bpc}");
        let b5 = step_time_ms(4, 5.0, false, bpc);
        let b1 = step_time_ms(4, 1.0, false, bpc);
        let q5 = step_time_ms(4, 5.0, true, bpc);
        let q1 = step_time_ms(4, 1.0, true, bpc);
        // baseline degrades as bandwidth drops; QODA5 nearly flat
        assert!(b1 > b5 + 20.0, "{b1} vs {b5}");
        assert!((q1 - q5).abs() < 15.0, "{q1} vs {q5}");
        // speedups in the paper's 1.2-1.6x band
        let s5 = b5 / q5;
        let s1 = b1 / q1;
        assert!(s5 > 1.1 && s5 < 1.6, "{s5}");
        assert!(s1 > s5, "speedup should grow as bandwidth shrinks");
    }

    #[test]
    fn table2_shape_baseline_degrades_qoda_scales() {
        let bpc = measure_qoda5_bytes_per_coord(1 << 16, 1);
        let b4 = step_time_ms(4, 5.0, false, bpc);
        let b12 = step_time_ms(12, 5.0, false, bpc);
        let q4 = step_time_ms(4, 5.0, true, bpc);
        let q12 = step_time_ms(12, 5.0, true, bpc);
        assert!(b12 > b4, "baseline should degrade with K: {b4} -> {b12}");
        assert!(q12 < q4, "QODA should scale with K: {q4} -> {q12}");
        let speedup12 = b12 / q12;
        assert!(speedup12 > 2.0, "12-node speedup {speedup12} (paper: 2.5x)");
    }

    #[test]
    fn flat_topology_reproduces_the_flat_step_time() {
        let bpc = measure_qoda5_bytes_per_coord(1 << 16, 1);
        let flat = TopologySpec::BroadcastAllGather;
        for k in [4usize, 12] {
            for qoda5 in [false, true] {
                let a = step_time_ms(k, 5.0, qoda5, bpc);
                let b = step_time_ms_topo(k, 5.0, qoda5, bpc, &flat);
                assert!((a - b).abs() < 1e-3, "k={k} qoda5={qoda5}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn hierarchical_beats_broadcast_at_scale() {
        // the acceptance regime: under the heterogeneous-link model the
        // two-level topology wins at K >= 12, for the fp32 baseline and
        // for QODA5 alike
        let bpc = measure_qoda5_bytes_per_coord(1 << 16, 1);
        for k in [12usize, 16] {
            let hier = TopologySpec::hierarchical_for(k);
            let flat = TopologySpec::BroadcastAllGather;
            for qoda5 in [false, true] {
                let t_flat = step_time_ms_topo(k, 5.0, qoda5, bpc, &flat);
                let t_hier = step_time_ms_topo(k, 5.0, qoda5, bpc, &hier);
                assert!(
                    t_hier < t_flat,
                    "K={k} qoda5={qoda5}: hier {t_hier} vs flat {t_flat}"
                );
            }
        }
        // and the parameter-server hub collapses under weak scaling
        let ps16 =
            step_time_ms_topo(16, 5.0, false, bpc, &TopologySpec::ParameterServer);
        let flat16 =
            step_time_ms_topo(16, 5.0, false, bpc, &TopologySpec::BroadcastAllGather);
        assert!(ps16 > flat16, "{ps16} vs {flat16}");
    }

    #[test]
    fn overlap_hides_the_table2_comm_and_never_exposes_more_than_sync() {
        // at the paper's weak-scaling points the compute window dwarfs the
        // quantized comm: overlapping hides all of it, for every topology,
        // and the overlapped step never exceeds the synchronous step
        let rows = overlap_sweep(&[4, 8, 12, 16], 5.0, 1);
        for row in &rows {
            assert!(row.comm_exposed_ms <= row.comm_ms + 1e-12, "{:?}", row.topology);
            assert!(
                (row.comm_exposed_ms + row.comm_hidden_ms - row.comm_ms).abs() < 1e-9,
                "split must conserve comm: {:?} K={}",
                row.topology,
                row.k
            );
            assert!(row.overlap_ms <= row.sync_ms + 1e-12);
        }
        // the acceptance regime: at K >= 12 the hidden-communication
        // speedup is real for flat and hierarchical routing
        for row in rows.iter().filter(|r| {
            r.k >= 12 && !matches!(r.topology, TopologySpec::ParameterServer)
        }) {
            assert!(
                row.comm_hidden_ms > 0.9 * row.comm_ms,
                "K={} {:?}: hidden {} of {}",
                row.k,
                row.topology,
                row.comm_hidden_ms,
                row.comm_ms
            );
            assert!(
                row.sync_ms / row.overlap_ms > 1.05,
                "K={} {:?}: {} vs {}",
                row.k,
                row.topology,
                row.sync_ms,
                row.overlap_ms
            );
        }
        // overlap closes the flat-vs-hierarchical gap once comm hides: at
        // K = 16 the synchronous step times differ across those topologies,
        // the overlapped ones agree to the compute+codec floor
        let at16: Vec<&OverlapRow> = rows
            .iter()
            .filter(|r| {
                r.k == 16 && !matches!(r.topology, TopologySpec::ParameterServer)
            })
            .collect();
        assert_eq!(at16.len(), 2);
        let sync_gap = (at16[0].sync_ms - at16[1].sync_ms).abs();
        let overlap_gap = (at16[0].overlap_ms - at16[1].overlap_ms).abs();
        assert!(overlap_gap < 0.1 * sync_gap, "{overlap_gap} vs {sync_gap}");
    }

    #[test]
    fn mqv_improvement_grows_with_heterogeneity() {
        let t = verify_mqv();
        let imp = |row: usize| -> f64 {
            t.rows[row][3].trim_end_matches('x').parse().unwrap()
        };
        // layerwise never loses (Remark 3.2) ...
        for r in 0..3 {
            assert!(imp(r) >= 0.99, "row {r}: {}", imp(r));
        }
        // ... and heterogeneity is where it wins
        assert!(imp(2) > imp(0), "{} vs {}", imp(2), imp(0));
    }
}

// ---------------------------------------------------------------------------
// Design-choice ablations (DESIGN.md: adaptive levels, L-GreCo reallocation,
// coding protocol) — same workload, one knob changed at a time.
// ---------------------------------------------------------------------------

/// Ablation: bits-on-the-wire and quantization error of one gradient stream
/// under (a) static uniform levels, (b) adaptive levels (Eq. 2), (c) full
/// L-GreCo, at a matched ~5-bit budget. The stream is a `StreamSource`
/// driven through the shared `RunDriver` (zero learning rate pins the
/// iterate), so the wire-bit and fidelity numbers come straight off the
/// driver's accounting.
pub fn ablation_table() -> Table {
    use crate::comm::Adaptation;
    let mut t = Table::new(
        "Ablation — adaptation knobs at matched 5-bit budget (400 heterogeneous grads)",
        &["configuration", "bits/coord", "rel. error", "vs static"],
    );
    let map = LayerMap::from_spec(&[
        ("dense.w", 4096, "ff"),
        ("emb.w", 2048, "embedding"),
        ("head.w", 1024, "attention"),
    ]);
    let mk_grad = |rng: &mut Rng| -> Vec<f64> {
        let mut v = Vec::with_capacity(map.dim);
        for i in 0..map.dim {
            let scale = if i < 4096 {
                0.05
            } else if i < 6144 {
                if rng.uniform() < 0.05 { 5.0 } else { 0.01 }
            } else {
                1.0
            };
            v.push(rng.gaussian() * scale);
        }
        v
    };
    let configs: Vec<(&str, Adaptation)> = vec![
        ("static uniform", Adaptation::Fixed),
        ("adaptive levels", Adaptation::Levels { every: 40 }),
        (
            "L-GreCo (levels + alpha realloc)",
            Adaptation::LGreco { every: 40, budget_bits_per_coord: 6.0, max_bits: 6 },
        ),
    ];
    let mut static_bits = 0.0f64;
    let steps = 400;
    for (name, adaptation) in configs {
        let spec =
            CompressionSpec::Quantized { map: map.clone(), bits: 5, adaptation };
        let comp = spec.build(map.dim, ProtocolKind::Main, 9);
        let mut rng = Rng::new(31);
        let mut src = StreamSource::new(map.dim, 1, |_k| mk_grad(&mut rng));
        let mut solver = Qoda::new(
            &mut src,
            vec![comp],
            Box::new(ConstantLr { gamma: 0.0, eta: 0.0 }),
        );
        let run = RunDriver::new().run(&mut solver, &vec![0.0; map.dim], steps);
        let bpc = run.total_bits as f64 / (steps as f64 * map.dim as f64);
        if static_bits == 0.0 {
            static_bits = bpc;
        }
        t.row(&[
            name.to_string(),
            format!("{bpc:.3}"),
            format!("{:.5}", run.rel_quant_error()),
            format!("{:.2}x", static_bits / bpc),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// Adaptive bit-width scheduling (quant::schedule) vs static allocations at
// equal total wire bits.
// ---------------------------------------------------------------------------

/// Measured per-type statistics of a heterogeneous gradient stream:
/// `samples` draws per layer with strongly type-dependent scales (quiet ff
/// weights, spiky embeddings, unit-scale attention), so redistributing bits
/// across layers has something to win. Shared by
/// [`adaptive_schedule_table`], its tier-1 pin and
/// `examples/adaptive_sweep.rs`.
pub fn scheduling_stats(map: &LayerMap, samples: usize, seed: u64) -> Vec<TypeStats> {
    let mut stats: Vec<TypeStats> =
        (0..map.num_types()).map(|_| TypeStats::default()).collect();
    let mut rng = Rng::new(seed);
    for _ in 0..samples {
        for l in &map.layers {
            let v: Vec<f32> = (0..l.len)
                .map(|_| {
                    let scale = match l.type_id % 3 {
                        0 => 0.05,
                        1 => {
                            if rng.uniform() < 0.05 {
                                5.0
                            } else {
                                0.01
                            }
                        }
                        _ => 1.0,
                    };
                    (rng.gaussian() * scale) as f32
                })
                .collect();
            stats[l.type_id].add_layer_sample(&v, 2.0);
        }
    }
    stats
}

/// True cost and weighted error of the uniform width-`b` static allocation
/// on the DP's candidate grid (ladder index `b - 1` in every layer), summed
/// in the DP's own layer order so the comparison is term-for-term.
pub fn static_allocation(problems: &[lgreco::LayerProblem], b: usize) -> (f64, f64) {
    let mut bits = 0.0f64;
    let mut err = 0.0f64;
    for p in problems {
        let c = &p.candidates[(b - 1).min(p.candidates.len() - 1)];
        bits += c.bits * p.size as f64;
        err += c.err * p.size as f64;
    }
    (bits, err)
}

/// The budget that makes the uniform width-`b` choice provably reachable in
/// the DP's ceil-discretized state space: the static allocation's true cost
/// plus the [`lgreco::UNITS`] headroom (each layer's ceil adds less than one
/// unit). At this budget the DP's solved error is a certified lower bound on
/// the static error.
pub fn matched_budget(static_cost: f64, num_layers: usize) -> f64 {
    static_cost * (1.0 + (num_layers + 1) as f64 / lgreco::UNITS as f64)
}

/// Ablation: the scheduled planner ([`schedule::plan`]) vs every static
/// uniform bit width on the same measured statistics, each comparison at
/// the static allocation's own true wire cost (plus only the DP's
/// discretization headroom — under 0.2%). The static choice is inside the
/// DP's reachable set, and the DP minimizes weighted quantization error
/// over that set, so the adaptive row can never lose; heterogeneous layer
/// statistics are where it wins outright.
pub fn adaptive_schedule_table() -> Table {
    let map = LayerMap::from_spec(&[
        ("dense.w", 4096, "ff"),
        ("emb.w", 2048, "embedding"),
        ("head.w", 1024, "attention"),
    ]);
    let stats = scheduling_stats(&map, 8, 31);
    let max_bits = 6u32;
    let ladder = lgreco::alpha_ladder(max_bits);
    let problems = schedule::type_problems(&map, &stats, &ladder);
    let mut t = Table::new(
        "Adaptive schedule vs static uniform widths (equal total wire bits)",
        &[
            "static width",
            "bits/coord",
            "static err",
            "adaptive bits/coord",
            "adaptive err",
            "err ratio",
        ],
    );
    for b in 1..=max_bits as usize {
        let (cost, err) = static_allocation(&problems, b);
        let budget = matched_budget(cost, problems.len());
        let plan = schedule::plan(&map, &stats, budget / map.dim as f64, max_bits);
        let ratio = if plan.total_err > 0.0 { err / plan.total_err } else { 1.0 };
        t.row(&[
            format!("{b}-bit"),
            format!("{:.3}", cost / map.dim as f64),
            format!("{err:.5}"),
            format!("{:.3}", plan.bits_per_coord(map.dim)),
            format!("{:.5}", plan.total_err),
            format!("{ratio:.3}x"),
        ]);
    }
    t
}

#[cfg(test)]
mod schedule_pins {
    use super::*;

    /// The ablation's acceptance bar, as a proof rather than a benchmark:
    /// for every static uniform width, grant the planner the static
    /// allocation's true cost plus only the DP's ceil-discretization
    /// headroom ([`matched_budget`]). The uniform choice is then reachable
    /// in the DP's state space, the DP minimizes weighted error over the
    /// reachable set, so the scheduled plan can never have higher error —
    /// and never exceeds the granted budget.
    #[test]
    fn adaptive_never_loses_to_any_static_at_equal_budget() {
        let map = LayerMap::from_spec(&[
            ("dense.w", 4096, "ff"),
            ("emb.w", 2048, "embedding"),
            ("head.w", 1024, "attention"),
        ]);
        for seed in [31u64, 77, 123] {
            let stats = scheduling_stats(&map, 8, seed);
            let ladder = lgreco::alpha_ladder(6);
            let problems = schedule::type_problems(&map, &stats, &ladder);
            let mut strict_win = false;
            for b in 1..=6usize {
                let (cost, err) = static_allocation(&problems, b);
                let budget = matched_budget(cost, problems.len());
                let plan = schedule::plan(&map, &stats, budget / map.dim as f64, 6);
                assert!(
                    plan.total_bits <= budget,
                    "seed {seed} b={b}: {} bits over budget {budget}",
                    plan.total_bits
                );
                assert!(
                    plan.total_err <= err * (1.0 + 1e-12),
                    "seed {seed} b={b}: adaptive err {} vs static {err}",
                    plan.total_err
                );
                if plan.total_err < err * (1.0 - 1e-9) {
                    strict_win = true;
                }
            }
            // heterogeneous per-type scales: at least one width must be
            // beaten outright, not just matched
            assert!(strict_win, "seed {seed}: adaptive never improved on static");
        }
    }

    /// The table renders one row per static width without panicking and the
    /// shared stats helper is deterministic (the schedule layer's contract).
    #[test]
    fn adaptive_schedule_table_is_deterministic() {
        let a = format!("{:?}", adaptive_schedule_table());
        let b = format!("{:?}", adaptive_schedule_table());
        assert_eq!(a, b);
    }
}

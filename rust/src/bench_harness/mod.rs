//! Micro-benchmark harness (offline environment: no criterion). Benches are
//! `harness = false` binaries that use `bench()` below and print
//! criterion-style lines; `cargo bench` runs them all.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub iters: u64,
    /// optional throughput denominator (elements per iteration)
    pub elems: Option<u64>,
}

impl BenchResult {
    pub fn report(&self) {
        let per = self.mean_ns;
        let (val, unit) = if per >= 1e9 {
            (per / 1e9, "s")
        } else if per >= 1e6 {
            (per / 1e6, "ms")
        } else if per >= 1e3 {
            (per / 1e3, "us")
        } else {
            (per, "ns")
        };
        let thr = self
            .elems
            .map(|e| {
                let per_sec = e as f64 / (per / 1e9);
                if per_sec >= 1e9 {
                    format!("  thrpt: {:.3} Gelem/s", per_sec / 1e9)
                } else {
                    format!("  thrpt: {:.3} Melem/s", per_sec / 1e6)
                }
            })
            .unwrap_or_default();
        println!(
            "{:<46} time: [{:.3} {unit} ± {:.3} {unit}] ({} iters){}",
            self.name,
            val,
            self.stddev_ns / per.max(1e-12) * val,
            self.iters,
            thr
        );
    }
}

/// Run `f` until ~`target_ms` of samples are collected (after warmup).
pub fn bench<T>(name: &str, elems: Option<u64>, mut f: impl FnMut() -> T) -> BenchResult {
    // warmup
    let warm_t0 = Instant::now();
    let mut warm_iters = 0u64;
    while warm_t0.elapsed().as_millis() < 50 || warm_iters < 2 {
        std::hint::black_box(f());
        warm_iters += 1;
    }
    // calibrate iteration count for ~400 ms of measurement
    let t0 = Instant::now();
    std::hint::black_box(f());
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((0.4 / once) as u64).clamp(3, 1_000_000);
    let mut samples = Vec::with_capacity((iters as usize).min(1000));
    let chunk = (iters / 20).max(1);
    let mut done = 0;
    while done < iters {
        let n = chunk.min(iters - done);
        let t = Instant::now();
        for _ in 0..n {
            std::hint::black_box(f());
        }
        samples.push(t.elapsed().as_secs_f64() / n as f64 * 1e9);
        done += n;
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>()
        / samples.len() as f64;
    let res = BenchResult {
        name: name.to_string(),
        mean_ns: mean,
        stddev_ns: var.sqrt(),
        iters,
        elems,
    };
    res.report();
    res
}

pub mod experiments;
pub mod model_experiments;

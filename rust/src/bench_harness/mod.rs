//! Micro-benchmark harness (offline environment: no criterion). Benches are
//! `harness = false` binaries that use `bench()` below and print
//! criterion-style lines; `cargo bench` runs them all.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub iters: u64,
    /// optional throughput denominator (elements per iteration)
    pub elems: Option<u64>,
}

impl BenchResult {
    pub fn report(&self) {
        let per = self.mean_ns;
        let (val, unit) = if per >= 1e9 {
            (per / 1e9, "s")
        } else if per >= 1e6 {
            (per / 1e6, "ms")
        } else if per >= 1e3 {
            (per / 1e3, "us")
        } else {
            (per, "ns")
        };
        let thr = self
            .elems
            .map(|e| {
                let per_sec = e as f64 / (per / 1e9);
                if per_sec >= 1e9 {
                    format!("  thrpt: {:.3} Gelem/s", per_sec / 1e9)
                } else {
                    format!("  thrpt: {:.3} Melem/s", per_sec / 1e6)
                }
            })
            .unwrap_or_default();
        println!(
            "{:<46} time: [{:.3} {unit} ± {:.3} {unit}] ({} iters){}",
            self.name,
            val,
            self.stddev_ns / per.max(1e-12) * val,
            self.iters,
            thr
        );
    }
}

/// Run `f` until ~`target_ms` of samples are collected (after warmup).
pub fn bench<T>(name: &str, elems: Option<u64>, mut f: impl FnMut() -> T) -> BenchResult {
    // warmup
    let warm_t0 = Instant::now();
    let mut warm_iters = 0u64;
    while warm_t0.elapsed().as_millis() < 50 || warm_iters < 2 {
        std::hint::black_box(f());
        warm_iters += 1;
    }
    // calibrate iteration count for ~400 ms of measurement
    let t0 = Instant::now();
    std::hint::black_box(f());
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((0.4 / once) as u64).clamp(3, 1_000_000);
    let mut samples = Vec::with_capacity((iters as usize).min(1000));
    let chunk = (iters / 20).max(1);
    let mut done = 0;
    while done < iters {
        let n = chunk.min(iters - done);
        let t = Instant::now();
        for _ in 0..n {
            std::hint::black_box(f());
        }
        samples.push(t.elapsed().as_secs_f64() / n as f64 * 1e9);
        done += n;
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>()
        / samples.len() as f64;
    let res = BenchResult {
        name: name.to_string(),
        mean_ns: mean,
        stddev_ns: var.sqrt(),
        iters,
        elems,
    };
    res.report();
    res
}

/// Machine-readable bench sink: collects named records and writes them as a
/// JSON array under `results/` (hand-rolled — the environment is offline,
/// no serde). The comm benches emit `BENCH_comm.json` through this so CI
/// and regression tooling can diff ns/step + bytes/step per topology
/// without scraping stdout.
#[derive(Default)]
pub struct JsonBench {
    entries: Vec<String>,
}

impl JsonBench {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one record; `fields` are (key, already-JSON-encoded value)
    /// pairs appended after `"name"`. The name is JSON-escaped.
    pub fn push(&mut self, name: &str, fields: &[(&str, String)]) {
        let escaped: String = name
            .chars()
            .flat_map(|c| match c {
                '\\' => "\\\\".chars().collect::<Vec<_>>(),
                '"' => "\\\"".chars().collect(),
                c if (c as u32) < 0x20 => {
                    format!("\\u{:04x}", c as u32).chars().collect()
                }
                c => vec![c],
            })
            .collect();
        let mut obj = format!("{{\"name\":\"{escaped}\"");
        for (k, v) in fields {
            obj.push_str(&format!(",\"{k}\":{v}"));
        }
        obj.push('}');
        self.entries.push(obj);
    }

    /// Convenience for the common (ns/step, bytes/step) record shape.
    pub fn push_perf(&mut self, name: &str, ns_per_step: f64, bytes_per_step: f64) {
        self.push(
            name,
            &[
                ("ns_per_step", format!("{ns_per_step:.1}")),
                ("bytes_per_step", format!("{bytes_per_step:.1}")),
            ],
        );
    }

    pub fn to_json(&self) -> String {
        format!("[\n  {}\n]\n", self.entries.join(",\n  "))
    }

    /// Write under `results/` (created on demand); returns the path.
    /// Overwrites the whole file — see [`Self::save_merged`] when several
    /// bench binaries share one result file.
    pub fn save(&self, name: &str) -> std::io::Result<std::path::PathBuf> {
        let dir = crate::util::repo_path("results");
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(name);
        std::fs::write(&path, self.to_json())?;
        Ok(path)
    }

    /// Merge-write under `results/`: records already in the file keep their
    /// place unless this run produced a record of the same name, which
    /// replaces them; this run's new records append. Lets the comm benches
    /// (`comm_pipeline`, `quantize`, `topology_comm`) share one committed
    /// `BENCH_comm.json` without clobbering each other's sections.
    pub fn save_merged(&self, name: &str) -> std::io::Result<std::path::PathBuf> {
        let dir = crate::util::repo_path("results");
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(name);
        let mut merged: Vec<String> = Vec::new();
        if let Ok(old) = std::fs::read_to_string(&path) {
            for e in parse_entries(&old) {
                let keep = match entry_name(&e) {
                    Some(n) => !self.entries.iter().any(|m| entry_name(m) == Some(n)),
                    None => true,
                };
                if keep {
                    merged.push(e);
                }
            }
        }
        merged.extend(self.entries.iter().cloned());
        let body = if merged.is_empty() {
            "[]\n".to_string()
        } else {
            format!("[\n  {}\n]\n", merged.join(",\n  "))
        };
        std::fs::write(&path, body)?;
        Ok(path)
    }
}

/// The (escaped) `"name"` field of a rendered record, as emitted by
/// [`JsonBench::push`] — every record starts with it.
fn entry_name(entry: &str) -> Option<&str> {
    let rest = entry.strip_prefix("{\"name\":\"")?;
    let bytes = rest.as_bytes();
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'\\' => i += 2,
            b'"' => return Some(&rest[..i]),
            _ => i += 1,
        }
    }
    None
}

/// Split a JSON array of flat objects (the shape `to_json` writes) back
/// into rendered entries. A string-aware brace scanner — sufficient for
/// this sink's output, not a general JSON parser.
fn parse_entries(json: &str) -> Vec<String> {
    let bytes = json.as_bytes();
    let mut out = Vec::new();
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == b'{' {
            let start = i;
            let mut depth = 0usize;
            let mut in_str = false;
            while i < bytes.len() {
                let c = bytes[i];
                if in_str {
                    match c {
                        b'\\' => i += 1,
                        b'"' => in_str = false,
                        _ => {}
                    }
                } else {
                    match c {
                        b'"' => in_str = true,
                        b'{' => depth += 1,
                        b'}' => {
                            depth -= 1;
                            if depth == 0 {
                                out.push(json[start..=i].to_string());
                                break;
                            }
                        }
                        _ => {}
                    }
                }
                i += 1;
            }
        }
        i += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_bench_renders_valid_records() {
        let mut j = JsonBench::new();
        j.push_perf("comm/flat", 1234.5, 8192.0);
        j.push(
            "comm/hier",
            &[("ns_per_step", "10.0".into()), ("k", "8".into())],
        );
        let s = j.to_json();
        assert!(s.starts_with("[\n"));
        assert!(s.contains("{\"name\":\"comm/flat\",\"ns_per_step\":1234.5,\"bytes_per_step\":8192.0}"));
        assert!(s.contains("\"k\":8"));
        assert!(s.trim_end().ends_with(']'));
    }

    #[test]
    fn entries_roundtrip_through_the_parser() {
        let mut j = JsonBench::new();
        j.push_perf("a/b", 1.0, 2.0);
        j.push("weird \"name\"", &[("x", "1".into())]);
        let parsed = parse_entries(&j.to_json());
        assert_eq!(parsed, j.entries);
        assert_eq!(entry_name(&parsed[0]), Some("a/b"));
        assert_eq!(entry_name(&parsed[1]), Some("weird \\\"name\\\""));
    }

    #[test]
    fn merge_replaces_same_name_and_keeps_the_rest() {
        let mut old = JsonBench::new();
        old.push_perf("keep/me", 1.0, 1.0);
        old.push_perf("replace/me", 100.0, 1.0);
        let mut new = JsonBench::new();
        new.push_perf("replace/me", 5.0, 1.0);
        new.push_perf("brand/new", 7.0, 1.0);
        // simulate the merge in memory (save_merged does the same via disk)
        let mut merged: Vec<String> = parse_entries(&old.to_json())
            .into_iter()
            .filter(|e| {
                !new.entries.iter().any(|m| entry_name(m) == entry_name(e))
            })
            .collect();
        merged.extend(new.entries.iter().cloned());
        let names: Vec<_> = merged.iter().filter_map(|e| entry_name(e)).collect();
        assert_eq!(names, ["keep/me", "replace/me", "brand/new"]);
        assert!(merged[1].contains("\"ns_per_step\":5.0"));
    }
}

pub mod experiments;
pub mod model_experiments;

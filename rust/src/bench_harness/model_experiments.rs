//! PJRT-backed experiment harnesses: Figure 4 (WGAN FID curves), Table 3
//! (Transformer compression rates at matched perplexity) and Figure 5
//! (per-layer-type quantization ablation).

use crate::gan::trainer::{self as gan_trainer, GanCompression, GanOptimizer, GanTrainConfig};
use crate::util::error::Result;
use crate::lm::trainer::{self as lm_trainer, LmTrainConfig, QuantTarget};
use crate::runtime::{LmModel, Runtime, WganModel};
use crate::util::table::Table;

/// Figure 4: FID evolution for Adam vs QODA+global vs QODA+layerwise.
/// Returns (rows for CSV: step, adam, global, layerwise averaged over seeds).
pub fn fig4(steps: usize, seeds: &[u64]) -> Result<(Table, Vec<Vec<f64>>)> {
    let rt = Runtime::cpu()?;
    let model = WganModel::load(&rt)?;
    let configs: Vec<(&str, GanOptimizer, GanCompression)> = vec![
        ("Adam", GanOptimizer::Adam, GanCompression::None),
        (
            "QODA+global(Q-GenX)",
            GanOptimizer::OptimisticAdam,
            GanCompression::Global { bits: 5, bucket: 128 },
        ),
        (
            "QODA+layerwise(L-GreCo)",
            GanOptimizer::OptimisticAdam,
            GanCompression::LayerwiseLGreco { bits: 5, bucket: 128, every: 50 },
        ),
    ];
    let fid_every = (steps / 12).max(5);
    // curves[c] = averaged fid at each checkpoint
    let mut curves: Vec<Vec<f64>> = Vec::new();
    let mut checkpoints: Vec<usize> = Vec::new();
    let mut summary = Table::new(
        "Figure 4 — final FID after training (mean over seeds)",
        &["config", "final FID", "mean step ms", "MB/step/node"],
    );
    for (name, opt, comp) in &configs {
        let mut acc: Vec<f64> = Vec::new();
        let mut final_fid = 0.0;
        let mut step_ms = 0.0;
        let mut mb = 0.0;
        for &seed in seeds {
            let cfg = GanTrainConfig {
                optimizer: *opt,
                compression: *comp,
                steps,
                fid_every,
                seed,
                ..Default::default()
            };
            let run = gan_trainer::train(&model, &cfg)?;
            if acc.is_empty() {
                acc = vec![0.0; run.fid_curve.len()];
                checkpoints = run.fid_curve.iter().map(|&(s, _)| s).collect();
            }
            for (a, &(_, f)) in acc.iter_mut().zip(&run.fid_curve) {
                *a += f / seeds.len() as f64;
            }
            final_fid += run.final_fid / seeds.len() as f64;
            step_ms += run.metrics.mean_step_ms() / seeds.len() as f64;
            mb += run.metrics.steps.iter().map(|m| m.bytes_per_node).sum::<f64>()
                / run.metrics.steps.len() as f64
                / 1e6
                / seeds.len() as f64;
        }
        summary.row(&[
            name.to_string(),
            format!("{final_fid:.4}"),
            format!("{step_ms:.1}"),
            format!("{mb:.4}"),
        ]);
        curves.push(acc);
    }
    // CSV rows: step, adam, global, layerwise
    let rows: Vec<Vec<f64>> = checkpoints
        .iter()
        .enumerate()
        .map(|(i, &s)| {
            let mut r = vec![s as f64];
            for c in &curves {
                r.push(c.get(i).copied().unwrap_or(f64::NAN));
            }
            r
        })
        .collect();
    Ok((summary, rows))
}

/// Table 3: PowerSGD rank x {global, layerwise} — test ppl + compression.
pub fn table3(steps: usize, ranks: &[usize], seeds: &[u64]) -> Result<Table> {
    let rt = Runtime::cpu()?;
    let model = LmModel::load(&rt)?;
    let mut t = Table::new(
        "Table 3 — layer-wise vs global quantization for the transformer LM",
        &["rank", "quantization", "test ppl", "ppl std", "compression rate", "vs global"],
    );
    // uncompressed baseline
    {
        let (mean_ppl, std_ppl, rate) = run_lm_avg(
            &model,
            seeds,
            &LmTrainConfig {
                rank: 0,
                quant_bits: None,
                layerwise: false,
                steps,
                ..Default::default()
            },
        )?;
        t.row(&[
            "-".into(),
            "baseline".into(),
            format!("{mean_ppl:.2}"),
            format!("{std_ppl:.2}"),
            format!("{rate:.2}"),
            "-".into(),
        ]);
    }
    for &rank in ranks {
        let mut global_rate = 0.0;
        for (layerwise, name) in [(false, "global"), (true, "layerwise")] {
            let cfg = LmTrainConfig {
                rank,
                quant_bits: Some(4),
                layerwise,
                steps,
                ..Default::default()
            };
            let (mean_ppl, std_ppl, rate) = run_lm_avg(&model, seeds, &cfg)?;
            if !layerwise {
                global_rate = rate;
            }
            let rel = if layerwise && global_rate > 0.0 {
                format!("[{:.2}x]", rate / global_rate)
            } else {
                "-".into()
            };
            t.row(&[
                format!("{rank}"),
                name.into(),
                format!("{mean_ppl:.2}"),
                format!("{std_ppl:.2}"),
                format!("{rate:.2}"),
                rel,
            ]);
        }
    }
    Ok(t)
}

fn run_lm_avg(
    model: &LmModel,
    seeds: &[u64],
    cfg: &LmTrainConfig,
) -> Result<(f64, f64, f64)> {
    let mut ppls = Vec::new();
    let mut rate = 0.0;
    for &seed in seeds {
        let mut c = cfg.clone();
        c.seed = seed;
        let run = lm_trainer::train(model, &c)?;
        ppls.push(run.final_ppl);
        rate += run.compression_rate / seeds.len() as f64;
    }
    let mean = crate::util::mean(&ppls);
    let std = crate::util::stddev(&ppls);
    Ok((mean, std, rate))
}

/// Figure 5: quantize ONLY one layer type at various bit widths and report
/// the perplexity degradation (embedding should hurt most).
pub fn fig5(steps: usize, seeds: &[u64]) -> Result<Table> {
    let rt = Runtime::cpu()?;
    let model = LmModel::load(&rt)?;
    let mut t = Table::new(
        "Figure 5 — ablation: quantizing a single layer type (PowerSGD rank 16)",
        &["quantized type", "bits", "test ppl", "ppl std", "compression rate"],
    );
    // unquantized reference
    {
        let (ppl, std, rate) = run_lm_avg(
            &model,
            seeds,
            &LmTrainConfig {
                rank: 16,
                quant_bits: None,
                layerwise: false,
                steps,
                ..Default::default()
            },
        )?;
        t.row(&[
            "none".into(),
            "-".into(),
            format!("{ppl:.2}"),
            format!("{std:.2}"),
            format!("{rate:.2}"),
        ]);
    }
    for ty in ["ff", "embedding", "attention"] {
        for bits in [2u32, 4] {
            let cfg = LmTrainConfig {
                rank: 16,
                quant_bits: Some(bits),
                layerwise: false,
                target: QuantTarget::OnlyType(match ty {
                    "ff" => "ff",
                    "embedding" => "embedding",
                    _ => "attention",
                }),
                steps,
                ..Default::default()
            };
            let (ppl, std, rate) = run_lm_avg(&model, seeds, &cfg)?;
            t.row(&[
                ty.into(),
                format!("{bits}"),
                format!("{ppl:.2}"),
                format!("{std:.2}"),
                format!("{rate:.2}"),
            ]);
        }
    }
    Ok(t)
}

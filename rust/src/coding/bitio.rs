//! Bit-level I/O for the wire protocols. LSB-first within each u64 word.

/// Append-only bit writer.
#[derive(Clone, Debug, Default)]
pub struct BitWriter {
    words: Vec<u64>,
    /// total bits written
    bits: usize,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity_bits(bits: usize) -> Self {
        BitWriter { words: Vec::with_capacity(bits.div_ceil(64)), bits: 0 }
    }

    /// Total number of bits written.
    #[inline]
    pub fn len_bits(&self) -> usize {
        self.bits
    }

    pub fn len_bytes(&self) -> usize {
        self.len_bits().div_ceil(8)
    }

    /// Write the low `n` bits of `value` (n <= 64).
    #[inline]
    pub fn write_bits(&mut self, value: u64, n: u32) {
        debug_assert!(n <= 64);
        if n == 0 {
            return;
        }
        let value = if n == 64 { value } else { value & ((1u64 << n) - 1) };
        let off = (self.bits % 64) as u32;
        if off == 0 {
            self.words.push(0);
        }
        let last = self.words.len() - 1;
        self.words[last] |= value << off;
        // spill into a fresh word when the write crosses the boundary
        // (off > 0 guaranteed there, so the shift amount is in 1..=63)
        if n > 64 - off {
            self.words.push(value >> (64 - off));
        }
        self.bits += n as usize;
    }

    #[inline]
    pub fn write_bit(&mut self, b: bool) {
        self.write_bits(b as u64, 1);
    }

    /// Write an f32 as its 32 raw bits (the norm header, C_q = 32).
    pub fn write_f32(&mut self, x: f32) {
        self.write_bits(x.to_bits() as u64, 32);
    }

    pub fn write_f64(&mut self, x: f64) {
        self.write_bits(x.to_bits(), 64);
    }

    pub fn finish(self) -> BitBuf {
        let bits = self.len_bits();
        BitBuf { words: self.words, bits }
    }

    /// Reset to empty, keeping the allocation.
    pub fn clear(&mut self) {
        self.words.clear();
        self.bits = 0;
    }

    /// Move the written bits into `buf` (reusing `buf`'s allocation for the
    /// next round); the writer is left empty with `buf`'s old capacity.
    pub fn finish_into(&mut self, buf: &mut BitBuf) {
        std::mem::swap(&mut self.words, &mut buf.words);
        buf.bits = self.bits;
        self.words.clear();
        self.bits = 0;
    }

    /// Append a finished buffer bit-for-bit (stream concatenation — used by
    /// the per-layer parallel encoder to splice chunk streams in order).
    pub fn append(&mut self, buf: &BitBuf) {
        let mut left = buf.bits;
        let mut i = 0;
        while left > 0 {
            let n = left.min(64) as u32;
            self.write_bits(buf.words[i], n);
            left -= n as usize;
            i += 1;
        }
    }
}

/// Finished bit buffer.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BitBuf {
    words: Vec<u64>,
    bits: usize,
}

impl BitBuf {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len_bits(&self) -> usize {
        self.bits
    }

    pub fn len_bytes(&self) -> usize {
        self.bits.div_ceil(8)
    }

    pub fn reader(&self) -> BitReader<'_> {
        BitReader { words: &self.words, pos: 0, bits: self.bits }
    }

    /// Hand this buffer's allocation to `w` for reuse and leave the buffer
    /// empty (the scratch-recycling counterpart of `finish_into`).
    pub fn recycle_into(&mut self, w: &mut BitWriter) {
        std::mem::swap(&mut self.words, &mut w.words);
        self.words.clear();
        self.bits = 0;
        w.words.clear();
        w.bits = 0;
    }

    /// The backing 64-bit words (bit 0 of the stream is the LSB of word 0).
    /// A transport serializing the buffer ships these little-endian plus
    /// `len_bits`; [`BitBuf::from_words`] reconstructs on the far side.
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Reassemble a buffer from its backing words and exact bit count (the
    /// wire-transport counterpart of [`BitBuf::words`]). Returns `None`
    /// when the word count does not match the bit count — a framing error,
    /// not a panic.
    pub fn from_words(words: Vec<u64>, bits: usize) -> Option<BitBuf> {
        if words.len() == bits.div_ceil(64) {
            Some(BitBuf { words, bits })
        } else {
            None
        }
    }
}

/// Sequential bit reader.
#[derive(Clone, Debug)]
pub struct BitReader<'a> {
    words: &'a [u64],
    pos: usize,
    bits: usize,
}

impl<'a> BitReader<'a> {
    #[inline]
    pub fn remaining(&self) -> usize {
        self.bits - self.pos
    }

    /// Current bit position (for decode-error reporting).
    #[inline]
    pub fn bit_pos(&self) -> usize {
        self.pos
    }

    /// Checked read for untrusted (wire) streams: `None` past the end.
    #[inline]
    pub fn try_read_bits(&mut self, n: u32) -> Option<u64> {
        if n as usize > self.remaining() {
            None
        } else {
            Some(self.read_bits(n))
        }
    }

    /// Read `n` bits (n <= 64); panics past the end (protocol bugs are bugs).
    #[inline]
    pub fn read_bits(&mut self, n: u32) -> u64 {
        debug_assert!(n as usize <= self.remaining(), "bit underrun");
        if n == 0 {
            return 0;
        }
        let word = self.pos / 64;
        let off = (self.pos % 64) as u32;
        let avail = 64 - off;
        let out = if n <= avail {
            let v = self.words[word] >> off;
            if n == 64 {
                v
            } else {
                v & ((1u64 << n) - 1)
            }
        } else {
            let lo = self.words[word] >> off;
            let hi = self.words[word + 1] & ((1u64 << (n - avail)) - 1);
            lo | (hi << avail)
        };
        self.pos += n as usize;
        out
    }

    #[inline]
    pub fn read_bit(&mut self) -> bool {
        self.read_bits(1) == 1
    }

    pub fn read_f32(&mut self) -> f32 {
        f32::from_bits(self.read_bits(32) as u32)
    }

    pub fn read_f64(&mut self) -> f64 {
        f64::from_bits(self.read_bits(64))
    }

    /// Peek up to 32 bits without consuming (short reads near the end are
    /// zero-padded) — used by the table-driven Huffman decoder.
    #[inline]
    pub fn peek_bits(&self, n: u32) -> u64 {
        debug_assert!(n <= 32);
        let word = self.pos / 64;
        let off = (self.pos % 64) as u32;
        // fast path: the n bits live in one word and inside the stream
        if off + n <= 64 && self.pos + n as usize <= self.bits {
            let mask = if n == 0 { 0 } else { (1u64 << n) - 1 };
            return (self.words[word] >> off) & mask;
        }
        self.peek_bits_slow(n)
    }

    #[cold]
    fn peek_bits_slow(&self, n: u32) -> u64 {
        let mut out = 0u64;
        let mut got = 0u32;
        let take = (n as usize).min(self.remaining()) as u32;
        let mut pos = self.pos;
        while got < take {
            let word = pos / 64;
            let off = (pos % 64) as u32;
            let avail = (64 - off).min(take - got);
            let v = (self.words[word] >> off)
                & if avail == 64 { u64::MAX } else { (1u64 << avail) - 1 };
            out |= v << got;
            got += avail;
            pos += avail as usize;
        }
        out
    }

    #[inline]
    pub fn skip(&mut self, n: u32) {
        self.pos += n as usize;
        debug_assert!(self.pos <= self.bits);
    }

    /// Move the cursor back `n` bits (n must not exceed the bits already
    /// consumed). The batched decoder uses this to return its unconsumed
    /// local cache to the stream before falling back to the bit-by-bit
    /// slow path, so both paths observe identical positions.
    #[inline]
    pub fn rewind(&mut self, n: usize) {
        debug_assert!(n <= self.pos, "rewind past start");
        self.pos -= n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::for_cases;

    #[test]
    fn roundtrip_simple() {
        let mut w = BitWriter::new();
        w.write_bits(0b101, 3);
        w.write_bits(0xFF, 8);
        w.write_bit(true);
        let buf = w.finish();
        assert_eq!(buf.len_bits(), 12);
        let mut r = buf.reader();
        assert_eq!(r.read_bits(3), 0b101);
        assert_eq!(r.read_bits(8), 0xFF);
        assert!(r.read_bit());
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn word_boundary_crossing() {
        let mut w = BitWriter::new();
        w.write_bits(u64::MAX, 60);
        w.write_bits(0b1010, 4);
        w.write_bits(0xDEADBEEF, 32);
        let buf = w.finish();
        let mut r = buf.reader();
        assert_eq!(r.read_bits(60), u64::MAX >> 4);
        assert_eq!(r.read_bits(4), 0b1010);
        assert_eq!(r.read_bits(32), 0xDEADBEEF);
    }

    #[test]
    fn f32_roundtrip() {
        let mut w = BitWriter::new();
        w.write_bit(true); // misalign
        w.write_f32(3.14159);
        w.write_f32(-0.0);
        let buf = w.finish();
        let mut r = buf.reader();
        r.read_bit();
        assert_eq!(r.read_f32(), 3.14159f32);
        assert_eq!(r.read_f32().to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn full_64bit_writes() {
        let mut w = BitWriter::new();
        w.write_bits(0x0123456789ABCDEF, 64);
        w.write_bits(0xFEDCBA9876543210, 64);
        let buf = w.finish();
        assert_eq!(buf.len_bits(), 128);
        let mut r = buf.reader();
        assert_eq!(r.read_bits(64), 0x0123456789ABCDEF);
        assert_eq!(r.read_bits(64), 0xFEDCBA9876543210);
    }

    #[test]
    fn peek_does_not_consume() {
        let mut w = BitWriter::new();
        w.write_bits(0b110101, 6);
        let buf = w.finish();
        let mut r = buf.reader();
        assert_eq!(r.peek_bits(4), 0b0101);
        assert_eq!(r.read_bits(6), 0b110101);
    }

    #[test]
    fn peek_past_end_zero_pads() {
        let mut w = BitWriter::new();
        w.write_bits(0b11, 2);
        let buf = w.finish();
        let r = buf.reader();
        assert_eq!(r.peek_bits(8), 0b11);
    }

    #[test]
    fn append_concatenates_streams() {
        let mut a = BitWriter::new();
        a.write_bits(0b101, 3);
        let mut b = BitWriter::new();
        b.write_bits(0xABCD, 16);
        b.write_bits(0xFFFF_FFFF_FFFF_FFFF, 64);
        let bb = b.finish();
        a.append(&bb);
        let buf = a.finish();
        assert_eq!(buf.len_bits(), 3 + 16 + 64);
        let mut r = buf.reader();
        assert_eq!(r.read_bits(3), 0b101);
        assert_eq!(r.read_bits(16), 0xABCD);
        assert_eq!(r.read_bits(64), u64::MAX);
    }

    #[test]
    fn finish_into_and_recycle_reuse_buffers() {
        let mut w = BitWriter::new();
        let mut buf = BitBuf::new();
        for round in 0..3u64 {
            buf.recycle_into(&mut w);
            w.write_bits(round, 7);
            w.write_f32(round as f32);
            w.finish_into(&mut buf);
            assert_eq!(buf.len_bits(), 39);
            let mut r = buf.reader();
            assert_eq!(r.read_bits(7), round);
            assert_eq!(r.read_f32(), round as f32);
        }
    }

    #[test]
    fn try_read_bits_checks_bounds() {
        let mut w = BitWriter::new();
        w.write_bits(0b11, 2);
        let buf = w.finish();
        let mut r = buf.reader();
        assert_eq!(r.try_read_bits(3), None);
        assert_eq!(r.try_read_bits(2), Some(0b11));
        assert_eq!(r.try_read_bits(1), None);
    }

    #[test]
    fn prop_random_chunks_roundtrip() {
        for_cases(60, 21, |g| {
            let n = g.usize_in(1, 200);
            let chunks: Vec<(u64, u32)> = (0..n)
                .map(|_| {
                    let bits = g.usize_in(1, 64) as u32;
                    let v = g.rng.next_u64()
                        & if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 };
                    (v, bits)
                })
                .collect();
            let mut w = BitWriter::new();
            for &(v, b) in &chunks {
                w.write_bits(v, b);
            }
            let buf = w.finish();
            assert_eq!(
                buf.len_bits(),
                chunks.iter().map(|&(_, b)| b as usize).sum::<usize>()
            );
            let mut r = buf.reader();
            for &(v, b) in &chunks {
                assert_eq!(r.read_bits(b), v, "chunk of {b} bits");
            }
        });
    }
}

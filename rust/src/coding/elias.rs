//! Elias universal integer codes (Elias, 1975) — gamma, delta, and the
//! recursive (omega) code the paper calls "Elias recursive coding (ERC)"
//! (Appendix D.3: the prefix code of choice when only "smaller values are
//! more frequent" is known, without a full distribution estimate).
//!
//! All codes here encode n >= 1; the protocols map level indices i >= 0 via
//! n = i + 1.

use super::bitio::{BitReader, BitWriter};

/// Elias gamma: unary length prefix + binary remainder. |gamma(n)| =
/// 2*floor(log2 n) + 1.
pub fn gamma_encode(w: &mut BitWriter, n: u64) {
    assert!(n >= 1);
    let nbits = 64 - n.leading_zeros(); // position of MSB, >= 1
    // (nbits - 1) zeros, then the number MSB-first
    w.write_bits(0, nbits - 1);
    // write MSB-first: bit (nbits-1) down to 0
    for i in (0..nbits).rev() {
        w.write_bit((n >> i) & 1 == 1);
    }
}

pub fn gamma_decode(r: &mut BitReader) -> u64 {
    let mut zeros = 0u32;
    while !r.read_bit() {
        zeros += 1;
        assert!(zeros < 64, "corrupt gamma code");
    }
    let mut n = 1u64;
    for _ in 0..zeros {
        n = (n << 1) | r.read_bit() as u64;
    }
    n
}

/// Elias delta: gamma-coded length + remainder. |delta(n)| =
/// floor(log2 n) + 2*floor(log2(floor(log2 n)+1)) + 1 — asymptotically
/// shorter than gamma.
pub fn delta_encode(w: &mut BitWriter, n: u64) {
    assert!(n >= 1);
    let nbits = 64 - n.leading_zeros();
    gamma_encode(w, nbits as u64);
    for i in (0..nbits.saturating_sub(1)).rev() {
        w.write_bit((n >> i) & 1 == 1);
    }
}

pub fn delta_decode(r: &mut BitReader) -> u64 {
    let nbits = gamma_decode(r) as u32;
    let mut n = 1u64;
    for _ in 0..nbits - 1 {
        n = (n << 1) | r.read_bit() as u64;
    }
    n
}

/// Elias omega ("recursive"): recursively length-prefixed groups, terminated
/// by a 0 bit.
pub fn omega_encode(w: &mut BitWriter, n: u64) {
    assert!(n >= 1);
    // build groups in reverse
    let mut groups: Vec<u64> = Vec::new();
    let mut k = n;
    while k > 1 {
        groups.push(k);
        let nbits = 64 - k.leading_zeros();
        k = (nbits - 1) as u64;
    }
    for &g in groups.iter().rev() {
        let nbits = 64 - g.leading_zeros();
        for i in (0..nbits).rev() {
            w.write_bit((g >> i) & 1 == 1);
        }
    }
    w.write_bit(false);
}

pub fn omega_decode(r: &mut BitReader) -> u64 {
    let mut n = 1u64;
    loop {
        if !r.read_bit() {
            return n;
        }
        // group of n more bits, MSB already read as 1
        let mut v = 1u64;
        for _ in 0..n {
            v = (v << 1) | r.read_bit() as u64;
        }
        n = v;
    }
}

/// Code length in bits without encoding (for the code-length bound harness).
pub fn gamma_len(n: u64) -> usize {
    let nbits = 64 - n.leading_zeros();
    (2 * nbits - 1) as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::bitio::BitWriter;
    use crate::util::prop::for_cases;

    #[test]
    fn gamma_known_codewords() {
        // classic table: 1 -> "1", 2 -> "010", 3 -> "011", 4 -> "00100"
        let enc = |n: u64| {
            let mut w = BitWriter::new();
            gamma_encode(&mut w, n);
            let buf = w.finish();
            let mut r = buf.reader();
            (0..buf.len_bits())
                .map(|_| if r.read_bit() { '1' } else { '0' })
                .collect::<String>()
        };
        assert_eq!(enc(1), "1");
        assert_eq!(enc(2), "010");
        assert_eq!(enc(3), "011");
        assert_eq!(enc(4), "00100");
        assert_eq!(enc(5), "00101");
    }

    #[test]
    fn gamma_lengths() {
        assert_eq!(gamma_len(1), 1);
        assert_eq!(gamma_len(2), 3);
        assert_eq!(gamma_len(4), 5);
        assert_eq!(gamma_len(255), 15);
    }

    #[test]
    fn all_codes_roundtrip_small() {
        for n in 1u64..=300 {
            for code in 0..3 {
                let mut w = BitWriter::new();
                match code {
                    0 => gamma_encode(&mut w, n),
                    1 => delta_encode(&mut w, n),
                    _ => omega_encode(&mut w, n),
                }
                let buf = w.finish();
                let mut r = buf.reader();
                let got = match code {
                    0 => gamma_decode(&mut r),
                    1 => delta_decode(&mut r),
                    _ => omega_decode(&mut r),
                };
                assert_eq!(got, n, "code {code} n {n}");
                assert_eq!(r.remaining(), 0);
            }
        }
    }

    #[test]
    fn streams_self_delimit() {
        let ns = [1u64, 7, 2, 100, 1, 65535, 3];
        let mut w = BitWriter::new();
        for &n in &ns {
            delta_encode(&mut w, n);
        }
        let buf = w.finish();
        let mut r = buf.reader();
        for &n in &ns {
            assert_eq!(delta_decode(&mut r), n);
        }
    }

    #[test]
    fn prop_roundtrip_large_values() {
        for_cases(50, 33, |g| {
            let n = 1 + (g.rng.next_u64() >> g.usize_in(1, 40) as u32);
            let mut w = BitWriter::new();
            gamma_encode(&mut w, n);
            delta_encode(&mut w, n);
            omega_encode(&mut w, n);
            let buf = w.finish();
            let mut r = buf.reader();
            assert_eq!(gamma_decode(&mut r), n);
            assert_eq!(delta_decode(&mut r), n);
            assert_eq!(omega_decode(&mut r), n);
        });
    }
}

//! Fused single-pass ENC/DEC kernels — the comm hot path.
//!
//! The staged pipeline (`quant::quantizer` → `coding::protocol`) makes four
//! passes over every vector: f64→f32 copy, `TypeStats` sweep, stochastic
//! rounding into a materialized `QuantizedVector`, then entropy coding.
//! This module collapses them: per layer, one norm pass over the f64 input
//! (computing the L^q norm of its *f32 image*), an optional statistics
//! fold, and one hot loop that normalizes, stochastically rounds, and
//! emits the Huffman codeword + sign bit straight into the [`BitWriter`]
//! through a 64-bit accumulator — no `indices`/`signs` materialization.
//! Decode drives the table-driven Huffman lookup through a batched
//! word-level cache ([`BitCache`]) refilled 64 bits at a time and
//! dequantizes via a per-layer value table directly into the caller's
//! `f64` output.
//!
//! **Bit-exactness is the contract.** Every arithmetic step replicates the
//! staged path operation-for-operation (f32 rounding points, stochastic
//! rounding comparisons, one `uniform_f32` per coordinate iff the
//! f32-rounded norm is positive, histogram accumulation order, decode
//! error positions), so fused and staged streams are bit-identical and
//! decode to bit-identical vectors. `QuantCompressor { staged }` keeps the
//! reference path alive and `tests/fused_parity.rs` + `tests/comm_fuzz.rs`
//! pin the equivalence across protocols × adaptation modes × seeds ×
//! thread counts.

use super::bitio::{BitReader, BitWriter};
use super::huffman::Huffman;
use super::protocol::Codebooks;
use super::DecodeError;
use crate::quant::adaptive::TypeStats;
use crate::quant::layer_map::LayerMap;
use crate::quant::levels::LevelSequence;
use crate::quant::QuantConfig;
use crate::stats::rng::Rng;

/// L^q norm of the f32 image of an f64 slice — bit-identical to
/// `vecops::lq_norm` applied to the staged path's `v32` copy, without
/// materializing it.
pub fn layer_norm_f32(v: &[f64], q: f64) -> f64 {
    if q <= 0.0 || q.is_infinite() {
        v.iter().fold(0.0f64, |m, &x| m.max((x as f32).abs() as f64))
    } else if q == 2.0 {
        v.iter()
            .map(|&x| {
                let y = (x as f32) as f64;
                y * y
            })
            .sum::<f64>()
            .sqrt()
    } else if q == 1.0 {
        v.iter().map(|&x| (x as f32).abs() as f64).sum()
    } else {
        v.iter()
            .map(|&x| ((x as f32).abs() as f64).powf(q))
            .sum::<f64>()
            .powf(1.0 / q)
    }
}

/// Number of `uniform_f32` draws the encode body consumes for a layer:
/// one per coordinate iff the f32-rounded norm is positive (the zero
/// layer draws nothing). The parallel encoder uses this to advance each
/// worker's RNG clone to its chunk's start position.
#[inline]
pub fn layer_draws(raw_norm: f64, len: usize) -> usize {
    if (raw_norm as f32 as f64) > 0.0 {
        len
    } else {
        0
    }
}

/// Fold one layer's normalized magnitudes into its type statistics —
/// value-for-value what `TypeStats::add_layer_sample` accumulates over the
/// staged `v32` copy (weight `‖·‖_q²`, unrounded norm, layer order).
pub fn fold_layer_stats(v: &[f64], raw_norm: f64, st: &mut TypeStats) {
    if raw_norm <= 0.0 {
        return;
    }
    let inv = 1.0 / raw_norm;
    let w = raw_norm * raw_norm;
    for &x in v {
        st.hist.add_one((((x as f32).abs() as f64) * inv).clamp(0.0, 1.0), w);
    }
}

/// Fused quantize + entropy-encode of one layer: norm header, then per
/// coordinate the stochastic-rounding decision and the codeword + sign bit,
/// buffered through a 64-bit accumulator (one `write_bits` per ~8–20
/// symbols instead of two per coordinate).
///
/// `raw_norm` is `layer_norm_f32(v, q)`; `codes[j]` is type `type_id`'s
/// stream-order codeword for symbol j (`Codebooks::fill_code_table`).
/// Draws exactly `layer_draws(raw_norm, v.len())` randoms from `rng`.
pub fn encode_layer_body(
    v: &[f64],
    seq: &LevelSequence,
    raw_norm: f64,
    codes: &[(u64, u32)],
    rng: &mut Rng,
    w: &mut BitWriter,
) {
    assert!(seq.num_symbols() <= 256, "u8 index encoding");
    // the wire header carries the norm as f32 (C_q = 32); rounding here
    // keeps encode → decode → dequantize bit-exact with the staged path
    let norm = raw_norm as f32 as f64;
    w.write_f32(norm as f32);
    if !(norm > 0.0) {
        // zero (or NaN-norm) layer: every symbol is level 0, no sign bits,
        // no RNG draws — identical to the staged all-zero `QuantizedLayer`
        let (c0, l0) = codes[0];
        for _ in 0..v.len() {
            w.write_bits(c0, l0);
        }
        return;
    }
    let inv = 1.0 / norm;
    let ls = seq.as_slice();
    let nlev = ls.len();
    // 64-bit write accumulator: codeword + optional sign land together
    let mut cache = 0u64;
    let mut clen: u32 = 0;
    macro_rules! emit {
        ($idx:expr, $neg:expr) => {{
            let (c, l) = codes[$idx];
            let mut bits = c;
            let mut nb = l;
            if $idx != 0 {
                bits |= (($neg) as u64) << nb;
                nb += 1;
            }
            if clen + nb >= 64 {
                w.write_bits(cache, clen);
                cache = 0;
                clen = 0;
            }
            cache |= bits << clen;
            clen += nb;
        }};
    }
    if let Some(inv_step) = seq.uniform_inv_step() {
        // fast path: uniformly spaced levels — closed-form bracket
        for &x64 in v {
            let x = x64 as f32;
            let mag = ((x.abs() as f64) * inv).min(1.0);
            let pos = mag * inv_step;
            let mut tau = pos as usize;
            let mut xi = pos - tau as f64;
            if tau >= nlev - 1 {
                tau = nlev - 2;
                xi = 1.0;
            }
            let u01 = rng.uniform_f32() as f64;
            let idx = if u01 < xi { tau + 1 } else { tau };
            emit!(idx, x < 0.0);
        }
    } else {
        for &x64 in v {
            let x = x64 as f32;
            let mag = ((x.abs() as f64) * inv).clamp(0.0, 1.0);
            let tau = seq.bracket(mag);
            let (lo, hi) = (ls[tau], ls[tau + 1]);
            let xi = (mag - lo) / (hi - lo).max(1e-38);
            let u01 = rng.uniform_f32() as f64;
            let idx = if u01 < xi { tau + 1 } else { tau };
            emit!(idx, x < 0.0);
        }
    }
    if clen > 0 {
        w.write_bits(cache, clen);
    }
}

/// Batched bit consumer: a 64-bit local cache refilled word-at-a-time from
/// the [`BitReader`], so symbol decode is one table lookup + shift instead
/// of per-symbol reader arithmetic. `pos()` reports the logical stream
/// position (reader position minus cached bits), which is what keeps
/// decode-error positions identical to the staged path; `spill` returns
/// unconsumed cached bits to the reader before any slow-path or exit.
struct BitCache<'r, 'a> {
    r: &'r mut BitReader<'a>,
    cache: u64,
    len: u32,
}

impl<'r, 'a> BitCache<'r, 'a> {
    fn new(r: &'r mut BitReader<'a>) -> Self {
        BitCache { r, cache: 0, len: 0 }
    }

    /// Logical bit position (for decode-error reporting).
    #[inline]
    fn pos(&self) -> usize {
        self.r.bit_pos() - self.len as usize
    }

    #[inline]
    fn refill(&mut self) {
        let take = self.r.remaining().min((64 - self.len) as usize) as u32;
        if take > 0 {
            self.cache |= self.r.read_bits(take) << self.len;
            self.len += take;
        }
    }

    /// Consume `n` bits (n <= 32); `None` when the stream runs dry.
    #[inline]
    fn take(&mut self, n: u32) -> Option<u64> {
        if self.len < n {
            self.refill();
            if self.len < n {
                return None;
            }
        }
        let v = self.cache & ((1u64 << n) - 1);
        self.cache >>= n;
        self.len -= n;
        Some(v)
    }

    /// Decode one symbol via the code's fast table, falling back to the
    /// bit-exact canonical slow path on a table miss.
    #[inline]
    fn decode_sym(&mut self, h: &Huffman) -> Result<usize, DecodeError> {
        if self.len < 16 {
            // one refill covers the widest table (11 bits) + a sign bit
            // for several symbols; when the stream is exhausted the cache
            // holds every remaining bit, so indexing zero-pads exactly
            // like the staged `peek_bits`
            self.refill();
        }
        let (table, table_bits) = h.fast_table();
        let idx = (self.cache & ((1u64 << table_bits) - 1)) as usize;
        let (sym, l) = table[idx];
        if sym != u16::MAX && (l as u32) <= self.len {
            self.cache >>= l;
            self.len -= l as u32;
            return Ok(sym as usize);
        }
        self.decode_sym_slow(h)
    }

    #[cold]
    fn decode_sym_slow(&mut self, h: &Huffman) -> Result<usize, DecodeError> {
        self.spill();
        h.decode(self.r)
    }

    /// Return unconsumed cached bits to the reader.
    fn spill(&mut self) {
        self.r.rewind(self.len as usize);
        self.cache = 0;
        self.len = 0;
    }
}

/// Fused decode of one layer straight into `out` (f64): norm header, then
/// per symbol a value-table lookup `±(norm · l_sym)` with the staged
/// path's exact f32 rounding. Range-checks every decoded symbol like
/// `Codebooks::decode_symbol` (same `InvalidCode`/`Truncated` positions).
fn decode_layer_fused(
    c: &mut BitCache,
    books: &Codebooks,
    type_id: usize,
    len: usize,
    seq: &LevelSequence,
    out: &mut Vec<f64>,
) -> Result<(), DecodeError> {
    let norm_bits = match c.take(32) {
        Some(b) => b as u32,
        None => return Err(DecodeError::Truncated { bit_pos: c.pos() }),
    };
    let norm = f32::from_bits(norm_bits) as f64;
    let (h, off, size) = books.decode_surface(type_id);
    let ls = seq.as_slice();
    // dequantize table: symbol -> positive magnitude, rounded through f32
    // exactly like `dequantize_layer_into` (`(norm * l) as f32`); negation
    // commutes with the f32→f64 widening, so sign flip happens on the f64
    let cap = ls.len().min(256);
    let mut vtab = [0.0f64; 256];
    for (j, &l) in ls.iter().enumerate().take(cap) {
        vtab[j] = ((norm * l) as f32) as f64;
    }
    for _ in 0..len {
        let bit_pos = c.pos();
        let joint = c.decode_sym(h)?;
        if joint < off || joint - off >= size {
            // decodable codeword of the wrong type / rank: desynchronized
            return Err(DecodeError::InvalidCode { bit_pos });
        }
        let sym = joint - off;
        if sym >= cap {
            // rank beyond this type's level sequence (stale codebooks)
            return Err(DecodeError::InvalidCode { bit_pos });
        }
        let mut val = vtab[sym];
        if sym != 0 {
            match c.take(1) {
                Some(1) => val = -val,
                Some(_) => {}
                None => return Err(DecodeError::Truncated { bit_pos: c.pos() }),
            }
        }
        out.push(val);
    }
    Ok(())
}

/// Fused decode of a full vector into `out` (cleared first). The reader is
/// left exactly where the staged decode would leave it — on success all
/// consumed bits are accounted, so the caller's trailing-bits check is
/// unchanged. On error, `out`'s contents are unspecified (the staged path
/// buffers internally; engines abort the round on any decode error).
pub fn decode_vector_fused(
    r: &mut BitReader,
    map: &LayerMap,
    books: &Codebooks,
    cfg: &QuantConfig,
    out: &mut Vec<f64>,
) -> Result<(), DecodeError> {
    out.clear();
    out.reserve(map.dim);
    let mut c = BitCache::new(r);
    let mut res = Ok(());
    for l in &map.layers {
        let seq = &cfg.sequences[l.type_id];
        if let Err(e) = decode_layer_fused(&mut c, books, l.type_id, l.len, seq, out) {
            res = Err(e);
            break;
        }
    }
    c.spill();
    res
}

/// Fused decode of a contiguous run of layers into `out` (cleared first) —
/// the shard-decode entry point of the sharded reduce-scatter transport.
/// `layers` is a validated sub-slice of a `LayerMap`'s layers (e.g.
/// `&map.layers[lo..hi]`) and the reader holds *exactly* those layers'
/// coded bits, as produced by
/// [`WirePacket::shard`](crate::comm::WirePacket::shard): sharding slices
/// at layer bit-offset boundaries, so a shard's payload is the same byte
/// stream a sequential decode would have consumed for that range —
/// decoding shard-by-shard and concatenating is bit-identical to
/// [`decode_vector_fused`] on the whole packet. Error semantics match the
/// full decode (same variants, positions relative to the shard payload).
pub fn decode_layers_fused(
    r: &mut BitReader,
    layers: &[crate::quant::layer_map::Layer],
    books: &Codebooks,
    cfg: &QuantConfig,
    out: &mut Vec<f64>,
) -> Result<(), DecodeError> {
    out.clear();
    out.reserve(layers.iter().map(|l| l.len).sum());
    let mut c = BitCache::new(r);
    let mut res = Ok(());
    for l in layers {
        let seq = &cfg.sequences[l.type_id];
        if let Err(e) = decode_layer_fused(&mut c, books, l.type_id, l.len, seq, out) {
            res = Err(e);
            break;
        }
    }
    c.spill();
    res
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::protocol::{
        decode_vector_into, encode_layer, Codebooks, ProtocolKind,
    };
    use crate::quant::quantizer::{dequantize_into, quantize_slice_into, QuantizedVector};
    use crate::util::prop::for_cases;

    /// Staged reference for one layer: quantize into wire form, then
    /// entropy-code — the exact two passes the fused body collapses.
    fn staged_layer_bits(
        v32: &[f32],
        seq: &LevelSequence,
        q: f64,
        type_id: usize,
        books: &Codebooks,
        rng: &mut Rng,
    ) -> BitWriter {
        let mut layer = Default::default();
        quantize_slice_into(v32, seq, q, type_id, rng, &mut layer);
        let mut w = BitWriter::new();
        encode_layer(&layer, books, &mut w);
        w
    }

    #[test]
    fn fused_layer_encode_matches_staged_bit_for_bit() {
        for_cases(40, 0xF05ED, |g| {
            let n = g.usize_in(1, 300);
            let v: Vec<f64> = g.vec_f64(n, g.f64_in(0.05, 6.0));
            let v32: Vec<f32> = v.iter().map(|&x| x as f32).collect();
            // exercise both the uniform fast path and the bracket-search
            // slow path, both protocols
            let seq = if g.f64_in(0.0, 1.0) < 0.5 {
                LevelSequence::bits(g.usize_in(2, 6) as u32)
            } else {
                LevelSequence::new(g.level_sequence(8))
            };
            let kind = if g.f64_in(0.0, 1.0) < 0.5 {
                ProtocolKind::Main
            } else {
                ProtocolKind::Alternating
            };
            let cfg = QuantConfig { sequences: vec![seq.clone()], q: 2.0 };
            let books = Codebooks::uniform(kind, &cfg, &[1.0]);
            let seed = g.rng.next_u64();

            let mut rng_a = Rng::new(seed);
            let staged = staged_layer_bits(&v32, &seq, 2.0, 0, &books, &mut rng_a);

            let mut rng_b = Rng::new(seed);
            let raw = layer_norm_f32(&v, 2.0);
            let mut codes = Vec::new();
            books.fill_code_table(0, &mut codes);
            let mut w = BitWriter::new();
            encode_layer_body(&v, &seq, raw, &codes, &mut rng_b, &mut w);

            assert_eq!(staged.finish(), w.finish(), "fused stream diverged");
            // both paths consumed the same number of randoms
            assert_eq!(rng_a.next_u64(), rng_b.next_u64());
        });
    }

    #[test]
    fn zero_layer_draws_nothing_and_matches() {
        let v = vec![0.0f64; 17];
        let v32 = vec![0.0f32; 17];
        let seq = LevelSequence::bits(3);
        let cfg = QuantConfig { sequences: vec![seq.clone()], q: 2.0 };
        let books = Codebooks::uniform(ProtocolKind::Main, &cfg, &[1.0]);
        let mut rng_a = Rng::new(9);
        let staged = staged_layer_bits(&v32, &seq, 2.0, 0, &books, &mut rng_a);
        let mut rng_b = Rng::new(9);
        let raw = layer_norm_f32(&v, 2.0);
        assert_eq!(layer_draws(raw, 17), 0);
        let mut codes = Vec::new();
        books.fill_code_table(0, &mut codes);
        let mut w = BitWriter::new();
        encode_layer_body(&v, &seq, raw, &codes, &mut rng_b, &mut w);
        assert_eq!(staged.finish(), w.finish());
        assert_eq!(rng_a.next_u64(), rng_b.next_u64());
    }

    #[test]
    fn fused_stats_fold_matches_staged_sweep() {
        for_cases(20, 0x57A75, |g| {
            let n = g.usize_in(1, 200);
            let v: Vec<f64> = g.vec_f64(n, 2.0);
            let v32: Vec<f32> = v.iter().map(|&x| x as f32).collect();
            let mut a = TypeStats::default();
            a.add_layer_sample(&v32, 2.0);
            let mut b = TypeStats::default();
            fold_layer_stats(&v, layer_norm_f32(&v, 2.0), &mut b);
            assert_eq!(a.hist.total_weight().to_bits(), b.hist.total_weight().to_bits());
            for i in 0..=64 {
                let u = i as f64 / 64.0;
                assert_eq!(a.hist.cdf(u).to_bits(), b.hist.cdf(u).to_bits());
            }
        });
    }

    #[test]
    fn fused_decode_matches_staged_decode() {
        for_cases(40, 0xDEC0DE, |g| {
            let map = LayerMap::from_spec(&[
                ("a", g.usize_in(1, 200), "x"),
                ("b", g.usize_in(1, 200), "y"),
            ]);
            let cfg = QuantConfig {
                sequences: vec![
                    LevelSequence::bits(g.usize_in(2, 6) as u32),
                    LevelSequence::new(g.level_sequence(9)),
                ],
                q: 2.0,
            };
            let kind = if g.f64_in(0.0, 1.0) < 0.5 {
                ProtocolKind::Main
            } else {
                ProtocolKind::Alternating
            };
            let books = Codebooks::uniform(kind, &cfg, &map.type_proportions());
            let v = g.vec_f64(map.dim, 3.0);
            // encode fused (already pinned against staged above)
            let mut rng = Rng::new(g.rng.next_u64());
            let mut w = BitWriter::new();
            for l in &map.layers {
                let s = &v[l.offset..l.offset + l.len];
                let mut codes = Vec::new();
                books.fill_code_table(l.type_id, &mut codes);
                encode_layer_body(
                    s,
                    &cfg.sequences[l.type_id],
                    layer_norm_f32(s, cfg.q),
                    &codes,
                    &mut rng,
                    &mut w,
                );
            }
            let buf = w.finish();

            // staged: wire form -> dequantize -> widen
            let mut qv = QuantizedVector::default();
            let mut r = buf.reader();
            decode_vector_into(&mut r, &map, &books, &mut qv).expect("staged decode");
            assert_eq!(r.remaining(), 0);
            let mut out32: Vec<f32> = Vec::new();
            dequantize_into(&qv, &cfg, &mut out32);
            let staged: Vec<f64> = out32.iter().map(|&x| x as f64).collect();

            // fused: straight to f64
            let mut r2 = buf.reader();
            let mut fused: Vec<f64> = Vec::new();
            decode_vector_fused(&mut r2, &map, &books, &cfg, &mut fused)
                .expect("fused decode");
            assert_eq!(r2.remaining(), 0, "fused decode must consume the stream");
            assert_eq!(staged.len(), fused.len());
            for (i, (a, b)) in staged.iter().zip(&fused).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "coord {i}");
            }
        });
    }

    #[test]
    fn ranged_decode_concatenates_to_the_full_decode() {
        // decode_layers_fused over [0, split) then [split, L) — with each
        // range's payload re-sliced at layer bit boundaries exactly like
        // WirePacket::shard — must reproduce the full fused decode bit for
        // bit, including the degenerate empty ranges at either end
        for_cases(20, 0x5A4D, |g| {
            let map = LayerMap::from_spec(&[
                ("a", g.usize_in(1, 120), "x"),
                ("b", g.usize_in(1, 120), "y"),
                ("c", g.usize_in(1, 120), "x"),
            ]);
            let cfg = QuantConfig::uniform_bits(2, g.usize_in(2, 5) as u32, 2.0);
            let books = Codebooks::uniform(ProtocolKind::Main, &cfg, &map.type_proportions());
            let v = g.vec_f64(map.dim, 2.0);
            let mut rng = Rng::new(g.rng.next_u64());
            let mut w = BitWriter::new();
            let mut offsets = Vec::new();
            for l in &map.layers {
                let s = &v[l.offset..l.offset + l.len];
                let mut codes = Vec::new();
                books.fill_code_table(l.type_id, &mut codes);
                offsets.push(w.len_bits());
                encode_layer_body(
                    s,
                    &cfg.sequences[l.type_id],
                    layer_norm_f32(s, cfg.q),
                    &codes,
                    &mut rng,
                    &mut w,
                );
            }
            let buf = w.finish();
            let mut full = Vec::new();
            let mut r = buf.reader();
            decode_vector_fused(&mut r, &map, &books, &cfg, &mut full).expect("full decode");

            let split = g.usize_in(0, map.layers.len());
            let mut cat: Vec<f64> = Vec::new();
            for (lo, hi) in [(0, split), (split, map.layers.len())] {
                let lo_bit = offsets.get(lo).copied().unwrap_or(buf.len_bits());
                let hi_bit = offsets.get(hi).copied().unwrap_or(buf.len_bits());
                let mut rr = buf.reader();
                rr.skip(lo_bit as u32);
                let mut sw = BitWriter::with_capacity_bits(hi_bit - lo_bit);
                let mut left = hi_bit - lo_bit;
                while left > 0 {
                    let take = left.min(64) as u32;
                    sw.write_bits(rr.read_bits(take), take);
                    left -= take as usize;
                }
                let shard = sw.finish();
                let mut sr = shard.reader();
                let mut part = Vec::new();
                decode_layers_fused(&mut sr, &map.layers[lo..hi], &books, &cfg, &mut part)
                    .expect("ranged decode");
                assert_eq!(sr.remaining(), 0, "range ({lo},{hi}) left bits behind");
                cat.extend(part);
            }
            assert_eq!(cat.len(), full.len());
            for (i, (a, b)) in full.iter().zip(&cat).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "coord {i}");
            }
        });
    }

    #[test]
    fn fused_decode_errors_match_staged_on_truncation() {
        // every strict prefix must fail identically in both decoders:
        // same error variant AND same reported bit position
        for_cases(30, 0x7235, |g| {
            let map = LayerMap::from_spec(&[
                ("a", g.usize_in(4, 80), "x"),
                ("b", g.usize_in(4, 80), "y"),
            ]);
            let cfg = QuantConfig::uniform_bits(2, g.usize_in(2, 5) as u32, 2.0);
            let kind = if g.f64_in(0.0, 1.0) < 0.5 {
                ProtocolKind::Main
            } else {
                ProtocolKind::Alternating
            };
            let books = Codebooks::uniform(kind, &cfg, &map.type_proportions());
            let v = g.vec_f64(map.dim, 1.0);
            let mut rng = Rng::new(g.rng.next_u64());
            let mut w = BitWriter::new();
            for l in &map.layers {
                let s = &v[l.offset..l.offset + l.len];
                let mut codes = Vec::new();
                books.fill_code_table(l.type_id, &mut codes);
                encode_layer_body(
                    s,
                    &cfg.sequences[l.type_id],
                    layer_norm_f32(s, cfg.q),
                    &codes,
                    &mut rng,
                    &mut w,
                );
            }
            let full = w.finish();
            let cut = g.usize_in(0, full.len_bits() - 1);
            let mut wc = BitWriter::new();
            let mut rr = full.reader();
            let mut left = cut;
            while left > 0 {
                let take = left.min(64) as u32;
                wc.write_bits(rr.read_bits(take), take);
                left -= take as usize;
            }
            let short = wc.finish();

            let mut qv = QuantizedVector::default();
            let staged_err = {
                let mut r = short.reader();
                decode_vector_into(&mut r, &map, &books, &mut qv)
                    .expect_err("truncated stream must fail (staged)")
            };
            let fused_err = {
                let mut r = short.reader();
                let mut out = Vec::new();
                decode_vector_fused(&mut r, &map, &books, &cfg, &mut out)
                    .expect_err("truncated stream must fail (fused)")
            };
            assert_eq!(staged_err, fused_err, "cut at {cut}/{}", full.len_bits());
        });
    }
}

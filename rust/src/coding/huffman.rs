//! Canonical Huffman coding (Huffman, 1952; Cover & Thomas Thm 5.4.1/5.8.1).
//!
//! The protocols build one codebook per quantization type (Alternating) or a
//! merged codebook (Main) from the level-occurrence probabilities of
//! Proposition D.1. Expected code length is within 1 bit of the source
//! entropy — exactly the guarantee Theorem 5.3 builds on.

use super::bitio::{BitReader, BitWriter};
use super::DecodeError;

/// A built canonical Huffman code over symbols 0..n.
#[derive(Clone, Debug)]
pub struct Huffman {
    /// code length per symbol (0 = symbol never occurs, unencodable)
    pub lengths: Vec<u32>,
    /// canonical codeword per symbol, MSB-first in the low `lengths[s]` bits
    pub codes: Vec<u64>,
    /// bit-reversed codeword (stream order) — single write_bits per symbol
    rev_codes: Vec<u64>,
    /// decode tables: for each length, (first_code, offset into sorted syms)
    first_code: Vec<u64>,
    offset: Vec<usize>,
    count: Vec<usize>,
    sorted_syms: Vec<u16>,
    max_len: u32,
    /// table-driven fast decode: indexed by the next `table_bits` stream
    /// bits; entry = (symbol, len) or (u16::MAX, 0) => slow path
    table_bits: u32,
    table: Vec<(u16, u8)>,
}

impl Huffman {
    /// Build from non-negative weights. Symbols with weight 0 get no code;
    /// callers must only encode symbols with positive weight (the protocols
    /// guarantee this by constructing weights from the actual index stream,
    /// or by flooring with a tiny epsilon when building from model CDFs).
    pub fn from_weights(weights: &[f64]) -> Self {
        let n = weights.len();
        assert!(n >= 1 && n <= u16::MAX as usize);
        let mut lengths = vec![0u32; n];
        let alive: Vec<usize> = (0..n).filter(|&i| weights[i] > 0.0).collect();
        match alive.len() {
            0 => {}
            1 => lengths[alive[0]] = 1,
            _ => {
                // O(s log s) heap Huffman over (weight, node)
                #[derive(PartialEq)]
                struct Node {
                    w: f64,
                    id: usize,
                }
                impl Eq for Node {}
                impl PartialOrd for Node {
                    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
                        Some(self.cmp(o))
                    }
                }
                impl Ord for Node {
                    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
                        // min-heap via reverse; tie-break on id for
                        // determinism. total_cmp is IEEE total order — same
                        // result as partial_cmp on these weights (positive,
                        // never NaN), but total
                        o.w.total_cmp(&self.w).then_with(|| o.id.cmp(&self.id))
                    }
                }
                let mut heap = std::collections::BinaryHeap::new();
                // children[internal - n] = (left, right)
                let mut children: Vec<(usize, usize)> = Vec::new();
                for &i in &alive {
                    heap.push(Node { w: weights[i], id: i });
                }
                let mut next_id = n;
                while heap.len() > 1 {
                    let (a, b) = match (heap.pop(), heap.pop()) {
                        (Some(a), Some(b)) => (a, b),
                        _ => break, // unreachable: len > 1 was just checked
                    };
                    children.push((a.id, b.id));
                    heap.push(Node { w: a.w + b.w, id: next_id });
                    next_id += 1;
                }
                let root = heap.pop().map_or(0, |n| n.id);
                // depth-first assign lengths
                let mut stack = vec![(root, 0u32)];
                while let Some((id, depth)) = stack.pop() {
                    if id < n {
                        lengths[id] = depth.max(1);
                    } else {
                        let (l, r) = children[id - n];
                        stack.push((l, depth + 1));
                        stack.push((r, depth + 1));
                    }
                }
            }
        }
        Self::from_lengths(lengths)
    }

    /// Canonical code from the length vector.
    pub fn from_lengths(lengths: Vec<u32>) -> Self {
        assert!(
            lengths.len() <= u16::MAX as usize,
            "alphabet too large for u16 symbol ids ({})",
            lengths.len()
        );
        let max_len = lengths.iter().copied().max().unwrap_or(0);
        assert!(max_len <= 63, "codeword too long ({max_len})");
        let ml = max_len as usize;
        let mut count = vec![0usize; ml + 1];
        for &l in &lengths {
            if l > 0 {
                count[l as usize] += 1;
            }
        }
        // canonical first codes per length
        let mut first_code = vec![0u64; ml + 1];
        let mut code = 0u64;
        for len in 1..=ml {
            code = (code + count[len - 1] as u64) << 1;
            first_code[len] = code;
        }
        // symbols sorted by (length, symbol)
        // audit:allow(lossy-cast) — alphabet size asserted ≤ u16::MAX above
        let mut sorted_syms: Vec<u16> = (0..lengths.len() as u16)
            .filter(|&s| lengths[s as usize] > 0)
            .collect();
        sorted_syms.sort_by_key(|&s| (lengths[s as usize], s));
        let mut offset = vec![0usize; ml + 1];
        {
            let mut acc = 0usize;
            for len in 1..=ml {
                offset[len] = acc;
                acc += count[len];
            }
        }
        // assign codes
        let mut codes = vec![0u64; lengths.len()];
        let mut next = first_code.clone();
        for &s in &sorted_syms {
            let l = lengths[s as usize] as usize;
            codes[s as usize] = next[l];
            next[l] += 1;
        }
        // bit-reversed codes: one write_bits call per symbol on encode
        let rev_codes: Vec<u64> = codes
            .iter()
            .zip(&lengths)
            .map(|(&c, &l)| {
                if l == 0 {
                    0
                } else {
                    c.reverse_bits() >> (64 - l)
                }
            })
            .collect();
        // table-driven decode: index by the next `table_bits` stream bits
        // (stream order = reversed code), entry = (symbol, code length)
        let table_bits = max_len.min(11);
        let mut table = vec![(u16::MAX, 0u8); 1usize << table_bits];
        for (s, (&rc, &l)) in rev_codes.iter().zip(&lengths).enumerate() {
            if l == 0 || l > table_bits {
                continue;
            }
            // all entries whose low l bits equal rc
            let step = 1usize << l;
            let mut idx = rc as usize;
            while idx < table.len() {
                // audit:allow(lossy-cast) — s < alphabet ≤ u16::MAX, l ≤ table_bits ≤ 11
                table[idx] = (s as u16, l as u8);
                idx += step;
            }
        }
        Huffman {
            lengths,
            codes,
            rev_codes,
            first_code,
            offset,
            count,
            sorted_syms,
            max_len,
            table_bits,
            table,
        }
    }

    #[inline]
    pub fn encode(&self, w: &mut BitWriter, sym: usize) {
        let len = self.lengths[sym];
        debug_assert!(len > 0, "symbol {sym} has no code");
        // the bit-reversed code emits MSB-of-code first in stream order —
        // a single write_bits call (perf: EXPERIMENTS.md §Perf L3 iter 2)
        w.write_bits(self.rev_codes[sym], len);
    }

    /// Decode one symbol. Never panics: a stream that ends mid-symbol or
    /// whose bits match no codeword yields a [`DecodeError`] instead.
    #[inline]
    pub fn decode(&self, r: &mut BitReader) -> Result<usize, DecodeError> {
        // fast path: one peek + table lookup covers codes up to table_bits
        // (peek zero-pads past the end, so a hit is only trusted when the
        // full codeword actually fits in the remaining stream)
        let peek = r.peek_bits(self.table_bits) as usize;
        let (sym, len) = self.table[peek];
        if sym != u16::MAX && len as usize <= r.remaining() {
            r.skip(len as u32);
            return Ok(sym as usize);
        }
        self.decode_slow(r)
    }

    #[cold]
    fn decode_slow(&self, r: &mut BitReader) -> Result<usize, DecodeError> {
        let start = r.bit_pos();
        let mut code = 0u64;
        for len in 1..=self.max_len as usize {
            match r.try_read_bits(1) {
                None => return Err(DecodeError::Truncated { bit_pos: start }),
                Some(b) => code = (code << 1) | b,
            }
            let c = self.count[len];
            if c > 0 {
                let fc = self.first_code[len];
                if code >= fc && code < fc + c as u64 {
                    return Ok(self.sorted_syms[self.offset[len] + (code - fc) as usize]
                        as usize);
                }
            }
        }
        Err(DecodeError::InvalidCode { bit_pos: start })
    }

    /// Expected code length under `probs` (bits/symbol).
    pub fn expected_length(&self, probs: &[f64]) -> f64 {
        probs
            .iter()
            .zip(&self.lengths)
            .map(|(&p, &l)| p * l as f64)
            .sum()
    }

    pub fn code_len(&self, sym: usize) -> u32 {
        self.lengths[sym]
    }

    /// Stream-order codeword for `sym` as `(bits, len)` — the exact pair
    /// `encode` feeds to `write_bits`. The fused encoder snapshots these
    /// into flat per-type tables so the hot loop never chases pointers.
    #[inline]
    pub(crate) fn code_bits(&self, sym: usize) -> (u64, u32) {
        (self.rev_codes[sym], self.lengths[sym])
    }

    /// Fast-decode surface for the batched reader: the lookup table and its
    /// index width. Entry = (symbol, code length), `(u16::MAX, 0)` = miss.
    #[inline]
    pub(crate) fn fast_table(&self) -> (&[(u16, u8)], u32) {
        (&self.table, self.table_bits)
    }
}

/// Shannon entropy in bits of a probability vector (0 log 0 = 0).
pub fn entropy(probs: &[f64]) -> f64 {
    probs
        .iter()
        .filter(|&&p| p > 0.0)
        .map(|&p| -p * p.log2())
        .sum()
}

/// Normalize raw counts into probabilities.
pub fn normalize(counts: &[f64]) -> Vec<f64> {
    let total: f64 = counts.iter().sum();
    if total <= 0.0 {
        return vec![0.0; counts.len()];
    }
    counts.iter().map(|&c| c / total).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::bitio::BitWriter;
    use crate::stats::rng::Rng;
    use crate::util::prop::for_cases;

    #[test]
    fn prefix_free() {
        let h = Huffman::from_weights(&[5.0, 3.0, 1.0, 1.0, 0.5]);
        let codes: Vec<(u64, u32)> = (0..5).map(|s| (h.codes[s], h.lengths[s])).collect();
        for (i, &(ci, li)) in codes.iter().enumerate() {
            for (j, &(cj, lj)) in codes.iter().enumerate() {
                if i == j {
                    continue;
                }
                let l = li.min(lj);
                assert!(
                    ci >> (li - l) != cj >> (lj - l),
                    "codes {i} and {j} share a prefix"
                );
            }
        }
    }

    #[test]
    fn roundtrip_stream() {
        let weights = [10.0, 5.0, 2.0, 1.0];
        let h = Huffman::from_weights(&weights);
        let mut rng = Rng::new(1);
        let syms: Vec<usize> = (0..2000).map(|_| rng.below(4) as usize).collect();
        let mut w = BitWriter::new();
        for &s in &syms {
            h.encode(&mut w, s);
        }
        let buf = w.finish();
        let mut r = buf.reader();
        for &s in &syms {
            assert_eq!(h.decode(&mut r).unwrap(), s);
        }
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn within_one_bit_of_entropy() {
        // Cover & Thomas 5.4.1: H <= E[L] < H + 1
        let probs = normalize(&[0.4, 0.3, 0.15, 0.1, 0.05]);
        let h = Huffman::from_weights(&probs);
        let el = h.expected_length(&probs);
        let ent = entropy(&probs);
        assert!(el >= ent - 1e-9, "{el} < {ent}");
        assert!(el < ent + 1.0, "{el} vs {ent}");
    }

    #[test]
    fn skewed_source_gets_short_code() {
        let probs = normalize(&[0.97, 0.01, 0.01, 0.01]);
        let h = Huffman::from_weights(&probs);
        assert_eq!(h.lengths[0], 1);
        assert!(h.expected_length(&probs) < 1.2);
    }

    #[test]
    fn single_symbol_source() {
        let h = Huffman::from_weights(&[1.0]);
        let mut w = BitWriter::new();
        h.encode(&mut w, 0);
        h.encode(&mut w, 0);
        let buf = w.finish();
        assert_eq!(buf.len_bits(), 2);
        let mut r = buf.reader();
        assert_eq!(h.decode(&mut r).unwrap(), 0);
        assert_eq!(h.decode(&mut r).unwrap(), 0);
    }

    #[test]
    fn zero_weight_symbols_excluded() {
        let h = Huffman::from_weights(&[1.0, 0.0, 3.0]);
        assert_eq!(h.lengths[1], 0);
        assert!(h.lengths[0] > 0 && h.lengths[2] > 0);
    }

    #[test]
    fn entropy_reference() {
        assert!((entropy(&[0.5, 0.5]) - 1.0).abs() < 1e-12);
        assert!((entropy(&[0.25; 4]) - 2.0).abs() < 1e-12);
        assert_eq!(entropy(&[1.0]), 0.0);
    }

    #[test]
    fn prop_roundtrip_random_distributions() {
        for_cases(30, 55, |g| {
            let n = g.usize_in(2, 40);
            let weights: Vec<f64> = (0..n).map(|_| g.f64_in(0.01, 10.0)).collect();
            let h = Huffman::from_weights(&weights);
            let syms: Vec<usize> =
                (0..500).map(|_| g.usize_in(0, n - 1)).collect();
            let mut w = BitWriter::new();
            for &s in &syms {
                h.encode(&mut w, s);
            }
            let buf = w.finish();
            let mut r = buf.reader();
            for &s in &syms {
                assert_eq!(h.decode(&mut r).unwrap(), s);
            }
        });
    }

    #[test]
    fn corrupt_stream_errors_instead_of_panicking() {
        // deliberately incomplete canonical code: '00' and '01' assigned,
        // '1x' codeword space unassigned
        let h = Huffman::from_lengths(vec![2, 2]);
        let mut w = BitWriter::new();
        w.write_bits(0b11, 2);
        let buf = w.finish();
        let mut r = buf.reader();
        assert!(matches!(h.decode(&mut r), Err(DecodeError::InvalidCode { .. })));
    }

    #[test]
    fn truncated_stream_errors_instead_of_panicking() {
        let h = Huffman::from_weights(&[8.0, 4.0, 2.0, 1.0]);
        // a single '1' bit is a strict prefix of every >=2-bit codeword
        let mut w = BitWriter::new();
        w.write_bit(true);
        let buf = w.finish();
        let mut r = buf.reader();
        assert!(matches!(h.decode(&mut r), Err(DecodeError::Truncated { .. })));
    }

    #[test]
    fn deterministic_construction() {
        let w1 = Huffman::from_weights(&[1.0, 1.0, 1.0, 1.0]);
        let w2 = Huffman::from_weights(&[1.0, 1.0, 1.0, 1.0]);
        assert_eq!(w1.codes, w2.codes);
        assert_eq!(w1.lengths, vec![2, 2, 2, 2]);
    }
}

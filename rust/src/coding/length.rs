//! Code-length bounds (Theorem 5.3 / D.5) and the Proposition D.1 level
//! probabilities, computed from the synchronized per-type CDFs.

use crate::quant::levels::LevelSequence;
use crate::stats::histogram::NormalizedHistogram;

/// Proposition D.1: probability of level j of a sequence under CDF F~:
///   p_j = ∫_{l_{j-1}}^{l_j} (u - l_{j-1})/(l_j - l_{j-1}) dF
///       + ∫_{l_j}^{l_{j+1}} (l_{j+1} - u)/(l_{j+1} - l_j) dF
/// (boundary levels take only the existing side).
pub fn level_probabilities(hist: &NormalizedHistogram, seq: &LevelSequence) -> Vec<f64> {
    let ls = seq.as_slice();
    let n = ls.len();
    let mut probs = vec![0.0f64; n];
    if hist.is_empty() {
        // degenerate: uniform CDF fallback (matches histogram::cdf)
        // fall through — mass/conditional_mean handle it
    }
    for j in 0..n {
        let mut p = 0.0;
        if j > 0 {
            let (a, b) = (ls[j - 1], ls[j]);
            let m = hist.mass(a, b);
            if m > 0.0 && b > a {
                p += m * (hist.conditional_mean(a, b) - a).max(0.0) / (b - a);
            }
        }
        if j + 1 < n {
            let (a, b) = (ls[j], ls[j + 1]);
            let m = hist.mass(a, b);
            if m > 0.0 && b > a {
                p += m * (b - hist.conditional_mean(a, b)).max(0.0) / (b - a);
            }
        }
        probs[j] = p;
    }
    // numerical renormalization
    let total: f64 = probs.iter().sum();
    if total > 0.0 {
        for p in &mut probs {
            *p /= total;
        }
    }
    probs
}

/// The exact pre-big-O expression of Theorem 5.3 (Main protocol): expected
/// bits to transmit one d-dimensional quantized dual vector,
///   C_q + sum_m (1 - p_0^m) mu^m d + sum_m (H(l^m) + 1) mu^m d.
pub fn main_protocol_bound(
    probs_per_type: &[Vec<f64>],
    proportions: &[f64],
    d: usize,
    norm_bits: usize,
) -> f64 {
    let mut total = norm_bits as f64;
    for (probs, &mu) in probs_per_type.iter().zip(proportions) {
        let p0 = probs.first().copied().unwrap_or(0.0);
        let h: f64 = probs
            .iter()
            .skip(1)
            .filter(|&&p| p > 0.0)
            .map(|&p| -p * p.log2())
            .sum();
        total += (1.0 - p0) * mu * d as f64; // sign bits of nonzeros
        total += (h + 1.0) * mu * d as f64; // entropy-coded symbols
    }
    total
}

/// Theorem D.5 (Alternating protocol) exact expression:
///   C_q + (1 - sum_m p_0^m mu^m) d + (sum_m H(l^m) mu^m + 1) d
/// evaluated with the joint (type,level) alphabet entropy.
pub fn alternating_protocol_bound(
    probs_per_type: &[Vec<f64>],
    proportions: &[f64],
    d: usize,
    norm_bits: usize,
) -> f64 {
    let mut p0_total = 0.0;
    let mut joint_entropy = 0.0;
    for (probs, &mu) in probs_per_type.iter().zip(proportions) {
        p0_total += mu * probs.first().copied().unwrap_or(0.0);
        for &p in probs {
            let pj = mu * p;
            if pj > 0.0 {
                joint_entropy += -pj * pj.log2();
            }
        }
    }
    norm_bits as f64 + (1.0 - p0_total) * d as f64 + (joint_entropy + 1.0) * d as f64
}

/// Expected number of nonzeros after quantization (Lemma D.2):
/// sum_m (1 - p_0^m) mu^m d.
pub fn expected_nonzeros(probs_per_type: &[Vec<f64>], proportions: &[f64], d: usize) -> f64 {
    probs_per_type
        .iter()
        .zip(proportions)
        .map(|(p, &mu)| (1.0 - p.first().copied().unwrap_or(0.0)) * mu * d as f64)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::rng::Rng;

    fn hist_gradientlike(seed: u64) -> NormalizedHistogram {
        let mut rng = Rng::new(seed);
        let mut h = NormalizedHistogram::new(256);
        h.add_sample((0..20_000).map(|_| (rng.gaussian().abs() * 0.08).min(1.0)), 1.0);
        h
    }

    // the three histogram tests below draw 20k-100k RNG samples — pure
    // arithmetic with no UB surface, so skip them under Miri's interpreter
    #[test]
    #[cfg_attr(miri, ignore)]
    fn probabilities_sum_to_one() {
        let h = hist_gradientlike(1);
        let seq = LevelSequence::bits(4);
        let p = level_probabilities(&h, &seq);
        assert_eq!(p.len(), seq.num_symbols());
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        assert!(p.iter().all(|&x| x >= 0.0));
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn zero_level_dominates_for_gradients() {
        // most normalized magnitudes are tiny => p_0 large
        let h = hist_gradientlike(2);
        let seq = LevelSequence::bits(4);
        let p = level_probabilities(&h, &seq);
        assert!(p[0] > 0.3, "p0 = {}", p[0]);
        assert!(p[0] > p[seq.num_symbols() - 1]);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn uniform_cdf_uniform_levels_symmetric_probs() {
        let mut h = NormalizedHistogram::new(512);
        let mut rng = Rng::new(3);
        h.add_sample((0..100_000).map(|_| rng.uniform()), 1.0);
        let seq = LevelSequence::uniform(3);
        let p = level_probabilities(&h, &seq);
        // interior levels get ~1/4 each; boundary levels ~1/8
        assert!((p[1] - 0.25).abs() < 0.02, "{p:?}");
        assert!((p[0] - 0.125).abs() < 0.02, "{p:?}");
    }

    #[test]
    fn bound_decreases_with_skew() {
        // more skew toward level 0 => fewer expected bits
        let seq = LevelSequence::bits(5);
        let uniform = vec![1.0 / seq.num_symbols() as f64; seq.num_symbols()];
        let mut skewed = vec![0.01; seq.num_symbols()];
        skewed[0] = 1.0 - 0.01 * (seq.num_symbols() - 1) as f64;
        let d = 10_000;
        let b_u = main_protocol_bound(&[uniform], &[1.0], d, 32);
        let b_s = main_protocol_bound(&[skewed], &[1.0], d, 32);
        assert!(b_s < b_u, "{b_s} vs {b_u}");
    }

    #[test]
    fn expected_nonzeros_lemma() {
        let probs = vec![vec![0.8, 0.1, 0.1], vec![0.5, 0.25, 0.25]];
        let nz = expected_nonzeros(&probs, &[0.5, 0.5], 1000);
        assert!((nz - (0.2 * 500.0 + 0.5 * 500.0)).abs() < 1e-9);
    }

    #[test]
    fn alternating_bound_at_least_main_minus_slack() {
        // shared-codeword main protocol should not be (much) worse
        let probs = vec![vec![0.7, 0.2, 0.1], vec![0.6, 0.3, 0.1]];
        let mu = [0.5, 0.5];
        let d = 1000;
        let bm = main_protocol_bound(&probs, &mu, d, 32);
        let ba = alternating_protocol_bound(&probs, &mu, d, 32);
        assert!(bm <= ba * 1.2 + 64.0, "{bm} vs {ba}");
    }
}

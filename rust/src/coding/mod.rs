//! Entropy coding stack (Section 3.2, Appendix D): bit I/O, Elias universal
//! codes, canonical Huffman, the Main and Alternating wire protocols, and
//! the Theorem 5.3 / D.5 code-length bounds.

pub mod bitio;
pub mod elias;
pub mod huffman;
pub mod length;
pub mod protocol;

pub use bitio::{BitBuf, BitReader, BitWriter};
pub use huffman::{entropy, Huffman};
pub use protocol::{decode_vector, encode_vector, Codebooks, ProtocolKind, NORM_BITS};

//! Entropy coding stack (Section 3.2, Appendix D): bit I/O, Elias universal
//! codes, canonical Huffman, the Main and Alternating wire protocols, the
//! Theorem 5.3 / D.5 code-length bounds — and the fused single-pass
//! kernels that actually run the comm hot path.
//!
//! Two implementations share one wire format:
//!
//! * **Staged** (`protocol` over `quant::quantizer`): quantize into an
//!   explicit `QuantizedVector`, then entropy-code it. This is the
//!   readable reference — every arithmetic step is a named function.
//! * **Fused** ([`fused`]): per layer, one pass computes the norm, folds
//!   the adaptive statistics, stochastically rounds, and emits codeword +
//!   sign bits through a 64-bit write accumulator; decode batches the
//!   table-driven Huffman lookup through a word-level bit cache and
//!   dequantizes straight into `f64`. No intermediate buffers.
//!
//! The two paths are pinned bit-identical (streams AND decoded values) by
//! `fused`'s unit tests, `tests/fused_parity.rs` and `tests/comm_fuzz.rs`;
//! `comm::QuantCompressor` keeps both behind a `staged` toggle.
//!
//! Decoding operates on *wire* data and therefore never panics on malformed
//! input: every decode entry point returns [`DecodeError`], which the
//! `crate::comm` pipeline surfaces as `comm::CommError`.

pub mod bitio;
pub mod elias;
pub mod fused;
pub mod huffman;
pub mod length;
pub mod protocol;

pub use bitio::{BitBuf, BitReader, BitWriter};
pub use huffman::{entropy, Huffman};
pub use protocol::{decode_vector, encode_vector, Codebooks, ProtocolKind, NORM_BITS};

/// Decode-side failure on an untrusted / wire bitstream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecodeError {
    /// The stream ended in the middle of a symbol or header.
    Truncated { bit_pos: usize },
    /// No codeword of the active codebook matches the upcoming bits.
    InvalidCode { bit_pos: usize },
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DecodeError::Truncated { bit_pos } => {
                write!(f, "bitstream truncated at bit {bit_pos}")
            }
            DecodeError::InvalidCode { bit_pos } => {
                write!(f, "corrupt huffman stream: no codeword matches at bit {bit_pos}")
            }
        }
    }
}

impl std::error::Error for DecodeError {}

//! The paper's two coding protocols (Section 3.2, Appendix D).
//!
//! **Main protocol**: codewords are *shared across types* — one canonical
//! Huffman code over level *ranks*, built on the type-proportion-weighted
//! merged distribution. The receiver knows each coordinate's type from the
//! (shared) layer map, so rank j decodes to level l^m_j of the right type.
//!
//! **Alternating protocol**: one joint codebook over the *union alphabet*
//! of all (type, level) pairs — every level of every type has a unique
//! codeword, so the receiver needs no positional type knowledge (the
//! robust-to-jitter variant of Remark D.3).
//!
//! Wire layout per layer: `f32` L^q norm (C_q = 32 bits), then per
//! coordinate the entropy-coded symbol followed by one sign bit iff the
//! symbol is a nonzero level (Appendix D.1: signs of *nonzero* entries).
//!
//! All decoding is fallible ([`DecodeError`]) — malformed wire bytes must
//! never panic the coordinator. The `crate::comm` pipeline is the only
//! production caller; it wraps these primitives in `WirePacket` framing.

use super::bitio::{BitBuf, BitReader, BitWriter};
use super::huffman::{normalize, Huffman};
use super::DecodeError;
use crate::quant::layer_map::LayerMap;
use crate::quant::quantizer::{QuantizedLayer, QuantizedVector};
use crate::quant::QuantConfig;

pub const NORM_BITS: usize = 32; // C_q

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProtocolKind {
    Main,
    Alternating,
}

/// Shared encoder/decoder state: built identically on every node from the
/// synchronized per-type level probabilities (Prop D.1), so codebooks never
/// travel on the wire.
#[derive(Clone, Debug)]
pub struct Codebooks {
    pub kind: ProtocolKind,
    /// number of symbols per type
    sizes: Vec<usize>,
    code: Code,
}

/// The protocol-specific code: the variant is fixed by `kind` at build time,
/// so every accessor is a total match — no `Option` unwraps on the decode
/// path.
#[derive(Clone, Debug)]
enum Code {
    /// Main: one code over ranks 0..max_size
    Merged(Huffman),
    /// Alternating: one code over the union alphabet; type m's symbol j is
    /// `offsets[m] + j`
    Joint { huff: Huffman, offsets: Vec<usize> },
}

const FLOOR: f64 = 1e-6;

impl Codebooks {
    /// `probs_per_type[m][j]` = probability of level j of type m;
    /// `proportions[m]` = mu^m share of coordinates of type m.
    pub fn build(kind: ProtocolKind, probs_per_type: &[Vec<f64>], proportions: &[f64]) -> Self {
        assert_eq!(probs_per_type.len(), proportions.len());
        let sizes: Vec<usize> = probs_per_type.iter().map(|p| p.len()).collect();
        match kind {
            ProtocolKind::Main => {
                let max = sizes.iter().copied().max().unwrap_or(0);
                let mut merged = vec![0.0f64; max];
                for (probs, &mu) in probs_per_type.iter().zip(proportions) {
                    for (j, &p) in probs.iter().enumerate() {
                        merged[j] += mu * p.max(FLOOR);
                    }
                }
                let huff = Huffman::from_weights(&normalize(&merged));
                Codebooks { kind, sizes, code: Code::Merged(huff) }
            }
            ProtocolKind::Alternating => {
                let mut offsets = Vec::with_capacity(sizes.len());
                let mut joint = Vec::new();
                for (probs, &mu) in probs_per_type.iter().zip(proportions) {
                    offsets.push(joint.len());
                    for &p in probs {
                        joint.push(mu.max(FLOOR) * p.max(FLOOR));
                    }
                }
                let huff = Huffman::from_weights(&normalize(&joint));
                Codebooks { kind, sizes, code: Code::Joint { huff, offsets } }
            }
        }
    }

    /// Uniform-probability codebooks (before any statistics exist).
    pub fn uniform(kind: ProtocolKind, cfg: &QuantConfig, proportions: &[f64]) -> Self {
        let probs: Vec<Vec<f64>> = cfg
            .sequences
            .iter()
            .map(|s| vec![1.0 / s.num_symbols() as f64; s.num_symbols()])
            .collect();
        Self::build(kind, &probs, proportions)
    }

    #[inline]
    fn encode_symbol(&self, w: &mut BitWriter, type_id: usize, sym: usize) {
        match &self.code {
            Code::Merged(huff) => huff.encode(w, sym),
            Code::Joint { huff, offsets } => huff.encode(w, offsets[type_id] + sym),
        }
    }

    #[inline]
    fn decode_symbol(&self, r: &mut BitReader, type_id: usize) -> Result<usize, DecodeError> {
        let bit_pos = r.bit_pos();
        match &self.code {
            Code::Merged(huff) => {
                let sym = huff.decode(r)?;
                if sym >= self.sizes[type_id] {
                    // rank exists in the merged codebook but not for this
                    // type: corrupt or desynchronized stream (previously an
                    // out-of-bounds panic in dequantize)
                    return Err(DecodeError::InvalidCode { bit_pos });
                }
                Ok(sym)
            }
            Code::Joint { huff, offsets } => {
                let joint = huff.decode(r)?;
                if joint < offsets[type_id] || joint >= offsets[type_id] + self.sizes[type_id] {
                    // a decodable codeword of the *wrong* type: the stream
                    // desynchronized (or the layer map disagrees)
                    return Err(DecodeError::InvalidCode { bit_pos });
                }
                Ok(joint - offsets[type_id])
            }
        }
    }

    /// Snapshot the stream-order codeword of every symbol of `type_id` into
    /// `out` as `(bits, len)` pairs — `out[j]` is exactly what
    /// `encode_symbol(w, type_id, j)` would feed to `write_bits`. The fused
    /// encoder rebuilds these flat tables whenever the codebooks change.
    pub fn fill_code_table(&self, type_id: usize, out: &mut Vec<(u64, u32)>) {
        out.clear();
        match &self.code {
            Code::Merged(huff) => {
                out.extend((0..self.sizes[type_id]).map(|j| huff.code_bits(j)));
            }
            Code::Joint { huff, offsets } => {
                let off = offsets[type_id];
                out.extend((0..self.sizes[type_id]).map(|j| huff.code_bits(off + j)));
            }
        }
    }

    /// Decode surface for `type_id`: the Huffman code driving the stream
    /// plus the `(offset, size)` window mapping joint symbols back to ranks
    /// (Main: offset 0 over the merged code; Alternating: this type's slice
    /// of the union alphabet). The batched decoder range-checks against the
    /// window exactly like `decode_symbol`.
    pub(crate) fn decode_surface(&self, type_id: usize) -> (&Huffman, usize, usize) {
        match &self.code {
            Code::Merged(huff) => (huff, 0, self.sizes[type_id]),
            Code::Joint { huff, offsets } => (huff, offsets[type_id], self.sizes[type_id]),
        }
    }

    /// Expected bits per coordinate of type m (excluding sign/norm).
    pub fn expected_symbol_bits(&self, type_id: usize, probs: &[f64]) -> f64 {
        match &self.code {
            Code::Merged(huff) => huff.expected_length(probs),
            Code::Joint { huff, offsets } => probs
                .iter()
                .enumerate()
                .map(|(j, &p)| p * huff.code_len(offsets[type_id] + j) as f64)
                .sum(),
        }
    }
}

/// ENC one quantized layer: norm header, then entropy-coded symbols with
/// sign bits on nonzero levels. The layer segments are independent, which
/// is what lets `comm` encode layers on worker threads and splice streams.
pub fn encode_layer(layer: &QuantizedLayer, books: &Codebooks, w: &mut BitWriter) {
    // audit:allow(lossy-cast) — the norm header is fp32 on the wire by contract (C_q = 32)
    w.write_f32(layer.norm as f32);
    for i in 0..layer.len {
        let sym = layer.indices[i] as usize;
        books.encode_symbol(w, layer.type_id, sym);
        if sym != 0 {
            w.write_bit(layer.sign(i));
        }
    }
}

/// ENC: entropy-code a quantized vector into a bit buffer.
pub fn encode_vector(qv: &QuantizedVector, books: &Codebooks) -> BitBuf {
    // rough capacity guess: 6 bits/coord
    let mut w = BitWriter::with_capacity_bits(qv.dim * 6 + qv.layers.len() * NORM_BITS);
    for layer in &qv.layers {
        encode_layer(layer, books, &mut w);
    }
    w.finish()
}

/// DEC one layer of `len` coordinates of `type_id` into `out` (scratch
/// buffers inside `out` are reused).
pub fn decode_layer_into(
    r: &mut BitReader,
    type_id: usize,
    len: usize,
    books: &Codebooks,
    out: &mut QuantizedLayer,
) -> Result<(), DecodeError> {
    let norm = match r.try_read_bits(32) {
        Some(bits) => f32::from_bits(bits as u32) as f64,
        None => return Err(DecodeError::Truncated { bit_pos: r.bit_pos() }),
    };
    out.norm = norm;
    out.type_id = type_id;
    out.len = len;
    out.indices.clear();
    out.indices.resize(len, 0);
    out.signs.clear();
    out.signs.resize(len.div_ceil(64), 0);
    for i in 0..len {
        let sym = books.decode_symbol(r, type_id)?;
        // audit:allow(lossy-cast) — decode_symbol range-checks against sizes[type_id] ≤ 255
        out.indices[i] = sym as u8;
        if sym != 0 {
            match r.try_read_bits(1) {
                Some(1) => out.signs[i / 64] |= 1 << (i % 64),
                Some(_) => {}
                None => return Err(DecodeError::Truncated { bit_pos: r.bit_pos() }),
            }
        }
    }
    Ok(())
}

/// DEC a full vector given the shared layer map, reusing `qv`'s buffers.
pub fn decode_vector_into(
    r: &mut BitReader,
    map: &LayerMap,
    books: &Codebooks,
    qv: &mut QuantizedVector,
) -> Result<(), DecodeError> {
    qv.dim = map.dim;
    qv.layers.resize_with(map.layers.len(), Default::default);
    for (l, out) in map.layers.iter().zip(&mut qv.layers) {
        decode_layer_into(r, l.type_id, l.len, books, out)?;
    }
    Ok(())
}

/// DEC: reconstruct the wire form given the shared layer map.
pub fn decode_vector(
    buf: &BitBuf,
    map: &LayerMap,
    books: &Codebooks,
) -> Result<QuantizedVector, DecodeError> {
    let mut r = buf.reader();
    let mut qv = QuantizedVector::default();
    decode_vector_into(&mut r, map, books, &mut qv)?;
    debug_assert_eq!(r.remaining(), 0, "trailing bits");
    Ok(qv)
}

/// Convenience: measured wire size in bits for a quantized vector.
pub fn encoded_bits(qv: &QuantizedVector, books: &Codebooks) -> usize {
    encode_vector(qv, books).len_bits()
}

/// Empirical per-type symbol counts of a quantized vector — used to build /
/// refresh codebooks and to check the Theorem 5.3 bound.
pub fn symbol_counts(qv: &QuantizedVector, num_types: usize, sizes: &[usize]) -> Vec<Vec<f64>> {
    let mut counts: Vec<Vec<f64>> = (0..num_types).map(|m| vec![0.0; sizes[m]]).collect();
    for l in &qv.layers {
        for i in 0..l.len {
            counts[l.type_id][l.indices[i] as usize] += 1.0;
        }
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::layer_map::LayerMap;
    use crate::quant::quantizer::{dequantize, quantize};
    use crate::quant::{LevelSequence, QuantConfig};
    use crate::stats::rng::Rng;
    use crate::util::prop::for_cases;

    fn setup() -> (LayerMap, QuantConfig, Vec<f32>) {
        let map = LayerMap::from_spec(&[
            ("a.w", 300, "ff"),
            ("a.b", 20, "bias"),
            ("b.w", 200, "ff"),
        ]);
        let cfg = QuantConfig {
            sequences: vec![LevelSequence::bits(3), LevelSequence::bits(5)],
            q: 2.0,
        };
        let mut rng = Rng::new(1);
        let v: Vec<f32> = (0..map.dim).map(|_| rng.gaussian() as f32).collect();
        (map, cfg, v)
    }

    #[test]
    fn roundtrip_main() {
        let (map, cfg, v) = setup();
        let mut rng = Rng::new(2);
        let qv = quantize(&v, &map, &cfg, &mut rng);
        let books = Codebooks::uniform(ProtocolKind::Main, &cfg, &map.type_proportions());
        let buf = encode_vector(&qv, &books);
        let back = decode_vector(&buf, &map, &books).unwrap();
        assert_eq!(dequantize(&back, &cfg), dequantize(&qv, &cfg));
    }

    #[test]
    fn roundtrip_alternating() {
        let (map, cfg, v) = setup();
        let mut rng = Rng::new(3);
        let qv = quantize(&v, &map, &cfg, &mut rng);
        let books =
            Codebooks::uniform(ProtocolKind::Alternating, &cfg, &map.type_proportions());
        let buf = encode_vector(&qv, &books);
        let back = decode_vector(&buf, &map, &books).unwrap();
        assert_eq!(dequantize(&back, &cfg), dequantize(&qv, &cfg));
    }

    #[test]
    fn truncated_stream_surfaces_decode_error() {
        let (map, cfg, v) = setup();
        let mut rng = Rng::new(9);
        let qv = quantize(&v, &map, &cfg, &mut rng);
        let books = Codebooks::uniform(ProtocolKind::Main, &cfg, &map.type_proportions());
        let buf = encode_vector(&qv, &books);
        // cut the stream hard: keep only the first 40 bits
        let mut w = crate::coding::bitio::BitWriter::new();
        let mut r = buf.reader();
        w.write_bits(r.read_bits(40), 40);
        let cut = w.finish();
        let err = decode_vector(&cut, &map, &books);
        assert!(
            matches!(err, Err(DecodeError::Truncated { .. })),
            "want Truncated, got {err:?}"
        );
    }

    #[test]
    fn tuned_codebook_shrinks_stream() {
        let (map, cfg, v) = setup();
        let mut rng = Rng::new(4);
        let qv = quantize(&v, &map, &cfg, &mut rng);
        let uniform = Codebooks::uniform(ProtocolKind::Main, &cfg, &map.type_proportions());
        let sizes: Vec<usize> = cfg.sequences.iter().map(|s| s.num_symbols()).collect();
        let counts = symbol_counts(&qv, map.num_types(), &sizes);
        let probs: Vec<Vec<f64>> = counts.iter().map(|c| normalize(c)).collect();
        let tuned = Codebooks::build(ProtocolKind::Main, &probs, &map.type_proportions());
        let b_uniform = encoded_bits(&qv, &uniform);
        let b_tuned = encoded_bits(&qv, &tuned);
        assert!(b_tuned <= b_uniform, "{b_tuned} vs {b_uniform}");
        // roundtrip still exact with the tuned codebook
        let buf = encode_vector(&qv, &tuned);
        let back = decode_vector(&buf, &map, &tuned).unwrap();
        assert_eq!(dequantize(&back, &cfg), dequantize(&qv, &cfg));
    }

    #[test]
    fn main_beats_or_matches_alternating_on_shared_structure() {
        // Remark D.3: main trades robustness for compression.
        let (map, cfg, v) = setup();
        let mut rng = Rng::new(5);
        let qv = quantize(&v, &map, &cfg, &mut rng);
        let sizes: Vec<usize> = cfg.sequences.iter().map(|s| s.num_symbols()).collect();
        let probs: Vec<Vec<f64>> =
            symbol_counts(&qv, map.num_types(), &sizes).iter().map(|c| normalize(c)).collect();
        let main = Codebooks::build(ProtocolKind::Main, &probs, &map.type_proportions());
        let alt =
            Codebooks::build(ProtocolKind::Alternating, &probs, &map.type_proportions());
        let bm = encoded_bits(&qv, &main);
        let ba = encoded_bits(&qv, &alt);
        assert!(bm as f64 <= ba as f64 * 1.05, "main {bm} vs alt {ba}");
    }

    #[test]
    fn compresses_below_fixed_width_on_skewed_gradients() {
        // gradient-like vectors: most mass at the zero level with a tuned book
        let map = LayerMap::single(4096);
        let cfg = QuantConfig::uniform_bits(1, 5, 2.0);
        let mut rng = Rng::new(6);
        // heavy-tailed: a few large coords dominate the norm
        let v: Vec<f32> = (0..4096)
            .map(|i| if i % 97 == 0 { rng.gaussian() as f32 * 30.0 } else { rng.gaussian() as f32 * 0.05 })
            .collect();
        let qv = quantize(&v, &map, &cfg, &mut rng);
        let sizes = vec![cfg.sequences[0].num_symbols()];
        let probs: Vec<Vec<f64>> =
            symbol_counts(&qv, 1, &sizes).iter().map(|c| normalize(c)).collect();
        let books = Codebooks::build(ProtocolKind::Main, &probs, &map.type_proportions());
        let bits = encoded_bits(&qv, &books);
        let fixed = crate::quant::quantizer::fixed_width_bits(&qv, &cfg, NORM_BITS);
        assert!(bits < fixed, "entropy {bits} vs fixed {fixed}");
    }

    #[test]
    fn prop_roundtrip_both_protocols() {
        for_cases(25, 77, |g| {
            let n1 = g.usize_in(1, 150);
            let n2 = g.usize_in(1, 150);
            let map = LayerMap::from_spec(&[("x", n1, "ff"), ("y", n2, "emb")]);
            let cfg = QuantConfig {
                sequences: vec![
                    LevelSequence::new(g.level_sequence(6)),
                    LevelSequence::new(g.level_sequence(10)),
                ],
                q: 2.0,
            };
            let v = g.vec_f32(map.dim, 2.0);
            let mut rng = Rng::new(g.rng.next_u64());
            let qv = quantize(&v, &map, &cfg, &mut rng);
            for kind in [ProtocolKind::Main, ProtocolKind::Alternating] {
                let books = Codebooks::uniform(kind, &cfg, &map.type_proportions());
                let buf = encode_vector(&qv, &books);
                let back = decode_vector(&buf, &map, &books).unwrap();
                assert_eq!(dequantize(&back, &cfg), dequantize(&qv, &cfg));
            }
        });
    }
}

//! Node-side codecs: quantize → entropy-code into a [`WirePacket`] (ENC)
//! and packet → flat `f64` vector (DEC), with exact bit accounting and the
//! L-GreCo-style adaptive re-optimization of levels at update steps
//! (Algorithm 1, lines 2–7).
//!
//! ENC/DEC run the **fused** single-pass kernels of [`crate::coding::fused`]
//! by default: per layer, one pass computes the norm, folds the adaptive
//! statistics, stochastically rounds and emits Huffman bits straight into
//! the codec-owned [`BitWriter`]; decode drives the table-driven Huffman
//! lookup through a batched word-level bit cache and dequantizes directly
//! into the caller's `f64` output. The staged reference pipeline
//! (`quantize_into` → `encode_layer`, `decode_vector_into` →
//! `dequantize_into`) stays available behind [`QuantCompressor::staged`]
//! and is pinned bit-identical to the fused path (streams, decoded values,
//! RNG trajectory, statistics) by `tests/fused_parity.rs` and
//! `tests/comm_fuzz.rs`.
//!
//! Codecs keep every buffer (bit writer, per-type codeword tables, norm and
//! decode scratch) alive across calls, so the per-step hot path allocates
//! nothing once warm. Entropy coding can optionally fan out across worker
//! threads — the stream is spliced back in layer order and is bit-identical
//! to a sequential encode; a panicking worker surfaces as
//! [`CommError::EncodeWorker`] instead of tearing down the engine.

use super::packet::WirePacket;
use super::CommError;
use crate::coding::bitio::{BitBuf, BitWriter};
use crate::coding::fused;
use crate::coding::protocol::{
    decode_vector_into, encode_layer, Codebooks, ProtocolKind,
};
use crate::quant::adaptive::TypeStats;
use crate::quant::layer_map::LayerMap;
use crate::quant::quantizer::{
    dequantize_into, quantize_into, QuantizedLayer, QuantizedVector,
};
use crate::quant::{LevelSequence, QuantConfig};
use crate::stats::rng::Rng;

/// What a node applies to its dual vector before "broadcasting": ENC into a
/// wire packet, and DEC of a received packet back to the flat vector.
///
/// Both directions reuse internal scratch; `decode_into` clears and fills
/// the caller's output buffer so the caller controls its lifetime (the
/// engines keep one per node). Encoding is fallible: the parallel entropy
/// coder reports worker panics as [`CommError::EncodeWorker`].
pub trait Compressor: Send {
    /// ENC: encode `v` into `packet`, reusing the packet's allocation.
    fn encode_into(&mut self, v: &[f64], packet: &mut WirePacket)
        -> Result<(), CommError>;

    /// DEC: reconstruct the receiver-side vector from an encoded packet.
    fn decode_into(&mut self, packet: &WirePacket, out: &mut Vec<f64>)
        -> Result<(), CommError>;

    /// Partial DEC: reconstruct only the coordinates of the contiguous
    /// layer range `layers` from a shard produced by
    /// [`WirePacket::shard`] over that same range — the owner-side decode
    /// of the sharded reduce-scatter plan. Decoding every shard of a
    /// partition and concatenating in range order is bit-identical to
    /// [`Compressor::decode_into`] on the unsharded packet. Codecs without
    /// layer framing may decline with [`CommError::Unsupported`] (the
    /// default).
    fn decode_layers_into(
        &mut self,
        packet: &WirePacket,
        layers: std::ops::Range<usize>,
        out: &mut Vec<f64>,
    ) -> Result<(), CommError> {
        let _ = (packet, layers, out);
        Err(CommError::Unsupported { what: "partial decode" })
    }

    /// Hook for Algorithm 1's update steps (t in U): re-estimate level
    /// sequences / codebooks from the statistics gathered since the last
    /// update. Default: no-op. Must only be called between exchanges —
    /// packets encoded before an update decode with the pre-update books.
    fn update_levels(&mut self) {}

    fn name(&self) -> &'static str;

    /// Allocating convenience ENC.
    fn encode(&mut self, v: &[f64]) -> Result<WirePacket, CommError> {
        let mut packet = WirePacket::new();
        self.encode_into(v, &mut packet)?;
        Ok(packet)
    }

    /// Allocating convenience DEC.
    fn decode(&mut self, packet: &WirePacket) -> Result<Vec<f64>, CommError> {
        let mut out = Vec::with_capacity(packet.dim());
        self.decode_into(packet, &mut out)?;
        Ok(out)
    }
}

/// No compression: raw f32 on the wire (the uncompressed fp32 baseline —
/// 32 bits/coordinate of *real* payload, not an accounting fiction).
/// Owns its bit-writer scratch so a warm encode allocates nothing.
#[derive(Default)]
pub struct IdentityCompressor {
    w: BitWriter,
}

impl IdentityCompressor {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Compressor for IdentityCompressor {
    fn encode_into(&mut self, v: &[f64], packet: &mut WirePacket)
        -> Result<(), CommError> {
        let w = &mut self.w;
        packet.begin_encode(v.len(), w);
        packet.mark_layer(0);
        for &x in v {
            // audit:allow(lossy-cast) — identity codec ships fp32 on the wire by definition
            w.write_f32(x as f32);
        }
        packet.finish_encode(w);
        Ok(())
    }

    fn decode_into(
        &mut self,
        packet: &WirePacket,
        out: &mut Vec<f64>,
    ) -> Result<(), CommError> {
        let dim = packet.dim();
        let mut r = packet.payload().reader();
        out.clear();
        out.reserve(dim);
        for _ in 0..dim {
            match r.try_read_bits(32) {
                Some(bits) => out.push(f32::from_bits(bits as u32) as f64),
                None => {
                    let e = CommError::Decode(crate::coding::DecodeError::Truncated {
                        bit_pos: r.bit_pos(),
                    });
                    #[cfg(debug_assertions)]
                    debug_check_decode_error(packet, &r, &e);
                    return Err(e);
                }
            }
        }
        if r.remaining() != 0 {
            return Err(CommError::TrailingBits { bits: r.remaining() });
        }
        Ok(())
    }

    /// Identity packets frame the whole vector as one layer, so the only
    /// supported range is the full one (`0..1`); everything else declines.
    fn decode_layers_into(
        &mut self,
        packet: &WirePacket,
        layers: std::ops::Range<usize>,
        out: &mut Vec<f64>,
    ) -> Result<(), CommError> {
        if layers == (0..1) {
            return self.decode_into(packet, out);
        }
        Err(CommError::Unsupported { what: "identity partial decode" })
    }

    fn name(&self) -> &'static str {
        "uncompressed"
    }
}

/// Adaptation policy of the quantized compressor.
#[derive(Clone, Debug, PartialEq)]
pub enum Adaptation {
    /// fixed sequences forever (Q-GenX-style static global quantization)
    Fixed,
    /// re-optimize each type's levels at its current alpha (Eq. 2 fixed
    /// point) every `every` compressions
    Levels { every: usize },
    /// full L-GreCo: re-allocate per-type alphas under a total bit budget
    /// (bits/coordinate) *and* re-optimize levels every `every` compressions
    LGreco { every: usize, budget_bits_per_coord: f64, max_bits: u32 },
    /// scheduled L-GreCo driven by *receiver-observable* statistics: the
    /// codec folds histograms from the values it **decodes** and re-solves
    /// the budgeted allocation (via `quant::schedule::plan_sequences`) every
    /// `every` decodes, checked at the start of both ENC and DEC. Every
    /// party that observes a node's stream — the encoding node itself via a
    /// self-decode, a sim endpoint, the leader's per-node decoder replica —
    /// folds identical values and updates at identical counts, so schedules
    /// stay in lock-step across engines with no side channel (pinned by
    /// `tests/scheduled_parity.rs`).
    Scheduled { every: usize, budget_bits_per_coord: f64, max_bits: u32 },
}

/// Quantize + entropy-code codec (the paper's scheme).
pub struct QuantCompressor {
    pub map: LayerMap,
    pub cfg: QuantConfig,
    pub protocol: ProtocolKind,
    pub adaptation: Adaptation,
    /// worker threads for the per-layer encode stage (1 = inline);
    /// the emitted stream is bit-identical either way
    pub encode_threads: usize,
    /// run the staged reference pipeline instead of the fused kernels.
    /// Wire streams and decoded vectors are bit-identical either way —
    /// this is the A/B switch the parity suite and benches flip.
    pub staged: bool,
    books: Codebooks,
    stats: Vec<TypeStats>,
    /// receiver-side statistics: histograms folded from *decoded* values,
    /// the sole input of `Adaptation::Scheduled` updates (every observer of
    /// a stream reconstructs these identically)
    sched_stats: Vec<TypeStats>,
    rng: Rng,
    calls: usize,
    /// successful full-vector decodes — the `Scheduled` update trigger
    decodes: usize,
    last_scheduled_update: usize,
    /// running totals for reporting
    pub total_bits: u64,
    pub total_coords: u64,
    /// eps_Q of the *current* configuration (refreshed on update)
    pub current_eps_q: f64,
    // ---- reusable scratch (the no-churn hot path) ----
    /// codec-owned bit writer (swaps buffers with the packet each call)
    w: BitWriter,
    /// per-type stream-order codeword tables (rebuilt with the books)
    enc_tables: Vec<Vec<(u64, u32)>>,
    /// per-layer raw norms of the current encode (parallel fused path)
    layer_norms: Vec<f64>,
    /// f32 view of a decoded slice for the scheduled statistics fold
    sched_v32: Vec<f32>,
    // staged-path scratch
    v32: Vec<f32>,
    qv: QuantizedVector,
    dec_qv: QuantizedVector,
    out32: Vec<f32>,
}

impl QuantCompressor {
    pub fn new(
        map: LayerMap,
        cfg: QuantConfig,
        protocol: ProtocolKind,
        adaptation: Adaptation,
        seed: u64,
    ) -> Self {
        let books = Codebooks::uniform(protocol, &cfg, &map.type_proportions());
        let stats = (0..map.num_types()).map(|_| TypeStats::default()).collect();
        let sched_stats = (0..map.num_types()).map(|_| TypeStats::default()).collect();
        let eps = crate::quant::variance::eps_q_for(&map, &cfg);
        let mut c = QuantCompressor {
            map,
            cfg,
            protocol,
            adaptation,
            encode_threads: 1,
            staged: false,
            books,
            stats,
            sched_stats,
            rng: Rng::new(seed),
            calls: 0,
            decodes: 0,
            last_scheduled_update: 0,
            total_bits: 0,
            total_coords: 0,
            current_eps_q: eps,
            w: BitWriter::new(),
            enc_tables: Vec::new(),
            layer_norms: Vec::new(),
            sched_v32: Vec::new(),
            v32: Vec::new(),
            qv: QuantizedVector::default(),
            dec_qv: QuantizedVector::default(),
            out32: Vec::new(),
        };
        c.rebuild_enc_tables();
        c
    }

    /// Convenience: b-bit global quantization with bucketing (the paper's
    /// "QODA5 (bucket size 128)" configuration collapses types).
    pub fn global_bits(map: &LayerMap, bits: u32, bucket: usize, seed: u64) -> Self {
        Self::global_bits_proto(map, bits, bucket, ProtocolKind::Main, seed)
    }

    /// [`Self::global_bits`] under an explicit coding protocol (the
    /// `RunSpec` construction path parameterizes it).
    pub fn global_bits_proto(
        map: &LayerMap,
        bits: u32,
        bucket: usize,
        protocol: ProtocolKind,
        seed: u64,
    ) -> Self {
        let m = map.bucketed(bucket).with_single_type();
        let cfg = QuantConfig::uniform_bits(1, bits, 2.0);
        Self::new(m, cfg, protocol, Adaptation::Fixed, seed)
    }

    /// Layer-wise adaptive compressor: per-type sequences starting at
    /// `bits`, L-GreCo reallocation every `every` steps at the same average
    /// bit budget.
    pub fn layerwise(map: &LayerMap, bits: u32, bucket: usize, every: usize, seed: u64) -> Self {
        Self::layerwise_proto(map, bits, bucket, every, ProtocolKind::Main, seed)
    }

    /// [`Self::layerwise`] under an explicit coding protocol.
    pub fn layerwise_proto(
        map: &LayerMap,
        bits: u32,
        bucket: usize,
        every: usize,
        protocol: ProtocolKind,
        seed: u64,
    ) -> Self {
        let m = map.bucketed(bucket);
        let cfg = QuantConfig::uniform_bits(m.num_types(), bits, 2.0);
        Self::new(
            m,
            cfg,
            protocol,
            Adaptation::LGreco {
                every,
                budget_bits_per_coord: (bits + 1) as f64,
                // candidates above 6 bits are never selected at a ~6-bit
                // budget but dominate the DP's level-optimization cost
                // (alpha = 254); capping is a pure perf win (§Perf iter 5)
                max_bits: 6,
            },
            seed,
        )
    }

    /// Scheduled compressor: per-type sequences starting at the budget's
    /// uniform allocation, then receiver-driven L-GreCo re-planning every
    /// `every` decodes under `budget_bits_per_coord` total wire bits per
    /// coordinate (fixed-width model, sign included).
    pub fn scheduled_proto(
        map: &LayerMap,
        budget_bits_per_coord: f64,
        bucket: usize,
        every: usize,
        protocol: ProtocolKind,
        seed: u64,
    ) -> Self {
        let m = map.bucketed(bucket);
        // same perf-motivated ladder cap as `layerwise_proto`
        let max_bits = 6u32;
        // start uniform at the budget's per-coordinate spend (sign costs 1)
        let start = ((budget_bits_per_coord - 1.0).round() as u32).clamp(1, max_bits);
        let cfg = QuantConfig::uniform_bits(m.num_types(), start, 2.0);
        Self::new(
            m,
            cfg,
            protocol,
            Adaptation::Scheduled { every, budget_bits_per_coord, max_bits },
            seed,
        )
    }

    /// Rebuild the entropy codebooks from the statistics gathered since the
    /// last reset, *without* moving the level sequences — the lightweight
    /// half of an update step (Prop D.1 codebook synchronization).
    pub fn retune_books(&mut self) {
        self.refresh_codebooks();
    }

    fn refresh_codebooks(&mut self) {
        // scheduled adaptation builds books from the receiver-side
        // histograms so pure decoders (which never encode) reconstruct the
        // exact same books as encoding nodes
        let src = if matches!(self.adaptation, Adaptation::Scheduled { .. }) {
            &self.sched_stats
        } else {
            &self.stats
        };
        let probs: Vec<Vec<f64>> = self
            .cfg
            .sequences
            .iter()
            .enumerate()
            .map(|(m, seq)| {
                crate::coding::length::level_probabilities(&src[m].hist, seq)
            })
            .collect();
        self.books = Codebooks::build(self.protocol, &probs, &self.map.type_proportions());
        self.rebuild_enc_tables();
    }

    /// Re-snapshot every type's flat codeword table from the current books
    /// (the fused encoder's lookup surface).
    fn rebuild_enc_tables(&mut self) {
        self.enc_tables.resize_with(self.map.num_types(), Vec::new);
        for (m, tab) in self.enc_tables.iter_mut().enumerate() {
            self.books.fill_code_table(m, tab);
        }
    }

    /// The self-scheduled cadence of Algorithm 1's update set U, applied at
    /// the *start* of an encode so that packets already in flight keep
    /// decoding with the books they were encoded under.
    fn maybe_scheduled_update(&mut self) {
        let every = match self.adaptation {
            Adaptation::Levels { every } | Adaptation::LGreco { every, .. } => every,
            Adaptation::Fixed | Adaptation::Scheduled { .. } => 0,
        };
        if every > 0
            && self.calls > 0
            && self.calls % every == 0
            && self.last_scheduled_update != self.calls
        {
            self.last_scheduled_update = self.calls;
            self.update_levels();
        }
    }

    /// The `Scheduled` update trigger: fires on the *decode* counter,
    /// checked at the start of both ENC and DEC. An encoding node that
    /// self-decodes each packet (worker, sim endpoint) reaches count `t-1`
    /// before encoding packet `t`; a pure decoder replica reaches the same
    /// count before decoding packet `t` — so packet `t` is encoded *and*
    /// decoded under the post-update books on every party, and a packet is
    /// never split across an update boundary.
    fn maybe_decode_scheduled_update(&mut self) {
        let every = match self.adaptation {
            Adaptation::Scheduled { every, .. } => every,
            _ => 0,
        };
        if every > 0
            && self.decodes > 0
            && self.decodes % every == 0
            && self.last_scheduled_update != self.decodes
        {
            self.last_scheduled_update = self.decodes;
            self.update_levels();
        }
    }

    /// Fold a successfully decoded vector into the receiver-side statistics
    /// and advance the `Scheduled` decode counter. Decoded values are
    /// identical on every observer of the stream (wire determinism), so the
    /// folded histograms — and therefore the schedules they drive — are too.
    fn fold_scheduled_stats(&mut self, out: &[f64]) {
        if !matches!(self.adaptation, Adaptation::Scheduled { .. }) {
            return;
        }
        for l in &self.map.layers {
            self.sched_v32.clear();
            let s = &out[l.offset..l.offset + l.len];
            // audit:allow(lossy-cast) — receiver-side statistics fold at the fp32 wire precision
            self.sched_v32.extend(s.iter().map(|&x| x as f32));
            self.sched_stats[l.type_id].add_layer_sample(&self.sched_v32, self.cfg.q);
        }
        self.decodes += 1;
    }

    /// Staged reference ENC: four explicit passes (f32 copy, statistics
    /// sweep, quantize into wire form, entropy-code).
    fn encode_staged(&mut self, v: &[f64], packet: &mut WirePacket)
        -> Result<(), CommError> {
        self.v32.clear();
        // audit:allow(lossy-cast) — the staged reference quantizes from fp32, like the wire contract
        self.v32.extend(v.iter().map(|&x| x as f32));
        {
            // per-type statistics for the next update step
            let (stats, map, cfg, v32) =
                (&mut self.stats, &self.map, &self.cfg, &self.v32);
            for l in &map.layers {
                stats[l.type_id]
                    .add_layer_sample(&v32[l.offset..l.offset + l.len], cfg.q);
            }
        }
        quantize_into(&self.v32, &self.map, &self.cfg, &mut self.rng, &mut self.qv);

        let w = &mut self.w;
        packet.begin_encode(v.len(), w);
        let threads = self.encode_threads;
        if threads > 1 && self.qv.layers.len() >= 2 * threads {
            encode_layers_parallel(&self.qv.layers, &self.books, threads, w, packet)?;
        } else {
            for layer in &self.qv.layers {
                packet.mark_layer(w.len_bits());
                encode_layer(layer, &self.books, w);
            }
        }
        packet.finish_encode(w);
        Ok(())
    }

    /// Fused ENC: one pass per layer folds norm, statistics, stochastic
    /// rounding and entropy coding (no intermediate wire form).
    fn encode_fused(&mut self, v: &[f64], packet: &mut WirePacket)
        -> Result<(), CommError> {
        assert_eq!(v.len(), self.map.dim);
        let threads = self.encode_threads;
        if threads > 1 && self.map.layers.len() >= 2 * threads {
            return self.encode_fused_parallel(v, packet);
        }
        let Self {
            ref map,
            ref cfg,
            ref mut stats,
            ref mut rng,
            ref mut w,
            ref enc_tables,
            ..
        } = *self;
        packet.begin_encode(v.len(), w);
        for l in &map.layers {
            let s = &v[l.offset..l.offset + l.len];
            let raw = fused::layer_norm_f32(s, cfg.q);
            fused::fold_layer_stats(s, raw, &mut stats[l.type_id]);
            packet.mark_layer(w.len_bits());
            fused::encode_layer_body(
                s,
                &cfg.sequences[l.type_id],
                raw,
                &enc_tables[l.type_id],
                rng,
                w,
            );
        }
        packet.finish_encode(w);
        Ok(())
    }

    /// Parallel fused ENC: a sequential pass computes per-layer norms and
    /// folds statistics (preserving the staged accumulation order), then
    /// layer chunks encode on scoped workers whose RNG clones are advanced
    /// to exactly the draw position a sequential encode would reach — the
    /// spliced stream and the final RNG state are bit-identical to
    /// `encode_fused` with `encode_threads == 1`.
    fn encode_fused_parallel(&mut self, v: &[f64], packet: &mut WirePacket)
        -> Result<(), CommError> {
        let threads = self.encode_threads;
        let Self {
            ref map,
            ref cfg,
            ref mut stats,
            ref mut rng,
            ref mut w,
            ref enc_tables,
            ref mut layer_norms,
            ..
        } = *self;
        layer_norms.clear();
        for l in &map.layers {
            let s = &v[l.offset..l.offset + l.len];
            let raw = fused::layer_norm_f32(s, cfg.q);
            fused::fold_layer_stats(s, raw, &mut stats[l.type_id]);
            layer_norms.push(raw);
        }
        let chunk = map.layers.len().div_ceil(threads);
        // worker RNGs: one clone per chunk, advanced past the draws of all
        // preceding chunks (one `next_u64` per coordinate of every layer
        // with a positive f32-rounded norm)
        let mut worker_rngs: Vec<Rng> = Vec::with_capacity(threads);
        // audit:allow(rng-clone) — parallel-splice site: the cursor below replays the leader stream
        let mut splice_rng = rng.clone();
        for (chunk_layers, chunk_norms) in
            map.layers.chunks(chunk).zip(layer_norms.chunks(chunk))
        {
            // audit:allow(rng-clone) — worker seed = leader stream advanced past all prior chunks' draws
            worker_rngs.push(splice_rng.clone());
            let draws: usize = chunk_layers
                .iter()
                .zip(chunk_norms)
                .map(|(l, &raw)| fused::layer_draws(raw, l.len))
                .sum();
            for _ in 0..draws {
                splice_rng.next_u64();
            }
        }
        *rng = splice_rng; // final state == sequential encode's end state

        let mut parts: Vec<Option<(Vec<usize>, BitBuf)>> = Vec::with_capacity(threads);
        std::thread::scope(|scope| {
            let handles: Vec<_> = map
                .layers
                .chunks(chunk)
                .zip(layer_norms.chunks(chunk))
                .zip(worker_rngs)
                .map(|((chunk_layers, chunk_norms), mut crng)| {
                    scope.spawn(move || {
                        let mut lw = BitWriter::new();
                        let mut offs = Vec::with_capacity(chunk_layers.len());
                        for (l, &raw) in chunk_layers.iter().zip(chunk_norms) {
                            let s = &v[l.offset..l.offset + l.len];
                            offs.push(lw.len_bits());
                            fused::encode_layer_body(
                                s,
                                &cfg.sequences[l.type_id],
                                raw,
                                &enc_tables[l.type_id],
                                &mut crng,
                                &mut lw,
                            );
                        }
                        (offs, lw.finish())
                    })
                })
                .collect();
            for h in handles {
                parts.push(h.join().ok());
            }
        });
        let panicked = parts.iter().filter(|p| p.is_none()).count();
        if panicked > 0 {
            return Err(CommError::EncodeWorker { panicked });
        }
        packet.begin_encode(v.len(), w);
        for (offs, buf) in parts.into_iter().flatten() {
            let base = w.len_bits();
            for &o in &offs {
                packet.mark_layer(base + o);
            }
            w.append(&buf);
        }
        packet.finish_encode(w);
        Ok(())
    }

    /// DEC body shared by the staged and fused paths; split out so
    /// `decode_into` can inspect the reader position when it errors.
    fn decode_body(
        &mut self,
        r: &mut crate::coding::bitio::BitReader<'_>,
        out: &mut Vec<f64>,
    ) -> Result<(), CommError> {
        if self.staged {
            decode_vector_into(r, &self.map, &self.books, &mut self.dec_qv)?;
            if r.remaining() != 0 {
                return Err(CommError::TrailingBits { bits: r.remaining() });
            }
            dequantize_into(&self.dec_qv, &self.cfg, &mut self.out32);
            out.clear();
            out.extend(self.out32.iter().map(|&x| x as f64));
        } else {
            fused::decode_vector_fused(r, &self.map, &self.books, &self.cfg, out)?;
            if r.remaining() != 0 {
                return Err(CommError::TrailingBits { bits: r.remaining() });
            }
        }
        Ok(())
    }
}

/// Decode-error invariant (debug builds): whatever the failure, the reader
/// must have stopped inside the payload, and the error's reported bit
/// position must point inside it too — a decoder that runs past the end or
/// reports a phantom position is a bug even when it correctly errors.
#[cfg(debug_assertions)]
fn debug_check_decode_error(
    packet: &WirePacket,
    r: &crate::coding::bitio::BitReader<'_>,
    e: &CommError,
) {
    let len = packet.len_bits();
    debug_assert!(
        r.bit_pos() <= len,
        "decode error left the reader at bit {} of a {len}-bit payload",
        r.bit_pos()
    );
    if let CommError::Decode(d) = e {
        let reported = match *d {
            crate::coding::DecodeError::Truncated { bit_pos }
            | crate::coding::DecodeError::InvalidCode { bit_pos } => bit_pos,
        };
        debug_assert!(
            reported <= len,
            "decode error reports bit {reported} outside the {len}-bit payload"
        );
    }
}

impl Compressor for QuantCompressor {
    fn encode_into(&mut self, v: &[f64], packet: &mut WirePacket)
        -> Result<(), CommError> {
        self.maybe_scheduled_update();
        self.maybe_decode_scheduled_update();
        if self.staged {
            self.encode_staged(v, packet)?;
        } else {
            self.encode_fused(v, packet)?;
        }
        self.total_bits += packet.len_bits() as u64;
        self.total_coords += v.len() as u64;
        self.calls += 1;
        Ok(())
    }

    fn decode_into(
        &mut self,
        packet: &WirePacket,
        out: &mut Vec<f64>,
    ) -> Result<(), CommError> {
        self.maybe_decode_scheduled_update();
        if packet.dim() != self.map.dim {
            return Err(CommError::DimMismatch { want: self.map.dim, got: packet.dim() });
        }
        let mut r = packet.payload().reader();
        let res = self.decode_body(&mut r, out);
        #[cfg(debug_assertions)]
        if let Err(ref e) = res {
            debug_check_decode_error(packet, &r, e);
        }
        if res.is_ok() {
            self.fold_scheduled_stats(out);
        }
        res
    }

    /// Shard DEC through the fused ranged kernel. The fused path is pinned
    /// bit-identical to the staged one, so this serves both `staged`
    /// settings: shard decodes concatenate to exactly what either full
    /// decode produces.
    fn decode_layers_into(
        &mut self,
        packet: &WirePacket,
        layers: std::ops::Range<usize>,
        out: &mut Vec<f64>,
    ) -> Result<(), CommError> {
        if matches!(self.adaptation, Adaptation::Scheduled { .. }) {
            // a shard observer sees only part of the stream, so it cannot
            // fold the full-vector statistics the schedule trigger needs;
            // the sharded transports pin Adaptation::Fixed anyway
            return Err(CommError::Unsupported {
                what: "partial decode under scheduled adaptation",
            });
        }
        let total = self.map.layers.len();
        if layers.start > layers.end || layers.end > total {
            return Err(CommError::ShardRange {
                start: layers.start,
                end: layers.end,
                layers: total,
            });
        }
        let run = &self.map.layers[layers];
        let want: usize = run.iter().map(|l| l.len).sum();
        if packet.dim() != want {
            return Err(CommError::DimMismatch { want, got: packet.dim() });
        }
        let mut r = packet.payload().reader();
        let res = (|| {
            fused::decode_layers_fused(&mut r, run, &self.books, &self.cfg, out)?;
            if r.remaining() != 0 {
                return Err(CommError::TrailingBits { bits: r.remaining() });
            }
            Ok(())
        })();
        #[cfg(debug_assertions)]
        if let Err(ref e) = res {
            debug_check_decode_error(packet, &r, e);
        }
        res
    }

    fn update_levels(&mut self) {
        match self.adaptation {
            Adaptation::Fixed => {}
            Adaptation::Levels { .. } => {
                let alphas: Vec<usize> =
                    self.cfg.sequences.iter().map(|s| s.alpha()).collect();
                let (seqs, _) = crate::quant::adaptive::adapt_all(&self.stats, &alphas, 6);
                self.cfg.sequences = seqs;
            }
            Adaptation::LGreco { budget_bits_per_coord, max_bits, .. } => {
                // budgeted re-plan from the encode-side statistics (error
                // curves per *type* — types share statistics — with sizes
                // aggregated over layers of that type); the solve lives in
                // quant::schedule and is bit-identical to the historical
                // inline DP arm
                self.cfg.sequences = crate::quant::schedule::plan_sequences(
                    &self.map,
                    &self.stats,
                    budget_bits_per_coord,
                    max_bits,
                );
            }
            Adaptation::Scheduled { budget_bits_per_coord, max_bits, .. } => {
                // same solve, driven by the receiver-side statistics every
                // observer of the stream reconstructs identically
                self.cfg.sequences = crate::quant::schedule::plan_sequences(
                    &self.map,
                    &self.sched_stats,
                    budget_bits_per_coord,
                    max_bits,
                );
            }
        }
        self.refresh_codebooks();
        self.current_eps_q = crate::quant::variance::eps_q_for(&self.map, &self.cfg);
        for s in &mut self.stats {
            s.reset();
        }
        for s in &mut self.sched_stats {
            s.reset();
        }
    }

    fn name(&self) -> &'static str {
        match self.adaptation {
            Adaptation::Fixed => "quantized-global",
            Adaptation::Levels { .. } => "quantized-adaptive",
            Adaptation::LGreco { .. } => "quantized-lgreco",
            Adaptation::Scheduled { .. } => "quantized-scheduled",
        }
    }
}

/// Entropy-code the layers on `threads` scoped worker threads and splice
/// the chunk streams back in layer order. Bit-identical to the sequential
/// path: concatenating per-layer segments IS the sequential stream. A
/// panicking worker is contained and reported as
/// [`CommError::EncodeWorker`]; nothing is spliced in that case.
fn encode_layers_parallel(
    layers: &[QuantizedLayer],
    books: &Codebooks,
    threads: usize,
    w: &mut BitWriter,
    packet: &mut WirePacket,
) -> Result<(), CommError> {
    let chunk = layers.len().div_ceil(threads);
    let mut parts: Vec<Option<(Vec<usize>, BitBuf)>> = Vec::with_capacity(threads);
    std::thread::scope(|scope| {
        let handles: Vec<_> = layers
            .chunks(chunk)
            .map(|chunk_layers| {
                scope.spawn(move || {
                    let mut lw = BitWriter::new();
                    let mut offs = Vec::with_capacity(chunk_layers.len());
                    for layer in chunk_layers {
                        offs.push(lw.len_bits());
                        encode_layer(layer, books, &mut lw);
                    }
                    (offs, lw.finish())
                })
            })
            .collect();
        for h in handles {
            parts.push(h.join().ok());
        }
    });
    let panicked = parts.iter().filter(|p| p.is_none()).count();
    if panicked > 0 {
        return Err(CommError::EncodeWorker { panicked });
    }
    for (offs, buf) in parts.into_iter().flatten() {
        let base = w.len_bits();
        for &o in &offs {
            packet.mark_layer(base + o);
        }
        w.append(&buf);
    }
    Ok(())
}

/// Build a default level sequence set for an adaptive start.
pub fn default_sequences(num_types: usize, bits: u32) -> Vec<LevelSequence> {
    (0..num_types).map(|_| LevelSequence::bits(bits)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::DecodeError;

    fn grad_like(map: &LayerMap, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..map.dim)
            .map(|i| rng.gaussian() * if i % 3 == 0 { 2.0 } else { 0.05 })
            .collect()
    }

    /// encode + self-decode, as a loopback node would.
    fn roundtrip(c: &mut dyn Compressor, v: &[f64]) -> (Vec<f64>, usize) {
        let packet = c.encode(v).expect("loopback encode");
        let out = c.decode(&packet).expect("loopback decode");
        (out, packet.len_bits())
    }

    #[test]
    fn identity_costs_32_bits_per_coord() {
        let mut c = IdentityCompressor::new();
        let (out, bits) = roundtrip(&mut c, &[1.0, 2.0, 3.0]);
        assert_eq!(out, vec![1.0, 2.0, 3.0]);
        assert_eq!(bits, 96);
    }

    #[test]
    fn identity_wire_is_f32_rounded() {
        let mut c = IdentityCompressor::new();
        let v = [std::f64::consts::PI];
        let (out, _) = roundtrip(&mut c, &v);
        assert_eq!(out[0], std::f64::consts::PI as f32 as f64);
    }

    #[test]
    fn quantized_reduces_bits() {
        let map = LayerMap::from_spec(&[("a", 1000, "ff"), ("b", 500, "bias")]);
        let mut c = QuantCompressor::global_bits(&map, 5, 128, 1);
        let v = grad_like(&map, 2);
        let (out, bits) = roundtrip(&mut c, &v);
        assert_eq!(out.len(), v.len());
        assert!(bits < 1500 * 32, "{bits}");
        assert!(bits > 0);
    }

    #[test]
    fn packet_layer_offsets_frame_the_stream() {
        let map = LayerMap::from_spec(&[("a", 64, "ff"), ("b", 32, "bias")]).bucketed(16);
        let mut c = QuantCompressor::new(
            map.clone(),
            QuantConfig::uniform_bits(2, 4, 2.0),
            ProtocolKind::Main,
            Adaptation::Fixed,
            3,
        );
        let packet = c.encode(&grad_like(&map, 4)).expect("encode");
        assert_eq!(packet.layer_offsets().len(), map.layers.len());
        assert_eq!(packet.layer_offsets()[0], 0);
        // offsets strictly increase and stay inside the payload
        for w in packet.layer_offsets().windows(2) {
            assert!(w[0] < w[1]);
        }
        assert!(*packet.layer_offsets().last().unwrap() < packet.len_bits());
        assert_eq!(packet.dim(), map.dim);
    }

    #[test]
    fn fused_and_staged_packets_are_bit_identical() {
        // the cheap in-module pin; the full protocol × adaptation × seed ×
        // thread grid lives in tests/fused_parity.rs
        let map = LayerMap::from_spec(&[("a", 700, "ff"), ("b", 300, "emb")]).bucketed(128);
        let mk = |staged: bool| {
            let mut c = QuantCompressor::new(
                map.clone(),
                QuantConfig::uniform_bits(2, 5, 2.0),
                ProtocolKind::Main,
                Adaptation::Fixed,
                77,
            );
            c.staged = staged;
            c
        };
        let (mut cf, mut cs) = (mk(false), mk(true));
        for step in 0..3 {
            let v = grad_like(&map, 400 + step);
            let pf = cf.encode(&v).expect("fused encode");
            let ps = cs.encode(&v).expect("staged encode");
            assert_eq!(pf.payload(), ps.payload(), "step {step}");
            assert_eq!(pf.layer_offsets(), ps.layer_offsets());
            let df = cf.decode(&pf).expect("fused decode");
            let ds = cs.decode(&ps).expect("staged decode");
            assert_eq!(df.len(), ds.len());
            for (a, b) in df.iter().zip(&ds) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn shard_decodes_concatenate_to_the_full_decode() {
        let map = LayerMap::from_spec(&[("a", 500, "ff"), ("b", 250, "emb")]);
        let mut c = QuantCompressor::global_bits(&map, 5, 64, 17);
        let v = grad_like(&map, 18);
        let packet = c.encode(&v).expect("encode");
        let full = c.decode(&packet).expect("full decode");
        let nl = c.map.layers.len();
        assert!(nl >= 3, "bucketing should split the map, got {nl} layer(s)");
        // partition the layers into three contiguous owner ranges, decode
        // each range's shard independently, concatenate in range order
        let cuts = [0, nl / 3, 2 * nl / 3, nl];
        let mut cat: Vec<f64> = Vec::new();
        for w in cuts.windows(2) {
            let (lo, hi) = (w[0], w[1]);
            let dim: usize = c.map.layers[lo..hi].iter().map(|l| l.len).sum();
            let shard = packet.shard(lo..hi, dim).expect("shard");
            let mut part = Vec::new();
            c.decode_layers_into(&shard, lo..hi, &mut part).expect("shard decode");
            assert_eq!(part.len(), dim);
            cat.extend(part);
        }
        assert_eq!(cat.len(), full.len());
        for (i, (a, b)) in full.iter().zip(&cat).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "coord {i}");
        }
    }

    #[test]
    fn ranged_decode_validates_range_and_dim() {
        let map = LayerMap::from_spec(&[("a", 128, "ff")]);
        let mut c = QuantCompressor::global_bits(&map, 4, 32, 3);
        let packet = c.encode(&grad_like(&map, 4)).expect("encode");
        let nl = c.map.layers.len();
        let mut out = Vec::new();
        assert!(matches!(
            c.decode_layers_into(&packet, 0..nl + 1, &mut out),
            Err(CommError::ShardRange { .. })
        ));
        // the full packet under a sub-range: coordinate widths disagree
        assert!(matches!(
            c.decode_layers_into(&packet, 0..1, &mut out),
            Err(CommError::DimMismatch { .. })
        ));
        // identity codecs decline everything but the full single-layer range
        let mut id = IdentityCompressor::new();
        let idp = id.encode(&[1.0, 2.0]).expect("encode");
        id.decode_layers_into(&idp, 0..1, &mut out).expect("full range");
        assert_eq!(out, vec![1.0, 2.0]);
        assert!(matches!(
            id.decode_layers_into(&idp, 0..0, &mut out),
            Err(CommError::Unsupported { .. })
        ));
    }

    #[test]
    fn parallel_layer_encode_is_bit_identical() {
        let map = LayerMap::single(4096).bucketed(128);
        let v = grad_like(&map, 7);
        let mk = |threads, staged| {
            let mut c = QuantCompressor::global_bits(&map, 5, 128, 11);
            c.encode_threads = threads;
            c.staged = staged;
            c.encode(&v).expect("encode")
        };
        for staged in [false, true] {
            let seq = mk(1, staged);
            for threads in [2, 4] {
                let par = mk(threads, staged);
                assert_eq!(par.payload(), seq.payload(), "threads={threads} staged={staged}");
                assert_eq!(par.layer_offsets(), seq.layer_offsets());
                assert_eq!(par.len_bits(), seq.len_bits());
            }
        }
    }

    #[test]
    fn encode_worker_panic_is_an_error() {
        // force a worker panic by desynchronizing the level sequences from
        // the built codebooks: symbols beyond the books' alphabet index out
        // of range inside the workers, which must surface as EncodeWorker
        // rather than poisoning the engine thread
        for staged in [false, true] {
            let map = LayerMap::single(256).bucketed(32).with_single_type();
            let mut c = QuantCompressor::new(
                map,
                QuantConfig::uniform_bits(1, 2, 2.0),
                ProtocolKind::Main,
                Adaptation::Fixed,
                1,
            );
            c.encode_threads = 2;
            c.staged = staged;
            // books/tables still cover 4 symbols; the sequence now produces
            // indices up to 63
            c.cfg.sequences = vec![LevelSequence::bits(6)];
            let v = vec![1.0f64; 256];
            match c.encode(&v) {
                Err(CommError::EncodeWorker { panicked }) => assert!(panicked > 0),
                other => panic!("want EncodeWorker (staged={staged}), got {other:?}"),
            }
        }
    }

    #[test]
    fn corrupt_packet_surfaces_comm_error() {
        let map = LayerMap::single(256);
        let mut c = QuantCompressor::global_bits(&map, 5, 128, 5);
        let packet = c.encode(&grad_like(&map, 6)).expect("encode");
        // truncate the payload to its first 50 bits
        let mut w = BitWriter::new();
        let mut r = packet.payload().reader();
        w.write_bits(r.read_bits(50), 50);
        let cut = WirePacket::from_raw(w.finish(), packet.layer_offsets().to_vec(), map.dim);
        let err = c.decode(&cut);
        assert!(
            matches!(err, Err(CommError::Decode(DecodeError::Truncated { .. }))),
            "want Truncated, got {err:?}"
        );
    }

    #[test]
    fn trailing_bits_are_an_error() {
        let map = LayerMap::single(128);
        let mut c = QuantCompressor::global_bits(&map, 4, 128, 13);
        let packet = c.encode(&grad_like(&map, 14)).expect("encode");
        // append garbage past the legitimate stream
        let mut w = BitWriter::new();
        let mut r = packet.payload().reader();
        let n = packet.len_bits();
        let mut left = n;
        while left > 0 {
            let take = left.min(64) as u32;
            w.write_bits(r.read_bits(take), take);
            left -= take as usize;
        }
        w.write_bits(0x5A5A, 16);
        let long =
            WirePacket::from_raw(w.finish(), packet.layer_offsets().to_vec(), map.dim);
        assert!(matches!(c.decode(&long), Err(CommError::TrailingBits { bits: 16 })));
    }

    #[test]
    fn dim_mismatch_is_an_error() {
        let map = LayerMap::single(64);
        let mut c = QuantCompressor::global_bits(&map, 4, 128, 9);
        let packet = c.encode(&grad_like(&map, 10)).expect("encode");
        let wrong = WirePacket::from_raw(
            packet.payload().clone(),
            packet.layer_offsets().to_vec(),
            63,
        );
        assert!(matches!(c.decode(&wrong), Err(CommError::DimMismatch { .. })));
    }

    #[test]
    fn compression_error_bounded_by_eps() {
        let map = LayerMap::from_spec(&[("a", 512, "ff")]);
        let mut c = QuantCompressor::global_bits(&map, 5, 128, 3);
        let v = grad_like(&map, 4);
        let norm2: f64 = v.iter().map(|x| x * x).sum();
        let mut err_acc = 0.0;
        let reps = 30;
        for _ in 0..reps {
            let (out, _) = roundtrip(&mut c, &v);
            err_acc += v.iter().zip(&out).map(|(a, b)| (a - b).powi(2)).sum::<f64>();
        }
        let ratio = err_acc / reps as f64 / norm2;
        assert!(ratio <= c.current_eps_q * 1.1, "{ratio} vs {}", c.current_eps_q);
    }

    #[test]
    fn adaptation_reduces_bits_or_error() {
        let map = LayerMap::from_spec(&[("a", 2048, "ff"), ("e", 512, "embedding")]);
        let mut c = QuantCompressor::layerwise(&map, 5, 1 << 30, 10, 5);
        let mut bits_before = 0usize;
        let mut bits_after = 0usize;
        for i in 0..30 {
            let v = grad_like(&map, 100 + i);
            let (_, b) = roundtrip(&mut c, &v);
            if i < 10 {
                bits_before += b;
            }
            if i >= 20 {
                bits_after += b;
            }
        }
        // after two L-GreCo updates the entropy coder + level placement must
        // not be worse than the cold-start uniform configuration
        assert!(
            bits_after as f64 <= bits_before as f64 * 1.05,
            "{bits_after} vs {bits_before}"
        );
    }

    #[test]
    fn retuned_books_do_not_grow_the_stream() {
        let map = LayerMap::single(4096).bucketed(128);
        let mut c = QuantCompressor::global_bits(&map, 5, 128, 21);
        let v = grad_like(&map, 22);
        let (_, cold) = roundtrip(&mut c, &v);
        c.retune_books();
        let (_, tuned) = roundtrip(&mut c, &v);
        assert!(tuned as f64 <= cold as f64 * 1.01, "{tuned} vs {cold}");
    }

    #[test]
    fn scheduled_observers_stay_bit_identical() {
        // node A encodes + self-decodes each packet; observer B only
        // decodes A's stream. Both fold the same decoded values, so when
        // the decode-count trigger fires their re-planned sequences and
        // books agree and decodes stay bit-identical across updates.
        let map = LayerMap::from_spec(&[("a", 600, "ff"), ("e", 200, "embedding")]);
        let mk = || {
            QuantCompressor::scheduled_proto(&map, 5.0, 1 << 30, 3, ProtocolKind::Main, 9)
        };
        let (mut a, mut b) = (mk(), mk());
        assert_eq!(a.name(), "quantized-scheduled");
        for step in 0..10 {
            let v = grad_like(&map, 700 + step);
            let p = a.encode(&v).expect("encode");
            let da = a.decode(&p).expect("self decode");
            let db = b.decode(&p).expect("observer decode");
            for (x, y) in da.iter().zip(&db) {
                assert_eq!(x.to_bits(), y.to_bits(), "step {step}");
            }
        }
    }

    #[test]
    fn scheduled_declines_partial_decode() {
        let map = LayerMap::from_spec(&[("a", 256, "ff"), ("b", 128, "bias")]);
        let mut c = QuantCompressor::scheduled_proto(
            &map,
            4.0,
            64,
            0, // never updates; the decline is unconditional under Scheduled
            ProtocolKind::Main,
            3,
        );
        let packet = c.encode(&grad_like(&map, 4)).expect("encode");
        let mut out = Vec::new();
        assert!(matches!(
            c.decode_layers_into(&packet, 0..1, &mut out),
            Err(CommError::Unsupported { .. })
        ));
        // the full decode path still works
        c.decode_into(&packet, &mut out).expect("full decode");
        assert_eq!(out.len(), map.dim);
    }

    #[test]
    fn update_levels_keeps_roundtrip_consistent() {
        let map = LayerMap::from_spec(&[("a", 300, "ff")]);
        let mut c = QuantCompressor::new(
            map.clone(),
            QuantConfig::uniform_bits(1, 4, 2.0),
            ProtocolKind::Alternating,
            Adaptation::Levels { every: 3 },
            7,
        );
        for i in 0..12 {
            let v = grad_like(&map, 50 + i);
            let (out, _) = roundtrip(&mut c, &v);
            // unbiased-ish: reconstruction correlates positively
            let dot: f64 = v.iter().zip(&out).map(|(a, b)| a * b).sum();
            assert!(dot > 0.0);
        }
    }
}

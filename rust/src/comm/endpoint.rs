//! One node's end of the broadcast: a codec plus its packet scratch.

use super::codec::Compressor;
use super::packet::WirePacket;
use super::CommError;

/// A node-side comm endpoint. Both coordinator engines hold one per node;
/// the packet buffer is owned here so repeated exchanges recycle the same
/// allocation, and the *engine* reads the authoritative wire size off the
/// packet rather than trusting the codec's self-report.
pub struct CommEndpoint {
    codec: Box<dyn Compressor>,
    packet: WirePacket,
}

impl CommEndpoint {
    pub fn new(codec: Box<dyn Compressor>) -> Self {
        CommEndpoint { codec, packet: WirePacket::new() }
    }

    /// ENC the node's dual vector into the endpoint's packet; returns the
    /// actual encoded payload size in bits.
    pub fn send(&mut self, v: &[f64]) -> Result<usize, CommError> {
        self.codec.encode_into(v, &mut self.packet)?;
        Ok(self.packet.len_bits())
    }

    /// DEC the last sent packet into `out`, exactly as a receiving node
    /// would decode it off the wire.
    pub fn recv_into(&mut self, out: &mut Vec<f64>) -> Result<(), CommError> {
        self.codec.decode_into(&self.packet, out)
    }

    /// ENC + loopback DEC in one call: the self-decode every node performs
    /// so that all K nodes apply identical values. Returns the wire bits.
    pub fn roundtrip_into(&mut self, v: &[f64], out: &mut Vec<f64>) -> Result<usize, CommError> {
        let bits = self.send(v)?;
        self.recv_into(out)?;
        Ok(bits)
    }

    /// The last encoded packet (what actually travels).
    pub fn packet(&self) -> &WirePacket {
        &self.packet
    }

    pub fn codec(&self) -> &dyn Compressor {
        self.codec.as_ref()
    }

    pub fn codec_mut(&mut self) -> &mut dyn Compressor {
        self.codec.as_mut()
    }

    pub fn update_levels(&mut self) {
        self.codec.update_levels();
    }

    pub fn name(&self) -> &'static str {
        self.codec.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::codec::IdentityCompressor;

    #[test]
    fn endpoint_roundtrip_reports_real_bits() {
        let mut ep = CommEndpoint::new(Box::new(IdentityCompressor::new()));
        let mut out = Vec::new();
        let bits = ep.roundtrip_into(&[1.0, -2.0], &mut out).unwrap();
        assert_eq!(bits, 64);
        assert_eq!(ep.packet().len_bits(), 64);
        assert_eq!(out, vec![1.0, -2.0]);
        assert_eq!(ep.name(), "uncompressed");
    }
}

//! Error feedback (EF14/EF21-style compensation): keep the quantization
//! residual on the encoder and fold it into the next dual before
//! compressing ("Quantized Adam with Error Feedback").
//!
//! [`FeedbackCompressor`] wraps any inner [`Compressor`]. On encode it
//! compresses the *compensated* vector `v + e_t`, immediately self-decodes
//! the packet it just produced, and stores the new residual
//! `e_{t+1} = (v + e_t) - Q(v + e_t)`. What travels on the wire is exactly
//! the inner codec's packet for the compensated vector, so receivers decode
//! it with the inner codec's ordinary decode path — no receiver-side state,
//! and the staged/fused parity pin of the inner codec carries over
//! unchanged (the wrapper never reaches into the coding layer).
//!
//! Because the encoder self-decodes its own packet, an inner codec with
//! decode-count-triggered scheduling (`Adaptation::Scheduled`) sees **two**
//! decodes per exchanged packet on the encoding node (the self-decode plus
//! the engine's aggregate decode) and one on pure receivers of other nodes'
//! streams. Constructors that combine EF with scheduling therefore double
//! the inner `every` (see `CompressionSpec`/`GanCompression`), which keeps
//! updates firing at encode boundaries only — never between a packet's
//! encode and its aggregate decode.

use super::codec::Compressor;
use super::packet::WirePacket;
use super::CommError;

/// Error-feedback wrapper: residual-compensated encode over any inner codec.
pub struct FeedbackCompressor {
    inner: Box<dyn Compressor>,
    /// e_t — the accumulated compression error, one entry per coordinate
    residual: Vec<f64>,
    /// scratch: v + e_t, the vector actually handed to the inner codec
    compensated: Vec<f64>,
    /// scratch: the self-decoded Q(v + e_t)
    decoded: Vec<f64>,
}

impl FeedbackCompressor {
    pub fn new(inner: Box<dyn Compressor>) -> Self {
        FeedbackCompressor {
            inner,
            residual: Vec::new(),
            compensated: Vec::new(),
            decoded: Vec::new(),
        }
    }

    /// The wrapped codec.
    pub fn inner(&self) -> &dyn Compressor {
        self.inner.as_ref()
    }

    /// Mutable access to the wrapped codec (tests retune books through it).
    pub fn inner_mut(&mut self) -> &mut dyn Compressor {
        self.inner.as_mut()
    }

    /// l2 norm of the current residual — bounded over a run when the inner
    /// codec is a contraction on the compensated vector.
    pub fn residual_norm(&self) -> f64 {
        self.residual.iter().map(|x| x * x).sum::<f64>().sqrt()
    }
}

impl Compressor for FeedbackCompressor {
    fn encode_into(&mut self, v: &[f64], packet: &mut WirePacket)
        -> Result<(), CommError> {
        if self.residual.len() != v.len() {
            // first call (or a dimension change): start from zero error
            self.residual.clear();
            self.residual.resize(v.len(), 0.0);
        }
        self.compensated.clear();
        self.compensated
            .extend(v.iter().zip(&self.residual).map(|(&x, &e)| x + e));
        self.inner.encode_into(&self.compensated, packet)?;
        // self-decode the freshly produced packet: the residual must be
        // measured against exactly what receivers will reconstruct
        self.inner.decode_into(packet, &mut self.decoded)?;
        for ((e, &c), &d) in self
            .residual
            .iter_mut()
            .zip(&self.compensated)
            .zip(&self.decoded)
        {
            *e = c - d;
        }
        Ok(())
    }

    fn decode_into(
        &mut self,
        packet: &WirePacket,
        out: &mut Vec<f64>,
    ) -> Result<(), CommError> {
        // EF is encoder-side only: receiving is the inner codec's decode
        self.inner.decode_into(packet, out)
    }

    fn decode_layers_into(
        &mut self,
        packet: &WirePacket,
        layers: std::ops::Range<usize>,
        out: &mut Vec<f64>,
    ) -> Result<(), CommError> {
        self.inner.decode_layers_into(packet, layers, out)
    }

    fn update_levels(&mut self) {
        self.inner.update_levels();
    }

    fn name(&self) -> &'static str {
        "error-feedback"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::codec::{Adaptation, QuantCompressor};
    use crate::quant::{LayerMap, QuantConfig};
    use crate::stats::rng::Rng;

    fn grad_like(dim: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..dim).map(|_| rng.gaussian() * 0.3).collect()
    }

    fn quant(map: &LayerMap, bits: u32, seed: u64) -> Box<dyn Compressor> {
        Box::new(QuantCompressor::new(
            map.clone(),
            QuantConfig::uniform_bits(map.num_types(), bits, 2.0),
            crate::coding::protocol::ProtocolKind::Main,
            Adaptation::Fixed,
            seed,
        ))
    }

    #[test]
    fn wire_is_the_inner_packet_for_the_compensated_vector() {
        let map = LayerMap::single(256).bucketed(64);
        let mut ef = FeedbackCompressor::new(quant(&map, 3, 7));
        let mut plain = QuantCompressor::new(
            map.clone(),
            QuantConfig::uniform_bits(map.num_types(), 3, 2.0),
            crate::coding::protocol::ProtocolKind::Main,
            Adaptation::Fixed,
            7,
        );
        let v = grad_like(map.dim, 8);
        // step 1: residual is zero, so EF's packet == plain packet
        let p_ef = ef.encode(&v).expect("ef encode");
        let p_plain = plain.encode(&v).expect("plain encode");
        assert_eq!(p_ef.payload(), p_plain.payload());
        // receivers decode with the ordinary path
        let d = ef.decode(&p_ef).expect("decode");
        assert_eq!(d.len(), v.len());
    }

    #[test]
    fn residual_tracks_compression_error() {
        let map = LayerMap::single(512).bucketed(128);
        let mut ef = FeedbackCompressor::new(quant(&map, 2, 21));
        let v = grad_like(map.dim, 22);
        ef.encode(&v).expect("encode");
        let r1 = ef.residual_norm();
        assert!(r1 > 0.0, "2-bit quantization must leave a residual");
        // residual stays bounded across steps (no blow-up)
        let mut last = r1;
        for s in 0..20 {
            ef.encode(&grad_like(map.dim, 100 + s)).expect("encode");
            last = ef.residual_norm();
        }
        let vnorm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!(last < 4.0 * vnorm, "residual blew up: {last} vs |v|={vnorm}");
    }

    #[test]
    fn compensation_reduces_accumulated_error() {
        // the EF telescoping sum: after T steps the accumulated decoded
        // stream is within one residual of the accumulated input stream,
        // while the uncompensated codec's errors add up independently
        let map = LayerMap::single(512).bucketed(128);
        let mut ef = FeedbackCompressor::new(quant(&map, 2, 5));
        let mut plain = quant(&map, 2, 5);
        let dim = map.dim;
        let (mut sum_v, mut sum_ef, mut sum_plain) =
            (vec![0.0f64; dim], vec![0.0f64; dim], vec![0.0f64; dim]);
        for s in 0..30 {
            let v = grad_like(dim, 300 + s);
            let pe = ef.encode(&v).expect("ef encode");
            let de = ef.decode(&pe).expect("ef decode");
            let pp = plain.encode(&v).expect("plain encode");
            let dp = plain.decode(&pp).expect("plain decode");
            for i in 0..dim {
                sum_v[i] += v[i];
                sum_ef[i] += de[i];
                sum_plain[i] += dp[i];
            }
        }
        let err = |s: &[f64]| -> f64 {
            s.iter().zip(&sum_v).map(|(a, b)| (a - b) * (a - b)).sum::<f64>()
        };
        let (e_ef, e_plain) = (err(&sum_ef), err(&sum_plain));
        assert!(
            e_ef < e_plain,
            "EF should shrink accumulated error: {e_ef} vs {e_plain}"
        );
        // and the telescoped error is exactly the final residual
        let res2: f64 = ef.residual_norm().powi(2);
        assert!(
            (e_ef - res2).abs() <= 1e-6 * (1.0 + res2),
            "telescope broken: {e_ef} vs residual^2 {res2}"
        );
    }

    #[test]
    fn dimension_change_resets_the_residual() {
        // the identity codec accepts any dimension, so one wrapper can see a
        // length change mid-run; the residual must re-zero, not mis-zip
        let mut ef = FeedbackCompressor::new(Box::new(
            crate::comm::codec::IdentityCompressor::new(),
        ));
        ef.encode(&grad_like(64, 1)).expect("encode");
        ef.encode(&grad_like(32, 2)).expect("encode after dim change");
        assert!(ef.residual_norm().is_finite());
        // fp32 wire: per-coordinate residual is at most one f32 ulp around
        let v = grad_like(32, 3);
        ef.encode(&v).expect("encode");
        assert!(ef.residual_norm() < 1e-5);
    }
}

//! The real-wire communication pipeline — the single artifact both
//! coordinator engines (and the analytic timing model) measure.
//!
//! Historically the repo carried two divergent copies of the
//! quantize → entropy-code → wire → decode path: `coordinator/sim` trusted
//! each compressor's *self-reported* bit count, while `coordinator/parallel`
//! hand-rolled its own `encode_vector`/`decode_vector` plumbing. This module
//! unifies them: a [`Compressor`] produces a [`WirePacket`] — the actual
//! encoded payload, with per-layer bit offsets and an exact bit count — and
//! every engine charges, times and ships that packet. Wire-size accounting
//! can no longer drift from protocol semantics because there is only one
//! encoder, and the engines differ only in transport (simulated clock vs
//! real threads + channels).
//!
//! The ENC/DEC hot path is **fused** (see [`crate::coding::fused`]): encode
//! is one pass per layer — norm, adaptive statistics, stochastic rounding
//! and Huffman emission folded together, writing straight into the codec's
//! reusable [`crate::coding::BitWriter`] — and decode drives a batched
//! word-level bit cache through the table-driven Huffman lookup,
//! dequantizing directly into the caller's `f64` buffer. The staged
//! reference pipeline survives behind `QuantCompressor::staged` and is held
//! bit-identical by the parity suites, so every optimization stays
//! falsifiable against the readable implementation.
//!
//! Layout:
//! * [`packet`] — `WirePacket`: encoded `BitBuf` + layer offsets + bit count;
//! * [`codec`] — the `Compressor` trait (fallible packet production with
//!   reusable scratch, optional per-layer encode parallelism) and its two
//!   implementations, [`IdentityCompressor`] (fp32 on the wire) and
//!   [`QuantCompressor`] (the paper's quantize + entropy-code scheme with
//!   L-GreCo-style adaptation);
//! * [`endpoint`] — `CommEndpoint`: one node's codec + packet scratch, the
//!   unit both engines hold per node.
//!
//! # Error feedback
//!
//! [`FeedbackCompressor`] ([`feedback`]) wraps any codec with EF14-style
//! compensation: each encode compresses `v + e_t` (the input plus the
//! residual left by the previous compression), self-decodes its own packet
//! and stores `e_{t+1} = (v + e_t) - Q(v + e_t)`. The semantics are
//! strictly encoder-side: the wire carries the inner codec's ordinary
//! packet for the compensated vector, receivers decode with the inner
//! decode path, and no state crosses the wire — so EF composes with every
//! transport unchanged. Over a run the decoded stream telescopes to the
//! input stream minus one residual, which is what keeps aggressive low-bit
//! schedules convergent. Combined with decode-count-triggered scheduling
//! (`Adaptation::Scheduled`), the encoder's self-decode doubles its decode
//! rate, so EF constructors double the inner schedule's `every` to keep
//! update steps at packet boundaries (see [`feedback`] docs).
//!
//! Both directions are fallible end to end: corrupt or truncated wire bytes
//! surface as [`CommError`], never a panic, and a panicking encode worker
//! thread is contained as [`CommError::EncodeWorker`] instead of poisoning
//! the engine. The per-layer bit offsets carried by every packet make the
//! payload shardable at layer boundaries ([`WirePacket::shard`]) without
//! re-coding — the mechanism behind the sharded reduce-scatter transport —
//! and further transports drop in as new packet consumers without forking
//! the engines.

pub mod codec;
pub mod endpoint;
pub mod feedback;
pub mod packet;

pub use codec::{default_sequences, Adaptation, Compressor, IdentityCompressor, QuantCompressor};
pub use endpoint::CommEndpoint;
pub use feedback::FeedbackCompressor;
pub use packet::WirePacket;

use crate::coding::DecodeError;

/// Failure while encoding or decoding a [`WirePacket`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommError {
    /// The entropy-coded payload is corrupt or truncated.
    Decode(DecodeError),
    /// The packet reconstructs a different dimensionality than the codec's
    /// synchronized layer map expects.
    DimMismatch { want: usize, got: usize },
    /// The payload decoded cleanly but left unconsumed bits — the framing
    /// disagrees with the synchronized state (mis-spliced segments).
    TrailingBits { bits: usize },
    /// `panicked` parallel entropy-encode workers died; the packet was not
    /// produced. The codec itself stays usable.
    EncodeWorker { panicked: usize },
    /// A node's worker thread (or its channel) went away before delivering
    /// its round's packet — the exchange cannot complete.
    WorkerLost,
    /// A [`WirePacket::shard`] request named a layer range that the packet's
    /// framing cannot satisfy: reversed bounds, layers past the last marked
    /// segment, or offsets that escape the payload.
    ShardRange { start: usize, end: usize, layers: usize },
    /// A transport plan was combined with a rack-structured spec it does not
    /// support (sharded / ring plans are rack-free peer meshes).
    UnsupportedRacks { racks: usize },
    /// The requested operation is not available on this codec or runtime
    /// (e.g. partial decode on a codec without layer framing, or a wire
    /// schedule the measured runtime does not implement).
    Unsupported { what: &'static str },
}

impl From<DecodeError> for CommError {
    fn from(e: DecodeError) -> Self {
        CommError::Decode(e)
    }
}

impl std::fmt::Display for CommError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CommError::Decode(e) => write!(f, "wire decode failed: {e}"),
            CommError::DimMismatch { want, got } => {
                write!(f, "packet dim {got} does not match codec dim {want}")
            }
            CommError::TrailingBits { bits } => {
                write!(f, "packet payload has {bits} unconsumed trailing bits")
            }
            CommError::EncodeWorker { panicked } => {
                write!(f, "{panicked} parallel encode worker(s) panicked; packet dropped")
            }
            CommError::WorkerLost => {
                write!(f, "a worker thread exited before delivering its round's packet")
            }
            CommError::ShardRange { start, end, layers } => {
                write!(f, "shard range {start}..{end} invalid for packet with {layers} layer(s)")
            }
            CommError::UnsupportedRacks { racks } => {
                write!(
                    f,
                    "sharded/ring transports are rack-free peer meshes; got a spec with {racks} rack(s)"
                )
            }
            CommError::Unsupported { what } => write!(f, "unsupported operation: {what}"),
        }
    }
}

impl std::error::Error for CommError {}

impl From<CommError> for crate::util::error::Error {
    fn from(e: CommError) -> Self {
        crate::util::error::Error::wrap(e.to_string(), e)
    }
}

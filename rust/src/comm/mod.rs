//! The real-wire communication pipeline — the single artifact both
//! coordinator engines (and the analytic timing model) measure.
//!
//! Historically the repo carried two divergent copies of the
//! quantize → entropy-code → wire → decode path: `coordinator/sim` trusted
//! each compressor's *self-reported* bit count, while `coordinator/parallel`
//! hand-rolled its own `encode_vector`/`decode_vector` plumbing. This module
//! unifies them: a [`Compressor`] produces a [`WirePacket`] — the actual
//! encoded payload, with per-layer bit offsets and an exact bit count — and
//! every engine charges, times and ships that packet. Wire-size accounting
//! can no longer drift from protocol semantics because there is only one
//! encoder, and the engines differ only in transport (simulated clock vs
//! real threads + channels).
//!
//! Layout:
//! * [`packet`] — `WirePacket`: encoded `BitBuf` + layer offsets + bit count;
//! * [`codec`] — the `Compressor` trait (packet production with reusable
//!   scratch buffers, optional per-layer encode parallelism) and its two
//!   implementations, [`IdentityCompressor`] (fp32 on the wire) and
//!   [`QuantCompressor`] (the paper's quantize + entropy-code scheme with
//!   L-GreCo-style adaptation);
//! * [`endpoint`] — `CommEndpoint`: one node's codec + packet scratch, the
//!   unit both engines hold per node.
//!
//! Decode is fallible end to end: corrupt or truncated wire bytes surface
//! as [`CommError`], never a panic. Future transports (sharded allgather,
//! async collectives, multi-backend) drop in as new packet consumers
//! without forking the engines.

pub mod codec;
pub mod endpoint;
pub mod packet;

pub use codec::{default_sequences, Adaptation, Compressor, IdentityCompressor, QuantCompressor};
pub use endpoint::CommEndpoint;
pub use packet::WirePacket;

use crate::coding::DecodeError;

/// Failure while decoding a [`WirePacket`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommError {
    /// The entropy-coded payload is corrupt or truncated.
    Decode(DecodeError),
    /// The packet reconstructs a different dimensionality than the codec's
    /// synchronized layer map expects.
    DimMismatch { want: usize, got: usize },
    /// The payload decoded cleanly but left unconsumed bits — the framing
    /// disagrees with the synchronized state (mis-spliced segments).
    TrailingBits { bits: usize },
}

impl From<DecodeError> for CommError {
    fn from(e: DecodeError) -> Self {
        CommError::Decode(e)
    }
}

impl std::fmt::Display for CommError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CommError::Decode(e) => write!(f, "wire decode failed: {e}"),
            CommError::DimMismatch { want, got } => {
                write!(f, "packet dim {got} does not match codec dim {want}")
            }
            CommError::TrailingBits { bits } => {
                write!(f, "packet payload has {bits} unconsumed trailing bits")
            }
        }
    }
}

impl std::error::Error for CommError {}

impl From<CommError> for crate::util::error::Error {
    fn from(e: CommError) -> Self {
        crate::util::error::Error::wrap(e.to_string(), e)
    }
}

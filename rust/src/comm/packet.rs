//! The wire packet: one node's fully encoded broadcast payload.
//!
//! # Shard-boundary semantics
//!
//! Every packet records the starting bit offset of each layer segment, and
//! each segment is self-contained given the shared codebooks (it opens with
//! its own f32 norm header). That makes layer boundaries the natural —
//! and only — shard boundaries: [`WirePacket::shard`] slices the coded
//! payload at `layer_offsets[start]..layer_offsets[end]` *without
//! re-coding*, rebasing the retained offsets to bit 0 so the shard is
//! itself a well-formed packet containing exactly layers `start..end`.
//! Requests that are not aligned to layer boundaries cannot be expressed
//! (the API takes a layer range, not a bit range), and ranges outside the
//! packet's framing fail with [`CommError::ShardRange`] — never a panic,
//! even on hand-assembled malformed packets from [`WirePacket::from_raw`].

use crate::coding::bitio::{BitBuf, BitWriter};
use crate::comm::CommError;

/// An encoded dual vector as it travels between nodes: the entropy-coded
/// payload, the bit offset of every layer segment, and the flat coordinate
/// count it reconstructs to.
///
/// The layer offsets let receivers (and future sharded transports) locate
/// and decode layer segments independently — each segment starts with its
/// f32 norm header and is self-contained given the shared codebooks.
///
/// The packet owns its buffers and is recycled by the codecs: re-encoding
/// into an existing packet reuses the payload allocation, so the steady
/// state of the hot loop allocates nothing.
#[derive(Clone, Debug, Default)]
pub struct WirePacket {
    payload: BitBuf,
    layer_offsets: Vec<usize>,
    dim: usize,
}

impl WirePacket {
    pub fn new() -> Self {
        Self::default()
    }

    /// Assemble from raw parts (custom transports, corruption tests).
    pub fn from_raw(payload: BitBuf, layer_offsets: Vec<usize>, dim: usize) -> Self {
        WirePacket { payload, layer_offsets, dim }
    }

    /// Exact size of the encoded payload in bits — the number every engine
    /// charges to the network model.
    pub fn len_bits(&self) -> usize {
        self.payload.len_bits()
    }

    pub fn len_bytes(&self) -> usize {
        self.payload.len_bytes()
    }

    /// Flat coordinate count the packet decodes to.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Bit offset of each layer segment within the payload.
    pub fn layer_offsets(&self) -> &[usize] {
        &self.layer_offsets
    }

    pub fn payload(&self) -> &BitBuf {
        &self.payload
    }

    /// Exact coded size of each layer segment in bits — offset diffs, with
    /// the last segment running to the end of the payload. This is the
    /// per-layer size table the sharded transport balances owners over.
    pub fn layer_bits(&self) -> Vec<u64> {
        let n = self.layer_offsets.len();
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let hi = if i + 1 < n { self.layer_offsets[i + 1] } else { self.payload.len_bits() };
            out.push(hi.saturating_sub(self.layer_offsets[i]) as u64);
        }
        out
    }

    /// Slice the coded payload at layer bit-offset boundaries: the returned
    /// packet contains exactly layers `layers.start..layers.end` of this
    /// one, with offsets rebased to bit 0 and an exact bit count. No
    /// re-coding happens — the segments are copied verbatim, so a shard
    /// concatenation reproduces the original payload bit for bit.
    ///
    /// `shard_dim` is the flat coordinate count of the retained layers; the
    /// packet does not know the layer map, so the caller (who does) supplies
    /// it. Empty ranges are valid and yield an empty packet — owners can
    /// legitimately own zero layers when there are fewer layers than peers.
    ///
    /// Fails with [`CommError::ShardRange`] on reversed bounds, ranges past
    /// the framed layer count, or framing that escapes the payload
    /// (possible only via [`WirePacket::from_raw`]). Never panics.
    pub fn shard(
        &self,
        layers: std::ops::Range<usize>,
        shard_dim: usize,
    ) -> Result<WirePacket, CommError> {
        let n = self.layer_offsets.len();
        let err = CommError::ShardRange { start: layers.start, end: layers.end, layers: n };
        if layers.start > layers.end || layers.end > n {
            return Err(err);
        }
        if layers.start == layers.end {
            return Ok(WirePacket { payload: BitBuf::default(), layer_offsets: Vec::new(), dim: shard_dim });
        }
        let len_bits = self.payload.len_bits();
        let lo_bit = self.layer_offsets[layers.start];
        let hi_bit =
            if layers.end < n { self.layer_offsets[layers.end] } else { len_bits };
        let window = &self.layer_offsets[layers.start..layers.end];
        let monotone = window.windows(2).all(|p| p[0] <= p[1]);
        if !monotone || lo_bit > hi_bit || hi_bit > len_bits {
            return Err(err);
        }
        let mut r = self.payload.reader();
        let mut to_skip = lo_bit;
        while to_skip > 0 {
            let step = to_skip.min(u32::MAX as usize);
            r.skip(step as u32);
            to_skip -= step;
        }
        let total = hi_bit - lo_bit;
        let mut w = BitWriter::with_capacity_bits(total);
        let mut left = total;
        while left > 0 {
            let take = left.min(64);
            match r.try_read_bits(take as u32) {
                Some(bits) => w.write_bits(bits, take as u32),
                None => return Err(err),
            }
            left -= take;
        }
        let rebased: Vec<usize> = window.iter().map(|&o| o - lo_bit).collect();
        Ok(WirePacket { payload: w.finish(), layer_offsets: rebased, dim: shard_dim })
    }

    /// Start a fresh encode: hand the payload allocation to `w` and reset
    /// the framing metadata.
    pub(crate) fn begin_encode(&mut self, dim: usize, w: &mut BitWriter) {
        self.payload.recycle_into(w);
        self.layer_offsets.clear();
        self.dim = dim;
    }

    /// Record the next layer segment's starting bit offset.
    pub(crate) fn mark_layer(&mut self, bit_offset: usize) {
        self.layer_offsets.push(bit_offset);
    }

    /// Finish an encode: move the written bits into the payload.
    ///
    /// Debug builds validate the framing invariants here — and only here:
    /// [`WirePacket::from_raw`] stays unchecked so corruption tests can
    /// assemble deliberately malformed packets.
    pub(crate) fn finish_encode(&mut self, w: &mut BitWriter) {
        #[cfg(debug_assertions)]
        let written_bits = w.len_bits();
        w.finish_into(&mut self.payload);
        #[cfg(debug_assertions)]
        self.debug_validate(written_bits);
    }

    /// Encode-side invariants (debug builds): exact-bit-count consistency
    /// between the writer and the finished payload, and layer-offset
    /// monotonicity — offsets strictly increase, start at bit 0, and stay
    /// inside the payload. The dynamic complement to the static
    /// `qoda audit` rules (see `crate::analysis`).
    #[cfg(debug_assertions)]
    fn debug_validate(&self, written_bits: usize) {
        debug_assert_eq!(
            self.payload.len_bits(),
            written_bits,
            "finish_encode changed the bit count: writer had {written_bits}, payload has {}",
            self.payload.len_bits()
        );
        if let Some(&first) = self.layer_offsets.first() {
            debug_assert_eq!(first, 0, "first layer segment must start at bit 0");
        }
        for pair in self.layer_offsets.windows(2) {
            debug_assert!(
                pair[0] < pair[1],
                "layer offsets must be strictly increasing: {:?}",
                self.layer_offsets
            );
        }
        if let Some(&last) = self.layer_offsets.last() {
            debug_assert!(
                last <= self.payload.len_bits(),
                "layer offset {last} past payload end ({} bits)",
                self.payload.len_bits()
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_cycle_reuses_and_frames() {
        let mut p = WirePacket::new();
        for round in 1..=3u64 {
            let mut w = BitWriter::new();
            p.begin_encode(8, &mut w);
            p.mark_layer(w.len_bits());
            w.write_bits(round, 5);
            p.mark_layer(w.len_bits());
            w.write_bits(round + 1, 9);
            p.finish_encode(&mut w);
            assert_eq!(p.len_bits(), 14);
            assert_eq!(p.dim(), 8);
            assert_eq!(p.layer_offsets(), &[0, 5]);
            let mut r = p.payload().reader();
            assert_eq!(r.read_bits(5), round);
            assert_eq!(r.read_bits(9), round + 1);
        }
    }

    /// Build a 3-layer packet with segment sizes 7, 13 and 21 bits whose
    /// payload is a known bit pattern.
    fn three_layer_packet() -> WirePacket {
        let mut p = WirePacket::new();
        let mut w = BitWriter::new();
        p.begin_encode(12, &mut w);
        p.mark_layer(w.len_bits());
        w.write_bits(0b1010_101, 7);
        p.mark_layer(w.len_bits());
        w.write_bits(0b1_0011_0111_0101, 13);
        p.mark_layer(w.len_bits());
        w.write_bits(0x15_5555, 21);
        p.finish_encode(&mut w);
        p
    }

    #[test]
    fn layer_bits_are_offset_diffs() {
        let p = three_layer_packet();
        assert_eq!(p.layer_bits(), vec![7, 13, 21]);
        assert_eq!(p.layer_bits().iter().sum::<u64>(), p.len_bits() as u64);
    }

    #[test]
    fn shard_slices_at_layer_boundaries_and_rebases() {
        let p = three_layer_packet();
        let s = p.shard(1..3, 9).unwrap();
        assert_eq!(s.dim(), 9);
        assert_eq!(s.layer_offsets(), &[0, 13]);
        assert_eq!(s.len_bits(), 34);
        let mut r = s.payload().reader();
        assert_eq!(r.read_bits(13), 0b1_0011_0111_0101);
        assert_eq!(r.read_bits(21), 0x15_5555);
    }

    #[test]
    fn shards_concatenate_back_to_the_original_payload() {
        let p = three_layer_packet();
        let mut w = BitWriter::with_capacity_bits(p.len_bits());
        let mut offsets = Vec::new();
        for lo in 0..3 {
            let s = p.shard(lo..lo + 1, 4).unwrap();
            offsets.push(w.len_bits());
            w.append(s.payload());
        }
        let buf = w.finish();
        assert_eq!(buf.words(), p.payload().words());
        assert_eq!(buf.len_bits(), p.len_bits());
        assert_eq!(offsets, p.layer_offsets());
    }

    #[test]
    fn empty_shard_range_is_a_valid_empty_packet() {
        let p = three_layer_packet();
        let s = p.shard(2..2, 0).unwrap();
        assert_eq!(s.len_bits(), 0);
        assert_eq!(s.dim(), 0);
        assert!(s.layer_offsets().is_empty());
    }

    #[test]
    fn bad_shard_ranges_error_never_panic() {
        let p = three_layer_packet();
        for (start, end) in [(0usize, 4usize), (2, 1), (4, 4)] {
            assert_eq!(
                p.shard(start..end, 4).err(),
                Some(CommError::ShardRange { start, end, layers: 3 })
            );
        }
        // framing that escapes the payload (only constructible via from_raw)
        let bogus = WirePacket::from_raw(p.payload().clone(), vec![0, 5, 10_000], 12);
        assert_eq!(
            bogus.shard(2..3, 4).err(),
            Some(CommError::ShardRange { start: 2, end: 3, layers: 3 })
        );
        let reversed = WirePacket::from_raw(p.payload().clone(), vec![0, 20, 7], 12);
        assert_eq!(
            reversed.shard(1..3, 8).err(),
            Some(CommError::ShardRange { start: 1, end: 3, layers: 3 })
        );
    }
}

//! The wire packet: one node's fully encoded broadcast payload.

use crate::coding::bitio::{BitBuf, BitWriter};

/// An encoded dual vector as it travels between nodes: the entropy-coded
/// payload, the bit offset of every layer segment, and the flat coordinate
/// count it reconstructs to.
///
/// The layer offsets let receivers (and future sharded transports) locate
/// and decode layer segments independently — each segment starts with its
/// f32 norm header and is self-contained given the shared codebooks.
///
/// The packet owns its buffers and is recycled by the codecs: re-encoding
/// into an existing packet reuses the payload allocation, so the steady
/// state of the hot loop allocates nothing.
#[derive(Clone, Debug, Default)]
pub struct WirePacket {
    payload: BitBuf,
    layer_offsets: Vec<usize>,
    dim: usize,
}

impl WirePacket {
    pub fn new() -> Self {
        Self::default()
    }

    /// Assemble from raw parts (custom transports, corruption tests).
    pub fn from_raw(payload: BitBuf, layer_offsets: Vec<usize>, dim: usize) -> Self {
        WirePacket { payload, layer_offsets, dim }
    }

    /// Exact size of the encoded payload in bits — the number every engine
    /// charges to the network model.
    pub fn len_bits(&self) -> usize {
        self.payload.len_bits()
    }

    pub fn len_bytes(&self) -> usize {
        self.payload.len_bytes()
    }

    /// Flat coordinate count the packet decodes to.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Bit offset of each layer segment within the payload.
    pub fn layer_offsets(&self) -> &[usize] {
        &self.layer_offsets
    }

    pub fn payload(&self) -> &BitBuf {
        &self.payload
    }

    /// Start a fresh encode: hand the payload allocation to `w` and reset
    /// the framing metadata.
    pub(crate) fn begin_encode(&mut self, dim: usize, w: &mut BitWriter) {
        self.payload.recycle_into(w);
        self.layer_offsets.clear();
        self.dim = dim;
    }

    /// Record the next layer segment's starting bit offset.
    pub(crate) fn mark_layer(&mut self, bit_offset: usize) {
        self.layer_offsets.push(bit_offset);
    }

    /// Finish an encode: move the written bits into the payload.
    ///
    /// Debug builds validate the framing invariants here — and only here:
    /// [`WirePacket::from_raw`] stays unchecked so corruption tests can
    /// assemble deliberately malformed packets.
    pub(crate) fn finish_encode(&mut self, w: &mut BitWriter) {
        #[cfg(debug_assertions)]
        let written_bits = w.len_bits();
        w.finish_into(&mut self.payload);
        #[cfg(debug_assertions)]
        self.debug_validate(written_bits);
    }

    /// Encode-side invariants (debug builds): exact-bit-count consistency
    /// between the writer and the finished payload, and layer-offset
    /// monotonicity — offsets strictly increase, start at bit 0, and stay
    /// inside the payload. The dynamic complement to the static
    /// `qoda audit` rules (see `crate::analysis`).
    #[cfg(debug_assertions)]
    fn debug_validate(&self, written_bits: usize) {
        debug_assert_eq!(
            self.payload.len_bits(),
            written_bits,
            "finish_encode changed the bit count: writer had {written_bits}, payload has {}",
            self.payload.len_bits()
        );
        if let Some(&first) = self.layer_offsets.first() {
            debug_assert_eq!(first, 0, "first layer segment must start at bit 0");
        }
        for pair in self.layer_offsets.windows(2) {
            debug_assert!(
                pair[0] < pair[1],
                "layer offsets must be strictly increasing: {:?}",
                self.layer_offsets
            );
        }
        if let Some(&last) = self.layer_offsets.last() {
            debug_assert!(
                last <= self.payload.len_bits(),
                "layer offset {last} past payload end ({} bits)",
                self.payload.len_bits()
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_cycle_reuses_and_frames() {
        let mut p = WirePacket::new();
        for round in 1..=3u64 {
            let mut w = BitWriter::new();
            p.begin_encode(8, &mut w);
            p.mark_layer(w.len_bits());
            w.write_bits(round, 5);
            p.mark_layer(w.len_bits());
            w.write_bits(round + 1, 9);
            p.finish_encode(&mut w);
            assert_eq!(p.len_bits(), 14);
            assert_eq!(p.dim(), 8);
            assert_eq!(p.layer_offsets(), &[0, 5]);
            let mut r = p.payload().reader();
            assert_eq!(r.read_bits(5), round);
            assert_eq!(r.read_bits(9), round + 1);
        }
    }
}

//! Bandwidth-optimal collective transports over entropy-coded bundles:
//! sharded reduce-scatter → allgather, and the classic ring.
//!
//! Both plans here attack the per-link hot spot that caps the flat /
//! hierarchical / parameter-server topologies: each of those pushes at
//! least one *full* bundle set over some link, so peak per-link bytes/step
//! grows linearly with K and the paper's Table 1/2 speedup plateaus exactly
//! where weak scaling begins. The sharded plan cuts the peak to ~1/K of
//! flat's; the ring holds it ~constant in K.
//!
//! The enabling mechanism is layer-wise quantization itself: every
//! [`WirePacket`](crate::comm::WirePacket) carries per-layer bit offsets,
//! so the entropy-coded payload shards at layer boundaries
//! ([`WirePacket::shard`](crate::comm::WirePacket::shard)) without
//! re-coding, and heterogeneous layers produce heterogeneous shard sizes —
//! which is why layer ownership is balanced on *measured coded bits*
//! (previous round's [`WirePacket::layer_bits`]
//! tables fed through [`Transport::observe_packet_layers`]), not on layer
//! counts.
//!
//! Like every [`Transport`], these are pure accounting: routing and
//! charging only. The aggregation math stays in
//! [`super::core`] (`decode_aggregate_into` /
//! `decode_aggregate_slice_into`), identical for every topology, so all
//! five plans produce bit-identical aggregates by construction — the
//! slice fold is the same node-order `v / k` accumulation per coordinate,
//! and concatenating owner slices reproduces the full fold bit for bit.

use crate::net::{NetworkModel, PhaseKind, PhaseTimeline};
use crate::stats::rng::Rng;

use super::topology::{
    TopologySpec, Transport, WireCharge, PHASE_SETUP_MS,
};

/// Owner `o`'s share of `total` units split as evenly as possible over `k`
/// owners: `total / k`, with the first `total % k` owners taking one extra.
/// Shares sum to `total` exactly and differ by at most one unit.
pub fn split_share(total: u64, o: usize, k: usize) -> u64 {
    if k == 0 {
        return 0;
    }
    let base = total / k as u64;
    let extra = ((o as u64) < total % k as u64) as u64;
    base + extra
}

/// Assign layers to `k` owners as contiguous ranges balanced on *coded
/// bits*: owner `o`'s range ends at the last layer whose cumulative bit
/// count stays within the target `total · (o+1) / k` (u128 arithmetic, so
/// huge payloads cannot overflow); the last owner takes the remainder.
/// Ranges are contiguous, cover `0..layer_bits.len()` exactly, and may be
/// empty (fewer layers than owners, or one giant layer).
pub fn assign_layers_by_bits(layer_bits: &[u64], k: usize) -> Vec<(usize, usize)> {
    let l = layer_bits.len();
    if k == 0 {
        return Vec::new();
    }
    let total: u128 = layer_bits.iter().map(|&b| b as u128).sum();
    let mut ranges = Vec::with_capacity(k);
    let mut layer = 0usize;
    let mut cum: u128 = 0;
    for o in 0..k {
        let start = layer;
        if o + 1 == k {
            layer = l;
        } else {
            let target = total * (o as u128 + 1) / k as u128;
            while layer < l && cum + layer_bits[layer] as u128 <= target {
                cum += layer_bits[layer] as u128;
                layer += 1;
            }
        }
        ranges.push((start, layer));
    }
    ranges
}

/// Per-node shard sizes implied by an ownership assignment:
/// `shard_bits[j][o]` = the coded bits of node `j`'s packet that belong to
/// owner `o`'s layer range. Falls back to the idealized [`split_share`]
/// split of the node's total when no per-layer table is available.
fn shard_table(
    packet_bits: &[u64],
    tables: Option<&[Vec<u64>]>,
    ranges: Option<&[(usize, usize)]>,
) -> Vec<Vec<u64>> {
    let k = packet_bits.len();
    let mut out = vec![vec![0u64; k]; k];
    match (tables, ranges) {
        (Some(tables), Some(ranges)) if tables.len() == k => {
            for (j, table) in tables.iter().enumerate() {
                for (o, &(lo, hi)) in ranges.iter().enumerate() {
                    let hi = hi.min(table.len());
                    let lo = lo.min(hi);
                    out[j][o] = table[lo..hi].iter().sum();
                }
            }
        }
        _ => {
            for (j, &b) in packet_bits.iter().enumerate() {
                for (o, slot) in out[j].iter_mut().enumerate() {
                    *slot = split_share(b, o, k);
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Sharded reduce-scatter → allgather
// ---------------------------------------------------------------------------

/// Each of K peers owns ~1/K of the coded bits. Two phases:
///
/// 1. **reduce-scatter** — every node ships, for each other owner `o`, the
///    shard of its own packet covering `o`'s layers
///    ([`WirePacket::shard`](crate::comm::WirePacket::shard) at layer
///    bit-offset boundaries; a node's own shard stays local). Owners
///    partial-decode and fold only their slice
///    (`decode_aggregate_slice_into`).
/// 2. **allgather** — every owner sends its reduced fp32 slice to the K−1
///    other peers; each slice crosses the wire-bit ledger once, like the
///    flat allgather accounting.
///
/// Wire bits: `W = Σ_j (b_j − s_{jj}) + 32·d`, where `s_{jo}` is the exact
/// coded size of node j's shard for owner o when the transport has seen the
/// per-layer tables (via [`Transport::observe_packet_layers`]; ownership is
/// balanced on the *previous* round's summed per-layer bits, so routing
/// never depends on data it hasn't shipped yet — round 1 uses the current
/// observation), and the idealized [`split_share`] split when it has not
/// (e.g. the totals-only `NetClock` path). `k = 1` degenerates to zero
/// wire and zero clock.
///
/// Peak per-link bytes: `max_{j≠o} [ s_{jo}/8 + 4·split_share(d, j, k) ]`
/// — the busiest directed link carries one phase-1 shard plus one phase-2
/// fp32 slice — which is ~`ΣB/(8K)` vs flat's `(K−1)/K · ΣB/8`: the ~1/K
/// reduction this plan exists for.
///
/// Clock: phase 1 is one cross-rack hop bounded by the busiest endpoint
/// (max of egress and ingress), slowed by the worst straggler, taxed by the
/// expected coded-payload jitter, plus a (K−1)-deep incast straggler term
/// on the owner side; phase 2 is a (K−1)-message fp32 slice allgather,
/// never jittered (uniform fp32 carries no coded-size variance). Both
/// phases pay [`PHASE_SETUP_MS`].
pub struct ShardedReduceScatter {
    /// summed per-layer coded bits of the previous round — the balance
    /// basis for this round's ownership
    prev_layer_totals: Option<Vec<u64>>,
    /// per-node per-layer tables observed for the imminent charge
    current: Option<Vec<Vec<u64>>>,
}

impl ShardedReduceScatter {
    pub fn new() -> Self {
        ShardedReduceScatter { prev_layer_totals: None, current: None }
    }
}

impl Default for ShardedReduceScatter {
    fn default() -> Self {
        Self::new()
    }
}

impl Transport for ShardedReduceScatter {
    fn spec(&self) -> TopologySpec {
        TopologySpec::ShardedReduceScatter
    }

    fn observes_layers(&self) -> bool {
        true
    }

    fn observe_packet_layers(&mut self, layer_bits: &[Vec<u64>]) {
        self.current = Some(layer_bits.to_vec());
    }

    fn charge_timeline(
        &mut self,
        packet_bits: &[u64],
        agg_dim: usize,
        net: &NetworkModel,
        uncompressed: bool,
        main_protocol: bool,
        _rng: &mut Rng,
    ) -> (WireCharge, PhaseTimeline) {
        let k = packet_bits.len();
        let current = self.current.take();
        // ownership balances on the previous round's measured per-layer
        // bits; round 1 falls back to the current observation
        let basis: Option<Vec<u64>> = match (&self.prev_layer_totals, &current) {
            (Some(prev), Some(cur))
                if cur.iter().all(|t| t.len() == prev.len()) && !prev.is_empty() =>
            {
                Some(prev.clone())
            }
            (_, Some(cur)) if !cur.is_empty() => {
                let l = cur[0].len();
                if cur.iter().all(|t| t.len() == l) && l > 0 {
                    let mut sums = vec![0u64; l];
                    for t in cur {
                        for (s, &b) in sums.iter_mut().zip(t.iter()) {
                            *s += b;
                        }
                    }
                    Some(sums)
                } else {
                    None
                }
            }
            _ => None,
        };
        let ranges = basis.as_deref().map(|b| assign_layers_by_bits(b, k));
        let shards = shard_table(packet_bits, current.as_deref(), ranges.as_deref());
        // remember this round's summed tables for the next round's balance
        if let Some(cur) = &current {
            if !cur.is_empty() && cur.iter().all(|t| t.len() == cur[0].len()) {
                let mut sums = vec![0u64; cur[0].len()];
                for t in cur {
                    for (s, &b) in sums.iter_mut().zip(t.iter()) {
                        *s += b;
                    }
                }
                self.prev_layer_totals = Some(sums);
            }
        }

        if k <= 1 {
            return (
                WireCharge { wire_bits: 0, comm_s: 0.0, peak_link_bytes: 0.0 },
                PhaseTimeline::single(PhaseKind::CrossRack, 0.0),
            );
        }
        let kf = k as f64;
        let agg_bits = 32u64 * agg_dim as u64;
        let bw = net.bytes_per_sec();
        let lat = net.latency_us * 1e-6;
        let slow = net.max_slowdown_over(0..k);
        let jitter = if uncompressed { 1.0 } else { net.jitter_multiplier(main_protocol) };
        let setup = PHASE_SETUP_MS * 1e-3;

        // --- phase 1: shard to owners, who partial-decode and reduce --------
        let mut wire_bits = 0u64;
        let mut egress_max = 0.0f64;
        let mut ingress_max = 0.0f64;
        for j in 0..k {
            let out_bits = packet_bits[j].saturating_sub(shards[j][j]);
            wire_bits += out_bits;
            egress_max = egress_max.max(out_bits as f64 / 8.0);
        }
        for o in 0..k {
            let in_bits: u64 =
                (0..k).filter(|&j| j != o).map(|j| shards[j][o]).sum();
            ingress_max = ingress_max.max(in_bits as f64 / 8.0);
        }
        let t1_wire = egress_max.max(ingress_max) / bw * slow + lat;
        let t1_straggler =
            net.straggler_ms_per_node_mb * 1e-3 * (ingress_max / 1e6) * (kf - 1.0);
        let t1 = (t1_wire + t1_straggler) * jitter;

        // --- phase 2: fp32 slice allgather ----------------------------------
        wire_bits += agg_bits;
        let slice_max_bytes =
            4.0 * (0..k).map(|o| split_share(agg_dim as u64, o, k)).fold(0, u64::max) as f64;
        let t2_wire = (kf - 1.0) * slice_max_bytes / bw * slow + lat;
        let t2_straggler =
            net.straggler_ms_per_node_mb * 1e-3 * (slice_max_bytes / 1e6) * (kf - 1.0);
        let t2 = t2_wire + t2_straggler;

        // --- peak per-link: busiest directed link j -> o ---------------------
        let mut peak_link_bytes = 0.0f64;
        for j in 0..k {
            let slice_j = 4.0 * split_share(agg_dim as u64, j, k) as f64;
            for o in 0..k {
                if o == j {
                    continue;
                }
                // phase-1 shard j -> o plus phase-2 slice j -> o
                let link = shards[j][o] as f64 / 8.0 + slice_j;
                peak_link_bytes = peak_link_bytes.max(link);
            }
        }

        let comm_s = t1 + t2 + 2.0 * setup;
        let mut timeline = PhaseTimeline::default();
        timeline.push(PhaseKind::CrossRack, t1 + setup);
        timeline.push(PhaseKind::CrossRack, t2 + setup);
        (WireCharge { wire_bits, comm_s, peak_link_bytes }, timeline)
    }
}

// ---------------------------------------------------------------------------
// Ring
// ---------------------------------------------------------------------------

/// The classic bandwidth-optimal ring: payloads split into K chunks, K−1
/// reduce-scatter steps then K−1 allgather steps, every node sending one
/// chunk per step to its ring successor.
///
/// Chunking is the idealized [`split_share`] split of each node's coded
/// bits (the ring relays fixed chunk *slots*, so slot `o`'s wire size is
/// the worst packet's share: `chunk_o = max_j split_share(b_j, o, k)`).
/// In each step all K nodes send distinct slots, so
///
/// * wire bits: `W = 2·(K−1)·Σ_o chunk_o` — for uniform fp32 payloads this
///   is exactly the classic `2·(K−1)/K · total` per-node ring-allreduce
///   volume summed over the K links;
/// * peak per-link bytes: `2·(K−1)·max_o chunk_o` — *independent of the
///   payload total's growth with K*, the constant-per-link property that
///   makes the ring the asymptote for huge clusters;
/// * clock: `2·(K−1)` serialized steps of `chunk_max/bw·slow + lat`; coded
///   steps pay the expected jitter multiplier; the reduce-scatter half
///   additionally pays the straggler chain (a slow node delays every
///   reduction it relays), the allgather half is a pure relay. Both halves
///   pay [`PHASE_SETUP_MS`]. The `2(K−1)` latency term is the ring's cost:
///   it loses to the 2-phase sharded plan when payloads are small.
///
/// Like the sharded plan this is pure accounting — aggregation math is the
/// shared full fold, so coded-chunk in-network reduction is *modeled*, not
/// performed, and aggregates remain bit-identical across all five plans.
pub struct Ring;

impl Transport for Ring {
    fn spec(&self) -> TopologySpec {
        TopologySpec::Ring
    }

    fn charge_timeline(
        &mut self,
        packet_bits: &[u64],
        _agg_dim: usize,
        net: &NetworkModel,
        uncompressed: bool,
        main_protocol: bool,
        _rng: &mut Rng,
    ) -> (WireCharge, PhaseTimeline) {
        let k = packet_bits.len();
        if k <= 1 {
            return (
                WireCharge { wire_bits: 0, comm_s: 0.0, peak_link_bytes: 0.0 },
                PhaseTimeline::single(PhaseKind::CrossRack, 0.0),
            );
        }
        let kf = k as f64;
        let bw = net.bytes_per_sec();
        let lat = net.latency_us * 1e-6;
        let slow = net.max_slowdown_over(0..k);
        let jitter = if uncompressed { 1.0 } else { net.jitter_multiplier(main_protocol) };
        let setup = PHASE_SETUP_MS * 1e-3;

        let mut chunk_sum = 0u64;
        let mut chunk_max = 0u64;
        for o in 0..k {
            let chunk = packet_bits.iter().map(|&b| split_share(b, o, k)).fold(0, u64::max);
            chunk_sum += chunk;
            chunk_max = chunk_max.max(chunk);
        }
        let chunk_max_bytes = chunk_max as f64 / 8.0;
        let wire_bits = 2 * (k as u64 - 1) * chunk_sum;
        let peak_link_bytes = 2.0 * (kf - 1.0) * chunk_max_bytes;

        let t_step = chunk_max_bytes / bw * slow + lat;
        let half = (kf - 1.0) * t_step * jitter;
        // stragglers delay every reduction the slow node relays; the
        // allgather half is a pure store-and-forward relay
        let straggler =
            net.straggler_ms_per_node_mb * 1e-3 * (chunk_max_bytes / 1e6) * (kf - 1.0);
        let t_rs = half + straggler;
        let t_ag = half;

        let comm_s = t_rs + t_ag + 2.0 * setup;
        let mut timeline = PhaseTimeline::default();
        timeline.push(PhaseKind::CrossRack, t_rs + setup);
        timeline.push(PhaseKind::CrossRack, t_ag + setup);
        (WireCharge { wire_bits, comm_s, peak_link_bytes }, timeline)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::NetworkModel;

    fn charge(
        spec: &TopologySpec,
        bits: &[u64],
        d: usize,
        net: &NetworkModel,
    ) -> WireCharge {
        let mut rng = Rng::new(7);
        spec.build().charge(bits, d, net, false, true, &mut rng)
    }

    #[test]
    fn split_share_sums_exactly_and_balances() {
        for (total, k) in [(512u64, 6usize), (360_000, 32), (7, 3), (0, 4), (5, 8)] {
            let shares: Vec<u64> = (0..k).map(|o| split_share(total, o, k)).collect();
            assert_eq!(shares.iter().sum::<u64>(), total, "total={total} k={k}");
            let lo = shares.iter().copied().min().unwrap_or(0);
            let hi = shares.iter().copied().max().unwrap_or(0);
            assert!(hi - lo <= 1, "shares differ by more than one unit: {shares:?}");
        }
        assert_eq!(split_share(10, 0, 0), 0);
    }

    #[test]
    fn assignment_covers_contiguously_and_balances_bits() {
        // heterogeneous coded layers, as layer-wise quantization produces
        let bits = [4000u64, 120, 120, 3800, 50, 900, 900, 2100, 10, 4000];
        let total: u64 = bits.iter().sum();
        for k in [1usize, 2, 3, 4, 8] {
            let ranges = assign_layers_by_bits(&bits, k);
            assert_eq!(ranges.len(), k);
            assert_eq!(ranges[0].0, 0);
            assert_eq!(ranges[k - 1].1, bits.len());
            for w in ranges.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous");
            }
            // every owner's load is within one max-layer of the ideal share
            let max_layer = *bits.iter().max().unwrap_or(&0);
            for &(lo, hi) in &ranges {
                let load: u64 = bits[lo..hi].iter().sum();
                assert!(
                    load <= total / k as u64 + max_layer,
                    "k={k}: owner load {load} too far above ideal {}",
                    total / k as u64
                );
            }
        }
        // more owners than layers: trailing/interior empties are fine
        let ranges = assign_layers_by_bits(&[100, 100], 5);
        assert_eq!(ranges.len(), 5);
        assert_eq!(ranges[4].1, 2);
        assert!(ranges.iter().any(|&(lo, hi)| lo == hi));
    }

    #[test]
    fn sharded_and_ring_wire_bit_pins_uniform_payloads() {
        // k = 6 identical packets of 512 bits, d = 16 (fp32 agg = 512 bits)
        let bits = [512u64; 6];
        let net = NetworkModel::genesis_cloud(5.0);
        // sharded (idealized split — no layer tables observed):
        // phase 1 ships Σ_j (512 − split_share(512, j, 6)) = 5·512,
        // phase 2 allgathers 32·16 = 512 fp32 bits once → 3072
        let sharded = charge(&TopologySpec::ShardedReduceScatter, &bits, 16, &net);
        assert_eq!(sharded.wire_bits, 5 * 512 + 512);
        // ring: chunk_o = split_share(512, o, 6), Σ_o = 512,
        // W = 2·(6−1)·512 = 5120
        let ring = charge(&TopologySpec::Ring, &bits, 16, &net);
        assert_eq!(ring.wire_bits, 2 * 5 * 512);
    }

    #[test]
    fn observed_layer_tables_make_shard_accounting_exact() {
        let net = NetworkModel::genesis_cloud(5.0);
        let k = 3usize;
        // three nodes, four layers with very uneven coded sizes
        let tables = vec![
            vec![6000u64, 200, 200, 1600],
            vec![5800, 180, 260, 1760],
            vec![6100, 240, 160, 1500],
        ];
        let bits: Vec<u64> = tables.iter().map(|t| t.iter().sum()).collect();
        let mut t = ShardedReduceScatter::new();
        assert!(t.observes_layers());
        t.observe_packet_layers(&tables);
        let mut rng = Rng::new(7);
        let c = t.charge(&bits, 64, &net, false, true, &mut rng);
        // recompute by hand: ownership from summed tables, exact per-node
        // shard sizes from each node's own table
        let sums: Vec<u64> = (0..4)
            .map(|l| tables.iter().map(|t| t[l]).sum())
            .collect();
        let ranges = assign_layers_by_bits(&sums, k);
        let mut want = 0u64;
        for (j, table) in tables.iter().enumerate() {
            let (lo, hi) = ranges[j];
            let own: u64 = table[lo..hi].iter().sum();
            want += bits[j] - own;
        }
        want += 32 * 64;
        assert_eq!(c.wire_bits, want);

        // next round: ownership must come from the PREVIOUS round's totals
        // even though fresh (different) tables are observed
        let tables2 = vec![
            vec![100u64, 100, 100, 7700],
            vec![100, 100, 100, 7700],
            vec![100, 100, 100, 7700],
        ];
        let bits2: Vec<u64> = tables2.iter().map(|t| t.iter().sum()).collect();
        t.observe_packet_layers(&tables2);
        let c2 = t.charge(&bits2, 64, &net, false, true, &mut rng);
        let mut want2 = 0u64;
        for (j, table) in tables2.iter().enumerate() {
            let (lo, hi) = ranges[j]; // prev-round assignment
            let own: u64 = table[lo..hi].iter().sum();
            want2 += bits2[j] - own;
        }
        want2 += 32 * 64;
        assert_eq!(c2.wire_bits, want2);
    }

    #[test]
    fn sharded_peak_link_is_a_small_fraction_of_flats_at_k32() {
        // the acceptance pin: 45 kB coded payloads per node at K = 32,
        // d = 64k — sharded's busiest link carries ≤ 1.5/K of flat's
        let net = NetworkModel::genesis_cloud(5.0);
        let k = 32usize;
        let d = 1 << 16;
        let bits = vec![360_000u64; k]; // 45,000 bytes coded per node
        let flat = charge(&TopologySpec::BroadcastAllGather, &bits, d, &net);
        let sharded = charge(&TopologySpec::ShardedReduceScatter, &bits, d, &net);
        // flat streams (K−1)/K of the 1.44 MB total through every link
        assert_eq!(flat.peak_link_bytes, 31.0 * 45_000.0);
        // sharded's busiest directed link: one 1/K shard + one fp32 slice
        assert_eq!(sharded.peak_link_bytes, 360_000.0 / 32.0 / 8.0 + 4.0 * 2048.0);
        let ratio = sharded.peak_link_bytes / flat.peak_link_bytes;
        assert!(
            ratio <= 1.5 / k as f64,
            "peak ratio {ratio} exceeds 1.5/K = {}",
            1.5 / k as f64
        );
    }

    #[test]
    fn ring_peak_link_stays_constant_as_k_grows() {
        let net = NetworkModel::genesis_cloud(5.0);
        let d = 1 << 16;
        let peak = |k: usize| {
            let bits = vec![360_000u64; k];
            charge(&TopologySpec::Ring, &bits, d, &net).peak_link_bytes
        };
        // per-link load 2(K−1)/K·B is bounded by 2B per node-payload,
        // approaching it from below as K grows — never growing with the
        // cluster the way flat's K·B/link does
        let p8 = peak(8);
        let p64 = peak(64);
        assert!(p64 <= 2.0 * 45_000.0, "ring peak {p64} above the 2B bound");
        assert!(p64 / p8 < 1.2, "ring peak drifted: {p8} -> {p64}");
        // while flat's grows ~8x over the same range
        let flat = |k: usize| {
            let bits = vec![360_000u64; k];
            charge(&TopologySpec::BroadcastAllGather, &bits, d, &net).peak_link_bytes
        };
        assert!(flat(64) / flat(8) > 7.0);
    }

    #[test]
    fn sharded_or_ring_beats_every_existing_transport_at_scale() {
        // the Table 2 weak-scaling regime: 0.7 MB coded payloads, 5 Gbps
        let net = NetworkModel::genesis_cloud(5.0);
        let d = 1 << 20;
        for k in [32usize, 64] {
            let bits = vec![0.7e6 as u64 * 8; k];
            let old = [
                TopologySpec::BroadcastAllGather,
                TopologySpec::hierarchical_for(k),
                TopologySpec::ParameterServer,
            ];
            let best_old = old
                .iter()
                .map(|s| charge(s, &bits, d, &net).comm_s)
                .fold(f64::INFINITY, f64::min);
            let sharded = charge(&TopologySpec::ShardedReduceScatter, &bits, d, &net);
            let ring = charge(&TopologySpec::Ring, &bits, d, &net);
            assert!(
                sharded.comm_s < best_old && ring.comm_s < best_old,
                "K={k}: sharded {} ring {} vs best existing {}",
                sharded.comm_s,
                ring.comm_s,
                best_old
            );
        }
    }

    #[test]
    fn single_node_degenerates_to_zero() {
        let net = NetworkModel::genesis_cloud(5.0);
        for spec in [TopologySpec::ShardedReduceScatter, TopologySpec::Ring] {
            let c = charge(&spec, &[4096], 64, &net);
            assert_eq!(c.wire_bits, 0, "{spec:?}");
            assert_eq!(c.comm_s, 0.0, "{spec:?}");
            assert_eq!(c.peak_link_bytes, 0.0, "{spec:?}");
        }
    }

    #[test]
    fn new_transports_never_draw_from_the_shared_rng() {
        // golden parity across engines depends on the charge rng stream
        // staying untouched by transports that don't sample (only the flat
        // collective model draws); pin that the new plans are deterministic
        let net = NetworkModel::genesis_cloud(5.0);
        let bits = vec![360_000u64; 8];
        for spec in [TopologySpec::ShardedReduceScatter, TopologySpec::Ring] {
            let mut rng = Rng::new(0xDEAD);
            let mut fresh = Rng::new(0xDEAD);
            let c1 = spec.build().charge(&bits, 1 << 16, &net, false, true, &mut rng);
            assert_eq!(rng.next_u64(), fresh.next_u64(), "{spec:?} consumed rng");
            let mut rng2 = Rng::new(0x7777);
            let c2 = spec.build().charge(&bits, 1 << 16, &net, false, true, &mut rng2);
            assert_eq!(c1, c2, "{spec:?} charge depends on the rng seed");
        }
    }
}

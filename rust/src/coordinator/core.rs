//! The decode-aggregate core both engines (and every topology) share.
//!
//! Historically `sim.rs` and `parallel.rs` each carried a private copy of
//! the "decode every node's packet, fold into the running mean" loop. The
//! copies had to stay float-for-float identical for the engines' parity
//! guarantee to hold, which made every transport change a two-file edit.
//! This module is now the single owner of that loop: the aggregation rule
//! is *node order, one running mean, `v / k` folds* — so aggregates are
//! bit-identical across engines **and** topologies by construction, because
//! nothing topology-specific can touch the arithmetic.

use crate::comm::CommError;

/// Decode every node's payload in node order and fold it into `mean`.
///
/// `decode(node, out)` materializes node `node`'s decoded vector into
/// `out` — the sim engine decodes through each node's own endpoint, the
/// threaded engine through the leader's synchronized codec; both produce
/// identical values, and this function owns the (order-sensitive) float
/// accumulation they share.
pub fn decode_aggregate_into(
    k: usize,
    d: usize,
    mean: &mut Vec<f64>,
    scratch: &mut Vec<f64>,
    mut decode: impl FnMut(usize, &mut Vec<f64>) -> Result<(), CommError>,
) -> Result<(), CommError> {
    mean.clear();
    mean.resize(d, 0.0);
    let kf = k as f64;
    for node in 0..k {
        decode(node, scratch)?;
        for (m, v) in mean.iter_mut().zip(scratch.iter()) {
            *m += v / kf;
        }
    }
    Ok(())
}

/// Decode every node's *shard* of one owner's slice in node order and fold
/// it into `mean` — the partial-reduce half of the sharded reduce-scatter
/// transport.
///
/// This is [`decode_aggregate_into`] restricted to a `slice_len`-coordinate
/// window: same node order, same running mean, same `v / k` fold, so
/// concatenating every owner's slice reproduces the full-fold aggregate
/// bit for bit (each coordinate sees the identical sequence of float
/// operations either way). `decode(node, out)` materializes node `node`'s
/// decoded shard — exactly the owner's layers — into `out`; a shard of the
/// wrong width surfaces as [`CommError::DimMismatch`].
pub fn decode_aggregate_slice_into(
    k: usize,
    slice_len: usize,
    mean: &mut Vec<f64>,
    scratch: &mut Vec<f64>,
    mut decode: impl FnMut(usize, &mut Vec<f64>) -> Result<(), CommError>,
) -> Result<(), CommError> {
    mean.clear();
    mean.resize(slice_len, 0.0);
    let kf = k as f64;
    for node in 0..k {
        decode(node, scratch)?;
        if scratch.len() != slice_len {
            return Err(CommError::DimMismatch { want: slice_len, got: scratch.len() });
        }
        for (m, v) in mean.iter_mut().zip(scratch.iter()) {
            *m += v / kf;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn folds_in_node_order() {
        let inputs = [vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]];
        let mut mean = Vec::new();
        let mut scratch = Vec::new();
        decode_aggregate_into(3, 2, &mut mean, &mut scratch, |node, out| {
            out.clear();
            out.extend_from_slice(&inputs[node]);
            Ok(())
        })
        .unwrap();
        // the exact float fold the engines are parity-tested on
        let want: Vec<f64> = (0..2)
            .map(|i| {
                let mut m = 0.0;
                for v in &inputs {
                    m += v[i] / 3.0;
                }
                m
            })
            .collect();
        assert_eq!(mean, want);
    }

    #[test]
    fn decode_error_propagates() {
        let mut mean = Vec::new();
        let mut scratch = Vec::new();
        let err = decode_aggregate_into(2, 4, &mut mean, &mut scratch, |node, _| {
            if node == 1 {
                Err(CommError::DimMismatch { want: 4, got: 3 })
            } else {
                Ok(())
            }
        });
        assert_eq!(err, Err(CommError::DimMismatch { want: 4, got: 3 }));
    }

    #[test]
    fn concatenated_slice_folds_equal_the_full_fold_bitwise() {
        // 3 nodes, 7 coordinates, split into slices [0..3), [3..5), [5..7)
        let inputs = [
            vec![0.1, -2.0, 3.5, 0.25, 1.0 / 3.0, -7.125, 0.9],
            vec![5.0, 0.125, -0.6, 2.5, 1e-3, 4.0, -0.33],
            vec![-1.5, 2.25, 0.75, -3.125, 8.0, 0.5, 1.0 / 7.0],
        ];
        let mut full = Vec::new();
        let mut scratch = Vec::new();
        decode_aggregate_into(3, 7, &mut full, &mut scratch, |node, out| {
            out.clear();
            out.extend_from_slice(&inputs[node]);
            Ok(())
        })
        .unwrap();

        let mut concat = Vec::new();
        for (lo, hi) in [(0usize, 3usize), (3, 5), (5, 7)] {
            let mut slice_mean = Vec::new();
            decode_aggregate_slice_into(3, hi - lo, &mut slice_mean, &mut scratch, |node, out| {
                out.clear();
                out.extend_from_slice(&inputs[node][lo..hi]);
                Ok(())
            })
            .unwrap();
            concat.extend_from_slice(&slice_mean);
        }
        // bit-identical, not approximately equal: same fold order per coord
        assert_eq!(full, concat);
    }

    #[test]
    fn slice_fold_rejects_wrong_width_shards() {
        let mut mean = Vec::new();
        let mut scratch = Vec::new();
        let err = decode_aggregate_slice_into(2, 3, &mut mean, &mut scratch, |_, out| {
            out.clear();
            out.extend_from_slice(&[1.0, 2.0]);
            Ok(())
        });
        assert_eq!(err, Err(CommError::DimMismatch { want: 3, got: 2 }));
    }
}

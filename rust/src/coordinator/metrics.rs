//! Per-step metrics the coordinator emits (compute vs encode vs wire time,
//! bytes, losses) — the raw material of Tables 1–2 and Figure 4.

#[derive(Clone, Debug, Default)]
pub struct StepMetrics {
    pub step: usize,
    /// measured seconds spent in oracle / model execution
    pub compute_s: f64,
    /// measured seconds spent quantizing + entropy coding + decoding
    pub codec_s: f64,
    /// modeled seconds on the wire (network simulator on real byte counts)
    pub comm_s: f64,
    /// the share of `comm_s` left on the critical path by the engine's
    /// exchange schedule (== `comm_s` for a synchronous exchange)
    pub comm_exposed_s: f64,
    /// the share of `comm_s` hidden behind the next step's compute window
    /// (0.0 for a synchronous exchange); `comm_exposed_s + comm_hidden_s
    /// == comm_s` always
    pub comm_hidden_s: f64,
    /// encoded payload bytes per node this step
    pub bytes_per_node: f64,
    /// exact total wire bits across all nodes this step (summed off the
    /// actual `WirePacket` payloads)
    pub wire_bits: u64,
    /// peak bytes any single point-to-point link carried this step, per the
    /// topology's charge (the hot-spot metric sharded/ring plans shrink)
    pub peak_link_bytes: f64,
    /// workload-specific scalars (losses, w-dist, fid...)
    pub scalars: Vec<(String, f64)>,
}

impl StepMetrics {
    /// Synchronous wall-clock: compute + codec + the full wire time.
    pub fn total_s(&self) -> f64 {
        self.compute_s + self.codec_s + self.comm_s
    }

    /// Wall-clock under the engine's exchange schedule: compute + codec +
    /// only the *exposed* share of the wire time. Falls back to
    /// [`StepMetrics::total_s`] semantics when no split was recorded
    /// (`comm_hidden_s == 0`).
    pub fn wall_s(&self) -> f64 {
        self.compute_s + self.codec_s + self.comm_s - self.comm_hidden_s
    }

    pub fn scalar(&self, name: &str) -> Option<f64> {
        self.scalars.iter().find(|(n, _)| n == name).map(|&(_, v)| v)
    }

    pub fn push_scalar(&mut self, name: &str, v: f64) {
        self.scalars.push((name.to_string(), v));
    }
}

/// Aggregate a run's step metrics.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub steps: Vec<StepMetrics>,
}

impl RunMetrics {
    pub fn push(&mut self, m: StepMetrics) {
        self.steps.push(m);
    }

    pub fn mean_step_ms(&self) -> f64 {
        if self.steps.is_empty() {
            return 0.0;
        }
        self.steps.iter().map(|m| m.total_s()).sum::<f64>() / self.steps.len() as f64
            * 1e3
    }

    pub fn total_bytes(&self) -> f64 {
        self.steps.iter().map(|m| m.bytes_per_node).sum()
    }

    pub fn series(&self, name: &str) -> Vec<(usize, f64)> {
        self.steps
            .iter()
            .filter_map(|m| m.scalar(name).map(|v| (m.step, v)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_and_series() {
        let mut run = RunMetrics::default();
        for i in 0..3 {
            let mut m = StepMetrics {
                step: i,
                compute_s: 0.1,
                codec_s: 0.01,
                comm_s: 0.04,
                comm_exposed_s: 0.04,
                comm_hidden_s: 0.0,
                bytes_per_node: 100.0,
                wire_bits: 800,
                peak_link_bytes: 75.0,
                scalars: vec![],
            };
            m.push_scalar("loss", i as f64);
            run.push(m);
        }
        assert!((run.mean_step_ms() - 150.0).abs() < 1e-9);
        assert_eq!(run.series("loss"), vec![(0, 0.0), (1, 1.0), (2, 2.0)]);
        assert_eq!(run.total_bytes(), 300.0);
    }

    #[test]
    fn wall_time_subtracts_only_the_hidden_share() {
        let mut m = StepMetrics {
            compute_s: 0.1,
            codec_s: 0.01,
            comm_s: 0.04,
            comm_exposed_s: 0.04,
            comm_hidden_s: 0.0,
            ..Default::default()
        };
        // synchronous: wall == total
        assert_eq!(m.wall_s(), m.total_s());
        // overlapped: only the exposed share stays on the critical path
        m.comm_exposed_s = 0.01;
        m.comm_hidden_s = 0.03;
        assert!((m.wall_s() - 0.12).abs() < 1e-12, "{}", m.wall_s());
        // records without a recorded split keep the synchronous reading
        let legacy = StepMetrics { compute_s: 0.2, comm_s: 0.05, ..Default::default() };
        assert_eq!(legacy.wall_s(), legacy.total_s());
    }
}

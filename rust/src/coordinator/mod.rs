//! L3 distributed coordinator: the data-parallel engine of Section 3.1 —
//! K nodes, each holding a local parameter copy and a private stochastic
//! oracle; per step every node quantizes + entropy-codes its dual vector,
//! the topology routes it, decodes the others and applies the identical
//! (ODA) update.
//!
//! The stack is split into orthogonal layers:
//!
//! * **Packets** — all wire traffic flows through the `crate::comm`
//!   subsystem: each node's [`comm::CommEndpoint`](crate::comm::CommEndpoint)
//!   encodes its dual into a real [`comm::WirePacket`](crate::comm::WirePacket)
//!   (entropy-coded payload + per-layer bit offsets + exact bit count) and
//!   decodes received packets through the same codec.
//! * **Aggregation** — [`core`] owns the one decode-aggregate rule (node
//!   order, `v / k` folds). Both engines call it, so aggregates are
//!   bit-identical across engines and topologies *by construction*.
//! * **Topology** — [`topology`] is the pluggable transport layer: a
//!   [`Transport`] is a routing/charging plan over the per-node packets,
//!   selected by a [`TopologySpec`] that travels through `RunSpec`, the
//!   `qoda run` CLI and the bench harnesses. Five plans ship, spanning the
//!   per-link-load spectrum:
//!
//!   | plan | peak bytes/link/step | latency terms | wins when |
//!   |---|---|---|---|
//!   | broadcast-allgather | `(K−1)/K·ΣB` — linear in K | 1 collective | small K |
//!   | hierarchical | full bundle set on leader links | 3 phases | racks exist, K ≈ 12–16 |
//!   | param-server | `ΣB` on the hub link | 2 phases | toy K only |
//!   | sharded reduce-scatter | `~ΣB/K` — 1/K of flat | 2 phases | weak scaling, K ≥ 32 |
//!   | ring | `~2·B` — constant in K | 2(K−1) steps | huge payloads |
//!
//!   The first three live in [`topology`]; the bandwidth-optimal pair lives
//!   in [`collectives`], built on
//!   [`comm::WirePacket::shard`](crate::comm::WirePacket::shard)
//!   (entropy-coded payloads slice at layer bit-offset boundaries, no
//!   re-coding) with layer ownership balanced on the previous round's
//!   *measured coded bits* per layer. Every charge also decomposes into a
//!   [`net::PhaseTimeline`](crate::net::PhaseTimeline) of rack-local /
//!   cross-rack intervals against the heterogeneous link classes and
//!   injectable stragglers of [`net::NetworkModel`](crate::net::NetworkModel),
//!   and reports the peak per-link bytes of its hottest link
//!   ([`WireCharge::peak_link_bytes`]).
//! * **Exchange schedule** — an [`ExchangePlan`] decides how each charge
//!   meets the clock. [`ExchangeMode::Synchronous`] is lock-step: the full
//!   `comm_s` sits on the critical path, and the engines are bit- and
//!   clock-identical to the pre-overlap coordinator (pinned by
//!   `tests/overlap_parity.rs`). [`ExchangeMode::Overlapped`] double-buffers
//!   the duals: round t's bundle travels while round t+1 computes, the
//!   engines apply aggregates `depth` rounds stale, and each step's
//!   `comm_s` splits into `comm_exposed_s` (outlives the compute window)
//!   vs `comm_hidden_s` (overlapped away) — the split the Table 1/2
//!   overlap harness and `examples/overlap_sweep.rs` report.
//!
//! Two engines consume the same packets through the same core:
//!
//! * `sim`      — deterministic in-process engine with a simulated network
//!                clock (drives the Table 1/2 harnesses and the GAN/LM
//!                trainers backed by the native model runtime); overlapped
//!                mode stages aggregates in an engine-side double buffer
//!                ([`sim::ClusterSim::drain_staged`] flushes the tail);
//! * `parallel` — real `std::thread` workers shipping `WirePacket`s over
//!                channels, with the leader decoding in node order
//!                (exercises the actual concurrency for VI-operator
//!                sources). In overlapped mode the double buffer is real:
//!                the leader queues round t+1 before collecting round t's
//!                round-tagged replies, so the in-flight bundle overlaps
//!                worker compute on actual threads. Integration-tested for
//!                bit-identical aggregates *and identical wire bit counts*
//!                against `sim` across all topologies, both protocols and
//!                multiple seeds — in both exchange modes.
//!
//! A third engine lives outside this module: [`wire`](crate::wire) runs the
//! same exchange over real localhost TCP sockets — every node an OS thread,
//! the coded packets shipped as actual bytes — and *measures* `comm_s` with
//! monotonic clocks instead of charging the analytic model. It consumes the
//! same [`core`] decode-aggregate rule, so its aggregates are pinned
//! bit-identical to both engines here by `tests/wire_e2e.rs`.
//!
//! Decode failures surface as `comm::CommError` from both engines — corrupt
//! wire bytes can never panic the coordinator. A new transport is a new
//! [`Transport`] implementation (one file), not an engine fork: the engines
//! never see topology internals, only the [`WireCharge`] they are billed
//! and the timeline the overlap scheduler splits.

pub mod collectives;
pub mod core;
pub mod metrics;
pub mod parallel;
pub mod sim;
pub mod topology;

pub use metrics::StepMetrics;
pub use sim::{ClusterSim, StepTimeModel};
pub use topology::{
    ExchangeMode, ExchangePlan, TopologySpec, Transport, WireCharge,
};

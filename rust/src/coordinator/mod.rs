//! L3 distributed coordinator: the data-parallel synchronous engine of
//! Section 3.1 — K nodes, each holding a local parameter copy and a private
//! stochastic oracle; per step every node quantizes + entropy-codes its dual
//! vector, broadcasts it, decodes the others and applies the identical
//! (ODA) update.
//!
//! All wire traffic flows through the `crate::comm` subsystem: each node's
//! [`comm::CommEndpoint`](crate::comm::CommEndpoint) encodes its dual into a
//! real [`comm::WirePacket`](crate::comm::WirePacket) (entropy-coded
//! payload + per-layer bit offsets + exact bit count), and decodes received
//! packets through the same codec. The engines here are *thin transports*
//! over that shared pipeline — they never re-implement encode/decode and
//! they charge the network model with the packet's actual byte count, so
//! wire-size accounting cannot drift from protocol semantics.
//!
//! Two engines share the same step math and the same packets:
//!  * `sim`      — deterministic in-process engine with a simulated network
//!                 clock (drives the Table 1/2 harnesses and the GAN/LM
//!                 trainers backed by the native model runtime);
//!  * `parallel` — real `std::thread` workers shipping `WirePacket`s over
//!                 channels, with the leader decoding in node order
//!                 (exercises the actual concurrency for VI-operator
//!                 sources; integration-tested for bit-identical aggregates
//!                 *and identical wire bit counts* against `sim` across
//!                 both protocols and multiple seeds).
//!
//! Decode failures surface as `comm::CommError` from both engines — corrupt
//! wire bytes can never panic the coordinator. Future transports (sharded /
//! async allgather, multi-backend collectives) slot in as new consumers of
//! the same packets rather than engine forks.

pub mod metrics;
pub mod parallel;
pub mod sim;

pub use metrics::StepMetrics;
pub use sim::{ClusterSim, StepTimeModel};

//! L3 distributed coordinator: the data-parallel synchronous engine of
//! Section 3.1 — K nodes, each holding a local parameter copy and a private
//! stochastic oracle; per step every node quantizes + entropy-codes its dual
//! vector, broadcasts it, decodes the others and applies the identical
//! (ODA) update.
//!
//! Two engines share the same step math:
//!  * `sim`      — deterministic in-process engine with a simulated network
//!                 clock (drives the Table 1/2 harnesses and the GAN/LM
//!                 trainers; PJRT executables are not Sync so model-backed
//!                 sources run here);
//!  * `parallel` — real `std::thread` workers exchanging encoded `BitBuf`s
//!                 over channels (exercises the actual concurrency for
//!                 VI-operator sources; integration-tested for bit-identical
//!                 agreement with `sim`).

pub mod metrics;
pub mod parallel;
pub mod sim;

pub use metrics::StepMetrics;
pub use sim::{ClusterSim, StepTimeModel};

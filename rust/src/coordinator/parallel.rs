//! Threaded coordinator: real `std::thread` workers shipping encoded
//! [`WirePacket`]s over channels. Each worker owns its oracle plus a
//! `crate::comm` codec; the leader decodes every payload through the same
//! pipeline, exactly as a receiving node would — there is no engine-local
//! copy of the encode/decode plumbing, and the (order-sensitive) aggregate
//! fold is the shared [`super::core`] one, so this engine is bit-identical
//! to the sim engine under every topology.
//!
//! Used by the VI-operator workloads (operators are `Sync`); the model-
//! backed sources run on the `sim` engine instead. Integration tests assert
//! bit-identical aggregates *and identical wire bit counts* between both
//! engines under the same seeds — replies are therefore aggregated in node
//! order, not arrival order.
//!
//! Exchanges follow an [`ExchangePlan`]. Synchronous runs use the lock-step
//! loop (send round t, collect round t, update) that is bit-identical to
//! the pre-overlap engine. Overlapped runs use a *real* double buffer over
//! the same channels: the leader queues round t+1's query before collecting
//! round t's packets (workers never idle on the leader's decode), applies
//! aggregates `depth` rounds stale while the newer bundle is still in
//! flight, round-tags replies so interleaved rounds cannot mix, and drains
//! the pipeline at the end so every round's aggregate is applied exactly
//! once, in order.

use super::core::decode_aggregate_into;
use super::topology::{ExchangeMode, ExchangePlan, TopologySpec, Transport};
use crate::coding::protocol::ProtocolKind;
use crate::comm::{Adaptation, CommError, Compressor, QuantCompressor, WirePacket};
use crate::net::NetworkModel;
use crate::quant::layer_map::LayerMap;
use crate::quant::QuantConfig;
use crate::stats::rng::Rng;
use crate::vi::noise::{NoiseModel, Oracle};
use crate::vi::operator::Operator;
use std::sync::mpsc;

/// Message from leader to workers each round.
enum Cmd {
    Eval(Vec<f64>),
    Stop,
}

/// Worker reply: the node id, the round the packet belongs to (rounds
/// interleave on the reply channel under an overlapped exchange), and the
/// encode outcome — a worker whose encode fails reports the error instead
/// of dying silently, and the leader surfaces it from the run.
struct Reply {
    node: usize,
    round: usize,
    packet: Result<WirePacket, CommError>,
}

/// Configuration shared by all nodes (the synchronized quantization state).
#[derive(Clone)]
pub struct SharedQuantState {
    pub map: LayerMap,
    pub cfg: QuantConfig,
    pub protocol: ProtocolKind,
    /// adaptation policy every node starts from. `Fixed` (the wire-safe
    /// default) keeps books static for the whole run; `Scheduled` re-plans
    /// bit-widths from receiver-observable statistics, which this engine
    /// supports by decoding each node's stream through a dedicated per-node
    /// replica (see [`run_rounds_over`]). Encode-count policies (`Levels` /
    /// `LGreco`) are loopback-only: a pure decoder cannot replicate their
    /// encode-side statistics.
    pub adaptation: Adaptation,
}

impl SharedQuantState {
    /// Build the node codec for this synchronized state — identical on
    /// every node, so codebooks never travel on the wire.
    pub fn codec(&self, seed: u64) -> QuantCompressor {
        QuantCompressor::new(
            self.map.clone(),
            self.cfg.clone(),
            self.protocol,
            self.adaptation.clone(),
            seed,
        )
    }
}

/// Oracle seed for `node` (shared with the sim engine so the two can be
/// compared bit-for-bit under the same run seed).
pub fn worker_oracle_seed(seed: u64, node: usize) -> u64 {
    seed ^ (0x9E37 + node as u64 * 0x79B9)
}

/// Quantizer RNG seed for `node` (ditto).
pub fn worker_codec_seed(seed: u64, node: usize) -> u64 {
    seed.wrapping_add(node as u64 * 7919 + 13)
}

/// What [`run_rounds_over`] produced, including the topology's accounting.
pub struct RoundsReport {
    /// final iterate
    pub x: Vec<f64>,
    /// total wire bits charged by the topology across all rounds
    pub wire_bits: u64,
    /// mean decoded vector of the last round
    pub last_mean: Vec<f64>,
    /// simulated network-clock seconds accumulated across rounds
    pub comm_s: f64,
    /// the share of `comm_s` the exchange plan left on the critical path
    /// (== `comm_s` for synchronous runs)
    pub comm_exposed_s: f64,
    /// the share of `comm_s` hidden behind the plan's compute window
    /// (`comm_exposed_s + comm_hidden_s == comm_s`)
    pub comm_hidden_s: f64,
}

/// Run `steps` rounds of the distributed exchange with `k` worker threads:
/// at each round the leader broadcasts the query point, every worker samples
/// its oracle and encodes a wire packet via the shared comm pipeline; the
/// leader decodes all payloads (in node order, through the shared
/// decode-aggregate core), averages and applies `update` to produce the
/// next query point.
///
/// Returns (final x, total wire bits, mean decoded vector of the last
/// round), charging wire bits as the flat broadcast-allgather topology does
/// (each packet counted once). For other topologies and the network clock
/// use [`run_rounds_over`].
#[allow(clippy::too_many_arguments)]
pub fn run_rounds(
    op: &dyn Operator,
    noise: NoiseModel,
    k: usize,
    state: &SharedQuantState,
    x0: Vec<f64>,
    steps: usize,
    seed: u64,
    update: impl FnMut(&mut Vec<f64>, &[f64], usize),
) -> Result<(Vec<f64>, u64, Vec<f64>), CommError> {
    let report = run_rounds_over(
        op,
        noise,
        k,
        state,
        x0,
        steps,
        seed,
        &TopologySpec::BroadcastAllGather,
        &NetworkModel::genesis_cloud(5.0),
        ExchangePlan::synchronous(),
        update,
    )?;
    Ok((report.x, report.wire_bits, report.last_mean))
}

/// [`run_rounds`] under an arbitrary [`TopologySpec`] and [`ExchangePlan`]:
/// the same threaded exchange, with the topology routing/charging each
/// round's packets against `net` and the plan scheduling comm against
/// compute. The aggregates are identical under every topology (the
/// aggregate math lives in the shared core); only `wire_bits` / `comm_s` /
/// the exposed split differ. Under `ExchangeMode::Synchronous` the loop —
/// and every float it produces — is identical to the pre-overlap engine.
///
/// Under `ExchangeMode::Overlapped { depth }` the iterates follow the
/// depth-step-stale schedule: round t's query point is `x_t` where
/// `x_{t+1} = update(x_t, mean_{t-depth})` (no update while the pipe
/// fills), the leader queues round t+1 *before* collecting round t so the
/// in-flight bundle genuinely overlaps worker compute, and the pipeline
/// drains at the end — every round's aggregate is applied exactly once, in
/// round order, with `update` receiving the aggregate's producing round.
#[allow(clippy::too_many_arguments)]
pub fn run_rounds_over(
    op: &dyn Operator,
    noise: NoiseModel,
    k: usize,
    state: &SharedQuantState,
    x0: Vec<f64>,
    steps: usize,
    seed: u64,
    topology: &TopologySpec,
    net: &NetworkModel,
    plan: ExchangePlan,
    mut update: impl FnMut(&mut Vec<f64>, &[f64], usize),
) -> Result<RoundsReport, CommError> {
    let d = op.dim();
    assert_eq!(x0.len(), d);
    // the leader decodes with the same synchronized state (its RNG seed is
    // irrelevant: decode draws no randomness)
    let mut decoder = state.codec(0);
    // under scheduled adaptation the leader keeps one decoder replica per
    // node: replica n decodes only node n's stream, so it folds exactly the
    // statistics node n folds through its self-decode and re-plans at the
    // same decode counts — their books stay bit-identical with no side
    // channel (a single shared decoder would see k decodes per round and
    // desynchronize immediately)
    let scheduled = matches!(state.adaptation, Adaptation::Scheduled { .. });
    let mut replicas: Vec<QuantCompressor> = if scheduled {
        (0..k).map(|n| state.codec(worker_codec_seed(seed, n))).collect()
    } else {
        Vec::new()
    };
    let mut decoded = Vec::with_capacity(d);
    let mut transport = topology.build();
    let mut charge_rng = Rng::new(seed ^ 0x7A11);

    let (reply_tx, reply_rx) = mpsc::channel::<Reply>();

    let mut x = x0;
    let mut wire_bits = 0u64;
    let mut comm_s = 0.0f64;
    let mut comm_exposed_s = 0.0f64;
    let mut comm_hidden_s = 0.0f64;
    let mut last_mean = vec![0.0; d];

    let result: Result<(), CommError> = std::thread::scope(|scope| {
        // the senders live inside the scope: any exit path (including a
        // decode error) drops them, which unblocks and terminates workers
        let mut to_workers: Vec<mpsc::Sender<Cmd>> = Vec::with_capacity(k);
        for node in 0..k {
            let (tx, rx) = mpsc::channel::<Cmd>();
            to_workers.push(tx);
            let reply_tx = reply_tx.clone();
            let mut codec = state.codec(worker_codec_seed(seed, node));
            scope.spawn(move || {
                let mut oracle = Oracle::new(op, noise, worker_oracle_seed(seed, node));
                let mut selfdec: Vec<f64> = Vec::new();
                let mut round = 0usize;
                while let Ok(Cmd::Eval(xq)) = rx.recv() {
                    round += 1;
                    let dual = oracle.sample(&xq);
                    let mut packet = codec.encode(&dual);
                    if scheduled {
                        // observe the own stream: fold the decoded packet
                        // into the scheduled statistics so this node's
                        // schedule advances in lock-step with the leader's
                        // replica (which decodes the same packet with the
                        // same books and folds the same values)
                        if let Ok(p) = &packet {
                            if let Err(e) = codec.decode_into(p, &mut selfdec) {
                                packet = Err(e);
                            }
                        }
                    }
                    if reply_tx.send(Reply { node, round, packet }).is_err() {
                        break;
                    }
                }
            });
        }
        drop(reply_tx);

        let mut mean = Vec::with_capacity(d);
        let mut slots: Vec<Option<WirePacket>> = (0..k).map(|_| None).collect();
        // replies from a newer round that raced ahead of the one being
        // collected (only possible under an overlapped exchange)
        let mut early: Vec<Reply> = Vec::new();
        let collect_round = |t: usize,
                             slots: &mut [Option<WirePacket>],
                             early: &mut Vec<Reply>|
         -> Result<(), CommError> {
            for s in slots.iter_mut() {
                *s = None;
            }
            let mut have = 0usize;
            let mut i = 0;
            while i < early.len() {
                if early[i].round == t {
                    let r = early.swap_remove(i);
                    slots[r.node] = Some(r.packet?);
                    have += 1;
                } else {
                    i += 1;
                }
            }
            while have < k {
                // a dead worker (hung up without replying) is an exchange
                // failure, not a leader panic
                let r = reply_rx.recv().map_err(|_| CommError::WorkerLost)?;
                if r.round == t {
                    slots[r.node] = Some(r.packet?);
                    have += 1;
                } else {
                    debug_assert!(r.round > t, "stale reply for round {}", r.round);
                    early.push(r);
                }
            }
            Ok(())
        };

        // one full exchange for round `t`: collect the round-tagged
        // packets, decode-aggregate into `mean` (node order, bit-identical
        // to the sim engine), charge the topology and accumulate the plan's
        // exposed/hidden split. Shared verbatim by both schedule arms, so
        // the golden-parity-critical path exists exactly once.
        let mut exchange_round = |t: usize, mean: &mut Vec<f64>| -> Result<(), CommError> {
            collect_round(t, &mut slots, &mut early)?;
            // collect_round filled every slot for round t; an empty slot
            // here means the accounting broke — surface it, don't panic
            let mut bits: Vec<u64> = Vec::with_capacity(k);
            for s in slots.iter() {
                match s {
                    Some(p) => bits.push(p.len_bits() as u64),
                    None => return Err(CommError::WorkerLost),
                }
            }
            decode_aggregate_into(k, d, mean, &mut decoded, |node, out| {
                match slots[node].as_ref() {
                    Some(packet) if scheduled => replicas[node].decode_into(packet, out),
                    Some(packet) => decoder.decode_into(packet, out),
                    None => Err(CommError::WorkerLost),
                }
            })?;
            // layer-observing transports (sharded) balance ownership on the
            // measured per-layer coded bits of the packets just collected
            if transport.observes_layers() {
                let tables: Vec<Vec<u64>> = slots
                    .iter()
                    .map(|s| s.as_ref().map(|p| p.layer_bits()).unwrap_or_default())
                    .collect();
                transport.observe_packet_layers(&tables);
            }
            let charge = transport.charge(
                &bits,
                d,
                net,
                false,
                state.protocol == ProtocolKind::Main,
                &mut charge_rng,
            );
            wire_bits += charge.wire_bits;
            comm_s += charge.comm_s;
            let (e, h) = plan.split(charge.comm_s);
            comm_exposed_s += e;
            comm_hidden_s += h;
            Ok(())
        };

        match plan.mode {
            ExchangeMode::Synchronous => {
                for t in 1..=steps {
                    for tx in &to_workers {
                        tx.send(Cmd::Eval(x.clone())).map_err(|_| CommError::WorkerLost)?;
                    }
                    exchange_round(t, &mut mean)?;
                    update(&mut x, &mean, t);
                    last_mean.clone_from(&mean);
                }
            }
            ExchangeMode::Overlapped { depth } => {
                let depth = depth.max(1);
                // aggregates decoded but not yet applied: (producing round,
                // mean), oldest first — the leader-side double buffer
                let mut staged: std::collections::VecDeque<(usize, Vec<f64>)> =
                    std::collections::VecDeque::new();
                if steps > 0 {
                    for tx in &to_workers {
                        tx.send(Cmd::Eval(x.clone())).map_err(|_| CommError::WorkerLost)?;
                    }
                }
                for t in 1..=steps {
                    // round t is in flight. Before touching its replies,
                    // advance the iterate with the aggregate leaving the
                    // depth window and queue round t+1 — workers proceed
                    // while the leader decodes.
                    if t < steps {
                        if staged.front().map_or(false, |&(r, _)| r + depth <= t) {
                            if let Some((r, m)) = staged.pop_front() {
                                update(&mut x, &m, r);
                            }
                        }
                        for tx in &to_workers {
                            tx.send(Cmd::Eval(x.clone())).map_err(|_| CommError::WorkerLost)?;
                        }
                    }
                    exchange_round(t, &mut mean)?;
                    staged.push_back((t, mean.clone()));
                    last_mean.clone_from(&mean);
                }
                // pipeline drain: the aggregates still in flight apply in
                // round order — every exchange yields exactly one update
                while let Some((r, m)) = staged.pop_front() {
                    update(&mut x, &m, r);
                }
            }
        }
        for tx in &to_workers {
            let _ = tx.send(Cmd::Stop);
        }
        Ok(())
    });
    result?;

    Ok(RoundsReport { x, wire_bits, last_mean, comm_s, comm_exposed_s, comm_hidden_s })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::LevelSequence;
    use crate::stats::rng::Rng;
    use crate::stats::vecops::{l2_norm64, sub};
    use crate::vi::operator::QuadraticOperator;

    fn state(d: usize, bits: u32) -> SharedQuantState {
        SharedQuantState {
            map: LayerMap::single(d),
            cfg: QuantConfig::same(1, LevelSequence::bits(bits), 2.0),
            protocol: ProtocolKind::Main,
            adaptation: Adaptation::Fixed,
        }
    }

    #[test]
    fn threaded_sgd_converges() {
        let mut rng = Rng::new(1);
        let op = QuadraticOperator::random(16, 0.5, &mut rng);
        let st = state(16, 6);
        let (x, bits, _) = run_rounds(
            &op,
            NoiseModel::Absolute { sigma: 0.1 },
            4,
            &st,
            vec![0.0; 16],
            400,
            7,
            |x, mean, _| {
                for (xi, g) in x.iter_mut().zip(mean) {
                    *xi -= 0.08 * g;
                }
            },
        )
        .unwrap();
        let err = l2_norm64(&sub(&x, &op.sol));
        assert!(err < 0.3 * l2_norm64(&op.sol), "{err}");
        assert!(bits > 0);
    }

    #[test]
    fn threaded_matches_sequential_given_seeds() {
        // same oracle + codec seeds => identical aggregate per round
        let mut rng = Rng::new(2);
        let op = QuadraticOperator::random(8, 0.5, &mut rng);
        let st = state(8, 5);
        let seed = 42u64;
        let k = 3;
        let x0 = vec![0.25; 8];

        // sequential reference for one round, through the same comm pipeline
        let mut seq_mean = vec![0.0; 8];
        let mut decoded = Vec::new();
        for node in 0..k {
            let mut oracle = Oracle::new(
                &op,
                NoiseModel::Absolute { sigma: 0.2 },
                worker_oracle_seed(seed, node),
            );
            let mut codec = st.codec(worker_codec_seed(seed, node));
            let dual = oracle.sample(&x0);
            let packet = codec.encode(&dual).expect("encode");
            codec.decode_into(&packet, &mut decoded).unwrap();
            for (m, v) in seq_mean.iter_mut().zip(&decoded) {
                *m += v / k as f64;
            }
        }

        let (_, _, par_mean) = run_rounds(
            &op,
            NoiseModel::Absolute { sigma: 0.2 },
            k,
            &st,
            x0,
            1,
            seed,
            |_x, _mean, _| {},
        )
        .unwrap();
        assert_eq!(par_mean, seq_mean, "aggregates must be bit-identical");
    }

    #[test]
    fn all_nodes_contribute() {
        let mut rng = Rng::new(3);
        let op = QuadraticOperator::random(4, 0.5, &mut rng);
        let st = state(4, 8);
        // with zero noise and fine quantization, mean ~= A(x0)
        let x0 = vec![1.0; 4];
        let a = op.apply_vec(&x0);
        let (_, _, mean) =
            run_rounds(&op, NoiseModel::None, 5, &st, x0, 1, 9, |_, _, _| {}).unwrap();
        for (m, t) in mean.iter().zip(&a) {
            assert!((m - t).abs() < 0.05 * t.abs().max(1.0), "{m} vs {t}");
        }
    }

    #[test]
    fn topologies_agree_on_iterates_and_charge_the_clock() {
        let mut rng = Rng::new(5);
        let op = QuadraticOperator::random(8, 0.5, &mut rng);
        let st = state(8, 5);
        let net = NetworkModel::genesis_cloud(5.0);
        let run = |spec: &TopologySpec| {
            run_rounds_over(
                &op,
                NoiseModel::Absolute { sigma: 0.2 },
                6,
                &st,
                vec![0.1; 8],
                3,
                17,
                spec,
                &net,
                ExchangePlan::synchronous(),
                |x, mean, _| {
                    for (xi, g) in x.iter_mut().zip(mean) {
                        *xi -= 0.05 * g;
                    }
                },
            )
            .unwrap()
        };
        let flat = run(&TopologySpec::BroadcastAllGather);
        let hier = run(&TopologySpec::Hierarchical { racks: 3 });
        let ps = run(&TopologySpec::ParameterServer);
        let sharded = run(&TopologySpec::ShardedReduceScatter);
        let ring = run(&TopologySpec::Ring);
        assert_eq!(flat.x, hier.x);
        assert_eq!(flat.x, ps.x);
        assert_eq!(flat.x, sharded.x);
        assert_eq!(flat.x, ring.x);
        assert_eq!(flat.last_mean, hier.last_mean);
        assert_eq!(flat.last_mean, sharded.last_mean);
        assert_eq!(flat.last_mean, ring.last_mean);
        assert!(hier.wire_bits > flat.wire_bits);
        assert!(ps.wire_bits > flat.wire_bits);
        // the bandwidth-optimal plans route differently from flat too
        assert_ne!(sharded.wire_bits, flat.wire_bits);
        assert_ne!(ring.wire_bits, flat.wire_bits);
        assert!(
            flat.comm_s > 0.0
                && hier.comm_s > 0.0
                && ps.comm_s > 0.0
                && sharded.comm_s > 0.0
                && ring.comm_s > 0.0
        );
        // synchronous accounting: everything exposed, nothing hidden
        for r in [&flat, &hier, &ps, &sharded, &ring] {
            assert_eq!(r.comm_exposed_s, r.comm_s);
            assert_eq!(r.comm_hidden_s, 0.0);
        }
    }

    #[test]
    fn overlapped_rounds_apply_every_aggregate_once_in_order() {
        // instrument the update closure: under an overlapped exchange the
        // aggregates must arrive depth rounds stale but each exactly once,
        // in producing-round order, with the drain flushing the tail
        let mut rng = Rng::new(7);
        let op = QuadraticOperator::random(6, 0.5, &mut rng);
        let st = state(6, 6);
        let net = NetworkModel::genesis_cloud(5.0);
        let steps = 5;
        for depth in [1usize, 2] {
            let mut applied: Vec<usize> = Vec::new();
            let report = run_rounds_over(
                &op,
                NoiseModel::Absolute { sigma: 0.1 },
                3,
                &st,
                vec![0.2; 6],
                steps,
                23,
                &TopologySpec::BroadcastAllGather,
                &net,
                ExchangePlan::overlapped(depth, 0.0),
                |x, mean, t| {
                    applied.push(t);
                    for (xi, g) in x.iter_mut().zip(mean) {
                        *xi -= 0.05 * g;
                    }
                },
            )
            .unwrap();
            assert_eq!(applied, (1..=steps).collect::<Vec<_>>(), "depth {depth}");
            assert!(report.wire_bits > 0);
            // zero compute window: the overlap hides nothing
            assert_eq!(report.comm_exposed_s, report.comm_s);
            assert_eq!(report.comm_hidden_s, 0.0);
        }
    }

    #[test]
    fn overlapped_single_round_matches_synchronous_after_drain() {
        // with one round there is nothing to overlap: the drained pipeline
        // must land exactly where the synchronous engine does
        let mut rng = Rng::new(11);
        let op = QuadraticOperator::random(8, 0.5, &mut rng);
        let st = state(8, 5);
        let net = NetworkModel::genesis_cloud(5.0);
        let run = |plan: ExchangePlan| {
            run_rounds_over(
                &op,
                NoiseModel::Absolute { sigma: 0.2 },
                3,
                &st,
                vec![0.3; 8],
                1,
                31,
                &TopologySpec::BroadcastAllGather,
                &net,
                plan,
                |x, mean, _| {
                    for (xi, g) in x.iter_mut().zip(mean) {
                        *xi -= 0.07 * g;
                    }
                },
            )
            .unwrap()
        };
        let sync = run(ExchangePlan::synchronous());
        let over = run(ExchangePlan::overlapped(1, 0.0));
        assert_eq!(sync.x, over.x);
        assert_eq!(sync.last_mean, over.last_mean);
        assert_eq!(sync.wire_bits, over.wire_bits);
        assert_eq!(sync.comm_s, over.comm_s);
    }

    #[test]
    fn overlapped_hides_comm_behind_the_compute_window() {
        let mut rng = Rng::new(13);
        let op = QuadraticOperator::random(6, 0.5, &mut rng);
        let st = state(6, 5);
        let net = NetworkModel::genesis_cloud(5.0);
        let report = run_rounds_over(
            &op,
            NoiseModel::Absolute { sigma: 0.1 },
            4,
            &st,
            vec![0.1; 6],
            3,
            41,
            &TopologySpec::Hierarchical { racks: 2 },
            &net,
            // a generous window: everything hides
            ExchangePlan::overlapped(1, 10.0),
            |_, _, _| {},
        )
        .unwrap();
        assert!(report.comm_s > 0.0);
        assert_eq!(report.comm_exposed_s, 0.0);
        assert_eq!(report.comm_hidden_s, report.comm_s);
    }
}

//! Threaded coordinator: real `std::thread` workers, real encoded `BitBuf`s
//! over channels. Each worker owns its oracle + quantizer + encoder; the
//! leader decodes every payload exactly as a receiving node would.
//!
//! Used by the VI-operator workloads (operators are `Sync`); the PJRT-backed
//! models run on the `sim` engine instead (executables are not `Sync`).
//! Integration tests assert bit-identical aggregates between both engines
//! under the same seeds.

use crate::coding::bitio::BitBuf;
use crate::coding::protocol::{decode_vector, encode_vector, Codebooks, ProtocolKind};
use crate::quant::layer_map::LayerMap;
use crate::quant::quantizer::{dequantize, quantize};
use crate::quant::QuantConfig;
use crate::stats::rng::Rng;
use crate::vi::noise::{NoiseModel, Oracle};
use crate::vi::operator::Operator;
use std::sync::mpsc;

/// Message from leader to workers each round.
enum Cmd {
    Eval(Vec<f64>),
    Stop,
}

/// Worker reply: the encoded dual vector.
struct Reply {
    node: usize,
    payload: BitBuf,
}

/// Configuration shared by all nodes (the synchronized quantization state).
#[derive(Clone)]
pub struct SharedQuantState {
    pub map: LayerMap,
    pub cfg: QuantConfig,
    pub protocol: ProtocolKind,
}

impl SharedQuantState {
    pub fn books(&self) -> Codebooks {
        Codebooks::uniform(self.protocol, &self.cfg, &self.map.type_proportions())
    }
}

/// Run `steps` rounds of the distributed exchange with `k` worker threads:
/// at each round the leader broadcasts the query point, every worker samples
/// its oracle, quantizes, encodes; the leader decodes all payloads, averages
/// and applies `update` to produce the next query point.
///
/// Returns (final x, total wire bits, mean decoded vector of the last round).
pub fn run_rounds(
    op: &dyn Operator,
    noise: NoiseModel,
    k: usize,
    state: &SharedQuantState,
    x0: Vec<f64>,
    steps: usize,
    seed: u64,
    mut update: impl FnMut(&mut Vec<f64>, &[f64], usize),
) -> (Vec<f64>, u64, Vec<f64>) {
    let d = op.dim();
    assert_eq!(x0.len(), d);
    let books = state.books();

    let mut to_workers: Vec<mpsc::Sender<Cmd>> = Vec::with_capacity(k);
    let (reply_tx, reply_rx) = mpsc::channel::<Reply>();

    let mut x = x0;
    let mut total_bits = 0u64;
    let mut last_mean = vec![0.0; d];

    std::thread::scope(|scope| {
        for node in 0..k {
            let (tx, rx) = mpsc::channel::<Cmd>();
            to_workers.push(tx);
            let reply_tx = reply_tx.clone();
            let state = state.clone();
            let books = state.books();
            scope.spawn(move || {
                let mut oracle =
                    Oracle::new(op, noise, seed ^ (0x9E37 + node as u64 * 0x79B9));
                let mut qrng = Rng::new(seed.wrapping_add(node as u64 * 7919 + 13));
                while let Ok(Cmd::Eval(xq)) = rx.recv() {
                    let dual = oracle.sample(&xq);
                    let v32: Vec<f32> = dual.iter().map(|&v| v as f32).collect();
                    let qv = quantize(&v32, &state.map, &state.cfg, &mut qrng);
                    let payload = encode_vector(&qv, &books);
                    if reply_tx.send(Reply { node, payload }).is_err() {
                        break;
                    }
                }
            });
        }
        drop(reply_tx);

        for t in 1..=steps {
            for tx in &to_workers {
                tx.send(Cmd::Eval(x.clone())).expect("worker alive");
            }
            let mut mean = vec![0.0; d];
            for _ in 0..k {
                let r = reply_rx.recv().expect("reply");
                total_bits += r.payload.len_bits() as u64;
                let qv = decode_vector(&r.payload, &state.map, &books);
                let hat = dequantize(&qv, &state.cfg);
                let _ = r.node;
                for (m, v) in mean.iter_mut().zip(&hat) {
                    *m += *v as f64 / k as f64;
                }
            }
            update(&mut x, &mean, t);
            last_mean = mean;
        }
        for tx in &to_workers {
            let _ = tx.send(Cmd::Stop);
        }
    });

    (x, total_bits, last_mean)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::LevelSequence;
    use crate::stats::rng::Rng;
    use crate::stats::vecops::{l2_norm64, sub};
    use crate::vi::operator::QuadraticOperator;

    fn state(d: usize, bits: u32) -> SharedQuantState {
        SharedQuantState {
            map: LayerMap::single(d),
            cfg: QuantConfig::same(1, LevelSequence::bits(bits), 2.0),
            protocol: ProtocolKind::Main,
        }
    }

    #[test]
    fn threaded_sgd_converges() {
        let mut rng = Rng::new(1);
        let op = QuadraticOperator::random(16, 0.5, &mut rng);
        let st = state(16, 6);
        let (x, bits, _) = run_rounds(
            &op,
            NoiseModel::Absolute { sigma: 0.1 },
            4,
            &st,
            vec![0.0; 16],
            400,
            7,
            |x, mean, _| {
                for (xi, g) in x.iter_mut().zip(mean) {
                    *xi -= 0.08 * g;
                }
            },
        );
        let err = l2_norm64(&sub(&x, &op.sol));
        assert!(err < 0.3 * l2_norm64(&op.sol), "{err}");
        assert!(bits > 0);
    }

    #[test]
    fn threaded_matches_sequential_given_seeds() {
        // same oracle + quantizer seeds => identical aggregate per round
        let mut rng = Rng::new(2);
        let op = QuadraticOperator::random(8, 0.5, &mut rng);
        let st = state(8, 5);
        let books = st.books();
        let seed = 42u64;
        let k = 3;
        let x0 = vec![0.25; 8];

        // sequential reference for one round
        let mut seq_mean = vec![0.0; 8];
        for node in 0..k {
            let mut oracle = Oracle::new(
                &op,
                NoiseModel::Absolute { sigma: 0.2 },
                seed ^ (0x9E37 + node as u64 * 0x79B9),
            );
            let mut qrng = Rng::new(seed.wrapping_add(node as u64 * 7919 + 13));
            let dual = oracle.sample(&x0);
            let v32: Vec<f32> = dual.iter().map(|&v| v as f32).collect();
            let qv = quantize(&v32, &st.map, &st.cfg, &mut qrng);
            let hat = dequantize(&decode_vector(&encode_vector(&qv, &books), &st.map, &books), &st.cfg);
            for (m, v) in seq_mean.iter_mut().zip(&hat) {
                *m += *v as f64 / k as f64;
            }
        }

        let (_, _, par_mean) = run_rounds(
            &op,
            NoiseModel::Absolute { sigma: 0.2 },
            k,
            &st,
            x0,
            1,
            seed,
            |_x, _mean, _| {},
        );
        for (a, b) in par_mean.iter().zip(&seq_mean) {
            assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        }
    }

    #[test]
    fn all_nodes_contribute() {
        let mut rng = Rng::new(3);
        let op = QuadraticOperator::random(4, 0.5, &mut rng);
        let st = state(4, 8);
        // with zero noise and fine quantization, mean ~= A(x0)
        let x0 = vec![1.0; 4];
        let a = op.apply_vec(&x0);
        let (_, _, mean) =
            run_rounds(&op, NoiseModel::None, 5, &st, x0, 1, 9, |_, _, _| {});
        for (m, t) in mean.iter().zip(&a) {
            assert!((m - t).abs() < 0.05 * t.abs().max(1.0), "{m} vs {t}");
        }
    }
}

//! Deterministic cluster engine with a simulated network clock.
//!
//! The engine is a thin transport over the shared `crate::comm` pipeline:
//! each node's [`CommEndpoint`] encodes its dual vector into a real
//! [`WirePacket`](crate::comm::WirePacket), the engine charges the network
//! model with the packet's *actual* byte count (never a codec self-report),
//! decodes it exactly as a receiving node would, and aggregates. The
//! optimizer logic (ODA / Adam / SGD) lives in the drivers that call
//! `exchange` each step.

use super::metrics::StepMetrics;
use crate::comm::{CommEndpoint, CommError, Compressor};
use crate::net::{Collective, NetworkModel};
use crate::stats::rng::Rng;
use std::time::Instant;

/// How a harness obtains the per-step compute time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StepTimeModel {
    /// wall-clock measured around the oracle/model execution
    Measured,
    /// calibrated constant (paper-regime tables regenerate host-independent)
    Calibrated { compute_s: f64 },
}

pub struct ClusterSim {
    endpoints: Vec<CommEndpoint>,
    pub net: NetworkModel,
    /// true => payloads are uniform fp32 and in-network reduction applies
    /// (NCCL ring allreduce); false => entropy-coded allgather (OpenMPI)
    pub uncompressed_collective: bool,
    /// Main (shared-codeword) vs Alternating protocol for jitter accounting
    pub main_protocol: bool,
    rng: Rng,
    /// decode scratch, reused across nodes and steps
    decoded: Vec<f64>,
}

impl ClusterSim {
    pub fn new(
        codecs: Vec<Box<dyn Compressor>>,
        net: NetworkModel,
        uncompressed_collective: bool,
    ) -> Self {
        ClusterSim {
            endpoints: codecs.into_iter().map(CommEndpoint::new).collect(),
            net,
            uncompressed_collective,
            main_protocol: true,
            rng: Rng::new(0xC0FFEE),
            decoded: Vec::new(),
        }
    }

    pub fn k(&self) -> usize {
        self.endpoints.len()
    }

    pub fn endpoints(&self) -> &[CommEndpoint] {
        &self.endpoints
    }

    /// One synchronous exchange: every node encodes its dual vector into a
    /// wire packet, "broadcasts" it, everyone decodes and averages. Returns
    /// the mean decoded vector plus codec/wire timing on the real encoded
    /// byte counts.
    pub fn exchange(&mut self, duals: &[Vec<f64>]) -> Result<(Vec<f64>, StepMetrics), CommError> {
        assert_eq!(duals.len(), self.endpoints.len());
        let k = duals.len();
        let d = duals[0].len();
        let t0 = Instant::now();
        let mut mean = vec![0.0; d];
        let mut bytes = Vec::with_capacity(k);
        let mut wire_bits = 0u64;
        for (ep, dual) in self.endpoints.iter_mut().zip(duals) {
            // ENC onto the wire; the packet's bit count is the one truth
            let bits = ep.send(dual);
            wire_bits += bits as u64;
            bytes.push(bits as f64 / 8.0);
            // DEC as every receiving node would
            ep.recv_into(&mut self.decoded)?;
            for (m, v) in mean.iter_mut().zip(&self.decoded) {
                *m += v / k as f64;
            }
        }
        let codec_s = t0.elapsed().as_secs_f64();
        let kind = if self.uncompressed_collective {
            Collective::RingAllReduce
        } else {
            Collective::RingAllGather
        };
        let comm_s = self.net.sample_collective_seconds(
            kind,
            &bytes,
            self.main_protocol,
            &mut self.rng,
        );
        let metrics = StepMetrics {
            step: 0,
            compute_s: 0.0,
            codec_s,
            comm_s,
            bytes_per_node: bytes.iter().sum::<f64>() / k as f64,
            wire_bits,
            scalars: Vec::new(),
        };
        Ok((mean, metrics))
    }

    /// Trigger Algorithm 1's level update (lines 2-7) on every node. Must be
    /// called between exchanges (in-flight packets decode with the books
    /// they were encoded under).
    pub fn update_levels(&mut self) {
        for ep in &mut self.endpoints {
            ep.update_levels();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{IdentityCompressor, QuantCompressor};
    use crate::net::NetworkModel;
    use crate::quant::layer_map::LayerMap;
    use crate::stats::rng::Rng;

    fn duals(k: usize, d: usize, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = Rng::new(seed);
        (0..k).map(|_| (0..d).map(|_| rng.gaussian()).collect()).collect()
    }

    #[test]
    fn identity_exchange_is_exact_mean_of_f32_wire() {
        let comps: Vec<Box<dyn Compressor>> =
            (0..4).map(|_| Box::new(IdentityCompressor) as _).collect();
        let mut sim = ClusterSim::new(comps, NetworkModel::genesis_cloud(5.0), true);
        let ds = duals(4, 32, 1);
        let (mean, m) = sim.exchange(&ds).unwrap();
        for i in 0..32 {
            // fp32 travels on the wire, so the reference mean is over the
            // f32-rounded duals
            let want: f64 = ds.iter().map(|d| d[i] as f32 as f64).sum::<f64>() / 4.0;
            assert!((mean[i] - want).abs() < 1e-12);
        }
        assert_eq!(m.bytes_per_node, 32.0 * 4.0);
        assert_eq!(m.wire_bits, 4 * 32 * 32);
        assert!(m.comm_s > 0.0);
    }

    #[test]
    fn quantized_exchange_smaller_wire_time() {
        let map = LayerMap::single(4096);
        let idc: Vec<Box<dyn Compressor>> =
            (0..4).map(|_| Box::new(IdentityCompressor) as _).collect();
        let qc: Vec<Box<dyn Compressor>> = (0..4)
            .map(|i| Box::new(QuantCompressor::global_bits(&map, 5, 128, i as u64)) as _)
            .collect();
        let net = NetworkModel::genesis_cloud(5.0);
        let mut sim_raw = ClusterSim::new(idc, net.clone(), true);
        let mut sim_q = ClusterSim::new(qc, net, false);
        let ds = duals(4, 4096, 2);
        let (_, mr) = sim_raw.exchange(&ds).unwrap();
        let (_, mq) = sim_q.exchange(&ds).unwrap();
        assert!(mq.bytes_per_node < mr.bytes_per_node / 3.0);
        assert!(mq.comm_s < mr.comm_s);
    }

    #[test]
    fn charged_bytes_match_packet_payloads() {
        let map = LayerMap::single(512);
        let qc: Vec<Box<dyn Compressor>> = (0..2)
            .map(|i| Box::new(QuantCompressor::global_bits(&map, 4, 128, i as u64)) as _)
            .collect();
        let mut sim = ClusterSim::new(qc, NetworkModel::genesis_cloud(5.0), false);
        let ds = duals(2, 512, 7);
        let (_, m) = sim.exchange(&ds).unwrap();
        let packet_bits: u64 =
            sim.endpoints().iter().map(|e| e.packet().len_bits() as u64).sum();
        assert_eq!(m.wire_bits, packet_bits);
        assert!((m.bytes_per_node - packet_bits as f64 / 8.0 / 2.0).abs() < 1e-9);
    }

    #[test]
    fn deterministic_given_seeds() {
        let map = LayerMap::single(256);
        let mk = || -> Vec<Box<dyn Compressor>> {
            (0..2)
                .map(|i| {
                    Box::new(QuantCompressor::global_bits(&map, 4, 128, 100 + i as u64))
                        as _
                })
                .collect()
        };
        let net = NetworkModel::genesis_cloud(5.0);
        let ds = duals(2, 256, 3);
        let (m1, _) = ClusterSim::new(mk(), net.clone(), false).exchange(&ds).unwrap();
        let (m2, _) = ClusterSim::new(mk(), net, false).exchange(&ds).unwrap();
        assert_eq!(m1, m2);
    }
}

//! Deterministic cluster engine with a simulated network clock.
//!
//! The engine is a thin transport consumer over the shared `crate::comm`
//! pipeline: each node's [`CommEndpoint`] encodes its dual vector into a
//! real [`WirePacket`](crate::comm::WirePacket), the decode-aggregate core
//! ([`super::core`]) folds the decoded packets in node order, and the
//! pluggable [`Transport`] (broadcast-allgather by default; hierarchical or
//! parameter-server via [`ClusterSim::with_topology`]) charges the network
//! model with the packets' *actual* byte counts. The optimizer logic
//! (ODA / Adam / SGD) lives in the drivers that call `exchange` each step.
//!
//! Exchanges follow the engine's [`ExchangePlan`]. Under the default
//! [`ExchangeMode::Synchronous`] every call returns its own aggregate and
//! the full `comm_s` is exposed — bit- and clock-identical to the
//! pre-overlap engine. Under [`ExchangeMode::Overlapped`] the engine
//! double-buffers: each call stages its freshly decoded aggregate and
//! returns the one staged `depth` calls earlier (the zero vector while the
//! pipe fills), modeling duals that travel while the next step computes;
//! the step's `comm_s` splits into `comm_exposed_s` / `comm_hidden_s`
//! against the plan's compute window, and [`ClusterSim::drain_staged`]
//! flushes the still-in-flight aggregates when the run ends.

use super::core::decode_aggregate_into;
use super::metrics::StepMetrics;
use super::topology::{ExchangeMode, ExchangePlan, TopologySpec, Transport};
use crate::comm::{CommEndpoint, CommError, Compressor};
use crate::net::NetworkModel;
use crate::stats::rng::Rng;
use std::collections::VecDeque;
use std::time::Instant;

/// How a harness obtains the per-step compute time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StepTimeModel {
    /// wall-clock measured around the oracle/model execution
    Measured,
    /// calibrated constant (paper-regime tables regenerate host-independent)
    Calibrated { compute_s: f64 },
}

pub struct ClusterSim {
    endpoints: Vec<CommEndpoint>,
    pub net: NetworkModel,
    /// true => payloads are uniform fp32 and in-network reduction applies
    /// (NCCL ring allreduce); false => entropy-coded allgather (OpenMPI)
    pub uncompressed_collective: bool,
    /// Main (shared-codeword) vs Alternating protocol for jitter accounting
    pub main_protocol: bool,
    topology: Box<dyn Transport>,
    /// how exchanges are scheduled against compute (synchronous by default)
    plan: ExchangePlan,
    /// aggregates decoded but not yet released to the caller (the
    /// overlapped double buffer, oldest first)
    staged: VecDeque<Vec<f64>>,
    rng: Rng,
    /// decode scratch, reused across nodes and steps
    decoded: Vec<f64>,
}

impl ClusterSim {
    pub fn new(
        codecs: Vec<Box<dyn Compressor>>,
        net: NetworkModel,
        uncompressed_collective: bool,
    ) -> Self {
        ClusterSim {
            endpoints: codecs.into_iter().map(CommEndpoint::new).collect(),
            net,
            uncompressed_collective,
            main_protocol: true,
            topology: TopologySpec::BroadcastAllGather.build(),
            plan: ExchangePlan::synchronous(),
            staged: VecDeque::new(),
            rng: Rng::new(0xC0FFEE),
            decoded: Vec::new(),
        }
    }

    /// Swap in a different communication topology (default:
    /// broadcast-allgather, the pre-topology behavior).
    pub fn with_topology(mut self, spec: &TopologySpec) -> Self {
        self.topology = spec.build();
        self
    }

    /// Swap in a different exchange schedule (default: synchronous, the
    /// pre-overlap behavior).
    pub fn with_exchange(mut self, plan: ExchangePlan) -> Self {
        self.plan = plan;
        self
    }

    pub fn topology_spec(&self) -> TopologySpec {
        self.topology.spec()
    }

    pub fn exchange_plan(&self) -> ExchangePlan {
        self.plan
    }

    /// Update the compute window overlapped exchanges hide behind (e.g.
    /// from measured per-step compute, the way the GAN trainer does).
    pub fn set_compute_window(&mut self, compute_s: f64) {
        self.plan.compute_s_per_step = compute_s;
    }

    pub fn k(&self) -> usize {
        self.endpoints.len()
    }

    pub fn endpoints(&self) -> &[CommEndpoint] {
        &self.endpoints
    }

    /// One exchange under the engine's [`ExchangePlan`]: every node encodes
    /// its dual vector into a wire packet, the topology routes and charges
    /// the packets, everyone decodes and averages (in node order, via the
    /// shared decode-aggregate core — the aggregate is identical under
    /// every topology).
    ///
    /// Synchronous mode returns this step's aggregate. Overlapped mode
    /// returns the aggregate staged `depth` exchanges earlier — the
    /// one-step-stale (depth-step-stale) double buffer — and the zero
    /// vector while the pipe fills; call [`ClusterSim::drain_staged`] after
    /// the last step to flush the aggregates still in flight. The zero fill
    /// is a bitwise no-op for plain linear updates (which is what keeps this
    /// engine parity-testable against the threaded engine's skip), but
    /// callers driving a *stateful* optimizer must skip their update during
    /// the first [`ExchangeMode::staleness`] rounds — feeding Adam-style
    /// state synthetic zero gradients advances its timestep and decays its
    /// moments (see the GAN trainer for the pattern). Either way the
    /// metrics carry codec/wire timing on the real encoded byte counts,
    /// with `comm_s` split into exposed/hidden against the plan (a
    /// steady-state split — see [`ExchangePlan::split`]).
    pub fn exchange(&mut self, duals: &[Vec<f64>]) -> Result<(Vec<f64>, StepMetrics), CommError> {
        assert_eq!(duals.len(), self.endpoints.len());
        let k = duals.len();
        let d = duals[0].len();
        let t0 = Instant::now();
        // ENC every node's dual onto the wire; the packet's bit count is
        // the one truth
        let mut bits = Vec::with_capacity(k);
        for (ep, dual) in self.endpoints.iter_mut().zip(duals) {
            bits.push(ep.send(dual)? as u64);
        }
        // DEC as every receiving node would, folding in node order
        let mut mean = Vec::with_capacity(d);
        let endpoints = &mut self.endpoints;
        decode_aggregate_into(k, d, &mut mean, &mut self.decoded, |node, out| {
            endpoints[node].recv_into(out)
        })?;
        let codec_s = t0.elapsed().as_secs_f64();
        // layer-observing transports (sharded) balance ownership on the
        // measured per-layer coded bits; feed them the tables right before
        // the charge
        if self.topology.observes_layers() {
            let tables: Vec<Vec<u64>> =
                self.endpoints.iter().map(|e| e.packet().layer_bits()).collect();
            self.topology.observe_packet_layers(&tables);
        }
        let charge = self.topology.charge(
            &bits,
            d,
            &self.net,
            self.uncompressed_collective,
            self.main_protocol,
            &mut self.rng,
        );
        let (comm_exposed_s, comm_hidden_s) = self.plan.split(charge.comm_s);
        let payload_bits: u64 = bits.iter().sum();
        let metrics = StepMetrics {
            step: 0,
            compute_s: 0.0,
            codec_s,
            comm_s: charge.comm_s,
            comm_exposed_s,
            comm_hidden_s,
            bytes_per_node: payload_bits as f64 / 8.0 / k as f64,
            wire_bits: charge.wire_bits,
            peak_link_bytes: charge.peak_link_bytes,
            scalars: Vec::new(),
        };
        let out = match self.plan.mode {
            ExchangeMode::Synchronous => mean,
            ExchangeMode::Overlapped { depth } => {
                self.staged.push_back(mean);
                if self.staged.len() > depth.max(1) {
                    self.staged.pop_front()
                } else {
                    None
                }
                // the pipe is still filling: nothing has arrived yet
                .unwrap_or_else(|| vec![0.0; d])
            }
        };
        Ok((out, metrics))
    }

    /// Flush the overlapped double buffer: the aggregates still in flight,
    /// oldest first. Empty in synchronous mode (nothing is ever staged).
    /// Callers apply these to finish the run exactly one update per
    /// exchange, just `depth` steps late.
    pub fn drain_staged(&mut self) -> Vec<Vec<f64>> {
        self.staged.drain(..).collect()
    }

    /// Trigger Algorithm 1's level update (lines 2-7) on every node. Must be
    /// called between exchanges (in-flight packets decode with the books
    /// they were encoded under).
    pub fn update_levels(&mut self) {
        for ep in &mut self.endpoints {
            ep.update_levels();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{IdentityCompressor, QuantCompressor};
    use crate::net::NetworkModel;
    use crate::quant::layer_map::LayerMap;
    use crate::stats::rng::Rng;

    fn duals(k: usize, d: usize, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = Rng::new(seed);
        (0..k).map(|_| (0..d).map(|_| rng.gaussian()).collect()).collect()
    }

    #[test]
    fn identity_exchange_is_exact_mean_of_f32_wire() {
        let comps: Vec<Box<dyn Compressor>> =
            (0..4).map(|_| Box::new(IdentityCompressor::new()) as _).collect();
        let mut sim = ClusterSim::new(comps, NetworkModel::genesis_cloud(5.0), true);
        let ds = duals(4, 32, 1);
        let (mean, m) = sim.exchange(&ds).unwrap();
        for i in 0..32 {
            // fp32 travels on the wire, so the reference mean is over the
            // f32-rounded duals
            let want: f64 = ds.iter().map(|d| d[i] as f32 as f64).sum::<f64>() / 4.0;
            assert!((mean[i] - want).abs() < 1e-12);
        }
        assert_eq!(m.bytes_per_node, 32.0 * 4.0);
        assert_eq!(m.wire_bits, 4 * 32 * 32);
        assert!(m.comm_s > 0.0);
    }

    #[test]
    fn quantized_exchange_smaller_wire_time() {
        let map = LayerMap::single(4096);
        let idc: Vec<Box<dyn Compressor>> =
            (0..4).map(|_| Box::new(IdentityCompressor::new()) as _).collect();
        let qc: Vec<Box<dyn Compressor>> = (0..4)
            .map(|i| Box::new(QuantCompressor::global_bits(&map, 5, 128, i as u64)) as _)
            .collect();
        let net = NetworkModel::genesis_cloud(5.0);
        let mut sim_raw = ClusterSim::new(idc, net.clone(), true);
        let mut sim_q = ClusterSim::new(qc, net, false);
        let ds = duals(4, 4096, 2);
        let (_, mr) = sim_raw.exchange(&ds).unwrap();
        let (_, mq) = sim_q.exchange(&ds).unwrap();
        assert!(mq.bytes_per_node < mr.bytes_per_node / 3.0);
        assert!(mq.comm_s < mr.comm_s);
    }

    #[test]
    fn charged_bytes_match_packet_payloads() {
        let map = LayerMap::single(512);
        let qc: Vec<Box<dyn Compressor>> = (0..2)
            .map(|i| Box::new(QuantCompressor::global_bits(&map, 4, 128, i as u64)) as _)
            .collect();
        let mut sim = ClusterSim::new(qc, NetworkModel::genesis_cloud(5.0), false);
        let ds = duals(2, 512, 7);
        let (_, m) = sim.exchange(&ds).unwrap();
        let packet_bits: u64 =
            sim.endpoints().iter().map(|e| e.packet().len_bits() as u64).sum();
        assert_eq!(m.wire_bits, packet_bits);
        assert!((m.bytes_per_node - packet_bits as f64 / 8.0 / 2.0).abs() < 1e-9);
    }

    #[test]
    fn deterministic_given_seeds() {
        let map = LayerMap::single(256);
        let mk = || -> Vec<Box<dyn Compressor>> {
            (0..2)
                .map(|i| {
                    Box::new(QuantCompressor::global_bits(&map, 4, 128, 100 + i as u64))
                        as _
                })
                .collect()
        };
        let net = NetworkModel::genesis_cloud(5.0);
        let ds = duals(2, 256, 3);
        let (m1, _) = ClusterSim::new(mk(), net.clone(), false).exchange(&ds).unwrap();
        let (m2, _) = ClusterSim::new(mk(), net, false).exchange(&ds).unwrap();
        assert_eq!(m1, m2);
    }

    #[test]
    fn topologies_share_the_aggregate_but_not_the_charge() {
        let map = LayerMap::single(512);
        let mk = || -> Vec<Box<dyn Compressor>> {
            (0..6)
                .map(|i| {
                    Box::new(QuantCompressor::global_bits(&map, 5, 128, 40 + i as u64))
                        as _
                })
                .collect()
        };
        let net = NetworkModel::genesis_cloud(5.0);
        let ds = duals(6, 512, 11);
        let mut outs = Vec::new();
        for spec in [
            TopologySpec::BroadcastAllGather,
            TopologySpec::Hierarchical { racks: 3 },
            TopologySpec::ParameterServer,
            TopologySpec::ShardedReduceScatter,
            TopologySpec::Ring,
        ] {
            let mut sim =
                ClusterSim::new(mk(), net.clone(), false).with_topology(&spec);
            assert_eq!(sim.topology_spec(), spec);
            outs.push(sim.exchange(&ds).unwrap());
        }
        // bit-identical aggregates under every topology...
        for o in &outs[1..] {
            assert_eq!(outs[0].0, o.0);
        }
        // ...but distinct wire-bit totals (the routing differs)
        assert!(outs[1].1.wire_bits > outs[0].1.wire_bits);
        assert!(outs[2].1.wire_bits > outs[0].1.wire_bits);
        // sharded ships strictly less than flat (own shards stay local)...
        assert!(outs[3].1.wire_bits < outs[0].1.wire_bits + 32 * 512);
        assert!(outs[3].1.wire_bits > 0);
        // ...and its peak link load undercuts every full-bundle plan
        for o in &outs[..3] {
            assert!(outs[3].1.peak_link_bytes < o.1.peak_link_bytes);
        }
        assert!(outs[4].1.wire_bits > 0);
        // payload-per-node metric is topology-independent
        for o in &outs[1..] {
            assert_eq!(outs[0].1.bytes_per_node, o.1.bytes_per_node);
        }
    }

    #[test]
    fn overlapped_exchange_returns_stale_aggregates_and_drains() {
        use crate::coordinator::topology::ExchangePlan;
        let map = LayerMap::single(128);
        let mk = || -> Vec<Box<dyn Compressor>> {
            (0..3)
                .map(|i| {
                    Box::new(QuantCompressor::global_bits(&map, 5, 128, 60 + i as u64))
                        as _
                })
                .collect()
        };
        let net = NetworkModel::genesis_cloud(5.0);
        let rounds: Vec<Vec<Vec<f64>>> =
            (0..3).map(|r| duals(3, 128, 200 + r)).collect();

        // synchronous reference: the per-round aggregates
        let mut sync = ClusterSim::new(mk(), net.clone(), false);
        let sync_means: Vec<Vec<f64>> =
            rounds.iter().map(|ds| sync.exchange(ds).unwrap().0).collect();

        // overlapped depth 1: round t returns round t-1's aggregate,
        // round 1 returns zeros, and the drain flushes the last one
        let mut ov = ClusterSim::new(mk(), net.clone(), false)
            .with_exchange(ExchangePlan::overlapped(1, 0.0));
        let got: Vec<Vec<f64>> =
            rounds.iter().map(|ds| ov.exchange(ds).unwrap().0).collect();
        assert_eq!(got[0], vec![0.0; 128], "pipe fills with zeros");
        assert_eq!(got[1], sync_means[0], "one-step-stale aggregate");
        assert_eq!(got[2], sync_means[1]);
        let staged = ov.drain_staged();
        assert_eq!(staged.len(), 1);
        assert_eq!(staged[0], sync_means[2], "drain flushes the in-flight round");
        assert!(ov.drain_staged().is_empty(), "drain is idempotent");

        // depth 2 staggers by two rounds
        let mut ov2 = ClusterSim::new(mk(), net.clone(), false)
            .with_exchange(ExchangePlan::overlapped(2, 0.0));
        let got2: Vec<Vec<f64>> =
            rounds.iter().map(|ds| ov2.exchange(ds).unwrap().0).collect();
        assert_eq!(got2[0], vec![0.0; 128]);
        assert_eq!(got2[1], vec![0.0; 128]);
        assert_eq!(got2[2], sync_means[0]);
        assert_eq!(ov2.drain_staged(), vec![sync_means[1].clone(), sync_means[2].clone()]);

        // synchronous mode never stages anything
        assert!(sync.drain_staged().is_empty());
    }

    #[test]
    fn overlapped_metrics_split_comm_against_the_compute_window() {
        use crate::coordinator::topology::ExchangePlan;
        let map = LayerMap::single(512);
        let mk = || -> Vec<Box<dyn Compressor>> {
            (0..4)
                .map(|i| {
                    Box::new(QuantCompressor::global_bits(&map, 5, 128, 80 + i as u64))
                        as _
                })
                .collect()
        };
        let net = NetworkModel::genesis_cloud(5.0);
        let ds = duals(4, 512, 21);

        // synchronous: everything exposed
        let (_, m_sync) = ClusterSim::new(mk(), net.clone(), false).exchange(&ds).unwrap();
        assert_eq!(m_sync.comm_exposed_s, m_sync.comm_s);
        assert_eq!(m_sync.comm_hidden_s, 0.0);

        // overlapped with zero compute: exposed == comm_s exactly
        let (_, m0) = ClusterSim::new(mk(), net.clone(), false)
            .with_exchange(ExchangePlan::overlapped(1, 0.0))
            .exchange(&ds)
            .unwrap();
        assert_eq!(m0.comm_s, m_sync.comm_s, "the charge itself is mode-invariant");
        assert_eq!(m0.comm_exposed_s, m0.comm_s);

        // overlapped with a huge compute window: fully hidden
        let (_, m1) = ClusterSim::new(mk(), net.clone(), false)
            .with_exchange(ExchangePlan::overlapped(1, 10.0))
            .exchange(&ds)
            .unwrap();
        assert_eq!(m1.comm_exposed_s, 0.0);
        assert_eq!(m1.comm_hidden_s, m1.comm_s);
        assert!(m1.wall_s() < m1.total_s());

        // the invariants: exposed + hidden == comm_s, exposed <= comm_s
        for m in [&m_sync, &m0, &m1] {
            assert_eq!(m.comm_exposed_s + m.comm_hidden_s, m.comm_s);
            assert!(m.comm_exposed_s <= m.comm_s);
        }

        // set_compute_window retunes the split mid-run
        let mut sim = ClusterSim::new(mk(), net, false)
            .with_exchange(ExchangePlan::overlapped(1, 0.0));
        let (_, a) = sim.exchange(&ds).unwrap();
        assert_eq!(a.comm_exposed_s, a.comm_s);
        sim.set_compute_window(10.0);
        let (_, b) = sim.exchange(&ds).unwrap();
        assert_eq!(b.comm_exposed_s, 0.0);
    }
}

//! Deterministic cluster engine with a simulated network clock.
//!
//! The engine is a thin transport consumer over the shared `crate::comm`
//! pipeline: each node's [`CommEndpoint`] encodes its dual vector into a
//! real [`WirePacket`](crate::comm::WirePacket), the decode-aggregate core
//! ([`super::core`]) folds the decoded packets in node order, and the
//! pluggable [`Transport`] (broadcast-allgather by default; hierarchical or
//! parameter-server via [`ClusterSim::with_topology`]) charges the network
//! model with the packets' *actual* byte counts. The optimizer logic
//! (ODA / Adam / SGD) lives in the drivers that call `exchange` each step.

use super::core::decode_aggregate_into;
use super::metrics::StepMetrics;
use super::topology::{TopologySpec, Transport};
use crate::comm::{CommEndpoint, CommError, Compressor};
use crate::net::NetworkModel;
use crate::stats::rng::Rng;
use std::time::Instant;

/// How a harness obtains the per-step compute time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StepTimeModel {
    /// wall-clock measured around the oracle/model execution
    Measured,
    /// calibrated constant (paper-regime tables regenerate host-independent)
    Calibrated { compute_s: f64 },
}

pub struct ClusterSim {
    endpoints: Vec<CommEndpoint>,
    pub net: NetworkModel,
    /// true => payloads are uniform fp32 and in-network reduction applies
    /// (NCCL ring allreduce); false => entropy-coded allgather (OpenMPI)
    pub uncompressed_collective: bool,
    /// Main (shared-codeword) vs Alternating protocol for jitter accounting
    pub main_protocol: bool,
    topology: Box<dyn Transport>,
    rng: Rng,
    /// decode scratch, reused across nodes and steps
    decoded: Vec<f64>,
}

impl ClusterSim {
    pub fn new(
        codecs: Vec<Box<dyn Compressor>>,
        net: NetworkModel,
        uncompressed_collective: bool,
    ) -> Self {
        ClusterSim {
            endpoints: codecs.into_iter().map(CommEndpoint::new).collect(),
            net,
            uncompressed_collective,
            main_protocol: true,
            topology: TopologySpec::BroadcastAllGather.build(),
            rng: Rng::new(0xC0FFEE),
            decoded: Vec::new(),
        }
    }

    /// Swap in a different communication topology (default:
    /// broadcast-allgather, the pre-topology behavior).
    pub fn with_topology(mut self, spec: &TopologySpec) -> Self {
        self.topology = spec.build();
        self
    }

    pub fn topology_spec(&self) -> TopologySpec {
        self.topology.spec()
    }

    pub fn k(&self) -> usize {
        self.endpoints.len()
    }

    pub fn endpoints(&self) -> &[CommEndpoint] {
        &self.endpoints
    }

    /// One synchronous exchange: every node encodes its dual vector into a
    /// wire packet, the topology routes and charges the packets, everyone
    /// decodes and averages (in node order, via the shared decode-aggregate
    /// core — the aggregate is identical under every topology). Returns the
    /// mean decoded vector plus codec/wire timing on the real encoded byte
    /// counts.
    pub fn exchange(&mut self, duals: &[Vec<f64>]) -> Result<(Vec<f64>, StepMetrics), CommError> {
        assert_eq!(duals.len(), self.endpoints.len());
        let k = duals.len();
        let d = duals[0].len();
        let t0 = Instant::now();
        // ENC every node's dual onto the wire; the packet's bit count is
        // the one truth
        let mut bits = Vec::with_capacity(k);
        for (ep, dual) in self.endpoints.iter_mut().zip(duals) {
            bits.push(ep.send(dual) as u64);
        }
        // DEC as every receiving node would, folding in node order
        let mut mean = Vec::with_capacity(d);
        let endpoints = &mut self.endpoints;
        decode_aggregate_into(k, d, &mut mean, &mut self.decoded, |node, out| {
            endpoints[node].recv_into(out)
        })?;
        let codec_s = t0.elapsed().as_secs_f64();
        let charge = self.topology.charge(
            &bits,
            d,
            &self.net,
            self.uncompressed_collective,
            self.main_protocol,
            &mut self.rng,
        );
        let payload_bits: u64 = bits.iter().sum();
        let metrics = StepMetrics {
            step: 0,
            compute_s: 0.0,
            codec_s,
            comm_s: charge.comm_s,
            bytes_per_node: payload_bits as f64 / 8.0 / k as f64,
            wire_bits: charge.wire_bits,
            scalars: Vec::new(),
        };
        Ok((mean, metrics))
    }

    /// Trigger Algorithm 1's level update (lines 2-7) on every node. Must be
    /// called between exchanges (in-flight packets decode with the books
    /// they were encoded under).
    pub fn update_levels(&mut self) {
        for ep in &mut self.endpoints {
            ep.update_levels();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{IdentityCompressor, QuantCompressor};
    use crate::net::NetworkModel;
    use crate::quant::layer_map::LayerMap;
    use crate::stats::rng::Rng;

    fn duals(k: usize, d: usize, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = Rng::new(seed);
        (0..k).map(|_| (0..d).map(|_| rng.gaussian()).collect()).collect()
    }

    #[test]
    fn identity_exchange_is_exact_mean_of_f32_wire() {
        let comps: Vec<Box<dyn Compressor>> =
            (0..4).map(|_| Box::new(IdentityCompressor) as _).collect();
        let mut sim = ClusterSim::new(comps, NetworkModel::genesis_cloud(5.0), true);
        let ds = duals(4, 32, 1);
        let (mean, m) = sim.exchange(&ds).unwrap();
        for i in 0..32 {
            // fp32 travels on the wire, so the reference mean is over the
            // f32-rounded duals
            let want: f64 = ds.iter().map(|d| d[i] as f32 as f64).sum::<f64>() / 4.0;
            assert!((mean[i] - want).abs() < 1e-12);
        }
        assert_eq!(m.bytes_per_node, 32.0 * 4.0);
        assert_eq!(m.wire_bits, 4 * 32 * 32);
        assert!(m.comm_s > 0.0);
    }

    #[test]
    fn quantized_exchange_smaller_wire_time() {
        let map = LayerMap::single(4096);
        let idc: Vec<Box<dyn Compressor>> =
            (0..4).map(|_| Box::new(IdentityCompressor) as _).collect();
        let qc: Vec<Box<dyn Compressor>> = (0..4)
            .map(|i| Box::new(QuantCompressor::global_bits(&map, 5, 128, i as u64)) as _)
            .collect();
        let net = NetworkModel::genesis_cloud(5.0);
        let mut sim_raw = ClusterSim::new(idc, net.clone(), true);
        let mut sim_q = ClusterSim::new(qc, net, false);
        let ds = duals(4, 4096, 2);
        let (_, mr) = sim_raw.exchange(&ds).unwrap();
        let (_, mq) = sim_q.exchange(&ds).unwrap();
        assert!(mq.bytes_per_node < mr.bytes_per_node / 3.0);
        assert!(mq.comm_s < mr.comm_s);
    }

    #[test]
    fn charged_bytes_match_packet_payloads() {
        let map = LayerMap::single(512);
        let qc: Vec<Box<dyn Compressor>> = (0..2)
            .map(|i| Box::new(QuantCompressor::global_bits(&map, 4, 128, i as u64)) as _)
            .collect();
        let mut sim = ClusterSim::new(qc, NetworkModel::genesis_cloud(5.0), false);
        let ds = duals(2, 512, 7);
        let (_, m) = sim.exchange(&ds).unwrap();
        let packet_bits: u64 =
            sim.endpoints().iter().map(|e| e.packet().len_bits() as u64).sum();
        assert_eq!(m.wire_bits, packet_bits);
        assert!((m.bytes_per_node - packet_bits as f64 / 8.0 / 2.0).abs() < 1e-9);
    }

    #[test]
    fn deterministic_given_seeds() {
        let map = LayerMap::single(256);
        let mk = || -> Vec<Box<dyn Compressor>> {
            (0..2)
                .map(|i| {
                    Box::new(QuantCompressor::global_bits(&map, 4, 128, 100 + i as u64))
                        as _
                })
                .collect()
        };
        let net = NetworkModel::genesis_cloud(5.0);
        let ds = duals(2, 256, 3);
        let (m1, _) = ClusterSim::new(mk(), net.clone(), false).exchange(&ds).unwrap();
        let (m2, _) = ClusterSim::new(mk(), net, false).exchange(&ds).unwrap();
        assert_eq!(m1, m2);
    }

    #[test]
    fn topologies_share_the_aggregate_but_not_the_charge() {
        let map = LayerMap::single(512);
        let mk = || -> Vec<Box<dyn Compressor>> {
            (0..6)
                .map(|i| {
                    Box::new(QuantCompressor::global_bits(&map, 5, 128, 40 + i as u64))
                        as _
                })
                .collect()
        };
        let net = NetworkModel::genesis_cloud(5.0);
        let ds = duals(6, 512, 11);
        let mut outs = Vec::new();
        for spec in [
            TopologySpec::BroadcastAllGather,
            TopologySpec::Hierarchical { racks: 3 },
            TopologySpec::ParameterServer,
        ] {
            let mut sim =
                ClusterSim::new(mk(), net.clone(), false).with_topology(&spec);
            assert_eq!(sim.topology_spec(), spec);
            outs.push(sim.exchange(&ds).unwrap());
        }
        // bit-identical aggregates under every topology...
        assert_eq!(outs[0].0, outs[1].0);
        assert_eq!(outs[0].0, outs[2].0);
        // ...but distinct wire-bit totals (the routing differs)
        assert!(outs[1].1.wire_bits > outs[0].1.wire_bits);
        assert!(outs[2].1.wire_bits > outs[0].1.wire_bits);
        // payload-per-node metric is topology-independent
        assert_eq!(outs[0].1.bytes_per_node, outs[1].1.bytes_per_node);
    }
}

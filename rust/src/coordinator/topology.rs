//! Pluggable communication topologies: how `WirePacket`s move through the
//! cluster, and what that routing costs on the simulated network clock.
//!
//! A [`Transport`] is a *routing and charging plan* over the per-node
//! packets the `crate::comm` pipeline produces. It deliberately does **not**
//! own any decode or aggregation math — that lives in
//! [`super::core::decode_aggregate_into`] and is identical for every
//! topology, which is what makes aggregates bit-identical across topologies
//! and engines by construction. Topologies differ only in:
//!
//! * **wire bits** — how many payload bits actually cross links, per the
//!   per-topology analytic formulas documented on each implementation
//!   (pinned by `tests/topology_equivalence.rs`);
//! * **network-clock seconds** — which link class (cross-rack vs rack-local,
//!   see [`NetworkModel`]) each phase is charged against, which phases pay
//!   incast/straggler penalties, and which carry the entropy-coded payloads
//!   that the jitter model (Remark D.3) taxes.
//!
//! Five topologies ship — the plan matrix (per-link load, latency terms,
//! when each wins):
//!
//! * [`BroadcastAllGather`] — every node broadcasts its packet to every
//!   other node over the cross-rack network (today's ring collectives;
//!   golden-parity tested against the pre-topology engines). Peak per-link
//!   load `(K−1)/K · ΣB` grows linearly with K; wins only at small K.
//! * [`Hierarchical`] — two-level aggregation as on real multi-GPU nodes:
//!   rack-local gather onto a rack leader over fast PCIe-class links, a
//!   leaders-only cross-rack exchange, then a rack-local broadcast down.
//!   Trades cross-rack volume for rack-local bandwidth; wins once racks
//!   exist and cross-rack links are the bottleneck (K ≈ 12–16), but the
//!   leader links still carry full bundles, so it plateaus with K.
//! * [`ParameterServer`] — a hub ingests all K packets and unicasts the
//!   fp32 aggregate back, serializing on its egress link (the classic PS
//!   scaling wall). Lowest latency-term count (2 phases); loses everywhere
//!   beyond toy K.
//! * [`crate::coordinator::collectives::ShardedReduceScatter`] — each of K
//!   peers owns ~1/K of the *coded bits*; peers ship only the owner's shard
//!   to that owner, owners decode-and-reduce their slice, then an fp32
//!   allgather distributes reduced slices. Peak per-link load ~`ΣB/K`
//!   — 1/K of flat's — at 2 phase latencies; wins in the weak-scaling
//!   regime (K ≥ 32).
//! * [`crate::coordinator::collectives::Ring`] — K−1 reduce-scatter +
//!   K−1 allgather steps around a ring of coded-chunk links: per-link load
//!   ~constant in K (≈ `2·max_chunk` per step), at the cost of `2(K−1)`
//!   link latencies. The bandwidth-optimal asymptote for huge payloads;
//!   latency-bound for small ones.
//!
//! Sharded and ring plans are rack-free peer meshes — combining them with a
//! rack-structured spec is rejected with
//! [`CommError::UnsupportedRacks`](crate::comm::CommError) (see
//! [`TopologySpec::validate_racks`]).
//!
//! Every charge also decomposes into a
//! [`PhaseTimeline`](crate::net::PhaseTimeline) via
//! [`Transport::charge_timeline`]; the [`ExchangeMode`]/[`ExchangePlan`]
//! defined here decide how much of that timeline the engines' schedule
//! leaves on the critical path (synchronous: all of it; overlapped:
//! whatever the compute window cannot hide).

use crate::net::{Collective, NetworkModel, PhaseKind, PhaseTimeline};
use crate::stats::rng::Rng;

/// Fixed software launch/synchronization cost charged per phase of a
/// multi-phase topology (collective setup, leader coordination):
/// hierarchical pays 3x (up / cross / down), the parameter server 2x
/// (up / down). The flat broadcast topology pays none — its single
/// collective's setup cost is already absorbed in the calibrated constants
/// of the flat collective model, and charging it again would break golden
/// parity with the pre-topology engines.
pub const PHASE_SETUP_MS: f64 = 0.25;

/// Declarative description of a topology — the value that travels through
/// `RunSpec`, the `qoda run` CLI and the bench harnesses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TopologySpec {
    /// every node broadcasts to every other node (flat ring collectives)
    BroadcastAllGather,
    /// two-level: rack-local reduce/gather, cross-rack exchange between
    /// rack leaders, rack-local broadcast down
    Hierarchical { racks: usize },
    /// all packets to one hub; the hub unicasts the fp32 aggregate back
    ParameterServer,
    /// each of K peers owns ~1/K of the coded bits: shard to owners,
    /// partial decode-reduce, fp32 slice allgather back
    ShardedReduceScatter,
    /// K−1 reduce-scatter + K−1 allgather steps around a ring: per-link
    /// load ~constant in K
    Ring,
}

impl TopologySpec {
    /// Build the transport this spec describes.
    pub fn build(&self) -> Box<dyn Transport> {
        match *self {
            TopologySpec::BroadcastAllGather => Box::new(BroadcastAllGather),
            TopologySpec::Hierarchical { racks } => Box::new(Hierarchical { racks }),
            TopologySpec::ParameterServer => Box::new(ParameterServer),
            TopologySpec::ShardedReduceScatter => {
                Box::new(super::collectives::ShardedReduceScatter::new())
            }
            TopologySpec::Ring => Box::new(super::collectives::Ring),
        }
    }

    /// Sharded and ring plans are rack-free peer meshes: a rack-structured
    /// spec (`racks != 0`, i.e. anything but the "resolve at runtime"
    /// sentinel) cannot be routed by them yet and is rejected with a typed
    /// [`CommError::UnsupportedRacks`] instead of being silently ignored.
    /// Rack-aware plans accept any rack request ([`resolve_racks`] clamps).
    pub fn validate_racks(&self, racks: usize) -> Result<(), crate::comm::CommError> {
        match self {
            TopologySpec::ShardedReduceScatter | TopologySpec::Ring if racks != 0 => {
                Err(crate::comm::CommError::UnsupportedRacks { racks })
            }
            _ => Ok(()),
        }
    }

    /// The conventional rack layout for a K-node cluster of 4-GPU machines:
    /// K/4 racks (at least two, so a cross-rack phase always exists).
    pub fn hierarchical_for(k: usize) -> TopologySpec {
        TopologySpec::Hierarchical { racks: (k / 4).max(2) }
    }

    /// Parse a CLI name (`--topology`). `racks` feeds the hierarchical
    /// variant; 0 is a "resolve at runtime" sentinel — the transport falls
    /// back to the conventional K/4 layout of
    /// [`TopologySpec::hierarchical_for`] once it sees the node count, so
    /// an unresolved spec never degenerates to a single free-cross-phase
    /// rack. Callers that know K may still resolve it eagerly.
    pub fn parse(name: &str, racks: usize) -> Option<TopologySpec> {
        match name {
            "flat" | "broadcast" | "allgather" | "broadcast-allgather" => {
                Some(TopologySpec::BroadcastAllGather)
            }
            "hier" | "hierarchical" | "two-level" => {
                Some(TopologySpec::Hierarchical { racks })
            }
            "ps" | "hub" | "param-server" | "parameter-server" => {
                Some(TopologySpec::ParameterServer)
            }
            "sharded" | "reduce-scatter" => Some(TopologySpec::ShardedReduceScatter),
            "ring" => Some(TopologySpec::Ring),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            TopologySpec::BroadcastAllGather => "broadcast-allgather",
            TopologySpec::Hierarchical { .. } => "hierarchical",
            TopologySpec::ParameterServer => "param-server",
            TopologySpec::ShardedReduceScatter => "sharded",
            TopologySpec::Ring => "ring",
        }
    }
}

/// How exchanges are scheduled against compute.
///
/// `Synchronous` is the classic lock-step schedule: every step waits for
/// its own exchange, so the full `comm_s` sits on the critical path. It is
/// bit- and clock-identical to the pre-overlap engines (pinned by
/// `tests/overlap_parity.rs`). `Overlapped { depth }` double-buffers the
/// duals: round t's packets travel while round t+1's compute proceeds, the
/// engines apply aggregates `depth` rounds stale, and only the part of
/// `comm_s` that outlives the compute window stays exposed on the critical
/// path.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ExchangeMode {
    /// lock-step: exchange, then compute — `comm_s` fully exposed
    #[default]
    Synchronous,
    /// comm of round t overlaps compute of rounds t+1..t+depth; aggregates
    /// arrive `depth` rounds stale (`depth = 1` is the classic double
    /// buffer)
    Overlapped { depth: usize },
}

impl ExchangeMode {
    /// Parse a CLI name (`--exchange`); `depth` feeds the overlapped
    /// variant (clamped to at least 1 — a zero-deep overlap is synchronous
    /// in denial).
    pub fn parse(name: &str, depth: usize) -> Option<ExchangeMode> {
        match name {
            "sync" | "synchronous" => Some(ExchangeMode::Synchronous),
            "overlap" | "overlapped" | "async" => {
                Some(ExchangeMode::Overlapped { depth: depth.max(1) })
            }
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            ExchangeMode::Synchronous => "synchronous",
            ExchangeMode::Overlapped { .. } => "overlapped",
        }
    }

    /// Staleness of the aggregates the engines apply (0 = fresh).
    pub fn staleness(&self) -> usize {
        match *self {
            ExchangeMode::Synchronous => 0,
            ExchangeMode::Overlapped { depth } => depth.max(1),
        }
    }
}

/// An [`ExchangeMode`] plus the modeled compute window it can hide behind —
/// the value that travels through `ClusterSim`, `run_rounds_over`,
/// `NetClock` and `RunSpec`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ExchangePlan {
    pub mode: ExchangeMode,
    /// modeled compute seconds per step available to hide communication
    /// behind (0.0 = nothing to hide behind: overlap exposes everything)
    pub compute_s_per_step: f64,
}

impl Default for ExchangePlan {
    fn default() -> Self {
        Self::synchronous()
    }
}

impl ExchangePlan {
    pub fn synchronous() -> Self {
        ExchangePlan { mode: ExchangeMode::Synchronous, compute_s_per_step: 0.0 }
    }

    pub fn overlapped(depth: usize, compute_s_per_step: f64) -> Self {
        ExchangePlan {
            mode: ExchangeMode::Overlapped { depth: depth.max(1) },
            compute_s_per_step,
        }
    }

    /// Split one step's communication seconds into `(exposed, hidden)`.
    ///
    /// Synchronous exchanges expose everything. Overlapped exchanges hide
    /// comm behind **one** compute window per step — with one exchange
    /// issued per step, the sustained hiding capacity is one window
    /// regardless of `depth` (a deeper pipe buys staleness tolerance and
    /// transient absorption, not link bandwidth; were the window multiplied
    /// by depth, a run could report more comm hidden than compute exists to
    /// hide it behind). The accounting is steady-state: boundary rounds
    /// (the drain tail, a 1-step run) are charged as if the pipeline were
    /// full, an error of at most `depth` windows per run. The split is
    /// exact by construction: `exposed + hidden == comm_s` bit-for-bit,
    /// `0 <= exposed <= comm_s`, and `exposed == comm_s` exactly when the
    /// compute window is zero.
    pub fn split(&self, comm_s: f64) -> (f64, f64) {
        match self.mode {
            ExchangeMode::Synchronous => (comm_s, 0.0),
            ExchangeMode::Overlapped { .. } => {
                let window = self.compute_s_per_step.max(0.0);
                let exposed = (comm_s - window).max(0.0);
                (exposed, comm_s - exposed)
            }
        }
    }
}

/// What one synchronous exchange cost under a topology.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WireCharge {
    /// payload bits that crossed links, per the topology's analytic formula
    pub wire_bits: u64,
    /// simulated network-clock seconds for the exchange
    pub comm_s: f64,
    /// peak bytes any single point-to-point link carried this exchange —
    /// the per-link hot-spot metric the sharded/ring plans shrink (flat's
    /// grows linearly with K, sharded's falls as ~1/K, ring's is ~constant)
    pub peak_link_bytes: f64,
}

/// A routing/charging plan for one exchange of per-node packets.
/// Implementations must be pure accounting: the aggregate math is shared by
/// all topologies (see module docs).
pub trait Transport: Send {
    fn spec(&self) -> TopologySpec;

    fn name(&self) -> &'static str {
        // default to the spec label; concrete transports may refine
        self.spec().label()
    }

    /// Does this transport want per-layer coded-bit tables before each
    /// charge? Only the sharded plan does (it balances layer ownership on
    /// measured coded bits); engines skip building the tables otherwise.
    fn observes_layers(&self) -> bool {
        false
    }

    /// Feed the transport the per-node, per-layer coded-bit tables of the
    /// packets about to be exchanged (`layer_bits[node][layer]`, from
    /// [`crate::comm::WirePacket::layer_bits`]). Called by the engines
    /// immediately before [`Transport::charge`] when
    /// [`Transport::observes_layers`] is true. Default: ignored.
    fn observe_packet_layers(&mut self, layer_bits: &[Vec<u64>]) {
        let _ = layer_bits;
    }

    /// Charge one exchange and decompose it into per-phase intervals:
    /// `packet_bits[i]` is node i's encoded payload size, `agg_dim` the
    /// aggregate's dimensionality (sizes hub/leader downlinks that carry
    /// raw fp32), `uncompressed` selects in-network reduction (uniform fp32
    /// payloads) over store-and-forward of entropy-coded bundles, and
    /// `main_protocol` feeds the jitter model. The returned
    /// [`PhaseTimeline`] is the overlapped scheduler's view of the same
    /// exchange (rack-local gather / cross-rack / broadcast-down).
    fn charge_timeline(
        &mut self,
        packet_bits: &[u64],
        agg_dim: usize,
        net: &NetworkModel,
        uncompressed: bool,
        main_protocol: bool,
        rng: &mut Rng,
    ) -> (WireCharge, PhaseTimeline);

    /// Charge one synchronous exchange — [`Transport::charge_timeline`]
    /// minus the phase decomposition. Provided, so the two can never
    /// disagree: the synchronous accounting IS the timeline's charge.
    fn charge(
        &mut self,
        packet_bits: &[u64],
        agg_dim: usize,
        net: &NetworkModel,
        uncompressed: bool,
        main_protocol: bool,
        rng: &mut Rng,
    ) -> WireCharge {
        self.charge_timeline(packet_bits, agg_dim, net, uncompressed, main_protocol, rng)
            .0
    }
}

/// Resolve a requested rack count for a `k`-node cluster. `0` is the
/// "resolve at runtime" sentinel (see [`TopologySpec::parse`]) and maps to
/// the conventional K/4 layout of [`TopologySpec::hierarchical_for`]; any
/// explicit request is clamped to `[1, k]` so `racks > k` degenerates to
/// singleton racks instead of phantom empty spans. `k == 0` resolves to a
/// single (empty) rack.
pub fn resolve_racks(k: usize, racks: usize) -> usize {
    if k == 0 {
        return 1;
    }
    let want = if racks == 0 { (k / 4).max(2) } else { racks };
    want.clamp(1, k)
}

/// Contiguous rack layout: `k` nodes split into at most
/// `resolve_racks(k, racks)` blocks of `ceil(k / racks)`; returns the
/// non-empty `(start, end)` spans. The first node of each span is its rack
/// leader. Degenerate inputs are clamped, never trusted: `racks == 0`
/// resolves to the conventional layout, `racks > k` yields `k` singleton
/// racks, `k == 0` yields no spans.
pub fn rack_spans(k: usize, racks: usize) -> Vec<(usize, usize)> {
    if k == 0 {
        return Vec::new();
    }
    let racks = resolve_racks(k, racks);
    let m = k.div_ceil(racks);
    let mut spans = Vec::new();
    let mut start = 0;
    while start < k {
        let end = (start + m).min(k);
        spans.push((start, end));
        start = end;
    }
    spans
}

/// The rack-leader node ids of [`rack_spans`] (the first node of each
/// span) — the participants of the cross-rack phase.
pub fn rack_leaders(k: usize, racks: usize) -> Vec<usize> {
    rack_spans(k, racks).iter().map(|&(s, _)| s).collect()
}

// ---------------------------------------------------------------------------
// Broadcast-allgather (flat) — today's behavior
// ---------------------------------------------------------------------------

/// Flat broadcast: every node's packet reaches every other node via the
/// ring collectives of [`NetworkModel::sample_collective_seconds`] —
/// ring allreduce for uniform fp32, ring allgather for entropy-coded
/// payloads.
///
/// Wire bits: `W = Σ_i b_i` (each packet counted once — the ring forwards
/// chunks, it does not duplicate them). This is exactly the pre-topology
/// engines' accounting, asserted by golden parity.
pub struct BroadcastAllGather;

impl Transport for BroadcastAllGather {
    fn spec(&self) -> TopologySpec {
        TopologySpec::BroadcastAllGather
    }

    fn charge_timeline(
        &mut self,
        packet_bits: &[u64],
        _agg_dim: usize,
        net: &NetworkModel,
        uncompressed: bool,
        main_protocol: bool,
        rng: &mut Rng,
    ) -> (WireCharge, PhaseTimeline) {
        let bytes: Vec<f64> = packet_bits.iter().map(|&b| b as f64 / 8.0).collect();
        let kind = if uncompressed {
            Collective::RingAllReduce
        } else {
            Collective::RingAllGather
        };
        let comm_s = net.sample_collective_seconds(kind, &bytes, main_protocol, rng);
        // ring collectives stream (k−1)/k of the total payload through
        // every link — the per-link load that grows linearly with K
        let k = packet_bits.len().max(1) as f64;
        let total_bytes: f64 = bytes.iter().sum();
        let peak_link_bytes = (k - 1.0) / k * total_bytes;
        (
            WireCharge { wire_bits: packet_bits.iter().sum(), comm_s, peak_link_bytes },
            // one flat ring over the cross-rack links: a single phase
            PhaseTimeline::single(PhaseKind::CrossRack, comm_s),
        )
    }
}

// ---------------------------------------------------------------------------
// Hierarchical two-level aggregation
// ---------------------------------------------------------------------------

/// Two-level aggregation over [`rack_spans`]: members send up to their rack
/// leader on rack-local links, leaders exchange cross-rack, leaders
/// broadcast down.
///
/// With entropy-coded payloads leaders cannot reduce without decoding (and
/// re-encoding would break bit-identical aggregates), so rack bundles are
/// concatenations; with uniform fp32 the leader reduces in place and one
/// aggregate-sized vector crosses racks. Wire-bit formulas (B_r = rack r's
/// packet-bit sum, B = Σ_r B_r, A = 32·agg_dim, R = #racks):
///
/// * coded:  `W = Σ_r (B_r − b_leader(r))  +  B  +  Σ_{r: |r|>1} B`
///   (up-gather; cross allgather counted once per bundle like the flat
///   accounting; full-packet-set multicast down counted once per
///   multi-member rack — leader-only racks skip the down phase, they
///   already hold everything)
/// * fp32:   `W = Σ_r (B_r − b_leader(r))  +  R·A  +  Σ_{r: |r|>1} A`
///   (up-gather; cross allreduce counted once per leader contribution;
///   aggregate multicast down counted once per multi-member rack)
///
/// Rack-local phases are charged against the fast intra-rack link class and
/// pay no incast term (point-to-point PCIe); the cross-rack phase pays the
/// collective + straggler model at R participants and the expected jitter
/// multiplier when it carries entropy-coded bundles. The cross-phase ring
/// formulas deliberately mirror [`NetworkModel::collective_seconds`] (which
/// hard-codes participants `0..k`, while this phase spans only the leaders)
/// — keep them in sync; both sides are pinned by the calibration and
/// topology unit tests.
pub struct Hierarchical {
    pub racks: usize,
}

impl Transport for Hierarchical {
    fn spec(&self) -> TopologySpec {
        TopologySpec::Hierarchical { racks: self.racks }
    }

    fn charge_timeline(
        &mut self,
        packet_bits: &[u64],
        agg_dim: usize,
        net: &NetworkModel,
        uncompressed: bool,
        main_protocol: bool,
        _rng: &mut Rng,
    ) -> (WireCharge, PhaseTimeline) {
        let k = packet_bits.len();
        // racks = 0 is the "resolve at runtime" sentinel (see
        // `TopologySpec::parse`): resolve_racks falls back to the
        // conventional K/4 layout rather than degenerating to one rack
        // with a free cross phase
        let racks = resolve_racks(k, self.racks);
        let spans = rack_spans(k, racks);
        let r_eff = spans.len() as f64;
        let total_bits: u64 = packet_bits.iter().sum();
        let agg_bits = 32u64 * agg_dim as u64;

        let mut wire_bits = 0u64;
        let mut peak_link_bytes = 0.0f64;
        // --- phase 1: rack-local gather onto the leader ---------------------
        let mut t_up = 0.0f64;
        for &(start, end) in &spans {
            let up_bits: u64 = packet_bits[start + 1..end].iter().sum();
            wire_bits += up_bits;
            if end - start > 1 {
                let slow = net.max_slowdown_over(start..end);
                let t = up_bits as f64 / 8.0 / net.intra_bytes_per_sec() * slow
                    + net.intra_rack_latency_us * 1e-6;
                t_up = t_up.max(t);
                // each member's point-to-point uplink carries its own packet
                for &b in &packet_bits[start + 1..end] {
                    peak_link_bytes = peak_link_bytes.max(b as f64 / 8.0);
                }
            }
        }

        // --- phase 2: cross-rack exchange among the rack leaders -------------
        let leaders: Vec<usize> = spans.iter().map(|&(s, _)| s).collect();
        let slow_x = net.max_slowdown_over(leaders.iter().copied());
        let lat = net.latency_us * 1e-6;
        let bw = net.bytes_per_sec();
        let t_cross;
        if uncompressed {
            // leaders ring-allreduce one reduced fp32 vector
            let a_bytes = agg_bits as f64 / 8.0;
            wire_bits += spans.len() as u64 * agg_bits;
            let wire = 2.0 * (r_eff - 1.0) / r_eff * a_bytes / bw
                + 2.0 * (r_eff - 1.0) * lat;
            let straggler = net.straggler_ms_per_node_mb * 1e-3 * (a_bytes / 1e6)
                * (r_eff - 1.0);
            t_cross = wire * slow_x + straggler;
            peak_link_bytes = peak_link_bytes.max(2.0 * (r_eff - 1.0) / r_eff * a_bytes);
        } else {
            // leaders ring-allgather their rack bundles (store-and-forward)
            let bundles: Vec<f64> = spans
                .iter()
                .map(|&(s, e)| packet_bits[s..e].iter().sum::<u64>() as f64 / 8.0)
                .collect();
            wire_bits += total_bits;
            let sum_b: f64 = bundles.iter().sum();
            let max_b = bundles.iter().copied().fold(0.0, f64::max);
            let wire = (r_eff - 1.0) / r_eff * sum_b / bw + (r_eff - 1.0) * lat;
            let straggler =
                net.straggler_ms_per_node_mb * 1e-3 * (max_b / 1e6) * (r_eff - 1.0);
            // entropy-coded bundles pay the expected jitter overhead
            t_cross = (wire * slow_x + straggler) * net.jitter_multiplier(main_protocol);
            // each leader link streams (R−1)/R of the full bundle set
            peak_link_bytes = peak_link_bytes.max((r_eff - 1.0) / r_eff * sum_b);
        }

        // --- phase 3: rack-local broadcast down ------------------------------
        // multicast: counted once per rack with members (a leader-only rack
        // already holds everything after the cross phase). In coded mode the
        // stream must carry the *full* packet set: after the point-to-point
        // up-gather a member holds only its own packet, and the union of
        // what the members lack is every packet, so the multicast is
        // `total_bits` (each member skips its own contribution on decode,
        // but the bits cross the rack links once regardless).
        let mut t_down = 0.0f64;
        for &(start, end) in &spans {
            if end - start > 1 {
                let down_bits = if uncompressed { agg_bits } else { total_bits };
                wire_bits += down_bits;
                let slow = net.max_slowdown_over(start..end);
                let t = down_bits as f64 / 8.0 / net.intra_bytes_per_sec() * slow
                    + net.intra_rack_latency_us * 1e-6;
                t_down = t_down.max(t);
                // the multicast stream crosses each member link once
                peak_link_bytes = peak_link_bytes.max(down_bits as f64 / 8.0);
            }
        }

        let comm_s = t_up + t_cross + t_down + 3.0 * PHASE_SETUP_MS * 1e-3;
        let setup = PHASE_SETUP_MS * 1e-3;
        let mut timeline = PhaseTimeline::default();
        timeline.push(PhaseKind::RackLocalGather, t_up + setup);
        timeline.push(PhaseKind::CrossRack, t_cross + setup);
        timeline.push(PhaseKind::RackLocalBroadcast, t_down + setup);
        (WireCharge { wire_bits, comm_s, peak_link_bytes }, timeline)
    }
}

// ---------------------------------------------------------------------------
// Parameter-server hub
// ---------------------------------------------------------------------------

/// A single hub ingests every node's packet over the cross-rack network and
/// unicasts the fp32 aggregate back to each node, serialized on its egress
/// link — cheap at small K, a linear wall at large K.
///
/// Wire bits: `W = Σ_i b_i + K · 32 · agg_dim` (uplink packets once each;
/// one aggregate copy per worker downlink).
pub struct ParameterServer;

impl Transport for ParameterServer {
    fn spec(&self) -> TopologySpec {
        TopologySpec::ParameterServer
    }

    fn charge_timeline(
        &mut self,
        packet_bits: &[u64],
        agg_dim: usize,
        net: &NetworkModel,
        _uncompressed: bool,
        main_protocol: bool,
        _rng: &mut Rng,
    ) -> (WireCharge, PhaseTimeline) {
        let k = packet_bits.len();
        let kf = k as f64;
        let total_bits: u64 = packet_bits.iter().sum();
        let agg_bits = 32u64 * agg_dim as u64;
        let bw = net.bytes_per_sec();
        let lat = net.latency_us * 1e-6;
        let slow = net.max_slowdown_over(0..k);
        let max_b = packet_bits.iter().map(|&b| b as f64 / 8.0).fold(0.0, f64::max);

        // uplink: the hub's ingress serializes all K payloads; K-deep incast
        let up_wire = total_bits as f64 / 8.0 / bw * slow + lat;
        let up_straggler =
            net.straggler_ms_per_node_mb * 1e-3 * (max_b / 1e6) * (kf - 1.0).max(0.0);
        let t_up = (up_wire + up_straggler) * net.jitter_multiplier(main_protocol);

        // downlink: K unicast copies of the fp32 aggregate, serialized on
        // the hub's egress
        let t_down = kf * (agg_bits as f64 / 8.0) / bw * slow + lat;

        let comm_s = t_up + t_down + 2.0 * PHASE_SETUP_MS * 1e-3;
        let setup = PHASE_SETUP_MS * 1e-3;
        let mut timeline = PhaseTimeline::default();
        // both hub phases ride the cross-rack network
        timeline.push(PhaseKind::CrossRack, t_up + setup);
        timeline.push(PhaseKind::CrossRack, t_down + setup);
        // the hub's own link is the hot spot: all K payloads in, K
        // aggregate copies out
        let peak_link_bytes =
            (total_bits as f64 / 8.0).max(kf * agg_bits as f64 / 8.0);
        (
            WireCharge { wire_bits: total_bits + k as u64 * agg_bits, comm_s, peak_link_bytes },
            timeline,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::NetworkModel;

    fn charge(
        spec: &TopologySpec,
        bits: &[u64],
        d: usize,
        net: &NetworkModel,
        uncompressed: bool,
    ) -> WireCharge {
        let mut rng = Rng::new(7);
        spec.build().charge(bits, d, net, uncompressed, true, &mut rng)
    }

    #[test]
    fn rack_spans_cover_all_nodes() {
        assert_eq!(rack_spans(8, 2), vec![(0, 4), (4, 8)]);
        assert_eq!(rack_spans(6, 3), vec![(0, 2), (2, 4), (4, 6)]);
        // non-divisible: blocks of ceil(k/racks), last short, none empty
        assert_eq!(rack_spans(7, 3), vec![(0, 3), (3, 6), (6, 7)]);
        assert_eq!(rack_spans(6, 4), vec![(0, 2), (2, 4), (4, 6)]);
        assert_eq!(rack_spans(3, 8), vec![(0, 1), (1, 2), (2, 3)]);
        assert_eq!(rack_spans(0, 4), Vec::<(usize, usize)>::new());
    }

    #[test]
    fn wire_bit_formulas_uniform_payloads() {
        // k = 6 identical packets of 512 bits, d = 16 (fp32 agg = 512 bits)
        let bits = [512u64; 6];
        let net = NetworkModel::genesis_cloud(5.0);
        let flat = charge(&TopologySpec::BroadcastAllGather, &bits, 16, &net, false);
        assert_eq!(flat.wire_bits, 6 * 512);

        // hierarchical, 3 racks of 2, coded: up = 3*512 (one non-leader per
        // rack), cross = 6*512, down = full packet set per rack = 3 * 6*512
        let hier =
            charge(&TopologySpec::Hierarchical { racks: 3 }, &bits, 16, &net, false);
        assert_eq!(hier.wire_bits, 3 * 512 + 6 * 512 + 3 * 6 * 512);

        // hierarchical, fp32 reduce mode: up = 3*512, cross = R*A = 3*512,
        // down = R*A = 3*512
        let hier_fp =
            charge(&TopologySpec::Hierarchical { racks: 3 }, &bits, 16, &net, true);
        assert_eq!(hier_fp.wire_bits, 3 * 512 + 3 * 512 + 3 * 512);

        // parameter server: up = 6*512, down = K*A = 6*512
        let ps = charge(&TopologySpec::ParameterServer, &bits, 16, &net, false);
        assert_eq!(ps.wire_bits, 6 * 512 + 6 * 512);

        // sharded (idealized 1/K split, no observation): each node keeps its
        // own shard, ships the other 5/6 = 5*512; fp32 slice allgather adds
        // 32*d = 512 counted once
        let sharded = charge(&TopologySpec::ShardedReduceScatter, &bits, 16, &net, false);
        assert_eq!(sharded.wire_bits, 5 * 512 + 512);

        // ring: chunk slots sum to 512 bits, 2*(K-1) steps relay each slot
        let ring = charge(&TopologySpec::Ring, &bits, 16, &net, false);
        assert_eq!(ring.wire_bits, 2 * 5 * 512);
    }

    #[test]
    fn sharded_and_ring_reject_rack_structured_specs() {
        use crate::comm::CommError;
        for spec in [TopologySpec::ShardedReduceScatter, TopologySpec::Ring] {
            // the runtime-resolve sentinel (0) is the only acceptable value
            assert_eq!(spec.validate_racks(0), Ok(()));
            for racks in [1usize, 2, 8] {
                assert_eq!(
                    spec.validate_racks(racks),
                    Err(CommError::UnsupportedRacks { racks }),
                    "{spec:?} racks={racks}"
                );
            }
        }
        // rack-aware plans accept anything (resolve_racks clamps)
        assert_eq!(TopologySpec::Hierarchical { racks: 3 }.validate_racks(3), Ok(()));
        assert_eq!(TopologySpec::BroadcastAllGather.validate_racks(7), Ok(()));
        assert_eq!(TopologySpec::ParameterServer.validate_racks(7), Ok(()));
    }

    #[test]
    fn hierarchical_beats_flat_at_scale_under_heterogeneous_links() {
        // the Table 2 regime: 0.7 MB quantized payloads, 5 Gbps cross-rack,
        // 50 Gbps rack-local
        let net = NetworkModel::genesis_cloud(5.0);
        let d = 1 << 20;
        for k in [12usize, 16] {
            let bits = vec![0.7e6 as u64 * 8; k];
            let flat = charge(&TopologySpec::BroadcastAllGather, &bits, d, &net, false);
            let hier = charge(&TopologySpec::hierarchical_for(k), &bits, d, &net, false);
            assert!(
                hier.comm_s < flat.comm_s,
                "K={k}: hier {} vs flat {}",
                hier.comm_s,
                flat.comm_s
            );
        }
    }

    #[test]
    fn parameter_server_hits_a_scaling_wall() {
        let net = NetworkModel::genesis_cloud(5.0);
        let d = 1 << 20;
        let t = |k: usize| {
            let bits = vec![0.7e6 as u64 * 8; k];
            charge(&TopologySpec::ParameterServer, &bits, d, &net, false).comm_s
        };
        // hub egress serializes K aggregate copies: the cost grows ~linearly
        assert!(t(16) > 3.0 * t(4), "{} vs {}", t(16), t(4));
        // and at K = 16 the hub is far worse than the flat collective
        let bits = vec![0.7e6 as u64 * 8; 16];
        let flat = charge(&TopologySpec::BroadcastAllGather, &bits, d, &net, false);
        assert!(t(16) > 2.0 * flat.comm_s);
    }

    #[test]
    fn stragglers_slow_only_the_phases_they_touch() {
        let d = 1 << 18;
        let bits = vec![0.5e6 as u64 * 8; 8];
        let clean = NetworkModel::genesis_cloud(5.0);
        // node 5 lives in rack 1 of the 2-rack layout and is not a leader:
        // only the rack-1 local phases slow down
        let slowed = NetworkModel::genesis_cloud(5.0).with_straggler(5, 4.0);
        let spec = TopologySpec::Hierarchical { racks: 2 };
        let t_clean = charge(&spec, &bits, d, &clean, false).comm_s;
        let t_slow = charge(&spec, &bits, d, &slowed, false).comm_s;
        assert!(t_slow > t_clean, "{t_slow} vs {t_clean}");
        // a slow member does not touch the cross-rack phase, so the hit is
        // bounded by the (fast) rack-local phases
        let cross_only = charge(&spec, &bits, d, &clean, false).comm_s;
        assert!(t_slow - t_clean < 0.5 * cross_only, "{t_slow} vs {t_clean}");

        // a straggling *leader* (node 4) slows the cross-rack exchange too
        let slow_leader = NetworkModel::genesis_cloud(5.0).with_straggler(4, 4.0);
        let t_leader = charge(&spec, &bits, d, &slow_leader, false).comm_s;
        assert!(t_leader > t_slow, "{t_leader} vs {t_slow}");
    }

    #[test]
    fn parse_names() {
        assert_eq!(
            TopologySpec::parse("flat", 0),
            Some(TopologySpec::BroadcastAllGather)
        );
        assert_eq!(
            TopologySpec::parse("hier", 3),
            Some(TopologySpec::Hierarchical { racks: 3 })
        );
        assert_eq!(
            TopologySpec::parse("ps", 0),
            Some(TopologySpec::ParameterServer)
        );
        assert_eq!(
            TopologySpec::parse("sharded", 0),
            Some(TopologySpec::ShardedReduceScatter)
        );
        assert_eq!(
            TopologySpec::parse("reduce-scatter", 0),
            Some(TopologySpec::ShardedReduceScatter)
        );
        assert_eq!(TopologySpec::parse("ring", 0), Some(TopologySpec::Ring));
        assert_eq!(TopologySpec::ShardedReduceScatter.label(), "sharded");
        assert_eq!(TopologySpec::Ring.label(), "ring");
        assert_eq!(TopologySpec::parse("mesh", 0), None);
    }

    #[test]
    fn degenerate_rack_inputs_are_clamped() {
        // racks = 0 resolves to the conventional K/4 layout (>= 2 racks) —
        // never one mega-rack with a free cross phase
        assert_eq!(resolve_racks(8, 0), 2);
        assert_eq!(resolve_racks(16, 0), 4);
        assert_eq!(rack_spans(8, 0), vec![(0, 4), (4, 8)]);
        assert_eq!(rack_leaders(8, 0), vec![0, 4]);
        // racks > k clamps to singleton racks: every node leads itself
        assert_eq!(resolve_racks(3, 8), 3);
        assert_eq!(rack_spans(3, 8), vec![(0, 1), (1, 2), (2, 3)]);
        assert_eq!(rack_leaders(3, 8), vec![0, 1, 2]);
        // k = 0: no spans, no leaders, regardless of the rack request
        assert_eq!(rack_spans(0, 0), Vec::<(usize, usize)>::new());
        assert_eq!(rack_spans(0, 4), Vec::<(usize, usize)>::new());
        assert_eq!(rack_leaders(0, 4), Vec::<usize>::new());
        // tiny clusters under the sentinel: the K/4 layout clamps to k
        assert_eq!(resolve_racks(1, 0), 1);
        assert_eq!(rack_spans(1, 0), vec![(0, 1)]);
        assert_eq!(rack_leaders(1, 0), vec![0]);
        assert_eq!(resolve_racks(2, 0), 2);
        assert_eq!(rack_leaders(2, 0), vec![0, 1]);
        // spans always cover 0..k exactly, whatever the request
        for (k, racks) in [(7usize, 0usize), (7, 1), (7, 100), (1, 1), (5, 5)] {
            let spans = rack_spans(k, racks);
            assert_eq!(spans.first().map(|&(s, _)| s), Some(0), "k={k} racks={racks}");
            assert_eq!(spans.last().map(|&(_, e)| e), Some(k), "k={k} racks={racks}");
            for w in spans.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous spans: k={k} racks={racks}");
            }
            assert!(spans.iter().all(|&(s, e)| s < e), "non-empty: k={k} racks={racks}");
        }
    }

    #[test]
    fn degenerate_rack_charges_stay_finite_and_routable() {
        // a hierarchical transport built with degenerate rack requests must
        // still produce a finite, positive charge (racks = 0 resolved, racks
        // > k clamped, k = 1 collapses the cross phase to a no-op ring)
        let net = NetworkModel::genesis_cloud(5.0);
        for (k, racks) in [(6usize, 0usize), (3, 8), (1, 0), (2, 5)] {
            let bits = vec![4096u64; k];
            let spec = TopologySpec::Hierarchical { racks };
            let c = charge(&spec, &bits, 64, &net, false);
            assert!(c.comm_s.is_finite() && c.comm_s > 0.0, "k={k} racks={racks}");
            assert!(c.wire_bits >= bits.iter().sum::<u64>() - bits[0], "k={k}");
        }
    }

    #[test]
    fn exchange_mode_parse_and_labels() {
        assert_eq!(ExchangeMode::parse("sync", 1), Some(ExchangeMode::Synchronous));
        assert_eq!(
            ExchangeMode::parse("overlap", 2),
            Some(ExchangeMode::Overlapped { depth: 2 })
        );
        // depth 0 clamps to the classic double buffer
        assert_eq!(
            ExchangeMode::parse("overlapped", 0),
            Some(ExchangeMode::Overlapped { depth: 1 })
        );
        assert_eq!(ExchangeMode::parse("bogus", 1), None);
        assert_eq!(ExchangeMode::Synchronous.staleness(), 0);
        assert_eq!(ExchangeMode::Overlapped { depth: 3 }.staleness(), 3);
        assert_eq!(ExchangeMode::default(), ExchangeMode::Synchronous);
    }

    #[test]
    fn exchange_plan_split_invariants() {
        let comm = 0.017;
        // synchronous: everything exposed
        let (e, h) = ExchangePlan::synchronous().split(comm);
        assert_eq!((e, h), (comm, 0.0));
        // zero compute window: overlap degenerates to full exposure, exactly
        let (e, h) = ExchangePlan::overlapped(1, 0.0).split(comm);
        assert_eq!((e, h), (comm, 0.0));
        // window larger than comm: fully hidden
        let (e, h) = ExchangePlan::overlapped(1, 1.0).split(comm);
        assert_eq!((e, h), (0.0, comm));
        // partial: exposed + hidden == comm bit-for-bit, both non-negative
        for window in [0.001, 0.005, 0.016, 0.0169999] {
            let (e, h) = ExchangePlan::overlapped(1, window).split(comm);
            assert!(e >= 0.0 && h >= 0.0);
            assert!(e <= comm);
            assert_eq!(e + h, comm, "window {window}");
        }
        // depth buys staleness tolerance, NOT hiding capacity: with one
        // exchange per step the sustained window is one compute slot
        let (e1, _) = ExchangePlan::overlapped(1, 0.005).split(comm);
        let (e2, _) = ExchangePlan::overlapped(4, 0.005).split(comm);
        assert_eq!(e2, e1, "a deeper pipe cannot hide more than compute exists");
    }

    #[test]
    fn timelines_decompose_the_charge() {
        let net = NetworkModel::genesis_cloud(5.0);
        let bits = vec![0.7e6 as u64 * 8; 8];
        let d = 1 << 18;
        for spec in [
            TopologySpec::BroadcastAllGather,
            TopologySpec::Hierarchical { racks: 2 },
            TopologySpec::ParameterServer,
            TopologySpec::ShardedReduceScatter,
            TopologySpec::Ring,
        ] {
            let mut rng = Rng::new(7);
            let (c, tl) =
                spec.build().charge_timeline(&bits, d, &net, false, true, &mut rng);
            // the timeline sums back to the synchronous charge (association
            // of the same float terms)
            assert!(
                (tl.total_s() - c.comm_s).abs() < 1e-12 * c.comm_s.max(1.0),
                "{spec:?}: {} vs {}",
                tl.total_s(),
                c.comm_s
            );
            assert!(tl.phases.iter().all(|&(_, s)| s >= 0.0));
            // and charge() is charge_timeline().0 by construction
            let c2 = charge(&spec, &bits, d, &net, false);
            assert_eq!(c, c2, "{spec:?}");
        }
        // phase structure: flat is a single cross-rack ring; hierarchical
        // decomposes into gather / cross / broadcast; the hub pays two
        // cross-rack phases
        let mut rng = Rng::new(7);
        let (_, flat) = TopologySpec::BroadcastAllGather.build().charge_timeline(
            &bits, d, &net, false, true, &mut rng,
        );
        assert_eq!(flat.phases.len(), 1);
        assert_eq!(flat.phases[0].0, PhaseKind::CrossRack);
        let (_, hier) = TopologySpec::Hierarchical { racks: 2 }.build().charge_timeline(
            &bits, d, &net, false, true, &mut rng,
        );
        assert_eq!(
            hier.phases.iter().map(|&(k, _)| k).collect::<Vec<_>>(),
            vec![
                PhaseKind::RackLocalGather,
                PhaseKind::CrossRack,
                PhaseKind::RackLocalBroadcast
            ]
        );
        // the cross-rack phase dominates under heterogeneous links
        assert!(hier.phase_s(PhaseKind::CrossRack) > hier.phase_s(PhaseKind::RackLocalGather));
        let (_, ps) = TopologySpec::ParameterServer.build().charge_timeline(
            &bits, d, &net, false, true, &mut rng,
        );
        assert_eq!(ps.phases.len(), 2);
        assert!(ps.phases.iter().all(|&(k, _)| k == PhaseKind::CrossRack));
        // sharded pays a scatter + an allgather phase, the ring its two
        // halves — all on the cross-rack links
        for spec in [TopologySpec::ShardedReduceScatter, TopologySpec::Ring] {
            let (_, tl) =
                spec.build().charge_timeline(&bits, d, &net, false, true, &mut rng);
            assert_eq!(tl.phases.len(), 2, "{spec:?}");
            assert!(tl.phases.iter().all(|&(k, _)| k == PhaseKind::CrossRack), "{spec:?}");
        }
    }
}

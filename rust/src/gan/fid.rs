//! Fréchet distance between Gaussian fits of sample clouds — the FID metric
//! of Figure 4, computed exactly on the 2-D feature space of the GMM
//! substitute (DESIGN.md): FID = ||mu1 - mu2||^2 + tr(C1 + C2 - 2 (C1 C2)^{1/2}).
//!
//! For 2x2 PSD covariances tr((C1 C2)^{1/2}) = sqrt(l1) + sqrt(l2) with
//! l1, l2 the (real, nonnegative) eigenvalues of C1 C2 — computed in closed
//! form from the characteristic polynomial.

/// Mean + covariance of a 2-D point cloud (rows of (x, y)).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Gauss2 {
    pub mean: [f64; 2],
    /// covariance [[xx, xy], [xy, yy]]
    pub cov: [[f64; 2]; 2],
}

impl Gauss2 {
    pub fn fit(points: &[f32]) -> Self {
        assert!(points.len() >= 4 && points.len() % 2 == 0);
        let n = points.len() / 2;
        let nf = n as f64;
        let mut mean = [0.0f64; 2];
        for p in points.chunks(2) {
            mean[0] += p[0] as f64;
            mean[1] += p[1] as f64;
        }
        mean[0] /= nf;
        mean[1] /= nf;
        let mut cov = [[0.0f64; 2]; 2];
        for p in points.chunks(2) {
            let dx = p[0] as f64 - mean[0];
            let dy = p[1] as f64 - mean[1];
            cov[0][0] += dx * dx;
            cov[0][1] += dx * dy;
            cov[1][1] += dy * dy;
        }
        cov[0][0] /= nf;
        cov[0][1] /= nf;
        cov[1][0] = cov[0][1];
        cov[1][1] /= nf;
        Gauss2 { mean, cov }
    }
}

fn mat_mul(a: &[[f64; 2]; 2], b: &[[f64; 2]; 2]) -> [[f64; 2]; 2] {
    let mut c = [[0.0; 2]; 2];
    for i in 0..2 {
        for j in 0..2 {
            c[i][j] = a[i][0] * b[0][j] + a[i][1] * b[1][j];
        }
    }
    c
}

/// tr(sqrt(M)) for M = C1 C2 with C1, C2 PSD: eigenvalues of M are real and
/// nonnegative; tr sqrt = sqrt(l1) + sqrt(l2) = sqrt(tr + 2 sqrt(det)).
fn tr_sqrt_product(c1: &[[f64; 2]; 2], c2: &[[f64; 2]; 2]) -> f64 {
    let m = mat_mul(c1, c2);
    let tr = m[0][0] + m[1][1];
    let det = m[0][0] * m[1][1] - m[0][1] * m[1][0];
    // numerical guards: PSD product can dip slightly negative
    let det = det.max(0.0);
    let inner = (tr + 2.0 * det.sqrt()).max(0.0);
    inner.sqrt()
}

/// Fréchet distance between two fitted Gaussians.
pub fn frechet(a: &Gauss2, b: &Gauss2) -> f64 {
    let dm = (a.mean[0] - b.mean[0]).powi(2) + (a.mean[1] - b.mean[1]).powi(2);
    let tr_a = a.cov[0][0] + a.cov[1][1];
    let tr_b = b.cov[0][0] + b.cov[1][1];
    (dm + tr_a + tr_b - 2.0 * tr_sqrt_product(&a.cov, &b.cov)).max(0.0)
}

/// FID between two interleaved (x, y) sample buffers.
pub fn fid(fake: &[f32], real: &[f32]) -> f64 {
    frechet(&Gauss2::fit(fake), &Gauss2::fit(real))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::rng::Rng;

    fn cloud(rng: &mut Rng, n: usize, mx: f64, my: f64, sx: f64, sy: f64) -> Vec<f32> {
        let mut out = Vec::with_capacity(2 * n);
        for _ in 0..n {
            out.push((mx + sx * rng.gaussian()) as f32);
            out.push((my + sy * rng.gaussian()) as f32);
        }
        out
    }

    #[test]
    fn identical_clouds_zero_fid() {
        let mut rng = Rng::new(1);
        let c = cloud(&mut rng, 4000, 0.5, -0.5, 1.0, 2.0);
        assert!(fid(&c, &c) < 1e-9);
    }

    #[test]
    fn mean_shift_equals_squared_distance() {
        // same covariance, shifted mean: FID -> ||dmu||^2
        let mut rng = Rng::new(2);
        let a = cloud(&mut rng, 60_000, 0.0, 0.0, 1.0, 1.0);
        let b = cloud(&mut rng, 60_000, 3.0, 4.0, 1.0, 1.0);
        let f = fid(&a, &b);
        assert!((f - 25.0).abs() < 0.7, "{f}");
    }

    #[test]
    fn scale_mismatch_detected() {
        // zero-mean isotropic with std 1 vs std 2:
        // FID = tr(C1 + C2 - 2 sqrt(C1 C2)) = 2 (1 + 4 - 2*2) = 2
        let mut rng = Rng::new(3);
        let a = cloud(&mut rng, 80_000, 0.0, 0.0, 1.0, 1.0);
        let b = cloud(&mut rng, 80_000, 0.0, 0.0, 2.0, 2.0);
        let f = fid(&a, &b);
        assert!((f - 2.0).abs() < 0.25, "{f}");
    }

    #[test]
    fn symmetric() {
        let mut rng = Rng::new(4);
        let a = cloud(&mut rng, 5000, 0.0, 0.0, 1.0, 0.5);
        let b = cloud(&mut rng, 5000, 1.0, 0.0, 0.8, 1.2);
        let fab = fid(&a, &b);
        let fba = fid(&b, &a);
        assert!((fab - fba).abs() < 1e-9);
        assert!(fab > 0.5);
    }

    #[test]
    fn fit_recovers_moments() {
        let mut rng = Rng::new(5);
        let c = cloud(&mut rng, 100_000, 1.0, -2.0, 0.5, 1.5);
        let g = Gauss2::fit(&c);
        assert!((g.mean[0] - 1.0).abs() < 0.02);
        assert!((g.mean[1] + 2.0).abs() < 0.03);
        assert!((g.cov[0][0] - 0.25).abs() < 0.02);
        assert!((g.cov[1][1] - 2.25).abs() < 0.06);
        assert!(g.cov[0][1].abs() < 0.02);
    }
}

//! WGAN training system (Section 7.1): the FID metric on the GMM substitute
//! and the distributed training driver combining PJRT model execution,
//! compression and the network-timed coordinator.

pub mod fid;
pub mod trainer;

pub use fid::{fid, Gauss2};
pub use trainer::{train, GanCompression, GanOptimizer, GanRunResult, GanTrainConfig};

//! WGAN training driver (Section 7.1): optimizes the PJRT-loaded WGAN VI
//! operator with a chosen optimizer x compression combination over K
//! simulated data-parallel nodes, logging losses, W-distance, FID and the
//! full per-step time breakdown.
//!
//! The Figure 4 configurations:
//!   * Adam (uncompressed)                — baseline
//!   * QODA-Adam + global quantization   — the Q-GenX-style configuration
//!   * QODA-Adam + layer-wise (L-GreCo)  — the paper's method

use super::fid::fid;
use crate::coding::protocol::ProtocolKind;
use crate::comm::{Compressor, FeedbackCompressor, IdentityCompressor, QuantCompressor};
use crate::coordinator::metrics::RunMetrics;
use crate::coordinator::sim::ClusterSim;
use crate::coordinator::topology::{ExchangeMode, ExchangePlan, TopologySpec};
use crate::net::NetworkModel;
use crate::oda::baseline::AdamState;
use crate::runtime::WganModel;
use crate::util::error::Result;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum GanOptimizer {
    /// simultaneous Adam on the dual vector (baseline)
    Adam,
    /// optimistic Adam: extrapolate with the previous direction (QODA-Adam)
    OptimisticAdam,
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum GanCompression {
    None,
    /// Q-GenX-style static global quantization (bits, bucket)
    Global { bits: u32, bucket: usize },
    /// layer-wise adaptive with L-GreCo re-allocation every `every` steps
    LayerwiseLGreco { bits: u32, bucket: usize, every: usize },
    /// decode-count-scheduled bit widths under `budget` wire bits per
    /// coordinate, optionally with encoder-side error feedback (the
    /// residual-compensated EF14 wrapper)
    Scheduled { budget: f64, bucket: usize, every: usize, error_feedback: bool },
}

#[derive(Clone, Debug)]
pub struct GanTrainConfig {
    pub optimizer: GanOptimizer,
    pub compression: GanCompression,
    pub k_nodes: usize,
    pub steps: usize,
    pub lr: f64,
    /// WGAN weight clipping on the critic segment (Arjovsky et al.)
    pub clip: f32,
    pub fid_every: usize,
    pub seed: u64,
    pub bandwidth_gbps: f64,
    /// communication topology the cluster engine routes packets through
    pub topology: TopologySpec,
    /// exchange schedule: synchronous lock-step (default) or overlapped
    /// double-buffered duals — the engine then applies one-step-stale
    /// aggregates and hides comm behind the *measured* per-step compute
    pub exchange: ExchangeMode,
}

impl Default for GanTrainConfig {
    fn default() -> Self {
        GanTrainConfig {
            optimizer: GanOptimizer::OptimisticAdam,
            compression: GanCompression::LayerwiseLGreco { bits: 5, bucket: 128, every: 50 },
            k_nodes: 4,
            steps: 300,
            lr: 5e-4,
            clip: 0.1,
            fid_every: 25,
            seed: 1,
            bandwidth_gbps: 5.0,
            topology: TopologySpec::BroadcastAllGather,
            exchange: ExchangeMode::Synchronous,
        }
    }
}

pub struct GanRunResult {
    pub metrics: RunMetrics,
    /// (step, fid)
    pub fid_curve: Vec<(usize, f64)>,
    pub final_fid: f64,
    pub params: Vec<f32>,
}

/// One optimizer application: Adam direction, parameter step, WGAN critic
/// clipping. Shared by the training loop and the overlapped-pipeline drain
/// so the two can never drift. Returns the applied direction (the
/// optimistic lookahead state).
fn apply_update(
    params: &mut [f32],
    adam: &mut AdamState,
    mean: &[f64],
    gen_dim: usize,
    clip: f32,
) -> Vec<f64> {
    let dir = adam.direction(mean);
    for (p, di) in params.iter_mut().zip(&dir) {
        *p -= *di as f32;
    }
    for p in params[gen_dim..].iter_mut() {
        *p = p.clamp(-clip, clip);
    }
    dir
}

fn build_compressors(
    model: &WganModel,
    compression: GanCompression,
    k: usize,
    seed: u64,
) -> Vec<Box<dyn Compressor>> {
    (0..k)
        .map(|i| -> Box<dyn Compressor> {
            match compression {
                GanCompression::None => Box::new(IdentityCompressor::new()),
                GanCompression::Global { bits, bucket } => Box::new(
                    QuantCompressor::global_bits(&model.meta, bits, bucket, seed + i as u64),
                ),
                GanCompression::LayerwiseLGreco { bits, bucket, every } => Box::new(
                    QuantCompressor::layerwise(&model.meta, bits, bucket, every, seed + i as u64),
                ),
                GanCompression::Scheduled { budget, bucket, every, error_feedback } => {
                    // EF's self-decode doubles the inner decode rate: double
                    // `every` so updates stay at packet boundaries
                    let every =
                        if error_feedback { every.saturating_mul(2) } else { every };
                    let inner: Box<dyn Compressor> = Box::new(QuantCompressor::scheduled_proto(
                        &model.meta,
                        budget,
                        bucket,
                        every,
                        ProtocolKind::Main,
                        seed + i as u64,
                    ));
                    if error_feedback {
                        Box::new(FeedbackCompressor::new(inner))
                    } else {
                        inner
                    }
                }
            }
        })
        .collect()
}

/// Train the WGAN; returns metrics + FID curve.
pub fn train(model: &WganModel, cfg: &GanTrainConfig) -> Result<GanRunResult> {
    let d = model.dim;
    let comps = build_compressors(model, cfg.compression, cfg.k_nodes, cfg.seed * 977);
    let uncompressed = matches!(cfg.compression, GanCompression::None);
    let mut cluster = ClusterSim::new(
        comps,
        NetworkModel::genesis_cloud(cfg.bandwidth_gbps),
        uncompressed,
    )
    .with_topology(&cfg.topology)
    .with_exchange(ExchangePlan { mode: cfg.exchange, compute_s_per_step: 0.0 });

    let mut params = model.init_params(cfg.seed as i32)?;
    let mut adam = AdamState::new(d, cfg.lr);
    let mut prev_dir = vec![0.0f64; d];
    let mut run = RunMetrics::default();
    let mut fid_curve = Vec::new();
    let optimistic = cfg.optimizer == GanOptimizer::OptimisticAdam;

    for step in 1..=cfg.steps {
        let t0 = std::time::Instant::now();
        // optimistic lookahead query point
        let query: Vec<f32> = if optimistic {
            params
                .iter()
                .zip(&prev_dir)
                .map(|(p, d)| p - *d as f32)
                .collect()
        } else {
            params.clone()
        };
        // each logical node draws its own minibatch (distinct seeds)
        let mut duals: Vec<Vec<f64>> = Vec::with_capacity(cfg.k_nodes);
        let mut g_loss = 0.0f64;
        let mut w_dist = 0.0f64;
        for node in 0..cfg.k_nodes {
            let seed = (cfg.seed as i32)
                .wrapping_mul(31)
                .wrapping_add(step as i32 * 131 + node as i32);
            let (dual, gl, wd) = model.dual(&query, seed)?;
            duals.push(dual.iter().map(|&x| x as f64).collect());
            g_loss += gl as f64 / cfg.k_nodes as f64;
            w_dist += wd as f64 / cfg.k_nodes as f64;
        }
        let compute_s = t0.elapsed().as_secs_f64();

        // overlapped exchanges hide comm behind this step's measured compute
        cluster.set_compute_window(compute_s);
        // under ExchangeMode::Overlapped `mean` is the previous round's
        // aggregate — the one-step-stale path. While the pipe fills the
        // engine returns zeros: skip the optimizer entirely (exactly as the
        // threaded engine does), otherwise Adam's timestep and moment decay
        // would advance on synthetic zero gradients and the run would pay
        // steps + depth updates for steps exchanges.
        let (mean, mut metrics) = cluster.exchange(&duals)?;
        // staleness() is the pipe depth (0 when synchronous): the first
        // `staleness` rounds return the zero fill
        let filling = step <= cfg.exchange.staleness();
        if !filling {
            prev_dir = apply_update(&mut params, &mut adam, &mean, model.gen_dim, cfg.clip);
        }

        metrics.step = step;
        metrics.compute_s = compute_s;
        metrics.push_scalar("g_loss", g_loss);
        metrics.push_scalar("w_dist", w_dist);
        if step % cfg.fid_every == 0 || step == cfg.steps {
            let (fake, real) = model.samples(&params, (cfg.seed as i32) * 7 + step as i32)?;
            let f = fid(&fake, &real);
            metrics.push_scalar("fid", f);
            fid_curve.push((step, f));
        }
        run.push(metrics);
    }
    // pipeline drain: apply the aggregates still in the overlapped double
    // buffer so every exchanged round lands exactly one optimizer update
    let drained = cluster.drain_staged();
    let drained_any = !drained.is_empty();
    for mean in drained {
        apply_update(&mut params, &mut adam, &mean, model.gen_dim, cfg.clip);
    }
    // the drain moved the weights after the in-loop FID was sampled:
    // re-evaluate the final point (same eval seed) so the curve and
    // `final_fid` describe the params actually returned
    if drained_any {
        if let Some(last) = fid_curve.last_mut() {
            if last.0 == cfg.steps {
                let (fake, real) =
                    model.samples(&params, (cfg.seed as i32) * 7 + cfg.steps as i32)?;
                last.1 = fid(&fake, &real);
            }
        }
    }
    let final_fid = fid_curve.last().map(|&(_, f)| f).unwrap_or(f64::NAN);
    Ok(GanRunResult { metrics: run, fid_curve, final_fid, params })
}

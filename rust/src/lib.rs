//! # qoda — Layer-wise Quantization for Quantized Optimistic Dual Averaging
//!
//! Production reproduction of the ICML 2025 paper, built as a fully
//! self-contained rust system (the environment is offline: every substrate
//! is in-tree, no external crates).
//!
//! The architecture rests on two unifications:
//!
//! **The `comm` pipeline** — one real-bytes quantize → entropy-code →
//! wire → decode path. Node codecs ([`comm::Compressor`]) produce
//! [`comm::WirePacket`]s — the actual encoded payload with per-layer bit
//! offsets and an exact bit count — and everything downstream consumes
//! those packets. Wire decoding is fallible end to end
//! (`comm::CommError`); malformed bytes never panic the coordinator.
//!
//! **The `oda` solver layer** — every solver (QODA/Algorithm 1, the
//! Q-GenX extra-gradient baseline, the Adam baselines) is a step-wise
//! [`oda::Solver`] state machine (`init` / `step` / `state`) driven by one
//! shared [`oda::RunDriver`] outer loop that owns checkpointing, ergodic
//! averaging, wire-bit/oracle accounting, gap evaluation with early
//! stopping and streaming [`oda::MetricsSink`]s. Runs are constructed
//! declaratively through the [`oda::RunSpec`] builder
//! (operator / noise / nodes / compression / lr / protocol / steps) — the
//! CLI's `run` subcommand, the bench harnesses and the examples all go
//! through it.
//!
//! Around those:
//!
//! * [`coordinator`] — the two cluster engines (deterministic `sim` with a
//!   calibrated network clock, threaded `parallel` shipping packets over
//!   channels) share one decode-aggregate core and route packets through a
//!   pluggable [`coordinator::Transport`] topology (broadcast-allgather,
//!   hierarchical two-level, parameter-server), charged with measured
//!   packet bytes against the heterogeneous-link network model, under a
//!   synchronous or overlapped [`coordinator::ExchangePlan`]
//!   (double-buffered duals hiding comm behind the next step's compute);
//!   engines, topologies and exchange modes are integration-tested for
//!   bit-identical agreement;
//! * [`quant`] + [`coding`] — the layer-wise quantizer, level-sequence
//!   adaptation (Eq. 2 / L-GreCo) and the Main/Alternating entropy-coding
//!   protocols the codecs compose;
//! * [`runtime`] — the native model backend (WGAN game + transformer-LM
//!   stand-in) driving the Section 7 workloads via [`gan`], [`lm`] and
//!   [`powersgd`];
//! * [`wire`] — the measured-wire TCP runtime: a third coordinator engine
//!   where every node is a real OS thread shipping the actual coded
//!   [`comm::WirePacket`] bytes over localhost sockets and `comm_s` is a
//!   monotonic-clock *measurement* around real socket I/O (the analytic
//!   charge model is never consulted on this path); aggregates reuse the
//!   same decode-aggregate core, so they stay bit-identical to the
//!   simulated engines (pinned by `tests/wire_e2e.rs`);
//! * [`bench_harness`], [`net`], [`vi`], [`stats`], [`util`] — experiment
//!   harnesses, the analytic cluster network model, VI substrate and shared
//!   infrastructure;
//! * [`analysis`] — the in-tree static auditor behind `qoda audit`.
//!
//! ## Invariant catalog
//!
//! The bit-exactness the parity suites pin is also enforced *statically* by
//! `qoda audit` (see [`analysis`]) over the wire-affecting trees `coding/`,
//! `comm/`, `quant/`, `coordinator/`, `wire/`:
//!
//! | rule | invariant | parity suite it protects |
//! |------|-----------|--------------------------|
//! | `hash-container` | no `HashMap`/`HashSet` on wire paths — hash iteration order must never reach a codebook or layer walk | `golden_parity`, `topology_equivalence` |
//! | `panic-path` | decode/comm paths return [`comm::CommError`], never panic — corrupt bytes cannot poison a node | `comm_fuzz` |
//! | `rng-clone` | `Rng` clones only at justified parallel-splice sites with `layer_draws` accounting | `fused_parity` (parallel == sequential encode) |
//! | `lossy-cast` | truncating `as f32`/`as u8`/`as u16` confined to quantizer/bitio owner modules | protocol wire-width contract (`C_q` fp32 norms, u8 symbols) |
//!
//! Exceptions are explicit `// audit:allow(<rule>) — <reason>` pragmas that
//! the auditor verifies still suppress a finding (stale allows fail the
//! build). The dynamic complement runs in CI: Miri over `coding/` + `stats/`
//! tests and ThreadSanitizer over `coordinator/parallel` tests, plus the
//! `#[cfg(debug_assertions)]` packet invariants in [`comm::packet`].

pub mod analysis;
pub mod bench_harness;
pub mod coding;
pub mod comm;
pub mod coordinator;
pub mod gan;
pub mod lm;
pub mod net;
pub mod oda;
pub mod powersgd;
pub mod quant;
pub mod runtime;
pub mod stats;
pub mod util;
pub mod vi;
pub mod wire;

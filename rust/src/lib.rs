//! # qoda — Layer-wise Quantization for Quantized Optimistic Dual Averaging
//!
//! Production reproduction of the ICML 2025 paper: a three-layer
//! rust + JAX + Pallas stack where rust owns the distributed training loop
//! (L3), JAX defines the models (L2, AOT-lowered to HLO text) and Pallas
//! provides the quantization / matmul kernels (L1). Python never runs on
//! the request path — the rust binary executes `artifacts/*.hlo.txt` via
//! PJRT (the `xla` crate).
//!
//! Top-level modules mirror DESIGN.md's system inventory.

pub mod bench_harness;
pub mod coding;
pub mod coordinator;
pub mod gan;
pub mod lm;
pub mod net;
pub mod oda;
pub mod powersgd;
pub mod quant;
pub mod runtime;
pub mod stats;
pub mod util;
pub mod vi;

//! Synthetic corpus generator — the WikiText-103 stand-in (DESIGN.md
//! substitutions): an order-1 Markov chain over the vocab with sparse,
//! skewed transitions plus periodic "phrase" structure, so the LM has real
//! sequential signal to learn (perplexity well below uniform) while staying
//! fully deterministic and dependency-free.

use crate::stats::rng::Rng;

pub struct Corpus {
    pub vocab: usize,
    /// transition CDF rows: trans[v] = cumulative probs over next tokens
    trans: Vec<Vec<f64>>,
    rng: Rng,
    state: usize,
}

impl Corpus {
    pub fn new(vocab: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut trans = Vec::with_capacity(vocab);
        for v in 0..vocab {
            // each token has a handful of likely successors (sparse, skewed)
            let mut probs = vec![0.02 / vocab as f64; vocab];
            let fan = 3 + (v % 4);
            for f in 0..fan {
                let succ = (v * 7 + f * 13 + 1) % vocab;
                probs[succ] += if f == 0 { 0.55 } else { 0.4 / fan as f64 };
            }
            // normalize to CDF
            let total: f64 = probs.iter().sum();
            let mut acc = 0.0;
            let cdf: Vec<f64> = probs
                .iter()
                .map(|p| {
                    acc += p / total;
                    acc
                })
                .collect();
            trans.push(cdf);
            let _ = rng.next_u64(); // decorrelate construction from sampling
        }
        Corpus { vocab, trans, rng, state: 0 }
    }

    pub fn next_token(&mut self) -> i32 {
        let u = self.rng.uniform();
        let cdf = &self.trans[self.state];
        let next = match cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) | Err(i) => i.min(self.vocab - 1),
        };
        self.state = next;
        next as i32
    }

    /// A batch of sequences: batch x (seq + 1) row-major (inputs + shifted
    /// targets, as the LM artifacts expect).
    pub fn batch(&mut self, batch: usize, seq: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * (seq + 1));
        for _ in 0..batch {
            // random restart per sequence
            self.state = self.rng.below(self.vocab as u64) as usize;
            for _ in 0..=seq {
                out.push(self.next_token());
            }
        }
        out
    }

    /// Entropy rate upper bound of the chain (mean next-token entropy under
    /// the stationary-ish uniform state distribution) — the perplexity floor
    /// the trained LM should approach.
    pub fn entropy_rate_nats(&self) -> f64 {
        let mut h = 0.0;
        for cdf in &self.trans {
            let mut prev = 0.0;
            let mut hv = 0.0;
            for &c in cdf {
                let p = c - prev;
                prev = c;
                if p > 1e-12 {
                    hv -= p * p.ln();
                }
            }
            h += hv / self.trans.len() as f64;
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_range() {
        let mut c = Corpus::new(48, 1);
        let b = c.batch(4, 32);
        assert_eq!(b.len(), 4 * 33);
        assert!(b.iter().all(|&t| (0..48).contains(&t)));
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Corpus::new(32, 7);
        let mut b = Corpus::new(32, 7);
        assert_eq!(a.batch(2, 16), b.batch(2, 16));
    }

    #[test]
    fn structured_below_uniform_entropy() {
        let c = Corpus::new(48, 2);
        let h = c.entropy_rate_nats();
        let uniform = (48f64).ln();
        assert!(h < 0.75 * uniform, "entropy {h} vs uniform {uniform}");
        assert!(h > 0.2, "{h}"); // but not degenerate
    }

    #[test]
    fn bigram_statistics_nonuniform() {
        let mut c = Corpus::new(16, 3);
        let mut counts = vec![0usize; 16 * 16];
        let toks = c.batch(64, 255);
        for row in toks.chunks(256) {
            for w in row.windows(2) {
                counts[w[0] as usize * 16 + w[1] as usize] += 1;
            }
        }
        let max = *counts.iter().max().unwrap();
        let mean = counts.iter().sum::<usize>() / counts.len();
        assert!(max > 5 * mean, "max {max} mean {mean}");
    }
}

//! Transformer-LM training system (Section 7.2): the synthetic Markov
//! corpus (WikiText substitute), and the PowerSGD + quantization trainer
//! behind Table 3 and Figure 5.

pub mod corpus;
pub mod trainer;

pub use corpus::Corpus;
pub use trainer::{train, LmRunResult, LmTrainConfig, QuantTarget};

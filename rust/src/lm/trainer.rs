//! Transformer-LM training driver (Section 7.2): PowerSGD + {global,
//! layer-wise} quantization of the factors, with per-layer-type masks for
//! the Figure 5 ablation, K-node data parallelism and compression-rate
//! accounting read off the actual `comm` wire packets (identical to
//! Table 3's).

use crate::comm::{CommEndpoint, Compressor, IdentityCompressor};
use crate::lm::corpus::Corpus;
use crate::oda::baseline::AdamState;
use crate::powersgd::{FactorQuantMode, PowerSgdCodec};
use crate::quant::layer_map::LayerMap;
use crate::runtime::LmModel;
use crate::util::error::Result;

/// Which layers get quantized (Figure 5 masks; `All` is Table 3).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum QuantTarget {
    All,
    OnlyType(&'static str),
}

#[derive(Clone, Debug)]
pub struct LmTrainConfig {
    pub rank: usize,
    /// None => fp32 PowerSGD factors; Some(bits) => quantize factors
    pub quant_bits: Option<u32>,
    /// layer-wise assignment (vs the same bits everywhere)
    pub layerwise: bool,
    pub target: QuantTarget,
    pub k_nodes: usize,
    pub steps: usize,
    pub lr: f64,
    pub seed: u64,
    pub eval_every: usize,
}

impl Default for LmTrainConfig {
    fn default() -> Self {
        LmTrainConfig {
            rank: 16,
            quant_bits: Some(4),
            layerwise: true,
            target: QuantTarget::All,
            k_nodes: 2,
            steps: 120,
            // retuned (2e-3 -> 1e-2) for the native LM backend: the Markov
            // corpus + MLP stand-in needs the larger step to clear the
            // Table 3 perplexity thresholds in ~40-120 steps
            lr: 1e-2,
            seed: 1,
            eval_every: 20,
        }
    }
}

pub struct LmRunResult {
    /// (step, train loss)
    pub loss_curve: Vec<(usize, f64)>,
    /// (step, eval nll)
    pub eval_curve: Vec<(usize, f64)>,
    pub final_ppl: f64,
    pub compression_rate: f64,
    pub total_wire_bits: u64,
}

/// The layer-wise bit assignment: embedding layers are quantization-
/// sensitive (Figure 5) and get more bits; ff tolerates fewer — the
/// static L-GreCo-style profile derived from the gradient statistics.
pub fn layerwise_bits(map: &LayerMap, base_bits: u32) -> Vec<u32> {
    map.layers
        .iter()
        .map(|l| {
            let ty = &map.type_names[l.type_id];
            match ty.as_str() {
                "embedding" => (base_bits + 2).min(8),
                "attention" => base_bits.saturating_sub(1).max(2),
                "ff" => base_bits.saturating_sub(2).max(2),
                _ => 8,
            }
        })
        .collect()
}

fn quant_mode(map: &LayerMap, cfg: &LmTrainConfig) -> FactorQuantMode {
    match cfg.quant_bits {
        None => FactorQuantMode::None,
        Some(bits) => {
            let mut per_layer: Vec<u32> = if cfg.layerwise {
                layerwise_bits(map, bits)
            } else {
                vec![bits; map.layers.len()]
            };
            // figure-5 masks: quantize only the target type aggressively,
            // everything else at full width (8 bits ~ negligible error)
            if let QuantTarget::OnlyType(ty) = cfg.target {
                let tid = map.type_id(ty);
                for (l, b) in map.layers.iter().zip(per_layer.iter_mut()) {
                    if Some(l.type_id) != tid {
                        *b = 8;
                    } else {
                        *b = bits;
                    }
                }
            }
            FactorQuantMode::PerLayer { bits: per_layer }
        }
    }
}

/// Train the LM; reports perplexity + compression rate (Table 3 columns).
/// Every node's gradient travels through a `comm` endpoint — PowerSGD
/// factors as real wire packets, or raw fp32 for the uncompressed baseline
/// — so `total_wire_bits` is the sum of actual encoded payload sizes.
pub fn train(model: &LmModel, cfg: &LmTrainConfig) -> Result<LmRunResult> {
    let mut params = model.init_params(cfg.seed as i32)?;
    let mut adam = AdamState::new(model.dim, cfg.lr);
    let mode = quant_mode(&model.meta, cfg);
    // rank 0 sentinel = fully uncompressed fp32 baseline
    let uncompressed = cfg.quant_bits.is_none() && cfg.rank == 0;
    let mut endpoints: Vec<CommEndpoint> = (0..cfg.k_nodes)
        .map(|i| {
            let codec: Box<dyn Compressor> = if uncompressed {
                Box::new(IdentityCompressor::new())
            } else {
                Box::new(PowerSgdCodec::new(
                    &model.meta,
                    cfg.rank,
                    mode.clone(),
                    cfg.seed * 31 + i as u64,
                ))
            };
            CommEndpoint::new(codec)
        })
        .collect();
    let mut corpora: Vec<Corpus> = (0..cfg.k_nodes)
        .map(|i| Corpus::new(model.vocab, cfg.seed * 1009 + i as u64))
        .collect();
    let mut eval_corpus = Corpus::new(model.vocab, cfg.seed * 7919 + 555);

    let mut loss_curve = Vec::new();
    let mut eval_curve = Vec::new();
    let mut total_wire_bits = 0u64;
    let mut raw_bits_total = 0u64;
    let mut dec: Vec<f64> = Vec::with_capacity(model.dim);

    for step in 1..=cfg.steps {
        let mut mean = vec![0.0f64; model.dim];
        let mut loss_acc = 0.0;
        for node in 0..cfg.k_nodes {
            let tokens = corpora[node].batch(model.batch, model.seq);
            let (grads, loss) = model.grad(&params, &tokens)?;
            loss_acc += loss as f64 / cfg.k_nodes as f64;
            let g64: Vec<f64> = grads.iter().map(|&x| x as f64).collect();
            let bits = endpoints[node].roundtrip_into(&g64, &mut dec)?;
            total_wire_bits += bits as u64;
            raw_bits_total += (32 * model.dim) as u64;
            for (m, v) in mean.iter_mut().zip(&dec) {
                *m += v / cfg.k_nodes as f64;
            }
        }
        let dir = adam.direction(&mean);
        for (p, d) in params.iter_mut().zip(&dir) {
            *p -= *d as f32;
        }
        loss_curve.push((step, loss_acc));
        if step % cfg.eval_every == 0 || step == cfg.steps {
            let tokens = eval_corpus.batch(model.batch, model.seq);
            let nll = model.eval(&params, &tokens)? as f64;
            eval_curve.push((step, nll));
        }
    }
    let final_nll = eval_curve.last().map(|&(_, v)| v).unwrap_or(f64::NAN);
    Ok(LmRunResult {
        loss_curve,
        eval_curve,
        final_ppl: final_nll.exp(),
        compression_rate: raw_bits_total as f64 / total_wire_bits.max(1) as f64,
        total_wire_bits,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layerwise_bits_respects_types() {
        let map = LayerMap::parse_meta(
            "dim 48\nlayer e 0 16 embedding 4 4\nlayer a 16 16 attention 4 4\nlayer f 32 16 ff 4 4\n",
        )
        .unwrap();
        let bits = layerwise_bits(&map, 4);
        assert_eq!(bits, vec![6, 3, 2]);
    }

    #[test]
    fn masks_spare_other_layers() {
        let map = LayerMap::parse_meta(
            "dim 48\nlayer e 0 16 embedding 4 4\nlayer a 16 16 attention 4 4\nlayer f 32 16 ff 4 4\n",
        )
        .unwrap();
        let cfg = LmTrainConfig {
            quant_bits: Some(2),
            layerwise: false,
            target: QuantTarget::OnlyType("embedding"),
            ..Default::default()
        };
        let mode = quant_mode(&map, &cfg);
        assert!(
            matches!(&mode, FactorQuantMode::PerLayer { bits } if bits == &vec![2, 8, 8]),
            "expected per-layer mask, got {mode:?}"
        );
    }
}

//! `qoda` — the leader entrypoint / experiment CLI.
//!
//! Subcommands (every paper table & figure + theory verifications):
//!   table1            step time vs bandwidth (Table 1)
//!   table2            weak scaling (Table 2)
//!   fig4              WGAN FID curves: Adam vs QODA global vs layerwise
//!   table3            transformer: PowerSGD x quantization (Table 3)
//!   fig5              per-layer-type quantization ablation (Figure 5)
//!   rates             GAP decay (V3/V4)   [--noise absolute|relative|relative-alt]
//!   verify-variance   Theorem 5.1 check (V1)
//!   verify-codelen    Theorem 5.3/D.5 check (V2)
//!   verify-mqv        Remark 3.2 check (V5)
//!   protocols         Main vs Alternating under jitter (V6)
//!   optimism          QODA vs Q-GenX oracle/wire cost
//!   ablations         adaptation-knob ablation (static/adaptive/L-GreCo)
//!   train-gan         single WGAN training run
//!   train-lm          single transformer-LM training run
//!   all               run the non-PJRT suite (writes results/*.csv)

use qoda::util::error::Result;
use qoda::bench_harness::{experiments, model_experiments};
use qoda::gan::trainer::{GanCompression, GanOptimizer, GanTrainConfig};
use qoda::lm::trainer::{LmTrainConfig, QuantTarget};
use qoda::runtime::{LmModel, Runtime, WganModel};
use qoda::util::cli::Args;
use qoda::util::table::save_series_csv;

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "table1" => {
            let t = experiments::table1();
            t.print();
            t.save_csv("table1.csv")?;
        }
        "table2" => {
            let t = experiments::table2();
            t.print();
            t.save_csv("table2.csv")?;
        }
        "fig4" => {
            let steps = args.usize_or("steps", 240);
            let nseeds = args.usize_or("seeds", 2);
            let seeds: Vec<u64> = (1..=nseeds as u64).collect();
            let (summary, rows) = model_experiments::fig4(steps, &seeds)?;
            summary.print();
            summary.save_csv("fig4_summary.csv")?;
            save_series_csv(
                "fig4_fid.csv",
                &["step", "adam", "qoda_global", "qoda_layerwise"],
                &rows,
            )?;
            println!("curves -> results/fig4_fid.csv");
        }
        "table3" => {
            let steps = args.usize_or("steps", 120);
            let nseeds = args.usize_or("seeds", 2);
            let seeds: Vec<u64> = (1..=nseeds as u64).collect();
            let ranks = [4usize, 8, 16];
            let t = model_experiments::table3(steps, &ranks, &seeds)?;
            t.print();
            t.save_csv("table3.csv")?;
        }
        "fig5" => {
            let steps = args.usize_or("steps", 120);
            let nseeds = args.usize_or("seeds", 2);
            let seeds: Vec<u64> = (1..=nseeds as u64).collect();
            let t = model_experiments::fig5(steps, &seeds)?;
            t.print();
            t.save_csv("fig5.csv")?;
        }
        "rates" => {
            let noise = args.get_or("noise", "absolute");
            let t = experiments::rates_table(&noise);
            t.print();
            t.save_csv(&format!("rates_{noise}.csv"))?;
        }
        "verify-variance" => {
            let t = experiments::verify_variance();
            t.print();
            t.save_csv("verify_variance.csv")?;
        }
        "verify-codelen" => {
            let t = experiments::verify_codelen();
            t.print();
            t.save_csv("verify_codelen.csv")?;
        }
        "verify-mqv" => {
            let t = experiments::verify_mqv();
            t.print();
            t.save_csv("verify_mqv.csv")?;
        }
        "protocols" => {
            let t = experiments::protocols_table();
            t.print();
            t.save_csv("protocols.csv")?;
        }
        "ablations" => {
            let t = experiments::ablation_table();
            t.print();
            t.save_csv("ablations.csv")?;
        }
        "optimism" => {
            let t = experiments::optimism_table();
            t.print();
            t.save_csv("optimism.csv")?;
        }
        "train-gan" => {
            let rt = Runtime::cpu()?;
            let model = WganModel::load(&rt)?;
            let cfg = GanTrainConfig {
                optimizer: match args.get_or("optimizer", "qoda").as_str() {
                    "adam" => GanOptimizer::Adam,
                    _ => GanOptimizer::OptimisticAdam,
                },
                compression: match args.get_or("compression", "layerwise").as_str() {
                    "none" => GanCompression::None,
                    "global" => GanCompression::Global {
                        bits: args.usize_or("bits", 5) as u32,
                        bucket: args.usize_or("bucket", 128),
                    },
                    _ => GanCompression::LayerwiseLGreco {
                        bits: args.usize_or("bits", 5) as u32,
                        bucket: args.usize_or("bucket", 128),
                        every: args.usize_or("update-every", 50),
                    },
                },
                k_nodes: args.usize_or("k", 4),
                steps: args.usize_or("steps", 300),
                lr: args.f64_or("lr", 5e-4),
                clip: args.f64_or("clip", 0.1) as f32,
                fid_every: args.usize_or("fid-every", 25),
                seed: args.u64_or("seed", 1),
                bandwidth_gbps: args.f64_or("bandwidth", 5.0),
            };
            println!("training WGAN: {cfg:?}");
            let run = qoda::gan::trainer::train(&model, &cfg)?;
            for m in run.metrics.steps.iter().step_by(10.max(cfg.steps / 30)) {
                println!(
                    "step {:>4}  g_loss {:+.4}  w_dist {:+.4}  step_ms {:.1}  KB/node {:.1}{}",
                    m.step,
                    m.scalar("g_loss").unwrap_or(f64::NAN),
                    m.scalar("w_dist").unwrap_or(f64::NAN),
                    m.total_s() * 1e3,
                    m.bytes_per_node / 1e3,
                    m.scalar("fid").map(|f| format!("  FID {f:.4}")).unwrap_or_default(),
                );
            }
            println!("final FID: {:.4}", run.final_fid);
        }
        "train-lm" => {
            let rt = Runtime::cpu()?;
            let model = LmModel::load(&rt)?;
            let cfg = LmTrainConfig {
                rank: args.usize_or("rank", 16),
                quant_bits: args.get("bits").map(|b| b.parse().unwrap()),
                layerwise: args.bool_or("layerwise", true),
                target: QuantTarget::All,
                k_nodes: args.usize_or("k", 2),
                steps: args.usize_or("steps", 120),
                lr: args.f64_or("lr", 1e-2),
                seed: args.u64_or("seed", 1),
                eval_every: args.usize_or("eval-every", 20),
            };
            println!("training LM: {cfg:?}");
            let run = qoda::lm::trainer::train(&model, &cfg)?;
            for (s, l) in run.loss_curve.iter().step_by(10.max(cfg.steps / 20)) {
                println!("step {s:>4}  train nll {l:.4}");
            }
            for (s, l) in &run.eval_curve {
                println!("eval step {s:>4}  nll {l:.4}  ppl {:.2}", l.exp());
            }
            println!(
                "final ppl {:.2}  compression rate {:.2}x",
                run.final_ppl, run.compression_rate
            );
        }
        "all" => {
            for (name, t) in [
                ("table1", experiments::table1()),
                ("table2", experiments::table2()),
                ("verify_variance", experiments::verify_variance()),
                ("verify_codelen", experiments::verify_codelen()),
                ("verify_mqv", experiments::verify_mqv()),
                ("protocols", experiments::protocols_table()),
                ("optimism", experiments::optimism_table()),
            ] {
                t.print();
                t.save_csv(&format!("{name}.csv"))?;
                println!();
            }
            for noise in ["absolute", "relative", "relative-alt"] {
                let t = experiments::rates_table(noise);
                t.print();
                t.save_csv(&format!("rates_{noise}.csv"))?;
                println!();
            }
        }
        _ => {
            println!(
                "usage: qoda <table1|table2|fig4|table3|fig5|rates|verify-variance|\
                 verify-codelen|verify-mqv|protocols|optimism|train-gan|train-lm|all> [flags]"
            );
        }
    }
    Ok(())
}

//! `qoda` — the leader entrypoint / experiment CLI.
//!
//! Subcommands (every paper table & figure + theory verifications):
//!   run               drive an arbitrary solver RunSpec from flags
//!   table1            step time vs bandwidth (Table 1)
//!   table2            weak scaling (Table 2)
//!   topology          weak scaling x topology (flat / hier / PS / sharded
//!                     / ring) with each plan's peak per-link KB per step
//!   overlap           weak scaling x exchange schedule (sync vs overlapped)
//!   fig4              WGAN FID curves: Adam vs QODA global vs layerwise
//!   table3            transformer: PowerSGD x quantization (Table 3)
//!   fig5              per-layer-type quantization ablation (Figure 5)
//!   rates             GAP decay (V3/V4)   [--noise absolute|relative|relative-alt]
//!   verify-variance   Theorem 5.1 check (V1)
//!   verify-codelen    Theorem 5.3/D.5 check (V2)
//!   verify-mqv        Remark 3.2 check (V5)
//!   protocols         Main vs Alternating under jitter (V6)
//!   optimism          QODA vs Q-GenX oracle/wire cost
//!   ablations         adaptation-knob ablation (static/adaptive/L-GreCo)
//!   adaptive          scheduled bit widths vs every static width at equal
//!                     total wire bits (quant::schedule ablation)
//!   wire              measured-wire TCP runtime: fp32 vs coded exchanges
//!                     over real localhost sockets per K, comm_s from
//!                     monotonic clocks (never the analytic charge model)
//!                     [--nodes N | --ks 4,8,12] [--steps T] [--dim D]
//!                     [--bits B --bucket N] [--exchange sync|overlap]
//!                     [--depth D] [--compute-ms MS] [--seed S] [--out F]
//!   train-gan         single WGAN training run
//!   train-lm          single transformer-LM training run
//!   audit             static invariant audit of rust/src (see `analysis`)
//!                     [--json] [--out FILE.json] [--root DIR]
//!   all               run the non-PJRT suite (writes results/*.csv)
//!
//! Malformed flags print the error plus this usage and exit with status 2 —
//! no panics, no backtraces. `audit` exits 1 when the tree has unallowed
//! findings or stale pragmas (CI's blocking `audit` job keys off that).
//!
//! `run` flags (all optional):
//!   --solver qoda|qgenx|adam|oadam    --op quadratic|bilinear  --dim N --mu F
//!   --noise none|absolute|relative    --sigma F                --k N
//!   --bits B (omit = fp32 wire)       --bucket N               --seed S
//!   --lr adaptive|alt|constant        --qhat F --gamma F --eta F
//!   --protocol main|alternating       --steps T
//!   --checkpoints t1,t2,...           --update-every N
//!   --bit-budget B (scheduled layer-wise bit widths under B wire bits/coord)
//!   --error-feedback (EF14 residual compensation on every node's encoder)
//!   --gap true|false                  --gap-every N --gap-stop THRESH
//!   --topology flat|hier|ps|sharded|ring   --racks R (hier; 0 = K/4)
//!   --bandwidth GBPS (attach the network clock and report comm seconds)
//!   --exchange sync|overlap           --depth D (overlap pipeline depth)
//!   --compute-ms MS (modeled compute per step the overlap hides behind)

use qoda::bench_harness::{experiments, model_experiments, JsonBench};
use qoda::coding::protocol::ProtocolKind;
use qoda::coordinator::{ExchangeMode, ExchangePlan, TopologySpec};
use qoda::gan::trainer::{GanCompression, GanOptimizer, GanTrainConfig};
use qoda::lm::trainer::{LmTrainConfig, QuantTarget};
use qoda::net::NetworkModel;
use qoda::oda::{
    CompressionSpec, GapMode, LrSpec, OperatorSpec, RunSpec, SolverKind,
};
use qoda::runtime::{LmModel, Runtime, WganModel};
use qoda::util::cli::Args;
use qoda::util::error::{Error, Result};
use qoda::util::table::{save_series_csv, Table};
use qoda::vi::noise::NoiseModel;
use qoda::wire::{run_wire, WireCodecSpec, WireOptions, Workload};

fn usage() -> &'static str {
    "usage: qoda <run|table1|table2|topology|overlap|fig4|table3|fig5|rates|verify-variance|\
     verify-codelen|verify-mqv|protocols|optimism|ablations|adaptive|wire|train-gan|train-lm|\
     audit|all> [flags]\n(see `qoda help` or the module docs for per-command flags)"
}

/// Resolve `--exchange` / `--depth`. `ExchangeMode::parse` is the single
/// validator (it also accepts the `async` alias), so the CLI can never
/// drift from the library's accepted names.
fn exchange_from_args(args: &Args) -> Result<ExchangeMode> {
    let name = args.get_or("exchange", "sync");
    ExchangeMode::parse(&name, args.usize_or("depth", 1)?).ok_or_else(|| {
        Error::msg(format!("--exchange expects sync|overlap, got {name:?}"))
    })
}

/// Resolve `--topology` / `--racks` against the node count. The sharded
/// and ring plans are rack-free peer meshes, so pairing them with an
/// explicit `--racks` is a typed error, not a silently dropped flag.
fn topology_from_args(args: &Args, k: usize) -> Result<TopologySpec> {
    let name = args.get_or("topology", "flat");
    let racks = args.usize_or("racks", 0)?;
    let spec = TopologySpec::parse(&name, racks).ok_or_else(|| {
        Error::msg(format!("--topology expects flat|hier|ps|sharded|ring, got {name:?}"))
    })?;
    spec.validate_racks(racks)
        .map_err(|e| Error::msg(format!("--topology {name}: {e}")))?;
    Ok(match spec {
        TopologySpec::Hierarchical { racks: 0 } => TopologySpec::hierarchical_for(k),
        other => other,
    })
}

/// Assemble a [`RunSpec`] from `qoda run` flags — the CLI face of the
/// declarative builder.
fn run_spec_from_args(args: &Args) -> Result<RunSpec> {
    let solver = match args.one_of("solver", "qoda", &["qoda", "qgenx", "adam", "oadam", "optimistic-adam"])?.as_str() {
        "qoda" => SolverKind::Qoda,
        "qgenx" => SolverKind::QGenX,
        "adam" => SolverKind::Adam { lr: args.f64_or("adam-lr", 0.05)? },
        _ => SolverKind::OptimisticAdam { lr: args.f64_or("adam-lr", 0.05)? },
    };
    let seed = args.u64_or("seed", 1)?;
    let operator = match args.one_of("op", "quadratic", &["quadratic", "bilinear"])?.as_str() {
        "bilinear" => OperatorSpec::Bilinear { n: args.usize_or("dim", 16)? / 2, seed },
        _ => OperatorSpec::Quadratic {
            dim: args.usize_or("dim", 16)?,
            mu: args.f64_or("mu", 0.5)?,
            seed,
        },
    };
    let noise = match args.one_of("noise", "absolute", &["none", "absolute", "relative"])?.as_str() {
        "none" => NoiseModel::None,
        "relative" => NoiseModel::Relative { sigma_r: args.f64_or("sigma", 0.5)? },
        _ => NoiseModel::Absolute { sigma: args.f64_or("sigma", 0.5)? },
    };
    let compression = match args.get("bits") {
        None => CompressionSpec::None,
        Some(b) => CompressionSpec::Global {
            bits: b.parse().map_err(|_| {
                Error::msg(format!("--bits expects a small integer, got {b:?}"))
            })?,
            bucket: args.usize_or("bucket", 128)?,
        },
    };
    let lr = match args.one_of("lr", "adaptive", &["adaptive", "alt", "constant"])?.as_str() {
        "alt" => LrSpec::Alt { q_hat: args.f64_or("qhat", 0.25)? },
        "constant" => LrSpec::Constant {
            gamma: args.f64_or("gamma", 0.1)?,
            eta: args.f64_or("eta", 0.1)?,
        },
        _ => LrSpec::Adaptive,
    };
    let protocol = match args.one_of("protocol", "main", &["main", "alternating"])?.as_str() {
        "alternating" => ProtocolKind::Alternating,
        _ => ProtocolKind::Main,
    };
    let steps = args.usize_or("steps", 1000)?;
    // default checkpoints: log-spaced quarters plus the horizon (the driver
    // normalizes)
    let checkpoints: Vec<usize> =
        args.list_or("checkpoints", vec![steps / 8, steps / 4, steps / 2, steps])?;
    let gap = if args.has("gap-stop") {
        GapMode::EarlyStop {
            every: args.usize_or("gap-every", 100)?,
            threshold: args.f64_or("gap-stop", 1e-3)?,
        }
    } else if args.bool_or("gap", true) {
        GapMode::AtCheckpoints
    } else {
        GapMode::Off
    };
    let k = args.usize_or("k", 4)?;
    let mut spec = RunSpec::new(solver, operator)
        .noise(noise)
        .nodes(k)
        .compression(compression)
        .lr(lr)
        .protocol(protocol)
        .steps(steps)
        .checkpoints(&checkpoints)
        .seed(seed)
        .update_every(args.usize_or("update-every", 0)?)
        .error_feedback(args.has("error-feedback"))
        .gap(gap)
        .topology(topology_from_args(args, k)?)
        .exchange(exchange_from_args(args)?)
        .compute_per_step(args.f64_or("compute-ms", 0.0)? * 1e-3);
    // an explicit --topology or --exchange without --bandwidth still
    // attaches the default network clock — otherwise the flag would be a
    // silent no-op (both only show up in comm_s / exposed-vs-hidden /
    // net_wire_bits accounting)
    if args.has("bandwidth") || args.has("topology") || args.has("exchange") {
        spec = spec.network(NetworkModel::genesis_cloud(args.f64_or("bandwidth", 5.0)?));
    }
    if args.has("bit-budget") {
        spec = spec.bit_budget(args.f64_or("bit-budget", 4.0)?);
    }
    Ok(spec)
}

fn run_cmd(args: &Args) -> Result<()> {
    let spec = run_spec_from_args(args)?;
    println!("driving: {spec:?}\n");
    let report = spec.run();
    let mut t = Table::new(
        "run — checkpoints",
        &["t", "wire Mbits", "oracle calls", "GAP"],
    );
    for ck in &report.checkpoints {
        let gap = report
            .gap_trace
            .iter()
            .find(|&&(gt, _)| gt == ck.t)
            .map(|&(_, g)| format!("{g:.6}"))
            .unwrap_or_else(|| "-".into());
        t.row(&[
            format!("{}", ck.t),
            format!("{:.3}", ck.total_bits as f64 / 1e6),
            format!("{}", ck.oracle_calls),
            gap,
        ]);
    }
    t.print();
    t.save_csv("run.csv")?;
    println!(
        "\n{} steps ({}), {} oracle calls, {:.3} Mbits on the wire, \
         {:.2} bits/iter/node, rel. quant error {:.2e}",
        report.steps_run,
        if report.stopped_early { "stopped early on gap threshold" } else { "full horizon" },
        report.oracle_calls,
        report.total_bits as f64 / 1e6,
        report.bits_per_iter_node,
        report.rel_quant_error(),
    );
    if report.comm_s > 0.0 {
        println!(
            "{} topology, {} exchange: {:.3} Mbits routed, {:.1} ms on the simulated \
             network clock ({:.1} ms exposed + {:.1} ms hidden behind compute)",
            spec.topology.label(),
            spec.exchange.mode.label(),
            report.net_wire_bits as f64 / 1e6,
            report.comm_s * 1e3,
            report.comm_exposed_s * 1e3,
            report.comm_hidden_s * 1e3,
        );
    }
    if let Some(g) = report.final_gap() {
        println!("final GAP(x-bar) = {g:.6}");
    }
    Ok(())
}

/// `qoda wire` — drive the measured-wire TCP runtime: fp32 vs entropy-coded
/// exchanges over real localhost sockets at each K, flat and hierarchical,
/// with `comm_s` measured by monotonic clocks around the actual socket I/O
/// (the analytic charge model is never consulted on this path). Measured
/// records land as `wire/*` entries in `results/` for CI artifacts;
/// `scripts/check_bench.py` treats them as informational, not regression
/// floors, since socket latency varies across runners.
fn wire_cmd(args: &Args) -> Result<()> {
    let ks: Vec<usize> = if args.has("nodes") {
        vec![args.usize_or("nodes", 4)?]
    } else {
        args.list_or("ks", vec![4usize, 8, 12])?
    };
    let steps = args.usize_or("steps", 30)?;
    let dim = args.usize_or("dim", 1 << 18)?;
    let bits = args.usize_or("bits", 4)? as u32;
    let bucket = args.usize_or("bucket", 128)?;
    let seed = args.u64_or("seed", 1)?;
    // overlapped by default: the whole point of the measured runtime is to
    // overlap real latency, and the leader's read-before-write lookahead is
    // what keeps kernel socket buffers drained at the larger K
    let exchange = args.get_or("exchange", "overlap");
    let mode = ExchangeMode::parse(&exchange, args.usize_or("depth", 1)?).ok_or_else(|| {
        Error::msg(format!("--exchange expects sync|overlap, got {exchange:?}"))
    })?;
    let plan = ExchangePlan {
        mode,
        compute_s_per_step: args.f64_or("compute-ms", 0.0)? * 1e-3,
    };
    let out = args.get_or("out", "WIRE_timing.json");

    let fp32 = CompressionSpec::None.wire_codec(dim, ProtocolKind::Main);
    let coded = CompressionSpec::Global { bits, bucket }.wire_codec(dim, ProtocolKind::Main);
    let x0 = vec![0.0f64; dim];
    let update = |x: &mut Vec<f64>, mean: &[f64], _t: usize| {
        for (xi, m) in x.iter_mut().zip(mean) {
            *xi -= 0.05 * m;
        }
    };

    let mut t = Table::new(
        "wire — measured localhost comm (monotonic clocks around real sockets)",
        &[
            "K", "variant", "Mbit/round", "comm ms/round", "exposed ms/round",
            "peak link KB", "wire MB total",
        ],
    );
    let mut bench = JsonBench::new();
    for &k in &ks {
        let variants: Vec<(&str, &WireCodecSpec, TopologySpec)> = vec![
            ("fp32-flat", &fp32, TopologySpec::BroadcastAllGather),
            ("coded-flat", &coded, TopologySpec::BroadcastAllGather),
            ("coded-hier", &coded, TopologySpec::hierarchical_for(k)),
            ("coded-sharded", &coded, TopologySpec::ShardedReduceScatter),
        ];
        let mut comm_ms_of: Vec<(String, f64)> = Vec::new();
        let mut peak_kb_of: Vec<(String, f64)> = Vec::new();
        for (label, codec, topo) in variants {
            // the sharded mesh is sync-only by design — force it rather
            // than failing the whole sweep when --exchange overlap (the
            // default) is in effect
            let plan = if matches!(topo, TopologySpec::ShardedReduceScatter) {
                ExchangePlan { mode: ExchangeMode::Synchronous, ..plan }
            } else {
                plan
            };
            let report = run_wire(
                Workload::Synthetic { dim, scale: 1.0 },
                k,
                codec,
                &x0,
                steps,
                seed,
                &topo,
                plan,
                &WireOptions::default(),
                &update,
            )
            .map_err(|e| Error::msg(format!("wire {label} K={k}: {e:?}")))?;
            let rounds = report.rounds.len().max(1) as f64;
            let mbit_per_round = report.payload_bits as f64 / rounds / 1e6;
            let comm_ms = report.comm_s / rounds * 1e3;
            let exposed_ms = report.comm_exposed_s / rounds * 1e3;
            let peak_kb = report.peak_link_bytes / 1e3;
            let wire_mb = report.frame_bytes as f64 / 1e6;
            t.row(&[
                format!("{k}"),
                label.to_string(),
                format!("{mbit_per_round:.3}"),
                format!("{comm_ms:.3}"),
                format!("{exposed_ms:.3}"),
                format!("{peak_kb:.1}"),
                format!("{wire_mb:.1}"),
            ]);
            bench.push(
                &format!("wire/k{k}/{label}"),
                &[
                    ("nodes", format!("{k}")),
                    ("steps", format!("{steps}")),
                    ("dim", format!("{dim}")),
                    ("topology", format!("{:?}", topo.label())),
                    ("exchange", format!("{:?}", exchange)),
                    ("measured_comm_ms_per_round", format!("{comm_ms:.3}")),
                    ("measured_exposed_ms_per_round", format!("{exposed_ms:.3}")),
                    ("payload_mbit_per_round", format!("{mbit_per_round:.3}")),
                    ("measured_peak_link_kb", format!("{peak_kb:.3}")),
                    ("frame_mb_total", format!("{wire_mb:.3}")),
                ],
            );
            comm_ms_of.push((label.to_string(), comm_ms));
            peak_kb_of.push((label.to_string(), peak_kb));
        }
        let of = |table: &[(String, f64)], name: &str| {
            table
                .iter()
                .find(|(l, _)| l == name)
                .map(|&(_, v)| v)
                .unwrap_or(f64::NAN)
        };
        println!(
            "K={k}: coded gives {:.2}x the fp32 measured comm rate (flat); \
             hierarchical is {:.2}x flat (coded); sharded peak link carries \
             {:.1}% of flat's bytes",
            of(&comm_ms_of, "fp32-flat") / of(&comm_ms_of, "coded-flat"),
            of(&comm_ms_of, "coded-flat") / of(&comm_ms_of, "coded-hier"),
            100.0 * of(&peak_kb_of, "coded-sharded") / of(&peak_kb_of, "coded-flat"),
        );
    }
    t.print();
    t.save_csv("wire.csv")?;
    let path = bench
        .save_merged(&out)
        .map_err(|e| Error::msg(format!("write {out}: {e}")))?;
    println!("measured wire records -> {}", path.display());
    Ok(())
}

fn dispatch(args: &Args) -> Result<()> {
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "run" => {
            run_cmd(args)?;
        }
        "table1" => {
            let t = experiments::table1();
            t.print();
            t.save_csv("table1.csv")?;
        }
        "table2" => {
            let t = experiments::table2();
            t.print();
            t.save_csv("table2.csv")?;
        }
        "topology" => {
            let ks = args.list_or("ks", vec![4usize, 8, 12, 16])?;
            let bw = args.f64_or("bandwidth", 5.0)?;
            let t = experiments::topology_table(&ks, bw);
            t.print();
            t.save_csv("topology.csv")?;
        }
        "overlap" => {
            let ks = args.list_or("ks", vec![4usize, 8, 12, 16])?;
            let bw = args.f64_or("bandwidth", 5.0)?;
            let depth = args.usize_or("depth", 1)?;
            let t = experiments::overlap_table(&ks, bw, depth);
            t.print();
            t.save_csv("overlap.csv")?;
        }
        "fig4" => {
            let steps = args.usize_or("steps", 240)?;
            let nseeds = args.usize_or("seeds", 2)?;
            let seeds: Vec<u64> = (1..=nseeds as u64).collect();
            let (summary, rows) = model_experiments::fig4(steps, &seeds)?;
            summary.print();
            summary.save_csv("fig4_summary.csv")?;
            save_series_csv(
                "fig4_fid.csv",
                &["step", "adam", "qoda_global", "qoda_layerwise"],
                &rows,
            )?;
            println!("curves -> results/fig4_fid.csv");
        }
        "table3" => {
            let steps = args.usize_or("steps", 120)?;
            let nseeds = args.usize_or("seeds", 2)?;
            let seeds: Vec<u64> = (1..=nseeds as u64).collect();
            let ranks = [4usize, 8, 16];
            let t = model_experiments::table3(steps, &ranks, &seeds)?;
            t.print();
            t.save_csv("table3.csv")?;
        }
        "fig5" => {
            let steps = args.usize_or("steps", 120)?;
            let nseeds = args.usize_or("seeds", 2)?;
            let seeds: Vec<u64> = (1..=nseeds as u64).collect();
            let t = model_experiments::fig5(steps, &seeds)?;
            t.print();
            t.save_csv("fig5.csv")?;
        }
        "rates" => {
            let noise =
                args.one_of("noise", "absolute", &["absolute", "relative", "relative-alt"])?;
            let t = experiments::rates_table(&noise);
            t.print();
            t.save_csv(&format!("rates_{noise}.csv"))?;
        }
        "verify-variance" => {
            let t = experiments::verify_variance();
            t.print();
            t.save_csv("verify_variance.csv")?;
        }
        "verify-codelen" => {
            let t = experiments::verify_codelen();
            t.print();
            t.save_csv("verify_codelen.csv")?;
        }
        "verify-mqv" => {
            let t = experiments::verify_mqv();
            t.print();
            t.save_csv("verify_mqv.csv")?;
        }
        "protocols" => {
            let t = experiments::protocols_table();
            t.print();
            t.save_csv("protocols.csv")?;
        }
        "ablations" => {
            let t = experiments::ablation_table();
            t.print();
            t.save_csv("ablations.csv")?;
        }
        "adaptive" => {
            let t = experiments::adaptive_schedule_table();
            t.print();
            t.save_csv("adaptive.csv")?;
        }
        "optimism" => {
            let t = experiments::optimism_table();
            t.print();
            t.save_csv("optimism.csv")?;
        }
        "wire" => {
            wire_cmd(args)?;
        }
        "train-gan" => {
            let rt = Runtime::cpu()?;
            let model = WganModel::load(&rt)?;
            let k = args.usize_or("k", 4)?;
            let cfg = GanTrainConfig {
                optimizer: match args.one_of("optimizer", "qoda", &["qoda", "adam", "oadam"])?.as_str() {
                    "adam" => GanOptimizer::Adam,
                    _ => GanOptimizer::OptimisticAdam,
                },
                compression: match args
                    .one_of(
                        "compression",
                        "layerwise",
                        &["none", "global", "layerwise", "scheduled"],
                    )?
                    .as_str()
                {
                    "none" => GanCompression::None,
                    "global" => GanCompression::Global {
                        bits: args.usize_or("bits", 5)? as u32,
                        bucket: args.usize_or("bucket", 128)?,
                    },
                    "scheduled" => GanCompression::Scheduled {
                        budget: args.f64_or("bit-budget", 4.0)?,
                        bucket: args.usize_or("bucket", 128)?,
                        every: args.usize_or("update-every", 50)?,
                        error_feedback: args.has("error-feedback"),
                    },
                    _ => GanCompression::LayerwiseLGreco {
                        bits: args.usize_or("bits", 5)? as u32,
                        bucket: args.usize_or("bucket", 128)?,
                        every: args.usize_or("update-every", 50)?,
                    },
                },
                k_nodes: k,
                steps: args.usize_or("steps", 300)?,
                lr: args.f64_or("lr", 5e-4)?,
                clip: args.f64_or("clip", 0.1)? as f32,
                fid_every: args.usize_or("fid-every", 25)?,
                seed: args.u64_or("seed", 1)?,
                bandwidth_gbps: args.f64_or("bandwidth", 5.0)?,
                topology: topology_from_args(args, k)?,
                exchange: exchange_from_args(args)?,
            };
            println!("training WGAN: {cfg:?}");
            let run = qoda::gan::trainer::train(&model, &cfg)?;
            for m in run.metrics.steps.iter().step_by(10.max(cfg.steps / 30)) {
                println!(
                    "step {:>4}  g_loss {:+.4}  w_dist {:+.4}  step_ms {:.1}  KB/node {:.1}{}",
                    m.step,
                    m.scalar("g_loss").unwrap_or(f64::NAN),
                    m.scalar("w_dist").unwrap_or(f64::NAN),
                    m.total_s() * 1e3,
                    m.bytes_per_node / 1e3,
                    m.scalar("fid").map(|f| format!("  FID {f:.4}")).unwrap_or_default(),
                );
            }
            println!("final FID: {:.4}", run.final_fid);
        }
        "train-lm" => {
            let rt = Runtime::cpu()?;
            let model = LmModel::load(&rt)?;
            let quant_bits = match args.get("bits") {
                None => None,
                Some(b) => Some(b.parse().map_err(|_| {
                    Error::msg(format!("--bits expects a small integer, got {b:?}"))
                })?),
            };
            let cfg = LmTrainConfig {
                rank: args.usize_or("rank", 16)?,
                quant_bits,
                layerwise: args.bool_or("layerwise", true),
                target: QuantTarget::All,
                k_nodes: args.usize_or("k", 2)?,
                steps: args.usize_or("steps", 120)?,
                lr: args.f64_or("lr", 1e-2)?,
                seed: args.u64_or("seed", 1)?,
                eval_every: args.usize_or("eval-every", 20)?,
            };
            println!("training LM: {cfg:?}");
            let run = qoda::lm::trainer::train(&model, &cfg)?;
            for (s, l) in run.loss_curve.iter().step_by(10.max(cfg.steps / 20)) {
                println!("step {s:>4}  train nll {l:.4}");
            }
            for (s, l) in &run.eval_curve {
                println!("eval step {s:>4}  nll {l:.4}  ppl {:.2}", l.exp());
            }
            println!(
                "final ppl {:.2}  compression rate {:.2}x",
                run.final_ppl, run.compression_rate
            );
        }
        "audit" => {
            let root = match args.get("root") {
                Some(r) => std::path::PathBuf::from(r),
                None => qoda::analysis::default_root(),
            };
            let report = qoda::analysis::run_audit(&root)?;
            if let Some(path) = args.get("out") {
                std::fs::write(path, report.to_json())
                    .map_err(|e| Error::msg(format!("write {path}: {e}")))?;
                eprintln!("audit: JSON report -> {path}");
            }
            if args.has("json") {
                println!("{}", report.to_json());
            } else {
                print!("{}", report.render());
            }
            // distinct from the usage-error status 2: findings are a
            // *verdict*, not a malformed invocation
            if !report.clean() {
                std::process::exit(1);
            }
        }
        "all" => {
            for (name, t) in [
                ("table1", experiments::table1()),
                ("table2", experiments::table2()),
                ("topology", experiments::topology_table(&[4, 8, 12, 16], 5.0)),
                ("overlap", experiments::overlap_table(&[4, 8, 12, 16], 5.0, 1)),
                ("verify_variance", experiments::verify_variance()),
                ("verify_codelen", experiments::verify_codelen()),
                ("verify_mqv", experiments::verify_mqv()),
                ("protocols", experiments::protocols_table()),
                ("optimism", experiments::optimism_table()),
                ("adaptive", experiments::adaptive_schedule_table()),
            ] {
                t.print();
                t.save_csv(&format!("{name}.csv"))?;
                println!();
            }
            for noise in ["absolute", "relative", "relative-alt"] {
                let t = experiments::rates_table(noise);
                t.print();
                t.save_csv(&format!("rates_{noise}.csv"))?;
                println!();
            }
        }
        _ => {
            println!("{}", usage());
        }
    }
    Ok(())
}

fn main() {
    let args = Args::from_env();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e}");
        eprintln!();
        eprintln!("{}", usage());
        std::process::exit(2);
    }
}

//! The **modeled** half of the repo's comm-seconds story: an analytic
//! network clock for the multi-node Genesis-Cloud-style environment of the
//! paper's Section 7.1 (4–16 single-GPU nodes, 1–5 Gbps inter-node links,
//! OpenMPI for quantized payloads / NCCL ring-allreduce for fp32).
//!
//! `comm_s` in this codebase comes from one of two places, and the split is
//! architectural:
//!
//! * **Modeled** (this module) — the coder produces *real encoded byte
//!   counts* and this module converts them to wall-clock analytically, the
//!   way a bandwidth-bound cluster does. Deterministic, machine-independent,
//!   parameterized (bandwidth, link classes, stragglers, jitter) — what the
//!   Table 1/2 harnesses sweep, because a sweep over bandwidths needs a
//!   clock you can dial.
//! * **Measured** ([`crate::wire`]) — the same coded packets shipped as
//!   actual bytes over real localhost TCP sockets, with `comm_s` read off a
//!   monotonic clock around the socket I/O. Machine-dependent by design;
//!   nothing under `wire/` consults this module's charge model, and nothing
//!   here ever touches a socket. The two paths share only the packets, the
//!   decode-aggregate core and the exposed-vs-hidden split arithmetic
//!   ([`crate::coordinator::topology::ExchangePlan::split`]), so measured
//!   runs validate the model's *orderings* (coded vs fp32, hierarchical vs
//!   flat, overlap hiding) without inheriting its assumptions.
//!
//! The model side covers:
//!
//! * the flat ring collectives ([`Collective`]), per-hop latency, jitter
//!   (Remark D.3) and the baseline's scaling degradation that Table 2
//!   exhibits — pinned by the calibration tests in [`simulator`];
//! * **two heterogeneous link classes** — slow cross-rack links
//!   (`bandwidth_gbps`) and fast PCIe/NVLink-class rack-local links
//!   (`intra_rack_gbps`) — which the pluggable topologies of
//!   [`crate::coordinator::topology`] charge their phases against;
//! * **injectable stragglers** ([`NetworkModel::with_straggler`]): per-node
//!   link slowdowns that bottleneck exactly the phases the slow link
//!   participates in (a rack-local straggler never touches the cross-rack
//!   exchange; a straggling rack *leader* does);
//! * **phase decomposition** ([`PhaseTimeline`]): every topology charge
//!   splits into wall-clock-ordered intervals tagged by [`PhaseKind`]
//!   (rack-local gather → cross-rack exchange → rack-local broadcast).
//!   The *synchronous* exchange schedule puts the whole timeline on the
//!   critical path; the *overlapped* schedule
//!   ([`ExchangeMode::Overlapped`](crate::coordinator::topology::ExchangeMode))
//!   hides it behind the next step's compute window and exposes only the
//!   remainder — the calibration tests below pin which phases a given
//!   compute budget can hide and which a straggling leader re-exposes.
//!
//! Five transport plans charge their phases against this clock
//! ([`crate::coordinator::topology`] for the star-shaped three,
//! [`crate::coordinator::collectives`] for the bandwidth-optimal two).
//! With K nodes, per-node coded payloads `b_j` (total `B` bytes) and
//! aggregate dimension `d`, per step:
//!
//! | plan | wire bits | peak per-link bytes | shape |
//! |------|-----------|---------------------|-------|
//! | flat broadcast-allgather | `Σ b_j` | `(K−1)/K · B` — grows ~linearly with K | one collective over the cross-rack class |
//! | hierarchical (R racks) | up + cross + down bundle traffic | the busiest leader link | 3 phases over 2 link classes |
//! | parameter server | `Σ b_j + K·32d` | the hub's serialized egress | 2 phases, hub-bottlenecked |
//! | sharded reduce-scatter | `Σ_j (b_j − s_jj) + 32d` | `≈ B/K` — **~1/K of flat's** | 2 phases, every link carries one shard + one fp32 slice |
//! | ring | `2(K−1)·Σ_o chunk_o` | `2(K−1)·chunk_max ≈ 2·b` — **constant in K** | 2(K−1) serialized steps |
//!
//! The first three pin the paper's measured regimes (Tables 1/2); the last
//! two are the weak-scaling escape hatch — past K ≈ 32 the star plans all
//! push a full payload set over some link while the sharded plan's hottest
//! link carries ~1/K of that (`WireCharge::peak_link_bytes` reports it,
//! `qoda topology` prints it, and `scripts/check_bench.py` gates it).
//!
//! The topology layer asks this module for primitive phase costs
//! ([`NetworkModel::link_seconds`], [`NetworkModel::collective_seconds`],
//! [`NetworkModel::max_slowdown_over`]) and composes them into a charge
//! plus its timeline; this module never needs to know which topology — or
//! which exchange schedule — is running.

pub mod simulator;

pub use simulator::{Collective, JitterModel, NetworkModel, PhaseKind, PhaseTimeline};

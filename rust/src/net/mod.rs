//! Network model for the multi-node Genesis-Cloud-style environment of the
//! paper's Section 7.1 (4–16 single-GPU nodes, 1–5 Gbps inter-node links,
//! OpenMPI for quantized payloads / NCCL ring-allreduce for fp32).
//!
//! The coder produces *real encoded byte counts*; this module converts them
//! to wall-clock the way a bandwidth-bound cluster does. It models:
//!
//! * the flat ring collectives ([`Collective`]), per-hop latency, jitter
//!   (Remark D.3) and the baseline's scaling degradation that Table 2
//!   exhibits — pinned by the calibration tests in [`simulator`];
//! * **two heterogeneous link classes** — slow cross-rack links
//!   (`bandwidth_gbps`) and fast PCIe/NVLink-class rack-local links
//!   (`intra_rack_gbps`) — which the pluggable topologies of
//!   [`crate::coordinator::topology`] charge their phases against;
//! * **injectable stragglers** ([`NetworkModel::with_straggler`]): per-node
//!   link slowdowns that bottleneck exactly the phases the slow link
//!   participates in (a rack-local straggler never touches the cross-rack
//!   exchange; a straggling rack *leader* does).
//!
//! The topology layer asks this module for primitive phase costs
//! ([`NetworkModel::link_seconds`], [`NetworkModel::collective_seconds`],
//! [`NetworkModel::max_slowdown_over`]) and composes them; this module
//! never needs to know which topology is running.

pub mod simulator;

pub use simulator::{Collective, JitterModel, NetworkModel};

//! Network model for the multi-node Genesis-Cloud-style environment of the
//! paper's Section 7.1 (4–16 single-GPU nodes, 1–5 Gbps inter-node links,
//! OpenMPI for quantized payloads / NCCL ring-allreduce for fp32).
//!
//! The coder produces *real encoded byte counts*; this module converts them
//! to wall-clock the way a bandwidth-bound cluster does. It models:
//!
//! * the flat ring collectives ([`Collective`]), per-hop latency, jitter
//!   (Remark D.3) and the baseline's scaling degradation that Table 2
//!   exhibits — pinned by the calibration tests in [`simulator`];
//! * **two heterogeneous link classes** — slow cross-rack links
//!   (`bandwidth_gbps`) and fast PCIe/NVLink-class rack-local links
//!   (`intra_rack_gbps`) — which the pluggable topologies of
//!   [`crate::coordinator::topology`] charge their phases against;
//! * **injectable stragglers** ([`NetworkModel::with_straggler`]): per-node
//!   link slowdowns that bottleneck exactly the phases the slow link
//!   participates in (a rack-local straggler never touches the cross-rack
//!   exchange; a straggling rack *leader* does);
//! * **phase decomposition** ([`PhaseTimeline`]): every topology charge
//!   splits into wall-clock-ordered intervals tagged by [`PhaseKind`]
//!   (rack-local gather → cross-rack exchange → rack-local broadcast).
//!   The *synchronous* exchange schedule puts the whole timeline on the
//!   critical path; the *overlapped* schedule
//!   ([`ExchangeMode::Overlapped`](crate::coordinator::topology::ExchangeMode))
//!   hides it behind the next step's compute window and exposes only the
//!   remainder — the calibration tests below pin which phases a given
//!   compute budget can hide and which a straggling leader re-exposes.
//!
//! The topology layer asks this module for primitive phase costs
//! ([`NetworkModel::link_seconds`], [`NetworkModel::collective_seconds`],
//! [`NetworkModel::max_slowdown_over`]) and composes them into a charge
//! plus its timeline; this module never needs to know which topology — or
//! which exchange schedule — is running.

pub mod simulator;

pub use simulator::{Collective, JitterModel, NetworkModel, PhaseKind, PhaseTimeline};

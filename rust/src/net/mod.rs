//! Network model for the multi-node Genesis-Cloud-style environment of the
//! paper's Section 7.1 (4–16 single-GPU nodes, 1–5 Gbps inter-node links,
//! OpenMPI for quantized payloads / NCCL ring-allreduce for fp32).
//!
//! The coder produces *real encoded byte counts*; this module converts them
//! to wall-clock the way a bandwidth-bound cluster does, including the ring
//! collectives, per-hop latency, jitter (Remark D.3) and the baseline's
//! scaling degradation that Table 2 exhibits.

pub mod simulator;

pub use simulator::{Collective, JitterModel, NetworkModel};

//! Analytic cluster network model.
//!
//! Calibration targets (paper Tables 1–2): with K = 4 and 5 Gbps links the
//! uncompressed WGAN baseline spends ~251 ms/step and QODA5 ~195 ms; at
//! 1 Gbps the baseline degrades to ~291 ms while QODA5 stays ~197 ms; under
//! weak scaling the baseline *degrades* with K (303/318/285 ms at 8/12/16)
//! while QODA5 improves (165/127/115 ms). The model reproduces this regime
//! from first principles: ring collectives + per-hop latency + a
//! K-dependent straggler/incast term that full-fat fp32 payloads suffer and
//! sub-megabyte quantized payloads do not.

use crate::stats::rng::Rng;

/// Collective used to exchange the per-node payloads.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Collective {
    /// NCCL-style ring allreduce over raw fp32 (reduces in-network):
    /// per-node traffic 2 (K-1)/K * bytes.
    RingAllReduce,
    /// Allgather of (differently-sized, entropy-coded) payloads: each node
    /// receives the other K-1 compressed messages: (K-1)/K * sum_bytes.
    RingAllGather,
}

/// End-to-end delay jitter (Verma et al., 1991) for the Remark D.3 protocol
/// study: each message independently "jitters" with probability `p`, which
/// forces a retransmission of `retrans_fraction` of the payload for codes
/// without per-symbol resynchronization (Main protocol), but only
/// `resync_fraction` for uniquely-decodable-per-symbol codebooks
/// (Alternating protocol).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct JitterModel {
    pub p: f64,
    pub retrans_fraction: f64,
    pub resync_fraction: f64,
}

impl JitterModel {
    pub fn none() -> Self {
        JitterModel { p: 0.0, retrans_fraction: 1.0, resync_fraction: 0.05 }
    }
}

#[derive(Clone, Debug)]
pub struct NetworkModel {
    pub bandwidth_gbps: f64,
    /// one-hop latency
    pub latency_us: f64,
    /// incast/straggler coefficient: extra per-step milliseconds per node
    /// per megabyte of *per-node* payload (saturating switches; hits the
    /// fp32 baseline, negligible for compressed payloads)
    pub straggler_ms_per_node_mb: f64,
    pub jitter: JitterModel,
}

impl NetworkModel {
    /// The paper's testbed: 5 Gbps, ~50 us inter-node latency.
    pub fn genesis_cloud(bandwidth_gbps: f64) -> Self {
        NetworkModel {
            bandwidth_gbps,
            latency_us: 50.0,
            straggler_ms_per_node_mb: 0.9,
            jitter: JitterModel::none(),
        }
    }

    fn bytes_per_sec(&self) -> f64 {
        self.bandwidth_gbps * 1e9 / 8.0
    }

    /// Wall-clock seconds for one collective exchange.
    /// `per_node_bytes[k]` is node k's (possibly compressed) payload size.
    pub fn collective_seconds(&self, kind: Collective, per_node_bytes: &[f64]) -> f64 {
        let k = per_node_bytes.len().max(1) as f64;
        let total: f64 = per_node_bytes.iter().sum();
        let max_b = per_node_bytes.iter().copied().fold(0.0, f64::max);
        let bw = self.bytes_per_sec();
        let lat = self.latency_us * 1e-6;
        let wire = match kind {
            Collective::RingAllReduce => {
                // 2(K-1)/K of the (uniform) payload, 2(K-1) latency hops
                2.0 * (k - 1.0) / k * max_b / bw + 2.0 * (k - 1.0) * lat
            }
            Collective::RingAllGather => {
                // every node forwards the K-1 foreign chunks: (K-1)/K of the
                // total traffic crosses each link, pipelined
                (k - 1.0) / k * total / bw + (k - 1.0) * lat
            }
        };
        // incast/straggler degradation grows with K and per-node payload
        let per_node_mb = max_b / 1e6;
        let straggler =
            self.straggler_ms_per_node_mb * 1e-3 * per_node_mb * (k - 1.0).max(0.0);
        wire + straggler
    }

    /// Expected retransmission overhead multiplier for a payload under the
    /// jitter model (Remark D.3): Main pays `retrans_fraction` of the
    /// message again on a jitter event, Alternating only resynchronizes.
    pub fn jitter_multiplier(&self, main_protocol: bool) -> f64 {
        let j = self.jitter;
        let frac = if main_protocol { j.retrans_fraction } else { j.resync_fraction };
        1.0 + j.p * frac
    }

    /// Sampled (stochastic) step communication time with jitter events.
    pub fn sample_collective_seconds(
        &self,
        kind: Collective,
        per_node_bytes: &[f64],
        main_protocol: bool,
        rng: &mut Rng,
    ) -> f64 {
        let base = self.collective_seconds(kind, per_node_bytes);
        if self.jitter.p == 0.0 {
            return base;
        }
        let frac = if main_protocol {
            self.jitter.retrans_fraction
        } else {
            self.jitter.resync_fraction
        };
        let mut t = base;
        for _ in 0..per_node_bytes.len() {
            if rng.uniform() < self.jitter.p {
                t += base * frac / per_node_bytes.len() as f64;
            }
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn net(bw: f64) -> NetworkModel {
        NetworkModel {
            bandwidth_gbps: bw,
            latency_us: 50.0,
            straggler_ms_per_node_mb: 0.0,
            jitter: JitterModel::none(),
        }
    }

    #[test]
    fn allreduce_bandwidth_math() {
        // 16 MB over 4 nodes at 5 Gbps: 2*(3/4)*16MB / 625MB/s = 38.4 ms
        let n = net(5.0);
        let t = n.collective_seconds(Collective::RingAllReduce, &[16e6; 4]);
        assert!((t - (2.0 * 0.75 * 16e6 / 625e6 + 6.0 * 50e-6)).abs() < 1e-9, "{t}");
    }

    #[test]
    fn compression_shrinks_time() {
        let n = net(5.0);
        let raw = n.collective_seconds(Collective::RingAllReduce, &[16e6; 4]);
        let comp = n.collective_seconds(Collective::RingAllGather, &[2.5e6; 4]);
        assert!(comp < raw / 2.0, "{comp} vs {raw}");
    }

    #[test]
    fn lower_bandwidth_hurts_more_with_big_payloads() {
        let hi = net(5.0);
        let lo = net(1.0);
        let big = [16e6; 4];
        let small = [0.5e6; 4];
        let d_big = lo.collective_seconds(Collective::RingAllReduce, &big)
            - hi.collective_seconds(Collective::RingAllReduce, &big);
        let d_small = lo.collective_seconds(Collective::RingAllGather, &small)
            - hi.collective_seconds(Collective::RingAllGather, &small);
        assert!(d_big > 10.0 * d_small, "{d_big} vs {d_small}");
    }

    #[test]
    fn straggler_term_grows_with_k() {
        let mut n = net(5.0);
        n.straggler_ms_per_node_mb = 1.0;
        let t4 = n.collective_seconds(Collective::RingAllReduce, &[16e6; 4]);
        let t16 = n.collective_seconds(Collective::RingAllReduce, &[16e6; 16]);
        // with a straggler term, scaling degrades despite ring traffic
        // converging to 2x payload
        assert!(t16 > t4, "{t16} vs {t4}");
    }

    #[test]
    fn jitter_penalizes_main_protocol_more() {
        let mut n = net(5.0);
        n.jitter = JitterModel { p: 0.2, retrans_fraction: 1.0, resync_fraction: 0.05 };
        assert!(n.jitter_multiplier(true) > n.jitter_multiplier(false));
        let mut rng = Rng::new(1);
        let reps = 2000;
        let (mut tm, mut ta) = (0.0, 0.0);
        for _ in 0..reps {
            tm += n.sample_collective_seconds(
                Collective::RingAllGather,
                &[1e6; 4],
                true,
                &mut rng,
            );
            ta += n.sample_collective_seconds(
                Collective::RingAllGather,
                &[1e6; 4],
                false,
                &mut rng,
            );
        }
        assert!(tm > ta, "{tm} vs {ta}");
    }

    #[test]
    fn allgather_scales_with_total_bytes() {
        let n = net(5.0);
        let t1 = n.collective_seconds(Collective::RingAllGather, &[1e6; 4]);
        let t2 = n.collective_seconds(Collective::RingAllGather, &[2e6; 4]);
        assert!(t2 > 1.9 * t1 && t2 < 2.1 * t1);
    }
}

//! Analytic cluster network model with heterogeneous links.
//!
//! Calibration targets (paper Tables 1–2): with K = 4 and 5 Gbps links the
//! uncompressed WGAN baseline spends ~251 ms/step and QODA5 ~195 ms; at
//! 1 Gbps the baseline degrades to ~291 ms while QODA5 stays ~197 ms; under
//! weak scaling the baseline *degrades* with K (303/318/285 ms at 8/12/16)
//! while QODA5 improves (165/127/115 ms). The model reproduces this regime
//! from first principles: ring collectives + per-hop latency + a
//! K-dependent straggler/incast term that full-fat fp32 payloads suffer and
//! sub-megabyte quantized payloads do not. These regime numbers are pinned
//! by unit tests below (`calibration` module).
//!
//! Two kinds of heterogeneity are modeled so the coordinator's pluggable
//! topologies (`crate::coordinator::topology`) can be charged realistically:
//!
//! * **Two link classes.** Cross-rack links run at `bandwidth_gbps` /
//!   `latency_us` (the 1–5 Gbps inter-node network of the paper's testbed);
//!   rack-local links run at `intra_rack_gbps` / `intra_rack_latency_us`
//!   (PCIe/NVLink-class, 50 Gbps by default — an order of magnitude
//!   faster). The flat collectives below only ever use the cross-rack
//!   class, so pre-topology behavior is unchanged; hierarchical topologies
//!   charge their rack-local phases against the fast class.
//! * **Injectable stragglers.** `with_straggler(node, slowdown)` multiplies
//!   the effective wire time of any phase that node's link participates in
//!   (a ring is bottlenecked by its slowest member). With no stragglers
//!   injected every formula reduces exactly to the homogeneous model.

use crate::stats::rng::Rng;

/// One phase class of a multi-phase exchange. The overlapped exchange
/// scheduler reasons about a charge phase-by-phase: rack-local phases ride
/// the fast intra-rack links, the cross-rack phase is the slow long-haul
/// exchange that dominates at scale.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PhaseKind {
    /// members push their packets up to the rack leader (point-to-point,
    /// intra-rack link class)
    RackLocalGather,
    /// the long-haul exchange over the cross-rack network (leaders-only
    /// ring, hub ingest/egress, or the whole flat collective)
    CrossRack,
    /// leaders multicast the result back down inside the rack
    RackLocalBroadcast,
}

/// A [`WireCharge`](crate::coordinator::topology::WireCharge) decomposed
/// into per-phase intervals, in wall-clock order. Each entry carries its
/// share of the fixed per-phase setup cost, so `total_s()` tracks the
/// charge's `comm_s` (up to float association — the synchronous `comm_s`
/// stays the golden-parity number; the timeline is the overlap scheduler's
/// view of the same exchange).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PhaseTimeline {
    pub phases: Vec<(PhaseKind, f64)>,
}

impl PhaseTimeline {
    /// A single-phase exchange (the flat collectives: one cross-rack ring).
    pub fn single(kind: PhaseKind, seconds: f64) -> Self {
        PhaseTimeline { phases: vec![(kind, seconds)] }
    }

    pub fn push(&mut self, kind: PhaseKind, seconds: f64) {
        self.phases.push((kind, seconds));
    }

    /// Sum of all phase intervals.
    pub fn total_s(&self) -> f64 {
        self.phases.iter().map(|&(_, s)| s).sum()
    }

    /// Total seconds spent in phases of `kind`.
    pub fn phase_s(&self, kind: PhaseKind) -> f64 {
        self.phases.iter().filter(|&&(k, _)| k == kind).map(|&(_, s)| s).sum()
    }
}

/// Collective used to exchange the per-node payloads.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Collective {
    /// NCCL-style ring allreduce over raw fp32 (reduces in-network):
    /// per-node traffic 2 (K-1)/K * bytes.
    RingAllReduce,
    /// Allgather of (differently-sized, entropy-coded) payloads: each node
    /// receives the other K-1 compressed messages: (K-1)/K * sum_bytes.
    RingAllGather,
}

/// End-to-end delay jitter (Verma et al., 1991) for the Remark D.3 protocol
/// study: each message independently "jitters" with probability `p`, which
/// forces a retransmission of `retrans_fraction` of the payload for codes
/// without per-symbol resynchronization (Main protocol), but only
/// `resync_fraction` for uniquely-decodable-per-symbol codebooks
/// (Alternating protocol).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct JitterModel {
    pub p: f64,
    pub retrans_fraction: f64,
    pub resync_fraction: f64,
}

impl JitterModel {
    pub fn none() -> Self {
        JitterModel { p: 0.0, retrans_fraction: 1.0, resync_fraction: 0.05 }
    }
}

#[derive(Clone, Debug)]
pub struct NetworkModel {
    /// cross-rack (inter-node) link bandwidth
    pub bandwidth_gbps: f64,
    /// one-hop cross-rack latency
    pub latency_us: f64,
    /// rack-local link bandwidth (PCIe/NVLink class)
    pub intra_rack_gbps: f64,
    /// one-hop rack-local latency
    pub intra_rack_latency_us: f64,
    /// incast/straggler coefficient: extra per-step milliseconds per node
    /// per megabyte of *per-node* payload (saturating switches; hits the
    /// fp32 baseline, negligible for compressed payloads). Only charged on
    /// cross-rack phases — rack-local links are point-to-point.
    pub straggler_ms_per_node_mb: f64,
    pub jitter: JitterModel,
    /// per-node link slowdown multipliers (1.0 = nominal); empty means a
    /// homogeneous cluster. A phase is slowed by the worst link it touches.
    pub link_slowdown: Vec<f64>,
}

impl NetworkModel {
    /// The paper's testbed: 5 Gbps, ~50 us inter-node latency, 50 Gbps
    /// PCIe-class rack-local links.
    pub fn genesis_cloud(bandwidth_gbps: f64) -> Self {
        NetworkModel {
            bandwidth_gbps,
            latency_us: 50.0,
            intra_rack_gbps: 50.0,
            intra_rack_latency_us: 5.0,
            straggler_ms_per_node_mb: 0.9,
            jitter: JitterModel::none(),
            link_slowdown: Vec::new(),
        }
    }

    /// Override the rack-local link class.
    pub fn with_intra_rack(mut self, gbps: f64, latency_us: f64) -> Self {
        self.intra_rack_gbps = gbps;
        self.intra_rack_latency_us = latency_us;
        self
    }

    /// Inject a straggler: `node`'s link runs `slowdown`x slower than
    /// nominal. Every phase that link participates in is bottlenecked by it.
    pub fn with_straggler(mut self, node: usize, slowdown: f64) -> Self {
        if self.link_slowdown.len() <= node {
            self.link_slowdown.resize(node + 1, 1.0);
        }
        self.link_slowdown[node] = slowdown;
        self
    }

    /// The slowdown multiplier of `node`'s link (1.0 when homogeneous).
    pub fn slowdown(&self, node: usize) -> f64 {
        self.link_slowdown.get(node).copied().unwrap_or(1.0)
    }

    /// Worst slowdown among the given participants — the bottleneck factor
    /// of any collective phase they form.
    pub fn max_slowdown_over(&self, nodes: impl IntoIterator<Item = usize>) -> f64 {
        nodes.into_iter().map(|n| self.slowdown(n)).fold(1.0, f64::max)
    }

    pub fn bytes_per_sec(&self) -> f64 {
        self.bandwidth_gbps * 1e9 / 8.0
    }

    pub fn intra_bytes_per_sec(&self) -> f64 {
        self.intra_rack_gbps * 1e9 / 8.0
    }

    /// Seconds to move `bytes` across one link (cross-rack or rack-local),
    /// including the one-hop latency and the sender's straggler factor.
    pub fn link_seconds(&self, bytes: f64, node: usize, intra_rack: bool) -> f64 {
        let (bw, lat_us) = if intra_rack {
            (self.intra_bytes_per_sec(), self.intra_rack_latency_us)
        } else {
            (self.bytes_per_sec(), self.latency_us)
        };
        bytes / bw * self.slowdown(node) + lat_us * 1e-6
    }

    /// Wall-clock seconds for one flat collective exchange over the
    /// cross-rack links. `per_node_bytes[k]` is node k's (possibly
    /// compressed) payload size; node indices are `0..k` for straggler
    /// lookup.
    pub fn collective_seconds(&self, kind: Collective, per_node_bytes: &[f64]) -> f64 {
        let k = per_node_bytes.len().max(1) as f64;
        let total: f64 = per_node_bytes.iter().sum();
        let max_b = per_node_bytes.iter().copied().fold(0.0, f64::max);
        let bw = self.bytes_per_sec();
        let lat = self.latency_us * 1e-6;
        // a ring moves at the pace of its slowest member link
        let slow = self.max_slowdown_over(0..per_node_bytes.len());
        let wire = match kind {
            Collective::RingAllReduce => {
                // 2(K-1)/K of the (uniform) payload, 2(K-1) latency hops
                2.0 * (k - 1.0) / k * max_b / bw + 2.0 * (k - 1.0) * lat
            }
            Collective::RingAllGather => {
                // every node forwards the K-1 foreign chunks: (K-1)/K of the
                // total traffic crosses each link, pipelined
                (k - 1.0) / k * total / bw + (k - 1.0) * lat
            }
        };
        // incast/straggler degradation grows with K and per-node payload
        let per_node_mb = max_b / 1e6;
        let straggler =
            self.straggler_ms_per_node_mb * 1e-3 * per_node_mb * (k - 1.0).max(0.0);
        wire * slow + straggler
    }

    /// Expected retransmission overhead multiplier for a payload under the
    /// jitter model (Remark D.3): Main pays `retrans_fraction` of the
    /// message again on a jitter event, Alternating only resynchronizes.
    pub fn jitter_multiplier(&self, main_protocol: bool) -> f64 {
        let j = self.jitter;
        let frac = if main_protocol { j.retrans_fraction } else { j.resync_fraction };
        1.0 + j.p * frac
    }

    /// Sampled (stochastic) step communication time with jitter events.
    pub fn sample_collective_seconds(
        &self,
        kind: Collective,
        per_node_bytes: &[f64],
        main_protocol: bool,
        rng: &mut Rng,
    ) -> f64 {
        let base = self.collective_seconds(kind, per_node_bytes);
        if self.jitter.p == 0.0 {
            return base;
        }
        let frac = if main_protocol {
            self.jitter.retrans_fraction
        } else {
            self.jitter.resync_fraction
        };
        let mut t = base;
        for _ in 0..per_node_bytes.len() {
            if rng.uniform() < self.jitter.p {
                t += base * frac / per_node_bytes.len() as f64;
            }
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn net(bw: f64) -> NetworkModel {
        NetworkModel {
            bandwidth_gbps: bw,
            latency_us: 50.0,
            intra_rack_gbps: 50.0,
            intra_rack_latency_us: 5.0,
            straggler_ms_per_node_mb: 0.0,
            jitter: JitterModel::none(),
            link_slowdown: Vec::new(),
        }
    }

    #[test]
    fn allreduce_bandwidth_math() {
        // 16 MB over 4 nodes at 5 Gbps: 2*(3/4)*16MB / 625MB/s = 38.4 ms
        let n = net(5.0);
        let t = n.collective_seconds(Collective::RingAllReduce, &[16e6; 4]);
        assert!((t - (2.0 * 0.75 * 16e6 / 625e6 + 6.0 * 50e-6)).abs() < 1e-9, "{t}");
    }

    #[test]
    fn compression_shrinks_time() {
        let n = net(5.0);
        let raw = n.collective_seconds(Collective::RingAllReduce, &[16e6; 4]);
        let comp = n.collective_seconds(Collective::RingAllGather, &[2.5e6; 4]);
        assert!(comp < raw / 2.0, "{comp} vs {raw}");
    }

    #[test]
    fn lower_bandwidth_hurts_more_with_big_payloads() {
        let hi = net(5.0);
        let lo = net(1.0);
        let big = [16e6; 4];
        let small = [0.5e6; 4];
        let d_big = lo.collective_seconds(Collective::RingAllReduce, &big)
            - hi.collective_seconds(Collective::RingAllReduce, &big);
        let d_small = lo.collective_seconds(Collective::RingAllGather, &small)
            - hi.collective_seconds(Collective::RingAllGather, &small);
        assert!(d_big > 10.0 * d_small, "{d_big} vs {d_small}");
    }

    #[test]
    fn straggler_term_grows_with_k() {
        let mut n = net(5.0);
        n.straggler_ms_per_node_mb = 1.0;
        let t4 = n.collective_seconds(Collective::RingAllReduce, &[16e6; 4]);
        let t16 = n.collective_seconds(Collective::RingAllReduce, &[16e6; 16]);
        // with a straggler term, scaling degrades despite ring traffic
        // converging to 2x payload
        assert!(t16 > t4, "{t16} vs {t4}");
    }

    #[test]
    fn injected_straggler_bottlenecks_the_ring() {
        let n = net(5.0);
        let base = n.collective_seconds(Collective::RingAllGather, &[1e6; 4]);
        let slowed =
            net(5.0).with_straggler(2, 3.0).collective_seconds(
                Collective::RingAllGather,
                &[1e6; 4],
            );
        assert!((slowed - 3.0 * base).abs() < 1e-12, "{slowed} vs 3x {base}");
        // a straggler outside the participant set does not slow the phase
        let outside = net(5.0).with_straggler(7, 3.0).collective_seconds(
            Collective::RingAllGather,
            &[1e6; 4],
        );
        assert_eq!(outside, base);
    }

    #[test]
    fn intra_rack_links_are_faster() {
        let n = net(5.0);
        let cross = n.link_seconds(1e6, 0, false);
        let intra = n.link_seconds(1e6, 0, true);
        assert!(intra < cross / 5.0, "{intra} vs {cross}");
        // straggler multiplier applies to either class
        let s = net(5.0).with_straggler(1, 2.0);
        assert!(s.link_seconds(1e6, 1, true) > 1.9 * n.link_seconds(1e6, 1, true));
        assert_eq!(s.link_seconds(1e6, 0, true), n.link_seconds(1e6, 0, true));
    }

    #[test]
    fn jitter_penalizes_main_protocol_more() {
        let mut n = net(5.0);
        n.jitter = JitterModel { p: 0.2, retrans_fraction: 1.0, resync_fraction: 0.05 };
        assert!(n.jitter_multiplier(true) > n.jitter_multiplier(false));
        let mut rng = Rng::new(1);
        let reps = 2000;
        let (mut tm, mut ta) = (0.0, 0.0);
        for _ in 0..reps {
            tm += n.sample_collective_seconds(
                Collective::RingAllGather,
                &[1e6; 4],
                true,
                &mut rng,
            );
            ta += n.sample_collective_seconds(
                Collective::RingAllGather,
                &[1e6; 4],
                false,
                &mut rng,
            );
        }
        assert!(tm > ta, "{tm} vs {ta}");
    }

    #[test]
    fn allgather_scales_with_total_bytes() {
        let n = net(5.0);
        let t1 = n.collective_seconds(Collective::RingAllGather, &[1e6; 4]);
        let t2 = n.collective_seconds(Collective::RingAllGather, &[2e6; 4]);
        assert!(t2 > 1.9 * t1 && t2 < 2.1 * t1);
    }
}

/// Pins the Table 1/2 regime documented in the module header: the model,
/// driven through the bench harness's calibrated compute/codec constants,
/// must keep reproducing the paper's step times and the weak-scaling
/// inversion. These tests are the contract future network-model changes are
/// measured against.
#[cfg(test)]
mod calibration {
    use super::{NetworkModel, PhaseKind};
    use crate::bench_harness::experiments::{
        measure_qoda5_bytes_per_coord, step_time_ms, table2_compute_window_s,
        PAYLOAD_BYTES,
    };
    use crate::coordinator::topology::{ExchangePlan, TopologySpec};
    use crate::stats::rng::Rng;

    #[test]
    fn table1_k4_step_times_pin() {
        let bpc = measure_qoda5_bytes_per_coord(1 << 16, 1);
        // K = 4, 5 Gbps: baseline ~251 ms vs QODA5 ~195 ms
        let b5 = step_time_ms(4, 5.0, false, bpc);
        let q5 = step_time_ms(4, 5.0, true, bpc);
        assert!((b5 - 251.0).abs() < 10.0, "baseline@5Gbps {b5} (want ~251)");
        assert!((q5 - 195.0).abs() < 17.0, "qoda5@5Gbps {q5} (want ~195)");
        assert!(b5 > q5 + 35.0, "{b5} vs {q5}");
        // K = 4, 1 Gbps: baseline degrades to ~291 ms, QODA5 barely moves
        let b1 = step_time_ms(4, 1.0, false, bpc);
        let q1 = step_time_ms(4, 1.0, true, bpc);
        assert!((b1 - 291.0).abs() < 10.0, "baseline@1Gbps {b1} (want ~291)");
        assert!(q1 - q5 < 25.0, "qoda5 should be near-flat: {q5} -> {q1}");
    }

    #[test]
    fn table2_weak_scaling_inversion_pin() {
        let bpc = measure_qoda5_bytes_per_coord(1 << 16, 1);
        let b: Vec<f64> =
            [4, 8, 12, 16].iter().map(|&k| step_time_ms(k, 5.0, false, bpc)).collect();
        let q: Vec<f64> =
            [4, 8, 12, 16].iter().map(|&k| step_time_ms(k, 5.0, true, bpc)).collect();
        // the inversion: the baseline *degrades* monotonically with K while
        // QODA5 *improves* monotonically (the paper's 303/318 regime at
        // K = 8/12 vs 165/127)
        for i in 1..4 {
            assert!(b[i] > b[i - 1], "baseline must degrade: {b:?}");
            assert!(q[i] < q[i - 1], "qoda5 must improve: {q:?}");
        }
        assert!((b[2] - 318.0).abs() < 15.0, "baseline@12 {} (want ~318)", b[2]);
        // the headline end-to-end speedup at K = 12 (paper: ~2.5x)
        let s12 = b[2] / q[2];
        assert!(s12 > 2.0, "12-node speedup {s12}");
        // and it keeps widening under weak scaling
        assert!(b[3] / q[3] > b[1] / q[1], "{b:?} / {q:?}");
    }

    /// Pins the overlap regime at the Table 1/2 weak-scaling point (K = 12,
    /// heterogeneous links): the compute window dwarfs the quantized
    /// hierarchical exchange, so overlapping hides the whole timeline — in
    /// particular both rack-local phases — and exposes nothing.
    #[test]
    fn overlap_hides_at_least_the_rack_local_phases_at_k12() {
        let bpc = measure_qoda5_bytes_per_coord(1 << 16, 1);
        let k = 12usize;
        let coords = (PAYLOAD_BYTES / 4.0) as usize;
        let bits = vec![(coords as f64 * bpc * 8.0) as u64; k];
        let spec = TopologySpec::hierarchical_for(k);
        let net = NetworkModel::genesis_cloud(5.0);
        let mut rng = Rng::new(2);
        let (charge, tl) =
            spec.build().charge_timeline(&bits, coords, &net, false, true, &mut rng);
        // the Table 2 compute window at K = 12
        let window_s = table2_compute_window_s(k);
        assert!(charge.comm_s < window_s, "{} vs {window_s}", charge.comm_s);
        let (exposed, hidden) = ExchangePlan::overlapped(1, window_s).split(charge.comm_s);
        assert_eq!(exposed, 0.0, "the whole exchange hides behind compute");
        let rack_local = tl.phase_s(PhaseKind::RackLocalGather)
            + tl.phase_s(PhaseKind::RackLocalBroadcast);
        assert!(rack_local > 0.0);
        assert!(hidden >= rack_local, "{hidden} vs rack-local {rack_local}");
        // ... and the cross-rack phase too (it dominates the timeline)
        assert!(hidden >= tl.phase_s(PhaseKind::CrossRack));
    }

    /// A straggler re-exposes exactly the phases its link touches. On ideal
    /// (infinitely fast, zero-latency) rack-local links with a compute
    /// window sized to the clean exchange: a straggling rack *member*
    /// re-exposes nothing — its link only carries rack-local phases — while
    /// a straggling rack *leader* re-exposes exactly the cross-rack phase's
    /// inflation.
    #[test]
    fn leader_straggler_reexposes_exactly_the_cross_rack_phase() {
        let bpc = measure_qoda5_bytes_per_coord(1 << 16, 1);
        let k = 12usize;
        let coords = (PAYLOAD_BYTES / 4.0) as usize;
        let bits = vec![(coords as f64 * bpc * 8.0) as u64; k];
        // K/4 = 3 racks of 4: leaders are nodes 0, 4, 8
        let spec = TopologySpec::hierarchical_for(k);
        let ideal = |slow: Option<(usize, f64)>| {
            let mut net = NetworkModel::genesis_cloud(5.0)
                .with_intra_rack(f64::INFINITY, 0.0);
            if let Some((node, factor)) = slow {
                net = net.with_straggler(node, factor);
            }
            let mut rng = Rng::new(2);
            spec.build().charge_timeline(&bits, coords, &net, false, true, &mut rng)
        };
        let (clean, tl_clean) = ideal(None);
        // compute window exactly covers the clean exchange: fully hidden
        let plan = ExchangePlan::overlapped(1, clean.comm_s);
        assert_eq!(plan.split(clean.comm_s).0, 0.0);

        // a 4x straggler on node 5 — a member of rack 1, not a leader —
        // only touches the (free) rack-local phases: nothing re-exposes
        let (member, _) = ideal(Some((5, 4.0)));
        assert_eq!(member.comm_s, clean.comm_s, "member straggler is invisible");
        assert_eq!(plan.split(member.comm_s).0, 0.0);

        // a 4x straggler on node 4 — the rack-1 leader — inflates the
        // cross-rack phase, and exactly that inflation re-exposes
        let (slow, tl_slow) = ideal(Some((4, 4.0)));
        let (exposed, _) = plan.split(slow.comm_s);
        assert!(exposed > 0.0);
        let d_cross = tl_slow.phase_s(PhaseKind::CrossRack)
            - tl_clean.phase_s(PhaseKind::CrossRack);
        assert!(d_cross > 0.0);
        assert!(
            (exposed - d_cross).abs() < 1e-9 * slow.comm_s.max(1e-9),
            "exposed {exposed} vs cross-rack inflation {d_cross}"
        );
        // no other phase moved: the whole slowdown is the cross-rack phase
        assert_eq!(
            tl_slow.phase_s(PhaseKind::RackLocalGather),
            tl_clean.phase_s(PhaseKind::RackLocalGather)
        );
        assert_eq!(
            tl_slow.phase_s(PhaseKind::RackLocalBroadcast),
            tl_clean.phase_s(PhaseKind::RackLocalBroadcast)
        );
    }
}

//! Non-VI baselines for the GAN experiments (Figure 4): Adam on the game's
//! gradient field, and the optimistic-Adam variant that the paper's
//! "QODA-based extension of Adam" corresponds to (optimistic extrapolation
//! with Adam preconditioning of the averaged dual direction, as in
//! Daskalakis et al., 2018).

use super::source::DualSource;
use crate::comm::{CommEndpoint, Compressor};

/// Adam moment state over a flat vector.
pub struct AdamState {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    m: Vec<f64>,
    v: Vec<f64>,
    t: u64,
}

impl AdamState {
    pub fn new(dim: usize, lr: f64) -> Self {
        AdamState {
            lr,
            beta1: 0.5, // the WGAN-recipe betas (Gidel et al. codebase)
            beta2: 0.9,
            eps: 1e-8,
            m: vec![0.0; dim],
            v: vec![0.0; dim],
            t: 0,
        }
    }

    /// Preconditioned update direction for gradient g (call once per step).
    pub fn direction(&mut self, g: &[f64]) -> Vec<f64> {
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        let mut out = vec![0.0; g.len()];
        for i in 0..g.len() {
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * g[i];
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * g[i] * g[i];
            let mh = self.m[i] / b1t;
            let vh = self.v[i] / b2t;
            out[i] = self.lr * mh / (vh.sqrt() + self.eps);
        }
        out
    }
}

/// Plain (simultaneous) Adam descent on the operator: the Figure 4 "Adam"
/// baseline. Returns the iterate trajectory bits like the VI solvers.
pub struct AdamSolver<'s> {
    pub source: &'s mut dyn DualSource,
    pub endpoints: Vec<CommEndpoint>,
    pub adam: AdamState,
    /// optimistic extrapolation on/off (the QODA-extension toggle)
    pub optimistic: bool,
    pub total_bits: u64,
    /// decoded-dual scratch
    hat: Vec<f64>,
}

impl<'s> AdamSolver<'s> {
    pub fn new(
        source: &'s mut dyn DualSource,
        compressors: Vec<Box<dyn Compressor>>,
        lr: f64,
        optimistic: bool,
    ) -> Self {
        let dim = source.dim();
        assert_eq!(compressors.len(), source.num_nodes());
        AdamSolver {
            source,
            endpoints: compressors.into_iter().map(CommEndpoint::new).collect(),
            adam: AdamState::new(dim, lr),
            optimistic,
            total_bits: 0,
            hat: Vec::new(),
        }
    }

    /// One optimizer step in place; returns the mean compressed dual used.
    pub fn step(&mut self, x: &mut [f64], prev_dir: &mut Vec<f64>) -> Vec<f64> {
        let k = self.source.num_nodes();
        let kf = k as f64;
        let d = x.len();
        // optimistic lookahead using the previous direction
        let query: Vec<f64> = if self.optimistic {
            x.iter().zip(prev_dir.iter()).map(|(xi, p)| xi - p).collect()
        } else {
            x.to_vec()
        };
        let duals = self.source.duals(&query);
        let mut mean = vec![0.0; d];
        for (kk, dual) in duals.iter().enumerate() {
            let bits = self.endpoints[kk]
                .roundtrip_into(dual, &mut self.hat)
                .expect("comm loopback roundtrip");
            self.total_bits += bits as u64;
            for (m, v) in mean.iter_mut().zip(&self.hat) {
                *m += v / kf;
            }
        }
        let dir = self.adam.direction(&mean);
        for (xi, di) in x.iter_mut().zip(&dir) {
            *xi -= di;
        }
        *prev_dir = dir;
        mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oda::compress::{Compressor, IdentityCompressor};
    use crate::oda::source::OracleSource;
    use crate::stats::rng::Rng;
    use crate::stats::vecops::{l2_norm64, sub};
    use crate::vi::noise::NoiseModel;
    use crate::vi::operator::QuadraticOperator;

    fn identity_boxes(k: usize) -> Vec<Box<dyn Compressor>> {
        (0..k).map(|_| Box::new(IdentityCompressor) as Box<dyn Compressor>).collect()
    }

    #[test]
    fn adam_minimizes_quadratic() {
        let mut rng = Rng::new(1);
        let op = QuadraticOperator::random(8, 0.5, &mut rng);
        let mut src = OracleSource::new(&op, 2, NoiseModel::Absolute { sigma: 0.1 }, 2);
        let mut solver = AdamSolver::new(&mut src, identity_boxes(2), 0.05, false);
        let mut x = vec![0.0; 8];
        let mut prev = vec![0.0; 8];
        for _ in 0..600 {
            solver.step(&mut x, &mut prev);
        }
        let err = l2_norm64(&sub(&x, &op.sol));
        assert!(err < 0.3 * l2_norm64(&op.sol), "{err}");
    }

    #[test]
    fn optimistic_variant_also_converges() {
        let mut rng = Rng::new(3);
        let op = QuadraticOperator::random(8, 0.5, &mut rng);
        let mut src = OracleSource::new(&op, 2, NoiseModel::None, 4);
        let mut solver = AdamSolver::new(&mut src, identity_boxes(2), 0.05, true);
        let mut x = vec![0.0; 8];
        let mut prev = vec![0.0; 8];
        for _ in 0..600 {
            solver.step(&mut x, &mut prev);
        }
        let err = l2_norm64(&sub(&x, &op.sol));
        assert!(err < 0.3 * l2_norm64(&op.sol), "{err}");
    }

    #[test]
    fn adam_state_direction_bounded_by_lr() {
        let mut a = AdamState::new(4, 0.01);
        let dir = a.direction(&[1000.0, -1000.0, 0.0, 1.0]);
        for d in &dir {
            assert!(d.abs() <= 0.011, "{d}"); // |dir| ~ lr after bias correction
        }
    }
}

//! Non-VI baselines for the GAN experiments (Figure 4): Adam on the game's
//! gradient field, and the optimistic-Adam variant that the paper's
//! "QODA-based extension of Adam" corresponds to (optimistic extrapolation
//! with Adam preconditioning of the averaged dual direction, as in
//! Daskalakis et al., 2018). Both are step-wise [`Solver`]s driven by the
//! same [`super::driver::RunDriver`] as the VI solvers.

use super::driver::{exchange_mean, Solver, SolverState, StepStats};
use super::source::DualSource;
use crate::comm::{CommEndpoint, Compressor};

/// Adam moment state over a flat vector.
pub struct AdamState {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    m: Vec<f64>,
    v: Vec<f64>,
    t: u64,
}

impl AdamState {
    pub fn new(dim: usize, lr: f64) -> Self {
        AdamState {
            lr,
            beta1: 0.5, // the WGAN-recipe betas (Gidel et al. codebase)
            beta2: 0.9,
            eps: 1e-8,
            m: vec![0.0; dim],
            v: vec![0.0; dim],
            t: 0,
        }
    }

    /// Zero the moment estimates and the step counter (a fresh run).
    pub fn reset(&mut self) {
        self.m.fill(0.0);
        self.v.fill(0.0);
        self.t = 0;
    }

    /// Preconditioned update direction for gradient g (call once per step).
    pub fn direction(&mut self, g: &[f64]) -> Vec<f64> {
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        let mut out = vec![0.0; g.len()];
        for i in 0..g.len() {
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * g[i];
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * g[i] * g[i];
            let mh = self.m[i] / b1t;
            let vh = self.v[i] / b2t;
            out[i] = self.lr * mh / (vh.sqrt() + self.eps);
        }
        out
    }
}

/// Plain (simultaneous) Adam descent on the operator: the Figure 4 "Adam"
/// baseline. With `optimistic` set, queries the oracle at the lookahead
/// point `X_t - dir_{t-1}` instead (the QODA-extension toggle) — prefer
/// constructing that variant as [`OptimisticAdam`].
pub struct AdamSolver<'s> {
    pub source: &'s mut dyn DualSource,
    pub endpoints: Vec<CommEndpoint>,
    pub adam: AdamState,
    /// optimistic extrapolation on/off
    pub optimistic: bool,
    // —— step-wise run state, established by `init` ——
    x: Vec<f64>,
    prev_dir: Vec<f64>,
    query: Vec<f64>,
    mean: Vec<f64>,
    /// decoded-dual scratch
    hat: Vec<f64>,
}

impl<'s> AdamSolver<'s> {
    pub fn new(
        source: &'s mut dyn DualSource,
        compressors: Vec<Box<dyn Compressor>>,
        lr: f64,
    ) -> Self {
        let dim = source.dim();
        assert_eq!(compressors.len(), source.num_nodes());
        AdamSolver {
            source,
            endpoints: compressors.into_iter().map(CommEndpoint::new).collect(),
            adam: AdamState::new(dim, lr),
            optimistic: false,
            x: Vec::new(),
            prev_dir: Vec::new(),
            query: Vec::new(),
            mean: Vec::new(),
            hat: Vec::new(),
        }
    }
}

impl Solver for AdamSolver<'_> {
    fn name(&self) -> &'static str {
        if self.optimistic {
            "optimistic-adam"
        } else {
            "adam"
        }
    }

    fn dim(&self) -> usize {
        self.source.dim()
    }

    fn num_nodes(&self) -> usize {
        self.source.num_nodes()
    }

    fn init(&mut self, x0: &[f64]) {
        let d = self.source.dim();
        assert_eq!(x0.len(), d);
        self.x = x0.to_vec();
        self.prev_dir = vec![0.0; d];
        self.query = vec![0.0; d];
        self.mean = vec![0.0; d];
        self.adam.reset();
    }

    fn step(&mut self, _t: usize) -> StepStats {
        // optimistic lookahead using the previous direction
        self.query.clear();
        if self.optimistic {
            self.query
                .extend(self.x.iter().zip(&self.prev_dir).map(|(xi, p)| xi - p));
        } else {
            self.query.extend_from_slice(&self.x);
        }
        let duals = self.source.duals(&self.query);
        let mut stats = StepStats::default();
        exchange_mean(
            &mut self.endpoints,
            &duals,
            &mut self.hat,
            &mut self.mean,
            &mut stats,
        );
        let dir = self.adam.direction(&self.mean);
        for (xi, di) in self.x.iter_mut().zip(&dir) {
            *xi -= di;
        }
        self.prev_dir = dir;
        stats
    }

    fn state(&self) -> SolverState<'_> {
        // no half-step: the ergodic average runs over the iterates
        SolverState { x: &self.x, avg_point: &self.x }
    }

    fn oracle_calls(&self) -> u64 {
        self.source.calls()
    }
}

/// The optimistic-Adam variant as its own solver type (Figure 4's
/// "QODA-based extension of Adam").
pub struct OptimisticAdam<'s> {
    pub inner: AdamSolver<'s>,
}

impl<'s> OptimisticAdam<'s> {
    pub fn new(
        source: &'s mut dyn DualSource,
        compressors: Vec<Box<dyn Compressor>>,
        lr: f64,
    ) -> Self {
        let mut inner = AdamSolver::new(source, compressors, lr);
        inner.optimistic = true;
        OptimisticAdam { inner }
    }
}

impl Solver for OptimisticAdam<'_> {
    fn name(&self) -> &'static str {
        "optimistic-adam"
    }

    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn num_nodes(&self) -> usize {
        self.inner.num_nodes()
    }

    fn init(&mut self, x0: &[f64]) {
        self.inner.init(x0);
    }

    fn step(&mut self, t: usize) -> StepStats {
        self.inner.step(t)
    }

    fn state(&self) -> SolverState<'_> {
        self.inner.state()
    }

    fn oracle_calls(&self) -> u64 {
        self.inner.oracle_calls()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::IdentityCompressor;
    use crate::oda::driver::RunDriver;
    use crate::oda::source::OracleSource;
    use crate::stats::rng::Rng;
    use crate::stats::vecops::{l2_norm64, sub};
    use crate::vi::noise::NoiseModel;
    use crate::vi::operator::QuadraticOperator;

    fn identity_boxes(k: usize) -> Vec<Box<dyn Compressor>> {
        (0..k).map(|_| Box::new(IdentityCompressor::new()) as Box<dyn Compressor>).collect()
    }

    #[test]
    fn adam_minimizes_quadratic() {
        let mut rng = Rng::new(1);
        let op = QuadraticOperator::random(8, 0.5, &mut rng);
        let mut src = OracleSource::new(&op, 2, NoiseModel::Absolute { sigma: 0.1 }, 2);
        let mut solver = AdamSolver::new(&mut src, identity_boxes(2), 0.05);
        let run = RunDriver::new().run(&mut solver, &vec![0.0; 8], 600);
        let err = l2_norm64(&sub(&run.x_last, &op.sol));
        assert!(err < 0.3 * l2_norm64(&op.sol), "{err}");
    }

    #[test]
    fn optimistic_variant_also_converges() {
        let mut rng = Rng::new(3);
        let op = QuadraticOperator::random(8, 0.5, &mut rng);
        let mut src = OracleSource::new(&op, 2, NoiseModel::None, 4);
        let mut solver = OptimisticAdam::new(&mut src, identity_boxes(2), 0.05);
        assert_eq!(solver.name(), "optimistic-adam");
        let run = RunDriver::new().run(&mut solver, &vec![0.0; 8], 600);
        let err = l2_norm64(&sub(&run.x_last, &op.sol));
        assert!(err < 0.3 * l2_norm64(&op.sol), "{err}");
    }

    #[test]
    fn init_resets_the_moments() {
        // two driven runs from the same solver object are identical: init
        // must clear the Adam moment state between them
        let mut rng = Rng::new(5);
        let op = QuadraticOperator::random(6, 0.5, &mut rng);
        let mut src = OracleSource::new(&op, 1, NoiseModel::None, 6);
        let mut solver = AdamSolver::new(&mut src, identity_boxes(1), 0.05);
        let a = RunDriver::new().run(&mut solver, &vec![0.0; 6], 100);
        let b = RunDriver::new().run(&mut solver, &vec![0.0; 6], 100);
        assert_eq!(a.x_last, b.x_last);
        // the driver baselines the cumulative oracle counter per run
        assert_eq!(a.oracle_calls, 100);
        assert_eq!(b.oracle_calls, 100);
    }

    #[test]
    fn adam_state_direction_bounded_by_lr() {
        let mut a = AdamState::new(4, 0.01);
        let dir = a.direction(&[1000.0, -1000.0, 0.0, 1.0]);
        for d in &dir {
            assert!(d.abs() <= 0.011, "{d}"); // |dir| ~ lr after bias correction
        }
    }
}

//! Compression pipeline used inside the solvers: quantize -> entropy-code ->
//! (wire) -> decode -> dequantize, with exact bit accounting and the
//! L-GreCo-style adaptive re-optimization of levels at update steps
//! (Algorithm 1, lines 2–7).

use crate::coding::protocol::{
    decode_vector, encode_vector, Codebooks, ProtocolKind,
};
use crate::quant::adaptive::TypeStats;
use crate::quant::layer_map::LayerMap;
use crate::quant::lgreco;
use crate::quant::quantizer::{dequantize, quantize};
use crate::quant::{LevelSequence, QuantConfig};
use crate::stats::rng::Rng;

/// What a node applies to its dual vector before "broadcasting".
pub trait Compressor: Send {
    /// Returns the decoded (receiver-side) vector and the wire size in bits.
    fn compress(&mut self, v: &[f64]) -> (Vec<f64>, usize);

    /// Hook for Algorithm 1's update steps (t in U): re-estimate level
    /// sequences / codebooks from the statistics gathered since the last
    /// update. Default: no-op.
    fn update_levels(&mut self) {}

    fn name(&self) -> &'static str;
}

/// No compression: f32 on the wire (the uncompressed baseline).
pub struct IdentityCompressor;

impl Compressor for IdentityCompressor {
    fn compress(&mut self, v: &[f64]) -> (Vec<f64>, usize) {
        (v.to_vec(), v.len() * 32)
    }

    fn name(&self) -> &'static str {
        "uncompressed"
    }
}

/// Adaptation policy of the quantized compressor.
#[derive(Clone, Debug, PartialEq)]
pub enum Adaptation {
    /// fixed sequences forever (Q-GenX-style static global quantization)
    Fixed,
    /// re-optimize each type's levels at its current alpha (Eq. 2 fixed
    /// point) every `every` compressions
    Levels { every: usize },
    /// full L-GreCo: re-allocate per-type alphas under a total bit budget
    /// (bits/coordinate) *and* re-optimize levels every `every` compressions
    LGreco { every: usize, budget_bits_per_coord: f64, max_bits: u32 },
}

/// Quantize + entropy-code compressor (the paper's scheme).
pub struct QuantCompressor {
    pub map: LayerMap,
    pub cfg: QuantConfig,
    pub protocol: ProtocolKind,
    pub adaptation: Adaptation,
    books: Codebooks,
    stats: Vec<TypeStats>,
    rng: Rng,
    calls: usize,
    /// running totals for reporting
    pub total_bits: u64,
    pub total_coords: u64,
    /// eps_Q of the *current* configuration (refreshed on update)
    pub current_eps_q: f64,
}

impl QuantCompressor {
    pub fn new(
        map: LayerMap,
        cfg: QuantConfig,
        protocol: ProtocolKind,
        adaptation: Adaptation,
        seed: u64,
    ) -> Self {
        let books = Codebooks::uniform(protocol, &cfg, &map.type_proportions());
        let stats = (0..map.num_types()).map(|_| TypeStats::default()).collect();
        let eps = crate::quant::variance::eps_q_for(&map, &cfg);
        QuantCompressor {
            map,
            cfg,
            protocol,
            adaptation,
            books,
            stats,
            rng: Rng::new(seed),
            calls: 0,
            total_bits: 0,
            total_coords: 0,
            current_eps_q: eps,
        }
    }

    /// Convenience: b-bit global quantization with bucketing (the paper's
    /// "QODA5 (bucket size 128)" configuration collapses types).
    pub fn global_bits(map: &LayerMap, bits: u32, bucket: usize, seed: u64) -> Self {
        let m = map.bucketed(bucket).with_single_type();
        let cfg = QuantConfig::uniform_bits(1, bits, 2.0);
        Self::new(m, cfg, ProtocolKind::Main, Adaptation::Fixed, seed)
    }

    /// Layer-wise adaptive compressor: per-type sequences starting at
    /// `bits`, L-GreCo reallocation every `every` steps at the same average
    /// bit budget.
    pub fn layerwise(map: &LayerMap, bits: u32, bucket: usize, every: usize, seed: u64) -> Self {
        let m = map.bucketed(bucket);
        let cfg = QuantConfig::uniform_bits(m.num_types(), bits, 2.0);
        Self::new(
            m,
            cfg,
            ProtocolKind::Main,
            Adaptation::LGreco {
                every,
                budget_bits_per_coord: (bits + 1) as f64,
                // candidates above 6 bits are never selected at a ~6-bit
                // budget but dominate the DP's level-optimization cost
                // (alpha = 254); capping is a pure perf win (§Perf iter 5)
                max_bits: 6,
            },
            seed,
        )
    }

    fn gather_stats(&mut self, v32: &[f32]) {
        for l in &self.map.layers {
            self.stats[l.type_id]
                .add_layer_sample(&v32[l.offset..l.offset + l.len], self.cfg.q);
        }
    }

    fn refresh_codebooks(&mut self) {
        let probs: Vec<Vec<f64>> = self
            .cfg
            .sequences
            .iter()
            .enumerate()
            .map(|(m, seq)| {
                crate::coding::length::level_probabilities(&self.stats[m].hist, seq)
            })
            .collect();
        self.books = Codebooks::build(self.protocol, &probs, &self.map.type_proportions());
    }
}

impl Compressor for QuantCompressor {
    fn compress(&mut self, v: &[f64]) -> (Vec<f64>, usize) {
        let v32: Vec<f32> = v.iter().map(|&x| x as f32).collect();
        self.gather_stats(&v32);
        let qv = quantize(&v32, &self.map, &self.cfg, &mut self.rng);
        let buf = encode_vector(&qv, &self.books);
        let bits = buf.len_bits();
        // receiver path (exactness asserted in tests; skip re-decode cost on
        // the stats: decode is what the *other* nodes do)
        let back = decode_vector(&buf, &self.map, &self.books);
        let out32 = dequantize(&back, &self.cfg);
        self.total_bits += bits as u64;
        self.total_coords += v.len() as u64;
        self.calls += 1;

        let every = match self.adaptation {
            Adaptation::Levels { every } | Adaptation::LGreco { every, .. } => every,
            Adaptation::Fixed => 0,
        };
        if every > 0 && self.calls % every == 0 {
            self.update_levels();
        }
        (out32.iter().map(|&x| x as f64).collect(), bits)
    }

    fn update_levels(&mut self) {
        match self.adaptation {
            Adaptation::Fixed => {}
            Adaptation::Levels { .. } => {
                let alphas: Vec<usize> =
                    self.cfg.sequences.iter().map(|s| s.alpha()).collect();
                let (seqs, _) = crate::quant::adaptive::adapt_all(&self.stats, &alphas, 6);
                self.cfg.sequences = seqs;
            }
            Adaptation::LGreco { budget_bits_per_coord, max_bits, .. } => {
                // error curves per *type* (types share statistics), sizes
                // aggregated over layers of that type
                let ladder = lgreco::alpha_ladder(max_bits);
                let problems: Vec<lgreco::LayerProblem> = (0..self.map.num_types())
                    .map(|m| {
                        let size: usize =
                            self.map.layers_of_type(m).map(|l| l.len).sum();
                        lgreco::LayerProblem {
                            size: size.max(1),
                            candidates: lgreco::error_curve(&self.stats[m].hist, &ladder, 4),
                        }
                    })
                    .collect();
                let budget = budget_bits_per_coord * self.map.dim as f64;
                let alloc = lgreco::allocate(&problems, budget);
                // adopt the chosen alphas with optimized levels
                let alphas: Vec<usize> = alloc
                    .choice
                    .iter()
                    .map(|&c| ladder[c.min(ladder.len() - 1)])
                    .collect();
                let (seqs, _) = crate::quant::adaptive::adapt_all(&self.stats, &alphas, 6);
                self.cfg.sequences = seqs;
            }
        }
        self.refresh_codebooks();
        self.current_eps_q = crate::quant::variance::eps_q_for(&self.map, &self.cfg);
        for s in &mut self.stats {
            s.reset();
        }
    }

    fn name(&self) -> &'static str {
        match self.adaptation {
            Adaptation::Fixed => "quantized-global",
            Adaptation::Levels { .. } => "quantized-adaptive",
            Adaptation::LGreco { .. } => "quantized-lgreco",
        }
    }
}

/// Build a default level sequence set for an adaptive start.
pub fn default_sequences(num_types: usize, bits: u32) -> Vec<LevelSequence> {
    (0..num_types).map(|_| LevelSequence::bits(bits)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grad_like(map: &LayerMap, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..map.dim)
            .map(|i| rng.gaussian() * if i % 3 == 0 { 2.0 } else { 0.05 })
            .collect()
    }

    #[test]
    fn identity_costs_32_bits_per_coord() {
        let mut c = IdentityCompressor;
        let (out, bits) = c.compress(&[1.0, 2.0, 3.0]);
        assert_eq!(out, vec![1.0, 2.0, 3.0]);
        assert_eq!(bits, 96);
    }

    #[test]
    fn quantized_reduces_bits() {
        let map = LayerMap::from_spec(&[("a", 1000, "ff"), ("b", 500, "bias")]);
        let mut c = QuantCompressor::global_bits(&map, 5, 128, 1);
        let v = grad_like(&map, 2);
        let (out, bits) = c.compress(&v);
        assert_eq!(out.len(), v.len());
        assert!(bits < 1500 * 32, "{bits}");
        assert!(bits > 0);
    }

    #[test]
    fn compression_error_bounded_by_eps() {
        let map = LayerMap::from_spec(&[("a", 512, "ff")]);
        let mut c = QuantCompressor::global_bits(&map, 5, 128, 3);
        let v = grad_like(&map, 4);
        let norm2: f64 = v.iter().map(|x| x * x).sum();
        let mut err_acc = 0.0;
        let reps = 30;
        for _ in 0..reps {
            let (out, _) = c.compress(&v);
            err_acc += v.iter().zip(&out).map(|(a, b)| (a - b).powi(2)).sum::<f64>();
        }
        let ratio = err_acc / reps as f64 / norm2;
        assert!(ratio <= c.current_eps_q * 1.1, "{ratio} vs {}", c.current_eps_q);
    }

    #[test]
    fn adaptation_reduces_bits_or_error() {
        let map = LayerMap::from_spec(&[("a", 2048, "ff"), ("e", 512, "embedding")]);
        let mut c = QuantCompressor::layerwise(&map, 5, 1 << 30, 10, 5);
        let mut bits_before = 0usize;
        let mut bits_after = 0usize;
        for i in 0..30 {
            let v = grad_like(&map, 100 + i);
            let (_, b) = c.compress(&v);
            if i < 10 {
                bits_before += b;
            }
            if i >= 20 {
                bits_after += b;
            }
        }
        // after two L-GreCo updates the entropy coder + level placement must
        // not be worse than the cold-start uniform configuration
        assert!(
            bits_after as f64 <= bits_before as f64 * 1.05,
            "{bits_after} vs {bits_before}"
        );
    }

    #[test]
    fn update_levels_keeps_roundtrip_consistent() {
        let map = LayerMap::from_spec(&[("a", 300, "ff")]);
        let mut c = QuantCompressor::new(
            map.clone(),
            QuantConfig::uniform_bits(1, 4, 2.0),
            ProtocolKind::Alternating,
            Adaptation::Levels { every: 3 },
            7,
        );
        for i in 0..12 {
            let v = grad_like(&map, 50 + i);
            let (out, _) = c.compress(&v);
            // unbiased-ish: reconstruction correlates positively
            let dot: f64 = v.iter().zip(&out).map(|(a, b)| a * b).sum();
            assert!(dot > 0.0);
        }
    }
}

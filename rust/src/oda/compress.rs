//! Back-compat shim: the compression pipeline moved to [`crate::comm`],
//! where it is shared (as real wire packets) by both coordinator engines.
//! Import from `crate::comm` in new code.

pub use crate::comm::{
    default_sequences, Adaptation, CommEndpoint, CommError, Compressor, IdentityCompressor,
    QuantCompressor, WirePacket,
};

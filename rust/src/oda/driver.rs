//! The step-wise solver API and the one outer loop every run goes through.
//!
//! Historically each solver (`Qoda`, `QGenX`, the Adam baselines) owned a
//! private monolithic `run()` with copy-pasted checkpointing, ergodic
//! averaging, bits accounting and scratch management. This module splits
//! that into:
//!
//! * [`Solver`] — a resumable state machine: `init` establishes the run
//!   state from `X_1 = x0`, `step` advances exactly one iteration and
//!   returns its wire/fidelity accounting ([`StepStats`]), `state` exposes
//!   the current iterate and the point entering the ergodic average;
//! * [`RunDriver`] — the shared outer loop: checkpoint scheduling
//!   (sorted + deduped + clamped, never silently dropped), ergodic
//!   averaging, wire-bit and oracle-call accounting, optional restricted-gap
//!   evaluation with early stopping ([`GapPolicy`]), and streaming
//!   per-step records to pluggable [`MetricsSink`] observers;
//! * [`RunSpec`] — the declarative builder
//!   (operator / noise / nodes / compression / lr / protocol / steps) that
//!   is the one way oracle-backed runs are constructed by the CLI, the
//!   bench harness and the examples.
//!
//! Because solvers are stepped externally, scenarios the monolithic loops
//! forbade become plain library code: mid-run compressor-adaptation audits,
//! interleaved solver races under a shared wire budget
//! (`examples/solver_race.rs`), or driving a solver over a coordinator
//! transport.
//!
//! The optional [`NetClock`] charges every step's wire bits on a simulated
//! network under an [`ExchangePlan`]: synchronous exchanges expose the full
//! `comm_s`; overlapped exchanges model the engines' one-step-stale double
//! buffer and split each step's charge into `comm_exposed_s` (outlives the
//! compute window) vs `comm_hidden_s` (overlapped behind the next step's
//! compute) — see the [`NetClock`] docs for the exact staleness semantics.

use super::baseline::{AdamSolver, OptimisticAdam};
use super::lr::{AdaptiveLr, AltLr, ConstantLr, LrSchedule};
use super::qgenx::QGenX;
use super::qoda::Qoda;
use super::source::OracleSource;
use crate::coding::protocol::ProtocolKind;
use crate::comm::{
    Adaptation, CommEndpoint, CommError, Compressor, FeedbackCompressor, IdentityCompressor,
    QuantCompressor,
};
use crate::coordinator::parallel::SharedQuantState;
use crate::coordinator::topology::{
    ExchangeMode, ExchangePlan, TopologySpec, Transport, WireCharge,
};
use crate::net::NetworkModel;
use crate::quant::layer_map::LayerMap;
use crate::quant::QuantConfig;
use crate::stats::rng::Rng;
use crate::stats::vecops::{l2_norm64, sub};
use crate::vi::gap::GapEvaluator;
use crate::vi::noise::NoiseModel;
use crate::vi::operator::{BilinearGame, Operator, QuadraticOperator};
use crate::wire::{run_wire_observed, WireCodecSpec, WireOptions, WireReport, Workload};

// ---------------------------------------------------------------------------
// The step-wise solver contract
// ---------------------------------------------------------------------------

/// Per-step accounting returned by [`Solver::step`].
#[derive(Clone, Copy, Debug, Default)]
pub struct StepStats {
    /// actual wire bits charged across all nodes this step
    pub bits: u64,
    /// sum over nodes of ||V - V̂||² — the quantization error injected on
    /// the wire this step
    pub quant_err_sq: f64,
    /// sum over nodes of ||V||² — the dual energy this step
    pub dual_norm_sq: f64,
}

/// Read-only view of a solver's state after a `step`.
pub struct SolverState<'a> {
    /// the full iterate X_{t+1}
    pub x: &'a [f64],
    /// the point the ergodic average X̄ accumulates this step
    /// (X_{t+1/2} for the optimistic / extra-gradient solvers, the plain
    /// iterate for Adam)
    pub avg_point: &'a [f64],
}

/// A distributed VI solver as a resumable state machine. The driver — or
/// any custom harness, e.g. an interleaved solver race — owns the outer
/// loop; the solver owns exactly one iteration of algorithmic state.
pub trait Solver {
    /// Short identifier for tables and metrics streams.
    fn name(&self) -> &'static str;

    fn dim(&self) -> usize;

    fn num_nodes(&self) -> usize;

    /// Establish the run state from `X_1 = x0`. Must be called before the
    /// first `step`; the driver calls it once per run. Iterate and scratch
    /// state is reset; learning-rate schedules keep their accumulated
    /// statistics (pass a fresh schedule for a fresh run).
    fn init(&mut self, x0: &[f64]);

    /// Advance one iteration (`t` = 1, 2, ... as the driver counts them)
    /// and return its wire/fidelity accounting.
    fn step(&mut self, t: usize) -> StepStats;

    /// The iterate and averaging point after the last `step`.
    fn state(&self) -> SolverState<'_>;

    /// Total oracle calls so far — cumulative over the solver's lifetime
    /// (the cost extra-gradient pays twice). The driver snapshots this at
    /// `init` and reports per-run deltas.
    fn oracle_calls(&self) -> u64;
}

/// Roundtrip every node's dual vector through its comm endpoint, averaging
/// the decoded values into `mean` and accumulating wire/fidelity accounting
/// into `stats` — the shared exchange kernel of the mean-based solvers
/// (Q-GenX's two communications per step, Adam's one).
pub fn exchange_mean(
    endpoints: &mut [CommEndpoint],
    duals: &[Vec<f64>],
    hat: &mut Vec<f64>,
    mean: &mut [f64],
    stats: &mut StepStats,
) {
    let kf = endpoints.len() as f64;
    mean.fill(0.0);
    for (kk, dual) in duals.iter().enumerate() {
        let bits = endpoints[kk]
            .roundtrip_into(dual, hat)
            .expect("comm loopback roundtrip");
        stats.bits += bits as u64;
        for (v, h) in dual.iter().zip(hat.iter()) {
            stats.quant_err_sq += (v - h) * (v - h);
            stats.dual_norm_sq += v * v;
        }
        for (m, v) in mean.iter_mut().zip(hat.iter()) {
            *m += v / kf;
        }
    }
}

// ---------------------------------------------------------------------------
// Run artifacts
// ---------------------------------------------------------------------------

/// Per-checkpoint record for convergence curves.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub t: usize,
    pub xbar: Vec<f64>,
    pub total_bits: u64,
    pub oracle_calls: u64,
}

/// The result of one driven run — solver-neutral (QODA, Q-GenX and the
/// Adam baselines all produce it).
#[derive(Clone, Debug)]
pub struct RunReport {
    pub checkpoints: Vec<Checkpoint>,
    /// ergodic average X̄ over the steps actually run
    pub xbar: Vec<f64>,
    pub x_last: Vec<f64>,
    pub total_bits: u64,
    pub oracle_calls: u64,
    /// average wire bits per node per iteration
    pub bits_per_iter_node: f64,
    /// iterations actually executed (< the horizon on early stop)
    pub steps_run: usize,
    /// true iff a [`GapPolicy`] threshold ended the run early
    pub stopped_early: bool,
    /// (t, GAP(X̄_t)) at every gap evaluation the driver performed
    pub gap_trace: Vec<(usize, f64)>,
    /// accumulated sum over steps/nodes of ||V - V̂||²
    pub quant_err_sq: f64,
    /// accumulated sum over steps/nodes of ||V||²
    pub dual_norm_sq: f64,
    /// simulated network-clock seconds across the run (0.0 unless the
    /// driver was given a [`NetClock`] / the spec a network model)
    pub comm_s: f64,
    /// the share of `comm_s` the exchange schedule left on the critical
    /// path: equal to `comm_s` under [`ExchangeMode::Synchronous`] (and
    /// under an overlapped exchange with a zero compute window); always
    /// `comm_exposed_s + comm_hidden_s == comm_s`
    pub comm_exposed_s: f64,
    /// the share of `comm_s` hidden behind the next step's compute under
    /// [`ExchangeMode::Overlapped`] (0.0 when synchronous)
    pub comm_hidden_s: f64,
    /// wire bits as charged by the topology's routing (equals `total_bits`
    /// for broadcast-allgather; 0 without a [`NetClock`])
    pub net_wire_bits: u64,
    /// hottest single link of the run: the max over steps of the charge's
    /// peak per-link bytes ([`WireCharge::peak_link_bytes`]) — the hot-spot
    /// metric the sharded/ring plans shrink (0.0 without a [`NetClock`])
    pub peak_link_bytes: f64,
}

impl RunReport {
    /// Relative wire-quantization error of the whole run:
    /// sum ||V - V̂||² / sum ||V||².
    pub fn rel_quant_error(&self) -> f64 {
        if self.dual_norm_sq == 0.0 {
            0.0
        } else {
            self.quant_err_sq / self.dual_norm_sq
        }
    }

    /// The last gap the driver evaluated, if a [`GapPolicy`] was active.
    pub fn final_gap(&self) -> Option<f64> {
        self.gap_trace.last().map(|&(_, g)| g)
    }
}

// ---------------------------------------------------------------------------
// Metrics sinks
// ---------------------------------------------------------------------------

/// One per-step record streamed to [`MetricsSink`]s while a run is live —
/// no waiting for the post-hoc [`RunReport`].
#[derive(Clone, Debug)]
pub struct StepRecord {
    pub t: usize,
    pub stats: StepStats,
    /// cumulative wire bits through this step
    pub total_bits: u64,
    /// oracle calls so far in this run (baselined at `init`)
    pub oracle_calls: u64,
    /// the gap evaluated at this step, when the driver's [`GapPolicy`]
    /// scheduled one
    pub gap: Option<f64>,
    /// simulated network seconds this step charged (0.0 without a
    /// [`NetClock`])
    pub comm_s: f64,
    /// the exposed share of `comm_s` under the clock's exchange plan
    /// (== `comm_s` for synchronous exchanges)
    pub comm_exposed_s: f64,
    /// the share of `comm_s` hidden behind the compute window
    /// (`comm_exposed_s + comm_hidden_s == comm_s`)
    pub comm_hidden_s: f64,
    /// peak bytes any single link carried this step, per the topology's
    /// charge (0.0 without a [`NetClock`])
    pub peak_link_bytes: f64,
}

/// Observer of a live run. All hooks default to no-ops except `on_step`.
pub trait MetricsSink {
    fn on_step(&mut self, rec: &StepRecord);

    fn on_checkpoint(&mut self, _ck: &Checkpoint) {}

    fn on_finish(&mut self, _report: &RunReport) {}
}

/// Buffers every [`StepRecord`] in memory — tests and small runs.
#[derive(Default)]
pub struct MemorySink {
    pub records: Vec<StepRecord>,
}

impl MetricsSink for MemorySink {
    fn on_step(&mut self, rec: &StepRecord) {
        self.records.push(rec.clone());
    }
}

// ---------------------------------------------------------------------------
// The driver
// ---------------------------------------------------------------------------

/// A simulated network clock the driver charges each step's wire bits
/// against: a [`TopologySpec`]-built transport routing over a
/// [`NetworkModel`]. Per-node payloads are taken as equal shares of the
/// step's total bits (the solvers' per-node packets differ by at most the
/// entropy coder's jitter, and the split preserves the exact total).
///
/// The clock's [`ExchangePlan`] decides how each charge meets the critical
/// path. Under [`ExchangeMode::Overlapped`] the charge is split into
/// exposed vs hidden seconds against the plan's compute window — this is
/// *accounting for* the engines' one-step-stale double buffer, not a change
/// to the solver math: the driver's solvers exchange through in-process
/// loopback endpoints, so their iterates are exactly the synchronous ones.
/// The staleness cost lives where the staleness is real — in the
/// coordinator engines (`ClusterSim` overlapped mode, the pipelined
/// `run_rounds_over`), whose aggregates genuinely arrive `depth` rounds
/// late. A run report with `comm_hidden_s > 0` therefore reads as: "on a
/// cluster running this schedule, these seconds come off the critical
/// path, and the iterates follow the depth-stale trajectory the engines
/// (and `tests/overlap_parity.rs`) pin".
pub struct NetClock {
    transport: Box<dyn Transport>,
    pub model: NetworkModel,
    /// true => fp32 payloads, in-network reduction applies
    pub uncompressed: bool,
    pub main_protocol: bool,
    /// how charges are scheduled against compute (synchronous by default)
    pub plan: ExchangePlan,
    rng: Rng,
}

impl NetClock {
    pub fn new(
        spec: &TopologySpec,
        model: NetworkModel,
        uncompressed: bool,
        main_protocol: bool,
    ) -> Self {
        NetClock {
            transport: spec.build(),
            model,
            uncompressed,
            main_protocol,
            plan: ExchangePlan::synchronous(),
            rng: Rng::new(0x1C0C),
        }
    }

    /// Attach an exchange schedule (default: synchronous — the clock then
    /// behaves exactly as before overlap existed, same charges off the same
    /// RNG stream).
    pub fn with_exchange(mut self, plan: ExchangePlan) -> Self {
        self.plan = plan;
        self
    }

    pub fn spec(&self) -> TopologySpec {
        self.transport.spec()
    }

    /// Charge one step's exchange: `total_bits` split evenly across the
    /// `k` nodes (remainder spread over the first nodes, so the sum is
    /// exact), `d` the aggregate dimension.
    pub fn charge_step(&mut self, total_bits: u64, k: usize, d: usize) -> WireCharge {
        let k = k.max(1);
        let base = total_bits / k as u64;
        let rem = (total_bits % k as u64) as usize;
        let mut bits = vec![base; k];
        for b in bits.iter_mut().take(rem) {
            *b += 1;
        }
        self.transport.charge(
            &bits,
            d,
            &self.model,
            self.uncompressed,
            self.main_protocol,
            &mut self.rng,
        )
    }
}

/// Restricted-gap evaluation schedule for a driven run.
pub struct GapPolicy<'a> {
    pub eval: GapEvaluator<'a>,
    /// evaluate every `every` steps (0 = only at checkpoints)
    pub every: usize,
    /// end the run once an evaluated gap falls to or below this threshold
    pub stop_below: Option<f64>,
}

/// Sort, dedup and clamp a requested checkpoint list against the horizon.
/// The legacy `run()` loops walked the raw list with an exact-match peek and
/// silently dropped unsorted, duplicate or out-of-range entries; the driver
/// normalizes instead so every requested checkpoint produces a record.
pub fn normalize_checkpoints(requested: &[usize], steps: usize) -> Vec<usize> {
    let mut cks: Vec<usize> = requested
        .iter()
        .map(|&t| t.min(steps))
        .filter(|&t| t >= 1)
        .collect();
    cks.sort_unstable();
    cks.dedup();
    cks
}

/// The shared outer loop. Owns everything the solvers used to copy-paste:
/// checkpoint scheduling, ergodic averaging, bits/oracle accounting, gap
/// evaluation with early stopping, and metrics streaming.
pub struct RunDriver<'a> {
    checkpoints: Vec<usize>,
    gap: Option<GapPolicy<'a>>,
    net: Option<NetClock>,
}

impl Default for RunDriver<'_> {
    fn default() -> Self {
        Self::new()
    }
}

impl<'a> RunDriver<'a> {
    pub fn new() -> Self {
        RunDriver { checkpoints: Vec::new(), gap: None, net: None }
    }

    /// Record a [`Checkpoint`] at each of these iteration numbers (any
    /// order; duplicates and overshoots are normalized, not dropped).
    pub fn checkpoints(mut self, at: &[usize]) -> Self {
        self.checkpoints = at.to_vec();
        self
    }

    /// Attach a gap-evaluation schedule (and optional early stopping).
    pub fn gap(mut self, policy: GapPolicy<'a>) -> Self {
        self.gap = Some(policy);
        self
    }

    /// Attach a simulated network clock: every step's wire bits are routed
    /// through the clock's topology and charged to the report's `comm_s` /
    /// `net_wire_bits`.
    pub fn network(mut self, clock: NetClock) -> Self {
        self.net = Some(clock);
        self
    }

    /// Drive `solver` for `steps` iterations from `x0`.
    pub fn run(&mut self, solver: &mut dyn Solver, x0: &[f64], steps: usize) -> RunReport {
        self.run_observed(solver, x0, steps, &mut [])
    }

    /// Drive `solver`, streaming per-step records to the given sinks.
    pub fn run_observed(
        &mut self,
        solver: &mut dyn Solver,
        x0: &[f64],
        steps: usize,
        sinks: &mut [&mut dyn MetricsSink],
    ) -> RunReport {
        let d = solver.dim();
        let k = solver.num_nodes();
        let kf = k as f64;
        let cks = normalize_checkpoints(&self.checkpoints, steps);
        let mut ck_iter = cks.iter().peekable();
        solver.init(x0);
        // baseline the cumulative counter so reused solvers report per-run
        // deltas, not lifetime totals
        let calls0 = solver.oracle_calls();
        let mut xbar_sum = vec![0.0; d];
        let mut total_bits = 0u64;
        let mut quant_err_sq = 0.0f64;
        let mut dual_norm_sq = 0.0f64;
        let mut comm_s = 0.0f64;
        let mut comm_exposed_s = 0.0f64;
        let mut comm_hidden_s = 0.0f64;
        let mut net_wire_bits = 0u64;
        let mut peak_link_bytes = 0.0f64;
        let mut out_ckpts = Vec::new();
        let mut gap_trace = Vec::new();
        let mut stopped_early = false;
        let mut steps_run = 0usize;

        for t in 1..=steps {
            let stats = solver.step(t);
            steps_run = t;
            total_bits += stats.bits;
            quant_err_sq += stats.quant_err_sq;
            dual_norm_sq += stats.dual_norm_sq;
            let mut step_comm_s = 0.0;
            let mut step_exposed_s = 0.0;
            let mut step_hidden_s = 0.0;
            let mut step_peak_link = 0.0;
            if let Some(clock) = self.net.as_mut() {
                let charge = clock.charge_step(stats.bits, k, d);
                let (exposed, hidden) = clock.plan.split(charge.comm_s);
                step_comm_s = charge.comm_s;
                step_exposed_s = exposed;
                step_hidden_s = hidden;
                step_peak_link = charge.peak_link_bytes;
                comm_s += charge.comm_s;
                comm_exposed_s += exposed;
                comm_hidden_s += hidden;
                net_wire_bits += charge.wire_bits;
                peak_link_bytes = peak_link_bytes.max(charge.peak_link_bytes);
            }
            {
                let st = solver.state();
                for (s, v) in xbar_sum.iter_mut().zip(st.avg_point) {
                    *s += v;
                }
            }
            let at_checkpoint = ck_iter.peek() == Some(&&t);
            let gap_due = self
                .gap
                .as_ref()
                .is_some_and(|g| (g.every > 0 && t % g.every == 0) || at_checkpoint);
            // X̄_t materialized once per step, shared by gap eval + checkpoint
            let mut xbar_t: Option<Vec<f64>> = if at_checkpoint || gap_due {
                Some(xbar_sum.iter().map(|s| s / t as f64).collect())
            } else {
                None
            };
            let mut gap_now = None;
            if gap_due {
                if let (Some(g), Some(xb)) = (&self.gap, xbar_t.as_ref()) {
                    let gv = g.eval.eval(xb);
                    gap_trace.push((t, gv));
                    gap_now = Some(gv);
                }
            }
            let rec = StepRecord {
                t,
                stats,
                total_bits,
                oracle_calls: solver.oracle_calls() - calls0,
                gap: gap_now,
                comm_s: step_comm_s,
                comm_exposed_s: step_exposed_s,
                comm_hidden_s: step_hidden_s,
                peak_link_bytes: step_peak_link,
            };
            for sink in sinks.iter_mut() {
                sink.on_step(&rec);
            }
            if at_checkpoint {
                ck_iter.next();
                let ck = Checkpoint {
                    t,
                    xbar: xbar_t.take().expect("materialized at checkpoint"),
                    total_bits,
                    oracle_calls: solver.oracle_calls() - calls0,
                };
                for sink in sinks.iter_mut() {
                    sink.on_checkpoint(&ck);
                }
                out_ckpts.push(ck);
            }
            if let (Some(g), Some(gv)) = (&self.gap, gap_now) {
                if g.stop_below.is_some_and(|th| gv <= th) {
                    stopped_early = true;
                    break;
                }
            }
        }

        let denom = steps_run.max(1) as f64;
        let report = RunReport {
            checkpoints: out_ckpts,
            xbar: xbar_sum.iter().map(|s| s / denom).collect(),
            x_last: solver.state().x.to_vec(),
            total_bits,
            oracle_calls: solver.oracle_calls() - calls0,
            bits_per_iter_node: total_bits as f64 / (denom * kf),
            steps_run,
            stopped_early,
            gap_trace,
            quant_err_sq,
            dual_norm_sq,
            comm_s,
            comm_exposed_s,
            comm_hidden_s,
            net_wire_bits,
            peak_link_bytes,
        };
        for sink in sinks.iter_mut() {
            sink.on_finish(&report);
        }
        report
    }
}

// ---------------------------------------------------------------------------
// Declarative run construction
// ---------------------------------------------------------------------------

/// Which solver a [`RunSpec`] drives.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SolverKind {
    Qoda,
    QGenX,
    Adam { lr: f64 },
    OptimisticAdam { lr: f64 },
}

/// The analytic operator behind the run's oracles (seeded, so a spec
/// rebuilds the identical instance every time).
#[derive(Clone, Debug)]
pub enum OperatorSpec {
    /// strongly monotone quadratic `QuadraticOperator::random(dim, mu, ..)`
    Quadratic { dim: usize, mu: f64, seed: u64 },
    /// bilinear saddle game over `R^n x R^n` (dim = 2n)
    Bilinear { n: usize, seed: u64 },
}

impl OperatorSpec {
    pub fn build(&self) -> Box<dyn Operator> {
        match *self {
            OperatorSpec::Quadratic { dim, mu, seed } => {
                let mut rng = Rng::new(seed);
                Box::new(QuadraticOperator::random(dim, mu, &mut rng))
            }
            OperatorSpec::Bilinear { n, seed } => {
                let mut rng = Rng::new(seed);
                Box::new(BilinearGame::random(n, &mut rng))
            }
        }
    }
}

/// Per-node compression for a [`RunSpec`].
#[derive(Clone, Debug)]
pub enum CompressionSpec {
    /// fp32 on the wire
    None,
    /// single-type (global) quantization at `bits` over `bucket`-sized
    /// buckets, static levels
    Global { bits: u32, bucket: usize },
    /// layer-wise L-GreCo adaptation over an explicit layer map
    Layerwise { map: LayerMap, bits: u32, bucket: usize, every: usize },
    /// full control: explicit map, uniform per-type bits and an explicit
    /// [`Adaptation`] policy (the ablation harness)
    Quantized { map: LayerMap, bits: u32, adaptation: Adaptation },
}

impl CompressionSpec {
    /// Build one node's compressor for a `dim`-dimensional dual stream.
    pub fn build(
        &self,
        dim: usize,
        protocol: ProtocolKind,
        seed: u64,
    ) -> Box<dyn Compressor> {
        match self {
            CompressionSpec::None => Box::new(IdentityCompressor::new()),
            CompressionSpec::Global { bits, bucket } => {
                Box::new(QuantCompressor::global_bits_proto(
                    &LayerMap::single(dim),
                    *bits,
                    *bucket,
                    protocol,
                    seed,
                ))
            }
            CompressionSpec::Layerwise { map, bits, bucket, every } => {
                Box::new(QuantCompressor::layerwise_proto(
                    map, *bits, *bucket, *every, protocol, seed,
                ))
            }
            CompressionSpec::Quantized { map, bits, adaptation } => {
                let cfg = QuantConfig::uniform_bits(map.num_types(), *bits, 2.0);
                Box::new(QuantCompressor::new(
                    map.clone(),
                    cfg,
                    protocol,
                    adaptation.clone(),
                    seed,
                ))
            }
        }
    }

    /// Build one node's compressor under a scheduled bit budget
    /// ([`RunSpec::bit_budget`]): the spec's layer/bucket structure with
    /// [`Adaptation::Scheduled`] re-planning under `budget` wire bits per
    /// coordinate every `every` decodes. Callers that wrap the result in
    /// error feedback must double `every` first (the EF self-decode doubles
    /// the inner codec's decode rate — see [`crate::comm::feedback`]).
    pub fn build_scheduled(
        &self,
        dim: usize,
        protocol: ProtocolKind,
        seed: u64,
        budget: f64,
        every: usize,
    ) -> Box<dyn Compressor> {
        let (map, bucket) = match self {
            CompressionSpec::None => (LayerMap::single(dim), 128),
            CompressionSpec::Global { bucket, .. } => (LayerMap::single(dim), *bucket),
            CompressionSpec::Layerwise { map, bucket, .. } => (map.clone(), *bucket),
            // a bucket wider than any layer leaves the map's own structure
            CompressionSpec::Quantized { map, .. } => (map.clone(), 1 << 30),
        };
        Box::new(QuantCompressor::scheduled_proto(
            &map, budget, bucket, every, protocol, seed,
        ))
    }

    /// The [`WireCodecSpec`] equivalent of this compression for the
    /// measured-wire TCP runtime ([`crate::wire`]): the same layer maps and
    /// level widths, pinned to `Adaptation::Fixed`. Wire nodes carry no
    /// codebook control channel, so adaptive schedules (L-GreCo, the
    /// scheduled bit budget) map to their fixed-level equivalents — bit
    /// widths and bucket structure are preserved, in-run level adaptation
    /// is not.
    pub fn wire_codec(&self, dim: usize, protocol: ProtocolKind) -> WireCodecSpec {
        match self {
            CompressionSpec::None => WireCodecSpec::Identity,
            // mirror `QuantCompressor::global_bits_proto`: one global type
            // over bucket-sized segments
            CompressionSpec::Global { bits, bucket } => {
                WireCodecSpec::Quant(SharedQuantState {
                    map: LayerMap::single(dim).bucketed(*bucket).with_single_type(),
                    cfg: QuantConfig::uniform_bits(1, *bits, 2.0),
                    protocol,
                    adaptation: Adaptation::Fixed,
                })
            }
            CompressionSpec::Layerwise { map, bits, bucket, .. } => {
                let m = map.bucketed(*bucket);
                let cfg = QuantConfig::uniform_bits(m.num_types(), *bits, 2.0);
                WireCodecSpec::Quant(SharedQuantState {
                    map: m,
                    cfg,
                    protocol,
                    adaptation: Adaptation::Fixed,
                })
            }
            CompressionSpec::Quantized { map, bits, .. } => {
                WireCodecSpec::Quant(SharedQuantState {
                    map: map.clone(),
                    cfg: QuantConfig::uniform_bits(map.num_types(), *bits, 2.0),
                    protocol,
                    adaptation: Adaptation::Fixed,
                })
            }
        }
    }
}

/// Learning-rate schedule for a [`RunSpec`] (ignored by the Adam solvers,
/// which carry their own scalar rate).
#[derive(Clone, Copy, Debug)]
pub enum LrSpec {
    /// the paper's Eq. (4) schedule
    Adaptive,
    /// the (Alt) schedule of Section 6
    Alt { q_hat: f64 },
    /// fixed step sizes (ablation baseline)
    Constant { gamma: f64, eta: f64 },
}

impl LrSpec {
    pub fn build(&self) -> Box<dyn LrSchedule> {
        match *self {
            LrSpec::Adaptive => Box::new(AdaptiveLr::default()),
            LrSpec::Alt { q_hat } => Box::new(AltLr::new(q_hat)),
            LrSpec::Constant { gamma, eta } => Box::new(ConstantLr { gamma, eta }),
        }
    }
}

/// Gap-evaluation mode of a [`RunSpec`] run.
#[derive(Clone, Copy, Debug)]
pub enum GapMode {
    Off,
    /// evaluate GAP(X̄_t) at every checkpoint
    AtCheckpoints,
    /// evaluate every `every` steps (and at checkpoints) and stop early
    /// once GAP ≤ `threshold`
    EarlyStop { every: usize, threshold: f64 },
}

/// Declarative description of one solver run — the single construction
/// path the CLI (`qoda run`), the bench harnesses and the examples share.
///
/// ```
/// use qoda::oda::{CompressionSpec, GapMode, OperatorSpec, RunSpec, SolverKind};
/// use qoda::vi::noise::NoiseModel;
///
/// let report = RunSpec::new(
///     SolverKind::Qoda,
///     OperatorSpec::Quadratic { dim: 8, mu: 0.5, seed: 1 },
/// )
/// .nodes(2)
/// .noise(NoiseModel::Absolute { sigma: 0.2 })
/// .compression(CompressionSpec::Global { bits: 5, bucket: 128 })
/// .steps(400)
/// .checkpoints(&[100, 400])
/// .gap(GapMode::AtCheckpoints)
/// .run();
/// assert_eq!(report.checkpoints.len(), 2);
/// ```
#[derive(Clone, Debug)]
pub struct RunSpec {
    pub solver: SolverKind,
    pub operator: OperatorSpec,
    pub noise: NoiseModel,
    pub nodes: usize,
    pub compression: CompressionSpec,
    pub lr: LrSpec,
    pub protocol: ProtocolKind,
    pub steps: usize,
    pub checkpoints: Vec<usize>,
    pub seed: u64,
    /// Algorithm 1's explicit update-step period (0 = codec self-scheduled)
    pub update_every: usize,
    /// Global wire-bit budget per coordinate. When set, the loopback engines
    /// replace the spec's static levels with [`Adaptation::Scheduled`]: the
    /// fixed L-GreCo DP re-plans per-layer bit widths from receiver-observed
    /// statistics every `update_every` decodes (64 if unset) and retunes the
    /// entropy codebooks. The measured-wire path ([`Self::wire`]) ignores
    /// this and stays pinned to the fixed-level equivalent.
    pub bit_budget: Option<f64>,
    /// Wrap every node's codec in [`FeedbackCompressor`]: the quantization
    /// residual is folded into the next dual before compression (EF14).
    /// Encoder-side only — the wire format is unchanged. Ignored by
    /// [`Self::wire`].
    pub error_feedback: bool,
    /// starting point X_1 (default: the origin)
    pub x0: Option<Vec<f64>>,
    pub gap: GapMode,
    /// how the per-node packets are routed (affects `comm_s` /
    /// `net_wire_bits` accounting only — aggregates are topology-invariant)
    pub topology: TopologySpec,
    /// attach a network model to charge every step on the simulated clock
    pub network: Option<NetworkModel>,
    /// how exchanges are scheduled against compute on the simulated clock
    /// (synchronous by default; overlapped splits `comm_s` into exposed
    /// vs hidden against `exchange.compute_s_per_step`)
    pub exchange: ExchangePlan,
}

impl RunSpec {
    pub fn new(solver: SolverKind, operator: OperatorSpec) -> Self {
        RunSpec {
            solver,
            operator,
            noise: NoiseModel::None,
            nodes: 1,
            compression: CompressionSpec::None,
            lr: LrSpec::Adaptive,
            protocol: ProtocolKind::Main,
            steps: 1000,
            checkpoints: Vec::new(),
            seed: 1,
            update_every: 0,
            bit_budget: None,
            error_feedback: false,
            x0: None,
            gap: GapMode::Off,
            topology: TopologySpec::BroadcastAllGather,
            network: None,
            exchange: ExchangePlan::synchronous(),
        }
    }

    pub fn noise(mut self, noise: NoiseModel) -> Self {
        self.noise = noise;
        self
    }

    pub fn nodes(mut self, k: usize) -> Self {
        self.nodes = k;
        self
    }

    pub fn compression(mut self, c: CompressionSpec) -> Self {
        self.compression = c;
        self
    }

    pub fn lr(mut self, lr: LrSpec) -> Self {
        self.lr = lr;
        self
    }

    pub fn protocol(mut self, p: ProtocolKind) -> Self {
        self.protocol = p;
        self
    }

    pub fn steps(mut self, steps: usize) -> Self {
        self.steps = steps;
        self
    }

    pub fn checkpoints(mut self, at: &[usize]) -> Self {
        self.checkpoints = at.to_vec();
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn update_every(mut self, every: usize) -> Self {
        self.update_every = every;
        self
    }

    /// Drive layer-wise bit widths adaptively under a global wire-bit budget
    /// per coordinate (see [`RunSpec::bit_budget`]).
    pub fn bit_budget(mut self, bits_per_coord: f64) -> Self {
        self.bit_budget = Some(bits_per_coord);
        self
    }

    /// Enable encoder-side error feedback (see [`RunSpec::error_feedback`]).
    pub fn error_feedback(mut self, on: bool) -> Self {
        self.error_feedback = on;
        self
    }

    pub fn x0(mut self, x0: Vec<f64>) -> Self {
        self.x0 = Some(x0);
        self
    }

    pub fn gap(mut self, mode: GapMode) -> Self {
        self.gap = mode;
        self
    }

    pub fn topology(mut self, spec: TopologySpec) -> Self {
        self.topology = spec;
        self
    }

    pub fn network(mut self, net: NetworkModel) -> Self {
        self.network = Some(net);
        self
    }

    /// Select the exchange schedule charged on the simulated clock.
    pub fn exchange(mut self, mode: ExchangeMode) -> Self {
        self.exchange.mode = mode;
        self
    }

    /// Modeled compute seconds per step that an overlapped exchange hides
    /// communication behind (ignored when synchronous).
    pub fn compute_per_step(mut self, compute_s: f64) -> Self {
        self.exchange.compute_s_per_step = compute_s;
        self
    }

    /// The operator instance this spec's oracles wrap (rebuilt from the
    /// seed — identical every call), for external gap evaluation.
    pub fn operator_instance(&self) -> Box<dyn Operator> {
        self.operator.build()
    }

    /// Build everything and drive the run.
    pub fn run(&self) -> RunReport {
        self.run_observed(&mut [])
    }

    /// Drive this spec's exchange over the measured-wire TCP runtime
    /// ([`crate::wire`]): every node a real OS thread, the coded packets on
    /// real localhost sockets, `comm_s` a monotonic-clock measurement.
    ///
    /// The wire engine runs the mean-descent exchange (decode all K
    /// packets, average, constant-γ descent on the mean) — it exists to
    /// *measure* communication, so `solver`, `gap`, `network` and the
    /// checkpoint schedule are ignored on this path; `lr` contributes only
    /// a constant γ ([`LrSpec::Constant`], else 0.05). Compression maps
    /// through [`CompressionSpec::wire_codec`].
    pub fn wire(&self) -> Result<WireReport, CommError> {
        self.wire_observed(&mut [])
    }

    /// [`Self::wire`], streaming a measured per-round [`StepRecord`] to the
    /// given sinks.
    pub fn wire_observed(
        &self,
        sinks: &mut [&mut dyn MetricsSink],
    ) -> Result<WireReport, CommError> {
        let op = self.operator.build();
        let d = op.dim();
        let x0 = self.x0.clone().unwrap_or_else(|| vec![0.0; d]);
        assert_eq!(x0.len(), d, "x0 dimension must match the operator");
        let codec = self.compression.wire_codec(d, self.protocol);
        let gamma = match self.lr {
            LrSpec::Constant { gamma, .. } => gamma,
            _ => 0.05,
        };
        let update = move |x: &mut Vec<f64>, mean: &[f64], _t: usize| {
            for (xi, m) in x.iter_mut().zip(mean) {
                *xi -= gamma * m;
            }
        };
        run_wire_observed(
            Workload::Oracle { op: op.as_ref(), noise: self.noise },
            self.nodes,
            &codec,
            &x0,
            self.steps,
            self.seed,
            &self.topology,
            self.exchange,
            &WireOptions::default(),
            &update,
            sinks,
        )
    }

    /// Build everything and drive the run, streaming to the given sinks.
    pub fn run_observed(&self, sinks: &mut [&mut dyn MetricsSink]) -> RunReport {
        let op = self.operator.build();
        let d = op.dim();
        let x0 = self.x0.clone().unwrap_or_else(|| vec![0.0; d]);
        assert_eq!(x0.len(), d, "x0 dimension must match the operator");
        let mut src =
            OracleSource::new(op.as_ref(), self.nodes, self.noise, self.seed ^ 0xABCD);
        let comps: Vec<Box<dyn Compressor>> = (0..self.nodes)
            .map(|i| {
                let node_seed = self.seed + i as u64;
                let inner = match self.bit_budget {
                    Some(budget) => {
                        // decode-count cadence: explicit period, or a 64-step
                        // default; EF's self-decode doubles the decode rate,
                        // so double `every` to keep updates at packet
                        // boundaries (comm::feedback)
                        let every =
                            if self.update_every > 0 { self.update_every } else { 64 };
                        let every =
                            if self.error_feedback { every.saturating_mul(2) } else { every };
                        self.compression.build_scheduled(
                            d,
                            self.protocol,
                            node_seed,
                            budget,
                            every,
                        )
                    }
                    None => self.compression.build(d, self.protocol, node_seed),
                };
                if self.error_feedback {
                    Box::new(FeedbackCompressor::new(inner)) as Box<dyn Compressor>
                } else {
                    inner
                }
            })
            .collect();
        let mut driver = RunDriver::new().checkpoints(&self.checkpoints);
        if let Some(model) = &self.network {
            driver = driver.network(
                NetClock::new(
                    &self.topology,
                    model.clone(),
                    matches!(self.compression, CompressionSpec::None),
                    self.protocol == ProtocolKind::Main,
                )
                .with_exchange(self.exchange),
            );
        }
        if !matches!(self.gap, GapMode::Off) {
            let sol = op
                .solution()
                .expect("gap evaluation needs an operator with a known solution");
            let radius = 1.0 + l2_norm64(&sub(&x0, &sol));
            let eval = GapEvaluator::new(op.as_ref(), sol, radius);
            let policy = match self.gap {
                GapMode::AtCheckpoints => {
                    GapPolicy { eval, every: 0, stop_below: None }
                }
                GapMode::EarlyStop { every, threshold } => GapPolicy {
                    // scheduled in-run evaluations run on a reduced budget
                    // so the stopping check stays cheap per step
                    eval: eval.budget(3, 120),
                    every,
                    stop_below: Some(threshold),
                },
                GapMode::Off => unreachable!(),
            };
            driver = driver.gap(policy);
        }
        match self.solver {
            SolverKind::Qoda => {
                let mut solver = Qoda::new(&mut src, comps, self.lr.build());
                // under a scheduled bit budget the codec adapts on its own
                // decode counter; driving Algorithm 1's explicit update step
                // on top would reset the receiver-side statistics mid-window
                solver.update_every =
                    if self.bit_budget.is_some() { 0 } else { self.update_every };
                driver.run_observed(&mut solver, &x0, self.steps, sinks)
            }
            SolverKind::QGenX => {
                let mut solver = QGenX::new(&mut src, comps, self.lr.build());
                driver.run_observed(&mut solver, &x0, self.steps, sinks)
            }
            SolverKind::Adam { lr } => {
                let mut solver = AdamSolver::new(&mut src, comps, lr);
                driver.run_observed(&mut solver, &x0, self.steps, sinks)
            }
            SolverKind::OptimisticAdam { lr } => {
                let mut solver = OptimisticAdam::new(&mut src, comps, lr);
                driver.run_observed(&mut solver, &x0, self.steps, sinks)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oda::source::OracleSource;
    use crate::stats::vecops::{l2_norm64, sub};
    use crate::vi::noise::NoiseModel;

    fn identity_boxes(k: usize) -> Vec<Box<dyn Compressor>> {
        (0..k).map(|_| Box::new(IdentityCompressor::new()) as Box<dyn Compressor>).collect()
    }

    #[test]
    fn normalize_sorts_dedups_and_clamps() {
        // unsorted, duplicated, zero and overshooting entries all survive
        // normalization instead of being silently dropped
        assert_eq!(normalize_checkpoints(&[50, 10, 10, 999, 0], 100), vec![10, 50, 100]);
        assert_eq!(normalize_checkpoints(&[100, 999], 100), vec![100]);
        assert_eq!(normalize_checkpoints(&[], 100), Vec::<usize>::new());
        assert_eq!(normalize_checkpoints(&[5], 0), Vec::<usize>::new());
    }

    #[test]
    fn driver_records_normalized_checkpoints() {
        let mut rng = Rng::new(9);
        let op = QuadraticOperator::random(4, 0.5, &mut rng);
        let mut src = OracleSource::new(&op, 1, NoiseModel::None, 10);
        let mut solver =
            Qoda::new(&mut src, identity_boxes(1), Box::new(AdaptiveLr::default()));
        // legacy run() would have recorded nothing from this list (unsorted
        // + out of range); the driver records t = 10, 20, 50
        let run = RunDriver::new()
            .checkpoints(&[20, 10, 80, 20])
            .run(&mut solver, &vec![0.0; 4], 50);
        let ts: Vec<usize> = run.checkpoints.iter().map(|c| c.t).collect();
        assert_eq!(ts, vec![10, 20, 50]);
        assert!(run.checkpoints[0].total_bits <= run.checkpoints[2].total_bits);
        assert_eq!(run.steps_run, 50);
        assert!(!run.stopped_early);
    }

    #[test]
    fn memory_sink_streams_every_step() {
        let mut rng = Rng::new(3);
        let op = QuadraticOperator::random(4, 0.5, &mut rng);
        let mut src = OracleSource::new(&op, 2, NoiseModel::None, 4);
        let mut solver =
            Qoda::new(&mut src, identity_boxes(2), Box::new(AdaptiveLr::default()));
        let mut sink = MemorySink::default();
        let run = RunDriver::new().run_observed(
            &mut solver,
            &vec![0.0; 4],
            30,
            &mut [&mut sink],
        );
        assert_eq!(sink.records.len(), 30);
        let last = sink.records.last().unwrap();
        assert_eq!(last.t, 30);
        assert_eq!(last.total_bits, run.total_bits);
        assert_eq!(last.oracle_calls, run.oracle_calls);
        // identity wire: 32 bits/coord/node, monotone accumulation
        assert!(sink.records.windows(2).all(|w| w[0].total_bits < w[1].total_bits));
    }

    #[test]
    fn gap_early_stop_ends_run() {
        let mut rng = Rng::new(5);
        let op = QuadraticOperator::random(6, 1.0, &mut rng);
        let sol = op.sol.clone();
        let x0 = vec![0.0; 6];
        let radius = 1.0 + l2_norm64(&sub(&x0, &sol));
        let mut src = OracleSource::new(&op, 2, NoiseModel::None, 6);
        let mut solver =
            Qoda::new(&mut src, identity_boxes(2), Box::new(AdaptiveLr::default()));
        let policy = GapPolicy {
            eval: GapEvaluator::new(&op, sol, radius),
            every: 50,
            stop_below: Some(1e3), // any evaluation passes: stop at t = 50
        };
        let run = RunDriver::new().gap(policy).run(&mut solver, &x0, 5000);
        assert!(run.stopped_early);
        assert_eq!(run.steps_run, 50);
        assert_eq!(run.gap_trace.len(), 1);
        assert_eq!(run.gap_trace[0].0, 50);
        // the report's averages are over the 50 steps actually run
        assert!((run.bits_per_iter_node
            - run.total_bits as f64 / (50.0 * 2.0))
            .abs()
            < 1e-12);
    }

    #[test]
    fn runspec_reproduces_manual_construction() {
        // the declarative path must build byte-identical runs to manual
        // solver construction with the same seeds
        let spec = RunSpec::new(
            SolverKind::Qoda,
            OperatorSpec::Quadratic { dim: 8, mu: 0.5, seed: 21 },
        )
        .nodes(2)
        .noise(NoiseModel::Absolute { sigma: 0.2 })
        .compression(CompressionSpec::Global { bits: 5, bucket: 128 })
        .steps(200)
        .seed(7);
        let a = spec.run();

        let op = spec.operator_instance();
        let mut src = OracleSource::new(
            op.as_ref(),
            2,
            NoiseModel::Absolute { sigma: 0.2 },
            7 ^ 0xABCD,
        );
        let comps: Vec<Box<dyn Compressor>> = (0..2)
            .map(|i| {
                spec.compression.build(8, ProtocolKind::Main, 7 + i as u64)
            })
            .collect();
        let mut solver = Qoda::new(&mut src, comps, Box::new(AdaptiveLr::default()));
        let b = RunDriver::new().run(&mut solver, &vec![0.0; 8], 200);

        assert_eq!(a.total_bits, b.total_bits);
        assert_eq!(a.oracle_calls, b.oracle_calls);
        assert_eq!(a.xbar, b.xbar);
        assert_eq!(a.x_last, b.x_last);
    }

    #[test]
    fn runspec_gap_at_checkpoints_converges() {
        let report = RunSpec::new(
            SolverKind::Qoda,
            OperatorSpec::Quadratic { dim: 8, mu: 0.5, seed: 1 },
        )
        .nodes(2)
        .noise(NoiseModel::Absolute { sigma: 0.3 })
        .steps(800)
        .checkpoints(&[100, 800])
        .gap(GapMode::AtCheckpoints)
        .run();
        assert_eq!(report.gap_trace.len(), 2);
        let (t0, g0) = report.gap_trace[0];
        let (t1, g1) = report.gap_trace[1];
        assert_eq!((t0, t1), (100, 800));
        assert!(g1 < g0, "gap should shrink: {g0} -> {g1}");
    }

    #[test]
    fn solver_kinds_all_drive() {
        for kind in [
            SolverKind::Qoda,
            SolverKind::QGenX,
            SolverKind::Adam { lr: 0.05 },
            SolverKind::OptimisticAdam { lr: 0.05 },
        ] {
            let report = RunSpec::new(
                kind,
                OperatorSpec::Quadratic { dim: 6, mu: 0.5, seed: 3 },
            )
            .nodes(2)
            .steps(50)
            .run();
            assert_eq!(report.steps_run, 50, "{kind:?}");
            assert!(report.total_bits > 0, "{kind:?}");
            // extra-gradient pays two oracle calls per node per iteration
            let expect = if matches!(kind, SolverKind::QGenX) { 200 } else { 100 };
            assert_eq!(report.oracle_calls, expect, "{kind:?}");
        }
    }

    #[test]
    fn network_clock_charges_topologies_differently() {
        let spec = |topo: TopologySpec| {
            RunSpec::new(
                SolverKind::Qoda,
                OperatorSpec::Quadratic { dim: 16, mu: 0.5, seed: 4 },
            )
            .nodes(4)
            .compression(CompressionSpec::Global { bits: 5, bucket: 128 })
            .steps(40)
            .topology(topo)
            .network(NetworkModel::genesis_cloud(5.0))
            .run()
        };
        let flat = spec(TopologySpec::BroadcastAllGather);
        let hier = spec(TopologySpec::Hierarchical { racks: 2 });
        // algorithmic results are topology-invariant...
        assert_eq!(flat.x_last, hier.x_last);
        assert_eq!(flat.total_bits, hier.total_bits);
        // ...while the network accounting reflects the routing
        assert_eq!(flat.net_wire_bits, flat.total_bits);
        assert!(hier.net_wire_bits > flat.net_wire_bits);
        assert!(flat.comm_s > 0.0 && hier.comm_s > 0.0);
        // no network model attached => no clock
        let off = RunSpec::new(
            SolverKind::Qoda,
            OperatorSpec::Quadratic { dim: 16, mu: 0.5, seed: 4 },
        )
        .nodes(4)
        .steps(10)
        .run();
        assert_eq!(off.comm_s, 0.0);
        assert_eq!(off.net_wire_bits, 0);
    }

    #[test]
    fn overlapped_clock_splits_comm_without_touching_the_math() {
        let spec = |mode: ExchangeMode, compute_s: f64| {
            RunSpec::new(
                SolverKind::Qoda,
                OperatorSpec::Quadratic { dim: 16, mu: 0.5, seed: 6 },
            )
            .nodes(4)
            .compression(CompressionSpec::Global { bits: 5, bucket: 128 })
            .steps(30)
            .topology(TopologySpec::Hierarchical { racks: 2 })
            .network(NetworkModel::genesis_cloud(5.0))
            .exchange(mode)
            .compute_per_step(compute_s)
            .run()
        };
        let sync = spec(ExchangeMode::Synchronous, 0.0);
        let ov0 = spec(ExchangeMode::Overlapped { depth: 1 }, 0.0);
        let ov = spec(ExchangeMode::Overlapped { depth: 1 }, 10.0);
        // the clock is pure accounting: iterates, bits and the charge
        // itself are mode-invariant
        assert_eq!(sync.x_last, ov.x_last);
        assert_eq!(sync.total_bits, ov.total_bits);
        assert_eq!(sync.comm_s, ov.comm_s);
        assert_eq!(sync.net_wire_bits, ov.net_wire_bits);
        // synchronous: everything exposed
        assert_eq!(sync.comm_exposed_s, sync.comm_s);
        assert_eq!(sync.comm_hidden_s, 0.0);
        // overlapped with zero compute: exposed == comm_s exactly
        assert_eq!(ov0.comm_exposed_s, ov0.comm_s);
        assert_eq!(ov0.comm_hidden_s, 0.0);
        // overlapped with a generous window: fully hidden
        assert_eq!(ov.comm_exposed_s, 0.0);
        assert_eq!(ov.comm_hidden_s, ov.comm_s);
        // invariants hold for all three
        for r in [&sync, &ov0, &ov] {
            assert!(r.comm_exposed_s <= r.comm_s);
            assert!((r.comm_exposed_s + r.comm_hidden_s - r.comm_s).abs() < 1e-12);
        }
    }

    #[test]
    fn step_records_carry_the_exposed_split() {
        let mut sink = MemorySink::default();
        RunSpec::new(
            SolverKind::Qoda,
            OperatorSpec::Quadratic { dim: 8, mu: 0.5, seed: 9 },
        )
        .nodes(2)
        .compression(CompressionSpec::Global { bits: 5, bucket: 128 })
        .steps(12)
        .network(NetworkModel::genesis_cloud(5.0))
        .exchange(ExchangeMode::Overlapped { depth: 1 })
        .compute_per_step(10.0)
        .run_observed(&mut [&mut sink]);
        assert_eq!(sink.records.len(), 12);
        for rec in &sink.records {
            assert!(rec.comm_s > 0.0);
            assert_eq!(rec.comm_exposed_s, 0.0, "fully hidden at this window");
            assert_eq!(rec.comm_hidden_s, rec.comm_s);
        }
    }
}

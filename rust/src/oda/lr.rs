//! Adaptive learning-rate schedules.
//!
//! Eq. (4):   eta_t = gamma_t = (1 + sum_{s<t} sum_k ||V̂_{k,s+1/2} -
//! ```text
//!            V̂_{k,s-1/2}||^2 / K^2)^{-1/2}
//! ```
//!
//! (Alt):     lambda_t = sum_{s<=t} ||sum_k V̂_{k,s+1/2}||^2 / K^2,
//! ```text
//!            mu_t     = sum_{s<=t} ||X_s - X_{s+1}||^2,
//!            gamma_t  = (1 + lambda_{t-2})^{q̂ - 1/2},
//!            eta_t    = (1 + lambda_{t-2} + mu_{t-2})^{-1/2},  q̂ in (0, 1/4].
//! ```


pub trait LrSchedule: Send {
    /// Called once per iteration after the new half-step dual vectors are
    /// known. `avg_diff_sq` = sum_k ||V̂_{k,t+1/2} - V̂_{k,t-1/2}||^2 / K^2;
    /// `avg_sum_sq` = ||sum_k V̂_{k,t+1/2}||^2 / K^2; `dx_sq` =
    /// ||X_t - X_{t+1}||^2.
    fn observe(&mut self, avg_diff_sq: f64, avg_sum_sq: f64, dx_sq: f64);

    /// Extrapolation step size gamma_t for the *next* iteration.
    fn gamma(&self) -> f64;

    /// Averaging step size eta_t for the *next* iteration.
    fn eta(&self) -> f64;
}

/// Constant step sizes (ablation baseline).
pub struct ConstantLr {
    pub gamma: f64,
    pub eta: f64,
}

impl LrSchedule for ConstantLr {
    fn observe(&mut self, _: f64, _: f64, _: f64) {}
    fn gamma(&self) -> f64 {
        self.gamma
    }
    fn eta(&self) -> f64 {
        self.eta
    }
}

/// The paper's main schedule (4).
#[derive(Default)]
pub struct AdaptiveLr {
    sum: f64,
}

impl LrSchedule for AdaptiveLr {
    fn observe(&mut self, avg_diff_sq: f64, _: f64, _: f64) {
        self.sum += avg_diff_sq;
    }

    fn gamma(&self) -> f64 {
        (1.0 + self.sum).powf(-0.5)
    }

    fn eta(&self) -> f64 {
        self.gamma()
    }
}

/// The (Alt) schedule of Section 6 with learning-rate separation.
/// Histories are lagged by 2 as in the definition (t-2 sums).
pub struct AltLr {
    pub q_hat: f64,
    lambda_hist: Vec<f64>,
    mu_hist: Vec<f64>,
}

impl AltLr {
    pub fn new(q_hat: f64) -> Self {
        assert!(q_hat > 0.0 && q_hat <= 0.25, "q̂ in (0, 1/4]");
        AltLr { q_hat, lambda_hist: vec![0.0], mu_hist: vec![0.0] }
    }

    fn lagged(&self, hist: &[f64]) -> f64 {
        // value of the running sum two observations ago
        let n = hist.len();
        if n >= 3 {
            hist[n - 3]
        } else {
            0.0
        }
    }
}

impl LrSchedule for AltLr {
    fn observe(&mut self, _: f64, avg_sum_sq: f64, dx_sq: f64) {
        let last_l = *self.lambda_hist.last().unwrap();
        let last_m = *self.mu_hist.last().unwrap();
        self.lambda_hist.push(last_l + avg_sum_sq);
        self.mu_hist.push(last_m + dx_sq);
    }

    fn gamma(&self) -> f64 {
        (1.0 + self.lagged(&self.lambda_hist)).powf(self.q_hat - 0.5)
    }

    fn eta(&self) -> f64 {
        (1.0 + self.lagged(&self.lambda_hist) + self.lagged(&self.mu_hist)).powf(-0.5)
    }
}

/// Helper: the observation quantities from per-node dual vectors.
pub fn observe_from_duals(
    duals: &[Vec<f64>],
    prev_duals: &[Vec<f64>],
    x_t: &[f64],
    x_next: &[f64],
) -> (f64, f64, f64) {
    let k = duals.len() as f64;
    let mut diff_sq = 0.0;
    for (d, p) in duals.iter().zip(prev_duals) {
        diff_sq += d
            .iter()
            .zip(p)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>();
    }
    let dim = duals[0].len();
    let mut sum = vec![0.0; dim];
    for d in duals {
        for (s, v) in sum.iter_mut().zip(d) {
            *s += v;
        }
    }
    let sum_sq = sum.iter().map(|v| v * v).sum::<f64>();
    let dx = x_t
        .iter()
        .zip(x_next)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>();
    (diff_sq / (k * k), sum_sq / (k * k), dx)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adaptive_is_nonincreasing_and_equal() {
        let mut lr = AdaptiveLr::default();
        assert_eq!(lr.gamma(), 1.0);
        let mut prev = 1.0;
        for i in 0..50 {
            lr.observe(0.1 * (i % 3) as f64, 0.0, 0.0);
            assert!(lr.gamma() <= prev + 1e-15);
            assert_eq!(lr.gamma(), lr.eta());
            prev = lr.gamma();
        }
    }

    #[test]
    fn adaptive_matches_formula() {
        let mut lr = AdaptiveLr::default();
        lr.observe(3.0, 0.0, 0.0);
        assert!((lr.gamma() - (4.0f64).powf(-0.5)).abs() < 1e-15);
    }

    #[test]
    fn alt_gamma_geq_eta() {
        let mut lr = AltLr::new(0.25);
        for i in 0..30 {
            lr.observe(0.0, 0.5 + (i % 5) as f64 * 0.1, 0.2);
            assert!(lr.gamma() >= lr.eta() - 1e-15, "{} {}", lr.gamma(), lr.eta());
        }
    }

    #[test]
    fn alt_lags_by_two() {
        let mut lr = AltLr::new(0.1);
        // after one observation the t-2 sums are still empty
        lr.observe(0.0, 10.0, 10.0);
        assert_eq!(lr.gamma(), 1.0);
        assert_eq!(lr.eta(), 1.0);
        lr.observe(0.0, 10.0, 10.0);
        assert_eq!(lr.gamma(), 1.0);
        // third observation sees the first sum
        lr.observe(0.0, 10.0, 10.0);
        assert!(lr.gamma() < 1.0);
    }

    #[test]
    #[should_panic]
    fn alt_rejects_bad_qhat() {
        AltLr::new(0.3);
    }

    #[test]
    fn observe_from_duals_math() {
        let duals = vec![vec![1.0, 0.0], vec![0.0, 1.0]];
        let prev = vec![vec![0.0, 0.0], vec![0.0, 0.0]];
        let (d, s, dx) =
            observe_from_duals(&duals, &prev, &[0.0, 0.0], &[1.0, 1.0]);
        // diff: (1 + 1) / 4
        assert!((d - 0.5).abs() < 1e-15);
        // sum = (1,1), ||.||^2 = 2, / 4
        assert!((s - 0.5).abs() < 1e-15);
        assert!((dx - 2.0).abs() < 1e-15);
    }
}

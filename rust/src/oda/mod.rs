//! The distributed VI solver layer, built around a step-wise [`Solver`]
//! API.
//!
//! * [`driver`] — the [`Solver`] trait (`init` / `step` / `state`), the
//!   shared [`RunDriver`] outer loop (checkpoints, ergodic averaging,
//!   wire-bit/oracle accounting, gap evaluation + early stopping, streaming
//!   [`MetricsSink`]s, optional [`NetClock`] charging every step against a
//!   pluggable topology) and the declarative [`RunSpec`] builder every
//!   consumer constructs runs through;
//! * [`qoda`] — QODA (Algorithm 1): optimistic dual averaging, one oracle
//!   call and one compressed exchange per iteration;
//! * [`qgenx`] — the Q-GenX extra-gradient baseline (two calls, two
//!   exchanges per iteration);
//! * [`baseline`] — the Adam and optimistic-Adam baselines of Figure 4;
//! * [`lr`] — the adaptive learning-rate schedules (Eq. 4 and Alt);
//! * [`source`] — `DualSource` oracles (analytic operators, synthetic
//!   gradient streams; the GAN/LM trainers implement it over real models).
//!
//! All solvers communicate through per-node [`crate::comm::CommEndpoint`]s
//! — import compressor types from [`crate::comm`] (the old
//! `oda::compress` shim is gone).

pub mod baseline;
pub mod driver;
pub mod lr;
pub mod qgenx;
pub mod qoda;
pub mod source;

pub use baseline::{AdamSolver, AdamState, OptimisticAdam};
pub use driver::{
    normalize_checkpoints, Checkpoint, CompressionSpec, GapMode, GapPolicy, LrSpec,
    MemorySink, MetricsSink, NetClock, OperatorSpec, RunDriver, RunReport, RunSpec,
    Solver, SolverKind, SolverState, StepRecord, StepStats,
};
pub use lr::{AdaptiveLr, AltLr, ConstantLr, LrSchedule};
pub use qgenx::QGenX;
pub use qoda::Qoda;
pub use source::{DualSource, OracleSource, StreamSource};

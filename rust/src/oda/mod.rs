//! The distributed VI solvers: QODA (Algorithm 1), the Q-GenX extra-gradient
//! baseline, Adam/optimistic-Adam baselines and the adaptive learning-rate
//! schedules (Eq. 4 and Alt). All solvers communicate through the shared
//! `crate::comm` wire pipeline (re-exported here for compatibility).

pub mod baseline;
pub mod compress;
pub mod lr;
pub mod qgenx;
pub mod qoda;
pub mod source;

pub use compress::{Adaptation, Compressor, IdentityCompressor, QuantCompressor};
pub use crate::comm::{CommEndpoint, CommError, WirePacket};
pub use lr::{AdaptiveLr, AltLr, ConstantLr, LrSchedule};
pub use qgenx::QGenX;
pub use qoda::{Qoda, QodaRun};
pub use source::{DualSource, OracleSource};

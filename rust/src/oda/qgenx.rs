//! Q-GenX baseline (Ramezani-Kebrya et al., 2023): distributed *extra-
//! gradient* with global quantization and an adaptive step size, as a
//! step-wise [`Solver`]. Two oracle calls AND two compressed communications
//! per iteration — the cost QODA's optimism halves (paper Section 4 /
//! Appendix A.2).

use super::driver::{exchange_mean, Solver, SolverState, StepStats};
use super::lr::LrSchedule;
use super::source::DualSource;
use crate::comm::{CommEndpoint, Compressor};

pub struct QGenX<'s> {
    pub source: &'s mut dyn DualSource,
    /// one comm endpoint per node (extrapolation and update messages share
    /// its codec and packet scratch)
    pub endpoints: Vec<CommEndpoint>,
    pub lr: Box<dyn LrSchedule>,
    // —— step-wise run state, established by `init` ——
    x: Vec<f64>,
    x_half: Vec<f64>,
    /// decoded-dual scratch, reused across nodes and steps
    hat: Vec<f64>,
    mean0: Vec<f64>,
    mean1: Vec<f64>,
}

impl<'s> QGenX<'s> {
    pub fn new(
        source: &'s mut dyn DualSource,
        compressors: Vec<Box<dyn Compressor>>,
        lr: Box<dyn LrSchedule>,
    ) -> Self {
        assert_eq!(compressors.len(), source.num_nodes());
        let endpoints = compressors.into_iter().map(CommEndpoint::new).collect();
        QGenX {
            source,
            endpoints,
            lr,
            x: Vec::new(),
            x_half: Vec::new(),
            hat: Vec::new(),
            mean0: Vec::new(),
            mean1: Vec::new(),
        }
    }
}

impl Solver for QGenX<'_> {
    fn name(&self) -> &'static str {
        "qgenx"
    }

    fn dim(&self) -> usize {
        self.source.dim()
    }

    fn num_nodes(&self) -> usize {
        self.source.num_nodes()
    }

    fn init(&mut self, x0: &[f64]) {
        let d = self.source.dim();
        assert_eq!(x0.len(), d);
        self.x = x0.to_vec();
        self.x_half = x0.to_vec();
        self.hat = Vec::with_capacity(d);
        self.mean0 = vec![0.0; d];
        self.mean1 = vec![0.0; d];
    }

    fn step(&mut self, _t: usize) -> StepStats {
        let gamma = self.lr.gamma();
        let mut stats = StepStats::default();
        // extrapolation: quantized oracle at X_t  (communication #1)
        let duals0 = self.source.duals(&self.x);
        exchange_mean(
            &mut self.endpoints,
            &duals0,
            &mut self.hat,
            &mut self.mean0,
            &mut stats,
        );
        self.x_half.clear();
        self.x_half
            .extend(self.x.iter().zip(&self.mean0).map(|(xi, g)| xi - gamma * g));
        // update: quantized oracle at X_{t+1/2}   (communication #2)
        let duals1 = self.source.duals(&self.x_half);
        exchange_mean(
            &mut self.endpoints,
            &duals1,
            &mut self.hat,
            &mut self.mean1,
            &mut stats,
        );
        // adaptive step statistics: ||mean1 - mean0||^2 (the Q-GenX
        // gradient-variation term)
        let diff_sq: f64 = self
            .mean1
            .iter()
            .zip(&self.mean0)
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        self.lr.observe(diff_sq, 0.0, 0.0);
        for (xi, g) in self.x.iter_mut().zip(&self.mean1) {
            *xi -= gamma * g;
        }
        stats
    }

    fn state(&self) -> SolverState<'_> {
        SolverState { x: &self.x, avg_point: &self.x_half }
    }

    fn oracle_calls(&self) -> u64 {
        self.source.calls()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{IdentityCompressor, QuantCompressor};
    use crate::oda::driver::RunDriver;
    use crate::oda::lr::AdaptiveLr;
    use crate::oda::source::OracleSource;
    use crate::quant::layer_map::LayerMap;
    use crate::stats::rng::Rng;
    use crate::stats::vecops::{l2_norm64, sub};
    use crate::vi::noise::NoiseModel;
    use crate::vi::operator::{BilinearGame, Operator, QuadraticOperator};

    fn identity_boxes(k: usize) -> Vec<Box<dyn Compressor>> {
        (0..k).map(|_| Box::new(IdentityCompressor::new()) as Box<dyn Compressor>).collect()
    }

    #[test]
    fn converges_on_quadratic() {
        let mut rng = Rng::new(1);
        let op = QuadraticOperator::random(8, 0.5, &mut rng);
        let mut src = OracleSource::new(&op, 2, NoiseModel::None, 2);
        let mut solver =
            QGenX::new(&mut src, identity_boxes(2), Box::new(AdaptiveLr::default()));
        let run = RunDriver::new().run(&mut solver, &vec![0.0; 8], 800);
        let err = l2_norm64(&sub(&run.xbar, &op.sol));
        assert!(err < 0.25 * l2_norm64(&op.sol), "{err}");
    }

    #[test]
    fn two_oracle_calls_per_iter() {
        let mut rng = Rng::new(3);
        let op = QuadraticOperator::random(4, 0.5, &mut rng);
        let mut src = OracleSource::new(&op, 3, NoiseModel::None, 4);
        let mut solver =
            QGenX::new(&mut src, identity_boxes(3), Box::new(AdaptiveLr::default()));
        let run = RunDriver::new().run(&mut solver, &vec![0.0; 4], 100);
        assert_eq!(run.oracle_calls, 600, "extra-gradient pays 2 calls/iter");
    }

    #[test]
    fn qgenx_communicates_twice_as_much_as_qoda() {
        // same compressor, same steps: Q-GenX wire bits ≈ 2x QODA wire bits
        let mut rng = Rng::new(5);
        let op = QuadraticOperator::random(16, 0.5, &mut rng);
        let map = LayerMap::single(16);
        let mk = |seed| -> Vec<Box<dyn Compressor>> {
            vec![Box::new(QuantCompressor::global_bits(&map, 5, 128, seed))
                as Box<dyn Compressor>]
        };
        let mut src1 = OracleSource::new(&op, 1, NoiseModel::None, 6);
        let mut qgenx =
            QGenX::new(&mut src1, mk(1), Box::new(AdaptiveLr::default()));
        let bits_qgenx =
            RunDriver::new().run(&mut qgenx, &vec![0.0; 16], 200).total_bits;
        let mut src2 = OracleSource::new(&op, 1, NoiseModel::None, 6);
        let mut qoda = crate::oda::qoda::Qoda::new(
            &mut src2,
            mk(1),
            Box::new(AdaptiveLr::default()),
        );
        let bits_qoda =
            RunDriver::new().run(&mut qoda, &vec![0.0; 16], 200).total_bits;
        let ratio = bits_qgenx as f64 / bits_qoda as f64;
        assert!((ratio - 2.0).abs() < 0.2, "ratio {ratio}");
    }

    #[test]
    fn handles_bilinear() {
        let mut rng = Rng::new(7);
        let op = BilinearGame::random(4, &mut rng);
        let mut src = OracleSource::new(&op, 1, NoiseModel::None, 8);
        let mut solver =
            QGenX::new(&mut src, identity_boxes(1), Box::new(AdaptiveLr::default()));
        let x0 = vec![1.0; 8];
        let run = RunDriver::new().run(&mut solver, &x0, 1500);
        let res = l2_norm64(&op.apply_vec(&run.xbar));
        assert!(res < 0.2 * l2_norm64(&op.apply_vec(&x0)), "{res}");
    }
}

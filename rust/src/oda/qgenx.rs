//! Q-GenX baseline (Ramezani-Kebrya et al., 2023): distributed *extra-
//! gradient* with global quantization and an adaptive step size. Two oracle
//! calls AND two compressed communications per iteration — the cost QODA's
//! optimism halves (paper Section 4 / Appendix A.2).

use super::lr::LrSchedule;
use super::qoda::{Checkpoint, QodaRun};
use super::source::DualSource;
use crate::comm::{CommEndpoint, Compressor};

pub struct QGenX<'s> {
    pub source: &'s mut dyn DualSource,
    /// one comm endpoint per node (extrapolation and update messages share
    /// its codec and packet scratch)
    pub endpoints: Vec<CommEndpoint>,
    pub lr: Box<dyn LrSchedule>,
}

impl<'s> QGenX<'s> {
    pub fn new(
        source: &'s mut dyn DualSource,
        compressors: Vec<Box<dyn Compressor>>,
        lr: Box<dyn LrSchedule>,
    ) -> Self {
        assert_eq!(compressors.len(), source.num_nodes());
        let endpoints = compressors.into_iter().map(CommEndpoint::new).collect();
        QGenX { source, endpoints, lr }
    }

    pub fn run(&mut self, x0: &[f64], steps: usize, checkpoints: &[usize]) -> QodaRun {
        let d = self.source.dim();
        let k = self.source.num_nodes();
        let kf = k as f64;
        let mut x = x0.to_vec();
        let mut xbar_sum = vec![0.0; d];
        let mut total_bits = 0u64;
        let mut out_ckpts = Vec::new();
        let mut ck_iter = checkpoints.iter().peekable();
        // decoded-dual scratch, reused across nodes and steps
        let mut hat: Vec<f64> = Vec::with_capacity(d);

        for t in 1..=steps {
            let gamma = self.lr.gamma();
            // extrapolation: quantized oracle at X_t  (communication #1)
            let duals0 = self.source.duals(&x);
            let mut mean0 = vec![0.0; d];
            for (kk, dual) in duals0.iter().enumerate() {
                let bits = self.endpoints[kk]
                    .roundtrip_into(dual, &mut hat)
                    .expect("comm loopback roundtrip");
                total_bits += bits as u64;
                for (m, v) in mean0.iter_mut().zip(&hat) {
                    *m += v / kf;
                }
            }
            let x_half: Vec<f64> =
                x.iter().zip(&mean0).map(|(xi, g)| xi - gamma * g).collect();
            // update: quantized oracle at X_{t+1/2}   (communication #2)
            let duals1 = self.source.duals(&x_half);
            let mut mean1 = vec![0.0; d];
            for (kk, dual) in duals1.iter().enumerate() {
                let bits = self.endpoints[kk]
                    .roundtrip_into(dual, &mut hat)
                    .expect("comm loopback roundtrip");
                total_bits += bits as u64;
                for (m, v) in mean1.iter_mut().zip(&hat) {
                    *m += v / kf;
                }
            }
            // adaptive step statistics: ||mean1 - mean0||^2 (the Q-GenX
            // gradient-variation term)
            let diff_sq: f64 = mean1
                .iter()
                .zip(&mean0)
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            self.lr.observe(diff_sq, 0.0, 0.0);
            for i in 0..d {
                x[i] -= gamma * mean1[i];
            }
            for (s, v) in xbar_sum.iter_mut().zip(&x_half) {
                *s += v;
            }
            if ck_iter.peek() == Some(&&t) {
                ck_iter.next();
                out_ckpts.push(Checkpoint {
                    t,
                    xbar: xbar_sum.iter().map(|s| s / t as f64).collect(),
                    total_bits,
                    oracle_calls: self.source.calls(),
                });
            }
        }
        let xbar: Vec<f64> = xbar_sum.iter().map(|s| s / steps as f64).collect();
        QodaRun {
            checkpoints: out_ckpts,
            xbar,
            x_last: x,
            total_bits,
            oracle_calls: self.source.calls(),
            bits_per_iter_node: total_bits as f64 / (steps as f64 * kf),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oda::compress::{Compressor, IdentityCompressor, QuantCompressor};
    use crate::oda::lr::AdaptiveLr;
    use crate::oda::source::OracleSource;
    use crate::quant::layer_map::LayerMap;
    use crate::stats::rng::Rng;
    use crate::stats::vecops::{l2_norm64, sub};
    use crate::vi::noise::NoiseModel;
    use crate::vi::operator::{BilinearGame, Operator, QuadraticOperator};

    fn identity_boxes(k: usize) -> Vec<Box<dyn Compressor>> {
        (0..k).map(|_| Box::new(IdentityCompressor) as Box<dyn Compressor>).collect()
    }

    #[test]
    fn converges_on_quadratic() {
        let mut rng = Rng::new(1);
        let op = QuadraticOperator::random(8, 0.5, &mut rng);
        let mut src = OracleSource::new(&op, 2, NoiseModel::None, 2);
        let mut solver =
            QGenX::new(&mut src, identity_boxes(2), Box::new(AdaptiveLr::default()));
        let run = solver.run(&vec![0.0; 8], 800, &[]);
        let err = l2_norm64(&sub(&run.xbar, &op.sol));
        assert!(err < 0.25 * l2_norm64(&op.sol), "{err}");
    }

    #[test]
    fn two_oracle_calls_per_iter() {
        let mut rng = Rng::new(3);
        let op = QuadraticOperator::random(4, 0.5, &mut rng);
        let mut src = OracleSource::new(&op, 3, NoiseModel::None, 4);
        let mut solver =
            QGenX::new(&mut src, identity_boxes(3), Box::new(AdaptiveLr::default()));
        let run = solver.run(&vec![0.0; 4], 100, &[]);
        assert_eq!(run.oracle_calls, 600, "extra-gradient pays 2 calls/iter");
    }

    #[test]
    fn qgenx_communicates_twice_as_much_as_qoda() {
        // same compressor, same steps: Q-GenX wire bits ≈ 2x QODA wire bits
        let mut rng = Rng::new(5);
        let op = QuadraticOperator::random(16, 0.5, &mut rng);
        let map = LayerMap::single(16);
        let mk = |seed| -> Vec<Box<dyn Compressor>> {
            vec![Box::new(QuantCompressor::global_bits(&map, 5, 128, seed))
                as Box<dyn Compressor>]
        };
        let mut src1 = OracleSource::new(&op, 1, NoiseModel::None, 6);
        let bits_qgenx =
            QGenX::new(&mut src1, mk(1), Box::new(AdaptiveLr::default()))
                .run(&vec![0.0; 16], 200, &[])
                .total_bits;
        let mut src2 = OracleSource::new(&op, 1, NoiseModel::None, 6);
        let bits_qoda = crate::oda::qoda::Qoda::new(
            &mut src2,
            mk(1),
            Box::new(AdaptiveLr::default()),
        )
        .run(&vec![0.0; 16], 200, &[])
        .total_bits;
        let ratio = bits_qgenx as f64 / bits_qoda as f64;
        assert!((ratio - 2.0).abs() < 0.2, "ratio {ratio}");
    }

    #[test]
    fn handles_bilinear() {
        let mut rng = Rng::new(7);
        let op = BilinearGame::random(4, &mut rng);
        let mut src = OracleSource::new(&op, 1, NoiseModel::None, 8);
        let mut solver =
            QGenX::new(&mut src, identity_boxes(1), Box::new(AdaptiveLr::default()));
        let x0 = vec![1.0; 8];
        let run = solver.run(&x0, 1500, &[]);
        let res = l2_norm64(&op.apply_vec(&run.xbar));
        assert!(res < 0.2 * l2_norm64(&op.apply_vec(&x0)), "{res}");
    }
}

//! QODA — Quantized Optimistic Dual Averaging (Algorithm 1).
//!
//! Per iteration (ODA):
//!   X_{t+1/2} = X_t - gamma_t * (1/K) sum_k V̂_{k,t-1/2}     (optimism: the
//! ```text
//!              stored *previous* half-step duals — no extra oracle call)
//! ```
//!   V_{k,t+1/2} = g_k(X_{t+1/2})                       (one oracle call)
//!   V̂_{k,t+1/2} = DEC(ENC(Q_{L^{t,M}}(V_{k,t+1/2})))   (compressed wire)
//!   Y_{t+1} = Y_t - (1/K) sum_k V̂_{k,t+1/2}
//!   X_{t+1} = X_1 + eta_{t+1} Y_{t+1}
//!
//! with the adaptive learning rates of Eq. (4) or (Alt). The candidate
//! solution is the ergodic average X̄_{T+1/2}.

use super::lr::{observe_from_duals, LrSchedule};
use super::source::DualSource;
use crate::comm::{CommEndpoint, Compressor};

/// Per-checkpoint record for convergence curves.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub t: usize,
    pub xbar: Vec<f64>,
    pub total_bits: u64,
    pub oracle_calls: u64,
}

pub struct QodaRun {
    pub checkpoints: Vec<Checkpoint>,
    pub xbar: Vec<f64>,
    pub x_last: Vec<f64>,
    pub total_bits: u64,
    pub oracle_calls: u64,
    /// average wire bits per node per iteration
    pub bits_per_iter_node: f64,
}

pub struct Qoda<'s> {
    pub source: &'s mut dyn DualSource,
    /// one comm endpoint (codec + packet scratch) per node
    pub endpoints: Vec<CommEndpoint>,
    pub lr: Box<dyn LrSchedule>,
    /// Algorithm 1's update-step set U as a period (0 = never); forwarded to
    /// the codecs' `update_levels`
    pub update_every: usize,
}

impl<'s> Qoda<'s> {
    pub fn new(
        source: &'s mut dyn DualSource,
        compressors: Vec<Box<dyn Compressor>>,
        lr: Box<dyn LrSchedule>,
    ) -> Self {
        assert_eq!(compressors.len(), source.num_nodes());
        let endpoints = compressors.into_iter().map(CommEndpoint::new).collect();
        Qoda { source, endpoints, lr, update_every: 0 }
    }

    /// Run T iterations from X_1 = x0, recording checkpoints at the given
    /// iteration numbers (sorted).
    pub fn run(&mut self, x0: &[f64], steps: usize, checkpoints: &[usize]) -> QodaRun {
        let d = self.source.dim();
        let k = self.source.num_nodes();
        let kf = k as f64;
        let x1 = x0.to_vec();
        let mut x = x0.to_vec();
        let mut y = vec![0.0; d];
        // V̂_{k,1/2} = 0 (the paper's initialization)
        let mut prev_hat: Vec<Vec<f64>> = vec![vec![0.0; d]; k];
        // decoded-dual buffers, swapped with prev_hat each step (no per-step
        // allocation: the comm endpoints recycle their packet scratch too)
        let mut hats: Vec<Vec<f64>> = vec![vec![0.0; d]; k];
        let mut xbar_sum = vec![0.0; d];
        let mut total_bits = 0u64;
        let mut out_ckpts = Vec::new();
        let mut last_dx_sq = 0.0;
        let mut ck_iter = checkpoints.iter().peekable();

        for t in 1..=steps {
            let gamma = self.lr.gamma();
            // extrapolation with the stored previous duals (lines 9-10)
            let mut x_half = x.clone();
            for kk in 0..k {
                for (xh, v) in x_half.iter_mut().zip(&prev_hat[kk]) {
                    *xh -= gamma * v / kf;
                }
            }
            // oracle + comm pipeline roundtrip (lines 11-15): ENC to a wire
            // packet, loopback DEC of the same packet — the bits charged are
            // the packet's actual payload size
            let duals = self.source.duals(&x_half);
            for (kk, dual) in duals.iter().enumerate() {
                let bits = self.endpoints[kk]
                    .roundtrip_into(dual, &mut hats[kk])
                    .expect("comm loopback roundtrip");
                total_bits += bits as u64;
            }
            // learning-rate statistics (Eq. 4 / Alt); dx lagged one step
            let (diff_sq, sum_sq, _) =
                observe_from_duals(&hats, &prev_hat, &x, &x);
            self.lr.observe(diff_sq, sum_sq, last_dx_sq);
            // dual averaging (lines 17-18)
            for kk in 0..k {
                for (yi, v) in y.iter_mut().zip(&hats[kk]) {
                    *yi -= v / kf;
                }
            }
            let eta = self.lr.eta();
            let mut x_next = vec![0.0; d];
            for i in 0..d {
                x_next[i] = x1[i] + eta * y[i];
            }
            last_dx_sq = x
                .iter()
                .zip(&x_next)
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            x = x_next;
            std::mem::swap(&mut prev_hat, &mut hats);
            for (s, v) in xbar_sum.iter_mut().zip(&x_half) {
                *s += v;
            }
            // explicit update-step set U (line 2): codecs may also
            // self-schedule; this drives them at a fixed cadence
            if self.update_every > 0 && t % self.update_every == 0 {
                for ep in &mut self.endpoints {
                    ep.update_levels();
                }
            }
            if ck_iter.peek() == Some(&&t) {
                ck_iter.next();
                out_ckpts.push(Checkpoint {
                    t,
                    xbar: xbar_sum.iter().map(|s| s / t as f64).collect(),
                    total_bits,
                    oracle_calls: self.source.calls(),
                });
            }
        }
        let xbar: Vec<f64> = xbar_sum.iter().map(|s| s / steps as f64).collect();
        QodaRun {
            checkpoints: out_ckpts,
            xbar,
            x_last: x,
            total_bits,
            oracle_calls: self.source.calls(),
            bits_per_iter_node: total_bits as f64 / (steps as f64 * kf),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::oda::compress::{IdentityCompressor, QuantCompressor};
    use crate::oda::lr::{AdaptiveLr, AltLr};
    use crate::oda::source::OracleSource;
    use crate::quant::layer_map::LayerMap;
    use crate::stats::rng::Rng;
    use crate::stats::vecops::{l2_norm64, sub};
    use crate::vi::noise::NoiseModel;
    use crate::vi::operator::{BilinearGame, Operator, QuadraticOperator};

    fn identity_boxes(k: usize) -> Vec<Box<dyn Compressor>> {
        (0..k).map(|_| Box::new(IdentityCompressor) as Box<dyn Compressor>).collect()
    }

    #[test]
    fn converges_on_quadratic_no_noise() {
        let mut rng = Rng::new(1);
        let op = QuadraticOperator::random(8, 0.5, &mut rng);
        let sol = op.sol.clone();
        let mut src = OracleSource::new(&op, 2, NoiseModel::None, 2);
        let mut solver =
            Qoda::new(&mut src, identity_boxes(2), Box::new(AdaptiveLr::default()));
        let run = solver.run(&vec![0.0; 8], 800, &[]);
        let err = l2_norm64(&sub(&run.xbar, &sol));
        let err0 = l2_norm64(&sol);
        assert!(err < 0.2 * err0, "err {err} vs initial {err0}");
    }

    #[test]
    fn converges_on_bilinear_game() {
        // bilinear games cycle under naive gradient steps; optimism fixes it
        let mut rng = Rng::new(3);
        let op = BilinearGame::random(5, &mut rng);
        let mut src = OracleSource::new(&op, 1, NoiseModel::None, 4);
        let mut solver =
            Qoda::new(&mut src, identity_boxes(1), Box::new(AdaptiveLr::default()));
        let x0 = vec![1.0; 10];
        let run = solver.run(&x0, 2000, &[]);
        let res = l2_norm64(&op.apply_vec(&run.xbar));
        let res0 = l2_norm64(&op.apply_vec(&x0));
        assert!(res < 0.15 * res0, "residual {res} vs {res0}");
    }

    #[test]
    fn converges_with_quantization() {
        let mut rng = Rng::new(5);
        let op = QuadraticOperator::random(16, 0.5, &mut rng);
        let sol = op.sol.clone();
        let mut src = OracleSource::new(&op, 2, NoiseModel::Absolute { sigma: 0.2 }, 6);
        let map = LayerMap::single(16);
        let comps: Vec<Box<dyn Compressor>> = (0..2)
            .map(|i| {
                Box::new(QuantCompressor::global_bits(&map, 6, 128, 10 + i))
                    as Box<dyn Compressor>
            })
            .collect();
        let mut solver = Qoda::new(&mut src, comps, Box::new(AdaptiveLr::default()));
        let run = solver.run(&vec![0.0; 16], 1500, &[]);
        let err = l2_norm64(&sub(&run.xbar, &sol));
        let err0 = l2_norm64(&sol);
        assert!(err < 0.35 * err0, "err {err} vs {err0}");
        assert!(run.total_bits > 0);
        // compressed wire must be well below 32 bits/coord
        assert!(run.bits_per_iter_node < 16.0 * 16.0, "{}", run.bits_per_iter_node);
    }

    #[test]
    fn one_oracle_call_per_node_per_iter() {
        // the optimism claim: T iterations => exactly T*K oracle calls
        let mut rng = Rng::new(7);
        let op = QuadraticOperator::random(4, 0.5, &mut rng);
        let mut src = OracleSource::new(&op, 3, NoiseModel::None, 8);
        let mut solver =
            Qoda::new(&mut src, identity_boxes(3), Box::new(AdaptiveLr::default()));
        let run = solver.run(&vec![0.0; 4], 100, &[]);
        assert_eq!(run.oracle_calls, 300);
    }

    #[test]
    fn checkpoints_recorded_in_order() {
        let mut rng = Rng::new(9);
        let op = QuadraticOperator::random(4, 0.5, &mut rng);
        let mut src = OracleSource::new(&op, 1, NoiseModel::None, 10);
        let mut solver =
            Qoda::new(&mut src, identity_boxes(1), Box::new(AdaptiveLr::default()));
        let run = solver.run(&vec![0.0; 4], 50, &[10, 20, 50]);
        assert_eq!(run.checkpoints.len(), 3);
        assert_eq!(run.checkpoints[0].t, 10);
        assert_eq!(run.checkpoints[2].t, 50);
        assert!(run.checkpoints[0].total_bits <= run.checkpoints[2].total_bits);
    }

    #[test]
    fn alt_schedule_converges_under_relative_noise() {
        let mut rng = Rng::new(11);
        let op = QuadraticOperator::random(8, 1.0, &mut rng);
        let sol = op.sol.clone();
        let mut src = OracleSource::new(&op, 2, NoiseModel::Relative { sigma_r: 0.5 }, 12);
        let mut solver =
            Qoda::new(&mut src, identity_boxes(2), Box::new(AltLr::new(0.25)));
        let run = solver.run(&vec![0.0; 8], 1500, &[]);
        let err = l2_norm64(&sub(&run.x_last, &sol));
        let err0 = l2_norm64(&sol);
        assert!(err < 0.3 * err0, "err {err} vs {err0}");
    }
}

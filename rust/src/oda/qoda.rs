//! QODA — Quantized Optimistic Dual Averaging (Algorithm 1), as a
//! step-wise [`Solver`] state machine (the outer loop — checkpoints,
//! ergodic averaging, accounting — lives in [`super::driver::RunDriver`]).
//!
//! Per iteration (ODA):
//!   X_{t+1/2} = X_t - gamma_t * (1/K) sum_k V̂_{k,t-1/2}     (optimism: the
//! ```text
//!              stored *previous* half-step duals — no extra oracle call)
//! ```
//!   V_{k,t+1/2} = g_k(X_{t+1/2})                       (one oracle call)
//!   V̂_{k,t+1/2} = DEC(ENC(Q_{L^{t,M}}(V_{k,t+1/2})))   (compressed wire)
//!   Y_{t+1} = Y_t - (1/K) sum_k V̂_{k,t+1/2}
//!   X_{t+1} = X_1 + eta_{t+1} Y_{t+1}
//!
//! with the adaptive learning rates of Eq. (4) or (Alt). The candidate
//! solution is the ergodic average X̄_{T+1/2}, which the driver accumulates
//! from this solver's `avg_point` (= X_{t+1/2}).

use super::driver::{Solver, SolverState, StepStats};
use super::lr::{observe_from_duals, LrSchedule};
use super::source::DualSource;
use crate::comm::{CommEndpoint, Compressor};

pub struct Qoda<'s> {
    pub source: &'s mut dyn DualSource,
    /// one comm endpoint (codec + packet scratch) per node
    pub endpoints: Vec<CommEndpoint>,
    pub lr: Box<dyn LrSchedule>,
    /// Algorithm 1's update-step set U as a period (0 = never); forwarded to
    /// the codecs' `update_levels`
    pub update_every: usize,
    // —— step-wise run state, established by `init` ——
    x1: Vec<f64>,
    x: Vec<f64>,
    y: Vec<f64>,
    /// V̂_{k,t-1/2}: the stored previous half-step duals
    prev_hat: Vec<Vec<f64>>,
    /// decoded-dual buffers, swapped with `prev_hat` each step (no per-step
    /// allocation: the comm endpoints recycle their packet scratch too)
    hats: Vec<Vec<f64>>,
    x_half: Vec<f64>,
    x_next: Vec<f64>,
    last_dx_sq: f64,
}

impl<'s> Qoda<'s> {
    pub fn new(
        source: &'s mut dyn DualSource,
        compressors: Vec<Box<dyn Compressor>>,
        lr: Box<dyn LrSchedule>,
    ) -> Self {
        assert_eq!(compressors.len(), source.num_nodes());
        let endpoints = compressors.into_iter().map(CommEndpoint::new).collect();
        Qoda {
            source,
            endpoints,
            lr,
            update_every: 0,
            x1: Vec::new(),
            x: Vec::new(),
            y: Vec::new(),
            prev_hat: Vec::new(),
            hats: Vec::new(),
            x_half: Vec::new(),
            x_next: Vec::new(),
            last_dx_sq: 0.0,
        }
    }
}

impl Solver for Qoda<'_> {
    fn name(&self) -> &'static str {
        "qoda"
    }

    fn dim(&self) -> usize {
        self.source.dim()
    }

    fn num_nodes(&self) -> usize {
        self.source.num_nodes()
    }

    fn init(&mut self, x0: &[f64]) {
        let d = self.source.dim();
        let k = self.source.num_nodes();
        assert_eq!(x0.len(), d);
        self.x1 = x0.to_vec();
        self.x = x0.to_vec();
        self.y = vec![0.0; d];
        // V̂_{k,1/2} = 0 (the paper's initialization)
        self.prev_hat = vec![vec![0.0; d]; k];
        self.hats = vec![vec![0.0; d]; k];
        self.x_half = x0.to_vec();
        self.x_next = vec![0.0; d];
        self.last_dx_sq = 0.0;
    }

    fn step(&mut self, t: usize) -> StepStats {
        let k = self.endpoints.len();
        let kf = k as f64;
        let gamma = self.lr.gamma();
        // extrapolation with the stored previous duals (lines 9-10)
        self.x_half.clone_from(&self.x);
        for kk in 0..k {
            for (xh, v) in self.x_half.iter_mut().zip(&self.prev_hat[kk]) {
                *xh -= gamma * v / kf;
            }
        }
        // oracle + comm pipeline roundtrip (lines 11-15): ENC to a wire
        // packet, loopback DEC of the same packet — the bits charged are
        // the packet's actual payload size
        let duals = self.source.duals(&self.x_half);
        let mut stats = StepStats::default();
        for (kk, dual) in duals.iter().enumerate() {
            let bits = self.endpoints[kk]
                .roundtrip_into(dual, &mut self.hats[kk])
                .expect("comm loopback roundtrip");
            stats.bits += bits as u64;
            for (v, h) in dual.iter().zip(&self.hats[kk]) {
                stats.quant_err_sq += (v - h) * (v - h);
                stats.dual_norm_sq += v * v;
            }
        }
        // learning-rate statistics (Eq. 4 / Alt); dx lagged one step
        let (diff_sq, sum_sq, _) =
            observe_from_duals(&self.hats, &self.prev_hat, &self.x, &self.x);
        self.lr.observe(diff_sq, sum_sq, self.last_dx_sq);
        // dual averaging (lines 17-18)
        for kk in 0..k {
            for (yi, v) in self.y.iter_mut().zip(&self.hats[kk]) {
                *yi -= v / kf;
            }
        }
        let eta = self.lr.eta();
        for ((xn, x1), yv) in self.x_next.iter_mut().zip(&self.x1).zip(&self.y) {
            *xn = x1 + eta * yv;
        }
        self.last_dx_sq = self
            .x
            .iter()
            .zip(&self.x_next)
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        std::mem::swap(&mut self.x, &mut self.x_next);
        std::mem::swap(&mut self.prev_hat, &mut self.hats);
        // explicit update-step set U (line 2): codecs may also
        // self-schedule; this drives them at a fixed cadence
        if self.update_every > 0 && t % self.update_every == 0 {
            for ep in &mut self.endpoints {
                ep.update_levels();
            }
        }
        stats
    }

    fn state(&self) -> SolverState<'_> {
        SolverState { x: &self.x, avg_point: &self.x_half }
    }

    fn oracle_calls(&self) -> u64 {
        self.source.calls()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{IdentityCompressor, QuantCompressor};
    use crate::oda::driver::RunDriver;
    use crate::oda::lr::{AdaptiveLr, AltLr};
    use crate::oda::source::OracleSource;
    use crate::quant::layer_map::LayerMap;
    use crate::stats::rng::Rng;
    use crate::stats::vecops::{l2_norm64, sub};
    use crate::vi::noise::NoiseModel;
    use crate::vi::operator::{BilinearGame, Operator, QuadraticOperator};

    fn identity_boxes(k: usize) -> Vec<Box<dyn Compressor>> {
        (0..k).map(|_| Box::new(IdentityCompressor::new()) as Box<dyn Compressor>).collect()
    }

    #[test]
    fn converges_on_quadratic_no_noise() {
        let mut rng = Rng::new(1);
        let op = QuadraticOperator::random(8, 0.5, &mut rng);
        let sol = op.sol.clone();
        let mut src = OracleSource::new(&op, 2, NoiseModel::None, 2);
        let mut solver =
            Qoda::new(&mut src, identity_boxes(2), Box::new(AdaptiveLr::default()));
        let run = RunDriver::new().run(&mut solver, &vec![0.0; 8], 800);
        let err = l2_norm64(&sub(&run.xbar, &sol));
        let err0 = l2_norm64(&sol);
        assert!(err < 0.2 * err0, "err {err} vs initial {err0}");
    }

    #[test]
    fn converges_on_bilinear_game() {
        // bilinear games cycle under naive gradient steps; optimism fixes it
        let mut rng = Rng::new(3);
        let op = BilinearGame::random(5, &mut rng);
        let mut src = OracleSource::new(&op, 1, NoiseModel::None, 4);
        let mut solver =
            Qoda::new(&mut src, identity_boxes(1), Box::new(AdaptiveLr::default()));
        let x0 = vec![1.0; 10];
        let run = RunDriver::new().run(&mut solver, &x0, 2000);
        let res = l2_norm64(&op.apply_vec(&run.xbar));
        let res0 = l2_norm64(&op.apply_vec(&x0));
        assert!(res < 0.15 * res0, "residual {res} vs {res0}");
    }

    #[test]
    fn converges_with_quantization() {
        let mut rng = Rng::new(5);
        let op = QuadraticOperator::random(16, 0.5, &mut rng);
        let sol = op.sol.clone();
        let mut src = OracleSource::new(&op, 2, NoiseModel::Absolute { sigma: 0.2 }, 6);
        let map = LayerMap::single(16);
        let comps: Vec<Box<dyn Compressor>> = (0..2)
            .map(|i| {
                Box::new(QuantCompressor::global_bits(&map, 6, 128, 10 + i))
                    as Box<dyn Compressor>
            })
            .collect();
        let mut solver = Qoda::new(&mut src, comps, Box::new(AdaptiveLr::default()));
        let run = RunDriver::new().run(&mut solver, &vec![0.0; 16], 1500);
        let err = l2_norm64(&sub(&run.xbar, &sol));
        let err0 = l2_norm64(&sol);
        assert!(err < 0.35 * err0, "err {err} vs {err0}");
        assert!(run.total_bits > 0);
        // compressed wire must be well below 32 bits/coord
        assert!(run.bits_per_iter_node < 16.0 * 16.0, "{}", run.bits_per_iter_node);
        // the driver's fidelity accounting: small but nonzero wire error
        let rel = run.rel_quant_error();
        assert!(rel > 0.0 && rel < 0.2, "rel quant error {rel}");
    }

    #[test]
    fn one_oracle_call_per_node_per_iter() {
        // the optimism claim: T iterations => exactly T*K oracle calls
        let mut rng = Rng::new(7);
        let op = QuadraticOperator::random(4, 0.5, &mut rng);
        let mut src = OracleSource::new(&op, 3, NoiseModel::None, 8);
        let mut solver =
            Qoda::new(&mut src, identity_boxes(3), Box::new(AdaptiveLr::default()));
        let run = RunDriver::new().run(&mut solver, &vec![0.0; 4], 100);
        assert_eq!(run.oracle_calls, 300);
    }

    #[test]
    fn checkpoints_recorded_in_order() {
        let mut rng = Rng::new(9);
        let op = QuadraticOperator::random(4, 0.5, &mut rng);
        let mut src = OracleSource::new(&op, 1, NoiseModel::None, 10);
        let mut solver =
            Qoda::new(&mut src, identity_boxes(1), Box::new(AdaptiveLr::default()));
        let run = RunDriver::new()
            .checkpoints(&[10, 20, 50])
            .run(&mut solver, &vec![0.0; 4], 50);
        assert_eq!(run.checkpoints.len(), 3);
        assert_eq!(run.checkpoints[0].t, 10);
        assert_eq!(run.checkpoints[2].t, 50);
        assert!(run.checkpoints[0].total_bits <= run.checkpoints[2].total_bits);
    }

    #[test]
    fn alt_schedule_converges_under_relative_noise() {
        let mut rng = Rng::new(11);
        let op = QuadraticOperator::random(8, 1.0, &mut rng);
        let sol = op.sol.clone();
        let mut src = OracleSource::new(&op, 2, NoiseModel::Relative { sigma_r: 0.5 }, 12);
        let mut solver =
            Qoda::new(&mut src, identity_boxes(2), Box::new(AltLr::new(0.25)));
        let run = RunDriver::new().run(&mut solver, &vec![0.0; 8], 1500);
        let err = l2_norm64(&sub(&run.x_last, &sol));
        let err0 = l2_norm64(&sol);
        assert!(err < 0.3 * err0, "err {err} vs {err0}");
    }

    #[test]
    fn stepping_is_resumable() {
        // driving 2 x 50 steps through the trait by hand matches one driven
        // 100-step run — the state machine carries everything across
        let mut rng = Rng::new(13);
        let op = QuadraticOperator::random(6, 0.5, &mut rng);
        let x0 = vec![0.0; 6];

        let mut src_a = OracleSource::new(&op, 2, NoiseModel::Absolute { sigma: 0.1 }, 14);
        let mut a =
            Qoda::new(&mut src_a, identity_boxes(2), Box::new(AdaptiveLr::default()));
        let run = RunDriver::new().run(&mut a, &x0, 100);

        let mut src_b = OracleSource::new(&op, 2, NoiseModel::Absolute { sigma: 0.1 }, 14);
        let mut b =
            Qoda::new(&mut src_b, identity_boxes(2), Box::new(AdaptiveLr::default()));
        b.init(&x0);
        for t in 1..=100 {
            b.step(t);
        }
        assert_eq!(run.x_last, b.state().x.to_vec());
    }
}

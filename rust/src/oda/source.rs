//! Sources of per-node stochastic dual vectors. The VI rate harness uses
//! `OracleSource` (K noisy oracles over an analytic operator); the GAN and
//! LM drivers implement this trait over the PJRT-loaded L2 models.

use crate::vi::noise::{NoiseModel, Oracle};
use crate::vi::operator::Operator;

/// K-node stochastic dual-vector source: duals(x)[k] = g_k(x; omega_{k,t}).
pub trait DualSource {
    fn dim(&self) -> usize;
    fn num_nodes(&self) -> usize;
    /// One oracle call per node at the query point.
    fn duals(&mut self, x: &[f64]) -> Vec<Vec<f64>>;
    /// Total oracle calls so far (gradient computations — the cost Q-GenX
    /// pays twice per iteration).
    fn calls(&self) -> u64;
}

/// K independent noisy oracles sharing one operator (the data-parallel
/// homogeneous setting A_k = A of the paper's analysis).
pub struct OracleSource<'a> {
    oracles: Vec<Oracle<'a>>,
    dim: usize,
}

impl<'a> OracleSource<'a> {
    pub fn new(op: &'a dyn Operator, k: usize, noise: NoiseModel, seed: u64) -> Self {
        let oracles = (0..k)
            .map(|i| Oracle::new(op, noise, seed ^ (0x9E37 + i as u64 * 0x79B9)))
            .collect();
        OracleSource { oracles, dim: op.dim() }
    }
}

impl<'a> DualSource for OracleSource<'a> {
    fn dim(&self) -> usize {
        self.dim
    }

    fn num_nodes(&self) -> usize {
        self.oracles.len()
    }

    fn duals(&mut self, x: &[f64]) -> Vec<Vec<f64>> {
        self.oracles.iter_mut().map(|o| o.sample(x)).collect()
    }

    fn calls(&self) -> u64 {
        self.oracles.iter().map(|o| o.calls).sum()
    }
}

/// A dual source that ignores the query point: synthesizes (or replays) a
/// per-node gradient stream. Lets compressor-fidelity ablations and codec
/// audits run through the same `Solver`/`RunDriver` path as oracle-backed
/// runs — drive it with a zero learning rate so the iterate stays put.
pub struct StreamSource<F: FnMut(usize) -> Vec<f64>> {
    gen: F,
    dim: usize,
    nodes: usize,
    calls: u64,
}

impl<F: FnMut(usize) -> Vec<f64>> StreamSource<F> {
    /// `gen(k)` produces node `k`'s next dual vector (length `dim`).
    pub fn new(dim: usize, nodes: usize, gen: F) -> Self {
        StreamSource { gen, dim, nodes, calls: 0 }
    }
}

impl<F: FnMut(usize) -> Vec<f64>> DualSource for StreamSource<F> {
    fn dim(&self) -> usize {
        self.dim
    }

    fn num_nodes(&self) -> usize {
        self.nodes
    }

    fn duals(&mut self, _x: &[f64]) -> Vec<Vec<f64>> {
        self.calls += self.nodes as u64;
        (0..self.nodes).map(|k| (self.gen)(k)).collect()
    }

    fn calls(&self) -> u64 {
        self.calls
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::rng::Rng;
    use crate::vi::operator::QuadraticOperator;

    #[test]
    fn stream_source_replays_its_generator() {
        let mut n = 0.0;
        let mut src = StreamSource::new(2, 3, |k| {
            n += 1.0;
            vec![n, k as f64]
        });
        let a = src.duals(&[9.0, 9.0]);
        assert_eq!(a, vec![vec![1.0, 0.0], vec![2.0, 1.0], vec![3.0, 2.0]]);
        assert_eq!(src.calls(), 3);
        assert_eq!(src.dim(), 2);
        assert_eq!(src.num_nodes(), 3);
    }

    #[test]
    fn nodes_draw_independent_noise() {
        let mut rng = Rng::new(1);
        let op = QuadraticOperator::random(6, 0.5, &mut rng);
        let mut src = OracleSource::new(&op, 4, NoiseModel::Absolute { sigma: 1.0 }, 7);
        let x = vec![0.5; 6];
        let ds = src.duals(&x);
        assert_eq!(ds.len(), 4);
        assert_ne!(ds[0], ds[1]);
        assert_eq!(src.calls(), 4);
    }

    #[test]
    fn averaging_reduces_variance() {
        let mut rng = Rng::new(2);
        let op = QuadraticOperator::random(4, 0.5, &mut rng);
        let x = vec![1.0; 4];
        let a = op.apply_vec(&x);
        let err_of = |k: usize| {
            let mut src = OracleSource::new(&op, k, NoiseModel::Absolute { sigma: 1.0 }, 3);
            let mut acc = 0.0;
            let reps = 2000;
            for _ in 0..reps {
                let ds = src.duals(&x);
                let mut mean = vec![0.0; 4];
                for d in &ds {
                    for (m, v) in mean.iter_mut().zip(d) {
                        *m += v / k as f64;
                    }
                }
                acc += mean.iter().zip(&a).map(|(m, t)| (m - t).powi(2)).sum::<f64>();
            }
            acc / reps as f64
        };
        let e1 = err_of(1);
        let e8 = err_of(8);
        assert!(e8 < e1 / 4.0, "K=8 var {e8} should be ~1/8 of K=1 var {e1}");
    }
}

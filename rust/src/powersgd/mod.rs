//! PowerSGD (Vogels et al.) — the low-rank gradient compressor underlying
//! the paper's Transformer-XL experiments (Section 7.2): each weight matrix
//! M (n x m) is approximated as P Q^T with rank r via one warm-started power
//! iteration per step; the factors P, Q are what travels on the wire, and
//! the paper applies {global, layer-wise} *quantization on top of the
//! factors*.
//!
//! The factors travel as real wire bits: [`PowerSgdCodec`] implements the
//! `crate::comm::Compressor` trait, encoding every layer segment (raw f32
//! pass-through for 1-D layers, fixed-width quantized or raw factors for
//! matrices) into a [`WirePacket`], so the LM trainer's compression-rate
//! accounting reads actual payload sizes like every other workload.
//!
//! Error feedback (the residual memory) keeps the compression unbiased in
//! the long run, matching the reference implementation.

use crate::coding::bitio::{BitReader, BitWriter};
use crate::coding::DecodeError;
use crate::comm::{CommError, Compressor, WirePacket};
use crate::quant::layer_map::LayerMap;
use crate::quant::quantizer::quantize_slice;
use crate::quant::LevelSequence;
use crate::stats::rng::Rng;

/// Per-matrix PowerSGD state.
pub struct MatrixState {
    pub rows: usize,
    pub cols: usize,
    pub rank: usize,
    /// warm-started right factor Q (cols x rank), row-major
    pub q: Vec<f32>,
    /// error-feedback residual (rows * cols)
    pub residual: Vec<f32>,
}

impl MatrixState {
    pub fn new(rows: usize, cols: usize, rank: usize, rng: &mut Rng) -> Self {
        let rank = rank.min(rows.min(cols));
        let q = (0..cols * rank).map(|_| rng.gaussian() as f32).collect();
        MatrixState { rows, cols, rank, q, residual: vec![0.0; rows * cols] }
    }
}

/// C = A (n x k, row-major) * B (k x m).
fn matmul(a: &[f32], b: &[f32], n: usize, k: usize, m: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; n * m];
    for i in 0..n {
        for kk in 0..k {
            let aik = a[i * k + kk];
            if aik == 0.0 {
                continue;
            }
            let brow = &b[kk * m..(kk + 1) * m];
            let crow = &mut c[i * m..(i + 1) * m];
            for j in 0..m {
                crow[j] += aik * brow[j];
            }
        }
    }
    c
}

/// C = A^T (a is n x k) * B (n x m) -> (k x m)
fn matmul_tn(a: &[f32], b: &[f32], n: usize, k: usize, m: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; k * m];
    for i in 0..n {
        let arow = &a[i * k..(i + 1) * k];
        let brow = &b[i * m..(i + 1) * m];
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let crow = &mut c[kk * m..(kk + 1) * m];
            for j in 0..m {
                crow[j] += aik * brow[j];
            }
        }
    }
    c
}

/// Gram–Schmidt orthonormalization of the columns of P (n x r, row-major).
fn orthonormalize(p: &mut [f32], n: usize, r: usize) {
    for j in 0..r {
        // two projection passes ("twice is enough", Kahan–Parlett): a single
        // pass leaves O(eps)-correlated residue when columns are nearly
        // parallel, which rank-deficient gradients make the common case
        for _pass in 0..2 {
            for prev in 0..j {
                let mut dot = 0.0f64;
                for i in 0..n {
                    dot += p[i * r + j] as f64 * p[i * r + prev] as f64;
                }
                for i in 0..n {
                    p[i * r + j] -= (dot as f32) * p[i * r + prev];
                }
            }
        }
        let mut norm = 0.0f64;
        for i in 0..n {
            norm += (p[i * r + j] as f64).powi(2);
        }
        let norm = norm.sqrt().max(1e-12) as f32;
        for i in 0..n {
            p[i * r + j] /= norm;
        }
    }
}

/// One PowerSGD round on matrix `grad` (rows x cols): returns (P, Q) and
/// leaves the approximation error in the residual (error feedback).
pub fn compress_matrix(state: &mut MatrixState, grad: &[f32]) -> (Vec<f32>, Vec<f32>) {
    let (n, m, r) = (state.rows, state.cols, state.rank);
    assert_eq!(grad.len(), n * m);
    // M = grad + residual
    let mut mbuf: Vec<f32> = grad
        .iter()
        .zip(&state.residual)
        .map(|(g, e)| g + e)
        .collect();
    // P = M Q ; orthonormalize P ; Q = M^T P
    let mut p = matmul(&mbuf, &state.q, n, m, r);
    orthonormalize(&mut p, n, r);
    let q = matmul_tn(&mbuf, &p, n, m, r);
    // residual = M - P Q^T
    for i in 0..n {
        for j in 0..m {
            let mut acc = 0.0f32;
            for rr in 0..r {
                acc += p[i * r + rr] * q[j * r + rr];
            }
            mbuf[i * m + j] -= acc;
        }
    }
    state.residual.copy_from_slice(&mbuf);
    state.q = q.clone();
    (p, q)
}

/// Decompress: P Q^T.
pub fn decompress(p: &[f32], q: &[f32], n: usize, m: usize, r: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n * m];
    for i in 0..n {
        for j in 0..m {
            let mut acc = 0.0f32;
            for rr in 0..r {
                acc += p[i * r + rr] * q[j * r + rr];
            }
            out[i * m + j] = acc;
        }
    }
    out
}

/// `decompress` straight into an f64 output slice (the decode hot path —
/// no intermediate matrix allocation).
fn decompress_into(p: &[f32], q: &[f32], n: usize, m: usize, r: usize, out: &mut [f64]) {
    debug_assert_eq!(out.len(), n * m);
    for i in 0..n {
        for j in 0..m {
            let mut acc = 0.0f32;
            for rr in 0..r {
                acc += p[i * r + rr] * q[j * r + rr];
            }
            out[i * m + j] = acc as f64;
        }
    }
}

/// ENC one factor buffer as a single quantization bucket: f32 norm header,
/// then per element a fixed-width level index plus a sign bit (torch_cgx-
/// style "no extra coding" format, footnote 6).
fn write_quantized_factor(buf: &[f32], seq: &LevelSequence, rng: &mut Rng, w: &mut BitWriter) {
    let ql = quantize_slice(buf, seq, 2.0, 0, rng);
    w.write_f32(ql.norm as f32);
    let ib = seq.index_bits();
    for i in 0..buf.len() {
        w.write_bits(ql.indices[i] as u64, ib);
        w.write_bit(ql.sign(i));
    }
}

/// DEC the factor format written by `write_quantized_factor`.
fn read_quantized_factor(
    n: usize,
    seq: &LevelSequence,
    r: &mut BitReader,
    out: &mut Vec<f32>,
) -> Result<(), DecodeError> {
    out.clear();
    out.reserve(n);
    let norm = match r.try_read_bits(32) {
        Some(bits) => f32::from_bits(bits as u32) as f64,
        None => return Err(DecodeError::Truncated { bit_pos: r.bit_pos() }),
    };
    let ib = seq.index_bits();
    let ls = seq.as_slice();
    for _ in 0..n {
        let idx = match r.try_read_bits(ib) {
            Some(i) => i as usize,
            None => return Err(DecodeError::Truncated { bit_pos: r.bit_pos() }),
        };
        if idx >= ls.len() {
            return Err(DecodeError::InvalidCode { bit_pos: r.bit_pos() });
        }
        let neg = match r.try_read_bits(1) {
            Some(b) => b == 1,
            None => return Err(DecodeError::Truncated { bit_pos: r.bit_pos() }),
        };
        let mag = (norm * ls[idx]) as f32;
        out.push(if neg { -mag } else { mag });
    }
    Ok(())
}

/// DEC `n` raw f32 values.
fn read_raw_f32(n: usize, r: &mut BitReader, out: &mut Vec<f32>) -> Result<(), DecodeError> {
    out.clear();
    out.reserve(n);
    for _ in 0..n {
        match r.try_read_bits(32) {
            Some(bits) => out.push(f32::from_bits(bits as u32)),
            None => return Err(DecodeError::Truncated { bit_pos: r.bit_pos() }),
        }
    }
    Ok(())
}

/// Per-layer quantization assignment on top of PowerSGD.
#[derive(Clone, Debug)]
pub enum FactorQuantMode {
    /// fp32 factors (plain PowerSGD)
    None,
    /// same level count for every layer's factors (global)
    Global { bits: u32 },
    /// per-layer bits (the layer-wise / L-GreCo assignment); indexed by layer
    PerLayer { bits: Vec<u32> },
}

/// Whole-model PowerSGD compressor over the 2-D layers of a LayerMap
/// (1-D layers — biases, norms — travel uncompressed, as in the reference
/// implementation).
pub struct PowerSgd {
    pub rank: usize,
    pub states: Vec<Option<MatrixState>>,
    pub map: LayerMap,
    rng: Rng,
    /// per-layer f32 cast scratch, reused every encode
    g32: Vec<f32>,
}

impl PowerSgd {
    pub fn new(map: &LayerMap, rank: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let states = map
            .layers
            .iter()
            .map(|l| {
                if l.rows > 1 && l.cols > 1 {
                    Some(MatrixState::new(l.rows, l.cols, rank, &mut rng))
                } else {
                    None
                }
            })
            .collect();
        PowerSgd { rank, states, map: map.clone(), rng, g32: Vec::new() }
    }

    fn layer_bits(mode: &FactorQuantMode, li: usize) -> Option<u32> {
        match mode {
            FactorQuantMode::None => None,
            FactorQuantMode::Global { bits } => Some(*bits),
            FactorQuantMode::PerLayer { bits } => Some(bits[li]),
        }
    }

    /// ENC: one PowerSGD round into a wire packet — runs the warm-started
    /// power iteration, updates the error-feedback residual, and writes the
    /// (optionally quantized) factors plus 1-D pass-through layers as real
    /// wire bits with per-layer offsets.
    pub fn encode_into_with_mode(
        &mut self,
        grad: &[f64],
        mode: &FactorQuantMode,
        packet: &mut WirePacket,
    ) {
        assert_eq!(grad.len(), self.map.dim);
        let mut w = BitWriter::new();
        packet.begin_encode(grad.len(), &mut w);
        for (li, l) in self.map.layers.iter().enumerate() {
            packet.mark_layer(w.len_bits());
            self.g32.clear();
            self.g32.extend(grad[l.offset..l.offset + l.len].iter().map(|&x| x as f32));
            match &mut self.states[li] {
                None => {
                    for &v in &self.g32 {
                        w.write_f32(v);
                    }
                }
                Some(st) => {
                    let (p, q) = compress_matrix(st, &self.g32);
                    match Self::layer_bits(mode, li) {
                        None => {
                            for &v in p.iter().chain(q.iter()) {
                                w.write_f32(v);
                            }
                        }
                        Some(nb) => {
                            let seq = LevelSequence::bits(nb);
                            write_quantized_factor(&p, &seq, &mut self.rng, &mut w);
                            write_quantized_factor(&q, &seq, &mut self.rng, &mut w);
                        }
                    }
                }
            }
        }
        packet.finish_encode(&mut w);
    }

    /// DEC: reconstruct the decoded gradient (P Q^T per matrix, raw values
    /// for 1-D layers) from a wire packet.
    pub fn decode_packet(
        &self,
        mode: &FactorQuantMode,
        packet: &WirePacket,
        out: &mut Vec<f64>,
    ) -> Result<(), CommError> {
        if packet.dim() != self.map.dim {
            return Err(CommError::DimMismatch { want: self.map.dim, got: packet.dim() });
        }
        let mut r = packet.payload().reader();
        out.clear();
        out.resize(self.map.dim, 0.0);
        let mut pbuf: Vec<f32> = Vec::new();
        let mut qbuf: Vec<f32> = Vec::new();
        for (li, l) in self.map.layers.iter().enumerate() {
            match &self.states[li] {
                None => {
                    read_raw_f32(l.len, &mut r, &mut pbuf)?;
                    for (o, v) in out[l.offset..l.offset + l.len].iter_mut().zip(&pbuf) {
                        *o = *v as f64;
                    }
                }
                Some(st) => {
                    let (n, m, rk) = (st.rows, st.cols, st.rank);
                    match Self::layer_bits(mode, li) {
                        None => {
                            read_raw_f32(n * rk, &mut r, &mut pbuf)?;
                            read_raw_f32(m * rk, &mut r, &mut qbuf)?;
                        }
                        Some(nb) => {
                            let seq = LevelSequence::bits(nb);
                            read_quantized_factor(n * rk, &seq, &mut r, &mut pbuf)?;
                            read_quantized_factor(m * rk, &seq, &mut r, &mut qbuf)?;
                        }
                    }
                    decompress_into(
                        &pbuf,
                        &qbuf,
                        n,
                        m,
                        rk,
                        &mut out[l.offset..l.offset + l.len],
                    );
                }
            }
        }
        if r.remaining() != 0 {
            return Err(CommError::TrailingBits { bits: r.remaining() });
        }
        Ok(())
    }

    /// Compress a flat gradient; returns (decoded gradient, wire bits).
    /// Convenience wrapper over the packet path — the bits reported are the
    /// actual encoded payload size.
    pub fn compress_with_quant(
        &mut self,
        grad: &[f64],
        mode: &FactorQuantMode,
    ) -> (Vec<f64>, usize) {
        let mut packet = WirePacket::new();
        self.encode_into_with_mode(grad, mode, &mut packet);
        let mut out = Vec::with_capacity(grad.len());
        self.decode_packet(mode, &packet, &mut out).expect("powersgd loopback decode");
        (out, packet.len_bits())
    }

    /// fp32 bits of the uncompressed gradient (compression-rate denominator).
    pub fn raw_bits(&self) -> usize {
        32 * self.map.dim
    }
}

/// PowerSGD as a `comm` codec: one node's low-rank + quantized factor
/// pipeline producing real wire packets (what the LM trainer ships).
pub struct PowerSgdCodec {
    pub ps: PowerSgd,
    pub mode: FactorQuantMode,
}

impl PowerSgdCodec {
    pub fn new(map: &LayerMap, rank: usize, mode: FactorQuantMode, seed: u64) -> Self {
        PowerSgdCodec { ps: PowerSgd::new(map, rank, seed), mode }
    }
}

impl Compressor for PowerSgdCodec {
    fn encode_into(&mut self, v: &[f64], packet: &mut WirePacket) -> Result<(), CommError> {
        self.ps.encode_into_with_mode(v, &self.mode, packet);
        Ok(())
    }

    fn decode_into(
        &mut self,
        packet: &WirePacket,
        out: &mut Vec<f64>,
    ) -> Result<(), CommError> {
        self.ps.decode_packet(&self.mode, packet, out)
    }

    fn name(&self) -> &'static str {
        match self.mode {
            FactorQuantMode::None => "powersgd",
            FactorQuantMode::Global { .. } => "powersgd-quantized",
            FactorQuantMode::PerLayer { .. } => "powersgd-layerwise",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_limited_exact_for_lowrank_matrix() {
        let mut rng = Rng::new(1);
        let (n, m) = (12, 8);
        let u: Vec<f32> = (0..n).map(|_| rng.gaussian() as f32).collect();
        let v: Vec<f32> = (0..m).map(|_| rng.gaussian() as f32).collect();
        let grad: Vec<f32> = (0..n * m).map(|i| u[i / m] * v[i % m]).collect();
        let mut st = MatrixState::new(n, m, 2, &mut rng);
        let mut approx = vec![];
        for _ in 0..3 {
            st.residual.iter_mut().for_each(|x| *x = 0.0);
            let (p, q) = compress_matrix(&mut st, &grad);
            approx = decompress(&p, &q, n, m, 2);
        }
        let err: f32 = grad.iter().zip(&approx).map(|(a, b)| (a - b).abs()).sum();
        let scale: f32 = grad.iter().map(|a| a.abs()).sum();
        assert!(err < 0.02 * scale, "{err} vs {scale}");
    }

    #[test]
    fn error_feedback_keeps_residual_bounded() {
        // with a constant gradient the residual must reach a bounded steady
        // state (not diverge): compare its norm mid-run vs end-of-run
        let mut rng = Rng::new(2);
        let (n, m) = (10, 10);
        let mut st = MatrixState::new(n, m, 1, &mut rng);
        let grad: Vec<f32> = (0..n * m).map(|_| rng.gaussian() as f32).collect();
        let res_norm = |st: &MatrixState| -> f64 {
            st.residual.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
        };
        let mut mid = 0.0;
        for t in 0..200 {
            let _ = compress_matrix(&mut st, &grad);
            if t == 99 {
                mid = res_norm(&st);
            }
        }
        let end = res_norm(&st);
        assert!(end < 1.5 * mid + 1e-9, "residual diverging: {mid} -> {end}");
        // and error feedback means the *average* transmitted gradient tracks
        // the true one in the top singular direction: residual never exceeds
        // a constant multiple of the gradient
        let gn: f64 = grad.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
        assert!(end < 10.0 * gn, "{end} vs {gn}");
    }

    #[test]
    fn orthonormalize_produces_orthonormal_columns() {
        let mut rng = Rng::new(3);
        let (n, r) = (20, 4);
        let mut p: Vec<f32> = (0..n * r).map(|_| rng.gaussian() as f32).collect();
        orthonormalize(&mut p, n, r);
        for a in 0..r {
            for b in 0..=a {
                let dot: f64 =
                    (0..n).map(|i| p[i * r + a] as f64 * p[i * r + b] as f64).sum();
                let want = if a == b { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-4, "col {a}.{b}: {dot}");
            }
        }
    }

    #[test]
    fn compression_rate_grows_with_lower_rank() {
        let map = LayerMap::parse_meta(
            "dim 8192\nlayer a 0 4096 ff 64 64\nlayer b 4096 4096 ff 64 64\n",
        )
        .unwrap();
        let grad: Vec<f64> = (0..8192).map(|i| (i % 17) as f64 / 17.0).collect();
        let mut p4 = PowerSgd::new(&map, 4, 1);
        let mut p16 = PowerSgd::new(&map, 16, 1);
        let (_, b4) = p4.compress_with_quant(&grad, &FactorQuantMode::None);
        let (_, b16) = p16.compress_with_quant(&grad, &FactorQuantMode::None);
        assert!(b4 < b16);
        assert!(b16 < p16.raw_bits());
    }

    #[test]
    fn quantized_factors_cut_bits_further() {
        let map = LayerMap::parse_meta("dim 4096\nlayer a 0 4096 ff 64 64\n").unwrap();
        let grad: Vec<f64> =
            (0..4096).map(|i| ((i * 31 % 101) as f64 - 50.0) / 50.0).collect();
        let mut ps = PowerSgd::new(&map, 8, 2);
        let (_, raw) = ps.compress_with_quant(&grad, &FactorQuantMode::None);
        let mut ps2 = PowerSgd::new(&map, 8, 2);
        let (dec, q4) = ps2.compress_with_quant(&grad, &FactorQuantMode::Global { bits: 4 });
        assert!(q4 < raw / 4, "{q4} vs {raw}");
        assert!(dec.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn codec_packet_matches_inline_roundtrip() {
        let map = LayerMap::parse_meta(
            "dim 1056\nlayer w 0 1024 ff 32 32\nlayer b 1024 32 bias 32 1\n",
        )
        .unwrap();
        let grad: Vec<f64> = (0..1056).map(|i| ((i * 13 % 97) as f64 - 48.0) / 50.0).collect();
        let mode = FactorQuantMode::Global { bits: 4 };
        let mut ps = PowerSgd::new(&map, 4, 7);
        let (dec_inline, bits_inline) = ps.compress_with_quant(&grad, &mode);
        let mut codec = PowerSgdCodec::new(&map, 4, mode, 7);
        let mut packet = WirePacket::new();
        codec.encode_into(&grad, &mut packet).unwrap();
        let mut dec = Vec::new();
        codec.decode_into(&packet, &mut dec).unwrap();
        assert_eq!(dec, dec_inline);
        assert_eq!(packet.len_bits(), bits_inline);
        // per-factor format: 32-bit norm + (idx_bits + sign) per element
        let seq = LevelSequence::bits(4);
        let per_factor = |elems: usize| 32 + elems * (seq.index_bits() as usize + 1);
        let want = per_factor(32 * 4) + per_factor(32 * 4) + 32 * 32;
        assert_eq!(packet.len_bits(), want);
        // layer offsets frame both segments
        assert_eq!(packet.layer_offsets().len(), 2);
        assert_eq!(packet.layer_offsets()[0], 0);
    }

    #[test]
    fn truncated_powersgd_packet_errors() {
        let map = LayerMap::parse_meta("dim 64\nlayer w 0 64 ff 8 8\n").unwrap();
        let mut codec =
            PowerSgdCodec::new(&map, 2, FactorQuantMode::Global { bits: 4 }, 3);
        let grad: Vec<f64> = (0..64).map(|i| i as f64 / 64.0).collect();
        let mut packet = WirePacket::new();
        codec.encode_into(&grad, &mut packet).unwrap();
        let mut w = BitWriter::new();
        let mut r = packet.payload().reader();
        w.write_bits(r.read_bits(40), 40);
        let cut = WirePacket::from_raw(w.finish(), packet.layer_offsets().to_vec(), 64);
        let mut dec = Vec::new();
        assert!(matches!(
            codec.decode_into(&cut, &mut dec),
            Err(CommError::Decode(DecodeError::Truncated { .. }))
        ));
    }

    #[test]
    fn one_dim_layers_pass_through() {
        let map = LayerMap::parse_meta(
            "dim 132\nlayer w 0 128 ff 16 8\nlayer b 128 4 bias 4 1\n",
        )
        .unwrap();
        let grad: Vec<f64> = (0..132).map(|i| i as f64 / 100.0).collect();
        let mut ps = PowerSgd::new(&map, 2, 3);
        let (dec, _) = ps.compress_with_quant(&grad, &FactorQuantMode::None);
        for i in 128..132 {
            assert!((dec[i] - grad[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn per_layer_bits_differ_in_wire_size() {
        let map = LayerMap::parse_meta(
            "dim 8192\nlayer a 0 4096 ff 64 64\nlayer b 4096 4096 embedding 64 64\n",
        )
        .unwrap();
        let grad: Vec<f64> = (0..8192).map(|i| ((i % 13) as f64 - 6.0) / 6.0).collect();
        let mut ps = PowerSgd::new(&map, 8, 4);
        let (_, b_hi) = ps.compress_with_quant(
            &grad,
            &FactorQuantMode::PerLayer { bits: vec![8, 8] },
        );
        let mut ps2 = PowerSgd::new(&map, 8, 4);
        let (_, b_mixed) = ps2.compress_with_quant(
            &grad,
            &FactorQuantMode::PerLayer { bits: vec![2, 8] },
        );
        assert!(b_mixed < b_hi);
    }
}

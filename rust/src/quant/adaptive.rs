//! Adaptive level optimization (Section 3.2, Eq. (2)–(3)).
//!
//! Given the weighted empirical CDF F~^m of normalized type-m coordinates
//! (collected from Z sampled dual vectors with weights lambda_z ∝ ||g_z||_q^2),
//! choose the interior levels of the type-m sequence to minimize
//!
//! ```text
//!     sum_i  ∫_{l_i}^{l_{i+1}} (l_{i+1} - u)(u - l_i) dF(u)        (MQV)
//! ```
//!
//! First-order optimality for an interior level l_j balances the mass-moment
//! of its two adjacent intervals:
//!
//! ```text
//!     ∫_{l_{j-1}}^{l_j} (u - l_{j-1}) dF  =  ∫_{l_j}^{l_{j+1}} (l_{j+1} - u) dF
//! ```
//!
//! We solve this by cyclic coordinate bisection (each step provably does not
//! increase the objective on the piecewise-constant histogram density), the
//! same fixed-point family as Lloyd–Max.

use super::levels::LevelSequence;
use crate::stats::histogram::NormalizedHistogram;
use crate::stats::vecops::lq_norm;

/// Accumulates the type-m statistics from sampled dual vectors.
#[derive(Clone, Debug)]
pub struct TypeStats {
    pub hist: NormalizedHistogram,
}

impl Default for TypeStats {
    fn default() -> Self {
        TypeStats { hist: NormalizedHistogram::new(256) }
    }
}

impl TypeStats {
    /// Add one layer slice of one sampled dual vector; weight = ||slice||_q^2
    /// per the paper's lambda_z (Eq. (3), applied at layer granularity).
    pub fn add_layer_sample(&mut self, slice: &[f32], q: f64) {
        let norm = lq_norm(slice, q);
        if norm <= 0.0 {
            return;
        }
        let inv = 1.0 / norm;
        self.hist.add_sample(
            slice.iter().map(|&x| ((x.abs() as f64) * inv).clamp(0.0, 1.0)),
            norm * norm,
        );
    }

    pub fn reset(&mut self) {
        self.hist.reset();
    }
}

/// MQV objective of a sequence against a histogram (per-coordinate expected
/// quantization variance; the ||v||_q^2 weights are already in the CDF).
pub fn objective(hist: &NormalizedHistogram, seq: &LevelSequence) -> f64 {
    hist.expected_quant_variance(seq.as_slice())
}

/// ∫_a^b (u - a) dF via the histogram (bin midpoint rule).
fn moment_above(hist: &NormalizedHistogram, a: f64, b: f64) -> f64 {
    if b <= a {
        return 0.0;
    }
    let m = hist.mass(a, b);
    if m == 0.0 {
        return 0.0;
    }
    m * (hist.conditional_mean(a, b) - a).max(0.0)
}

/// ∫_a^b (b - u) dF via the histogram.
fn moment_below(hist: &NormalizedHistogram, a: f64, b: f64) -> f64 {
    if b <= a {
        return 0.0;
    }
    let m = hist.mass(a, b);
    if m == 0.0 {
        return 0.0;
    }
    m * (b - hist.conditional_mean(a, b)).max(0.0)
}

/// Optimize the interior levels of `seq` against `hist` (alpha fixed).
/// Returns the optimized sequence and its objective value.
pub fn optimize_levels(
    hist: &NormalizedHistogram,
    alpha: usize,
    sweeps: usize,
) -> (LevelSequence, f64) {
    // start from uniform spacing
    let mut ls: Vec<f64> = LevelSequence::uniform(alpha).as_slice().to_vec();
    if hist.is_empty() {
        let seq = LevelSequence::new(ls);
        let obj = objective(hist, &seq);
        return (seq, obj);
    }
    let n = ls.len();
    for _ in 0..sweeps {
        for j in 1..n - 1 {
            let (left, right) = (ls[j - 1], ls[j + 1]);
            // bisection on g(l) = moment_above(left, l) - moment_below(l, right),
            // which is non-decreasing in l.
            let (mut lo, mut hi) = (left, right);
            for _ in 0..18 {
                let mid = 0.5 * (lo + hi);
                let g = moment_above(hist, left, mid) - moment_below(hist, mid, right);
                if g < 0.0 {
                    lo = mid;
                } else {
                    hi = mid;
                }
            }
            let cand = 0.5 * (lo + hi);
            // keep strict ordering with a small guard band
            let eps = 1e-9;
            ls[j] = cand.clamp(left + eps, right - eps);
        }
    }
    let seq = LevelSequence::new(ls);
    let obj = objective(hist, &seq);
    (seq, obj)
}

/// Full per-type adaptation: optimize each type's sequence keeping its
/// current alpha. Returns (sequences, objective per type).
pub fn adapt_all(
    stats: &[TypeStats],
    alphas: &[usize],
    sweeps: usize,
) -> (Vec<LevelSequence>, Vec<f64>) {
    assert_eq!(stats.len(), alphas.len());
    let mut seqs = Vec::with_capacity(stats.len());
    let mut objs = Vec::with_capacity(stats.len());
    for (st, &a) in stats.iter().zip(alphas) {
        let (s, o) = optimize_levels(&st.hist, a, sweeps);
        seqs.push(s);
        objs.push(o);
    }
    (seqs, objs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::rng::Rng;

    fn hist_from(vals: &[f64]) -> NormalizedHistogram {
        let mut h = NormalizedHistogram::new(256);
        h.add_sample(vals.iter().copied(), 1.0);
        h
    }

    #[test]
    fn optimized_no_worse_than_uniform() {
        let mut rng = Rng::new(1);
        // heavily skewed magnitudes (most mass near 0 — gradient-like)
        let vals: Vec<f64> = (0..5000)
            .map(|_| (rng.gaussian().abs() * 0.1).min(1.0))
            .collect();
        let h = hist_from(&vals);
        for alpha in [1usize, 3, 7, 15] {
            let uni = LevelSequence::uniform(alpha);
            let (opt, obj_opt) = optimize_levels(&h, alpha, 8);
            let obj_uni = objective(&h, &uni);
            assert!(
                obj_opt <= obj_uni * 1.001 + 1e-12,
                "alpha={alpha}: opt {obj_opt} vs uniform {obj_uni}"
            );
            assert_eq!(opt.alpha(), alpha);
        }
    }

    #[test]
    fn skewed_distribution_pulls_levels_down() {
        let mut rng = Rng::new(2);
        let vals: Vec<f64> = (0..5000)
            .map(|_| (rng.gaussian().abs() * 0.05).min(1.0))
            .collect();
        let h = hist_from(&vals);
        let (opt, _) = optimize_levels(&h, 3, 8);
        // all interior levels should sit well below uniform's positions
        let uni = LevelSequence::uniform(3);
        for (o, u) in opt.as_slice()[1..4].iter().zip(&uni.as_slice()[1..4]) {
            assert!(o < u, "{o} !< {u}");
        }
    }

    #[test]
    fn empty_hist_falls_back_to_uniform() {
        let h = NormalizedHistogram::new(32);
        let (opt, _) = optimize_levels(&h, 4, 4);
        assert_eq!(opt.as_slice(), LevelSequence::uniform(4).as_slice());
    }

    #[test]
    fn type_stats_weighting() {
        let mut st = TypeStats::default();
        st.add_layer_sample(&[0.1, 0.1], 2.0);
        st.add_layer_sample(&[10.0, 10.0], 2.0);
        // the large-norm layer dominates the CDF weights (lambda_z)
        assert!(st.hist.total_weight() > 100.0);
    }

    #[test]
    fn objective_decreases_with_alpha() {
        let mut rng = Rng::new(3);
        let vals: Vec<f64> = (0..3000).map(|_| rng.uniform()).collect();
        let h = hist_from(&vals);
        let (_, o2) = optimize_levels(&h, 2, 6);
        let (_, o8) = optimize_levels(&h, 8, 6);
        assert!(o8 < o2);
    }
}

//! Layer segmentation of the flat parameter/gradient vector.
//!
//! The L2 model exports `artifacts/<model>.meta` (plain text) describing how
//! the flat vector decomposes into named layers with a semantic type
//! (ff / bias / attention / embedding / norm). Layer types are the paper's
//! "M types of sequences": every layer of type m is quantized with the
//! type-m level sequence l^{t,m}, re-optimized over training.

use std::collections::BTreeMap;

/// Semantic layer categories exported by the L2 models.
pub const KNOWN_TYPES: &[&str] = &["ff", "bias", "attention", "embedding", "norm"];

#[derive(Clone, Debug, PartialEq)]
pub struct Layer {
    pub name: String,
    pub offset: usize,
    pub len: usize,
    /// index into `LayerMap::type_names`
    pub type_id: usize,
    /// matrix shape (rows, cols) when known; (len, 1) otherwise
    pub rows: usize,
    pub cols: usize,
}

#[derive(Clone, Debug, Default)]
pub struct LayerMap {
    pub dim: usize,
    pub layers: Vec<Layer>,
    pub type_names: Vec<String>,
    /// free-form key/value pairs from the meta file (batch, vocab, ...)
    pub extra: BTreeMap<String, String>,
}

impl LayerMap {
    /// Build from (name, len, type) triples laid out contiguously.
    pub fn from_spec(spec: &[(&str, usize, &str)]) -> Self {
        let mut map = LayerMap::default();
        let mut off = 0;
        for &(name, len, ty) in spec {
            let type_id = map.intern_type(ty);
            map.layers.push(Layer {
                name: name.to_string(),
                offset: off,
                len,
                type_id,
                rows: len,
                cols: 1,
            });
            off += len;
        }
        map.dim = off;
        map
    }

    /// A single-layer map covering the whole vector (global quantization).
    pub fn single(dim: usize) -> Self {
        Self::from_spec(&[("all", dim, "ff")])
    }

    fn intern_type(&mut self, ty: &str) -> usize {
        if let Some(i) = self.type_names.iter().position(|t| t == ty) {
            i
        } else {
            self.type_names.push(ty.to_string());
            self.type_names.len() - 1
        }
    }

    /// Number of distinct types M.
    pub fn num_types(&self) -> usize {
        self.type_names.len()
    }

    pub fn type_id(&self, name: &str) -> Option<usize> {
        self.type_names.iter().position(|t| t == name)
    }

    /// Proportion mu^m of coordinates belonging to each type (Thm 5.3).
    pub fn type_proportions(&self) -> Vec<f64> {
        let mut counts = vec![0usize; self.num_types()];
        for l in &self.layers {
            counts[l.type_id] += l.len;
        }
        counts.iter().map(|&c| c as f64 / self.dim as f64).collect()
    }

    /// Parse the `.meta` format emitted by python/compile/aot.py:
    /// `kind <k>` / `dim <d>` / `<key> <value>` / `layer <name> <off> <len> <type>`.
    pub fn parse_meta(text: &str) -> Result<Self, String> {
        let mut map = LayerMap::default();
        let mut dim = None;
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut it = line.split_whitespace();
            let Some(key) = it.next() else { continue };
            match key {
                "dim" => {
                    dim = Some(
                        it.next()
                            .ok_or_else(|| format!("line {lineno}: dim needs value"))?
                            .parse::<usize>()
                            .map_err(|e| format!("line {lineno}: {e}"))?,
                    );
                }
                "layer" => {
                    let name = it.next().ok_or("layer: missing name")?.to_string();
                    let off: usize = it
                        .next()
                        .ok_or("layer: missing offset")?
                        .parse()
                        .map_err(|e| format!("line {lineno}: {e}"))?;
                    let len: usize = it
                        .next()
                        .ok_or("layer: missing len")?
                        .parse()
                        .map_err(|e| format!("line {lineno}: {e}"))?;
                    let ty = it.next().ok_or("layer: missing type")?;
                    let type_id = map.intern_type(ty);
                    let rows: usize = it.next().and_then(|v| v.parse().ok()).unwrap_or(len);
                    let cols: usize = it.next().and_then(|v| v.parse().ok()).unwrap_or(1);
                    map.layers.push(Layer { name, offset: off, len, type_id, rows, cols });
                }
                other => {
                    let val = it.collect::<Vec<_>>().join(" ");
                    map.extra.insert(other.to_string(), val);
                }
            }
        }
        map.dim = dim.ok_or("meta missing dim")?;
        map.validate()?;
        Ok(map)
    }

    pub fn load_meta(path: &std::path::Path) -> Result<Self, String> {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
        Self::parse_meta(&text)
    }

    /// Contiguity + coverage invariants.
    pub fn validate(&self) -> Result<(), String> {
        let mut off = 0;
        for l in &self.layers {
            if l.offset != off {
                return Err(format!("layer {} offset {} != expected {off}", l.name, l.offset));
            }
            if l.len == 0 {
                return Err(format!("layer {} empty", l.name));
            }
            off += l.len;
        }
        if off != self.dim {
            return Err(format!("layers cover {off} of dim {}", self.dim));
        }
        Ok(())
    }

    pub fn extra_usize(&self, key: &str) -> Option<usize> {
        self.extra.get(key).and_then(|v| v.parse().ok())
    }

    pub fn extra_f64(&self, key: &str) -> Option<f64> {
        self.extra.get(key).and_then(|v| v.parse().ok())
    }

    /// Layers of a given type id.
    pub fn layers_of_type(&self, type_id: usize) -> impl Iterator<Item = &Layer> {
        self.layers.iter().filter(move |l| l.type_id == type_id)
    }

    /// Collapse to a global (single-type) map with the same layer boundaries
    /// — the Q-GenX baseline (one sequence for every layer) while keeping
    /// per-layer norms bucketing identical for a fair comparison.
    pub fn with_single_type(&self) -> Self {
        let mut m = self.clone();
        m.type_names = vec!["global".to_string()];
        for l in &mut m.layers {
            l.type_id = 0;
        }
        m
    }

    /// Re-bucket into fixed-size buckets (QSGD-style `bucket size` used by
    /// the paper's experiments, e.g. 128): each layer is split into chunks
    /// of at most `bucket` coordinates, preserving the type.
    pub fn bucketed(&self, bucket: usize) -> Self {
        assert!(bucket > 0);
        let mut m = LayerMap {
            dim: self.dim,
            layers: Vec::new(),
            type_names: self.type_names.clone(),
            extra: self.extra.clone(),
        };
        for l in &self.layers {
            let mut off = l.offset;
            let end = l.offset + l.len;
            let mut i = 0;
            while off < end {
                let len = bucket.min(end - off);
                m.layers.push(Layer {
                    name: format!("{}#{}", l.name, i),
                    offset: off,
                    len,
                    type_id: l.type_id,
                    rows: len,
                    cols: 1,
                });
                off += len;
                i += 1;
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo() -> LayerMap {
        LayerMap::from_spec(&[
            ("a.w", 100, "ff"),
            ("a.b", 10, "bias"),
            ("b.w", 50, "ff"),
        ])
    }

    #[test]
    fn spec_layout() {
        let m = demo();
        assert_eq!(m.dim, 160);
        assert_eq!(m.num_types(), 2);
        assert_eq!(m.layers[2].offset, 110);
        m.validate().unwrap();
    }

    #[test]
    fn proportions_sum_to_one() {
        let m = demo();
        let p = m.type_proportions();
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((p[0] - 150.0 / 160.0).abs() < 1e-12);
    }

    #[test]
    fn parse_meta_roundtrip() {
        let txt = "kind wgan\ndim 160\nbatch 64\nlayer a.w 0 100 ff\nlayer a.b 100 10 bias\nlayer b.w 110 50 ff\n";
        let m = LayerMap::parse_meta(txt).unwrap();
        assert_eq!(m.dim, 160);
        assert_eq!(m.layers.len(), 3);
        assert_eq!(m.extra_usize("batch"), Some(64));
        assert_eq!(m.type_names, vec!["ff", "bias"]);
    }

    #[test]
    fn parse_meta_rejects_gap() {
        let txt = "dim 100\nlayer a 0 40 ff\nlayer b 50 50 ff\n";
        assert!(LayerMap::parse_meta(txt).is_err());
    }

    #[test]
    fn single_type_collapse() {
        let m = demo().with_single_type();
        assert_eq!(m.num_types(), 1);
        assert!(m.layers.iter().all(|l| l.type_id == 0));
        m.validate().unwrap();
    }

    #[test]
    fn bucketing_preserves_coverage() {
        let m = demo().bucketed(32);
        m.validate().unwrap();
        assert_eq!(m.dim, 160);
        assert!(m.layers.iter().all(|l| l.len <= 32));
        // 100 -> 4 buckets, 10 -> 1, 50 -> 2
        assert_eq!(m.layers.len(), 4 + 1 + 2);
    }
}

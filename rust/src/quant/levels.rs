//! Quantization level sequences (Section 3.1).
//!
//! A sequence of type m is `[l_0=0, l_1, ..., l_alpha, l_{alpha+1}=1]` with
//! strictly increasing interior levels. The framework supports arbitrary
//! sequences; constructors are provided for the two classical families the
//! paper compares against (uniform/QSGD and exponential/NUQSGD spacing).

/// A valid level sequence including both endpoints 0 and 1.
#[derive(Clone, Debug, PartialEq)]
pub struct LevelSequence {
    levels: Vec<f64>,
    /// f32 copy for the hot loop (matches the Pallas kernel's precision)
    levels_f32: Vec<f32>,
    /// Some(1/step) when the levels are uniformly spaced — enables the
    /// closed-form bracket (perf: EXPERIMENTS.md §Perf L3 iteration 1)
    uniform_inv_step: Option<f64>,
}

impl LevelSequence {
    /// From the full vector including endpoints; validates the invariants.
    pub fn new(levels: Vec<f64>) -> Self {
        assert!(levels.len() >= 2, "need at least [0, 1]");
        assert_eq!(levels[0], 0.0, "l_0 must be 0");
        assert_eq!(levels.last().copied(), Some(1.0), "l_{{alpha+1}} must be 1");
        for w in levels.windows(2) {
            assert!(w[1] > w[0], "levels must be strictly increasing: {levels:?}");
        }
        let step = levels[1] - levels[0];
        let uniform = levels
            .windows(2)
            .all(|w| ((w[1] - w[0]) - step).abs() < 1e-12 * step.max(1e-12));
        let levels_f32 = levels.iter().map(|&x| x as f32).collect();
        LevelSequence {
            levels,
            levels_f32,
            uniform_inv_step: if uniform { Some(1.0 / step) } else { None },
        }
    }

    /// From interior levels only.
    pub fn from_inner(inner: &[f64]) -> Self {
        let mut v = Vec::with_capacity(inner.len() + 2);
        v.push(0.0);
        v.extend_from_slice(inner);
        v.push(1.0);
        Self::new(v)
    }

    /// QSGD-style: s uniformly spaced interior levels (alpha = s).
    /// `uniform(s)` has s+2 total levels: {0, 1/(s+1), ..., s/(s+1), 1}.
    pub fn uniform(s: usize) -> Self {
        let inner: Vec<f64> = (1..=s).map(|j| j as f64 / (s + 1) as f64).collect();
        Self::from_inner(&inner)
    }

    /// NUQSGD-style exponential spacing: levels {0, p^s, ..., p^2, p, 1}
    /// with ratio 1/p between consecutive nonzero levels (p in (0,1)).
    pub fn exponential(s: usize, p: f64) -> Self {
        assert!(p > 0.0 && p < 1.0);
        let mut inner: Vec<f64> = (1..=s).map(|j| p.powi(j as i32)).collect();
        inner.reverse();
        Self::from_inner(&inner)
    }

    /// The standard "b-bit" sequence used for QODA5-style runs: 2^b - 2
    /// interior levels, uniformly spaced (so indices fit in b bits together
    /// with... the sign carried separately — matches torch_cgx convention).
    pub fn bits(b: u32) -> Self {
        assert!((1..=12).contains(&b));
        Self::uniform((1usize << b) - 2)
    }

    pub fn as_slice(&self) -> &[f64] {
        &self.levels
    }

    pub fn as_f32(&self) -> Vec<f32> {
        self.levels.iter().map(|&x| x as f32).collect()
    }

    /// Number of interior levels (the paper's alpha).
    pub fn alpha(&self) -> usize {
        self.levels.len() - 2
    }

    /// Total number of symbols a coordinate can take (alpha + 2).
    pub fn num_symbols(&self) -> usize {
        self.levels.len()
    }

    /// Bits for a fixed-width index encoding of a symbol.
    pub fn index_bits(&self) -> u32 {
        (self.num_symbols() as f64).log2().ceil() as u32
    }

    /// max_j l_{j+1}/l_j over j >= 1 (the paper's bar-l; l_0 = 0 excluded).
    pub fn max_ratio(&self) -> f64 {
        self.levels
            .windows(2)
            .skip(1)
            .map(|w| w[1] / w[0])
            .fold(1.0f64, f64::max)
    }

    /// l_1 — the smallest nonzero level.
    pub fn l1(&self) -> f64 {
        self.levels[1]
    }

    /// f32 view of the levels (hot-loop table).
    #[inline]
    pub fn as_f32_slice(&self) -> &[f32] {
        &self.levels_f32
    }

    /// Closed-form inverse step when the sequence is uniformly spaced.
    #[inline]
    pub fn uniform_inv_step(&self) -> Option<f64> {
        self.uniform_inv_step
    }

    /// Bracket index tau(u): largest j with l_j <= u, clipped so that
    /// [l_tau, l_{tau+1}] is always valid (u = 1 falls in the last interval).
    ///
    /// The uniform fast path is *exact*: the closed-form guess is corrected
    /// against the actual level values, so it agrees with [`bracket_search`]
    /// for every u — including exact level boundaries, where the f64 product
    /// `u * inv` can round to either side of the integer.
    ///
    /// [`bracket_search`]: Self::bracket_search
    #[inline]
    pub fn bracket(&self, u: f64) -> usize {
        debug_assert!(
            (0.0..=1.0).contains(&u),
            "bracket domain is the normalized magnitude [0, 1], got {u}"
        );
        if let Some(inv) = self.uniform_inv_step {
            let ls = &self.levels;
            let top = ls.len() - 2;
            // closed-form guess; `.max(0.0)` keeps an (out-of-contract)
            // negative u on the same answer as the binary search instead of
            // relying on the cast's silent saturation to 0
            let mut j = ((u.max(0.0) * inv) as usize).min(top);
            // correct the guess by the <= 1 step FP rounding can move it
            while j < top && ls[j + 1] <= u {
                j += 1;
            }
            while j > 0 && ls[j] > u {
                j -= 1;
            }
            debug_assert_eq!(j, self.bracket_search(u));
            return j;
        }
        self.bracket_search(u)
    }

    /// Binary-search bracket (arbitrary sequences).
    #[inline]
    pub fn bracket_search(&self, u: f64) -> usize {
        // binary search on the sorted levels
        let ls = &self.levels;
        let mut lo = 0usize;
        let mut hi = ls.len() - 1; // invariant: ls[lo] <= u (lo may be 0), ls[hi] ... search
        while hi - lo > 1 {
            let mid = (lo + hi) / 2;
            if ls[mid] <= u {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        lo.min(ls.len() - 2)
    }

    /// Single-coordinate quantization variance sigma_Q^2(u) =
    /// (l_{tau+1} - u)(u - l_tau).
    pub fn coord_variance(&self, u: f64) -> f64 {
        let t = self.bracket(u.clamp(0.0, 1.0));
        (self.levels[t + 1] - u).max(0.0) * (u - self.levels[t]).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::for_cases;

    #[test]
    fn uniform_structure() {
        let l = LevelSequence::uniform(3);
        assert_eq!(l.as_slice(), &[0.0, 0.25, 0.5, 0.75, 1.0]);
        assert_eq!(l.alpha(), 3);
        assert_eq!(l.num_symbols(), 5);
    }

    #[test]
    fn exponential_structure() {
        let l = LevelSequence::exponential(3, 0.5);
        assert_eq!(l.as_slice(), &[0.0, 0.125, 0.25, 0.5, 1.0]);
        assert!((l.max_ratio() - 2.0).abs() < 1e-12);
        assert_eq!(l.l1(), 0.125);
    }

    #[test]
    fn bits_symbol_count() {
        // 5-bit quantization: 2^5 = 32 symbols total
        assert_eq!(LevelSequence::bits(5).num_symbols(), 32);
        assert_eq!(LevelSequence::bits(1).num_symbols(), 2); // {0, 1}
        assert_eq!(LevelSequence::bits(5).index_bits(), 5);
    }

    #[test]
    fn bracket_all_intervals() {
        let l = LevelSequence::uniform(3);
        assert_eq!(l.bracket(0.0), 0);
        assert_eq!(l.bracket(0.1), 0);
        assert_eq!(l.bracket(0.25), 1);
        assert_eq!(l.bracket(0.6), 2);
        assert_eq!(l.bracket(0.99), 3);
        assert_eq!(l.bracket(1.0), 3); // clipped into the final interval
    }

    #[test]
    fn prop_bracket_matches_search_on_uniform() {
        // the uniform fast path must agree with the binary search for every
        // u in [0, 1] — random points, the exact stored boundaries, their
        // one-ulp FP neighbors, and the independently recomputed j/(s+1)
        // products (which can round to the other side of the stored level)
        for_cases(60, 0xb4ac, |g| {
            let s = g.usize_in(1, 62);
            let l = LevelSequence::uniform(s);
            for _ in 0..64 {
                let u = g.f64_in(0.0, 1.0);
                assert_eq!(l.bracket(u), l.bracket_search(u), "u={u} s={s}");
            }
            let boundaries: Vec<f64> = l
                .as_slice()
                .iter()
                .copied()
                .chain((0..=s + 1).map(|j| j as f64 / (s + 1) as f64))
                .collect();
            for b in boundaries {
                let mut probes = vec![b, f64::from_bits(b.to_bits() + 1).min(1.0)];
                if b > 0.0 {
                    probes.push(f64::from_bits(b.to_bits() - 1));
                }
                for u in probes {
                    assert_eq!(l.bracket(u), l.bracket_search(u), "u={u} s={s}");
                }
            }
        });
    }

    #[test]
    fn coord_variance_zero_at_levels() {
        let l = LevelSequence::uniform(4);
        for &u in l.as_slice() {
            assert!(l.coord_variance(u) < 1e-15);
        }
        assert!(l.coord_variance(0.1) > 0.0);
    }

    #[test]
    fn coord_variance_peak_at_midpoint() {
        let l = LevelSequence::new(vec![0.0, 0.5, 1.0]);
        let v_mid = l.coord_variance(0.25);
        assert!((v_mid - 0.0625).abs() < 1e-12);
        assert!(l.coord_variance(0.2) < v_mid);
        assert!(l.coord_variance(0.3) < v_mid);
    }

    #[test]
    #[should_panic(expected = "strictly increasing")]
    fn rejects_unsorted() {
        LevelSequence::new(vec![0.0, 0.5, 0.4, 1.0]);
    }

    #[test]
    #[should_panic]
    fn rejects_bad_endpoints() {
        LevelSequence::new(vec![0.1, 0.5, 1.0]);
    }
}

//! L-GreCo (Markov et al., 2024): dynamic-programming allocation of
//! per-layer compression parameters.
//!
//! Given per-layer error curves err[l][c] (expected quantization variance of
//! layer l at candidate level-count c) and per-layer sizes, choose one
//! candidate per layer minimizing total error subject to a total-bits budget:
//!
//! ```text
//!     min sum_l err[l][c_l]   s.t.  sum_l size_l * bits(c_l) <= B
//! ```
//!
//! This is the exact knapsack DP of the L-GreCo paper, run over a discretized
//! budget axis. The coordinator calls it every `update_every` steps (the
//! paper runs it every 10K optimization steps), feeding error curves from the
//! per-type histograms, and maps the chosen alpha back into level sequences
//! optimized by `adaptive::optimize_levels`.

use super::adaptive;
use crate::stats::histogram::NormalizedHistogram;

/// One candidate setting for a layer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Candidate {
    /// number of interior levels (alpha); symbols = alpha + 2
    pub alpha: usize,
    /// bits per coordinate on the wire for a fixed-width index (incl. sign)
    pub bits: f64,
    /// expected per-coordinate quantization variance under this layer's CDF
    pub err: f64,
}

/// Per-layer inputs to the DP.
#[derive(Clone, Debug)]
pub struct LayerProblem {
    pub size: usize,
    pub candidates: Vec<Candidate>,
}

/// DP output.
#[derive(Clone, Debug)]
pub struct Allocation {
    /// chosen candidate index per layer
    pub choice: Vec<usize>,
    pub total_bits: f64,
    pub total_err: f64,
}

/// Budget resolution: the DP quantizes bit costs into this many units.
const UNITS: usize = 2048;

/// Solve the allocation problem. `budget_bits` is the total wire budget for
/// one dual vector (excluding norms). Greedy-safe fallback: if even the
/// cheapest choice per layer exceeds the budget, pick the cheapest anyway.
pub fn allocate(layers: &[LayerProblem], budget_bits: f64) -> Allocation {
    assert!(!layers.is_empty());
    let cheapest_total: f64 = layers
        .iter()
        .map(|l| {
            l.candidates
                .iter()
                .map(|c| c.bits * l.size as f64)
                .fold(f64::INFINITY, f64::min)
        })
        .sum();
    let budget = budget_bits.max(cheapest_total);
    let unit = budget / UNITS as f64;

    // dp[u] = (err, per-layer choices) best using <= u units; forward DP.
    let neg = f64::INFINITY;
    let mut dp = vec![neg; UNITS + 1];
    let mut back: Vec<Vec<u16>> = vec![Vec::new(); UNITS + 1];
    dp[0] = 0.0;
    // layer-by-layer: dp2[u] = min over candidates of dp[u - cost] + err
    for l in layers {
        let mut dp2 = vec![neg; UNITS + 1];
        let mut back2: Vec<Vec<u16>> = vec![Vec::new(); UNITS + 1];
        for (ci, c) in l.candidates.iter().enumerate() {
            let cost_units = ((c.bits * l.size as f64) / unit).round() as usize;
            let err = c.err * l.size as f64;
            for u in cost_units..=UNITS {
                let prev = dp[u - cost_units];
                if prev.is_finite() && prev + err < dp2[u] {
                    dp2[u] = prev + err;
                    let mut b = back[u - cost_units].clone();
                    // audit:allow(lossy-cast) — candidate index into the small alpha ladder
                    b.push(ci as u16);
                    back2[u] = b;
                }
            }
        }
        dp = dp2;
        back = back2;
    }
    // best over all u
    let (mut best_u, mut best) = (UNITS, f64::INFINITY);
    for (u, &e) in dp.iter().enumerate() {
        if e < best {
            best = e;
            best_u = u;
        }
    }
    if !best.is_finite() {
        // degenerate fallback: cheapest everywhere
        let choice: Vec<usize> = layers
            .iter()
            .map(|l| {
                l.candidates
                    .iter()
                    .enumerate()
                    .min_by(|a, b| a.1.bits.total_cmp(&b.1.bits))
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect();
        let total_bits = layers
            .iter()
            .zip(&choice)
            .map(|(l, &c)| l.candidates[c].bits * l.size as f64)
            .sum();
        let total_err = layers
            .iter()
            .zip(&choice)
            .map(|(l, &c)| l.candidates[c].err * l.size as f64)
            .sum();
        return Allocation { choice, total_bits, total_err };
    }
    let choice: Vec<usize> = back[best_u].iter().map(|&c| c as usize).collect();
    let total_bits = layers
        .iter()
        .zip(&choice)
        .map(|(l, &c)| l.candidates[c].bits * l.size as f64)
        .sum();
    Allocation { choice, total_bits, total_err: best }
}

/// Build the candidate error curve of one layer from its normalized-magnitude
/// histogram: for each alpha in `alphas`, optimize the levels against the CDF
/// and record (bits, expected variance).
pub fn error_curve(
    hist: &NormalizedHistogram,
    alphas: &[usize],
    sweeps: usize,
) -> Vec<Candidate> {
    alphas
        .iter()
        .map(|&alpha| {
            let (seq, err) = adaptive::optimize_levels(hist, alpha, sweeps);
            let bits = (seq.num_symbols() as f64).log2().ceil() + 1.0; // + sign
            Candidate { alpha, bits, err }
        })
        .collect()
}

/// Standard alpha ladder: level counts corresponding to 1..=max_bits wire bits.
pub fn alpha_ladder(max_bits: u32) -> Vec<usize> {
    (1..=max_bits).map(|b| (1usize << b) - 2).map(|a| a.max(1)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::rng::Rng;

    fn flat_candidates(errs: &[f64], bits: &[f64]) -> Vec<Candidate> {
        errs.iter()
            .zip(bits)
            .enumerate()
            .map(|(i, (&e, &b))| Candidate { alpha: i + 1, bits: b, err: e })
            .collect()
    }

    #[test]
    fn respects_budget() {
        let layers = vec![
            LayerProblem {
                size: 1000,
                candidates: flat_candidates(&[0.1, 0.01], &[2.0, 6.0]),
            },
            LayerProblem {
                size: 1000,
                candidates: flat_candidates(&[0.1, 0.01], &[2.0, 6.0]),
            },
        ];
        // budget only allows one layer at 6 bits
        let a = allocate(&layers, 8500.0);
        assert!(a.total_bits <= 8500.0 * 1.01);
        // it should upgrade exactly one layer
        let upgraded = a.choice.iter().filter(|&&c| c == 1).count();
        assert_eq!(upgraded, 1, "{:?}", a.choice);
    }

    #[test]
    fn spends_budget_on_sensitive_layer() {
        // layer 0 gains much more from extra bits than layer 1
        let layers = vec![
            LayerProblem {
                size: 1000,
                candidates: flat_candidates(&[1.0, 0.01], &[2.0, 5.0]),
            },
            LayerProblem {
                size: 1000,
                candidates: flat_candidates(&[0.02, 0.01], &[2.0, 5.0]),
            },
        ];
        let a = allocate(&layers, 7000.0);
        assert_eq!(a.choice[0], 1, "sensitive layer should get the bits");
        assert_eq!(a.choice[1], 0);
    }

    #[test]
    fn generous_budget_takes_best_everywhere() {
        let layers = vec![LayerProblem {
            size: 10,
            candidates: flat_candidates(&[0.5, 0.2, 0.05], &[1.0, 3.0, 8.0]),
        }];
        let a = allocate(&layers, 1e9);
        assert_eq!(a.choice, vec![2]);
    }

    #[test]
    fn impossible_budget_falls_back_to_cheapest() {
        let layers = vec![LayerProblem {
            size: 1_000_000,
            candidates: flat_candidates(&[0.5, 0.1], &[4.0, 8.0]),
        }];
        let a = allocate(&layers, 1.0);
        assert_eq!(a.choice, vec![0]);
    }

    #[test]
    fn error_curve_monotone() {
        let mut rng = Rng::new(4);
        let mut h = NormalizedHistogram::new(128);
        h.add_sample((0..4000).map(|_| rng.uniform()), 1.0);
        let curve = error_curve(&h, &alpha_ladder(6), 4);
        for w in curve.windows(2) {
            assert!(w[1].err <= w[0].err * 1.001, "{curve:?}");
            assert!(w[1].bits >= w[0].bits);
        }
    }

    #[test]
    fn dp_beats_uniform_allocation_on_heterogeneous_layers() {
        // Two layers, same size; one has near-zero error even at 2 bits.
        // Uniform 4-bit spend: err = (0.001 + 0.3) * size.
        // DP with the same total budget: 2 bits on easy + 6 bits on hard.
        let layers = vec![
            LayerProblem {
                size: 100,
                candidates: flat_candidates(&[0.001, 0.001, 0.001], &[2.0, 4.0, 6.0]),
            },
            LayerProblem {
                size: 100,
                candidates: flat_candidates(&[0.9, 0.3, 0.02], &[2.0, 4.0, 6.0]),
            },
        ];
        let budget = 100.0 * 4.0 * 2.0;
        let a = allocate(&layers, budget);
        let uniform_err = (0.001 + 0.3) * 100.0;
        assert!(a.total_err < uniform_err, "{} vs {uniform_err}", a.total_err);
        assert!(a.total_bits <= budget * 1.01);
    }
}

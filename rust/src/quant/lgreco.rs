//! L-GreCo (Markov et al., 2024): dynamic-programming allocation of
//! per-layer compression parameters.
//!
//! Given per-layer error curves err[l][c] (expected quantization variance of
//! layer l at candidate level-count c) and per-layer sizes, choose one
//! candidate per layer minimizing total error subject to a total-bits budget:
//!
//! ```text
//!     min sum_l err[l][c_l]   s.t.  sum_l size_l * bits(c_l) <= B
//! ```
//!
//! This is the exact knapsack DP of the L-GreCo paper, run over a discretized
//! budget axis. The coordinator calls it every `update_every` steps (the
//! paper runs it every 10K optimization steps), feeding error curves from the
//! per-type histograms, and maps the chosen alpha back into level sequences
//! optimized by `adaptive::optimize_levels`.

use super::adaptive;
use crate::stats::histogram::NormalizedHistogram;

/// One candidate setting for a layer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Candidate {
    /// number of interior levels (alpha); symbols = alpha + 2
    pub alpha: usize,
    /// bits per coordinate on the wire for a fixed-width index (incl. sign)
    pub bits: f64,
    /// expected per-coordinate quantization variance under this layer's CDF
    pub err: f64,
}

/// Per-layer inputs to the DP.
#[derive(Clone, Debug)]
pub struct LayerProblem {
    pub size: usize,
    pub candidates: Vec<Candidate>,
}

/// DP output.
#[derive(Clone, Debug)]
pub struct Allocation {
    /// chosen candidate index per layer
    pub choice: Vec<usize>,
    pub total_bits: f64,
    pub total_err: f64,
}

/// Budget resolution: the DP quantizes bit costs into this many units.
/// Public because the ceil-rounded discretization bounds how closely a true
/// bit cost maps into the DP's state space: a choice whose true cost is at
/// most `budget * (1 - (layers + 1) / UNITS)` is always reachable (each
/// layer's ceil adds less than one unit). The scheduling ablation pins
/// DP-optimality against static allocations through this bound.
pub const UNITS: usize = 2048;

/// Discretized unit cost of a candidate, rounded *up*: overestimating the
/// cost keeps every DP-reachable state's true bit total at or below
/// `units * unit`, so a feasible budget can never be overshot (the old
/// `.round()` understated costs and let `total_bits` exceed the budget).
#[inline]
fn cost_units(c: &Candidate, size: usize, unit: f64) -> usize {
    ((c.bits * size as f64) / unit).ceil() as usize
}

/// Solve the allocation problem. `budget_bits` is the total wire budget for
/// one dual vector (excluding norms). Greedy-safe fallback: if even the
/// cheapest choice per layer exceeds the budget, pick the cheapest anyway.
///
/// Guarantees, relied on by the schedule layer and the property suite:
/// - whenever the budget is *feasible* (the cheapest choice per layer fits),
///   the returned `total_bits <= budget_bits` — exactly, not within slack;
/// - `total_err` is monotone non-increasing in `budget_bits`: ceil-rounded
///   unit costs shrink as the budget (and hence the unit) grows, so every
///   allocation reachable at a smaller budget stays reachable at a larger
///   one.
pub fn allocate(layers: &[LayerProblem], budget_bits: f64) -> Allocation {
    assert!(!layers.is_empty());
    let cheapest_total: f64 = layers
        .iter()
        .map(|l| {
            l.candidates
                .iter()
                .map(|c| c.bits * l.size as f64)
                .fold(f64::INFINITY, f64::min)
        })
        .sum();
    let feasible = budget_bits >= cheapest_total;
    let budget = budget_bits.max(cheapest_total);
    let unit = budget / UNITS as f64;

    // dp[u] = min err over allocations of the layers so far whose ceil-unit
    // costs sum to exactly u; pick[l][u] = the candidate that achieved it
    // (one flat u16 row per layer — the old code cloned a Vec per relaxed
    // cell, O(layers^2 x UNITS) churn).
    const UNSET: u16 = u16::MAX;
    let neg = f64::INFINITY;
    let mut dp = vec![neg; UNITS + 1];
    dp[0] = 0.0;
    let mut picks: Vec<Vec<u16>> = Vec::with_capacity(layers.len());
    for l in layers {
        let mut dp2 = vec![neg; UNITS + 1];
        let mut pick = vec![UNSET; UNITS + 1];
        for (ci, c) in l.candidates.iter().enumerate() {
            let cost = cost_units(c, l.size, unit);
            let err = c.err * l.size as f64;
            for u in cost..=UNITS {
                let prev = dp[u - cost];
                if prev.is_finite() && prev + err < dp2[u] {
                    dp2[u] = prev + err;
                    // audit:allow(lossy-cast) — candidate index into the small alpha ladder
                    pick[u] = ci as u16;
                }
            }
        }
        dp = dp2;
        picks.push(pick);
    }
    // best over all u
    let (mut best_u, mut best) = (UNITS, f64::INFINITY);
    for (u, &e) in dp.iter().enumerate() {
        if e < best {
            best = e;
            best_u = u;
        }
    }
    if !best.is_finite() {
        // degenerate fallback: cheapest everywhere (also covers feasible
        // budgets so close to the floor that ceil-rounding overflows the
        // unit axis — the cheapest choice is within budget by definition)
        let choice: Vec<usize> = layers
            .iter()
            .map(|l| {
                l.candidates
                    .iter()
                    .enumerate()
                    .min_by(|a, b| a.1.bits.total_cmp(&b.1.bits))
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect();
        let total_bits: f64 = layers
            .iter()
            .zip(&choice)
            .map(|(l, &c)| l.candidates[c].bits * l.size as f64)
            .sum();
        let total_err = layers
            .iter()
            .zip(&choice)
            .map(|(l, &c)| l.candidates[c].err * l.size as f64)
            .sum();
        assert!(
            !feasible || total_bits <= budget_bits,
            "feasible budget overshot by cheapest fallback: {total_bits} > {budget_bits}"
        );
        return Allocation { choice, total_bits, total_err };
    }
    // backtrack through the per-layer choice tables: each layer's pick at
    // the current unit index names the candidate, whose ceil cost rewinds
    // the index deterministically
    let mut choice = vec![0usize; layers.len()];
    let mut u = best_u;
    for (li, l) in layers.iter().enumerate().rev() {
        let ci = picks[li][u] as usize;
        choice[li] = ci;
        u -= cost_units(&l.candidates[ci], l.size, unit);
    }
    let total_bits: f64 = layers
        .iter()
        .zip(&choice)
        .map(|(l, &c)| l.candidates[c].bits * l.size as f64)
        .sum();
    // ceil costs overestimate: sum of true bits <= best_u * unit <= budget
    assert!(
        !feasible || total_bits <= budget_bits,
        "feasible budget overshot by DP: {total_bits} > {budget_bits}"
    );
    Allocation { choice, total_bits, total_err: best }
}

/// Build the candidate error curve of one layer from its normalized-magnitude
/// histogram: for each alpha in `alphas`, optimize the levels against the CDF
/// and record (bits, expected variance).
pub fn error_curve(
    hist: &NormalizedHistogram,
    alphas: &[usize],
    sweeps: usize,
) -> Vec<Candidate> {
    alphas
        .iter()
        .map(|&alpha| {
            let (seq, err) = adaptive::optimize_levels(hist, alpha, sweeps);
            let bits = (seq.num_symbols() as f64).log2().ceil() + 1.0; // + sign
            Candidate { alpha, bits, err }
        })
        .collect()
}

/// Standard alpha ladder: level counts corresponding to 1..=max_bits wire bits.
pub fn alpha_ladder(max_bits: u32) -> Vec<usize> {
    (1..=max_bits).map(|b| (1usize << b) - 2).map(|a| a.max(1)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::rng::Rng;
    use crate::util::prop::{for_cases, Gen};

    fn flat_candidates(errs: &[f64], bits: &[f64]) -> Vec<Candidate> {
        errs.iter()
            .zip(bits)
            .enumerate()
            .map(|(i, (&e, &b))| Candidate { alpha: i + 1, bits: b, err: e })
            .collect()
    }

    #[test]
    fn respects_budget() {
        let layers = vec![
            LayerProblem {
                size: 1000,
                candidates: flat_candidates(&[0.1, 0.01], &[2.0, 6.0]),
            },
            LayerProblem {
                size: 1000,
                candidates: flat_candidates(&[0.1, 0.01], &[2.0, 6.0]),
            },
        ];
        // budget only allows one layer at 6 bits; the bound is exact — ceil
        // cost discretization never overshoots a feasible budget
        let a = allocate(&layers, 8500.0);
        assert!(a.total_bits <= 8500.0);
        // it should upgrade exactly one layer
        let upgraded = a.choice.iter().filter(|&&c| c == 1).count();
        assert_eq!(upgraded, 1, "{:?}", a.choice);
    }

    #[test]
    fn spends_budget_on_sensitive_layer() {
        // layer 0 gains much more from extra bits than layer 1
        let layers = vec![
            LayerProblem {
                size: 1000,
                candidates: flat_candidates(&[1.0, 0.01], &[2.0, 5.0]),
            },
            LayerProblem {
                size: 1000,
                candidates: flat_candidates(&[0.02, 0.01], &[2.0, 5.0]),
            },
        ];
        let a = allocate(&layers, 7000.0);
        assert_eq!(a.choice[0], 1, "sensitive layer should get the bits");
        assert_eq!(a.choice[1], 0);
    }

    #[test]
    fn generous_budget_takes_best_everywhere() {
        let layers = vec![LayerProblem {
            size: 10,
            candidates: flat_candidates(&[0.5, 0.2, 0.05], &[1.0, 3.0, 8.0]),
        }];
        let a = allocate(&layers, 1e9);
        assert_eq!(a.choice, vec![2]);
    }

    #[test]
    fn impossible_budget_falls_back_to_cheapest() {
        let layers = vec![LayerProblem {
            size: 1_000_000,
            candidates: flat_candidates(&[0.5, 0.1], &[4.0, 8.0]),
        }];
        let a = allocate(&layers, 1.0);
        assert_eq!(a.choice, vec![0]);
    }

    #[test]
    fn error_curve_monotone() {
        let mut rng = Rng::new(4);
        let mut h = NormalizedHistogram::new(128);
        h.add_sample((0..4000).map(|_| rng.uniform()), 1.0);
        let curve = error_curve(&h, &alpha_ladder(6), 4);
        for w in curve.windows(2) {
            assert!(w[1].err <= w[0].err * 1.001, "{curve:?}");
            assert!(w[1].bits >= w[0].bits);
        }
    }

    /// Random allocation problems: heterogeneous sizes, unsorted-by-merit
    /// candidate ladders with increasing bit costs.
    fn random_layers(g: &mut Gen) -> Vec<LayerProblem> {
        let nl = g.usize_in(1, 5);
        (0..nl)
            .map(|_| {
                let size = g.usize_in(1, 3000);
                let nc = g.usize_in(1, 5);
                let mut bits: Vec<f64> = (0..nc).map(|_| g.f64_in(1.0, 9.0)).collect();
                bits.sort_by(f64::total_cmp);
                let candidates = bits
                    .iter()
                    .enumerate()
                    .map(|(i, &b)| Candidate {
                        alpha: i + 1,
                        bits: b,
                        err: g.f64_in(1e-4, 1.0),
                    })
                    .collect();
                LayerProblem { size, candidates }
            })
            .collect()
    }

    fn cheapest_total(layers: &[LayerProblem]) -> f64 {
        layers
            .iter()
            .map(|l| {
                l.candidates
                    .iter()
                    .map(|c| c.bits * l.size as f64)
                    .fold(f64::INFINITY, f64::min)
            })
            .sum()
    }

    #[test]
    fn prop_allocate_never_exceeds_feasible_budget() {
        for_cases(80, 0x1ecc0, |g| {
            let layers = random_layers(g);
            let cheapest = cheapest_total(&layers);
            let max_total: f64 = layers
                .iter()
                .map(|l| {
                    l.candidates
                        .iter()
                        .map(|c| c.bits * l.size as f64)
                        .fold(0.0, f64::max)
                })
                .sum();
            // anywhere from the feasibility floor to beyond the richest spend
            let budget = cheapest + g.f64_in(0.0, 1.5) * (max_total - cheapest).max(1.0);
            let a = allocate(&layers, budget);
            assert!(
                a.total_bits <= budget,
                "overshoot: {} > {budget} (choice {:?})",
                a.total_bits,
                a.choice
            );
        });
    }

    #[test]
    fn prop_allocate_monotone_in_budget() {
        // more budget never hurts: ceil-rounded unit costs shrink as the
        // budget grows, so every allocation reachable at b1 stays reachable
        // at b2 >= b1 (this covers the infeasible -> fallback region too)
        for_cases(80, 0x1ecc1, |g| {
            let layers = random_layers(g);
            let cheapest = cheapest_total(&layers);
            let b1 = g.f64_in(0.1, 2.5) * cheapest.max(1.0);
            let b2 = b1 * (1.0 + g.f64_in(0.0, 2.0));
            let e1 = allocate(&layers, b1).total_err;
            let e2 = allocate(&layers, b2).total_err;
            assert!(e2 <= e1, "err went up with budget: {e2} > {e1} ({b1} -> {b2})");
        });
    }

    #[test]
    fn dp_beats_uniform_allocation_on_heterogeneous_layers() {
        // Two layers, same size; one has near-zero error even at 2 bits.
        // Uniform 4-bit spend: err = (0.001 + 0.3) * size.
        // DP with the same total budget: 2 bits on easy + 6 bits on hard.
        let layers = vec![
            LayerProblem {
                size: 100,
                candidates: flat_candidates(&[0.001, 0.001, 0.001], &[2.0, 4.0, 6.0]),
            },
            LayerProblem {
                size: 100,
                candidates: flat_candidates(&[0.9, 0.3, 0.02], &[2.0, 4.0, 6.0]),
            },
        ];
        let budget = 100.0 * 4.0 * 2.0;
        let a = allocate(&layers, budget);
        let uniform_err = (0.001 + 0.3) * 100.0;
        assert!(a.total_err < uniform_err, "{} vs {uniform_err}", a.total_err);
        assert!(a.total_bits <= budget);
    }
}

//! Layer-wise quantization framework (paper Section 3):
//! level sequences, the unbiased stochastic quantizer, layer maps, the
//! Theorem 5.1 variance bound, adaptive level optimization (Eq. 2–3) and
//! the L-GreCo dynamic-programming bit allocator.

pub mod adaptive;
pub mod layer_map;
pub mod levels;
pub mod lgreco;
pub mod quantizer;
pub mod variance;

pub use layer_map::{Layer, LayerMap};
pub use levels::LevelSequence;
pub use quantizer::{
    dequantize, quantize, quantize_dequantize, QuantConfig, QuantizedLayer, QuantizedVector,
};

//! Layer-wise quantization framework (paper Section 3):
//! level sequences, the unbiased stochastic quantizer, layer maps, the
//! Theorem 5.1 variance bound, adaptive level optimization (Eq. 2–3), the
//! L-GreCo dynamic-programming bit allocator and the bit-width scheduler
//! that re-runs it over training.
//!
//! # Static vs scheduled allocation
//!
//! The quantizer itself is static per call: a [`QuantConfig`] holds one
//! [`LevelSequence`] per layer type and every encode quantizes against it.
//! What changes over training is *which* sequences are installed:
//!
//! - **Fixed** (`Adaptation::Fixed`): the start sequences live for the whole
//!   run — the QSGD/Q-GenX-style global baseline.
//! - **Measured re-tuning** (`Adaptation::Levels` / `Adaptation::LGreco`):
//!   every `every` *encodes*, the codec re-optimizes levels (and, for
//!   L-GreCo, re-allocates per-type alphas under a bit budget) from the
//!   encode-side histograms it folded since the last update.
//! - **Scheduled** (`Adaptation::Scheduled`): the same L-GreCo solve, but
//!   driven by [`schedule::plan_sequences`] from *receiver-observable*
//!   statistics — histograms folded from **decoded** values, triggered by
//!   the decode counter. Every party that observes a stream (the encoding
//!   worker via a self-decode, the sim endpoint, the leader's per-node
//!   decoder replica) folds identical values and re-plans at identical
//!   counts, so the schedule stays in lock-step on every node without any
//!   side channel.
//!
//! # Determinism contract (what the parity suites pin)
//!
//! An update step is a pure function of the statistics folded since the last
//! update: [`schedule::plan`] draws no randomness, iterates types in index
//! order, and the DP breaks ties deterministically. Two codecs that fold the
//! same values in the same order and update at the same call counts hold
//! bit-identical sequences and codebooks forever after. This is the
//! invariant that keeps `tests/golden_parity.rs`, `tests/fused_parity.rs`,
//! `tests/topology_equivalence.rs` and `tests/wire_e2e.rs` bit-identical
//! with scheduling off, and `tests/scheduled_parity.rs` bit-identical across
//! both engines with scheduling on. Update steps happen only *between*
//! packets: a packet already encoded always decodes with the books it was
//! encoded under.

pub mod adaptive;
pub mod layer_map;
pub mod levels;
pub mod lgreco;
pub mod quantizer;
pub mod schedule;
pub mod variance;

pub use layer_map::{Layer, LayerMap};
pub use levels::LevelSequence;
pub use quantizer::{
    dequantize, quantize, quantize_dequantize, QuantConfig, QuantizedLayer, QuantizedVector,
};

//! Unbiased stochastic layer-wise quantization Q_{L^M} (Section 3.1).
//!
//! The coordinator hot path: each layer (or bucket) is normalized by its own
//! L^q norm and every coordinate is stochastically rounded to its type's
//! level sequence. The output is the *wire form* — per-layer norm + per
//! coordinate (sign, level index) — which the coding layer entropy-codes.
//! Dequantization reconstructs `norm * sign * level[idx]`.
//!
//! Bit-exactness with the L1 Pallas kernel / jnp oracle is enforced by
//! rust/tests/quant_crosscheck.rs on shared test vectors.

use super::layer_map::LayerMap;
use super::levels::LevelSequence;
use crate::stats::rng::Rng;
use crate::stats::vecops::lq_norm;

/// Per-type configuration of the quantizer.
#[derive(Clone, Debug)]
pub struct QuantConfig {
    /// level sequence for each type id of the LayerMap
    pub sequences: Vec<LevelSequence>,
    /// L^q normalization (2.0 for L2, 1.0 for L1, f64::INFINITY for Linf)
    pub q: f64,
}

impl QuantConfig {
    pub fn uniform_bits(num_types: usize, bits: u32, q: f64) -> Self {
        QuantConfig {
            sequences: (0..num_types).map(|_| LevelSequence::bits(bits)).collect(),
            q,
        }
    }

    pub fn same(num_types: usize, seq: LevelSequence, q: f64) -> Self {
        QuantConfig { sequences: vec![seq; num_types], q }
    }
}

/// Quantized layer in wire form.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct QuantizedLayer {
    /// L^q norm of the raw layer slice
    pub norm: f64,
    /// level index per coordinate (fits u8 for <= 256 symbols)
    pub indices: Vec<u8>,
    /// sign bit per coordinate, packed (1 = negative)
    pub signs: Vec<u64>,
    /// type id (selects the codebook / level sequence)
    pub type_id: usize,
    pub len: usize,
}

impl QuantizedLayer {
    #[inline]
    pub fn sign(&self, i: usize) -> bool {
        (self.signs[i / 64] >> (i % 64)) & 1 == 1
    }

    #[inline]
    fn set_sign(signs: &mut [u64], i: usize) {
        signs[i / 64] |= 1 << (i % 64);
    }
}

/// Quantized flat vector: one entry per layer of the LayerMap.
#[derive(Clone, Debug, Default)]
pub struct QuantizedVector {
    pub layers: Vec<QuantizedLayer>,
    pub dim: usize,
}

/// Stochastically quantize one contiguous slice against `seq`.
/// Uniform randoms are drawn from `rng` (one per coordinate), matching the
/// Pallas kernel's semantics: round up iff u01 < xi.
pub fn quantize_slice(
    v: &[f32],
    seq: &LevelSequence,
    q: f64,
    type_id: usize,
    rng: &mut Rng,
) -> QuantizedLayer {
    let mut out = QuantizedLayer::default();
    quantize_slice_into(v, seq, q, type_id, rng, &mut out);
    out
}

/// `quantize_slice` into a reusable layer buffer (the comm hot path — no
/// per-step allocation once `out` has warmed up).
pub fn quantize_slice_into(
    v: &[f32],
    seq: &LevelSequence,
    q: f64,
    type_id: usize,
    rng: &mut Rng,
    out: &mut QuantizedLayer,
) {
    assert!(seq.num_symbols() <= 256, "u8 index encoding");
    // the wire header carries the norm as f32 (C_q = 32); round here so
    // quantize -> encode -> decode -> dequantize is bit-exact
    let norm = lq_norm(v, q) as f32 as f64;
    let n = v.len();
    out.indices.clear();
    out.indices.resize(n, 0);
    out.signs.clear();
    out.signs.resize(n.div_ceil(64), 0);
    out.norm = norm;
    out.type_id = type_id;
    out.len = n;
    let indices = &mut out.indices;
    let signs = &mut out.signs;
    if norm > 0.0 {
        let inv = 1.0 / norm;
        let ls = seq.as_slice();
        let nlev = ls.len();
        if let Some(inv_step) = seq.uniform_inv_step() {
            // fast path: uniformly spaced levels — closed-form bracket, no
            // search, no per-interval division (xi = frac of u * inv_step)
            for (i, &x) in v.iter().enumerate() {
                if x < 0.0 {
                    QuantizedLayer::set_sign(signs, i);
                }
                let mag = ((x.abs() as f64) * inv).min(1.0);
                let pos = mag * inv_step;
                let mut tau = pos as usize;
                let mut xi = pos - tau as f64;
                if tau >= nlev - 1 {
                    tau = nlev - 2;
                    xi = 1.0;
                }
                let u01 = rng.uniform_f32() as f64;
                indices[i] = if u01 < xi { (tau + 1) as u8 } else { tau as u8 };
            }
        } else {
            for (i, &x) in v.iter().enumerate() {
                if x < 0.0 {
                    QuantizedLayer::set_sign(signs, i);
                }
                let mag = ((x.abs() as f64) * inv).clamp(0.0, 1.0);
                let tau = seq.bracket(mag);
                let (lo, hi) = (ls[tau], ls[tau + 1]);
                let xi = (mag - lo) / (hi - lo).max(1e-38);
                let u01 = rng.uniform_f32() as f64;
                indices[i] = if u01 < xi { (tau + 1) as u8 } else { tau as u8 };
            }
        }
    }
}

/// Quantize a full flat vector layer-by-layer per the map and config.
pub fn quantize(
    v: &[f32],
    map: &LayerMap,
    cfg: &QuantConfig,
    rng: &mut Rng,
) -> QuantizedVector {
    let mut qv = QuantizedVector::default();
    quantize_into(v, map, cfg, rng, &mut qv);
    qv
}

/// `quantize` into a reusable `QuantizedVector` (per-layer index/sign
/// buffers are recycled across calls).
pub fn quantize_into(
    v: &[f32],
    map: &LayerMap,
    cfg: &QuantConfig,
    rng: &mut Rng,
    qv: &mut QuantizedVector,
) {
    assert_eq!(v.len(), map.dim);
    qv.dim = map.dim;
    qv.layers.resize_with(map.layers.len(), Default::default);
    for (l, out) in map.layers.iter().zip(&mut qv.layers) {
        quantize_slice_into(
            &v[l.offset..l.offset + l.len],
            &cfg.sequences[l.type_id],
            cfg.q,
            l.type_id,
            rng,
            out,
        );
    }
}

/// Dequantize back into a flat f32 vector.
pub fn dequantize(qv: &QuantizedVector, cfg: &QuantConfig) -> Vec<f32> {
    let mut out = Vec::with_capacity(qv.dim);
    dequantize_into(qv, cfg, &mut out);
    out
}

/// `dequantize` into a reusable output buffer (cleared first).
pub fn dequantize_into(qv: &QuantizedVector, cfg: &QuantConfig, out: &mut Vec<f32>) {
    out.clear();
    out.reserve(qv.dim);
    for layer in &qv.layers {
        dequantize_layer_into(layer, cfg, out);
    }
    debug_assert_eq!(out.len(), qv.dim);
}

pub fn dequantize_layer_into(layer: &QuantizedLayer, cfg: &QuantConfig, out: &mut Vec<f32>) {
    let ls = cfg.sequences[layer.type_id].as_slice();
    for i in 0..layer.len {
        let mag = layer.norm * ls[layer.indices[i] as usize];
        out.push(if layer.sign(i) { -(mag as f32) } else { mag as f32 });
    }
}

/// One-call quantize+dequantize (what a node applies to its own dual vector
/// before local aggregation, ensuring every node sees identical values).
pub fn quantize_dequantize(
    v: &[f32],
    map: &LayerMap,
    cfg: &QuantConfig,
    rng: &mut Rng,
) -> Vec<f32> {
    dequantize(&quantize(v, map, cfg, rng), cfg)
}

/// Exact wire size in bits of the *naive fixed-width* encoding: C_q bits for
/// the norm + 1 sign bit per nonzero + ceil(log2(symbols)) per coordinate.
/// The entropy coder (coding::protocol) beats this; used for compression-
/// ratio accounting and as the torch_cgx-style "no extra coding" mode
/// (paper footnote 6: no additional encoding on top of quantization).
pub fn fixed_width_bits(qv: &QuantizedVector, cfg: &QuantConfig, norm_bits: usize) -> usize {
    qv.layers
        .iter()
        .map(|l| {
            let idx_bits = cfg.sequences[l.type_id].index_bits() as usize;
            norm_bits + l.len * (idx_bits + 1)
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::for_cases;

    fn map3() -> LayerMap {
        LayerMap::from_spec(&[("a", 64, "ff"), ("b", 32, "bias"), ("c", 100, "ff")])
    }

    #[test]
    fn roundtrip_values_are_levels() {
        let map = map3();
        let cfg = QuantConfig::uniform_bits(map.num_types(), 3, 2.0);
        let mut rng = Rng::new(1);
        let v: Vec<f32> = (0..196).map(|i| ((i as f32) - 98.0) / 17.0).collect();
        let qv = quantize(&v, &map, &cfg, &mut rng);
        let dq = dequantize(&qv, &cfg);
        assert_eq!(dq.len(), v.len());
        // each dequantized magnitude equals norm * some level of its layer
        for (li, l) in map.layers.iter().enumerate() {
            let norm = qv.layers[li].norm;
            let ls = cfg.sequences[l.type_id].as_slice();
            for i in 0..l.len {
                let mag = (dq[l.offset + i].abs() as f64) / norm.max(1e-30);
                let close = ls.iter().any(|&x| (x - mag).abs() < 1e-5);
                assert!(close, "mag {mag} not a level");
            }
        }
    }

    #[test]
    fn signs_preserved() {
        let map = LayerMap::single(50);
        let cfg = QuantConfig::uniform_bits(1, 4, 2.0);
        let mut rng = Rng::new(2);
        let v: Vec<f32> = (0..50).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let dq = quantize_dequantize(&v, &map, &cfg, &mut rng);
        for (x, y) in v.iter().zip(&dq) {
            assert!(x * y >= 0.0, "sign flipped: {x} {y}");
        }
    }

    #[test]
    fn zero_vector_roundtrips_to_zero() {
        let map = LayerMap::single(16);
        let cfg = QuantConfig::uniform_bits(1, 3, 2.0);
        let mut rng = Rng::new(3);
        let dq = quantize_dequantize(&vec![0.0; 16], &map, &cfg, &mut rng);
        assert!(dq.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn unbiasedness_statistical() {
        // E[Q(v)] = v componentwise (the scheme's defining property)
        let map = LayerMap::single(32);
        let cfg = QuantConfig::uniform_bits(1, 2, 2.0);
        let mut rng = Rng::new(4);
        let v: Vec<f32> = (0..32).map(|i| ((i * 37 % 17) as f32 - 8.0) / 3.0).collect();
        let reps = 4000;
        let mut acc = vec![0.0f64; 32];
        for _ in 0..reps {
            let dq = quantize_dequantize(&v, &map, &cfg, &mut rng);
            for (a, &x) in acc.iter_mut().zip(&dq) {
                *a += x as f64;
            }
        }
        let norm = lq_norm(&v, 2.0);
        for (i, a) in acc.iter().enumerate() {
            let mean = a / reps as f64;
            // 5-sigma CLT bound with per-coord std <= norm/2
            let tol = 5.0 * norm * 0.5 / (reps as f64).sqrt();
            assert!((mean - v[i] as f64).abs() < tol, "coord {i}: {mean} vs {}", v[i]);
        }
    }

    #[test]
    fn layerwise_norms_are_per_layer() {
        let map = LayerMap::from_spec(&[("small", 10, "ff"), ("big", 10, "ff")]);
        let cfg = QuantConfig::uniform_bits(1, 4, 2.0);
        let mut rng = Rng::new(5);
        let mut v = vec![0.01f32; 10];
        v.extend(vec![100.0f32; 10]);
        let qv = quantize(&v, &map, &cfg, &mut rng);
        assert!(qv.layers[0].norm < 1.0);
        assert!(qv.layers[1].norm > 100.0);
        // small layer still reconstructs to the right scale
        let dq = dequantize(&qv, &cfg);
        assert!(dq[..10].iter().all(|&x| x.abs() < 0.1));
    }

    #[test]
    fn fixed_width_accounting() {
        let map = LayerMap::single(100);
        let cfg = QuantConfig::uniform_bits(1, 5, 2.0);
        let mut rng = Rng::new(6);
        let v = vec![1.0f32; 100];
        let qv = quantize(&v, &map, &cfg, &mut rng);
        // 32-bit norm + 100 * (5 idx + 1 sign)
        assert_eq!(fixed_width_bits(&qv, &cfg, 32), 32 + 600);
    }

    #[test]
    fn prop_roundtrip_sign_and_levelset() {
        for_cases(40, 99, |g| {
            let n = g.usize_in(1, 400);
            let v = g.vec_f32(n, 3.0);
            let full = g.level_sequence(10);
            let seq = LevelSequence::new(full);
            let map = LayerMap::single(n);
            let cfg = QuantConfig::same(1, seq, 2.0);
            let mut rng = Rng::new(g.rng.next_u64());
            let dq = quantize_dequantize(&v, &map, &cfg, &mut rng);
            let norm = lq_norm(&v, 2.0);
            for (x, y) in v.iter().zip(&dq) {
                assert!(x * y >= 0.0);
                assert!((y.abs() as f64) <= norm * (1.0 + 1e-5));
            }
        });
    }
}

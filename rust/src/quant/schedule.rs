//! Bit-width scheduling: re-solve the L-GreCo allocation from measured
//! statistics as training evolves (ALQ-style norm/variance-driven
//! re-allocation).
//!
//! This module is the pure planning half of the scheduled adaptation loop:
//! given the per-type histograms gathered since the last update
//! ([`TypeStats`]) and the layer sizes of a [`LayerMap`], it runs the fixed
//! L-GreCo DP under a global wire-bit budget and maps the chosen alphas back
//! through `adaptive::optimize_levels` into per-type [`LevelSequence`]s.
//! [`plan_sequences`] is the exact computation `QuantCompressor::update_levels`
//! performs at an `Adaptation::LGreco`/`Adaptation::Scheduled` update step —
//! the codec delegates here, so the parity suites pin this function too.
//!
//! Determinism contract: the plan is a pure function of `(map, stats, budget,
//! max_bits)`. Nodes that fold identical statistics and call at identical
//! step counts compute identical schedules — this is what keeps the scheduled
//! runs bit-identical across engines (see `quant/mod.rs` and
//! `tests/scheduled_parity.rs`).

use crate::quant::adaptive::{adapt_all, TypeStats};
use crate::quant::layer_map::LayerMap;
use crate::quant::lgreco;
use crate::quant::LevelSequence;

/// One solved schedule: the DP's choice per type plus its cost/error
/// accounting, for reporting and for the ablation pins.
#[derive(Clone, Debug)]
pub struct BitSchedule {
    /// chosen interior-level count (alpha) per type
    pub alphas: Vec<usize>,
    /// fixed-width wire bits/coordinate per type (incl. sign) of the choice
    pub wire_bits: Vec<f64>,
    /// total estimated wire bits of the allocation (fixed-width model)
    pub total_bits: f64,
    /// total weighted quantization error of the allocation
    pub total_err: f64,
    /// the budget the plan was solved under, in total wire bits
    pub budget_bits: f64,
}

impl BitSchedule {
    /// Average scheduled bits/coordinate across the whole vector.
    pub fn bits_per_coord(&self, dim: usize) -> f64 {
        self.total_bits / dim.max(1) as f64
    }
}

/// Build the per-type DP inputs from the measured histograms: one
/// [`lgreco::LayerProblem`] per type, sized by the total coordinates of that
/// type's layers, with candidates along the standard alpha ladder. Public so
/// the ablation harness can evaluate static allocations on the exact
/// candidate grid the planner solves over.
pub fn type_problems(
    map: &LayerMap,
    stats: &[TypeStats],
    ladder: &[usize],
) -> Vec<lgreco::LayerProblem> {
    (0..map.num_types())
        .map(|m| {
            let size: usize = map.layers_of_type(m).map(|l| l.len).sum();
            lgreco::LayerProblem {
                size: size.max(1),
                candidates: lgreco::error_curve(&stats[m].hist, ladder, 4),
            }
        })
        .collect()
}

/// Solve the budgeted allocation and return the chosen per-type alphas with
/// their cost/error accounting. `budget_bits_per_coord` is the global budget
/// divided by the vector dimension (the same convention as
/// `Adaptation::LGreco`); `max_bits` caps the candidate ladder.
pub fn plan(
    map: &LayerMap,
    stats: &[TypeStats],
    budget_bits_per_coord: f64,
    max_bits: u32,
) -> BitSchedule {
    debug_assert!(max_bits >= 1, "the alpha ladder needs at least 1 bit");
    debug_assert_eq!(stats.len(), map.num_types());
    let ladder = lgreco::alpha_ladder(max_bits);
    let problems = type_problems(map, stats, &ladder);
    let budget = budget_bits_per_coord * map.dim as f64;
    let alloc = lgreco::allocate(&problems, budget);
    let alphas: Vec<usize> = alloc
        .choice
        .iter()
        .map(|&c| ladder[c.min(ladder.len() - 1)])
        .collect();
    let wire_bits: Vec<f64> = alloc
        .choice
        .iter()
        .zip(&problems)
        .map(|(&c, p)| p.candidates[c.min(p.candidates.len() - 1)].bits)
        .collect();
    BitSchedule {
        alphas,
        wire_bits,
        total_bits: alloc.total_bits,
        total_err: alloc.total_err,
        budget_bits: budget,
    }
}

/// The full update step the codec runs under scheduled adaptation: solve the
/// budgeted allocation, then re-optimize each type's levels at its chosen
/// alpha against the measured CDF. Bit-identical to the historical inline
/// `Adaptation::LGreco` arm of `QuantCompressor::update_levels` — the codec
/// now calls this function, and `tests/fused_parity.rs` pins the grid.
pub fn plan_sequences(
    map: &LayerMap,
    stats: &[TypeStats],
    budget_bits_per_coord: f64,
    max_bits: u32,
) -> Vec<LevelSequence> {
    let schedule = plan(map, stats, budget_bits_per_coord, max_bits);
    let (seqs, _) = adapt_all(stats, &schedule.alphas, 6);
    seqs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::rng::Rng;

    fn map3() -> LayerMap {
        LayerMap::from_spec(&[
            ("dense.w", 2048, "ff"),
            ("emb.w", 1024, "embedding"),
            ("head.w", 512, "attention"),
        ])
    }

    /// Fold gradient-like samples with per-type scale separation so the DP
    /// has a real trade-off to exploit.
    fn measured_stats(map: &LayerMap, seed: u64) -> Vec<TypeStats> {
        let mut rng = Rng::new(seed);
        let mut stats: Vec<TypeStats> =
            (0..map.num_types()).map(|_| TypeStats::default()).collect();
        for l in &map.layers {
            let scale = [1.0f32, 0.05, 2.0][l.type_id % 3];
            let v: Vec<f32> =
                (0..l.len).map(|_| rng.gaussian() as f32 * scale).collect();
            stats[l.type_id].add_layer_sample(&v, 2.0);
        }
        stats
    }

    #[test]
    fn plan_is_deterministic() {
        let map = map3();
        let stats = measured_stats(&map, 9);
        let a = plan(&map, &stats, 5.0, 6);
        let b = plan(&map, &stats, 5.0, 6);
        assert_eq!(a.alphas, b.alphas);
        assert_eq!(a.total_bits.to_bits(), b.total_bits.to_bits());
        assert_eq!(a.total_err.to_bits(), b.total_err.to_bits());
    }

    #[test]
    fn plan_respects_budget_and_monotone_error() {
        let map = map3();
        let stats = measured_stats(&map, 10);
        let tight = plan(&map, &stats, 2.0, 6);
        let loose = plan(&map, &stats, 6.0, 6);
        assert!(tight.total_bits <= tight.budget_bits);
        assert!(loose.total_bits <= loose.budget_bits);
        assert!(loose.total_err <= tight.total_err);
        assert!(tight.bits_per_coord(map.dim) <= 2.0);
    }

    #[test]
    fn plan_sequences_matches_plan_alphas() {
        let map = map3();
        let stats = measured_stats(&map, 11);
        let schedule = plan(&map, &stats, 5.0, 6);
        let seqs = plan_sequences(&map, &stats, 5.0, 6);
        assert_eq!(seqs.len(), map.num_types());
        for (seq, &alpha) in seqs.iter().zip(&schedule.alphas) {
            assert_eq!(seq.alpha(), alpha);
        }
    }

    #[test]
    fn empty_stats_still_plan() {
        // cold-start: no samples folded yet — the curve degenerates but the
        // plan must stay valid (cheapest-feasible) and never panic
        let map = map3();
        let stats: Vec<TypeStats> =
            (0..map.num_types()).map(|_| TypeStats::default()).collect();
        let s = plan(&map, &stats, 4.0, 6);
        assert_eq!(s.alphas.len(), map.num_types());
        assert!(s.total_bits <= s.budget_bits);
    }
}

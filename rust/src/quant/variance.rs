//! Theorem 5.1: variance bound for layer-wise quantization, plus empirical
//! variance measurement used by the verification harness (`qoda
//! verify-variance`) and the convergence-rate constants.

use super::layer_map::LayerMap;
use super::levels::LevelSequence;
use super::quantizer::{quantize_dequantize, QuantConfig};
use crate::stats::rng::Rng;

/// epsilon_Q of Theorem 5.1 for a set of per-type sequences, dimension d and
/// L^q normalization:
///
///   eps_Q = (lbar - 1)^2 / (4 lbar)
///         + (lbar_1 d^{1/min(q,2)} - 1)            if d >= d_th
///         + (lbar_1^2 / 4) d^{2/min(q,2)}          if d <  d_th
///
/// where lbar = max_m max_j l^m_{j+1}/l^m_j (j >= 1), lbar_1 = max_m l^m_1,
/// d_th = (2 / lbar_1)^{min(2,q)}.
pub fn eps_q(sequences: &[LevelSequence], d: usize, q: f64) -> f64 {
    assert!(!sequences.is_empty());
    let lbar = sequences.iter().map(|s| s.max_ratio()).fold(1.0f64, f64::max);
    let l1 = sequences.iter().map(|s| s.l1()).fold(0.0f64, f64::max);
    let qm = q.min(2.0).max(1.0);
    let d_th = (2.0 / l1).powf(qm);
    let df = d as f64;
    let mut eps = (lbar - 1.0).powi(2) / (4.0 * lbar);
    if df >= d_th {
        eps += l1 * df.powf(1.0 / qm) - 1.0;
    } else {
        eps += 0.25 * l1 * l1 * df.powf(2.0 / qm);
    }
    eps
}

/// eps_Q for a full quantizer configuration over a layer map. The bound
/// applies per normalization unit (layer); taking d = max layer length is
/// the worst case across layers.
pub fn eps_q_for(map: &LayerMap, cfg: &QuantConfig) -> f64 {
    let dmax = map.layers.iter().map(|l| l.len).max().unwrap_or(1);
    eps_q(&cfg.sequences, dmax, cfg.q)
}

/// Monte-Carlo estimate of E ||Q(v) - v||^2 / ||v||^2 for a fixed v.
pub fn empirical_variance_ratio(
    v: &[f32],
    map: &LayerMap,
    cfg: &QuantConfig,
    reps: usize,
    seed: u64,
) -> f64 {
    let mut rng = Rng::new(seed);
    let norm2: f64 = v.iter().map(|&x| (x as f64) * (x as f64)).sum();
    if norm2 == 0.0 {
        return 0.0;
    }
    let mut acc = 0.0;
    for _ in 0..reps {
        let dq = quantize_dequantize(v, map, cfg, &mut rng);
        acc += v
            .iter()
            .zip(&dq)
            .map(|(&a, &b)| {
                let d = a as f64 - b as f64;
                d * d
            })
            .sum::<f64>();
    }
    acc / reps as f64 / norm2
}

/// Remark 3.2 / (MQV): expected quantization variance of a *set* of vectors
/// under a configuration — the objective the adaptive optimizer minimizes.
pub fn mqv_objective(
    samples: &[Vec<f32>],
    map: &LayerMap,
    cfg: &QuantConfig,
    reps: usize,
    seed: u64,
) -> f64 {
    let mut rng = Rng::new(seed);
    let mut acc = 0.0;
    for v in samples {
        for _ in 0..reps {
            let dq = quantize_dequantize(v, map, cfg, &mut rng);
            acc += v
                .iter()
                .zip(&dq)
                .map(|(&a, &b)| {
                    let d = a as f64 - b as f64;
                    d * d
                })
                .sum::<f64>();
        }
    }
    acc / (samples.len() * reps) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::for_cases;

    #[test]
    fn eps_matches_qsgd_regime() {
        // M=1, L2 norm, s = sqrt(d) uniform levels: eps ~ O(sqrt(d)/s) small
        let d = 1024;
        let s = 32;
        let seq = LevelSequence::uniform(s);
        let e = eps_q(&[seq], d, 2.0);
        assert!(e > 0.0 && e < 10.0, "{e}");
    }

    #[test]
    fn eps_small_d_branch() {
        let seq = LevelSequence::uniform(255);
        // l1 = 1/256 => d_th = 512^2 huge => small-d branch
        let e_small = eps_q(&[seq.clone()], 4, 2.0);
        let expected = {
            let lbar = seq.max_ratio();
            (lbar - 1.0).powi(2) / (4.0 * lbar) + 0.25 * (1.0 / 256.0f64).powi(2) * 4.0
        };
        assert!((e_small - expected).abs() < 1e-12);
    }

    #[test]
    fn eps_monotone_in_dimension() {
        let seq = LevelSequence::uniform(3);
        let e1 = eps_q(&[seq.clone()], 64, 2.0);
        let e2 = eps_q(&[seq], 4096, 2.0);
        assert!(e2 > e1);
    }

    #[test]
    fn empirical_variance_below_bound() {
        // Theorem 5.1: empirical ratio <= eps_Q, for several sequences
        for_cases(10, 17, |g| {
            let n = g.usize_in(8, 300);
            let v = g.vec_f32(n, 1.0);
            let seq = LevelSequence::new(g.level_sequence(8));
            let map = LayerMap::single(n);
            let cfg = QuantConfig::same(1, seq.clone(), 2.0);
            let emp = empirical_variance_ratio(&v, &map, &cfg, 60, g.rng.next_u64());
            let bound = eps_q(&[seq], n, 2.0);
            assert!(
                emp <= bound * 1.10 + 1e-9,
                "empirical {emp} vs bound {bound} (n={n})"
            );
        });
    }

    #[test]
    fn more_levels_less_variance() {
        let n = 256;
        let mut rng = Rng::new(5);
        let v: Vec<f32> = (0..n).map(|_| rng.gaussian() as f32).collect();
        let map = LayerMap::single(n);
        let coarse = QuantConfig::uniform_bits(1, 2, 2.0);
        let fine = QuantConfig::uniform_bits(1, 6, 2.0);
        let ec = empirical_variance_ratio(&v, &map, &coarse, 50, 1);
        let ef = empirical_variance_ratio(&v, &map, &fine, 50, 1);
        assert!(ef < ec, "{ef} vs {ec}");
    }

    #[test]
    fn layerwise_beats_global_on_heterogeneous_layers() {
        // Remark 3.2: per-layer norms + tuned sequences cannot do worse.
        let mut rng = Rng::new(9);
        // layer A ~ N(0, 1), layer B ~ N(0, 100): global normalization
        // crushes layer A into the bottom interval.
        let mut v: Vec<f32> = (0..256).map(|_| rng.gaussian() as f32).collect();
        v.extend((0..256).map(|_| (rng.gaussian() * 100.0) as f32));
        let layer_map = LayerMap::from_spec(&[("a", 256, "ff"), ("b", 256, "ff")]);
        let global_map = LayerMap::single(512);
        let cfg = QuantConfig::uniform_bits(1, 4, 2.0);
        let lw = empirical_variance_ratio(&v, &layer_map, &cfg, 40, 2);
        let gl = empirical_variance_ratio(&v, &global_map, &cfg, 40, 2);
        assert!(lw <= gl, "layerwise {lw} vs global {gl}");
    }
}

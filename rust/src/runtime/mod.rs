//! PJRT runtime (L3 <-> L2 boundary): loads `artifacts/*.hlo.txt` produced
//! by `python -m compile.aot` and executes them on the CPU PJRT client.

pub mod model;
pub mod pjrt;

pub use model::{LmModel, WganModel};
pub use pjrt::{Executable, Runtime};

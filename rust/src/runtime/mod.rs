//! Model runtime (L3 <-> model boundary): the native pure-rust WGAN and
//! transformer-LM backends behind backend-agnostic wrappers. The original
//! PJRT/HLO-artifact path needs the external `xla` crate, which the offline
//! environment cannot provide; `Runtime` keeps the handle shape so such a
//! backend can return without driver changes.

pub mod model;
pub mod native;

pub use model::{LmModel, Runtime, WganModel};

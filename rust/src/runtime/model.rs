//! Model-level wrappers over the AOT artifacts: the WGAN VI operator and
//! sampler, and the transformer LM gradient/eval entry points. These are
//! the request-path interfaces the drivers (gan::trainer, lm::trainer) use.

use anyhow::{Context, Result};

use super::pjrt::{lit_f32, lit_i32_matrix, lit_i32_scalar, to_f32, to_f32_scalar, Executable, Runtime};
use crate::quant::layer_map::LayerMap;

/// WGAN operator + sampler + init (artifacts/wgan_*.hlo.txt).
pub struct WganModel {
    op: Executable,
    sample: Executable,
    init: Executable,
    pub meta: LayerMap,
    pub dim: usize,
    pub gen_dim: usize,
    pub sample_n: usize,
}

impl WganModel {
    pub fn load(rt: &Runtime) -> Result<Self> {
        let meta = LayerMap::load_meta(&crate::util::repo_path("artifacts/wgan.meta"))
            .map_err(anyhow::Error::msg)
            .context("load wgan.meta")?;
        let dim = meta.dim;
        let gen_dim = meta.extra_usize("gen_dim").context("gen_dim in meta")?;
        let sample_n = meta.extra_usize("sample_n").context("sample_n in meta")?;
        Ok(WganModel {
            op: rt.load_artifact("artifacts/wgan_op.hlo.txt")?,
            sample: rt.load_artifact("artifacts/wgan_sample.hlo.txt")?,
            init: rt.load_artifact("artifacts/wgan_init.hlo.txt")?,
            meta,
            dim,
            gen_dim,
            sample_n,
        })
    }

    /// Initial parameter vector (lowered from the jax initializer).
    pub fn init_params(&self, seed: i32) -> Result<Vec<f32>> {
        let out = self.init.run(&[lit_i32_scalar(seed)])?;
        to_f32(&out[0])
    }

    /// The stochastic dual vector A(theta) + minibatch noise:
    /// (dual, g_loss, w_dist).
    pub fn dual(&self, params: &[f32], seed: i32) -> Result<(Vec<f32>, f32, f32)> {
        anyhow::ensure!(params.len() == self.dim);
        let out = self.op.run(&[lit_f32(params), lit_i32_scalar(seed)])?;
        Ok((to_f32(&out[0])?, to_f32_scalar(&out[1])?, to_f32_scalar(&out[2])?))
    }

    /// (fake, real) samples, each sample_n x 2 row-major.
    pub fn samples(&self, params: &[f32], seed: i32) -> Result<(Vec<f32>, Vec<f32>)> {
        let out = self.sample.run(&[lit_f32(params), lit_i32_scalar(seed)])?;
        Ok((to_f32(&out[0])?, to_f32(&out[1])?))
    }
}

/// Transformer LM (artifacts/lm_*.hlo.txt).
pub struct LmModel {
    grad: Executable,
    eval: Executable,
    init: Executable,
    pub meta: LayerMap,
    pub dim: usize,
    pub vocab: usize,
    pub seq: usize,
    pub batch: usize,
}

impl LmModel {
    pub fn load(rt: &Runtime) -> Result<Self> {
        let meta = LayerMap::load_meta(&crate::util::repo_path("artifacts/lm.meta"))
            .map_err(anyhow::Error::msg)
            .context("load lm.meta")?;
        let dim = meta.dim;
        let vocab = meta.extra_usize("vocab").context("vocab")?;
        let seq = meta.extra_usize("seq").context("seq")?;
        let batch = meta.extra_usize("batch").context("batch")?;
        Ok(LmModel {
            grad: rt.load_artifact("artifacts/lm_grad.hlo.txt")?,
            eval: rt.load_artifact("artifacts/lm_eval.hlo.txt")?,
            init: rt.load_artifact("artifacts/lm_init.hlo.txt")?,
            meta,
            dim,
            vocab,
            seq,
            batch,
        })
    }

    pub fn init_params(&self, seed: i32) -> Result<Vec<f32>> {
        let out = self.init.run(&[lit_i32_scalar(seed)])?;
        to_f32(&out[0])
    }

    /// tokens: batch x (seq+1) row-major -> (grads, loss)
    pub fn grad(&self, params: &[f32], tokens: &[i32]) -> Result<(Vec<f32>, f32)> {
        anyhow::ensure!(params.len() == self.dim);
        anyhow::ensure!(tokens.len() == self.batch * (self.seq + 1));
        let toks = lit_i32_matrix(tokens, self.batch, self.seq + 1)?;
        let out = self.grad.run(&[lit_f32(params), toks])?;
        Ok((to_f32(&out[0])?, to_f32_scalar(&out[1])?))
    }

    /// mean NLL on a batch (perplexity = exp).
    pub fn eval(&self, params: &[f32], tokens: &[i32]) -> Result<f32> {
        let toks = lit_i32_matrix(tokens, self.batch, self.seq + 1)?;
        let out = self.eval.run(&[lit_f32(params), toks])?;
        to_f32_scalar(&out[0])
    }
}

//! Model-level wrappers: the WGAN VI operator and sampler, and the
//! transformer-LM gradient/eval entry points. These are the request-path
//! interfaces the drivers (gan::trainer, lm::trainer) use.
//!
//! Backed by the in-tree [`native`](super::native) implementations (the
//! offline environment has no PJRT/XLA runtime); the interfaces mirror the
//! original AOT-artifact wrappers so drivers are backend-agnostic.

use super::native;
use crate::quant::layer_map::LayerMap;
use crate::util::error::Result;

/// Device/runtime handle. The native backend is CPU-only; the struct exists
/// so that a future PJRT-style backend can slot in without driver changes.
pub struct Runtime;

impl Runtime {
    pub fn cpu() -> Result<Self> {
        Ok(Runtime)
    }
}

/// WGAN operator + sampler + init.
pub struct WganModel {
    pub meta: LayerMap,
    pub dim: usize,
    pub gen_dim: usize,
    pub sample_n: usize,
}

impl WganModel {
    pub fn load(_rt: &Runtime) -> Result<Self> {
        let meta = native::wgan_layer_map();
        meta.validate().map_err(crate::util::error::Error::msg)?;
        Ok(WganModel {
            dim: meta.dim,
            gen_dim: native::wgan_gen_dim(),
            sample_n: native::WGAN_SAMPLE_N,
            meta,
        })
    }

    /// Initial parameter vector.
    pub fn init_params(&self, seed: i32) -> Result<Vec<f32>> {
        Ok(native::wgan_init_params(seed))
    }

    /// The stochastic dual vector A(theta) + minibatch noise:
    /// (dual, g_loss, w_dist).
    pub fn dual(&self, params: &[f32], seed: i32) -> Result<(Vec<f32>, f32, f32)> {
        crate::ensure!(params.len() == self.dim, "params len != model dim");
        Ok(native::wgan_dual(params, seed))
    }

    /// (fake, real) samples, each sample_n x 2 row-major.
    pub fn samples(&self, params: &[f32], seed: i32) -> Result<(Vec<f32>, Vec<f32>)> {
        crate::ensure!(params.len() == self.dim, "params len != model dim");
        Ok(native::wgan_samples(params, seed))
    }
}

/// Transformer-LM stand-in.
pub struct LmModel {
    pub meta: LayerMap,
    pub dim: usize,
    pub vocab: usize,
    pub seq: usize,
    pub batch: usize,
}

impl LmModel {
    pub fn load(_rt: &Runtime) -> Result<Self> {
        let meta = native::lm_layer_map();
        meta.validate().map_err(crate::util::error::Error::msg)?;
        Ok(LmModel {
            dim: meta.dim,
            vocab: native::LM_VOCAB,
            seq: native::LM_SEQ,
            batch: native::LM_BATCH,
            meta,
        })
    }

    pub fn init_params(&self, seed: i32) -> Result<Vec<f32>> {
        Ok(native::lm_init_params(seed))
    }

    /// tokens: batch x (seq+1) row-major -> (grads, loss)
    pub fn grad(&self, params: &[f32], tokens: &[i32]) -> Result<(Vec<f32>, f32)> {
        crate::ensure!(params.len() == self.dim, "params len != model dim");
        crate::ensure!(
            tokens.len() == self.batch * (self.seq + 1),
            "tokens must be batch x (seq+1)"
        );
        let mut g = vec![0.0f64; self.dim];
        let loss = native::lm_loss_grad(params, tokens, Some(g.as_mut_slice()));
        Ok((g.iter().map(|&x| x as f32).collect(), loss as f32))
    }

    /// mean NLL on a batch (perplexity = exp).
    pub fn eval(&self, params: &[f32], tokens: &[i32]) -> Result<f32> {
        crate::ensure!(params.len() == self.dim, "params len != model dim");
        Ok(native::lm_loss_grad(params, tokens, None) as f32)
    }
}

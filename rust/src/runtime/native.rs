//! Native (pure-rust) model backend: the WGAN game on the 2-D mode circle
//! and the small transformer-LM stand-in, with hand-written forward and
//! backward passes.
//!
//! The original L2/L1 stack lowered jax models to HLO text and executed
//! them through PJRT (the external `xla` crate). That crate and the
//! `artifacts/*.hlo.txt` files are unavailable in the offline environment,
//! so this module provides numerically equivalent request-path models with
//! identical interfaces: deterministic given the minibatch seed, flat f32
//! parameter vectors, heterogeneous [`LayerMap`]s for the layer-wise
//! quantization machinery, and per-call gradient/loss/eval entry points.

use crate::quant::layer_map::LayerMap;
use crate::stats::rng::Rng;

/// Deterministic per-call RNG from an i32 minibatch seed (trainers derive
/// these with wrapping arithmetic, so negatives are legal).
pub fn call_rng(seed: i32, salt: u64) -> Rng {
    Rng::new((seed as i64 as u64) ^ salt.wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

// ---------------------------------------------------------------------------
// WGAN: generator z(2) -> tanh(H) -> 2, critic x(2) -> tanh(H) -> 1
// ---------------------------------------------------------------------------

/// Architecture constants of the native WGAN.
pub const WGAN_HIDDEN: usize = 128;
pub const WGAN_BATCH: usize = 128;
pub const WGAN_SAMPLE_N: usize = 512;
/// radius of the real-data mode circle
pub const WGAN_RADIUS: f64 = 2.0;
/// radial noise of the real data
pub const WGAN_RING_SIGMA: f64 = 0.05;
const WGAN_INIT_SCALE: f64 = 0.2;

/// Layer map of the flat WGAN parameter vector (generator first, then
/// critic — the trainer clips the critic segment).
pub fn wgan_layer_map() -> LayerMap {
    let h = WGAN_HIDDEN;
    let mut map = LayerMap::from_spec(&[
        ("gen.w1", 2 * h, "ff"),
        ("gen.b1", h, "bias"),
        ("gen.w2", h * 2, "ff"),
        ("gen.b2", 2, "bias"),
        ("critic.w1", 2 * h, "ff"),
        ("critic.b1", h, "bias"),
        ("critic.w2", h, "ff"),
        ("critic.b2", 1, "bias"),
    ]);
    // matrix shapes (rows x cols) for the factorizing compressors
    let shapes = [(2, h), (h, 1), (h, 2), (2, 1), (2, h), (h, 1), (h, 1), (1, 1)];
    for (l, &(r, c)) in map.layers.iter_mut().zip(&shapes) {
        l.rows = r;
        l.cols = c;
    }
    map.extra.insert("gen_dim".into(), wgan_gen_dim().to_string());
    map.extra.insert("sample_n".into(), WGAN_SAMPLE_N.to_string());
    map.extra.insert("batch".into(), WGAN_BATCH.to_string());
    map
}

pub fn wgan_dim() -> usize {
    let h = WGAN_HIDDEN;
    2 * h + h + h * 2 + 2 + 2 * h + h + h + 1
}

pub fn wgan_gen_dim() -> usize {
    let h = WGAN_HIDDEN;
    2 * h + h + h * 2 + 2
}

/// Parameter views into the flat vector (offsets match `wgan_layer_map`).
struct WganParams<'a> {
    gw1: &'a [f32], // 2 x H, row-major
    gb1: &'a [f32], // H
    gw2: &'a [f32], // H x 2
    gb2: &'a [f32], // 2
    cw1: &'a [f32], // 2 x H
    cb1: &'a [f32], // H
    cw2: &'a [f32], // H
    cb2: &'a [f32], // 1
}

fn wgan_split(params: &[f32]) -> WganParams<'_> {
    let h = WGAN_HIDDEN;
    let (gw1, rest) = params.split_at(2 * h);
    let (gb1, rest) = rest.split_at(h);
    let (gw2, rest) = rest.split_at(h * 2);
    let (gb2, rest) = rest.split_at(2);
    let (cw1, rest) = rest.split_at(2 * h);
    let (cb1, rest) = rest.split_at(h);
    let (cw2, cb2) = rest.split_at(h);
    WganParams { gw1, gb1, gw2, gb2, cw1, cb1, cw2, cb2 }
}

pub fn wgan_init_params(seed: i32) -> Vec<f32> {
    let mut rng = call_rng(seed, 0x57_47_41_4E);
    let h = WGAN_HIDDEN;
    let mut p = Vec::with_capacity(wgan_dim());
    // weights gaussian, biases zero — mirrors the jax initializer recipe
    for _ in 0..2 * h {
        p.push((rng.gaussian() * WGAN_INIT_SCALE) as f32);
    }
    p.extend(std::iter::repeat(0.0f32).take(h));
    for _ in 0..h * 2 {
        p.push((rng.gaussian() * WGAN_INIT_SCALE) as f32);
    }
    p.extend(std::iter::repeat(0.0f32).take(2));
    for _ in 0..2 * h {
        p.push((rng.gaussian() * WGAN_INIT_SCALE) as f32);
    }
    p.extend(std::iter::repeat(0.0f32).take(h));
    for _ in 0..h {
        p.push((rng.gaussian() * WGAN_INIT_SCALE) as f32);
    }
    p.push(0.0);
    debug_assert_eq!(p.len(), wgan_dim());
    p
}

fn real_point(rng: &mut Rng) -> [f64; 2] {
    let theta = rng.uniform() * std::f64::consts::TAU;
    let r = WGAN_RADIUS + rng.gaussian() * WGAN_RING_SIGMA;
    [r * theta.cos(), r * theta.sin()]
}

/// Generator forward: z -> (hidden activations, output point).
fn gen_forward(p: &WganParams, z: &[f64; 2], hg: &mut [f64]) -> [f64; 2] {
    let h = WGAN_HIDDEN;
    for j in 0..h {
        let a = z[0] * p.gw1[j] as f64 + z[1] * p.gw1[h + j] as f64 + p.gb1[j] as f64;
        hg[j] = a.tanh();
    }
    let mut out = [p.gb2[0] as f64, p.gb2[1] as f64];
    for j in 0..h {
        out[0] += hg[j] * p.gw2[j * 2] as f64;
        out[1] += hg[j] * p.gw2[j * 2 + 1] as f64;
    }
    out
}

/// Critic forward: x -> (hidden activations, score).
fn critic_forward(p: &WganParams, x: &[f64; 2], hc: &mut [f64]) -> f64 {
    let h = WGAN_HIDDEN;
    let mut f = p.cb2[0] as f64;
    for j in 0..h {
        let a = x[0] * p.cw1[j] as f64 + x[1] * p.cw1[h + j] as f64 + p.cb1[j] as f64;
        let t = a.tanh();
        hc[j] = t;
        f += t * p.cw2[j] as f64;
    }
    f
}

/// One stochastic dual-vector evaluation of the WGAN game at `params`:
/// returns (dual, g_loss, w_dist). The dual is the simultaneous-descent
/// field: generator block = grad of -E f(G(z)), critic block = grad of
/// -(E f(real) - E f(fake)) — descending it ascends the critic.
pub fn wgan_dual(params: &[f32], seed: i32) -> (Vec<f32>, f32, f32) {
    let h = WGAN_HIDDEN;
    let p = wgan_split(params);
    let mut rng = call_rng(seed, 0xD0_0D);
    let b = WGAN_BATCH;
    let bf = b as f64;

    let mut d_gw1 = vec![0.0f64; 2 * h];
    let mut d_gb1 = vec![0.0f64; h];
    let mut d_gw2 = vec![0.0f64; h * 2];
    let mut d_gb2 = [0.0f64; 2];
    let mut d_cw1 = vec![0.0f64; 2 * h];
    let mut d_cb1 = vec![0.0f64; h];
    let mut d_cw2 = vec![0.0f64; h];
    let mut d_cb2 = 0.0f64;

    let mut hg = vec![0.0f64; h];
    let mut hc = vec![0.0f64; h];
    let mut f_fake_acc = 0.0f64;
    let mut f_real_acc = 0.0f64;

    for _ in 0..b {
        // ---- fake sample: backprop through critic INTO the generator ----
        let z = [rng.gaussian(), rng.gaussian()];
        let xf = gen_forward(&p, &z, &mut hg);
        let f_fake = critic_forward(&p, &xf, &mut hc);
        f_fake_acc += f_fake;

        // critic loss d(E ff)/B contribution: +1/B toward L_c = E ff - E fr,
        // generator loss contribution: -1/B toward L_g = -E ff
        let gc = 1.0 / bf; // dL_c/df on fake
        let gg = -1.0 / bf; // dL_g/df on fake
        // shared backprop through the critic for both scalars
        let mut dx = [0.0f64; 2]; // dL_g/dx_fake
        for j in 0..h {
            let dt = 1.0 - hc[j] * hc[j];
            let w2 = p.cw2[j] as f64;
            // critic params (gc path)
            let da_c = gc * w2 * dt;
            d_cw2[j] += gc * hc[j];
            d_cb1[j] += da_c;
            d_cw1[j] += da_c * xf[0];
            d_cw1[h + j] += da_c * xf[1];
            // generator input (gg path)
            let da_g = gg * w2 * dt;
            dx[0] += da_g * p.cw1[j] as f64;
            dx[1] += da_g * p.cw1[h + j] as f64;
        }
        d_cb2 += gc;
        // generator backprop from dx
        for j in 0..h {
            let dhg = dx[0] * p.gw2[j * 2] as f64 + dx[1] * p.gw2[j * 2 + 1] as f64;
            d_gw2[j * 2] += hg[j] * dx[0];
            d_gw2[j * 2 + 1] += hg[j] * dx[1];
            let da = dhg * (1.0 - hg[j] * hg[j]);
            d_gb1[j] += da;
            d_gw1[j] += da * z[0];
            d_gw1[h + j] += da * z[1];
        }
        d_gb2[0] += dx[0];
        d_gb2[1] += dx[1];

        // ---- real sample: critic only -----------------------------------
        let xr = real_point(&mut rng);
        let f_real = critic_forward(&p, &xr, &mut hc);
        f_real_acc += f_real;
        let gr = -1.0 / bf; // dL_c/df on real (L_c = E ff - E fr)
        for j in 0..h {
            let dt = 1.0 - hc[j] * hc[j];
            let da = gr * p.cw2[j] as f64 * dt;
            d_cw2[j] += gr * hc[j];
            d_cb1[j] += da;
            d_cw1[j] += da * xr[0];
            d_cw1[h + j] += da * xr[1];
        }
        d_cb2 += gr;
    }

    let w_dist = (f_real_acc - f_fake_acc) / bf;
    let g_loss = -f_fake_acc / bf;

    let mut dual = Vec::with_capacity(wgan_dim());
    dual.extend(d_gw1.iter().map(|&x| x as f32));
    dual.extend(d_gb1.iter().map(|&x| x as f32));
    dual.extend(d_gw2.iter().map(|&x| x as f32));
    dual.extend(d_gb2.iter().map(|&x| x as f32));
    dual.extend(d_cw1.iter().map(|&x| x as f32));
    dual.extend(d_cb1.iter().map(|&x| x as f32));
    dual.extend(d_cw2.iter().map(|&x| x as f32));
    dual.push(d_cb2 as f32);
    (dual, g_loss as f32, w_dist as f32)
}

/// (fake, real) sample clouds, each `WGAN_SAMPLE_N` x 2 row-major.
pub fn wgan_samples(params: &[f32], seed: i32) -> (Vec<f32>, Vec<f32>) {
    let p = wgan_split(params);
    let mut rng = call_rng(seed, 0x5A_4D);
    let mut hg = vec![0.0f64; WGAN_HIDDEN];
    let mut fake = Vec::with_capacity(WGAN_SAMPLE_N * 2);
    let mut real = Vec::with_capacity(WGAN_SAMPLE_N * 2);
    for _ in 0..WGAN_SAMPLE_N {
        let z = [rng.gaussian(), rng.gaussian()];
        let xf = gen_forward(&p, &z, &mut hg);
        fake.push(xf[0] as f32);
        fake.push(xf[1] as f32);
        let xr = real_point(&mut rng);
        real.push(xr[0] as f32);
        real.push(xr[1] as f32);
    }
    (fake, real)
}

// ---------------------------------------------------------------------------
// Transformer-LM stand-in: embed -> "attention" mix -> norm scale -> ff ->
// output projection, next-token cross-entropy on the Markov corpus
// ---------------------------------------------------------------------------

pub const LM_VOCAB: usize = 48;
pub const LM_EMBED: usize = 16;
pub const LM_HIDDEN: usize = 32;
pub const LM_SEQ: usize = 16;
pub const LM_BATCH: usize = 16;
const LM_INIT_SCALE: f64 = 0.1;

/// Layer map of the flat LM parameter vector: covers every semantic type
/// the Figure 5 ablation masks on (embedding / attention / norm / ff /
/// bias), with true matrix shapes for PowerSGD.
pub fn lm_layer_map() -> LayerMap {
    let (v, e, h) = (LM_VOCAB, LM_EMBED, LM_HIDDEN);
    let mut map = LayerMap::from_spec(&[
        ("embed", v * e, "embedding"),
        ("attn.w", e * h, "attention"),
        ("attn.b", h, "bias"),
        ("norm.g", h, "norm"),
        ("ff.w", h * h, "ff"),
        ("ff.b", h, "bias"),
        ("out.w", h * v, "ff"),
        ("out.b", v, "bias"),
    ]);
    let shapes =
        [(v, e), (e, h), (h, 1), (h, 1), (h, h), (h, 1), (h, v), (v, 1)];
    for (l, &(r, c)) in map.layers.iter_mut().zip(&shapes) {
        l.rows = r;
        l.cols = c;
    }
    map.extra.insert("vocab".into(), v.to_string());
    map.extra.insert("seq".into(), LM_SEQ.to_string());
    map.extra.insert("batch".into(), LM_BATCH.to_string());
    map
}

pub fn lm_dim() -> usize {
    let (v, e, h) = (LM_VOCAB, LM_EMBED, LM_HIDDEN);
    v * e + e * h + h + h + h * h + h + h * v + v
}

struct LmParams<'a> {
    emb: &'a [f32],   // V x E
    aw: &'a [f32],    // E x H
    ab: &'a [f32],    // H
    ng: &'a [f32],    // H
    fw: &'a [f32],    // H x H
    fb: &'a [f32],    // H
    ow: &'a [f32],    // H x V
    ob: &'a [f32],    // V
}

fn lm_split(params: &[f32]) -> LmParams<'_> {
    let (v, e, h) = (LM_VOCAB, LM_EMBED, LM_HIDDEN);
    let (emb, rest) = params.split_at(v * e);
    let (aw, rest) = rest.split_at(e * h);
    let (ab, rest) = rest.split_at(h);
    let (ng, rest) = rest.split_at(h);
    let (fw, rest) = rest.split_at(h * h);
    let (fb, rest) = rest.split_at(h);
    let (ow, ob) = rest.split_at(h * v);
    LmParams { emb, aw, ab, ng, fw, fb, ow, ob }
}

pub fn lm_init_params(seed: i32) -> Vec<f32> {
    let (v, e, h) = (LM_VOCAB, LM_EMBED, LM_HIDDEN);
    let mut rng = call_rng(seed, 0x4C_4D);
    let mut p = Vec::with_capacity(lm_dim());
    for _ in 0..v * e {
        p.push((rng.gaussian() * LM_INIT_SCALE) as f32);
    }
    for _ in 0..e * h {
        p.push((rng.gaussian() * LM_INIT_SCALE) as f32);
    }
    p.extend(std::iter::repeat(0.0f32).take(h)); // attn.b
    p.extend(std::iter::repeat(1.0f32).take(h)); // norm.g starts at identity
    for _ in 0..h * h {
        p.push((rng.gaussian() * LM_INIT_SCALE) as f32);
    }
    p.extend(std::iter::repeat(0.0f32).take(h)); // ff.b
    for _ in 0..h * v {
        p.push((rng.gaussian() * LM_INIT_SCALE) as f32);
    }
    p.extend(std::iter::repeat(0.0f32).take(v)); // out.b
    debug_assert_eq!(p.len(), lm_dim());
    p
}

/// Forward + (optionally) backward over a token batch. `tokens` is
/// batch x (seq+1) row-major; position t predicts token t+1. Returns the
/// mean NLL; fills `grad_out` (len `lm_dim()`) when provided.
pub fn lm_loss_grad(params: &[f32], tokens: &[i32], mut grad_out: Option<&mut [f64]>) -> f64 {
    let (v, e, h) = (LM_VOCAB, LM_EMBED, LM_HIDDEN);
    let p = lm_split(params);
    let cols = LM_SEQ + 1;
    assert_eq!(tokens.len() % cols, 0, "tokens must be batch x (seq+1)");
    let rows = tokens.len() / cols;
    let n = rows * LM_SEQ;
    let nf = n as f64;

    if let Some(g) = grad_out.as_deref_mut() {
        assert_eq!(g.len(), lm_dim());
        g.iter_mut().for_each(|x| *x = 0.0);
    }

    let mut ev = vec![0.0f64; e];
    let mut a = vec![0.0f64; h];
    let mut hh = vec![0.0f64; h];
    let mut f = vec![0.0f64; h];
    let mut logits = vec![0.0f64; v];
    let mut probs = vec![0.0f64; v];
    let mut loss = 0.0f64;

    // grad section offsets in the flat vector
    let o_emb = 0;
    let o_aw = o_emb + v * e;
    let o_ab = o_aw + e * h;
    let o_ng = o_ab + h;
    let o_fw = o_ng + h;
    let o_fb = o_fw + h * h;
    let o_ow = o_fb + h;
    let o_ob = o_ow + h * v;

    for row in 0..rows {
        for t in 0..LM_SEQ {
            let x = tokens[row * cols + t] as usize;
            let y = tokens[row * cols + t + 1] as usize;
            assert!(x < v && y < v, "token out of vocab");
            // forward
            for j in 0..e {
                ev[j] = p.emb[x * e + j] as f64;
            }
            for j in 0..h {
                let mut acc = p.ab[j] as f64;
                for i in 0..e {
                    acc += ev[i] * p.aw[i * h + j] as f64;
                }
                a[j] = acc.tanh();
                hh[j] = a[j] * p.ng[j] as f64;
            }
            for j in 0..h {
                let mut acc = p.fb[j] as f64;
                for i in 0..h {
                    acc += hh[i] * p.fw[i * h + j] as f64;
                }
                f[j] = acc.tanh();
            }
            let mut maxl = f64::NEG_INFINITY;
            for c in 0..v {
                let mut acc = p.ob[c] as f64;
                for i in 0..h {
                    acc += f[i] * p.ow[i * v + c] as f64;
                }
                logits[c] = acc;
                maxl = maxl.max(acc);
            }
            let mut z = 0.0f64;
            for c in 0..v {
                probs[c] = (logits[c] - maxl).exp();
                z += probs[c];
            }
            loss += -(probs[y] / z).ln();

            let Some(g) = grad_out.as_deref_mut() else { continue };
            // backward: dL/dlogits = (softmax - onehot)/N
            let mut df = vec![0.0f64; h];
            for c in 0..v {
                let mut dl = probs[c] / z;
                if c == y {
                    dl -= 1.0;
                }
                dl /= nf;
                if dl == 0.0 {
                    continue;
                }
                g[o_ob + c] += dl;
                for i in 0..h {
                    g[o_ow + i * v + c] += f[i] * dl;
                    df[i] += p.ow[i * v + c] as f64 * dl;
                }
            }
            let mut dhh = vec![0.0f64; h];
            for j in 0..h {
                let dzf = df[j] * (1.0 - f[j] * f[j]);
                g[o_fb + j] += dzf;
                for i in 0..h {
                    g[o_fw + i * h + j] += hh[i] * dzf;
                    dhh[i] += p.fw[i * h + j] as f64 * dzf;
                }
            }
            let mut dev = vec![0.0f64; e];
            for j in 0..h {
                g[o_ng + j] += dhh[j] * a[j];
                let da = dhh[j] * p.ng[j] as f64;
                let dza = da * (1.0 - a[j] * a[j]);
                g[o_ab + j] += dza;
                for i in 0..e {
                    g[o_aw + i * h + j] += ev[i] * dza;
                    dev[i] += p.aw[i * h + j] as f64 * dza;
                }
            }
            for j in 0..e {
                g[o_emb + x * e + j] += dev[j];
            }
        }
    }
    loss / nf
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wgan_layout_consistent() {
        let map = wgan_layer_map();
        map.validate().unwrap();
        assert_eq!(map.dim, wgan_dim());
        assert!(map.dim > 1000);
        for l in &map.layers {
            assert_eq!(l.rows * l.cols, l.len, "{}", l.name);
        }
        let p = wgan_init_params(0);
        assert_eq!(p.len(), map.dim);
    }

    #[test]
    fn wgan_dual_deterministic_and_seed_sensitive() {
        let p = wgan_init_params(1);
        let (d1, _, _) = wgan_dual(&p, 7);
        let (d2, _, _) = wgan_dual(&p, 7);
        let (d3, _, _) = wgan_dual(&p, 8);
        assert_eq!(d1, d2);
        assert_ne!(d1, d3);
        assert!(d1.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn wgan_dual_matches_finite_difference() {
        // check a few random coordinates of the critic block against a
        // central finite difference of L_c = E f(fake) - E f(real)
        let p = wgan_init_params(3);
        let seed = 11;
        let (dual, _, _) = wgan_dual(&p, seed);
        let lc = |params: &[f32]| -> f64 {
            let (_, _g_loss, w_dist) = wgan_dual(params, seed);
            // L_c = E ff - E fr = (-g_loss) - (w_dist + (-g_loss)) ... derive
            // directly: w_dist = fr - ff, g_loss = -ff => ff = -g_loss,
            // fr = w_dist - g_loss; L_c = ff - fr = -w_dist
            -(w_dist as f64)
        };
        let eps = 1e-3f32;
        let gd = wgan_gen_dim();
        for &i in &[gd, gd + 37, gd + 2 * WGAN_HIDDEN + 5, wgan_dim() - 1] {
            let mut pp = p.clone();
            pp[i] += eps;
            let up = lc(&pp);
            pp[i] -= 2.0 * eps;
            let dn = lc(&pp);
            let fd = (up - dn) / (2.0 * eps as f64);
            let an = dual[i] as f64;
            assert!(
                (fd - an).abs() < 2e-2 * (1.0 + fd.abs().max(an.abs())),
                "coord {i}: fd {fd} vs analytic {an}"
            );
        }
    }

    #[test]
    fn wgan_real_points_on_mode_circle() {
        let p = wgan_init_params(0);
        let (fake, real) = wgan_samples(&p, 3);
        assert_eq!(fake.len(), WGAN_SAMPLE_N * 2);
        assert_eq!(real.len(), WGAN_SAMPLE_N * 2);
        for chunk in real.chunks(2) {
            let r = ((chunk[0] * chunk[0] + chunk[1] * chunk[1]) as f64).sqrt();
            assert!((r - WGAN_RADIUS).abs() < 0.5, "real point off-circle: {chunk:?}");
        }
    }

    #[test]
    fn lm_layout_and_init_loss() {
        let map = lm_layer_map();
        map.validate().unwrap();
        assert_eq!(map.dim, lm_dim());
        for l in &map.layers {
            assert_eq!(l.rows * l.cols, l.len, "{}", l.name);
        }
        let p = lm_init_params(0);
        let mut corpus = crate::lm::corpus::Corpus::new(LM_VOCAB, 7);
        let toks = corpus.batch(LM_BATCH, LM_SEQ);
        let loss = lm_loss_grad(&p, &toks, None);
        // near-uniform logits at init: loss ~ ln(vocab)
        assert!((loss - (LM_VOCAB as f64).ln()).abs() < 1.0, "{loss}");
    }

    #[test]
    fn lm_gradient_descends_on_same_batch() {
        let p = lm_init_params(0);
        let mut corpus = crate::lm::corpus::Corpus::new(LM_VOCAB, 9);
        let toks = corpus.batch(LM_BATCH, LM_SEQ);
        let mut g = vec![0.0f64; lm_dim()];
        let loss = lm_loss_grad(&p, &toks, Some(g.as_mut_slice()));
        let stepped: Vec<f32> =
            p.iter().zip(&g).map(|(pi, gi)| pi - 0.5 * *gi as f32).collect();
        let loss2 = lm_loss_grad(&stepped, &toks, None);
        assert!(loss2 < loss, "{loss} -> {loss2}");
    }

    #[test]
    fn lm_gradient_matches_finite_difference() {
        let p = lm_init_params(2);
        let mut corpus = crate::lm::corpus::Corpus::new(LM_VOCAB, 5);
        let toks = corpus.batch(2, 4);
        let mut g = vec![0.0f64; lm_dim()];
        lm_loss_grad(&p, &toks, Some(g.as_mut_slice()));
        let eps = 1e-3f32;
        // probe one coordinate in every parameter section
        let (v, e, h) = (LM_VOCAB, LM_EMBED, LM_HIDDEN);
        let probes = [
            toks[0] as usize * e, // embedding row actually touched
            v * e + 3,
            v * e + e * h + 1,
            v * e + e * h + h + 2,      // norm.g
            v * e + e * h + 2 * h + 5,  // ff.w
            lm_dim() - v + toks[1] as usize, // out.b of a seen target
        ];
        for &i in &probes {
            let mut pp = p.clone();
            pp[i] += eps;
            let up = lm_loss_grad(&pp, &toks, None);
            pp[i] -= 2.0 * eps;
            let dn = lm_loss_grad(&pp, &toks, None);
            let fd = (up - dn) / (2.0 * eps as f64);
            assert!(
                (fd - g[i]).abs() < 2e-2 * (1.0 + fd.abs().max(g[i].abs())),
                "coord {i}: fd {fd} vs analytic {}",
                g[i]
            );
        }
    }
}

//! PJRT runtime: load AOT-lowered HLO *text* artifacts and execute them on
//! the CPU client. This is the only boundary between L3 (rust) and the
//! L2/L1 graphs; Python never runs here.
//!
//! Interchange is HLO text — xla_extension 0.5.1 rejects jax>=0.5 protos
//! with 64-bit instruction ids, while the text parser reassigns ids
//! (see /opt/xla-example/README.md and DESIGN.md).

use anyhow::{Context, Result};

/// Thin wrapper over the PJRT CPU client.
pub struct Runtime {
    pub client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        Ok(Runtime { client: xla::PjRtClient::cpu().context("create PJRT CPU client")? })
    }

    /// Load + compile an HLO text artifact.
    pub fn load(&self, path: &std::path::Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))?;
        Ok(Executable { exe, name: path.display().to_string() })
    }

    /// Load an artifact by repo-relative name (e.g. "artifacts/wgan_op.hlo.txt").
    pub fn load_artifact(&self, rel: &str) -> Result<Executable> {
        let path = crate::util::repo_path(rel);
        anyhow::ensure!(
            path.exists(),
            "artifact {rel} not found — run `make artifacts` first"
        );
        self.load(&path)
    }
}

/// A compiled computation plus marshalling helpers.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    /// Execute with literal inputs; jax lowers with return_tuple=True so the
    /// single output is a tuple — returned here as a Vec of literals.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("execute {}", self.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetch result of {}", self.name))?;
        lit.to_tuple().context("untuple result")
    }
}

/// f32 vector -> rank-1 literal.
pub fn lit_f32(v: &[f32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

/// i32 scalar literal.
pub fn lit_i32_scalar(x: i32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// i32 matrix literal [rows, cols] from row-major data.
pub fn lit_i32_matrix(data: &[i32], rows: usize, cols: usize) -> Result<xla::Literal> {
    assert_eq!(data.len(), rows * cols);
    Ok(xla::Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
}

/// literal -> Vec<f32> (any shape, flattened).
pub fn to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// scalar literal -> f32.
pub fn to_f32_scalar(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

//! Histogram / empirical-CDF machinery for the adaptive level optimizer.
//!
//! The paper (Section 3.2, Eq. (2)–(3)) estimates the weighted marginal CDF
//! `F~^m(u)` of *normalized* coordinates of each layer type `m` from Z
//! sampled dual vectors, weighting sample z by `||g_z||_q^2`. We accumulate
//! these into a fixed-bin histogram over [0, 1]; the adaptive optimizer and
//! the L-GreCo DP both consume the resulting empirical CDF.

/// Fixed-bin weighted histogram over normalized magnitudes in [0, 1].
#[derive(Clone, Debug)]
pub struct NormalizedHistogram {
    bins: Vec<f64>,
    total: f64,
}

impl NormalizedHistogram {
    pub fn new(n_bins: usize) -> Self {
        assert!(n_bins >= 2);
        NormalizedHistogram { bins: vec![0.0; n_bins], total: 0.0 }
    }

    pub fn n_bins(&self) -> usize {
        self.bins.len()
    }

    /// Accumulate one sample's normalized magnitudes with weight `w`
    /// (the paper's lambda_z numerator ||g_z||_q^2).
    pub fn add_sample(&mut self, normalized: impl Iterator<Item = f64>, w: f64) {
        for u in normalized {
            self.add_one(u, w);
        }
    }

    /// Accumulate a single normalized magnitude with weight `w`. Exactly
    /// one iteration of `add_sample` — the fused encode kernel folds its
    /// statistics sweep through this so the two paths stay bit-identical.
    #[inline]
    pub fn add_one(&mut self, u: f64, w: f64) {
        let nb = self.bins.len() as f64;
        let u = u.clamp(0.0, 1.0);
        let idx = ((u * nb) as usize).min(self.bins.len() - 1);
        self.bins[idx] += w;
        self.total += w;
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0.0
    }

    pub fn total_weight(&self) -> f64 {
        self.total
    }

    /// Empirical CDF evaluated at `u` (piecewise-linear within bins).
    pub fn cdf(&self, u: f64) -> f64 {
        if self.total == 0.0 {
            return u.clamp(0.0, 1.0); // degenerate: pretend uniform
        }
        let u = u.clamp(0.0, 1.0);
        let nb = self.bins.len() as f64;
        let pos = u * nb;
        let idx = (pos as usize).min(self.bins.len() - 1);
        let frac = pos - idx as f64;
        let below: f64 = self.bins[..idx].iter().sum();
        (below + frac * self.bins[idx]) / self.total
    }

    /// Probability mass in [a, b).
    pub fn mass(&self, a: f64, b: f64) -> f64 {
        (self.cdf(b) - self.cdf(a)).max(0.0)
    }

    /// Mean of u restricted to [a, b) (bin-midpoint approximation),
    /// used by the Lloyd–Max style level refinement.
    pub fn conditional_mean(&self, a: f64, b: f64) -> f64 {
        let nb = self.bins.len();
        let (mut num, mut den) = (0.0, 0.0);
        for (i, &w) in self.bins.iter().enumerate() {
            let lo = i as f64 / nb as f64;
            let hi = (i + 1) as f64 / nb as f64;
            let il = lo.max(a);
            let ih = hi.min(b);
            if ih <= il {
                continue;
            }
            let frac = (ih - il) / (hi - lo);
            let mid = 0.5 * (il + ih);
            num += w * frac * mid;
            den += w * frac;
        }
        if den == 0.0 {
            0.5 * (a + b)
        } else {
            num / den
        }
    }

    /// Expected single-coordinate quantization variance
    /// ∫ sigma_Q^2(u; levels) dF(u) for the interval structure of `levels`
    /// (Eq. (2) integrand, bin-midpoint rule).
    pub fn expected_quant_variance(&self, levels: &[f64]) -> f64 {
        if self.total == 0.0 {
            return 0.0;
        }
        let nb = self.bins.len();
        let mut acc = 0.0;
        for (i, &w) in self.bins.iter().enumerate() {
            if w == 0.0 {
                continue;
            }
            let mid = (i as f64 + 0.5) / nb as f64;
            // find bracket
            let mut tau = 0usize;
            while tau + 2 < levels.len() && levels[tau + 1] <= mid {
                tau += 1;
            }
            let (lo, hi) = (levels[tau], levels[tau + 1]);
            acc += w * (hi - mid).max(0.0) * (mid - lo).max(0.0);
        }
        acc / self.total
    }

    pub fn merge(&mut self, other: &NormalizedHistogram) {
        assert_eq!(self.bins.len(), other.bins.len());
        for (a, b) in self.bins.iter_mut().zip(&other.bins) {
            *a += b;
        }
        self.total += other.total;
    }

    pub fn reset(&mut self) {
        self.bins.iter_mut().for_each(|b| *b = 0.0);
        self.total = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cdf_monotone_and_bounded() {
        let mut h = NormalizedHistogram::new(64);
        h.add_sample([0.1, 0.2, 0.2, 0.9].into_iter(), 1.0);
        let mut prev = 0.0;
        for i in 0..=100 {
            let u = i as f64 / 100.0;
            let c = h.cdf(u);
            assert!(c >= prev - 1e-12);
            assert!((0.0..=1.0 + 1e-12).contains(&c));
            prev = c;
        }
        assert!(h.cdf(1.0) > 0.999);
    }

    #[test]
    fn mass_splits() {
        let mut h = NormalizedHistogram::new(100);
        h.add_sample((0..1000).map(|i| i as f64 / 1000.0), 1.0);
        let m = h.mass(0.25, 0.75);
        assert!((m - 0.5).abs() < 0.02, "{m}");
    }

    #[test]
    fn weighting_matters() {
        let mut h = NormalizedHistogram::new(10);
        h.add_sample([0.05].into_iter(), 9.0);
        h.add_sample([0.95].into_iter(), 1.0);
        assert!((h.cdf(0.5) - 0.9).abs() < 1e-9);
    }

    #[test]
    fn conditional_mean_uniform() {
        let mut h = NormalizedHistogram::new(200);
        h.add_sample((0..10_000).map(|i| (i as f64 + 0.5) / 10_000.0), 1.0);
        let m = h.conditional_mean(0.2, 0.6);
        assert!((m - 0.4).abs() < 0.01, "{m}");
    }

    #[test]
    fn expected_variance_zero_when_levels_dense() {
        let mut h = NormalizedHistogram::new(50);
        h.add_sample([0.0, 0.5, 1.0].into_iter(), 1.0);
        // levels exactly on a fine uniform grid ⇒ tiny variance
        let levels: Vec<f64> = (0..=100).map(|i| i as f64 / 100.0).collect();
        let fine = h.expected_quant_variance(&levels);
        let coarse = h.expected_quant_variance(&[0.0, 1.0]);
        assert!(fine < coarse);
        assert!(coarse > 0.0);
    }

    #[test]
    fn merge_adds_mass() {
        let mut a = NormalizedHistogram::new(10);
        let mut b = NormalizedHistogram::new(10);
        a.add_sample([0.1].into_iter(), 1.0);
        b.add_sample([0.9].into_iter(), 1.0);
        a.merge(&b);
        assert!((a.total_weight() - 2.0).abs() < 1e-12);
        assert!((a.cdf(0.5) - 0.5).abs() < 1e-9);
    }
}

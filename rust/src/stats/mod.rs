//! Statistical substrates: deterministic RNG, histograms / empirical CDFs,
//! truncated-normal sufficient statistics (Remark 4.1 density estimation).

pub mod histogram;
pub mod rng;
pub mod truncnorm;

pub use histogram::NormalizedHistogram;
pub use rng::Rng;
pub use truncnorm::{Moments, TruncNorm};

/// Vector helpers shared across the crate (f64 host math).
pub mod vecops {
    /// L^q norm for q in {1, 2} or +inf (q <= 0 means inf).
    pub fn lq_norm(v: &[f32], q: f64) -> f64 {
        if q <= 0.0 || q.is_infinite() {
            v.iter().fold(0.0f64, |m, &x| m.max(x.abs() as f64))
        } else if q == 2.0 {
            v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
        } else if q == 1.0 {
            v.iter().map(|&x| x.abs() as f64).sum()
        } else {
            v.iter()
                .map(|&x| (x.abs() as f64).powf(q))
                .sum::<f64>()
                .powf(1.0 / q)
        }
    }

    pub fn l2_norm64(v: &[f64]) -> f64 {
        v.iter().map(|&x| x * x).sum::<f64>().sqrt()
    }

    pub fn dot64(a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| x * y).sum()
    }

    pub fn axpy(y: &mut [f64], alpha: f64, x: &[f64]) {
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi += alpha * xi;
        }
    }

    pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
        a.iter().zip(b).map(|(x, y)| x - y).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::vecops::*;

    #[test]
    fn lq_norms() {
        let v = [3.0f32, -4.0];
        assert!((lq_norm(&v, 2.0) - 5.0).abs() < 1e-9);
        assert!((lq_norm(&v, 1.0) - 7.0).abs() < 1e-9);
        assert!((lq_norm(&v, f64::INFINITY) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn axpy_works() {
        let mut y = vec![1.0, 2.0];
        axpy(&mut y, 2.0, &[10.0, 20.0]);
        assert_eq!(y, vec![21.0, 42.0]);
    }
}

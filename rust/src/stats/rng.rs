//! Deterministic PRNG (splitmix64 seeding + xoshiro256++), plus Gaussian
//! sampling via Box–Muller. Implemented in-tree because the environment is
//! offline (no `rand` crate); the generator is the reference xoshiro256++
//! from Blackman & Vigna and is unit-tested against its published vectors.

/// splitmix64 — used to expand a single u64 seed into xoshiro state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller variate
    gauss_spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent stream (for per-node / per-layer seeding).
    pub fn fork(&mut self, stream: u64) -> Rng {
        let base = self.next_u64();
        Rng::new(base ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = (s[0].wrapping_add(s[3]))
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's method without bias for our (non-cryptographic) needs.
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.gauss_spare = Some(r * s);
            return r * c;
        }
    }

    pub fn fill_gaussian(&mut self, out: &mut [f64], mean: f64, std: f64) {
        for v in out.iter_mut() {
            *v = mean + std * self.gaussian();
        }
    }

    pub fn gaussian_vec(&mut self, n: usize, mean: f64, std: f64) -> Vec<f64> {
        let mut v = vec![0.0; n];
        self.fill_gaussian(&mut v, mean, std);
        v
    }

    pub fn uniform_vec_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.uniform_f32()).collect()
    }

    /// Random permutation index (Fisher–Yates shuffle).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xoshiro_reference_vectors() {
        // Reference: seed state {1,2,3,4} produces this sequence
        // (computed from the published C implementation of xoshiro256++).
        let mut r = Rng { s: [1, 2, 3, 4], gauss_spare: None };
        let got: Vec<u64> = (0..4).map(|_| r.next_u64()).collect();
        assert_eq!(got, vec![41943041, 58720359, 3588806011781223, 3591011842654386]);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_decorrelate() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut mean = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            mean += u;
        }
        mean /= n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.gaussian();
            m1 += g;
            m2 += g * g;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.02, "{m1}");
        assert!((m2 - 1.0).abs() < 0.03, "{m2}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            let x = r.below(8) as usize;
            assert!(x < 8);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn fork_streams_differ() {
        let mut root = Rng::new(5);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}

//! Truncated-normal parametric density estimation (Remark 4.1).
//!
//! Faghri et al. (2020) model normalized gradient magnitudes with a
//! truncated normal on [0, 1] and fit it from cheap sufficient statistics
//! (first two moments). The coordinator uses this as the parametric
//! alternative to the histogram CDF when choosing the update-step set U:
//! a large shift in fitted (mu, sigma) triggers a level re-optimization.

/// Standard normal pdf.
#[inline]
pub fn phi(x: f64) -> f64 {
    (-0.5 * x * x).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Standard normal cdf via erf (Abramowitz–Stegun 7.1.26, |err| < 1.5e-7).
pub fn norm_cdf(x: f64) -> f64 {
    let t = 1.0 / (1.0 + 0.2316419 * x.abs());
    let poly = t
        * (0.319381530
            + t * (-0.356563782 + t * (1.781477937 + t * (-1.821255978 + t * 1.330274429))));
    let tail = phi(x.abs()) * poly;
    if x >= 0.0 {
        1.0 - tail
    } else {
        tail
    }
}

/// Normal distribution truncated to [a, b].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TruncNorm {
    pub mu: f64,
    pub sigma: f64,
    pub a: f64,
    pub b: f64,
}

impl TruncNorm {
    pub fn new(mu: f64, sigma: f64, a: f64, b: f64) -> Self {
        assert!(b > a && sigma > 0.0);
        TruncNorm { mu, sigma, a, b }
    }

    fn alpha(&self) -> f64 {
        (self.a - self.mu) / self.sigma
    }

    fn beta(&self) -> f64 {
        (self.b - self.mu) / self.sigma
    }

    fn z(&self) -> f64 {
        (norm_cdf(self.beta()) - norm_cdf(self.alpha())).max(1e-300)
    }

    pub fn cdf(&self, x: f64) -> f64 {
        if x <= self.a {
            return 0.0;
        }
        if x >= self.b {
            return 1.0;
        }
        let xi = (x - self.mu) / self.sigma;
        ((norm_cdf(xi) - norm_cdf(self.alpha())) / self.z()).clamp(0.0, 1.0)
    }

    pub fn mean(&self) -> f64 {
        let (al, be) = (self.alpha(), self.beta());
        self.mu + self.sigma * (phi(al) - phi(be)) / self.z()
    }

    pub fn var(&self) -> f64 {
        let (al, be) = (self.alpha(), self.beta());
        let z = self.z();
        let t1 = (al * phi(al) - be * phi(be)) / z;
        let t2 = (phi(al) - phi(be)) / z;
        (self.sigma * self.sigma) * (1.0 + t1 - t2 * t2)
    }

    /// Moment-match a truncated normal on [0,1] to sample mean/variance of
    /// normalized magnitudes. Crude two-pass fixed-point on (mu, sigma) —
    /// this is the "efficiently computing sufficient statistics" estimator;
    /// it only needs to be good enough to *detect distribution drift*.
    pub fn fit_unit(sample_mean: f64, sample_var: f64) -> TruncNorm {
        let mut mu = sample_mean.clamp(1e-4, 1.0 - 1e-4);
        let mut sigma = sample_var.max(1e-8).sqrt();
        for _ in 0..32 {
            let t = TruncNorm::new(mu, sigma, 0.0, 1.0);
            let (m, v) = (t.mean(), t.var());
            mu += 0.7 * (sample_mean - m);
            sigma *= ((sample_var / v.max(1e-12)).sqrt()).clamp(0.5, 2.0).powf(0.5);
            mu = mu.clamp(-2.0, 2.0);
            sigma = sigma.clamp(1e-6, 10.0);
        }
        TruncNorm::new(mu, sigma, 0.0, 1.0)
    }

    /// Symmetric drift measure between two fits (used to decide whether a
    /// step belongs to the update set U).
    pub fn drift(&self, other: &TruncNorm) -> f64 {
        let dm = (self.mean() - other.mean()).abs();
        let dv = (self.var().sqrt() - other.var().sqrt()).abs();
        dm + dv
    }
}

/// Streaming sufficient statistics (count, mean, M2) — Welford.
#[derive(Clone, Copy, Debug, Default)]
pub struct Moments {
    pub n: u64,
    pub mean: f64,
    m2: f64,
}

impl Moments {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn merge(&mut self, o: &Moments) {
        if o.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = *o;
            return;
        }
        let n = (self.n + o.n) as f64;
        let d = o.mean - self.mean;
        self.mean += d * o.n as f64 / n;
        self.m2 += o.m2 + d * d * (self.n as f64) * (o.n as f64) / n;
        self.n += o.n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::rng::Rng;

    #[test]
    fn norm_cdf_reference_values() {
        assert!((norm_cdf(0.0) - 0.5).abs() < 1e-7);
        assert!((norm_cdf(1.0) - 0.8413447).abs() < 1e-5);
        assert!((norm_cdf(-1.96) - 0.0249979).abs() < 1e-5);
        assert!((norm_cdf(3.0) - 0.9986501).abs() < 1e-5);
    }

    #[test]
    fn truncnorm_cdf_endpoints() {
        let t = TruncNorm::new(0.3, 0.2, 0.0, 1.0);
        assert_eq!(t.cdf(-0.1), 0.0);
        assert_eq!(t.cdf(1.1), 1.0);
        assert!(t.cdf(0.3) > 0.3 && t.cdf(0.3) < 0.7);
    }

    #[test]
    fn truncnorm_mean_inside_support() {
        let t = TruncNorm::new(-0.5, 0.4, 0.0, 1.0);
        let m = t.mean();
        assert!(m > 0.0 && m < 1.0, "{m}");
    }

    #[test]
    fn fit_recovers_moments_roughly() {
        let t0 = TruncNorm::new(0.35, 0.15, 0.0, 1.0);
        let (m, v) = (t0.mean(), t0.var());
        let fit = TruncNorm::fit_unit(m, v);
        assert!((fit.mean() - m).abs() < 0.02, "{} vs {}", fit.mean(), m);
        assert!((fit.var() - v).abs() < 0.01);
    }

    #[test]
    fn moments_welford_matches_naive() {
        let mut r = Rng::new(1);
        let xs: Vec<f64> = (0..1000).map(|_| r.uniform() * 3.0).collect();
        let mut mo = Moments::default();
        xs.iter().for_each(|&x| mo.push(x));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!((mo.mean - mean).abs() < 1e-10);
        assert!((mo.var() - var).abs() < 1e-10);
    }

    #[test]
    fn moments_merge_equals_bulk() {
        let mut r = Rng::new(2);
        let xs: Vec<f64> = (0..500).map(|_| r.gaussian()).collect();
        let mut all = Moments::default();
        xs.iter().for_each(|&x| all.push(x));
        let mut a = Moments::default();
        let mut b = Moments::default();
        xs[..200].iter().for_each(|&x| a.push(x));
        xs[200..].iter().for_each(|&x| b.push(x));
        a.merge(&b);
        assert!((a.mean - all.mean).abs() < 1e-10);
        assert!((a.var() - all.var()).abs() < 1e-10);
        assert_eq!(a.n, all.n);
    }

    #[test]
    fn drift_detects_change() {
        let a = TruncNorm::fit_unit(0.2, 0.01);
        let b = TruncNorm::fit_unit(0.5, 0.04);
        let c = TruncNorm::fit_unit(0.2001, 0.0101);
        assert!(a.drift(&b) > 10.0 * a.drift(&c));
    }
}

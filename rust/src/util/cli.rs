//! Minimal CLI argument parsing (offline environment: no clap).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::HashMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Self {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    out.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects usize, got {v}")))
            .unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects u64, got {v}")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects f64, got {v}")))
            .unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key)
            .map(|v| matches!(v, "true" | "1" | "yes"))
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_flags() {
        let a = parse(&["cmd", "--steps", "100", "--fast", "--k=4", "pos2"]);
        assert_eq!(a.positional, vec!["cmd", "pos2"]);
        assert_eq!(a.usize_or("steps", 0), 100);
        assert!(a.has("fast"));
        assert!(a.bool_or("fast", false));
        assert_eq!(a.usize_or("k", 0), 4);
        assert_eq!(a.usize_or("missing", 7), 7);
    }

    #[test]
    fn floats_and_strings() {
        let a = parse(&["--lr", "0.5", "--name", "abc"]);
        assert_eq!(a.f64_or("lr", 0.0), 0.5);
        assert_eq!(a.get_or("name", ""), "abc");
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["--verbose"]);
        assert!(a.has("verbose"));
    }
}

//! Minimal CLI argument parsing (offline environment: no clap).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.
//! Malformed values surface as [`Error`](crate::util::error::Error)s
//! (`Err`, never `panic!`) so `main` can print usage and exit nonzero
//! instead of aborting with a backtrace.

use crate::util::error::{Error, Result};
use std::collections::HashMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Self {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    out.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Parse `--key` as `T`, falling back to `default` when absent. A
    /// present-but-malformed value is an error, not a panic.
    fn parse_or<T: std::str::FromStr>(&self, key: &str, kind: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                Error::msg(format!("--{key} expects {kind}, got {v:?}"))
            }),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        self.parse_or(key, "a non-negative integer", default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        self.parse_or(key, "a non-negative integer", default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        self.parse_or(key, "a number", default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key)
            .map(|v| matches!(v, "true" | "1" | "yes"))
            .unwrap_or(default)
    }

    /// Parse `--key` as a comma-separated list of `T`.
    pub fn list_or<T: std::str::FromStr>(
        &self,
        key: &str,
        default: Vec<T>,
    ) -> Result<Vec<T>> {
        match self.get(key) {
            None => Ok(default),
            Some(list) => list
                .split(',')
                .map(|v| {
                    v.trim().parse().map_err(|_| {
                        Error::msg(format!(
                            "--{key} expects a comma-separated list, got {v:?}"
                        ))
                    })
                })
                .collect(),
        }
    }

    /// The value of `--key` (or `default`), validated against an allowlist.
    pub fn one_of(&self, key: &str, default: &str, allowed: &[&str]) -> Result<String> {
        let v = self.get_or(key, default);
        if allowed.contains(&v.as_str()) {
            Ok(v)
        } else {
            Err(Error::msg(format!(
                "--{key} expects {}, got {v:?}",
                allowed.join("|")
            )))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_flags() {
        let a = parse(&["cmd", "--steps", "100", "--fast", "--k=4", "pos2"]);
        assert_eq!(a.positional, vec!["cmd", "pos2"]);
        assert_eq!(a.usize_or("steps", 0).unwrap(), 100);
        assert!(a.has("fast"));
        assert!(a.bool_or("fast", false));
        assert_eq!(a.usize_or("k", 0).unwrap(), 4);
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
    }

    #[test]
    fn floats_and_strings() {
        let a = parse(&["--lr", "0.5", "--name", "abc"]);
        assert_eq!(a.f64_or("lr", 0.0).unwrap(), 0.5);
        assert_eq!(a.get_or("name", ""), "abc");
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["--verbose"]);
        assert!(a.has("verbose"));
    }

    #[test]
    fn malformed_values_error_instead_of_panicking() {
        let a = parse(&["--steps", "ten", "--lr", "fast", "--seed", "-3"]);
        let e = a.usize_or("steps", 0).unwrap_err();
        assert!(e.to_string().contains("--steps"), "{e}");
        assert!(a.f64_or("lr", 0.0).is_err());
        assert!(a.u64_or("seed", 0).is_err());
        // absent flags still fall back to the default
        assert_eq!(a.usize_or("absent", 9).unwrap(), 9);
    }

    #[test]
    fn lists_and_allowlists() {
        let a = parse(&["--checkpoints", "10, 20,50", "--solver", "qoda"]);
        assert_eq!(a.list_or("checkpoints", vec![0usize]).unwrap(), vec![10, 20, 50]);
        assert_eq!(a.list_or("absent", vec![7usize]).unwrap(), vec![7]);
        assert!(parse(&["--checkpoints", "a,b"])
            .list_or::<usize>("checkpoints", vec![])
            .is_err());
        assert_eq!(a.one_of("solver", "qoda", &["qoda", "qgenx"]).unwrap(), "qoda");
        assert!(a.one_of("solver", "qoda", &["adam"]).is_err());
        assert_eq!(a.one_of("absent", "main", &["main", "alt"]).unwrap(), "main");
    }
}

//! Minimal in-tree error handling (offline environment: no `anyhow`).
//!
//! `Error` is a message-chain error; `Context` adds `.context()` /
//! `.with_context()` on `Result` and `Option`; the crate-level `ensure!` /
//! `bail!` macros mirror the usual idiom.

use std::fmt;

/// A human-readable error with an optional source.
#[derive(Debug)]
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    pub fn msg(m: impl Into<String>) -> Self {
        Error { msg: m.into(), source: None }
    }

    pub fn wrap(
        m: impl Into<String>,
        source: impl std::error::Error + Send + Sync + 'static,
    ) -> Self {
        Error { msg: m.into(), source: Some(Box::new(source)) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        self.source.as_deref().map(|s| s as _)
    }
}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error::msg(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Self {
        Error::msg(s)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::wrap(e.to_string(), e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context("...")` / `.with_context(|| ...)` on results and options.
pub trait Context<T> {
    fn context<C: Into<String>>(self, msg: C) -> Result<T>;
    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: Into<String>>(self, msg: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", msg.into())))
    }

    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Into<String>>(self, msg: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg.into()))
    }

    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Return early with an `Err(Error)` when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr) => {
        if !($cond) {
            return Err($crate::util::error::Error::msg(format!(
                "condition failed: {}",
                stringify!($cond)
            ))
            .into());
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::util::error::Error::msg(format!($($arg)+)).into());
        }
    };
}

/// Return early with an `Err(Error)`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::util::error::Error::msg(format!($($arg)+)).into())
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> std::result::Result<u32, std::num::ParseIntError> {
        "x".parse::<u32>()
    }

    #[test]
    fn context_chains_messages() {
        let e = fails().context("parse knob").unwrap_err();
        assert!(e.to_string().starts_with("parse knob: "));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert_eq!(v.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }

    #[test]
    fn ensure_and_bail() {
        fn f(x: u32) -> Result<u32> {
            crate::ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                crate::bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(f(5).unwrap_err().to_string(), "five is right out");
    }
}

//! In-tree infrastructure (the environment is offline; see Cargo.toml).

pub mod cli;
pub mod error;
pub mod prop;
pub mod table;

use std::time::Instant;

/// Measure wall-clock of a closure in seconds.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Median of a slice (copies; fine for small stat vectors).
pub fn median(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Repo-root-relative path resolution: works from the crate root or any
/// subdirectory cargo runs us from (benches/tests/examples share this).
pub fn repo_path(rel: &str) -> std::path::PathBuf {
    let direct = std::path::PathBuf::from(rel);
    if direct.exists() {
        return direct;
    }
    if let Ok(mut dir) = std::env::current_dir() {
        loop {
            let cand = dir.join(rel);
            if cand.exists() {
                return cand;
            }
            if !dir.pop() {
                break;
            }
        }
    }
    if let Ok(manifest) = std::env::var("CARGO_MANIFEST_DIR") {
        let cand = std::path::Path::new(&manifest).join(rel);
        if cand.exists() {
            return cand;
        }
    }
    direct
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn stddev_zero_for_constant() {
        assert_eq!(stddev(&[2.0, 2.0, 2.0]), 0.0);
    }
}

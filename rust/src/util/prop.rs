//! Minimal property-based testing helper (offline environment: no proptest).
//!
//! `for_cases(n, seed, |gen| ...)` runs a property over `n` randomized cases
//! with a deterministic, reported seed per case — on failure the panic
//! message names the case index and seed so it can be replayed with
//! `Gen::new(seed)`.

use crate::stats::rng::Rng;

/// Random-input generator handed to each property case.
pub struct Gen {
    pub rng: Rng,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { rng: Rng::new(seed) }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.uniform() * (hi - lo)
    }

    pub fn vec_f32(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| (self.rng.gaussian() as f32) * scale).collect()
    }

    pub fn vec_f64(&mut self, n: usize, scale: f64) -> Vec<f64> {
        (0..n).map(|_| self.rng.gaussian() * scale).collect()
    }

    /// Strictly increasing inner levels in (0,1): a valid level sequence
    /// [0, l_1 < .. < l_alpha, 1].
    pub fn level_sequence(&mut self, max_inner: usize) -> Vec<f64> {
        let alpha = self.usize_in(1, max_inner);
        let mut inner: Vec<f64> = (0..alpha).map(|_| self.f64_in(0.01, 0.99)).collect();
        inner.sort_by(|a, b| a.partial_cmp(b).unwrap());
        inner.dedup_by(|a, b| (*a - *b).abs() < 1e-6);
        let mut seq = vec![0.0];
        seq.extend(inner);
        seq.push(1.0);
        seq
    }

    pub fn uniforms_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.rng.uniform_f32()).collect()
    }
}

/// Run `prop` over `n` deterministic random cases derived from `seed`.
///
/// Under Miri (CI's nightly UB-check job) the case count is capped: the
/// interpreter is orders of magnitude slower than native, and two cases per
/// property already exercise every code path the UB check cares about.
pub fn for_cases(n: usize, seed: u64, mut prop: impl FnMut(&mut Gen)) {
    let n = if cfg!(miri) { n.min(2) } else { n };
    for case in 0..n {
        let case_seed = seed
            .wrapping_mul(0x100000001B3)
            .wrapping_add((case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let mut g = Gen::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut g);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("property failed at case {case} (Gen seed {case_seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cases_are_deterministic() {
        let mut firsts = Vec::new();
        for _ in 0..2 {
            let mut v = Vec::new();
            for_cases(3, 42, |g| v.push(g.rng.next_u64()));
            firsts.push(v);
        }
        assert_eq!(firsts[0], firsts[1]);
    }

    #[test]
    fn level_sequence_valid() {
        for_cases(50, 7, |g| {
            let seq = g.level_sequence(12);
            assert_eq!(seq[0], 0.0);
            assert_eq!(*seq.last().unwrap(), 1.0);
            for w in seq.windows(2) {
                assert!(w[1] > w[0], "{seq:?}");
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed at case")]
    fn failure_reports_case() {
        for_cases(5, 1, |g| {
            let x = g.usize_in(0, 10);
            assert!(x < 100); // passes
            if x % 1 == 0 {
                // always; force failure on case 0
                panic!("boom");
            }
        });
    }
}

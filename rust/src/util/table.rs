//! Plain-text table / CSV rendering for the experiment harnesses.
//! Every paper table regenerator prints through this so the output rows
//! match the paper's layout and also land in results/*.csv.

use std::fmt::Write as _;
use std::io::Write as _;

#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) {
        self.row(&cells.iter().map(|c| format!("{c}")).collect::<Vec<_>>());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "== {} ==", self.title);
        }
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", line(&self.header, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (widths.len() - 1);
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.header.join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.join(","));
        }
        out
    }

    /// Write CSV under results/ (created on demand), path relative to repo.
    pub fn save_csv(&self, name: &str) -> std::io::Result<std::path::PathBuf> {
        let dir = crate::util::repo_path("results");
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(name);
        let mut f = std::fs::File::create(&path)?;
        f.write_all(self.to_csv().as_bytes())?;
        Ok(path)
    }
}

/// CSV series writer for figure-style outputs (step, series1, series2, ...).
pub fn save_series_csv(
    name: &str,
    header: &[&str],
    rows: &[Vec<f64>],
) -> std::io::Result<std::path::PathBuf> {
    let mut t = Table::new("", header);
    for r in rows {
        t.row(&r.iter().map(|x| format!("{x}")).collect::<Vec<_>>());
    }
    t.save_csv(name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["mode", "ms"]);
        t.row(&["baseline".into(), "251".into()]);
        t.row(&["QODA5".into(), "195".into()]);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.contains("baseline"));
        assert!(s.lines().count() == 5);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        let csv = t.to_csv();
        assert_eq!(csv, "a,b\n1,2\n");
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["1".into()]);
    }
}

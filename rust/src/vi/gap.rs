//! Restricted gap function (GAP): GAP_X(x̂) = sup_{x in X} <A(x), x̂ - x>
//! over the compact test domain X = B(center, radius).
//!
//! For the affine operators of the rate harness this is a (possibly
//! indefinite) quadratic maximization over a ball; we solve it with
//! multi-restart projected gradient ascent and verify against closed forms
//! where they exist (constant operator: GAP = <A, x̂ - c> + R ||A||).

use super::operator::Operator;
use crate::stats::rng::Rng;
use crate::stats::vecops::{dot64, l2_norm64, sub};

pub struct GapEvaluator<'a> {
    pub op: &'a dyn Operator,
    pub center: Vec<f64>,
    pub radius: f64,
    pub restarts: usize,
    pub iters: usize,
}

impl<'a> GapEvaluator<'a> {
    pub fn new(op: &'a dyn Operator, center: Vec<f64>, radius: f64) -> Self {
        GapEvaluator { op, center, radius, restarts: 6, iters: 200 }
    }

    /// Trade accuracy for speed: fewer restarts/ascent iterations. In-run
    /// evaluation schedules (the driver's `GapPolicy`, early stopping on a
    /// gap threshold) use this to keep the per-step cost bounded.
    pub fn budget(mut self, restarts: usize, iters: usize) -> Self {
        assert!(restarts >= 1 && iters >= 1);
        self.restarts = restarts;
        self.iters = iters;
        self
    }

    fn project(&self, x: &mut [f64]) {
        let diff = sub(x, &self.center);
        let n = l2_norm64(&diff);
        if n > self.radius {
            let s = self.radius / n;
            for (xi, (ci, di)) in x.iter_mut().zip(self.center.iter().zip(&diff)) {
                *xi = ci + s * di;
            }
        }
    }

    /// phi(x) = <A(x), x_hat - x> (the objective being maximized over x).
    fn phi(&self, x: &[f64], x_hat: &[f64]) -> f64 {
        let a = self.op.apply_vec(x);
        dot64(&a, &sub(x_hat, x))
    }

    /// Numerical gradient of phi at x (central differences). Operators here
    /// are cheap (affine); this keeps the evaluator operator-agnostic.
    fn grad_phi(&self, x: &[f64], x_hat: &[f64], out: &mut [f64]) {
        let h = 1e-5;
        let mut xp = x.to_vec();
        for i in 0..x.len() {
            let x0 = xp[i];
            xp[i] = x0 + h;
            let fp = self.phi(&xp, x_hat);
            xp[i] = x0 - h;
            let fm = self.phi(&xp, x_hat);
            xp[i] = x0;
            out[i] = (fp - fm) / (2.0 * h);
        }
    }

    /// Evaluate GAP_X(x_hat) >= 0 (0 iff x_hat solves the VI when X contains
    /// a neighbourhood of it — Prop B.1).
    pub fn eval(&self, x_hat: &[f64]) -> f64 {
        let d = self.op.dim();
        let mut rng = Rng::new(0xA5A5);
        let mut best = f64::NEG_INFINITY;
        for restart in 0..self.restarts {
            let mut x: Vec<f64> = match restart {
                0 => self.center.clone(),
                1 => x_hat.to_vec(),
                _ => {
                    let dir: Vec<f64> = (0..d).map(|_| rng.gaussian()).collect();
                    let n = l2_norm64(&dir).max(1e-12);
                    self.center
                        .iter()
                        .zip(&dir)
                        .map(|(c, g)| c + self.radius * g / n)
                        .collect()
                }
            };
            self.project(&mut x);
            let mut grad = vec![0.0; d];
            let mut step = self.radius * 0.2;
            let mut fx = self.phi(&x, x_hat);
            for _ in 0..self.iters {
                self.grad_phi(&x, x_hat, &mut grad);
                let gn = l2_norm64(&grad);
                if gn < 1e-12 {
                    break;
                }
                let mut cand = x.clone();
                for (ci, gi) in cand.iter_mut().zip(&grad) {
                    *ci += step * gi / gn;
                }
                self.project(&mut cand);
                let fc = self.phi(&cand, x_hat);
                if fc > fx {
                    x = cand;
                    fx = fc;
                    step *= 1.1;
                } else {
                    step *= 0.5;
                    if step < 1e-10 {
                        break;
                    }
                }
            }
            best = best.max(fx);
        }
        best.max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::rng::Rng;
    use crate::vi::operator::{BilinearGame, QuadraticOperator};

    struct ConstOp {
        a: Vec<f64>,
    }
    impl Operator for ConstOp {
        fn dim(&self) -> usize {
            self.a.len()
        }
        fn apply(&self, _x: &[f64], out: &mut [f64]) {
            out.copy_from_slice(&self.a);
        }
    }

    #[test]
    fn matches_closed_form_for_constant_operator() {
        // GAP = sup_{x in B(c,R)} <a, x̂ - x> = <a, x̂ - c> + R||a||
        let a = vec![1.0, -2.0, 0.5];
        let op = ConstOp { a: a.clone() };
        let center = vec![0.1, 0.2, -0.3];
        let radius = 1.5;
        let gap = GapEvaluator::new(&op, center.clone(), radius);
        let x_hat = vec![0.5, 0.5, 0.5];
        let want = dot64(&a, &sub(&x_hat, &center)) + radius * l2_norm64(&a);
        let got = gap.eval(&x_hat);
        assert!((got - want).abs() < 1e-3 * want.abs().max(1.0), "{got} vs {want}");
    }

    #[test]
    fn budgeted_evaluator_still_matches_closed_form() {
        let a = vec![1.0, -2.0, 0.5];
        let op = ConstOp { a: a.clone() };
        let center = vec![0.0, 0.0, 0.0];
        let radius = 1.0;
        // the constant-operator maximizer is a projection: even a tiny
        // budget lands on it
        let gap = GapEvaluator::new(&op, center.clone(), radius).budget(2, 60);
        let x_hat = vec![0.2, -0.1, 0.3];
        let want = dot64(&a, &sub(&x_hat, &center)) + radius * l2_norm64(&a);
        let got = gap.eval(&x_hat);
        assert!((got - want).abs() < 5e-3 * want.abs().max(1.0), "{got} vs {want}");
    }

    #[test]
    fn gap_nonnegative_and_zero_at_solution() {
        let mut rng = Rng::new(1);
        let op = QuadraticOperator::random(6, 0.5, &mut rng);
        let sol = op.sol.clone();
        let gap = GapEvaluator::new(&op, sol.clone(), 1.0);
        let g_at_sol = gap.eval(&sol);
        assert!(g_at_sol >= 0.0);
        assert!(g_at_sol < 1e-4, "{g_at_sol}");
        // a far point has positive gap
        let far: Vec<f64> = sol.iter().map(|s| s + 2.0).collect();
        assert!(gap.eval(&far) > 0.1);
    }

    #[test]
    fn gap_decreases_toward_solution_bilinear() {
        let mut rng = Rng::new(2);
        let op = BilinearGame::random(4, &mut rng);
        let sol = op.solution().unwrap();
        let gap = GapEvaluator::new(&op, sol.clone(), 2.0);
        let far: Vec<f64> = sol.iter().map(|_| 1.5).collect();
        let near: Vec<f64> = sol.iter().map(|_| 0.1).collect();
        let gf = gap.eval(&far);
        let gn = gap.eval(&near);
        assert!(gn < gf, "{gn} vs {gf}");
        assert!(gap.eval(&sol) < 1e-4);
    }
}

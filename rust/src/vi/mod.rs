//! Variational-inequality substrate (Section 2): operators and canonical
//! monotone test problems, stochastic oracles with the paper's three noise
//! models, and the restricted gap function evaluator.

pub mod gap;
pub mod noise;
pub mod operator;

pub use gap::GapEvaluator;
pub use noise::{NoiseModel, Oracle};
pub use operator::{BilinearGame, Operator, QuadraticOperator};

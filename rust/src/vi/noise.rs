//! Stochastic first-order oracles (Section 2.4): absolute noise
//! (Assumption 2.4), relative noise (Assumption 2.5), and the
//! almost-surely-bounded variant (Assumption 6.1).

use super::operator::Operator;
use crate::stats::rng::Rng;
use crate::stats::vecops::l2_norm64;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum NoiseModel {
    /// E||U||^2 <= sigma^2 (i.i.d. Gaussian per coordinate)
    Absolute { sigma: f64 },
    /// E||U||^2 <= sigma_R ||A(x)||^2 (Gaussian scaled by operator norm)
    Relative { sigma_r: f64 },
    /// absolute noise truncated so ||g|| <= j_bound a.s. (Assumption 6.1)
    BoundedAbsolute { sigma: f64, j_bound: f64 },
    None,
}

/// A stochastic oracle g(x; omega) = A(x) + U(x; omega) for one node.
pub struct Oracle<'a> {
    pub op: &'a dyn Operator,
    pub noise: NoiseModel,
    pub rng: Rng,
    /// count of oracle calls (gradient computations) for cost accounting
    pub calls: u64,
}

impl<'a> Oracle<'a> {
    pub fn new(op: &'a dyn Operator, noise: NoiseModel, seed: u64) -> Self {
        Oracle { op, noise, rng: Rng::new(seed), calls: 0 }
    }

    /// Draw g(x; omega).
    pub fn sample(&mut self, x: &[f64]) -> Vec<f64> {
        self.calls += 1;
        let mut g = self.op.apply_vec(x);
        let d = g.len() as f64;
        match self.noise {
            NoiseModel::None => {}
            NoiseModel::Absolute { sigma } => {
                // per-coordinate std sigma/sqrt(d) so E||U||^2 = sigma^2
                let s = sigma / d.sqrt();
                for v in g.iter_mut() {
                    *v += s * self.rng.gaussian();
                }
            }
            NoiseModel::Relative { sigma_r } => {
                let an = l2_norm64(&g);
                let s = (sigma_r.sqrt() * an) / d.sqrt();
                for v in g.iter_mut() {
                    *v += s * self.rng.gaussian();
                }
            }
            NoiseModel::BoundedAbsolute { sigma, j_bound } => {
                let s = sigma / d.sqrt();
                for v in g.iter_mut() {
                    *v += s * self.rng.gaussian();
                }
                let n = l2_norm64(&g);
                if n > j_bound {
                    let scale = j_bound / n;
                    for v in g.iter_mut() {
                        *v *= scale;
                    }
                }
            }
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::rng::Rng;
    use crate::vi::operator::QuadraticOperator;

    fn op() -> QuadraticOperator {
        let mut rng = Rng::new(1);
        QuadraticOperator::random(8, 0.5, &mut rng)
    }

    #[test]
    fn unbiasedness() {
        let q = op();
        let x = vec![0.3; 8];
        let mean_a = q.apply_vec(&x);
        let mut oracle = Oracle::new(&q, NoiseModel::Absolute { sigma: 1.0 }, 2);
        let reps = 20_000;
        let mut acc = vec![0.0; 8];
        for _ in 0..reps {
            let g = oracle.sample(&x);
            for (a, v) in acc.iter_mut().zip(&g) {
                *a += v;
            }
        }
        for (a, m) in acc.iter().zip(&mean_a) {
            assert!((a / reps as f64 - m).abs() < 0.02);
        }
        assert_eq!(oracle.calls, reps);
    }

    #[test]
    fn absolute_variance_calibrated() {
        let q = op();
        let x = vec![1.0; 8];
        let a = q.apply_vec(&x);
        let sigma = 0.7;
        let mut oracle = Oracle::new(&q, NoiseModel::Absolute { sigma }, 3);
        let reps = 20_000;
        let mut acc = 0.0;
        for _ in 0..reps {
            let g = oracle.sample(&x);
            acc += g.iter().zip(&a).map(|(gi, ai)| (gi - ai).powi(2)).sum::<f64>();
        }
        let emp = acc / reps as f64;
        assert!((emp - sigma * sigma).abs() < 0.05 * sigma * sigma, "{emp}");
    }

    #[test]
    fn relative_noise_vanishes_at_solution() {
        let q = op();
        let sol = q.sol.clone();
        let mut oracle = Oracle::new(&q, NoiseModel::Relative { sigma_r: 1.0 }, 4);
        let g = oracle.sample(&sol);
        // A(x*) = 0 => relative noise = 0 => g = 0
        assert!(l2_norm64(&g) < 1e-9, "{g:?}");
        // far from the solution the noise is nonzero
        let far = vec![5.0; 8];
        let g1 = oracle.sample(&far);
        let g2 = oracle.sample(&far);
        assert!(l2_norm64(&crate::stats::vecops::sub(&g1, &g2)) > 1e-6);
    }

    #[test]
    fn bounded_oracle_respects_bound() {
        let q = op();
        let mut oracle =
            Oracle::new(&q, NoiseModel::BoundedAbsolute { sigma: 10.0, j_bound: 3.0 }, 5);
        for i in 0..200 {
            let x = vec![i as f64 / 10.0; 8];
            let g = oracle.sample(&x);
            assert!(l2_norm64(&g) <= 3.0 + 1e-9);
        }
    }
}

//! VI operators (Section 2.3): the deterministic mean operator A and the
//! canonical monotone test problems used by the rate-verification harness
//! (bilinear saddle games, strongly-monotone quadratics, co-coercive
//! gradient fields).

/// A deterministic operator A: R^d -> R^d.
pub trait Operator: Send + Sync {
    fn dim(&self) -> usize;

    /// y = A(x)
    fn apply(&self, x: &[f64], out: &mut [f64]);

    fn apply_vec(&self, x: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.dim()];
        self.apply(x, &mut out);
        out
    }

    /// A known solution x* (for GAP test-domain placement), if available.
    fn solution(&self) -> Option<Vec<f64>> {
        None
    }

    /// Lipschitz constant, if known.
    fn lipschitz(&self) -> Option<f64> {
        None
    }
}

/// Bilinear saddle game: min_x max_y x^T B y (+ b^T x - c^T y).
/// Operator A(x, y) = (B y + b, -B^T x + c) — monotone, *not* co-coercive
/// (the Section 6 motivating class).
pub struct BilinearGame {
    pub n: usize,
    /// row-major n x n matrix B
    pub b_mat: Vec<f64>,
    pub b: Vec<f64>,
    pub c: Vec<f64>,
}

impl BilinearGame {
    /// Random well-conditioned instance with solution at the origin.
    pub fn random(n: usize, rng: &mut crate::stats::rng::Rng) -> Self {
        let mut b_mat = vec![0.0; n * n];
        for v in b_mat.iter_mut() {
            *v = rng.gaussian() / (n as f64).sqrt();
        }
        // strengthen the diagonal so B is nonsingular (unique saddle at 0)
        for i in 0..n {
            b_mat[i * n + i] += 1.0;
        }
        BilinearGame { n, b_mat, b: vec![0.0; n], c: vec![0.0; n] }
    }

    fn bx(&self, y: &[f64], out: &mut [f64]) {
        for i in 0..self.n {
            let row = &self.b_mat[i * self.n..(i + 1) * self.n];
            out[i] = row.iter().zip(y).map(|(a, b)| a * b).sum::<f64>();
        }
    }

    fn btx(&self, x: &[f64], out: &mut [f64]) {
        for j in 0..self.n {
            out[j] = (0..self.n).map(|i| self.b_mat[i * self.n + j] * x[i]).sum();
        }
    }

    /// Operator 2-norm of B (power iteration) — the Lipschitz constant.
    pub fn spectral_norm(&self) -> f64 {
        let mut v = vec![1.0 / (self.n as f64).sqrt(); self.n];
        let mut tmp = vec![0.0; self.n];
        let mut tmp2 = vec![0.0; self.n];
        let mut sigma = 0.0;
        for _ in 0..100 {
            self.bx(&v, &mut tmp); // B v
            self.btx(&tmp, &mut tmp2); // B^T B v
            let norm = crate::stats::vecops::l2_norm64(&tmp2);
            if norm == 0.0 {
                return 0.0;
            }
            for (vi, ti) in v.iter_mut().zip(&tmp2) {
                *vi = ti / norm;
            }
            sigma = norm.sqrt();
        }
        sigma
    }
}

impl Operator for BilinearGame {
    fn dim(&self) -> usize {
        2 * self.n
    }

    fn apply(&self, x: &[f64], out: &mut [f64]) {
        let (xs, ys) = x.split_at(self.n);
        let (ox, oy) = out.split_at_mut(self.n);
        self.bx(ys, ox);
        for (o, b) in ox.iter_mut().zip(&self.b) {
            *o += b;
        }
        self.btx(xs, oy);
        for (o, c) in oy.iter_mut().zip(&self.c) {
            *o = -*o + c;
        }
    }

    fn solution(&self) -> Option<Vec<f64>> {
        // with b = c = 0 and B nonsingular the unique solution is 0
        if self.b.iter().all(|&v| v == 0.0) && self.c.iter().all(|&v| v == 0.0) {
            Some(vec![0.0; 2 * self.n])
        } else {
            None
        }
    }

    fn lipschitz(&self) -> Option<f64> {
        Some(self.spectral_norm())
    }
}

/// Strongly monotone quadratic operator A(x) = M x - r with M = S + mu I,
/// S = G^T G / n PSD: the gradient field of a strongly convex quadratic —
/// monotone, Lipschitz AND co-coercive with beta = 1/L.
pub struct QuadraticOperator {
    pub d: usize,
    /// row-major d x d SPD matrix
    pub m: Vec<f64>,
    pub r: Vec<f64>,
    pub sol: Vec<f64>,
    lip: f64,
    pub mu: f64,
}

impl QuadraticOperator {
    pub fn random(d: usize, mu: f64, rng: &mut crate::stats::rng::Rng) -> Self {
        // M = G^T G / d + mu I
        let mut g = vec![0.0; d * d];
        for v in g.iter_mut() {
            *v = rng.gaussian();
        }
        let mut m = vec![0.0; d * d];
        for i in 0..d {
            for j in 0..d {
                let mut acc = 0.0;
                for k in 0..d {
                    acc += g[k * d + i] * g[k * d + j];
                }
                m[i * d + j] = acc / d as f64 + if i == j { mu } else { 0.0 };
            }
        }
        // solution x* random, r = M x*
        let sol: Vec<f64> = (0..d).map(|_| rng.gaussian()).collect();
        let mut r = vec![0.0; d];
        for i in 0..d {
            r[i] = m[i * d..(i + 1) * d].iter().zip(&sol).map(|(a, b)| a * b).sum();
        }
        // power iteration for the Lipschitz constant
        let mut v = vec![1.0 / (d as f64).sqrt(); d];
        let mut lip = 0.0;
        for _ in 0..100 {
            let mut t = vec![0.0; d];
            for i in 0..d {
                t[i] = m[i * d..(i + 1) * d].iter().zip(&v).map(|(a, b)| a * b).sum();
            }
            let norm = crate::stats::vecops::l2_norm64(&t);
            for (vi, ti) in v.iter_mut().zip(&t) {
                *vi = ti / norm;
            }
            lip = norm;
        }
        QuadraticOperator { d, m, r, sol, lip, mu }
    }

    /// Co-coercivity modulus beta = 1 / L for gradient fields.
    pub fn beta(&self) -> f64 {
        1.0 / self.lip
    }
}

impl Operator for QuadraticOperator {
    fn dim(&self) -> usize {
        self.d
    }

    fn apply(&self, x: &[f64], out: &mut [f64]) {
        for i in 0..self.d {
            let row = &self.m[i * self.d..(i + 1) * self.d];
            out[i] = row.iter().zip(x).map(|(a, b)| a * b).sum::<f64>() - self.r[i];
        }
    }

    fn solution(&self) -> Option<Vec<f64>> {
        Some(self.sol.clone())
    }

    fn lipschitz(&self) -> Option<f64> {
        Some(self.lip)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::rng::Rng;
    use crate::stats::vecops::{dot64, sub};
    use crate::util::prop::for_cases;

    #[test]
    fn bilinear_is_monotone() {
        // <A(x) - A(x'), x - x'> >= 0 (equals 0 exactly for bilinear)
        for_cases(20, 3, |g| {
            let mut rng = Rng::new(g.rng.next_u64());
            let op = BilinearGame::random(6, &mut rng);
            let x = g.vec_f64(12, 2.0);
            let y = g.vec_f64(12, 2.0);
            let d = dot64(&sub(&op.apply_vec(&x), &op.apply_vec(&y)), &sub(&x, &y));
            assert!(d >= -1e-9, "{d}");
        });
    }

    #[test]
    fn bilinear_solution_is_zero_of_operator() {
        let mut rng = Rng::new(1);
        let op = BilinearGame::random(8, &mut rng);
        let sol = op.solution().unwrap();
        let a = op.apply_vec(&sol);
        assert!(crate::stats::vecops::l2_norm64(&a) < 1e-12);
    }

    #[test]
    fn quadratic_strongly_monotone_and_cocoercive() {
        for_cases(10, 5, |g| {
            let mut rng = Rng::new(g.rng.next_u64());
            let op = QuadraticOperator::random(8, 0.5, &mut rng);
            let x = g.vec_f64(8, 2.0);
            let y = g.vec_f64(8, 2.0);
            let ax = op.apply_vec(&x);
            let ay = op.apply_vec(&y);
            let inner = dot64(&sub(&ax, &ay), &sub(&x, &y));
            let dxy2: f64 = sub(&x, &y).iter().map(|v| v * v).sum();
            let da2: f64 = sub(&ax, &ay).iter().map(|v| v * v).sum();
            // strong monotonicity with mu = 0.5
            assert!(inner >= 0.5 * dxy2 - 1e-9);
            // co-coercivity with beta = 1/L
            assert!(inner >= op.beta() * da2 - 1e-6, "{inner} vs {}", op.beta() * da2);
        });
    }

    #[test]
    fn quadratic_solution_zeroes_operator() {
        let mut rng = Rng::new(2);
        let op = QuadraticOperator::random(10, 0.1, &mut rng);
        let a = op.apply_vec(&op.solution().unwrap());
        assert!(crate::stats::vecops::l2_norm64(&a) < 1e-9, "{a:?}");
    }

    #[test]
    fn lipschitz_bound_holds() {
        for_cases(10, 7, |g| {
            let mut rng = Rng::new(g.rng.next_u64());
            let op = QuadraticOperator::random(8, 0.3, &mut rng);
            let l = op.lipschitz().unwrap();
            let x = g.vec_f64(8, 1.0);
            let y = g.vec_f64(8, 1.0);
            let da = crate::stats::vecops::l2_norm64(&sub(
                &op.apply_vec(&x),
                &op.apply_vec(&y),
            ));
            let dx = crate::stats::vecops::l2_norm64(&sub(&x, &y));
            assert!(da <= l * dx * (1.0 + 1e-6) + 1e-9);
        });
    }

    #[test]
    fn bilinear_not_cocoercive() {
        // For pure bilinear (skew) parts, <A(x)-A(y), x-y> = 0 while
        // ||A(x)-A(y)|| > 0 — co-coercivity fails for any beta > 0.
        let op = BilinearGame {
            n: 1,
            b_mat: vec![1.0],
            b: vec![0.0],
            c: vec![0.0],
        };
        let x = vec![1.0, 0.0];
        let y = vec![0.0, 0.0];
        let inner = dot64(&sub(&op.apply_vec(&x), &op.apply_vec(&y)), &sub(&x, &y));
        let da2: f64 = sub(&op.apply_vec(&x), &op.apply_vec(&y))
            .iter()
            .map(|v| v * v)
            .sum();
        assert!(inner.abs() < 1e-12);
        assert!(da2 > 0.5);
    }
}
